"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
artifacts/dryrun/*.json.

    PYTHONPATH=src python scripts/make_experiments_tables.py [tag]
"""
from __future__ import annotations

import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(tag: str = "") -> dict[tuple, dict]:
    cells = {}
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        parts = os.path.basename(path)[: -len(".json")].split("__")
        if tag and (len(parts) != 4 or parts[3] != tag):
            continue
        if not tag and len(parts) != 3:
            continue
        with open(path) as f:
            d = json.load(f)
        cells[(parts[0], parts[1], parts[2])] = d
    return cells


def fmt_cell(d: dict) -> str:
    if d.get("skipped"):
        return "— (skip)"
    if not d.get("ok"):
        return "**FAIL**"
    r = d["roofline"]
    mem_gib = r["memory"]["peak_bytes"] / 2**30
    return (f"ok, {d['compile_s']:.0f}s compile, {mem_gib:.1f} GiB/dev")


def dryrun_table(cells) -> str:
    archs = sorted({k[0] for k in cells})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    out = ["| arch | " + " | ".join(f"{s} (single / multi)" for s in shapes) + " |",
           "|---" * (len(shapes) + 1) + "|"]
    for a in archs:
        row = [a]
        for s in shapes:
            single = cells.get((a, s, "single"))
            multi = cells.get((a, s, "multi"))
            f = lambda d: ("—" if d is None else
                           ("skip" if d.get("skipped") else
                            ("OK" if d.get("ok") else "FAIL")))
            row.append(f"{f(single)} / {f(multi)}")
        out.append("| " + " | ".join(row) + " |")
    return "\n".join(out)


def roofline_table(cells, mesh="single") -> str:
    hdr = ("| arch / shape | comp (s) | mem (s) | mem-kern (s) | coll (s) | "
           "dominant | useful | HBM GiB/dev | fits 16G |")
    out = [hdr, "|---" * 9 + "|"]
    for (a, s, m), d in sorted(cells.items()):
        if m != mesh or d.get("skipped") or not d.get("ok"):
            continue
        r = d["roofline"]
        gib = r["memory"]["peak_bytes"] / 2**30
        mk = r.get("memory_s_kernel", r["memory_s"])
        out.append(
            f"| {a}/{s} | {r['compute_s']:.2f} | {r['memory_s']:.2f} | "
            f"{mk:.2f} | {r['collective_s']:.2f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {gib:.1f} | "
            f"{'yes' if gib <= 16 else 'NO'} |"
        )
    return "\n".join(out)


def summary(cells) -> str:
    ok = sum(1 for d in cells.values() if d.get("ok") and not d.get("skipped"))
    skip = sum(1 for d in cells.values() if d.get("skipped"))
    fail = sum(1 for d in cells.values() if not d.get("ok"))
    fits = sum(1 for d in cells.values()
               if d.get("ok") and not d.get("skipped")
               and d["roofline"]["memory"]["peak_bytes"] / 2**30 <= 16)
    return f"{ok} ok ({fits} fit 16 GiB HBM), {skip} documented skips, {fail} failures"


if __name__ == "__main__":
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    cells = load(tag)
    print(f"## cells (tag={tag or 'baseline'}): {summary(cells)}\n")
    print(dryrun_table(cells))
    print()
    for mesh in ("single", "multi"):
        print(f"### roofline — {mesh} pod\n")
        print(roofline_table(cells, mesh))
        print()
