"""Quick dev smoke: forward + loss + grad + decode for every reduced arch."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer as T

which = sys.argv[1:] or ARCH_IDS

for aid in which:
    cfg = get_arch(aid).reduced()
    key = jax.random.key(0)
    params = T.init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    b, s = 2, 64
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab),
        "targets": jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.ones((b, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.encoder is not None:
        batch["frame_embeds"] = jnp.ones(
            (b, cfg.encoder.n_frames, cfg.d_model), jnp.float32
        )

    loss, metrics = jax.jit(lambda p, ba: T.loss_fn(cfg, p, ba))(params, batch)
    grads = jax.jit(jax.grad(lambda p, ba: T.loss_fn(cfg, p, ba)[0]))(params, batch)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(loss)), aid
    assert np.isfinite(float(gn)), aid

    # decode 3 tokens
    cache = T.init_cache(cfg, b, 128)
    logits, cache = jax.jit(lambda p, t, c: T.prefill(cfg, p, t, c,
        frame_embeds=batch.get("frame_embeds"), patch_embeds=batch.get("patch_embeds")))(
        params, batch["tokens"], cache)
    assert logits.shape == (b, 1, cfg.vocab), (aid, logits.shape)
    pos = jnp.asarray(s, jnp.int32)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(3):
        logits, cache = jax.jit(lambda p, t, c, po: T.decode_step(cfg, p, t, c, po))(
            params, tok, cache, pos + i)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), aid
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    print(f"{aid:30s} OK  loss={float(loss):.3f} gnorm={float(gn):.3f} params={n_params:,}")
print("ALL OK")
