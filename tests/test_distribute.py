"""Property tests of the block→device assignment layer (core.distribute).

Host-side properties only — single device, no mesh: permutation algebra,
greedy balance, determinism, cache-key discipline, and the bit-exact
apply/undo round-trip on concrete matrices.  The distributed half (every
engine x rectangular/uneven-L mesh under every mode, shard→unshard
round-trips, the tuned auto path) runs in the ``tests/_dist.py``
subprocess as ``check_assignment`` (see test_distributed.py).

Runs under real hypothesis when installed, else the deterministic
fixed-example fallback from conftest.py.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bsm as B
from repro.core import distribute as D

SETTINGS = dict(max_examples=25, deadline=None)


def _counts(nb: int, seed: int, hub: bool = False) -> np.ndarray:
    """A reproducible mask-product count matrix (optionally hub-skewed)."""
    rng = np.random.default_rng(seed)
    mask = rng.random((nb, nb)) < 0.3
    if hub:
        mask[: max(nb // 8, 1)] = True  # dense hub rows, natural order
    mask[np.arange(nb), np.arange(nb)] = True
    return D.product_counts(mask, mask)


# ---- Assignment object -----------------------------------------------------


def test_identity_assignment():
    asg = D.identity_assignment(6)
    assert asg.is_identity and asg.nb == 6
    assert asg.key == ("identity",)
    assert asg.inv == asg.perm
    asg.validate(6, 6)
    with pytest.raises(ValueError):
        asg.validate(6, 8)  # non-square grid
    with pytest.raises(ValueError):
        asg.validate(4, 4)  # wrong length


def test_unknown_mode_rejected():
    with pytest.raises(ValueError):
        D.Assignment("bogus", (0, 1))
    with pytest.raises(ValueError):
        D.assignment_for("bogus", _counts(4, 0), (2, 2))


def test_validate_rejects_non_permutation():
    with pytest.raises(ValueError):
        D.Assignment("randomized", (0, 0, 1, 2)).validate(4, 4)


@settings(**SETTINGS)
@given(nb=st.sampled_from([4, 8, 12, 16]), seed=st.integers(0, 5))
def test_inverse_property(nb, seed):
    asg = D.randomized_assignment(nb, seed)
    x = np.arange(nb * nb).reshape(nb, nb)
    p = np.asarray(asg.perm)
    inv = np.asarray(asg.inv)
    np.testing.assert_array_equal(x[p][inv], x)
    np.testing.assert_array_equal(x[p][:, p][inv][:, inv], x)


@settings(**SETTINGS)
@given(nb=st.sampled_from([4, 8, 16]), seed=st.integers(0, 3))
def test_key_separates_permutations(nb, seed):
    a = D.randomized_assignment(nb, seed)
    b = D.randomized_assignment(nb, seed + 101)
    assert a.key == D.randomized_assignment(nb, seed).key  # deterministic
    if a.perm != b.perm:
        assert a.key != b.key  # distinct perms never share a program key
    assert D.identity_assignment(nb).key == ("identity",)


# ---- derivation determinism ------------------------------------------------


@settings(**SETTINGS)
@given(
    mode=st.sampled_from(["identity", "randomized", "nnz_greedy"]),
    nb=st.sampled_from([8, 16]),
    seed=st.integers(0, 3),
)
def test_assignment_for_is_deterministic(mode, nb, seed):
    """Every layer (tuner, DB rehydration, execution) must derive the
    identical permutation from the same counts."""
    c = _counts(nb, seed, hub=True)
    a1 = D.assignment_for(mode, c, (2, 2))
    a2 = D.assignment_for(mode, c.copy(), (2, 2))
    assert a1 == a2
    a1.validate(nb, nb)
    assert sorted(a1.perm) == list(range(nb))
    # a different pattern gives the randomized mode a different seed
    if mode == "randomized":
        other = D.assignment_for(mode, _counts(nb, seed + 7), (2, 2))
        assert other.perm != a1.perm or nb <= 4


def test_assignment_for_rejects_rectangular():
    with pytest.raises(ValueError):
        D.assignment_for("nnz_greedy", np.ones((4, 6), np.int64), (2, 2))
    # identity tolerates anything (it never permutes)
    asg = D.assignment_for("identity", np.ones((4, 6), np.int64), (2, 2))
    assert asg.is_identity


def test_balance_bins_divisibility():
    assert D.balance_bins(8, 2, 2) == 2
    assert D.balance_bins(24, 2, 3) == 6
    with pytest.raises(ValueError):
        D.balance_bins(8, 2, 3)  # lcm=6 does not divide 8


# ---- greedy balance --------------------------------------------------------


@settings(**SETTINGS)
@given(nb=st.sampled_from([16, 32, 64]), seed=st.integers(0, 5),
       p=st.sampled_from([2, 4]))
def test_greedy_never_worse_than_identity_on_hubs(nb, seed, p):
    """On hub-skewed counts the greedy packer's per-device product-load
    imbalance is <= the identity layout's (the point of the layer).

    Square grids with several blocks per bin only: the packer balances
    the 1-D row+column weight, which tracks the 2-D device load once bins
    hold enough blocks — tiny bins (nb=8, cap 4) can jitter either way,
    which is exactly why the tuner MEASURES candidates instead of
    trusting the heuristic."""
    c = _counts(nb, seed, hub=True)
    asg = D.nnz_greedy_assignment(c, p, p)
    id_imb = D.assignment_imbalance(c, (p, p))
    gr_imb = D.assignment_imbalance(c, (p, p), asg)
    assert gr_imb <= id_imb + 1e-9, (id_imb, gr_imb)


def test_greedy_flattens_zipf_hubs_materially():
    """The design-target workload: natural-order zipf hub rows.  Identity
    is materially imbalanced (>2x), greedy lands within the ISSUE's
    <=1.3x gate."""
    from repro.tuner.corpus import CorpusEntry

    z = CorpusEntry("zipf_hub", "zipf", 32, 8, occupancy=0.15,
                    zipf_alpha=1.4, seed=15)
    c = D.product_counts(*z.masks())
    asg = D.nnz_greedy_assignment(c, 4, 4)
    assert D.assignment_imbalance(c, (4, 4)) > 2.0
    assert D.assignment_imbalance(c, (4, 4), asg) <= 1.3


@settings(**SETTINGS)
@given(nb=st.sampled_from([8, 16]), p=st.sampled_from([(2, 2), (2, 4), (4, 2)]))
def test_greedy_bins_have_fixed_cardinality(nb, p):
    """Equal-cardinality bins: the permuted grid still divides the mesh
    (shard divisibility is preserved by construction)."""
    p_r, p_c = p
    if nb % D.balance_bins(nb, p_r, p_c):
        return
    c = _counts(nb, 3, hub=True)
    asg = D.nnz_greedy_assignment(c, p_r, p_c)
    g = D.balance_bins(nb, p_r, p_c)
    cap = nb // g
    # every consecutive cap-slice of the perm is one bin
    assert len(asg.perm) == nb
    assert sorted(asg.perm) == list(range(nb))
    assert len(set(asg.perm[:cap])) == cap


def test_device_product_loads_sums_to_total():
    c = _counts(16, 1, hub=True)
    loads = D.device_product_loads(c, 4, 4)
    assert loads.shape == (4, 4)
    assert int(loads.sum()) == int(c.sum())
    perm = D.nnz_greedy_assignment(c, 4, 4).perm
    loads_p = D.device_product_loads(c, 4, 4, perm=perm)
    assert int(loads_p.sum()) == int(c.sum())  # permutation moves, not drops


def test_load_imbalance_empty_pattern():
    assert D.load_imbalance(np.zeros((8, 8), np.int64), 2, 2) == 1.0


# ---- apply / undo on concrete matrices -------------------------------------


@settings(**SETTINGS)
@given(
    mode=st.sampled_from(["randomized", "nnz_greedy"]),
    seed=st.integers(0, 3),
)
def test_apply_undo_round_trip_bit_exact(mode, seed):
    """distribute → undistribute is pure reindexing: bit-exact."""
    m = B.random_bsm(jax.random.key(seed), nb=8, bs=4, occupancy=0.4)
    c = D.product_counts(np.asarray(m.mask), np.asarray(m.mask))
    asg = D.assignment_for(mode, c, (2, 2))
    back = D.undo_assignment(D.apply_assignment(m, asg), asg)
    np.testing.assert_array_equal(np.asarray(back.blocks),
                                  np.asarray(m.blocks))
    np.testing.assert_array_equal(np.asarray(back.mask), np.asarray(m.mask))
    np.testing.assert_array_equal(np.asarray(back.norms), np.asarray(m.norms))


def test_apply_assignment_permutes_symmetrically():
    m = B.random_bsm(jax.random.key(0), nb=8, bs=4, occupancy=0.5)
    asg = D.randomized_assignment(8, 3)
    p = np.asarray(asg.perm)
    got = D.apply_assignment(m, asg)
    np.testing.assert_array_equal(np.asarray(got.mask),
                                  np.asarray(m.mask)[p][:, p])
    # A' = P A Pᵀ on the dense matrix: the permuted BSM densifies to the
    # row+column-permuted dense matrix (block granularity)
    d = np.asarray(m.to_dense()).reshape(8, 4, 8, 4)
    np.testing.assert_array_equal(
        np.asarray(got.to_dense()).reshape(8, 4, 8, 4), d[p][:, :, p])


def test_multiplication_closure():
    """A' B' = P (A B) Pᵀ: one symmetric permutation serves a whole chain."""
    a = B.random_bsm(jax.random.key(1), nb=8, bs=4, occupancy=0.5)
    b = B.random_bsm(jax.random.key(2), nb=8, bs=4, occupancy=0.5)
    asg = D.randomized_assignment(8, 9)
    from repro.core.engine import multiply_reference

    c = multiply_reference(a, b)
    cp = multiply_reference(D.apply_assignment(a, asg),
                            D.apply_assignment(b, asg))
    np.testing.assert_allclose(
        np.asarray(D.undo_assignment(cp, asg).to_dense()),
        np.asarray(c.to_dense()), rtol=1e-5, atol=1e-6)


def test_identity_fixed_point():
    """P I Pᵀ = I — chains can shard the identity under any assignment."""
    ident = B.identity(8, 4)
    asg = D.randomized_assignment(8, 5)
    got = D.apply_assignment(ident, asg)
    np.testing.assert_array_equal(np.asarray(got.to_dense()),
                                  np.asarray(ident.to_dense()))


# ---- permute_cube ----------------------------------------------------------


@settings(**SETTINGS)
@given(seed=st.integers(0, 4))
def test_permute_cube_matches_pointwise(seed):
    rng = np.random.default_rng(seed)
    ok = rng.random((6, 6, 6)) < 0.4
    perm = tuple(int(i) for i in rng.permutation(6))
    got = D.permute_cube(ok, perm)
    for _ in range(10):
        i, k, j = rng.integers(0, 6, 3)
        assert got[i, k, j] == ok[perm[i], perm[k], perm[j]]


def test_permute_cube_capacity_soundness():
    """The permuted cube's per-device bound covers the permuted pattern —
    deriving from the identity layout can under-cover a hot panel (the
    silent-truncation hazard the engine/tuner code guards against)."""
    from repro.core import plan as plan_mod

    m = B.random_bsm(jax.random.key(3), nb=8, bs=4, occupancy=0.3)
    mask = np.asarray(m.mask).copy()
    mask[:2] = True  # hub rows
    am = mask
    ok = am[:, :, None] & am[None, :, :]
    asg = D.nnz_greedy_assignment(D.product_counts(am, am), 2, 2)
    ok_p = D.permute_cube(ok, asg.perm)
    # per-(r,c)-device max product count in each layout
    def dev_max(cube):
        t = cube.reshape(2, 4, 8, 2, 4).sum(axis=(1, 2, 4))
        return int(t.max())

    # soundness: the permuted bound covers the permuted pattern exactly
    assert int(ok_p.sum()) == int(ok.sum())
    assert dev_max(ok_p) <= dev_max(ok)  # balancing never raises the max
    del plan_mod
