"""Per-architecture smoke tests (reduced configs, CPU, single device).

For each assigned architecture: instantiate a REDUCED config of the same
family and run one forward/train step asserting output shapes + no NaNs,
plus prefill/decode consistency.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer as T

_B, _S = 2, 64


def _batch(cfg, key=0):
    ks = jax.random.split(jax.random.key(key), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (_B, _S), 0, cfg.vocab),
        "targets": jax.random.randint(ks[1], (_B, _S), 0, cfg.vocab),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = (
            jax.random.normal(ks[2], (_B, cfg.n_patches, cfg.d_model)) * 0.02
        )
    if cfg.encoder is not None:
        batch["frame_embeds"] = (
            jax.random.normal(ks[2], (_B, cfg.encoder.n_frames, cfg.d_model)) * 0.02
        )
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_arch(request.param).reduced()
    params = T.init_params(cfg, jax.random.key(0))
    return request.param, cfg, params


def test_forward_shapes_no_nans(arch_setup):
    aid, cfg, params = arch_setup
    batch = _batch(cfg)
    x, aux = jax.jit(lambda p, b: T.forward(cfg, p, b["tokens"],
                                            patch_embeds=b.get("patch_embeds"),
                                            frame_embeds=b.get("frame_embeds")))(
        params, batch)
    assert x.shape == (_B, _S, cfg.d_model), aid
    assert np.isfinite(np.asarray(x, np.float32)).all(), aid
    assert np.isfinite(float(aux)), aid


def test_train_step_loss_and_grads_finite(arch_setup):
    aid, cfg, params = arch_setup
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(
        lambda p, b: jax.value_and_grad(
            lambda q: T.loss_fn(cfg, q, b), has_aux=True
        )(p)
    )(params, batch)
    assert np.isfinite(float(loss)), aid
    # loss at init should be near log(vocab) (uniform prediction)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab)) < 1.5, aid
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves), aid
    # at least 90% of leaves receive nonzero gradient signal
    nz = sum(float(jnp.abs(l.astype(jnp.float32)).max()) > 0 for l in leaves)
    assert nz >= 0.9 * len(leaves), (aid, nz, len(leaves))


def test_prefill_decode_consistency(arch_setup):
    """decode_step after prefill must match a full forward pass's logits."""
    aid, cfg, params = arch_setup
    batch = _batch(cfg)
    toks = batch["tokens"]

    cache = T.init_cache(cfg, _B, _S + 8)
    logits_p, cache = jax.jit(
        lambda p, t, c: T.prefill(cfg, p, t, c,
                                  patch_embeds=batch.get("patch_embeds"),
                                  frame_embeds=batch.get("frame_embeds"))
    )(params, toks, cache)
    assert logits_p.shape == (_B, 1, cfg.vocab), aid

    # oracle: full forward at the last position
    x, _ = T.forward(cfg, params, toks,
                     patch_embeds=batch.get("patch_embeds"),
                     frame_embeds=batch.get("frame_embeds"))
    from repro.models import layers as L

    want = L.logits_matmul(cfg, params["embed"], L.apply_norm(
        cfg, params["final_norm"], x[:, -1:]))
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2,
        atol=2e-2,
        err_msg=aid,
    )

    # one decode step keeps shapes/NaN-freeness
    tok = jnp.argmax(logits_p[:, -1], -1)[:, None].astype(jnp.int32)
    logits_d, _ = jax.jit(lambda p, t, c, pos: T.decode_step(cfg, p, t, c, pos))(
        params, tok, cache, jnp.asarray(_S, jnp.int32))
    assert logits_d.shape == (_B, 1, cfg.vocab), aid
    assert np.isfinite(np.asarray(logits_d, np.float32)).all(), aid


def test_full_configs_match_assignment():
    """The full (published) configs carry the assigned hyperparameters."""
    expect = {
        "pixtral_12b": dict(n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
                            d_ff=14336, vocab=131072),
        "llama4_maverick_400b_a17b": dict(n_layers=48, d_model=5120, n_heads=40,
                                          n_kv_heads=8, d_ff=8192, vocab=202048),
        "deepseek_moe_16b": dict(n_layers=28, d_model=2048, n_heads=16,
                                 n_kv_heads=16, d_ff=1408, vocab=102400),
        "whisper_large_v3": dict(n_layers=32, d_model=1280, n_heads=20,
                                 n_kv_heads=20, d_ff=5120, vocab=51866),
        "jamba_v0_1_52b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=8, d_ff=14336, vocab=65536),
        "gemma2_27b": dict(n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
                           d_ff=36864, vocab=256000),
        "qwen2_72b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                          d_ff=29568, vocab=152064),
        "olmo_1b": dict(n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
                        d_ff=8192, vocab=50304),
        "qwen1_5_4b": dict(n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
                           d_ff=6912, vocab=151936),
        "rwkv6_7b": dict(n_layers=32, d_model=4096, d_ff=14336, vocab=65536),
    }
    for aid, fields in expect.items():
        cfg = get_arch(aid)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (aid, k, getattr(cfg, k), v)
    # family features
    assert get_arch("llama4_maverick_400b_a17b").moe.n_experts == 128
    assert get_arch("llama4_maverick_400b_a17b").moe.top_k == 1
    assert get_arch("deepseek_moe_16b").moe.n_experts == 64
    assert get_arch("deepseek_moe_16b").moe.top_k == 6
    assert get_arch("deepseek_moe_16b").moe.n_shared == 2
    assert get_arch("jamba_v0_1_52b").moe.n_experts == 16
    assert get_arch("jamba_v0_1_52b").mixer == "mamba_hybrid"
    assert get_arch("gemma2_27b").attn_softcap is not None
    assert get_arch("qwen2_72b").qkv_bias
    assert get_arch("qwen1_5_4b").qkv_bias
    assert get_arch("olmo_1b").norm == "nonparametric_ln"
    assert get_arch("rwkv6_7b").mixer == "rwkv6"
    assert get_arch("whisper_large_v3").encoder is not None
    assert get_arch("pixtral_12b").frontend == "vision"


def test_sub_quadratic_flags():
    """long_500k applicability (DESIGN.md §Arch-applicability)."""
    from repro.config import SHAPES, shape_applicable

    runs = {aid: shape_applicable(get_arch(aid), SHAPES["long_500k"])[0]
            for aid in ARCH_IDS}
    assert runs == {
        "pixtral_12b": False,
        "llama4_maverick_400b_a17b": False,
        "deepseek_moe_16b": False,
        "whisper_large_v3": False,
        "jamba_v0_1_52b": True,
        "gemma2_27b": False,
        "qwen2_72b": False,
        "olmo_1b": False,
        "qwen1_5_4b": False,
        "rwkv6_7b": True,
    }


def test_layer_pattern_periods():
    assert get_arch("gemma2_27b").layer_pattern_period == 2  # local/global
    assert get_arch("jamba_v0_1_52b").layer_pattern_period == 8  # 1:7 + moe
    assert get_arch("qwen2_72b").layer_pattern_period == 1
    kinds = get_arch("jamba_v0_1_52b").layer_kinds()
    assert sum(k["mixer"] == "attention" for k in kinds) == 1  # 1:7 ratio
    assert sum(k["moe"] for k in kinds) == 4  # every other layer


def test_training_reduces_loss():
    """Three AdamW steps on the synthetic pipeline reduce the loss (the data
    has learnable structure)."""
    from repro.data.pipeline import DataConfig, SyntheticLMData
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = get_arch("olmo_1b").reduced()
    params = T.init_params(cfg, jax.random.key(0))
    opt = AdamWConfig(lr=5e-3, weight_decay=0.0)
    state = adamw_init(opt, params)
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch), has_aux=True
        )(params)
        params, state, _ = adamw_update(opt, params, grads, state)
        return params, state, loss

    losses = []
    for i in range(6):
        b = {k: jnp.asarray(v) for k, v in data.batch_numpy(i).items()}
        params, state, loss = step(params, state, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


# ---------------------------------------------------------------------------
# MoE drop accounting + spgemm serving impl
# ---------------------------------------------------------------------------


def _moe_cfg(impl, capacity_factor=1.25, token_block=4):
    from repro.config import ArchConfig, MoEConfig

    moe = MoEConfig(n_experts=8, top_k=2, d_expert=32, impl=impl,
                    capacity_factor=capacity_factor, token_block=token_block)
    return ArchConfig(name=f"test-moe-{impl}", family="llama", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab=128, mlp="swiglu", moe=moe)


def _moe_fixture(impl, seed=0, b=2, s=24, **kw):
    from repro.models import moe as M

    cfg = _moe_cfg(impl, **kw)
    p = M.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, s, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_moe_drop_accounting_dense_vs_tp():
    """The dense oracle never drops; the tp buffer impl drops exactly the
    over-capacity routed pairs, and with generous capacity drops nothing
    and matches dense."""
    from repro.models import moe as M

    cfg, p, x = _moe_fixture("tp", capacity_factor=0.5)
    cfg_d = _moe_cfg("dense")

    yd, _, st_d = M.apply_moe(cfg_d, p, x, collect_stats=True)
    assert int(st_d["dropped"]) == 0
    assert int(st_d["routed"]) == x.shape[0] * x.shape[1] * cfg.moe.top_k

    yt, _, st_t = M.apply_moe(cfg, p, x, collect_stats=True)
    # oracle drop count straight from the router: per batch row, routed
    # pairs land in token order, so expert e keeps min(count_e, capacity)
    b, s, _ = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    cap = max(int(s * k * cfg.moe.capacity_factor / e), 1)
    logits = (x.astype(jnp.float32).reshape(-1, cfg.d_model)
              @ p["router"]).reshape(b, s, e)
    _, top_e, _ = M.router_probs(cfg.moe, logits.reshape(-1, e))
    te = np.asarray(top_e).reshape(b, s, k)
    want_dropped = sum(
        max(0, int((te[r] == ex).sum()) - cap)
        for r in range(b) for ex in range(e))
    assert int(st_t["dropped"]) == want_dropped
    assert want_dropped > 0  # capacity_factor 0.5 must actually clip
    assert int(st_t["routed"]) == int(st_d["routed"])

    # generous capacity: nothing dropped, tp == dense
    cfg_big = _moe_cfg("tp", capacity_factor=float(e))
    yb, _, st_b = M.apply_moe(cfg_big, p, x, collect_stats=True)
    assert int(st_b["dropped"]) == 0
    np.testing.assert_allclose(np.asarray(yb), np.asarray(yd),
                               rtol=1e-4, atol=1e-5)


def test_moe_spgemm_matches_dense_oracle():
    """The serving spgemm impl (dispatch mask -> BSM -> multiply) equals
    the dense oracle with zero drops, including ragged T (padding)."""
    from repro.models import moe as M

    for s in (24, 27):  # 27: not a token_block multiple -> padded tail
        cfg, p, x = _moe_fixture("spgemm", s=s)
        cfg_d = _moe_cfg("dense")
        yd, aux_d = M.apply_moe(cfg_d, p, x)
        ys, aux_s, st = M.apply_moe(cfg, p, x, collect_stats=True)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                                   rtol=1e-4, atol=1e-5)
        assert int(st["dropped"]) == 0
        np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_moe_unknown_impl_raises():
    from repro.models import moe as M

    cfg, p, x = _moe_fixture("dense")
    import dataclasses

    bad = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="nope"))
    with pytest.raises(ValueError, match="unknown moe impl"):
        M.apply_moe(bad, p, x)
