"""Checkpoint store: atomicity, keep-k GC, auto-resume, manifest."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros(8)},
        "opt": {"mu": jnp.ones((8, 8)), "step": jnp.asarray(7, jnp.int32)},
    }


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    assert latest_step(str(tmp_path)) == 3
    r = restore_checkpoint(str(tmp_path), 3, jax.eval_shape(lambda: t))
    _assert_tree_equal(t, r)


def test_atomicity_tmp_dirs_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crashed save: a stale .tmp dir and an incomplete manifest
    os.makedirs(tmp_path / "step_000000002.tmp")
    os.makedirs(tmp_path / "step_000000005")
    with open(tmp_path / "step_000000005" / "manifest.json", "w") as f:
        json.dump({"step": 5, "complete": False}, f)
    assert latest_step(str(tmp_path)) == 1


def test_corrupt_manifest_ignored(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    os.makedirs(tmp_path / "step_000000009")
    with open(tmp_path / "step_000000009" / "manifest.json", "w") as f:
        f.write("{not json")
    assert latest_step(str(tmp_path)) == 1


def test_keep_k_gc(tmp_path):
    t = _tree()
    for s in range(6):
        save_checkpoint(str(tmp_path), s, t, keep=3)
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert steps == [3, 4, 5]


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((8, 8))})


def test_missing_leaf_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(KeyError):
        restore_checkpoint(
            str(tmp_path), 1, {"w": jnp.zeros((4, 4)), "extra": jnp.zeros(2)}
        )


def test_manager_auto_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    assert mgr.restore_latest(_tree()) is None
    mgr.save(10, _tree(1))
    mgr.save(20, _tree(2))
    step, tree = mgr.restore_latest(jax.eval_shape(lambda: _tree()))
    assert step == 20
    _assert_tree_equal(tree, _tree(2))


def test_manifest_carries_mesh(tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    save_checkpoint(str(tmp_path), 1, _tree(), mesh=mesh)
    with open(tmp_path / "step_000000001" / "manifest.json") as f:
        m = json.load(f)
    assert m["mesh"]["axes"] == ["data"]
    assert m["mesh"]["shape"] == [1]
