"""Serving engine: batched prefill/decode, greedy determinism, EOS."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as T
from repro.serving.engine import GenerationConfig, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_arch("olmo_1b").reduced()
    params = T.init_params(cfg, jax.random.key(0))
    return cfg, params


def test_greedy_deterministic(engine_setup):
    cfg, params = engine_setup
    gen = GenerationConfig(max_new_tokens=6, temperature=0.0)
    e1 = ServingEngine(cfg, params, batch=2, max_len=128, gen=gen)
    e2 = ServingEngine(cfg, params, batch=2, max_len=128, gen=gen)
    prompts = [np.asarray([5, 7, 11, 13]), np.asarray([2, 3, 4, 9])]
    out1 = e1.generate(prompts)
    out2 = e2.generate(prompts)
    assert out1 == out2
    assert all(len(o) == 6 for o in out1)


def test_batch_slots_independent(engine_setup):
    """A request's output must not depend on its co-batched neighbours."""
    cfg, params = engine_setup
    gen = GenerationConfig(max_new_tokens=4)
    e = ServingEngine(cfg, params, batch=2, max_len=128, gen=gen)
    p = np.asarray([5, 7, 11, 13])
    solo = e.generate([p])[0]
    pair = e.generate([p, np.asarray([8, 8, 8, 8])])[0]
    assert solo == pair


def test_eos_stops_early(engine_setup):
    cfg, params = engine_setup
    gen0 = GenerationConfig(max_new_tokens=8, temperature=0.0)
    e0 = ServingEngine(cfg, params, batch=1, max_len=128, gen=gen0)
    prompts = [np.asarray([1, 2, 3, 4])]
    full = e0.generate(prompts)[0]
    eos = full[1]  # pretend the 2nd generated token is EOS
    gen1 = GenerationConfig(max_new_tokens=8, temperature=0.0, eos_token=eos)
    e1 = ServingEngine(cfg, params, batch=1, max_len=128, gen=gen1)
    out = e1.generate(prompts)[0]
    assert out == full[:2]


def test_temperature_sampling_runs(engine_setup):
    cfg, params = engine_setup
    gen = GenerationConfig(max_new_tokens=4, temperature=1.0, seed=1)
    e = ServingEngine(cfg, params, batch=1, max_len=128, gen=gen)
    out = e.generate([np.asarray([1, 2, 3])])[0]
    assert len(out) == 4
    assert all(0 <= t < cfg.vocab for t in out)


# ---------------------------------------------------------------------------
# slot lifecycle (continuous batching)
# ---------------------------------------------------------------------------


def _prompts(cfg, n, plen=6, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
            for _ in range(n)]


def test_serve_drains_queue_beyond_slots(engine_setup):
    """More requests than slots: every request completes, slots refill."""
    cfg, params = engine_setup
    gen = GenerationConfig(max_new_tokens=4)
    e = ServingEngine(cfg, params, batch=2, max_len=64, gen=gen)
    prompts = _prompts(cfg, 5)
    outs = e.serve(prompts)
    assert len(outs) == 5
    assert all(len(o) == 4 for o in outs)
    st = e.last_serve_stats
    assert st["n_requests"] == 5
    assert st["n_refills"] >= 2  # 5 requests through 2 slots
    assert all(0.0 < s["occupancy"] <= 1.0 for s in st["steps"])


def test_serve_matches_solo_generate(engine_setup):
    """Per-slot positions: a refilled slot's continuation equals the same
    prompt decoded alone (greedy), arrivals staggered or not."""
    cfg, params = engine_setup
    gen = GenerationConfig(max_new_tokens=4)
    e = ServingEngine(cfg, params, batch=2, max_len=64, gen=gen)
    prompts = _prompts(cfg, 4, seed=3)
    served = e.serve(prompts, arrivals=[0, 0, 2, 5])
    ref = ServingEngine(cfg, params, batch=2, max_len=64, gen=gen)
    for p, s in zip(prompts, served):
        assert s == ref.generate([p])[0]


def test_serve_eos_mid_batch_refills(engine_setup):
    """An EOS in one slot frees it for the queue while the other slot
    keeps decoding; the late request still completes correctly."""
    cfg, params = engine_setup
    probe = ServingEngine(
        cfg, params, batch=2, max_len=64,
        gen=GenerationConfig(max_new_tokens=6))
    prompts = _prompts(cfg, 3, seed=5)
    full = probe.serve(prompts)
    eos = full[0][1]  # pretend request 0's 2nd token is EOS
    gen = GenerationConfig(max_new_tokens=6, eos_token=eos)
    e = ServingEngine(cfg, params, batch=2, max_len=64, gen=gen)
    outs = e.serve(prompts)
    assert outs[0] == full[0][: full[0].index(eos) + 1]
    # the reference run with EOS: requests decoded alone stop at eos too
    ref = ServingEngine(cfg, params, batch=2, max_len=64, gen=gen)
    for p, o in zip(prompts, outs):
        assert o == ref.generate([p])[0]


def test_serve_temperature_vs_greedy_determinism(engine_setup):
    """Fixed seed: temperature serving is reproducible run-to-run but
    differs from greedy; greedy ignores the seed entirely."""
    cfg, params = engine_setup
    prompts = _prompts(cfg, 3, seed=7)

    def run(temperature, seed):
        gen = GenerationConfig(max_new_tokens=5, temperature=temperature,
                               seed=seed)
        e = ServingEngine(cfg, params, batch=2, max_len=64, gen=gen)
        return e.serve(prompts)

    t1, t2 = run(1.0, 11), run(1.0, 11)
    assert t1 == t2  # same seed -> identical sampled stream
    g1, g2 = run(0.0, 11), run(0.0, 99)
    assert g1 == g2  # greedy: seed is irrelevant
    assert t1 != g1  # temperature 1 at these sizes diverges from argmax
