"""Serving engine: batched prefill/decode, greedy determinism, EOS."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as T
from repro.serving.engine import GenerationConfig, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_arch("olmo_1b").reduced()
    params = T.init_params(cfg, jax.random.key(0))
    return cfg, params


def test_greedy_deterministic(engine_setup):
    cfg, params = engine_setup
    gen = GenerationConfig(max_new_tokens=6, temperature=0.0)
    e1 = ServingEngine(cfg, params, batch=2, max_len=128, gen=gen)
    e2 = ServingEngine(cfg, params, batch=2, max_len=128, gen=gen)
    prompts = [np.asarray([5, 7, 11, 13]), np.asarray([2, 3, 4, 9])]
    out1 = e1.generate(prompts)
    out2 = e2.generate(prompts)
    assert out1 == out2
    assert all(len(o) == 6 for o in out1)


def test_batch_slots_independent(engine_setup):
    """A request's output must not depend on its co-batched neighbours."""
    cfg, params = engine_setup
    gen = GenerationConfig(max_new_tokens=4)
    e = ServingEngine(cfg, params, batch=2, max_len=128, gen=gen)
    p = np.asarray([5, 7, 11, 13])
    solo = e.generate([p])[0]
    pair = e.generate([p, np.asarray([8, 8, 8, 8])])[0]
    assert solo == pair


def test_eos_stops_early(engine_setup):
    cfg, params = engine_setup
    gen0 = GenerationConfig(max_new_tokens=8, temperature=0.0)
    e0 = ServingEngine(cfg, params, batch=1, max_len=128, gen=gen0)
    prompts = [np.asarray([1, 2, 3, 4])]
    full = e0.generate(prompts)[0]
    eos = full[1]  # pretend the 2nd generated token is EOS
    gen1 = GenerationConfig(max_new_tokens=8, temperature=0.0, eos_token=eos)
    e1 = ServingEngine(cfg, params, batch=1, max_len=128, gen=gen1)
    out = e1.generate(prompts)[0]
    assert out == full[:2]


def test_temperature_sampling_runs(engine_setup):
    cfg, params = engine_setup
    gen = GenerationConfig(max_new_tokens=4, temperature=1.0, seed=1)
    e = ServingEngine(cfg, params, batch=1, max_len=128, gen=gen)
    out = e.generate([np.asarray([1, 2, 3])])[0]
    assert len(out) == 4
    assert all(0 <= t < cfg.vocab for t in out)
