"""Multi-device integration tests.

Each test spawns ``python -m tests._dist <check>`` with 16 fake CPU devices
(XLA_FLAGS is set inside _dist.py, never in this process — the rest of the
suite must keep seeing the real single device).
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(*checks: str, timeout: int = 900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep + _ROOT
    proc = subprocess.run(
        [sys.executable, "-m", "tests._dist", *checks],
        cwd=_ROOT,
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"check {checks} failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


def test_engines_match_reference():
    out = _run("engines")
    assert "engines OK" in out


def test_transport_compressed_bit_exact():
    """Compressed panel transport == dense transport bitwise for every
    engine across occupancies, rectangular meshes and uneven L; auto
    crossover + REPRO_TRANSPORT override."""
    out = _run("transport")
    assert "transport OK" in out


def test_stacks_backends_distributed():
    """Compacted backends + auto capacity bounds across engines/grids."""
    out = _run("stacks_backends")
    assert "stacks_backends OK" in out


def test_engines_rectangular_grids():
    out = _run("engines_rectangular")
    assert "OK" in out


def test_plan_rectangular_grids():
    """2.5D on (2,4)/(4,2) and square L=4: == reference == Algorithm 2."""
    out = _run("plan_rectangular")
    assert "plan_rectangular OK" in out


def test_plan_cache_no_relower():
    """Second multiply hits the compiled-plan cache (no re-lowering)."""
    out = _run("plan_cache")
    assert "plan_cache OK" in out


def test_signiter_sharded_device_resident():
    """Fused device-resident purification == legacy loop on a mesh; one
    program per multiply shape; no global gather in the fused step."""
    out = _run("signiter_sharded")
    assert "signiter_sharded OK" in out


def test_envelope_chain_sharded():
    """Envelope-compiled drifting-pattern chains on a mesh: builds == 1,
    bitwise == the chain-safe fused chain, compressed transport unlocked,
    warm path re-hits the forecast cache with zero retraces."""
    out = _run("envelope_sharded")
    assert "envelope_sharded OK" in out


def test_tuner_auto_multi_device():
    """engine="auto": tuned multiplies == oracle on 2x2/2x4/stacked
    meshes, warm-DB resolution is measurement-free, autotuned
    purification matches the static loop."""
    out = _run("tuner_auto")
    assert "tuner_auto OK" in out


def test_comm_volume_matches_paper_model():
    out = _run("comm_volume", "spgemm_scaling")
    assert "comm_volume OK" in out and "spgemm_scaling OK" in out


def test_train_steps_execute_and_learn():
    out = _run("train_steps")
    assert out.count("OK") == 2  # with and without gradient compression


def test_serve_steps_match_single_device():
    out = _run("serve_steps")
    assert "serve_steps OK" in out


def test_checkpoint_cross_mesh_restore():
    out = _run("checkpoint_cross_mesh")
    assert "OK" in out


def test_data_pipeline_sharded():
    out = _run("data_global_batch")
    assert "OK" in out


def test_matmul_2p5d_lm_head():
    out = _run("matmul_2p5d")
    assert "OK" in out


def test_compressed_allreduce():
    out = _run("compressed_allreduce")
    assert "OK" in out


def test_microbatch_gradient_accumulation():
    out = _run("microbatch")
    assert "microbatch_equivalence OK" in out


def test_pipeline_schedule():
    out = _run("pipeline")
    assert "pipeline OK" in out


def test_assignment_distributed():
    out = _run("assignment")
    assert "assignment OK" in out


def test_tensor_contraction():
    out = _run("tensor")
    assert "tensor OK" in out
