"""Local-stage backends: jnp dense vs stacks vs pallas (interpret).

Property tests (hypothesis; conftest fallback shim when absent) assert all
backends agree with the dense reference across occupancy, threshold and
dtype — including the empty-product-list edge case and rectangular atomic
blocks — plus the acceptance checks of the compaction PR: measured
surviving-product FLOPs and pattern-signature cache hits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import plan as plan_mod
from repro.core.bsm import random_bsm
from repro.core.engine import (
    AUTO_DENSE_FILL,
    choose_backend,
    multiply_reference,
)
from repro.core.local_mm import local_filtered_mm, pair_filter, stacks_mm
from repro.kernels.stacks import (
    bucket_capacity,
    compact_pair_mask,
    pattern_signature,
    product_count,
)
from repro.roofline.hlo_cost import (
    spgemm_stacks_flops,
    xla_cost_analysis,
)

BACKENDS = ("jnp", "stacks", "pallas")


def _mats(key, ni, nk, nj, bs_r, bs_k, bs_c, occupancy, dtype):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(key), 4)
    # divide before the cast: a NumPy f64 scalar would silently promote
    # bf16 operands back to f32 under JAX's promotion rules
    ab = (jax.random.normal(k1, (ni, nk, bs_r, bs_k))
          / np.sqrt(bs_k)).astype(dtype)
    bb = (jax.random.normal(k2, (nk, nj, bs_k, bs_c))
          / np.sqrt(bs_k)).astype(dtype)
    am = jax.random.bernoulli(k3, occupancy, (ni, nk))
    bm = jax.random.bernoulli(k4, occupancy, (nk, nj))
    ab = ab * am[:, :, None, None].astype(dtype)
    bb = bb * bm[:, :, None, None].astype(dtype)
    an = jnp.sqrt(jnp.sum(jnp.square(ab.astype(jnp.float32)), axis=(2, 3)))
    bn = jnp.sqrt(jnp.sum(jnp.square(bb.astype(jnp.float32)), axis=(2, 3)))
    return ab, am, an, bb, bm, bn


@settings(max_examples=12, deadline=None)
@given(
    occupancy=st.sampled_from([0.0, 0.05, 0.3, 1.0]),
    threshold=st.sampled_from([0.0, 0.05]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_backends_agree_with_dense_reference(occupancy, threshold, dtype):
    dt = jnp.dtype(dtype)
    args = _mats(42, 5, 6, 4, 8, 8, 8, occupancy, dt)
    want, want_m = local_filtered_mm(*args, threshold=threshold, backend="jnp")
    tol = 1e-5 if dt == jnp.float32 else 3e-2
    for backend in ("stacks", "pallas"):
        got, got_m = local_filtered_mm(
            *args, threshold=threshold, backend=backend
        )
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(want, np.float32),
            rtol=tol,
            atol=tol,
        )
        assert bool(jnp.all(got_m == want_m))


@settings(max_examples=6, deadline=None)
@given(capacity=st.sampled_from([8, 64, 1024]))
def test_tight_capacity_matches(capacity):
    """An exact (or generous) static capacity changes nothing numerically."""
    args = _mats(7, 4, 4, 4, 8, 8, 8, 0.3, jnp.float32)
    ok = pair_filter(args[1], args[2], args[4], args[5], 0.0)
    n = int(np.asarray(ok).sum())
    cap = max(capacity, bucket_capacity(n))  # sound: never below the count
    want, _ = local_filtered_mm(*args, backend="jnp")
    for backend in ("stacks", "pallas"):
        got, _ = local_filtered_mm(*args, backend=backend, stack_capacity=cap)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )


def test_empty_product_list():
    """occupancy 0 -> zero capacity, zero C, empty mask, on every backend."""
    args = _mats(3, 3, 4, 2, 8, 8, 8, 0.0, jnp.float32)
    for backend in BACKENDS:
        cb, cm = local_filtered_mm(*args, backend=backend)
        assert float(jnp.abs(cb).max()) == 0.0
        assert not bool(jnp.any(cm))
    # compacted with explicit capacity 0
    cb, cm = local_filtered_mm(*args, backend="stacks", stack_capacity=0)
    assert float(jnp.abs(cb).max()) == 0.0
    # threshold filters *everything* out despite full occupancy
    full = _mats(4, 3, 3, 3, 8, 8, 8, 1.0, jnp.float32)
    for backend in BACKENDS:
        cb, cm = local_filtered_mm(*full, threshold=1e9, backend=backend)
        assert float(jnp.abs(cb).max()) == 0.0
        assert not bool(jnp.any(cm))


@settings(max_examples=8, deadline=None)
@given(
    bs_r=st.sampled_from([4, 8]),
    bs_k=st.sampled_from([8, 16]),
    bs_c=st.sampled_from([4, 16]),
)
def test_rectangular_atomic_blocks(bs_r, bs_k, bs_c):
    """bs_r != bs_k != bs_c end-to-end through every backend."""
    args = _mats(11, 3, 5, 2, bs_r, bs_k, bs_c, 0.4, jnp.float32)
    want, want_m = local_filtered_mm(*args, threshold=0.01, backend="jnp")
    assert want.shape == (3, 2, bs_r, bs_c)
    for backend in ("stacks", "pallas"):
        got, got_m = local_filtered_mm(*args, threshold=0.01, backend=backend)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )
        assert bool(jnp.all(got_m == want_m))


# ---------------------------------------------------------------------------
# mixed precision (satellite: backend x dtype x occupancy x block shape
# against the kernels.ref mixed-precision oracle)
# ---------------------------------------------------------------------------


from repro.kernels import ref as kref  # noqa: E402

# documented tolerances vs the f32-accumulating oracle (see the
# ``kernels.ref.block_spgemm_ref`` docstring): all backends accumulate in
# f32, so the error is operand + output rounding at the storage width
_DTYPE_TOL = {"float32": 1e-5, "bfloat16": 2e-2}


@settings(max_examples=16, deadline=None)
@given(
    occupancy=st.sampled_from([0.0, 0.2, 0.7]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    shape=st.sampled_from([(8, 8, 8), (4, 16, 8), (8, 16, 4)]),
    backend=st.sampled_from(["jnp", "stacks", "pallas"]),
)
def test_mixed_precision_matches_ref_oracle(occupancy, dtype, shape, backend):
    """Every backend, at every storage dtype, over rectangular blocks and
    the occupancy range, lands within the documented tolerance of the
    mixed-precision oracle (quantized operands, f32 HIGHEST einsum)."""
    bs_r, bs_k, bs_c = shape
    args = _mats(17, 3, 4, 3, bs_r, bs_k, bs_c, occupancy, jnp.dtype(dtype))
    ab, am, an, bb, bm, bn = args
    got, got_m = local_filtered_mm(*args, backend=backend)
    assert got.dtype == jnp.dtype(dtype)  # storage dtype round-trips
    ok = pair_filter(am, an, bm, bn, 0.0)
    want = kref.block_spgemm_ref(ab, bb, ok)
    tol = _DTYPE_TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_f32_accumulation_beats_storage_precision():
    """The reduced-precision path accumulates in f32: a long k-sum of
    same-sign terms matches the f32 result to input-rounding error, far
    tighter than bf16 accumulation (which loses ~1 ulp per term) would."""
    nk, bs = 8, 16
    ab = jnp.full((1, nk, bs, bs), 1.0 + 1 / 256, jnp.bfloat16)
    bb = jnp.full((nk, 1, bs, bs), 1.0 - 1 / 256, jnp.bfloat16)
    m_a = jnp.ones((1, nk), bool)
    m_b = jnp.ones((nk, 1), bool)
    n_a = jnp.sqrt(jnp.sum(jnp.square(ab.astype(jnp.float32)), axis=(2, 3)))
    n_b = jnp.sqrt(jnp.sum(jnp.square(bb.astype(jnp.float32)), axis=(2, 3)))
    exact = float(nk * bs * (1.0 + 1 / 256) * (1.0 - 1 / 256))
    for backend in BACKENDS:
        got, _ = local_filtered_mm(ab, m_a, n_a, bb, m_b, n_b,
                                   backend=backend)
        rel = abs(float(jnp.asarray(got, jnp.float32)[0, 0, 0, 0]) - exact)
        rel /= exact
        # bf16 has ~3 decimal digits; f32 accumulation keeps the 128-term
        # sum within one bf16 output rounding (~0.4%), not ~n ulps
        assert rel < 5e-3, (backend, rel)


@settings(max_examples=8, deadline=None)
@given(tile=st.sampled_from([None, (8, 8, 8), (8, 16, 8), (16, 8, 16)]))
def test_pallas_tile_param_matches_dense(tile):
    """The tile override changes scheduling, never numerics."""
    args = _mats(23, 3, 3, 3, 16, 16, 16, 0.5, jnp.float32)
    want, want_m = local_filtered_mm(*args, backend="jnp")
    got, got_m = local_filtered_mm(*args, backend="pallas", tile=tile)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )
    assert bool(jnp.all(got_m == want_m))


# ---------------------------------------------------------------------------
# compaction machinery
# ---------------------------------------------------------------------------


def test_compact_pair_mask_structure():
    ok = jnp.asarray(
        np.array(
            [  # (ni=2, nk=2, nj=2)
                [[True, False], [True, True]],
                [[False, False], [False, True]],
            ]
        )
    )
    st_ = compact_pair_mask(ok, capacity=8)
    n = int(np.asarray(ok).sum())  # 4
    v = np.asarray(st_.valid)
    assert v.sum() == n and v[:n].all()
    # sorted by (i, j) with k-runs contiguous; padding repeats last triple
    tiles = np.asarray(st_.tile)
    assert (np.diff(tiles) >= 0).all()
    triples = list(
        zip(np.asarray(st_.ia)[:n], np.asarray(st_.ik)[:n], np.asarray(st_.ij)[:n])
    )
    assert triples == [(0, 0, 0), (0, 1, 0), (0, 1, 1), (1, 1, 1)]
    assert (np.asarray(st_.ia)[n:] == 1).all()  # padding = last triple
    # one first per distinct tile, one write per distinct tile boundary
    firsts = np.asarray(st_.first)
    writes = np.asarray(st_.write)
    assert firsts.sum() == len(set(tiles[:n].tolist()))
    assert writes[-1] == 1


def test_bucket_capacity():
    assert bucket_capacity(0) == 0
    assert bucket_capacity(1) == 8
    assert bucket_capacity(8) == 8
    assert bucket_capacity(9) == 16
    assert bucket_capacity(1000) == 1024


def test_pattern_signature_distinguishes():
    a = np.zeros((2, 2, 2), bool)
    b = a.copy()
    b[0, 0, 0] = True
    assert pattern_signature(a) != pattern_signature(b)
    assert pattern_signature(a) == pattern_signature(a.copy())
    assert pattern_signature(a) != pattern_signature(a.reshape(2, 1, 4))


# ---------------------------------------------------------------------------
# acceptance: surviving-product FLOPs + pattern-cache behaviour
# ---------------------------------------------------------------------------


def test_stacks_flops_fraction_at_low_occupancy():
    """At 10% block occupancy with filtering on, the compacted backend's
    measured FLOPs are <= 20% of the dense einsum's (acceptance)."""
    nb, bs = 16, 16
    a = random_bsm(jax.random.key(0), nb, bs, occupancy=0.1)
    b = random_bsm(jax.random.key(1), nb, bs, occupancy=0.1)
    thr = 1e-3
    args = (a.blocks, a.mask, a.norms, b.blocks, b.mask, b.norms)

    dense = jax.jit(
        lambda *xs: local_filtered_mm(*xs, threshold=thr, backend="jnp")
    )
    dense_flops = xla_cost_analysis(dense.lower(*args).compile())["flops"]

    ok = np.asarray(pair_filter(a.mask, a.norms, b.mask, b.norms, thr))
    stacks, n = plan_mod.get_product_stacks(ok)
    assert 0 < n <= stacks.capacity
    fn = plan_mod.get_local_compiled(
        nb, nb, nb, bs, bs, bs, jnp.float32,
        backend="stacks", capacity=stacks.capacity,
    )
    comp = fn.lower(a.blocks, b.blocks, stacks).compile()
    stacks_flops = xla_cost_analysis(comp)["flops"]

    assert stacks_flops <= 0.20 * dense_flops, (stacks_flops, dense_flops)
    # and the measured number is the surviving-product model, not the cube
    assert stacks_flops == pytest.approx(
        spgemm_stacks_flops(stacks.capacity, bs, bs, bs), rel=0.10
    )
    # numerics still match the dense reference to 1e-5
    want = multiply_reference(a, b, threshold=thr, backend="jnp")
    for backend in ("stacks", "pallas"):
        got = multiply_reference(a, b, threshold=thr, backend=backend)
        np.testing.assert_allclose(
            np.asarray(got.to_dense()),
            np.asarray(want.to_dense()),
            rtol=1e-5,
            atol=1e-5,
        )


def test_repeated_pattern_is_cache_hit_no_recompile():
    """Same sparsity pattern again -> pattern-cache hit, zero new builds."""
    plan_mod.clear_cache()
    a = random_bsm(jax.random.key(5), 8, 8, occupancy=0.2)
    b = random_bsm(jax.random.key(6), 8, 8, occupancy=0.2)
    c1 = multiply_reference(a, b, threshold=1e-3, backend="stacks")
    s1 = plan_mod.cache_stats()
    assert s1["pattern_misses"] >= 1 and s1["builds"] >= 1
    # the same multiply again — the sign-iteration / serving hot path
    c2 = multiply_reference(a, b, threshold=1e-3, backend="stacks")
    s2 = plan_mod.cache_stats()
    assert s2["pattern_hits"] == s1["pattern_hits"] + 1
    assert s2["builds"] == s1["builds"]  # no recompile
    assert s2["hits"] == s1["hits"] + 1  # compiled program reused
    np.testing.assert_allclose(
        np.asarray(c1.to_dense()), np.asarray(c2.to_dense()), rtol=1e-6
    )
    # a *different* pattern in the same capacity bucket still reuses the
    # compiled program (key is the bucket, not the pattern)
    a3 = random_bsm(jax.random.key(7), 8, 8, occupancy=0.2)
    multiply_reference(a3, b, threshold=1e-3, backend="stacks")
    s3 = plan_mod.cache_stats()
    assert s3["pattern_misses"] == s2["pattern_misses"] + 1
    ok3 = np.asarray(
        pair_filter(a3.mask, a3.norms, b.mask, b.norms, 1e-3)
    )
    ok1 = np.asarray(pair_filter(a.mask, a.norms, b.mask, b.norms, 1e-3))
    if bucket_capacity(int(ok3.sum())) == bucket_capacity(int(ok1.sum())):
        assert s3["builds"] == s2["builds"]


def test_auto_backend_heuristic():
    lo_a = random_bsm(jax.random.key(8), 8, 8, occupancy=0.05)
    lo_b = random_bsm(jax.random.key(9), 8, 8, occupancy=0.05)
    hi_a = random_bsm(jax.random.key(10), 8, 8, occupancy=1.0, pattern="dense")
    hi_b = random_bsm(jax.random.key(11), 8, 8, occupancy=1.0, pattern="dense")
    lo = choose_backend(lo_a, lo_b)
    hi = choose_backend(hi_a, hi_b)
    assert lo in ("stacks", "pallas")
    assert hi == "jnp"
    ok = np.asarray(pair_filter(hi_a.mask, hi_a.norms, hi_b.mask, hi_b.norms, 0.0))
    assert ok.mean() > AUTO_DENSE_FILL
    # auto end-to-end matches the dense reference
    want = multiply_reference(lo_a, lo_b, backend="jnp")
    got = multiply_reference(lo_a, lo_b, backend="auto")
    np.testing.assert_allclose(
        np.asarray(got.to_dense()), np.asarray(want.to_dense()),
        rtol=1e-5, atol=1e-5,
    )


def test_stacks_mm_direct_vs_einsum():
    """stacks_mm over an exact host-compacted list == masked einsum."""
    args = _mats(21, 4, 3, 5, 8, 16, 4, 0.5, jnp.float32)
    ab, am, an, bb, bm, bn = args
    ok = pair_filter(am, an, bm, bn, 0.0)
    n = product_count(np.asarray(ok))
    st_ = compact_pair_mask(ok, capacity=bucket_capacity(n))
    got = stacks_mm(ab, bb, st_, ni=4, nj=5)
    want, _ = local_filtered_mm(*args, backend="jnp")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )
