"""Blocked sparse tensor layer: matricization round-trips and the
einsum-style ``contract`` driver (DESIGN.md §10).

The load-bearing invariant is losslessness: ``unmatricize`` must invert
``matricize`` BIT-EXACTLY — blocks, mask and norms — for every ordered
index split, rectangular atomic blocks included, because the contraction
driver leans on the index map being a pure relabeling (no arithmetic, no
tolerance).  Semantics (does the matricized SpGEMM compute the einsum?)
are pinned against ``np.einsum`` on densified operands.

Multi-device coverage (all four engines, rectangular and uneven-L
meshes, sharded chaining) lives in ``tests/_dist.py::check_tensor``.
"""
from __future__ import annotations

from itertools import permutations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import tensor as T
from repro.core.bsm import block_norms


def _bit_equal(t1: T.BlockSparseTensor, t2: T.BlockSparseTensor) -> None:
    assert t1.blocks.shape == t2.blocks.shape
    assert np.array_equal(np.asarray(t1.blocks), np.asarray(t2.blocks))
    assert np.array_equal(np.asarray(t1.mask), np.asarray(t2.mask))
    assert np.array_equal(np.asarray(t1.norms), np.asarray(t2.norms))


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------


def test_make_tensor_zeroes_masked_blocks():
    key = jax.random.key(0)
    blocks = jax.random.normal(key, (2, 3, 2, 4, 5, 3))
    mask = np.zeros((2, 3, 2), bool)
    mask[0, 1, 1] = True
    t = T.make_tensor(blocks, jnp.asarray(mask))
    assert float(jnp.abs(t.blocks[1]).max()) == 0.0
    assert float(jnp.abs(t.blocks[0, 1, 1]).max()) > 0.0
    # norms recomputed from the zeroed data, f32
    ref = np.sqrt((np.asarray(t.blocks, np.float32) ** 2).sum(axis=(3, 4, 5)))
    np.testing.assert_allclose(np.asarray(t.norms), ref, rtol=1e-5, atol=1e-6)


def test_make_tensor_rank_check():
    with pytest.raises(ValueError, match="2x the mask's rank"):
        T.make_tensor(jnp.zeros((2, 2, 4, 4)), jnp.ones((2, 2, 2), bool))


def test_dense_roundtrip_rectangular_blocks():
    key = jax.random.key(1)
    dense = jax.random.normal(key, (6, 8, 10))
    t = T.from_dense_tensor(dense, (3, 2, 5))
    assert t.nbs == (2, 4, 2) and t.bss == (3, 2, 5)
    np.testing.assert_allclose(
        np.asarray(t.to_dense()), np.asarray(dense), rtol=1e-6
    )


def test_from_dense_shape_check():
    with pytest.raises(ValueError, match="not divisible"):
        T.from_dense_tensor(jnp.zeros((6, 7)), (3, 3))


def test_random_tensor_decay_keeps_diagonal():
    t = T.random_tensor(jax.random.key(2), (5, 5, 5), 4, occupancy=0.05)
    m = np.asarray(t.mask)
    assert m[np.arange(5), np.arange(5), np.arange(5)].all()
    assert 0.0 < m.mean() < 1.0


# ---------------------------------------------------------------------------
# matricization round-trips: bit-exact for EVERY ordered split
# ---------------------------------------------------------------------------


def test_matricize_roundtrip_all_ordered_splits_3d():
    t = T.random_tensor(jax.random.key(3), (2, 3, 4), (3, 2, 4),
                        occupancy=0.4)
    for perm in permutations(range(3)):
        for cut in (1, 2):
            rows, cols = perm[:cut], perm[cut:]
            m = T.matricize(t, rows, cols)
            assert m.blocks.shape == (
                int(np.prod([t.nbs[a] for a in rows])),
                int(np.prod([t.nbs[a] for a in cols])),
                int(np.prod([t.bss[a] for a in rows])),
                int(np.prod([t.bss[a] for a in cols])),
            )
            _bit_equal(t, T.unmatricize(m, rows, cols, t.nbs, t.bss))


def test_matricize_carries_mask_and_norms_exactly():
    t = T.random_tensor(jax.random.key(4), (3, 2, 2), (2, 5, 3),
                        occupancy=0.3)
    m = T.matricize(t, (2, 0), (1,))
    # occupancy is preserved (pure relabeling, no fill-in, no drops)
    assert int(np.asarray(m.mask).sum()) == int(np.asarray(t.mask).sum())
    # the carried norms ARE the Frobenius norms of the flattened blocks:
    # a reshape does not change a 2-norm
    np.testing.assert_allclose(
        np.asarray(m.norms), np.asarray(block_norms(m.blocks)),
        rtol=1e-5, atol=1e-6,
    )


def test_unmatricize_shape_mismatch_is_loud():
    t = T.random_tensor(jax.random.key(5), (2, 2, 2), 3, occupancy=0.5)
    m = T.matricize(t, (0, 1), (2,))
    with pytest.raises(ValueError, match="do not fold"):
        T.unmatricize(m, (0,), (1, 2), t.nbs, t.bss)


def test_matricize_split_validation():
    t = T.random_tensor(jax.random.key(6), (2, 2), 2, occupancy=1.0)
    with pytest.raises(ValueError, match="at least one index"):
        T.matricize(t, (0, 1), ())
    with pytest.raises(ValueError, match="partition"):
        T.matricize(t, (0,), (0,))


NBS_POOL = (2, 3, 4, 2)
BSS_RECT = (3, 2, 4, 5)


@settings(deadline=None, max_examples=40)
@given(
    ndim=st.integers(min_value=2, max_value=4),
    cut=st.integers(min_value=1, max_value=3),
    reverse=st.booleans(),
    occupancy=st.floats(min_value=0.0, max_value=1.0),
    rect=st.booleans(),
    seed=st.integers(min_value=0, max_value=7),
)
def test_matricize_roundtrip_property(ndim, cut, reverse, occupancy,
                                      rect, seed):
    """matricize ∘ unmatricize == id, bit-exact: every rank 2..4, every
    cut point, reversed (non-natural) axis orders, rectangular atomic
    blocks, and the occupancy extremes (all-empty / all-full included)."""
    cut = min(cut, ndim - 1)
    nbs = NBS_POOL[:ndim]
    bss = BSS_RECT[:ndim] if rect else (3,) * ndim
    t = T.random_tensor(jax.random.key(seed), nbs, bss,
                        occupancy=occupancy)
    axes = tuple(range(ndim))
    if reverse:
        axes = axes[::-1]
    rows, cols = axes[:cut], axes[cut:]
    m = T.matricize(t, rows, cols)
    _bit_equal(t, T.unmatricize(m, rows, cols, t.nbs, t.bss))


# ---------------------------------------------------------------------------
# contract: semantics vs np.einsum (single device, mesh=None)
# ---------------------------------------------------------------------------


def _pair(seed: int = 7, nb: int = 3, bs: int = 4):
    t = T.random_tensor(jax.random.key(seed), (nb, nb, nb), bs,
                        occupancy=0.3)
    m = T.random_tensor(jax.random.key(seed + 1), (nb, nb), bs,
                        occupancy=0.6)
    return t, m


def _check_contract(spec: str, *ops, **kw):
    got = T.contract(spec, *ops, **kw)
    ref = T.contract_reference(spec, *ops)
    np.testing.assert_allclose(
        np.asarray(got.to_dense()), ref, rtol=1e-4, atol=1e-4
    )
    return got


def test_contract_three_center_single_device():
    t, m = _pair()
    out = _check_contract("ijk,kl->ijl", t, m)
    assert out.nbs == (3, 3, 3) and out.bss == (4, 4, 4)


def test_contract_permuted_output():
    # non-natural output order: replicated path transposes after folding
    t, m = _pair(seed=9)
    _check_contract("ijk,kl->lij", t, m)


def test_contract_multi_index_contraction():
    # two indices contracted at once: (ij|k) with itself over (j, k)
    t, _ = _pair(seed=11)
    t2 = T.random_tensor(jax.random.key(20), (3, 3, 3), 4, occupancy=0.3)
    _check_contract("ijk,mjk->im", t, t2)


def test_contract_rectangular_blocks():
    t = T.random_tensor(jax.random.key(12), (2, 3, 4), (3, 2, 4),
                        occupancy=0.5)
    m = T.random_tensor(jax.random.key(13), (4, 3), (4, 5), occupancy=0.7)
    out = _check_contract("ijk,kl->ijl", t, m)
    assert out.bss == (3, 2, 5)


def test_contract_chain_three_operands():
    t, m = _pair(seed=15)
    m2 = T.random_tensor(jax.random.key(16), (3, 3), 4, occupancy=0.6)
    _check_contract("ijk,kl,lm->ijm", t, m, m2)


def test_contract_threshold_filters():
    t, m = _pair(seed=17)
    exact = T.contract("ijk,kl->ijl", t, m)
    loose = T.contract("ijk,kl->ijl", t, m, threshold=1e6)
    assert int(np.asarray(loose.mask).sum()) < int(np.asarray(exact.mask).sum())


# ---------------------------------------------------------------------------
# loud rejections: everything outside the matricized-SpGEMM model
# ---------------------------------------------------------------------------


def test_contract_requires_explicit_output():
    t, m = _pair()
    with pytest.raises(ValueError, match="->"):
        T.contract("ijk,kl", t, m)


def test_contract_rejects_traces():
    t, m = _pair()
    with pytest.raises(ValueError, match="trace"):
        T.contract("iik,kl->il", t, m)


def test_contract_rejects_batch_dims():
    t, m = _pair()
    with pytest.raises(NotImplementedError, match="batch"):
        T.contract("ijk,kl->ijkl", t, m)


def test_contract_rejects_outer_products():
    a = T.random_tensor(jax.random.key(21), (2, 2), 3, occupancy=1.0)
    b = T.random_tensor(jax.random.key(22), (2, 2), 3, occupancy=1.0)
    with pytest.raises(ValueError, match="outer"):
        T.contract("ij,kl->ijkl", a, b)


def test_contract_rejects_full_inner_products():
    a = T.random_tensor(jax.random.key(23), (2, 2), 3, occupancy=1.0)
    b = T.random_tensor(jax.random.key(24), (2, 2), 3, occupancy=1.0)
    with pytest.raises(ValueError, match="no free index"):
        T.contract("ij,ij->", a, b)


def test_contract_rejects_stray_output_index():
    t, m = _pair()
    with pytest.raises(ValueError, match="appears in no operand"):
        T.contract("ijk,kl->ijz", t, m)


def test_contract_rejects_contracted_dim_mismatch():
    t = T.random_tensor(jax.random.key(25), (2, 2, 3), 4, occupancy=1.0)
    m = T.random_tensor(jax.random.key(26), (2, 2), 4, occupancy=1.0)
    with pytest.raises(ValueError, match="disagrees"):
        T.contract("ijk,kl->ijl", t, m)


def test_contract_needs_two_operands():
    t, _ = _pair()
    with pytest.raises(ValueError):
        T.contract("ijk->ijk", t)


def test_contract_rejects_foreign_operands():
    t, m = _pair()
    with pytest.raises(TypeError, match="BlockSparseTensor"):
        T.contract("ijk,kl->ijl", t, np.zeros((12, 12)))


def test_rectangular_product_rejects_assignment():
    """Satellite of the non-square plumbing: symmetric block→device
    permutations have no meaning on a rectangular block grid, so the
    plan layer must refuse them LOUDLY (never silently corrupt)."""
    from repro.core import plan as plan_mod
    from repro.core.distribute import Assignment

    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("r", "c")
    )
    asg = Assignment("nnz_greedy", perm=(1, 0))
    with pytest.raises(ValueError, match="symmetric"):
        plan_mod.get_compiled(
            mesh, "gather", 2, 4, jnp.float32,
            assignment=asg, nb_k=4, nb_c=2, bs_k=4, bs_c=4,
        )
