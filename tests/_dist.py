"""Multi-device checks, run in a subprocess so the fake-device XLA flag never
leaks into the main pytest process (smoke tests must see 1 device).

Usage:  python -m tests._dist <check> [<check> ...]
Each check raises on failure; exit code 0 == all passed.
"""
from __future__ import annotations

import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=64 " + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------


def check_engines():
    """All distributed engines == single-device filtered oracle."""
    from repro.core import bsm as B
    from repro.core.engine import multiply, multiply_reference
    from repro.launch.mesh import make_spgemm_mesh

    key = jax.random.key(0)
    a = B.random_bsm(key, nb=8, bs=8, occupancy=0.4, pattern="decay")
    b = B.random_bsm(jax.random.key(1), nb=8, bs=8, occupancy=0.4, pattern="decay")

    for threshold in (0.0, 0.35):
        ref = multiply_reference(a, b, threshold=threshold)
        rd = np.asarray(ref.to_dense())
        mesh2 = make_spgemm_mesh(p=2)
        for eng in ("cannon", "onesided", "gather"):
            c = multiply(a, b, mesh2, engine=eng, threshold=threshold)
            np.testing.assert_allclose(
                np.asarray(c.to_dense()), rd, rtol=1e-5, atol=1e-5,
                err_msg=f"{eng} t={threshold}")
            np.testing.assert_array_equal(
                np.asarray(c.mask), np.asarray(ref.mask), err_msg=eng)
        for l in (2,):
            mesh3 = make_spgemm_mesh(p=2, l=l)
            for layout in ("2d", "scatter"):
                c = multiply(a, b, mesh3, engine="twofive",
                             threshold=threshold, c_layout=layout)
                np.testing.assert_allclose(
                    np.asarray(c.to_dense()), rd, rtol=1e-5, atol=1e-5,
                    err_msg=f"twofive {layout} t={threshold}")
    # pallas backend through the distributed gather engine
    mesh2 = make_spgemm_mesh(p=2)
    ref = multiply_reference(a, b)
    c = multiply(a, b, mesh2, engine="gather", backend="pallas")
    np.testing.assert_allclose(
        np.asarray(c.to_dense()), np.asarray(ref.to_dense()), rtol=1e-4, atol=1e-4)
    print("engines OK")


def check_stacks_backends():
    """Compacted backends distributed: the auto-derived per-device stack
    capacity (plan.get_device_capacity) must never drop products — checked
    with a *skewed* pattern where one device's panel dominates, across all
    engines, both compacted backends, and a non-square grid."""
    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.engine import multiply, multiply_reference
    from repro.launch.mesh import make_spgemm_mesh

    a = B.random_bsm(jax.random.key(0), nb=8, bs=8, occupancy=0.15)
    b = B.random_bsm(jax.random.key(1), nb=8, bs=8, occupancy=0.15)
    # skew: one quadrant fully occupied WITH data (fresh blocks — the
    # blocks random_bsm masked out are zero, and zero-norm products would
    # be filtered right back out) — the max-device capacity bound must
    # come from the dense quadrant, not the average
    mask = np.asarray(a.mask).copy()
    mask[:4, :4] = True
    blocks = jax.random.normal(jax.random.key(2), a.blocks.shape) / np.sqrt(8)
    a = B.make_bsm(blocks, jnp.asarray(mask))

    thr = 1e-3
    ref = np.asarray(multiply_reference(a, b, threshold=thr).to_dense())
    mesh2 = make_spgemm_mesh(p=2)
    for eng in ("cannon", "onesided", "gather", "twofive"):
        for be in ("stacks", "pallas"):
            c = multiply(a, b, mesh2, engine=eng, threshold=thr, backend=be)
            np.testing.assert_allclose(
                np.asarray(c.to_dense()), ref, rtol=1e-5, atol=1e-5,
                err_msg=f"{eng}/{be}")
    # non-square pull grid (forced virtual L) + stacked (l, r, c) mesh
    from jax.sharding import Mesh

    devs = np.array(jax.devices()[:8])
    mesh24 = Mesh(devs.reshape(2, 4), ("r", "c"))
    for eng in ("onesided", "twofive"):
        c = multiply(a, b, mesh24, engine=eng, threshold=thr, backend="stacks")
        np.testing.assert_allclose(
            np.asarray(c.to_dense()), ref, rtol=1e-5, atol=1e-5,
            err_msg=f"{eng}/stacks 2x4")
    mesh3 = make_spgemm_mesh(p=2, l=2)
    c = multiply(a, b, mesh3, engine="twofive", threshold=thr, backend="stacks")
    np.testing.assert_allclose(
        np.asarray(c.to_dense()), ref, rtol=1e-5, atol=1e-5,
        err_msg="twofive stacked/stacks")
    # repeated pattern: bound + product list re-derivations are cache hits
    s1 = plan_mod.cache_stats()
    multiply(a, b, mesh2, engine="gather", threshold=thr, backend="stacks")
    s2 = plan_mod.cache_stats()
    assert s2["pattern_hits"] > s1["pattern_hits"], (s1, s2)
    assert s2["builds"] == s1["builds"], (s1, s2)
    print("stacks_backends OK")


def check_engines_rectangular():
    """gather/onesided engines on non-square grids (non-ideal topologies)."""
    from repro.core import bsm as B
    from repro.core.engine import multiply, multiply_reference

    a = B.random_bsm(jax.random.key(2), nb=8, bs=4, occupancy=0.5)
    b = B.random_bsm(jax.random.key(3), nb=8, bs=4, occupancy=0.5)
    ref = np.asarray(multiply_reference(a, b).to_dense())
    for shape in ((2, 4), (4, 2), (1, 8)):
        mesh = jax.make_mesh(shape, ("r", "c"))
        for eng in ("gather", "onesided"):
            c = multiply(a, b, mesh, engine=eng)
            np.testing.assert_allclose(
                np.asarray(c.to_dense()), ref, rtol=1e-5, atol=1e-5,
                err_msg=f"{eng} {shape}")
    print("engines_rectangular OK")


def check_plan_rectangular():
    """The 2.5D engine on non-square grids (virtual depth L = max/min) and
    on a square grid with L = 4: equals both the single-device reference
    and the paper-fidelity numpy oracle ``simulate_algorithm2``."""
    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.engine import multiply, multiply_reference
    from repro.core.topology import simulate_algorithm2
    from repro.launch.mesh import make_spgemm_mesh

    a = B.random_bsm(jax.random.key(4), nb=8, bs=4, occupancy=0.5,
                     pattern="decay")
    b = B.random_bsm(jax.random.key(5), nb=8, bs=4, occupancy=0.5,
                     pattern="decay")
    ref = np.asarray(multiply_reference(a, b).to_dense())
    ad, bd = np.asarray(a.to_dense()), np.asarray(b.to_dense())

    for p_r, p_c, l in ((2, 4, None), (4, 2, None), (2, 2, 4)):
        mesh = make_spgemm_mesh(p_r=p_r, p_c=p_c)
        c = multiply(a, b, mesh, engine="twofive", l=l)
        plan = plan_mod.plan_multiply(mesh, "twofive", l)
        want_l = l if l is not None else max(p_r, p_c) // min(p_r, p_c)
        assert plan.topo.l == want_l, (p_r, p_c, plan.topo.l)
        sim = simulate_algorithm2(ad, bd, p_r, p_c, plan.topo.l)
        cd = np.asarray(c.to_dense())
        np.testing.assert_allclose(cd, ref, rtol=1e-5, atol=1e-5,
                                   err_msg=f"{p_r}x{p_c} L={plan.topo.l} ref")
        np.testing.assert_allclose(cd, sim, rtol=1e-5, atol=1e-5,
                                   err_msg=f"{p_r}x{p_c} L={plan.topo.l} sim")
        np.testing.assert_allclose(sim, ad @ bd, rtol=1e-5, atol=1e-5)

    # stacked mesh with uneven L (L does not divide the grid side)
    mesh = make_spgemm_mesh(p=2, l=4)
    for layout in ("2d", "scatter"):
        c = multiply(a, b, mesh, engine="twofive", c_layout=layout)
        np.testing.assert_allclose(np.asarray(c.to_dense()), ref,
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"stacked uneven {layout}")
    print("plan_rectangular OK")


def check_tensor():
    """Distributed blocked tensor contraction (DESIGN.md §10): the
    matricized ``contract("ijk,kl->ijl")`` of the three_center corpus
    entry equals the dense np.einsum oracle for every engine on the
    square 2x2 grid, the rectangular 2x4 grid, and the stacked
    uneven-L mesh; a sharded chain stays device-resident between
    contractions; and non-identity block→device assignments on the
    rectangular matricized product are rejected loudly."""
    from jax.sharding import Mesh

    from repro.core import tensor as T
    from repro.core.engine import multiply
    from repro.launch.mesh import make_spgemm_mesh
    from repro.tuner.corpus import corpus

    entry = [e for e in corpus(smoke=True) if e.kind == "three_center"][0]
    t, bm = entry.build_tensor()  # (4,4,4) blocks of 8^3 vs (4,4) of 8^2
    b2 = T.make_tensor(bm.blocks, bm.mask)  # the (k, l) operand as a tensor
    ref = T.contract_reference("ijk,kl->ijl", t, b2)

    meshes = {
        "2x2": (make_spgemm_mesh(p=2),
                ("cannon", "onesided", "gather", "twofive")),
        "2x4": (Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("r", "c")),
                ("onesided", "gather", "twofive")),
        "stacked": (make_spgemm_mesh(p=2, l=4), ("twofive",)),
    }
    for name, (mesh, engines) in meshes.items():
        for eng in engines:
            out = T.contract("ijk,kl->ijl", t, b2, mesh=mesh, engine=eng,
                             threshold=entry.threshold)
            assert out.nbs == t.nbs and out.bss == t.bss, (name, eng)
            np.testing.assert_allclose(
                np.asarray(out.to_dense()), ref, rtol=1e-4, atol=1e-4,
                err_msg=f"{name}/{eng}")

    # engine="auto": the tuner owns the choice end to end
    mesh24 = meshes["2x4"][0]
    out = T.contract("ijk,kl->ijl", t, b2, mesh=mesh24, engine="auto",
                     threshold=entry.threshold)
    np.testing.assert_allclose(np.asarray(out.to_dense()), ref,
                               rtol=1e-4, atol=1e-4, err_msg="auto")

    # sharded chain: shard once, contract twice, gather once — the
    # intermediate never leaves the devices and its split lines up with
    # the next contraction's needs
    mesh = meshes["2x2"][0]
    b3 = T.random_tensor(jax.random.key(33), (4, 4), 8, occupancy=0.6)
    st_ = T.shard_tensor(t, mesh, (0, 1), (2,))
    sb2 = T.shard_tensor(b2, mesh, (0,), (1,))
    sb3 = T.shard_tensor(b3, mesh, (0,), (1,))
    mid = T.contract("ijk,kl->ijl", st_, sb2, mesh=mesh, engine="gather")
    assert isinstance(mid, T.MatricizedTensor) and mid.sharded, mid
    fin = T.contract("ijl,lm->ijm", mid, sb3, mesh=mesh, engine="gather")
    assert isinstance(fin, T.MatricizedTensor) and fin.sharded, fin
    chain_ref = T.contract_reference("ijk,kl,lm->ijm", t, b2, b3)
    np.testing.assert_allclose(
        np.asarray(fin.to_tensor().to_dense()), chain_ref,
        rtol=1e-4, atol=1e-4, err_msg="sharded chain")

    # a sharded intermediate whose split does NOT line up must refuse the
    # implicit global redistribution, not silently gather
    try:
        T.contract("ijl,jm->ilm", mid, sb3, mesh=mesh, engine="gather")
        raise AssertionError("expected split-mismatch ValueError")
    except ValueError as e:
        assert "redistribution" in str(e), e

    # satellite: non-identity assignments have no symmetric layout on the
    # rectangular matricized product — loud rejection at both entry points
    ma = T.matricize(t, (0, 1), (2,))
    try:
        multiply(ma, bm, mesh, engine="gather", assignment="nnz_greedy")
        raise AssertionError("expected non-square assignment ValueError")
    except ValueError as e:
        assert "square" in str(e), e
    print("tensor OK")


def check_plan_cache():
    """Repeated multiplies reuse one compiled program: the second call hits
    the plan cache (no re-build / re-lower) and dispatches much faster."""
    import time

    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.engine import multiply
    from repro.core.signiter import sign_iteration
    from repro.launch.mesh import make_spgemm_mesh

    mesh = make_spgemm_mesh(p=2, l=2)
    a = B.random_bsm(jax.random.key(0), nb=8, bs=8, occupancy=0.5,
                     pattern="decay", symmetric=True)
    b = B.random_bsm(jax.random.key(1), nb=8, bs=8, occupancy=0.5)

    plan_mod.clear_cache()
    t0 = time.perf_counter()
    multiply(a, b, mesh, engine="twofive").blocks.block_until_ready()
    first = time.perf_counter() - t0
    s1 = plan_mod.cache_stats()
    assert s1["misses"] == 1 and s1["builds"] == 1, s1

    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        multiply(a, b, mesh, engine="twofive").blocks.block_until_ready()
        times.append(time.perf_counter() - t0)
    s2 = plan_mod.cache_stats()
    assert s2["builds"] == 1, s2  # no re-lowering on cache hits
    assert s2["hits"] == s1["hits"] + 5, (s1, s2)
    steady = sorted(times)[len(times) // 2]
    assert steady < first, (first, steady)

    # the driving hot path, legacy per-op loop: every multiply re-enters
    # the plan cache and shares one program
    plan_mod.clear_cache()
    _, st = sign_iteration(a, mesh=mesh, engine="twofive", max_iter=4,
                           mode="legacy")
    s3 = plan_mod.cache_stats()
    assert s3["builds"] == 1 and s3["hits"] == st.multiplications - 1, s3
    # fused mode: the whole sweep is ONE chain program, fetched per sweep
    plan_mod.clear_cache()
    _, st = sign_iteration(a, mesh=mesh, engine="twofive", max_iter=4)
    s4 = plan_mod.cache_stats()
    assert s4["builds"] == 1, s4  # one multiply body for both multiplies
    assert s4["chain_misses"] == 1, s4
    assert s4["chain_hits"] == st.iterations - 1, (s4, st.iterations)
    print(f"plan_cache OK first={first:.3f}s steady={steady:.4f}s")


def check_signiter_sharded():
    """The device-resident purification chain on a distributed mesh:

    * fused sweep == legacy per-op loop (residual trace, occupancy trace,
      converged X to 1e-5) across engines / thresholds / backends;
    * a 10-sweep iteration compiles AT MOST ONE program per distinct
      multiply shape (plan.cache_stats: builds == 1, one chain miss,
      sweeps-1 chain hits);
    * the fused step's compiled HLO performs no global gather — X enters
      and leaves in the 2D home layout (onesided/twofive: zero all-gather
      ops; the collectives are the engine's ppermutes and the scalar
      residual all-reduce);
    * ShardedBSM stays sharded end-to-end (C in the home layout) and
      density_matrix on a ShardedBSM H returns a ShardedBSM P.
    """
    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.signiter import (
        density_matrix,
        lower_sweep,
        sign_iteration,
        sign_iteration_legacy,
        trace,
    )
    from repro.launch.mesh import make_spgemm_mesh

    x = B.random_bsm(jax.random.key(0), nb=8, bs=8, occupancy=0.6,
                     pattern="banded", symmetric=True)
    mesh2 = make_spgemm_mesh(p=2)
    mesh3 = make_spgemm_mesh(p=2, l=2)

    for thr, eps in ((0.0, 0.0), (1e-7, 1e-6)):
        ref, st_ref = sign_iteration_legacy(
            x, mesh=mesh2, engine="onesided", threshold=thr,
            filter_eps=eps, max_iter=60, tol=1e-6)
        assert st_ref.converged
        rd = np.asarray(ref.to_dense())
        for engine, mesh, backend in (
            ("onesided", mesh2, "jnp"),
            ("gather", mesh2, "jnp"),
            ("cannon", mesh2, "jnp"),
            ("twofive", mesh3, "jnp"),
            ("onesided", mesh2, "stacks"),
        ):
            s, st = sign_iteration(
                x, mesh=mesh, engine=engine, threshold=thr, filter_eps=eps,
                max_iter=60, tol=1e-6, mode="fused", backend=backend)
            tag = f"{engine}/{backend} t={thr}"
            assert st.converged, tag
            assert st.iterations == st_ref.iterations, tag
            np.testing.assert_allclose(
                st.residual_trace, st_ref.residual_trace,
                rtol=1e-4, atol=1e-7, err_msg=tag)
            np.testing.assert_allclose(
                st.occupancy_trace, st_ref.occupancy_trace,
                atol=1e-7, err_msg=tag)
            np.testing.assert_allclose(
                np.asarray(s.to_dense()), rd, rtol=1e-5, atol=1e-5,
                err_msg=tag)

    # --- cache: 10 sweeps, at most one program per distinct multiply shape
    plan_mod.clear_cache()
    _, st = sign_iteration(x, mesh=mesh2, engine="onesided",
                           threshold=1e-7, filter_eps=1e-6,
                           max_iter=10, tol=0.0, sync_every=5)
    stats = plan_mod.cache_stats()
    assert st.iterations == 10 and st.host_syncs == 2, st
    assert stats["builds"] == 1, stats
    assert stats["chain_misses"] == 1, stats
    assert stats["chain_hits"] == 9, stats
    assert st.retraces == 1, st  # the whole chain traced ONE program
    # second chain on the same key: pure chain-cache hits, no new build
    _, st_warm = sign_iteration(x, mesh=mesh2, engine="onesided",
                                threshold=1e-7, filter_eps=1e-6,
                                max_iter=5, tol=0.0)
    s2 = plan_mod.cache_stats()
    assert s2["builds"] == 1 and s2["chain_misses"] == 1, s2
    assert st_warm.retraces == 0, st_warm  # warm chain: zero retraces

    # --- no global gather in the fused step (jaxpr/HLO of one sweep)
    for engine, mesh in (("onesided", mesh2), ("twofive", mesh3)):
        hlo = lower_sweep(mesh, 8, 8, engine=engine, threshold=1e-7,
                          filter_eps=1e-6).compile().as_text()
        n_ag = sum("all-gather" in ln for ln in hlo.splitlines())
        assert n_ag == 0, (engine, n_ag)

    # --- ShardedBSM end-to-end: sharded in, sharded out, home layout
    from jax.sharding import PartitionSpec as P

    hx = B.shard_bsm(x, mesh2)
    s, st = sign_iteration(hx, engine="onesided", threshold=1e-7,
                           filter_eps=1e-6, max_iter=60, tol=1e-6)
    assert isinstance(s, B.ShardedBSM)
    assert s.blocks.sharding.spec == P("r", "c", None, None), (
        s.blocks.sharding)
    assert s.mask.sharding.spec == P("r", "c"), s.mask.sharding
    ref, _ = sign_iteration_legacy(x, mesh=mesh2, engine="onesided",
                                   threshold=1e-7, filter_eps=1e-6,
                                   max_iter=60, tol=1e-6)
    np.testing.assert_allclose(np.asarray(s.to_dense()),
                               np.asarray(ref.to_dense()),
                               rtol=1e-5, atol=1e-5)
    p, stp = density_matrix(hx, 0.0, engine="onesided", threshold=1e-9,
                            filter_eps=1e-8, max_iter=80, tol=1e-6)
    assert isinstance(p, B.ShardedBSM) and stp.converged
    dense = np.asarray(x.to_dense(), np.float64)
    w = np.linalg.eigvalsh(dense)
    assert abs(float(trace(p)) - int((w < 0.0).sum())) < 0.05
    print("signiter_sharded OK")


def check_envelope_sharded():
    """Pattern-envelope chains on distributed meshes (DESIGN.md §7):

    * a 10-sweep drifting-pattern purification compiled against the
      forecast envelope runs builds == 1 / chain_misses == 1 /
      st.retraces == 1, with compacted capacities derived from the
      envelope's union cube — and matches the plain chain-safe fused
      chain BIT-EXACT (same engine/backend: identical contraction
      order, the envelope only pads the compacted product list with
      zero-contribution slots);
    * the envelope lifts the chain-safety pins: compressed panel
      transport inside a fused chain, previously a hard error, now
      packs against the envelope's operand-mask unions;
    * warm path: a second chain over the same operand re-hits the
      envelope cache (envelope_hits) and the chain program — zero
      retraces, zero new builds;
    * engine="auto" under an envelope ranks the full candidate space
      and still keys ONE chain program.
    """
    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.signiter import sign_iteration
    from repro.launch.mesh import make_spgemm_mesh

    mesh2 = make_spgemm_mesh(p=2)
    mesh3 = make_spgemm_mesh(p=2, l=2)
    x0 = B.random_bsm(jax.random.key(0), nb=8, bs=8, occupancy=0.3,
                      pattern="decay", symmetric=True)
    # pre-scale on the host so envelope and baseline chains see the SAME
    # input bits (scale_input=False: ShardedBSM.frobenius_norm reduces
    # in psum order, which may differ by a ULP between programs)
    x = B.scale(x0, float(1.0 / max(float(x0.frobenius_norm()), 1e-30)))
    kw = dict(threshold=1e-7, filter_eps=1e-6, max_iter=10, tol=0.0,
              scale_input=False, backend="stacks")

    for engine, mesh, l in (("onesided", mesh2, None),
                            ("twofive", mesh3, 2)):
        plan_mod.clear_cache()
        want, _ = sign_iteration(x, mesh=mesh, engine=engine, l=l, **kw)
        plan_mod.clear_cache()
        got, st = sign_iteration(x, mesh=mesh, engine=engine, l=l,
                                 envelope="auto", **kw)
        s = plan_mod.cache_stats()
        assert st.envelope and st.retraces == 1, (engine, st)
        assert s["builds"] == 1 and s["chain_misses"] == 1, (engine, s)
        assert s["chain_hits"] == st.iterations - 1, (engine, s)
        assert s["envelope_misses"] == 1 and s["drift_retunes"] == 0, (
            engine, s)
        np.testing.assert_array_equal(np.asarray(got.mask),
                                      np.asarray(want.mask), err_msg=engine)
        assert np.array_equal(np.asarray(got.blocks),
                              np.asarray(want.blocks)), engine
        # warm: same operand -> envelope cache hit, zero retraces
        _, st2 = sign_iteration(x, mesh=mesh, engine=engine, l=l,
                                envelope="auto", **kw)
        s2 = plan_mod.cache_stats()
        assert st2.retraces == 0, (engine, st2)
        assert s2["builds"] == 1 and s2["envelope_hits"] == 1, (engine, s2)

    # compressed transport inside a fused chain — envelope-only territory
    plan_mod.clear_cache()
    want, _ = sign_iteration(x, mesh=mesh2, engine="onesided", **kw)
    plan_mod.clear_cache()
    got, st = sign_iteration(x, mesh=mesh2, engine="onesided",
                             envelope="auto", transport="compressed", **kw)
    s = plan_mod.cache_stats()
    assert st.retraces == 1 and s["builds"] == 1, (st, s)
    assert s["transport_compressed"] >= 1, s
    assert np.array_equal(np.asarray(got.blocks),
                          np.asarray(want.blocks)), "compressed chain"
    # without an envelope the same request is a hard error (chain safety)
    try:
        sign_iteration(x, mesh=mesh2, engine="onesided",
                       transport="compressed", **kw)
    except ValueError:
        pass
    else:
        raise AssertionError(
            "compressed chain transport without an envelope must raise")

    # engine="auto" with an envelope: full candidate space, one chain
    plan_mod.clear_cache()
    got, st = sign_iteration(x, mesh=mesh2, engine="auto",
                             envelope="auto", **kw)
    s = plan_mod.cache_stats()
    assert s["chain_misses"] == 1 and st.retraces == 1, (s, st)
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               np.asarray(want.to_dense()),
                               rtol=1e-5, atol=1e-6)
    print("envelope_sharded OK")


def check_transport():
    """Compressed transport == dense transport BIT-EXACT for every
    engine, across occupancy in {0, low, medium, full}, thresholds,
    rectangular meshes (forced virtual L) and uneven-L stacked meshes —
    plus: the auto mode resolves compressed at low fill and dense at
    high fill, capacities are served from the signature cache on
    repeats, and the REPRO_TRANSPORT env override forces the mode."""
    from jax.sharding import Mesh

    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.engine import multiply, multiply_reference

    from repro.launch.mesh import make_spgemm_mesh

    mesh2 = make_spgemm_mesh(p=2)
    mesh24 = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("r", "c"))
    mesh42 = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("r", "c"))
    mesh_uneven = make_spgemm_mesh(p=2, l=4)  # L does not divide the side
    grids = (
        (mesh2, ("cannon", "onesided", "gather", "twofive")),
        (mesh24, ("onesided", "gather", "twofive")),  # forced virtual L=2
        (mesh42, ("onesided", "gather", "twofive")),
        (mesh_uneven, ("twofive",)),  # stacked, uneven chunks
    )
    for occ in (0.0, 0.1, 0.5, 1.0):
        a = B.random_bsm(jax.random.key(0), nb=8, bs=8, occupancy=occ,
                         pattern="decay")
        b = B.random_bsm(jax.random.key(1), nb=8, bs=8, occupancy=occ)
        for thr in (0.0, 1e-3):
            ref = np.asarray(
                multiply_reference(a, b, threshold=thr).to_dense())
            for mesh, engines in grids:
                for eng in engines:
                    tag = f"{eng}/{dict(mesh.shape)} occ={occ} t={thr}"
                    cd = multiply(a, b, mesh, engine=eng, threshold=thr,
                                  transport="dense")
                    cc = multiply(a, b, mesh, engine=eng, threshold=thr,
                                  transport="compressed")
                    np.testing.assert_array_equal(
                        np.asarray(cc.blocks), np.asarray(cd.blocks),
                        err_msg=tag)
                    np.testing.assert_array_equal(
                        np.asarray(cc.mask), np.asarray(cd.mask),
                        err_msg=tag)
                    np.testing.assert_allclose(
                        np.asarray(cd.to_dense()), ref,
                        rtol=1e-5, atol=1e-5, err_msg=tag)

    # auto crossover: sparse pattern -> compressed, full pattern -> dense
    # (nb=16 so a shard holds 64 blocks — auto never compresses panels
    # small enough for the bucket floor to dominate)
    plan_mod.clear_cache()
    ii, jj = np.meshgrid(np.arange(16), np.arange(16), indexing="ij")
    sparse_mask = (ii % 4 == 0) & (jj % 4 == 0)  # 4 blocks per 8x8 shard
    sparse = B.make_bsm(
        jax.random.normal(jax.random.key(2), (16, 16, 8, 8)),
        jnp.asarray(sparse_mask),
    )
    full = B.random_bsm(jax.random.key(3), nb=16, bs=8, occupancy=1.0)
    multiply(sparse, sparse, mesh2, engine="onesided", transport="auto")
    s = plan_mod.cache_stats()
    assert s["transport_compressed"] == 1, s
    multiply(full, full, mesh2, engine="onesided", transport="auto")
    s = plan_mod.cache_stats()
    assert s["transport_dense"] == 1, s
    # repeated pattern: resolution served from the signature cache
    multiply(sparse, sparse, mesh2, engine="onesided", transport="auto")
    s2 = plan_mod.cache_stats()
    assert s2["transport_hits"] >= 1, s2
    assert s2["transport_misses"] == s["transport_misses"], (s, s2)

    # REPRO_TRANSPORT forces the default mode (plumbed like
    # REPRO_PALLAS_INTERPRET)
    plan_mod.clear_cache()
    os.environ["REPRO_TRANSPORT"] = "dense"
    try:
        multiply(sparse, sparse, mesh2, engine="onesided")
        s = plan_mod.cache_stats()
        assert s["transport_misses"] == 0, s  # dense: no resolution walk
        os.environ["REPRO_TRANSPORT"] = "compressed"
        multiply(sparse, sparse, mesh2, engine="onesided")
        s = plan_mod.cache_stats()
        assert s["transport_compressed"] == 1, s
    finally:
        del os.environ["REPRO_TRANSPORT"]
    print("transport OK")


def check_tuner_auto():
    """engine="auto" on real multi-device meshes (DESIGN.md §6):

    * the tuned multiply equals the single-device filtered oracle on
      square, rectangular and stacked meshes (replicated AND sharded
      operands);
    * the decision is Eq. (6)-feasible and, on a rectangular grid, never
      an engine the topology forbids (cannon);
    * a warm tuning DB resolves with ZERO timed trials — the production
      property the persisted database exists for;
    * sign_iteration(engine="auto") matches the static legacy loop and
      keys ONE chain program (the tuner resolves before the chain key).
    """
    import tempfile

    from repro import tuner
    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.engine import multiply, multiply_reference
    from repro.core.signiter import sign_iteration, sign_iteration_legacy
    from repro.launch.mesh import make_spgemm_mesh

    a = B.random_bsm(jax.random.key(0), nb=8, bs=8, occupancy=0.3,
                     pattern="decay", symmetric=True)
    b = B.random_bsm(jax.random.key(1), nb=8, bs=8, occupancy=0.3,
                     pattern="decay")
    thr = 1e-6
    ref = np.asarray(multiply_reference(a, b, threshold=thr).to_dense())

    from jax.sharding import Mesh

    meshes = [
        make_spgemm_mesh(p=2),
        Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("r", "c")),
        make_spgemm_mesh(p=2, l=2),
    ]
    for mesh in meshes:
        plan_mod.clear_cache()
        c = multiply(a, b, mesh, engine="auto", threshold=thr)
        np.testing.assert_allclose(np.asarray(c.to_dense()), ref,
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=str(dict(mesh.shape)))
        s = plan_mod.cache_stats()
        assert s["tuner_misses"] == 1 and s["tuner_trials"] >= 1, s

    # rectangular grid: the decision can never be cannon (square-only)
    mesh24 = meshes[1]
    dec = tuner.autotune(a, b, mesh24, threshold=thr)
    assert dec.engine != "cannon", dec

    # sharded operands stay sharded through the tuned path
    mesh2 = meshes[0]
    plan_mod.clear_cache()
    c = multiply(B.shard_bsm(a, mesh2), B.shard_bsm(b, mesh2),
                 engine="auto", threshold=thr)
    assert isinstance(c, B.ShardedBSM)
    np.testing.assert_allclose(np.asarray(c.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)

    # warm DB: zero timed trials in a "fresh process"
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "db.json")
        plan_mod.clear_cache()
        tuner.set_default_db(path)
        multiply(a, b, mesh2, engine="auto", threshold=thr)
        assert plan_mod.cache_stats()["tuner_trials"] >= 1
        plan_mod.clear_cache()
        tuner.set_default_db(path)
        multiply(a, b, mesh2, engine="auto", threshold=thr)
        s = plan_mod.cache_stats()
        assert s["tuner_trials"] == 0 and s["tuner_hits"] == 1, s

    # autotuned purification == static legacy loop; one chain program
    plan_mod.clear_cache()
    x = a
    want, st_ref = sign_iteration_legacy(
        x, mesh=mesh2, engine="onesided", threshold=1e-7, filter_eps=1e-6,
        max_iter=60, tol=1e-6)
    plan_mod.clear_cache()
    got, st = sign_iteration(x, mesh=mesh2, engine="auto", threshold=1e-7,
                             filter_eps=1e-6, max_iter=60, tol=1e-6)
    assert st.converged and st.iterations == st_ref.iterations
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               np.asarray(want.to_dense()),
                               rtol=1e-5, atol=1e-5)
    s = plan_mod.cache_stats()
    assert s["chain_misses"] == 1, s  # tuner resolved BEFORE the chain key
    assert s["builds"] <= 1 + s["tuner_trials"], s
    print("tuner_auto OK")


def check_assignment():
    """The block→device assignment layer (core.distribute) end-to-end:

    * distribute → shard_bsm → unshard → undistribute round-trips
      BIT-EXACT for every mode on square, rectangular and uneven-L
      stacked meshes (pure reindexing + data movement, no arithmetic);
    * replicated multiply under every assignment mode returns results in
      ORIGINAL block coordinates matching the identity-layout multiply,
      for every engine x mesh x backend (the permutation is wrapped
      inside the compiled program);
    * sharded execution: operands sharded under one assignment multiply
      in-layout, the result carries the assignment, and unshard restores
      original coordinates; mixing layouts raises;
    * the fused purification chain under one pinned assignment matches
      the identity-layout chain trace-for-trace;
    * balancing pays: on the hub-skewed zipf pattern the nnz_greedy
      layout yields a strictly smaller compacted stack capacity.
    """
    from jax.sharding import Mesh

    from repro.core import bsm as B
    from repro.core import distribute as D
    from repro.core import plan as plan_mod
    from repro.core.engine import multiply, multiply_reference
    from repro.launch.mesh import make_spgemm_mesh
    from repro.tuner.corpus import CorpusEntry

    # hub-skewed operands: the workload assignments exist for
    z = CorpusEntry("zipf_hub", "zipf", 8, 8, occupancy=0.3,
                    zipf_alpha=1.4, seed=15)
    a, b = z.build()
    mesh2 = make_spgemm_mesh(p=2)
    mesh24 = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("r", "c"))
    mesh_uneven = make_spgemm_mesh(p=2, l=4)  # L does not divide the side
    # (mesh, engines, backends): compacted backends ride along where the
    # transport/stacks checks already cover that mesh class
    grids = (
        (mesh2, ("cannon", "onesided", "gather", "twofive"),
         ("jnp", "stacks")),
        (mesh24, ("onesided", "gather", "twofive"), ("jnp",)),  # virtual L
        (mesh_uneven, ("twofive",), ("jnp", "stacks")),  # stacked, uneven
    )

    # --- shard/unshard round-trip: bit-exact per mode and mesh
    for mesh, _, _ in grids:
        for mode in ("randomized", "nnz_greedy"):
            hm = B.shard_bsm(a, mesh, assignment=mode)
            assert hm.assignment is not None and not hm.assignment.is_identity
            back = hm.unshard()
            tag = f"{mode}/{dict(mesh.shape)}"
            np.testing.assert_array_equal(
                np.asarray(back.blocks), np.asarray(a.blocks), err_msg=tag)
            np.testing.assert_array_equal(
                np.asarray(back.mask), np.asarray(a.mask), err_msg=tag)
            np.testing.assert_array_equal(
                np.asarray(back.norms), np.asarray(a.norms), err_msg=tag)
        # identity spec collapses to the plain layout
        assert B.shard_bsm(a, mesh, assignment="identity").assignment is None

    # --- replicated multiply: every mode == identity layout, original
    #     coordinates (allclose: the permutation regroups the k-sum)
    thr = 1e-6
    ref = np.asarray(multiply_reference(a, b, threshold=thr).to_dense())
    for mesh, engines, backends in grids:
        for eng in engines:
            for spec in ("randomized", "nnz_greedy"):
                for backend in backends:
                    tag = f"{eng}/{backend}/{spec}/{dict(mesh.shape)}"
                    c = multiply(a, b, mesh, engine=eng, threshold=thr,
                                 backend=backend, assignment=spec)
                    np.testing.assert_allclose(
                        np.asarray(c.to_dense()), ref, rtol=1e-5, atol=1e-5,
                        err_msg=tag)
                    np.testing.assert_array_equal(
                        np.asarray(c.mask),
                        np.asarray(multiply_reference(
                            a, b, threshold=thr).mask), err_msg=tag)

    # an explicit Assignment object is honored as-is
    counts = D.product_counts(np.asarray(a.mask), np.asarray(b.mask))
    asg = D.assignment_for("nnz_greedy", counts, (2, 2))
    c = multiply(a, b, mesh2, engine="onesided", threshold=thr,
                 assignment=asg)
    np.testing.assert_allclose(np.asarray(c.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)

    # --- sharded path: multiply in-layout, result carries the assignment.
    # A mode STRING derives the perm from each operand's own mask, so an
    # A@B pair shards under one explicit Assignment from the pair's
    # product counts (mode strings remain the convenience for the
    # symmetric H@H chain, where both operands share the mask).
    for spec in ("randomized", "nnz_greedy"):
        pair_asg = D.compute_assignment(spec, np.asarray(a.mask),
                                        np.asarray(b.mask), mesh2)
        ha = B.shard_bsm(a, mesh2, assignment=pair_asg)
        hb = B.shard_bsm(b, mesh2, assignment=pair_asg)
        hc = multiply(ha, hb, None, engine="onesided", threshold=thr)
        assert isinstance(hc, B.ShardedBSM)
        assert hc.assignment == ha.assignment
        np.testing.assert_allclose(np.asarray(hc.to_dense()), ref,
                                   rtol=1e-5, atol=1e-5, err_msg=spec)
    # mixing layouts is an error, not a silent wrong answer
    ha = B.shard_bsm(a, mesh2, assignment="nnz_greedy")
    hb = B.shard_bsm(b, mesh2)
    try:
        multiply(ha, hb, None, engine="onesided")
    except ValueError as e:
        assert "assignment" in str(e)
    else:
        raise AssertionError("mixed-layout multiply must raise")

    # --- fused chain under one pinned assignment == identity-layout chain
    from repro.core.signiter import sign_iteration

    x = B.random_bsm(jax.random.key(0), nb=8, bs=8, occupancy=0.6,
                     pattern="banded", symmetric=True)
    want, st_ref = sign_iteration(x, mesh=mesh2, engine="onesided",
                                  threshold=1e-7, filter_eps=1e-6,
                                  max_iter=60, tol=1e-6)
    for spec in ("randomized", "nnz_greedy"):
        got, st = sign_iteration(x, mesh=mesh2, engine="onesided",
                                 threshold=1e-7, filter_eps=1e-6,
                                 max_iter=60, tol=1e-6, assignment=spec)
        assert st.iterations == st_ref.iterations, spec
        np.testing.assert_allclose(st.residual_trace, st_ref.residual_trace,
                                   rtol=1e-4, atol=1e-7, err_msg=spec)
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   np.asarray(want.to_dense()),
                                   rtol=1e-5, atol=1e-5, err_msg=spec)
    # sharded-in chain keeps its layout end-to-end
    hx = B.shard_bsm(x, mesh2, assignment="nnz_greedy")
    s, _ = sign_iteration(hx, engine="onesided", threshold=1e-7,
                          filter_eps=1e-6, max_iter=60, tol=1e-6)
    assert isinstance(s, B.ShardedBSM) and s.assignment == hx.assignment
    np.testing.assert_allclose(np.asarray(s.to_dense()),
                               np.asarray(want.to_dense()),
                               rtol=1e-5, atol=1e-5)

    # --- the win: balancing shrinks the max-device compacted capacity
    zz = CorpusEntry("zipf_hub", "zipf", 32, 4, occupancy=0.15,
                     zipf_alpha=1.4, seed=15)
    za, zb = zz.build()
    ok = np.asarray(za.mask)[:, :, None] & np.asarray(zb.mask)[None, :, :]
    mesh44 = Mesh(np.array(jax.devices()[:16]).reshape(4, 4), ("r", "c"))
    zasg = D.assignment_for(
        "nnz_greedy", D.product_counts(np.asarray(za.mask),
                                       np.asarray(zb.mask)), (4, 4))
    cap_id = plan_mod.get_device_capacity(ok, mesh44, "onesided")
    cap_gr = plan_mod.get_device_capacity(D.permute_cube(ok, zasg.perm),
                                          mesh44, "onesided")
    assert cap_gr < cap_id, (cap_id, cap_gr)
    print("assignment OK "
          f"cap identity={cap_id} nnz_greedy={cap_gr}")


def check_comm_volume():
    """Measured HLO collective bytes track the paper's volume model:

    * cannon and onesided (PTP vs OS1) move identical A/B volume (Table 2);
    * the 2.5D engine's A/B traffic drops ~L-fold in tick count (the mesh
      formulation's Eq. (7) analogue) while adding the (L-1)/L C reduction.
    """
    from repro.core.engine import lower_multiply
    from repro.launch.mesh import make_spgemm_mesh
    from repro.roofline.hlo_cost import analyze_hlo

    nb, bs = 16, 8

    def coll(mesh, engine, **kw):
        lowered = lower_multiply(mesh, nb, bs, engine=engine, **kw)
        txt = lowered.compile().as_text()
        return analyze_hlo(txt, default_group=mesh.size)

    mesh2 = make_spgemm_mesh(p=4)
    r_cannon = coll(mesh2, "cannon")
    r_onesided = coll(mesh2, "onesided")
    r_gather = coll(mesh2, "gather")

    # PTP == OS1 volume up to the pre-shift (a small constant)
    ratio = r_onesided.collective_wire_bytes / r_cannon.collective_wire_bytes
    assert 0.7 < ratio <= 1.01, ratio
    # gather moves the same panel volume as the streaming engines (+-20%)
    ratio_g = r_gather.collective_wire_bytes / r_onesided.collective_wire_bytes
    assert 0.5 < ratio_g < 1.5, ratio_g

    mesh25_l1 = make_spgemm_mesh(p=4)  # L=1 == onesided ticks
    mesh25_l4 = make_spgemm_mesh(p=4, l=4)
    r_l1 = coll(mesh25_l1, "onesided")
    r_l4 = coll(mesh25_l4, "twofive", c_layout="scatter")
    # per-device A/B traffic: 4 ticks -> 1 tick; plus the C reduce-scatter.
    # net must be well below L=1 (the communication reduction of the paper)
    assert r_l4.collective_wire_bytes < 0.7 * r_l1.collective_wire_bytes, (
        r_l4.collective_wire_bytes, r_l1.collective_wire_bytes)
    print("comm_volume OK:",
          f"cannon={r_cannon.collective_wire_bytes:.3g}",
          f"os1={r_onesided.collective_wire_bytes:.3g}",
          f"l4={r_l4.collective_wire_bytes:.3g}")


def check_train_steps():
    """build_train_step executes on a (2,2) mesh: loss finite + decreasing,
    donated buffers update, gradient compression preserves learning."""
    from repro.configs import get_arch
    from repro.data.pipeline import DataConfig, SyntheticLMData, make_global_batch
    from repro.launch.steps import StepOptions, build_train_step
    from repro.optim import AdamWConfig
    from repro.config import ShapeConfig
    from repro.models import transformer as T
    from repro.parallel.sharding import batch_spec

    cfg = get_arch("olmo_1b").reduced()
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    shape = ShapeConfig("t", seq_len=64, global_batch=8, kind="train")

    for compress in (False, True):
        options = StepOptions(remat="full", compress_grads=compress, loss_chunk=64)
        step, (p_sds, o_sds, b_sds) = build_train_step(
            cfg, mesh, shape, opt=AdamWConfig(lr=5e-3, weight_decay=0.0),
            options=options)

        params = jax.jit(
            lambda k: T.init_params(cfg, k),
            out_shardings=jax.tree.map(lambda s: s.sharding, p_sds),
        )(jax.random.key(0))
        opt_state = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype,
                                device=s.sharding), o_sds)

        data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=64,
                                          global_batch=8))
        spec = batch_spec(mesh, 8, 64)
        losses = []
        for i in range(5):
            batch = make_global_batch(data, i, mesh, spec)
            params, opt_state, metrics = step(params, opt_state, batch)
            loss = float(metrics["loss"])
            assert np.isfinite(loss), (compress, i)
            losses.append(loss)
        assert losses[-1] < losses[0], (compress, losses)
        print(f"train_steps compress={compress} OK {losses[0]:.3f}->{losses[-1]:.3f}")


def check_serve_steps():
    """build_serve_step + build_prefill_step execute on a (2,2) mesh and
    match the single-device decode."""
    from repro.configs import get_arch
    from repro.config import ShapeConfig
    from repro.launch.steps import StepOptions, build_prefill_step, build_serve_step
    from repro.models import transformer as T

    cfg = get_arch("olmo_1b").reduced()
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    b, s = 4, 32
    shape_d = ShapeConfig("d", seq_len=s, global_batch=b, kind="decode")
    shape_p = ShapeConfig("p", seq_len=s, global_batch=b, kind="prefill")

    params = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)

    # single-device oracle
    cache0 = T.init_cache(cfg, b, s)
    logits_ref, cache_ref = T.prefill(cfg, params, toks, cache0)
    tok = jnp.argmax(logits_ref[:, -1], -1)[:, None].astype(jnp.int32)
    logits_ref2, _ = T.decode_step(cfg, params, tok, cache_ref,
                                   jnp.asarray(s, jnp.int32))

    pstep, (p_sds, c_sds, b_sds) = build_prefill_step(cfg, mesh, shape_p,
                                                      options=StepOptions())
    put = lambda tree, sds: jax.tree.map(
        lambda x, s_: jax.device_put(x, s_.sharding), tree, sds)
    params_sh = put(params, p_sds)
    cache_sh = put(T.init_cache(cfg, b, s), c_sds)
    logits, cache_sh = pstep(params_sh, cache_sh, {"tokens": jax.device_put(
        toks, b_sds["tokens"].sharding)})
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_ref, np.float32),
                               rtol=2e-2, atol=2e-2)

    dstep, (p_sds2, c_sds2, b_sds2) = build_serve_step(cfg, mesh, shape_d,
                                                       options=StepOptions())
    logits2, _ = dstep(put(params, p_sds2),
                       jax.tree.map(lambda x, s_: jax.device_put(
                           np.asarray(x), s_.sharding), cache_sh, c_sds2),
                       jax.device_put(tok, b_sds2["tokens"].sharding),
                       jnp.asarray(s, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits2, np.float32),
                               np.asarray(logits_ref2, np.float32),
                               rtol=2e-2, atol=2e-2)
    print("serve_steps OK")


def check_checkpoint_cross_mesh():
    """Save sharded on (4,1), restore onto (2,2) — the elastic path."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    import tempfile

    mesh_a = jax.make_mesh((4, 1), ("data", "model"))
    mesh_b = jax.make_mesh((2, 2), ("data", "model"))
    x = jnp.arange(64.0).reshape(8, 8)
    xa = jax.device_put(x, NamedSharding(mesh_a, P("data", None)))
    tree = {"w": xa, "step": jnp.asarray(3)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree, mesh=mesh_a)
        shardings = {
            "w": NamedSharding(mesh_b, P("data", "model")),
            "step": NamedSharding(mesh_b, P()),
        }
        r = restore_checkpoint(d, 1, jax.eval_shape(lambda: tree),
                               shardings=shardings)
        np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(x))
        assert r["w"].sharding.spec == P("data", "model")
    print("checkpoint_cross_mesh OK")


def check_data_global_batch():
    from repro.data.pipeline import DataConfig, SyntheticLMData, make_global_batch
    from repro.parallel.sharding import batch_spec

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    d = SyntheticLMData(DataConfig(vocab=64, seq_len=16, global_batch=8))
    spec = batch_spec(mesh, 8, 16)
    gb = make_global_batch(d, 2, mesh, spec)
    want = d.batch_numpy(2)
    np.testing.assert_array_equal(np.asarray(gb["tokens"]), want["tokens"])
    np.testing.assert_array_equal(np.asarray(gb["targets"]), want["targets"])
    assert gb["tokens"].sharding.spec[0] == "data"
    print("data_global_batch OK")


def check_matmul_2p5d():
    """The paper's 2.5D schedule on the LM-head matmul: exact vs x @ w."""
    from repro.parallel.matmul_2p5d import matmul_2p5d_shardmap, plan_2p5d

    mesh = jax.make_mesh((2, 2, 4), ("pod", "data", "model"))
    t, dm, v = 16, 32, 64
    x = jax.random.normal(jax.random.key(0), (t, dm))
    w = jax.random.normal(jax.random.key(1), (dm, v))
    want = np.asarray(x @ w)
    for reduce in ("scatter", "psum"):
        fn = matmul_2p5d_shardmap(mesh, reduce=reduce)
        out = fn(x, w)
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4, atol=1e-4,
                                   err_msg=reduce)
    plan = plan_2p5d(tokens=2048, d_model=4096, vocab=128256, l=2, tp=16)
    assert plan.bytes_2p5d > 0 and plan.bytes_baseline > 0
    print("matmul_2p5d OK")


def check_compressed_allreduce():
    from repro.optim.compress import (
        compressed_allreduce_shardmap,
        init_compress_state,
    )

    mesh = jax.make_mesh((4,), ("data",))
    fn = compressed_allreduce_shardmap(mesh, axis="data")
    g = jax.random.normal(jax.random.key(0), (4, 64)) * 1e-2
    r0 = jnp.zeros((4, 64), jnp.float32)
    synced, resid = fn({"w": g}, {"w": r0})
    want = np.asarray(jnp.mean(g.astype(jnp.bfloat16).astype(jnp.float32), 0))
    for row in np.asarray(synced["w"]):
        np.testing.assert_allclose(row, want, rtol=2e-2, atol=1e-4)
    # residual carries the quantization error exactly
    np.testing.assert_allclose(
        np.asarray(resid["w"]),
        np.asarray(g, np.float32)
        - np.asarray(g.astype(jnp.bfloat16), np.float32),
        atol=1e-7,
    )
    print("compressed_allreduce OK")


def check_spgemm_scaling():
    """Comm-volume scaling over mesh sizes: measured bytes per device drop
    as the grid grows (O(1/sqrt(P)) of Eq. (7) with fixed matrix)."""
    from repro.core.engine import lower_multiply
    from repro.launch.mesh import make_spgemm_mesh
    from repro.roofline.hlo_cost import analyze_hlo

    nb, bs = 16, 8
    got = {}
    for p in (2, 4):
        lowered = lower_multiply(make_spgemm_mesh(p=p), nb, bs, engine="onesided")
        got[p] = analyze_hlo(lowered.compile().as_text(),
                             default_group=p * p).collective_wire_bytes
    # panel size shrinks 4x (p doubles both dims), ticks double -> net ~1/2
    ratio = got[4] / got[2]
    assert 0.3 < ratio < 0.75, (got, ratio)
    print("spgemm_scaling OK", got)


def check_microbatch_equivalence():
    """Gradient accumulation (microbatch=k) == single-batch step, and the
    ZeRO-1 layout produces the same update."""
    from repro.configs import get_arch
    from repro.config import ShapeConfig
    from repro.launch.steps import StepOptions, build_train_step
    from repro.optim import AdamWConfig
    from repro.models import transformer as T

    cfg = get_arch("olmo_1b").reduced()
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    shape = ShapeConfig("t", 64, 8, "train")
    opt = AdamWConfig(lr=1e-3, weight_decay=0.0)
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (8, 64), 0, cfg.vocab),
        "targets": jax.random.randint(jax.random.key(2), (8, 64), 0, cfg.vocab),
    }
    results = {}
    for name, opts in {
        "mb1": StepOptions(remat="full", loss_chunk=64),
        "mb4": StepOptions(remat="full", loss_chunk=64, microbatch=4),
        "mb4z": StepOptions(remat="full", loss_chunk=64, microbatch=4, zero1=True),
    }.items():
        step, (p_sds, o_sds, _) = build_train_step(cfg, mesh, shape, opt=opt,
                                                   options=opts)
        sh = lambda t: jax.tree.map(lambda x: x.sharding, t)
        params = jax.jit(lambda k: T.init_params(cfg, k),
                         out_shardings=sh(p_sds))(jax.random.key(0))
        opt_state = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype, device=s.sharding), o_sds)
        p2, _, m = step(params, opt_state, batch)
        results[name] = (float(m["loss"]), p2)
    base_loss, base_p = results["mb1"]
    for name in ("mb4", "mb4z"):
        loss, p = results[name]
        assert abs(loss - base_loss) < 1e-2, (name, loss, base_loss)
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(base_p), jax.tree.leaves(p)))
        assert d < 1e-4, (name, d)
    print("microbatch_equivalence OK")


def check_pipeline():
    """GPipe schedule over a 4-stage axis == sequential composition."""
    from repro.parallel.pipeline import pipeline_shardmap, split_microbatches

    mesh = jax.make_mesh((4,), ("pod",))
    d = 16
    ws = jax.random.normal(jax.random.key(0), (4, d, d)) * (d**-0.5)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    fn = pipeline_shardmap(mesh, stage_fn, axis="pod")
    x = jax.random.normal(jax.random.key(1), (8, 2, d))  # 8 microbatches
    out = fn(ws, x)

    want = x
    for i in range(4):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    print("pipeline OK")


CHECKS = {
    "engines": check_engines,
    "transport": check_transport,
    "stacks_backends": check_stacks_backends,
    "microbatch": check_microbatch_equivalence,
    "pipeline": check_pipeline,
    "engines_rectangular": check_engines_rectangular,
    "plan_rectangular": check_plan_rectangular,
    "plan_cache": check_plan_cache,
    "signiter_sharded": check_signiter_sharded,
    "envelope_sharded": check_envelope_sharded,
    "tuner_auto": check_tuner_auto,
    "comm_volume": check_comm_volume,
    "train_steps": check_train_steps,
    "serve_steps": check_serve_steps,
    "checkpoint_cross_mesh": check_checkpoint_cross_mesh,
    "data_global_batch": check_data_global_batch,
    "matmul_2p5d": check_matmul_2p5d,
    "compressed_allreduce": check_compressed_allreduce,
    "spgemm_scaling": check_spgemm_scaling,
    "assignment": check_assignment,
    "tensor": check_tensor,
}


def main(argv: list[str]) -> int:
    names = argv or list(CHECKS)
    for name in names:
        CHECKS[name]()
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
