"""Paper Algorithm 2 topology rules, buffer model, and schedule fidelity."""
from __future__ import annotations

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T


# ---- L validity (paper section 3) -----------------------------------------


@pytest.mark.parametrize(
    "pr,pc,l,valid",
    [
        (4, 4, 1, True),
        (4, 4, 4, True),  # square: L square int, sqrt(L) | P_R
        (4, 4, 9, False),  # 3 does not divide 4
        (6, 6, 4, True),
        (6, 6, 9, True),
        (4, 4, 2, False),  # 2 not a square
        (2, 4, 2, True),  # non-square: L = mx/mn forced
        (2, 4, 4, False),
        (2, 8, 4, True),  # mx=8 <= mn^2=4? NO: 8 > 4 -> invalid
        (3, 9, 3, True),  # 9 <= 9 ok, L = 3
        (2, 6, 3, False),  # mx=6 > mn^2=4
        (4, 2, 2, True),  # orientation-symmetric
    ],
)
def test_validate_l(pr, pc, l, valid):
    if (pr, pc, l) == (2, 8, 4):
        valid = False  # mx > mn^2 violates the paper's constraint
    assert T.validate_l(pr, pc, l) == valid


def test_invalid_l_falls_back_to_1():
    topo = T.make_topology(4, 4, 3)
    assert topo.l == 1  # Algorithm 2: "set L = 1 if not valid"


# ---- buffer counts (paper section 3) ---------------------------------------


@pytest.mark.parametrize(
    "pr,pc,l,expect",
    [
        (4, 4, 1, 6),  # OS1: 6 temporaries
        (2, 4, 2, 2 + 6),  # non-square: L + 6
        (4, 4, 4, 4 + 2 + 4),  # square: L + sqrt(L) + 4
        (9, 9, 9, 9 + 3 + 4),
    ],
)
def test_buffer_counts(pr, pc, l, expect):
    assert T.make_topology(pr, pc, l).total_buffers == expect


def test_nbuffers_a_square_rule():
    # square topology: max(2, sqrt(L)) buffers for A
    assert T.make_topology(4, 4, 4).nbuffers_a == 2
    assert T.make_topology(9, 9, 9).nbuffers_a == 3
    assert T.make_topology(4, 4, 1).nbuffers_a == 2


# ---- tick counts (V for Cannon, V/L for OSL) --------------------------------


@pytest.mark.parametrize("pr,pc,l", [(4, 4, 1), (4, 4, 4), (6, 6, 4), (2, 4, 2), (3, 9, 3)])
def test_tick_count(pr, pc, l):
    topo = T.make_topology(pr, pc, l)
    v = T.lcm(pr, pc)
    assert topo.v == v
    assert topo.ticks == math.ceil(v / topo.l)


def test_fetch_counts_sqrt_reduction():
    """A/B panel fetches per process drop by sqrt(L) on square grids — the
    panel-count form of Eq. (7): V -> V/sqrt(L)."""
    base = T.make_topology(4, 4, 1)
    deep = T.make_topology(4, 4, 4)
    a1, b1 = base.fetch_counts(0)
    a4, b4 = deep.fetch_counts(0)
    assert (a1, b1) == (4, 4)  # V = 4 fetches each for A and B
    assert (a4, b4) == (2, 2)  # V / sqrt(4) = 2
    assert a4 * math.isqrt(deep.l) == a1
    # 9x9 with L=9: V=9 -> 3
    nine = T.make_topology(9, 9, 9)
    a9, b9 = nine.fetch_counts(0)
    assert (a9, b9) == (3, 3)


def test_coords3d_partition():
    """Every process gets a unique (i3D, j3D) tile; layers partition k."""
    topo = T.make_topology(4, 4, 4)
    seen = {}
    for i in range(4):
        for j in range(4):
            i3, j3, l = T.coords3d(topo, i, j)
            assert 0 <= l < topo.l
            seen.setdefault(l, []).append((i, j))
    assert len(seen) == topo.l
    for l, procs in seen.items():
        assert len(procs) == 16 // topo.l
    # k-chunks partition [0, V)
    ranges = [topo.chunk(l) for l in range(topo.l)]
    flat = []
    for lo, hi in ranges:
        flat.extend(range(lo, hi))
    assert sorted(flat) == list(range(topo.v))


# ---- schedule fidelity: numpy simulator == A @ B ----------------------------


@pytest.mark.parametrize(
    "pr,pc,l",
    [
        (2, 2, 1),
        (2, 2, 4),
        (4, 4, 4),
        (4, 4, 16),
        (2, 4, 2),
        (4, 2, 2),
        (6, 2, 3),
        (3, 9, 3),
        (6, 6, 9),
    ],
)
def test_simulate_algorithm2_exact(pr, pc, l):
    rng = np.random.default_rng(pr * 100 + pc * 10 + l)
    v = T.lcm(pr, pc)
    n = math.lcm(v, pr, pc) * 2
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    c = T.simulate_algorithm2(a, b, pr, pc, l)
    np.testing.assert_allclose(c, a @ b, rtol=1e-10, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(
    pr=st.sampled_from([2, 3, 4]),
    ratio=st.sampled_from([1, 2, 3]),
    use_l=st.booleans(),
)
def test_property_schedule_all_grids(pr, ratio, use_l):
    """Any (pr, pr*ratio) grid with its forced/compatible L multiplies right."""
    pc = pr * ratio
    if ratio > pr:  # mx <= mn^2 constraint
        pc = pr
    l = 1
    if use_l:
        l = (pc // pr) if pr != pc else 4
        if not T.validate_l(pr, pc, l):
            l = 1
    rng = np.random.default_rng(0)
    v = T.lcm(pr, pc)
    n = math.lcm(v, pr, pc)
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    c = T.simulate_algorithm2(a, b, pr, pc, l)
    np.testing.assert_allclose(c, a @ b, rtol=1e-9, atol=1e-9)
