"""Transport layer: packing format, capacity bounds, mode resolution.

The distributed bit-exactness sweep (compressed == dense for every
engine across occupancy in {0, low, medium, full}, rectangular meshes
and uneven L) runs multi-device in tests/_dist.py::check_transport;
this module pins the layer's building blocks single-process:

* pack/unpack is an exact roundtrip whenever capacity bounds the
  occupied count (hypothesis over random patterns and capacities);
* the wire format is partial-permutation safe (all-zero wire state
  decodes as an empty panel, never as block (0, 0));
* ``panel_nnz_bound`` is sound for every partition cell (hypothesis);
* the auto mode crossover and the ``REPRO_TRANSPORT`` override;
* transport mode + capacities key the compiled-program cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import plan as plan_mod
from repro.core import transport as T


def _random_panel(seed: int, nr: int, nc: int, occ: float, bs: int = 4):
    rng = np.random.default_rng(seed)
    mask = rng.random((nr, nc)) < occ
    blocks = rng.standard_normal((nr, nc, bs, bs)).astype(np.float32)
    blocks = blocks * mask[:, :, None, None]
    return jnp.asarray(blocks), jnp.asarray(mask)


# ---- packing format --------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    nr=st.integers(1, 6),
    nc=st.integers(1, 6),
    occ=st.sampled_from([0.0, 0.1, 0.5, 1.0]),
    slack=st.integers(0, 5),
)
def test_pack_unpack_roundtrip_exact(seed, nr, nc, occ, slack):
    """unpack(pack(panel)) == panel bitwise for any capacity >= nnz."""
    blocks, mask = _random_panel(seed, nr, nc, occ)
    cap = int(np.asarray(mask).sum()) + slack
    if cap == 0:
        cap = 1  # capacity must stay positive for a wire buffer to exist
    packed, idx1 = T.pack_panel(blocks, mask, cap)
    assert packed.shape == (cap,) + blocks.shape[2:]
    assert idx1.shape == (cap,)
    ub, um = T.unpack_panel(packed, idx1, nr, nc)
    np.testing.assert_array_equal(np.asarray(ub), np.asarray(blocks))
    np.testing.assert_array_equal(np.asarray(um), np.asarray(mask))


def test_unpack_of_zero_wire_state_is_empty():
    """A device a partial permutation does not address receives zeros —
    they must decode as an empty panel (the one-based index encoding)."""
    bs = 4
    ub, um = T.unpack_panel(
        jnp.zeros((8, bs, bs), jnp.float32), jnp.zeros((8,), jnp.int32), 3, 5
    )
    assert not bool(np.asarray(um).any())
    assert not bool(np.asarray(ub).any())


def test_pack_drops_excess_beyond_capacity():
    """Under-capacity packing silently truncates — the reason the plan
    layer must derive sound bounds (and the bound test below exists)."""
    blocks, mask = _random_panel(0, 4, 4, 1.0)
    packed, idx1 = T.pack_panel(blocks, mask, 8)  # 16 occupied, cap 8
    _, um = T.unpack_panel(packed, idx1, 4, 4)
    assert int(np.asarray(um).sum()) == 8


def test_panel_norms_matches_block_norms_and_skips_when_unfiltered():
    from repro.core.bsm import block_norms

    blocks, _ = _random_panel(1, 3, 3, 0.5)
    np.testing.assert_array_equal(
        np.asarray(T.panel_norms(blocks, 0.5)),
        np.asarray(block_norms(blocks)),
    )
    assert not bool(np.asarray(T.panel_norms(blocks, 0.0)).any())


# ---- capacity bounds -------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    rp=st.sampled_from([1, 2, 4]),
    cp=st.sampled_from([1, 2, 4]),
    mult=st.integers(1, 3),
    occ=st.floats(0.0, 1.0),
)
def test_panel_nnz_bound_sound_for_every_cell(seed, rp, cp, mult, occ):
    """The derived capacity covers EVERY panel of the partition — the
    transport analogue of the distributed stack-bound soundness."""
    nr, nc = rp * mult, cp * mult * 2
    rng = np.random.default_rng(seed)
    mask = rng.random((nr, nc)) < occ
    bound = T.panel_nnz_bound(mask, rp, cp)
    hr, hc = nr // rp, nc // cp
    for i in range(rp):
        for j in range(cp):
            cell = mask[i * hr:(i + 1) * hr, j * hc:(j + 1) * hc]
            assert int(cell.sum()) <= bound


def test_panel_nnz_bound_rejects_non_dividing_partition():
    with pytest.raises(ValueError, match="does not divide"):
        T.panel_nnz_bound(np.ones((6, 6), bool), 4, 2)


def test_plan_panel_parts_pull_vs_shard():
    """Pull plans ship virtual-grid subpanels; everything else ships
    whole home shards."""
    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    for engine in ("gather", "cannon"):
        plan = plan_mod.plan_multiply(mesh, engine)
        assert T.plan_panel_parts(plan) == ((1, 1), (1, 1))
    pull = plan_mod.plan_multiply(mesh, "onesided")
    (ar, ac), (br, bc) = T.plan_panel_parts(pull)
    assert (ar, ac) == (1, pull.ca) and (br, bc) == (pull.cb, 1)


# ---- mode resolution -------------------------------------------------------


def test_resolve_mode_crossover():
    # low bucketed fill -> compressed; high fill / tiny panels -> dense
    assert T.resolve_mode("auto", 8, 8, 64, 64) == "compressed"
    assert T.resolve_mode("auto", 32, 8, 64, 64) == "dense"
    assert T.resolve_mode("auto", 8, 8, 16, 16) == "dense"
    # explicit modes pass through untouched
    assert T.resolve_mode("dense", 8, 8, 1024, 1024) == "dense"
    assert T.resolve_mode("compressed", 64, 64, 64, 64) == "compressed"


def test_panel_transport_validation():
    with pytest.raises(ValueError, match="unknown transport mode"):
        T.PanelTransport("zstd")
    with pytest.raises(ValueError, match="positive panel capacities"):
        T.PanelTransport("compressed", 0, 8)
    tr = T.PanelTransport("compressed", 8, 16)
    assert tr.key == ("compressed", 8, 16)
    assert T.DENSE.key == ("dense", 0, 0)


def test_transport_mode_env_override(monkeypatch):
    from repro.config import transport_mode

    monkeypatch.delenv("REPRO_TRANSPORT", raising=False)
    assert transport_mode() == "auto"
    for raw, want in (("dense", "dense"), ("COMPRESSED", "compressed"),
                      ("auto", "auto"), ("", "auto")):
        monkeypatch.setenv("REPRO_TRANSPORT", raw)
        assert transport_mode() == want
    monkeypatch.setenv("REPRO_TRANSPORT", "gzip")
    with pytest.raises(ValueError, match="REPRO_TRANSPORT"):
        transport_mode()


# ---- plan-layer resolution + program-cache keying --------------------------


def test_get_transport_caps_and_counters():
    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    plan_mod.clear_cache()
    mask = np.zeros((8, 8), bool)
    mask[0, :3] = True  # 3 occupied blocks in the single shard
    tr = plan_mod.get_transport(mask, mask, mesh, "gather",
                                mode="compressed")
    assert tr.mode == "compressed"
    assert tr.cap_a == tr.cap_b == T.MIN_CAPACITY  # 3 bucketed up to 8
    s1 = plan_mod.cache_stats()
    assert s1["transport_misses"] == 1 and s1["transport_compressed"] == 1
    # repeat: served from the signature cache
    tr2 = plan_mod.get_transport(mask, mask, mesh, "gather",
                                 mode="compressed")
    assert tr2 is tr
    s2 = plan_mod.cache_stats()
    assert s2["transport_hits"] == 1 and s2["transport_misses"] == 1
    # high fill under auto -> dense
    dense_tr = plan_mod.get_transport(
        np.ones((8, 8), bool), np.ones((8, 8), bool), mesh, "gather",
        mode="auto")
    assert dense_tr.mode == "dense"
    s3 = plan_mod.cache_stats()
    assert s3["transport_dense"] == 1
    # clear_cache drops the resolution cache and zeroes the counters
    plan_mod.clear_cache()
    s4 = plan_mod.cache_stats()
    assert s4["transport_hits"] == s4["transport_misses"] == 0
    assert s4["transport_dense"] == s4["transport_compressed"] == 0


def test_transport_keys_program_cache():
    """Dense and compressed transport compile distinct programs; the
    same resolved transport re-hits one program."""
    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    from repro.core import bsm as B
    from repro.core.engine import multiply, multiply_reference

    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a = B.random_bsm(jax.random.key(0), nb=4, bs=4, occupancy=0.3)
    b = B.random_bsm(jax.random.key(1), nb=4, bs=4, occupancy=0.3)
    ref = np.asarray(multiply_reference(a, b).to_dense())

    plan_mod.clear_cache()
    c1 = multiply(a, b, mesh, engine="onesided", transport="dense")
    s1 = plan_mod.cache_stats()
    c2 = multiply(a, b, mesh, engine="onesided", transport="compressed")
    s2 = plan_mod.cache_stats()
    assert s2["builds"] == s1["builds"] + 1  # distinct program per mode
    c3 = multiply(a, b, mesh, engine="onesided", transport="compressed")
    s3 = plan_mod.cache_stats()
    assert s3["builds"] == s2["builds"]  # same resolved transport: a hit
    assert s3["hits"] == s2["hits"] + 1
    for c in (c1, c2, c3):
        np.testing.assert_allclose(np.asarray(c.to_dense()), ref,
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c2.to_dense()),
                                  np.asarray(c3.to_dense()))


def test_under_capacity_transport_rejected():
    """An explicit PanelTransport whose capacities under-cover this
    engine's panels must be rejected at resolution — pack_panel
    truncates silently, so a mismatched transport (e.g. capacities
    derived for a different plan kind) would yield a wrong C."""
    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    from repro.core import bsm as B
    from repro.core.engine import multiply

    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a = B.random_bsm(jax.random.key(0), nb=8, bs=4, occupancy=1.0)
    with pytest.raises(ValueError, match="under-cover"):
        multiply(a, a, mesh, engine="cannon",
                 transport=T.PanelTransport("compressed", 8, 8))
    # sufficient (>= bound) capacities pass through untouched
    big = T.PanelTransport("compressed", 64, 64)
    c = multiply(a, a, mesh, engine="cannon", transport=big)
    d = multiply(a, a, mesh, engine="cannon", transport="dense")
    np.testing.assert_array_equal(np.asarray(c.to_dense()),
                                  np.asarray(d.to_dense()))


def test_forced_compressed_on_traced_operands_raises():
    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    from repro.core import bsm as B
    from repro.core.engine import multiply

    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a = B.random_bsm(jax.random.key(0), nb=4, bs=4, occupancy=0.5)

    @jax.jit
    def traced(x, y):
        return multiply(x, y, mesh, engine="onesided",
                        transport="compressed")

    with pytest.raises(ValueError, match="concrete operand patterns"):
        traced(a, a)


# ---- reduced-precision wire -------------------------------------------------


def test_wire_validation_and_key_back_compat():
    with pytest.raises(ValueError, match="unknown wire"):
        T.PanelTransport("dense", wire="float16x")
    # native wire keeps the historical 3-element key: a program cached
    # before the wire field must keep hitting
    assert T.PanelTransport("compressed", 8, 16).key == ("compressed", 8, 16)
    assert T.DENSE.key == ("dense", 0, 0)
    tr = T.PanelTransport("dense", wire="bfloat16")
    assert tr.key == ("dense", 0, 0, "bfloat16")
    assert tr.wire_itemsize(4.0) == 2.0
    assert T.DENSE.wire_itemsize(4.0) == 4.0
    assert T.DENSE.wire_dtype is None


def test_wire_cast_dense_roundtrip():
    """Dense transport at bf16 wire: ingest casts, dense_view widens back
    to the compute dtype; values land within bf16 rounding."""
    blocks, mask = _random_panel(5, 3, 4, 0.6)
    tr = T.PanelTransport("dense", wire="bfloat16")
    state = T.ingest(tr, tr.cap_a, blocks, mask)
    wb, _ = state
    assert wb.dtype == jnp.bfloat16
    vb, vm = T.dense_view(tr, state, 3, 4, dtype=jnp.float32)
    assert vb.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(vm), np.asarray(mask))
    np.testing.assert_allclose(
        np.asarray(vb), np.asarray(blocks), rtol=1e-2, atol=1e-2
    )


def test_wire_cast_compressed_roundtrip():
    blocks, mask = _random_panel(6, 4, 4, 0.5)
    cap = max(int(np.asarray(mask).sum()), 1)
    tr = T.PanelTransport("compressed", cap, cap, wire="bfloat16")
    state = T.ingest(tr, cap, blocks, mask)
    packed, idx1 = state
    assert packed.dtype == jnp.bfloat16
    assert idx1.dtype == jnp.int32  # indices never quantize
    vb, vm = T.dense_view(tr, state, 4, 4, dtype=jnp.float32)
    assert vb.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(vm), np.asarray(mask))
    np.testing.assert_allclose(
        np.asarray(vb), np.asarray(blocks), rtol=1e-2, atol=1e-2
    )


def test_bf16_storage_native_wire_is_lossless():
    """The headline path: bf16 *storage* rides the native wire with no
    further cast — bitwise identical blocks, half the f32 bytes."""
    blocks, mask = _random_panel(7, 3, 3, 0.7)
    blocks = blocks.astype(jnp.bfloat16)
    state = T.ingest(T.DENSE, T.DENSE.cap_a, blocks, mask)
    vb, _ = T.dense_view(T.DENSE, state, 3, 3, dtype=jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(vb, np.float32), np.asarray(blocks, np.float32)
    )


def test_plan_volume_models_wire_width_exactly():
    """Eq. (7) at wire width: A/B hop bytes scale by wire/storage
    itemsize; partial-C/psum traffic stays at storage width."""
    from jax.sharding import AbstractMesh

    from repro.core import commvolume as CV

    mesh = AbstractMesh((("r", 2), ("c", 2)))
    for engine in ("cannon", "gather", "onesided"):
        plan = plan_mod.plan_multiply(mesh, engine)
        v32 = CV.plan_volume(plan, 4, 8, itemsize=4.0)
        vw = CV.plan_volume(
            plan, 4, 8, itemsize=4.0,
            transport=T.PanelTransport("dense", wire="bfloat16"),
        )
        assert vw.c_volume == v32.c_volume  # C never rides the wire cast
        # A/B bytes: blocks halve, the 1-byte mask sidecar does not
        bs, nb = 8, 4
        blk32 = 4.0 * bs * bs
        blk16 = 2.0 * bs * bs
        n_blocks = v32.ab_volume / (blk32 + 1.0)
        assert vw.ab_volume == pytest.approx(n_blocks * (blk16 + 1.0))
    # the stacked twofive plan: same halving on its gather legs
    mesh3 = AbstractMesh((("l", 2), ("r", 2), ("c", 2)))
    plan = plan_mod.plan_multiply(mesh3, "twofive")
    v32 = CV.plan_volume(plan, 4, 8, itemsize=4.0)
    vw = CV.plan_volume(
        plan, 4, 8, itemsize=4.0,
        transport=T.PanelTransport("dense", wire="bfloat16"),
    )
    assert vw.ab_volume < v32.ab_volume
    assert vw.c_volume == v32.c_volume
