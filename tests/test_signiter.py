"""Matrix-sign iteration — the paper's driving application (Eqs. (1)-(3))."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bsm as B
from repro.core.signiter import density_matrix, sign_iteration, trace


def _sym_bsm(key, nb=4, bs=8, occupancy=0.6):
    return B.random_bsm(key, nb=nb, bs=bs, occupancy=occupancy,
                        pattern="banded", symmetric=True)


def test_sign_converges_and_is_involutory():
    m = _sym_bsm(jax.random.key(0))
    s, stats = sign_iteration(m, max_iter=80, tol=1e-6)
    assert stats.converged, stats
    dense = np.asarray(s.to_dense(), np.float64)
    # sign(A)^2 == I
    np.testing.assert_allclose(dense @ dense, np.eye(dense.shape[0]), atol=5e-4)


def test_sign_matches_eigendecomposition():
    m = _sym_bsm(jax.random.key(1))
    dense = np.asarray(m.to_dense(), np.float64)
    w, v = np.linalg.eigh(dense)
    want = v @ np.diag(np.sign(w)) @ v.T
    s, stats = sign_iteration(m, max_iter=100, tol=1e-6)
    assert stats.converged
    np.testing.assert_allclose(np.asarray(s.to_dense(), np.float64), want, atol=1e-3)


def test_density_matrix_counts_states():
    """trace(P) == number of eigenvalues below mu (paper Eq. (1) observable)."""
    m = _sym_bsm(jax.random.key(2), nb=4, bs=6)
    dense = np.asarray(m.to_dense(), np.float64)
    w = np.linalg.eigvalsh(dense)
    mu = float(np.median(w)) + 1e-3
    p, stats = density_matrix(m, mu, max_iter=100, tol=1e-6)
    assert stats.converged
    n_occ = int((w < mu).sum())
    assert float(trace(p)) == pytest.approx(n_occ, abs=1e-2)
    # P idempotent (a projector)
    pd = np.asarray(p.to_dense(), np.float64)
    np.testing.assert_allclose(pd @ pd, pd, atol=1e-3)


def test_filtering_keeps_convergence():
    """With on-the-fly + post filtering the iteration still converges and
    the result stays close to the unfiltered one (the paper's premise that
    filtered SpGEMM preserves the physics)."""
    m = _sym_bsm(jax.random.key(3), nb=6, bs=6, occupancy=0.4)
    s_exact, st_exact = sign_iteration(m, max_iter=100, tol=1e-6)
    s_filt, st_filt = sign_iteration(
        m, threshold=1e-7, filter_eps=1e-6, max_iter=100, tol=1e-6
    )
    assert st_exact.converged and st_filt.converged
    err = np.abs(
        np.asarray(s_exact.to_dense(), np.float64)
        - np.asarray(s_filt.to_dense(), np.float64)
    ).max()
    assert err < 1e-3
    # filtering keeps occupancy at or below the unfiltered trajectory end
    assert st_filt.occupancy_trace[-1] <= 1.0


def test_two_multiplications_per_iteration():
    """Paper: 'two multiplications per iteration' (Eq. (3))."""
    m = _sym_bsm(jax.random.key(4))
    _, stats = sign_iteration(m, max_iter=7, tol=0.0)
    assert stats.multiplications == 2 * stats.iterations


# ---------------------------------------------------------------------------
# fused device-resident sweep vs the legacy per-op loop (DESIGN.md §5)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "stacks"])
@pytest.mark.parametrize("thr,eps", [(0.0, 0.0), (1e-7, 1e-6), (1e-4, 1e-4)])
def test_fused_matches_legacy(backend, thr, eps):
    """Same residual trace, occupancy trace and converged X to 1e-5."""
    m = _sym_bsm(jax.random.key(5), nb=4, bs=6, occupancy=0.5)
    s_leg, st_leg = sign_iteration(
        m, threshold=thr, filter_eps=eps, max_iter=80, tol=1e-6,
        mode="legacy")
    s_fus, st_fus = sign_iteration(
        m, threshold=thr, filter_eps=eps, max_iter=80, tol=1e-6,
        mode="fused", backend=backend)
    assert st_leg.converged and st_fus.converged
    assert st_fus.iterations == st_leg.iterations
    assert st_fus.multiplications == st_leg.multiplications
    np.testing.assert_allclose(
        st_fus.residual_trace, st_leg.residual_trace, rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(
        st_fus.occupancy_trace, st_leg.occupancy_trace, atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(s_fus.to_dense()), np.asarray(s_leg.to_dense()),
        rtol=1e-5, atol=1e-5)


def test_sync_every_converges_to_same_sign():
    """sync_every > 1 trades host syncs for (at most k-1) extra polishing
    sweeps; the converged sign matrix is unchanged."""
    m = _sym_bsm(jax.random.key(6))
    s1, st1 = sign_iteration(m, max_iter=80, tol=1e-6, sync_every=1)
    s5, st5 = sign_iteration(m, max_iter=80, tol=1e-6, sync_every=5)
    assert st1.converged and st5.converged
    assert st1.iterations <= st5.iterations <= st1.iterations + 4
    assert st5.host_syncs <= -(-st5.iterations // 5) + 1
    assert st5.host_syncs < st5.iterations
    # traces are complete despite the batched syncs
    assert len(st5.residual_trace) == st5.iterations
    np.testing.assert_allclose(
        np.asarray(s5.to_dense()), np.asarray(s1.to_dense()), atol=1e-5)


def test_fused_density_matrix_counts_states():
    m = _sym_bsm(jax.random.key(7), nb=4, bs=6)
    dense = np.asarray(m.to_dense(), np.float64)
    w = np.linalg.eigvalsh(dense)
    mu = float(np.median(w)) + 1e-3
    p, stats = density_matrix(m, mu, max_iter=100, tol=1e-6,
                              mode="fused", sync_every=3)
    assert stats.converged and stats.mode == "fused"
    assert float(trace(p)) == pytest.approx(int((w < mu).sum()), abs=1e-2)


def test_pattern_cache_rehits_on_evolving_x():
    """Per-chain pattern counters: the legacy/compacted path walks X's
    concrete pattern every multiply; as the iteration's sparsity structure
    stabilizes, the walks become pattern-cache re-hits (and the capacity
    buckets keep the compiled-program count far below the multiply
    count)."""
    from repro.core import plan as plan_mod

    m = _sym_bsm(jax.random.key(9), nb=4, bs=6, occupancy=0.5)
    plan_mod.clear_cache()
    _, st = sign_iteration(m, threshold=1e-6, filter_eps=1e-6, max_iter=80,
                           tol=1e-6, mode="legacy", backend="stacks")
    stats = plan_mod.cache_stats()
    assert st.converged
    # every multiply compacted a pattern; most were repeats of an earlier
    # sweep's structure
    walks = stats["pattern_hits"] + stats["pattern_misses"]
    assert walks >= st.multiplications, (stats, st.multiplications)
    assert stats["pattern_hits"] > st.multiplications // 2, (
        stats, st.multiplications)
    # capacity bucketing: far fewer compiled local programs than multiplies
    assert stats["builds"] < st.multiplications // 2, stats


def test_fused_rejects_bad_args():
    m = _sym_bsm(jax.random.key(8))
    with pytest.raises(ValueError):
        sign_iteration(m, mode="turbo")
    with pytest.raises(ValueError):
        sign_iteration(m, sync_every=0)


def test_sign_iteration_storage_dtype_matrix():
    """The CI dtype matrix leg (REPRO_STORAGE_DTYPE): purification runs
    end-to-end at the configured storage dtype and lands within that
    dtype's documented tolerance of the f32 oracle (DESIGN.md §2 —
    bf16 blocks, f32 accumulation, norms recalibrated after the cast)."""
    from repro.config import storage_dtype

    dt = storage_dtype()
    m = _sym_bsm(jax.random.key(4))
    s32, _ = sign_iteration(m, max_iter=80, tol=1e-6)
    tol = {"float32": 1e-6, "bfloat16": 1e-2}[dt]
    s, st = sign_iteration(m, storage_dtype=dt, max_iter=80, tol=max(tol, 1e-6))
    assert st.converged, st
    assert s.blocks.dtype == jnp.dtype(dt)
    err = np.abs(np.asarray(s.to_dense(), np.float64)
                 - np.asarray(s32.to_dense(), np.float64)).max()
    assert err <= {"float32": 1e-5, "bfloat16": 7e-2}[dt], (dt, err)
