"""Matrix-sign iteration — the paper's driving application (Eqs. (1)-(3))."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bsm as B
from repro.core.signiter import density_matrix, sign_iteration, trace


def _sym_bsm(key, nb=4, bs=8, occupancy=0.6):
    return B.random_bsm(key, nb=nb, bs=bs, occupancy=occupancy,
                        pattern="banded", symmetric=True)


def test_sign_converges_and_is_involutory():
    m = _sym_bsm(jax.random.key(0))
    s, stats = sign_iteration(m, max_iter=80, tol=1e-6)
    assert stats.converged, stats
    dense = np.asarray(s.to_dense(), np.float64)
    # sign(A)^2 == I
    np.testing.assert_allclose(dense @ dense, np.eye(dense.shape[0]), atol=5e-4)


def test_sign_matches_eigendecomposition():
    m = _sym_bsm(jax.random.key(1))
    dense = np.asarray(m.to_dense(), np.float64)
    w, v = np.linalg.eigh(dense)
    want = v @ np.diag(np.sign(w)) @ v.T
    s, stats = sign_iteration(m, max_iter=100, tol=1e-6)
    assert stats.converged
    np.testing.assert_allclose(np.asarray(s.to_dense(), np.float64), want, atol=1e-3)


def test_density_matrix_counts_states():
    """trace(P) == number of eigenvalues below mu (paper Eq. (1) observable)."""
    m = _sym_bsm(jax.random.key(2), nb=4, bs=6)
    dense = np.asarray(m.to_dense(), np.float64)
    w = np.linalg.eigvalsh(dense)
    mu = float(np.median(w)) + 1e-3
    p, stats = density_matrix(m, mu, max_iter=100, tol=1e-6)
    assert stats.converged
    n_occ = int((w < mu).sum())
    assert float(trace(p)) == pytest.approx(n_occ, abs=1e-2)
    # P idempotent (a projector)
    pd = np.asarray(p.to_dense(), np.float64)
    np.testing.assert_allclose(pd @ pd, pd, atol=1e-3)


def test_filtering_keeps_convergence():
    """With on-the-fly + post filtering the iteration still converges and
    the result stays close to the unfiltered one (the paper's premise that
    filtered SpGEMM preserves the physics)."""
    m = _sym_bsm(jax.random.key(3), nb=6, bs=6, occupancy=0.4)
    s_exact, st_exact = sign_iteration(m, max_iter=100, tol=1e-6)
    s_filt, st_filt = sign_iteration(
        m, threshold=1e-7, filter_eps=1e-6, max_iter=100, tol=1e-6
    )
    assert st_exact.converged and st_filt.converged
    err = np.abs(
        np.asarray(s_exact.to_dense(), np.float64)
        - np.asarray(s_filt.to_dense(), np.float64)
    ).max()
    assert err < 1e-3
    # filtering keeps occupancy at or below the unfiltered trajectory end
    assert st_filt.occupancy_trace[-1] <= 1.0


def test_two_multiplications_per_iteration():
    """Paper: 'two multiplications per iteration' (Eq. (3))."""
    m = _sym_bsm(jax.random.key(4))
    _, stats = sign_iteration(m, max_iter=7, tol=0.0)
    assert stats.multiplications == 2 * stats.iterations
