"""Block-sparse matrix format: invariants and semantics (paper section 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bsm as B


def test_to_dense_roundtrip():
    key = jax.random.key(0)
    m = B.random_bsm(key, nb=6, bs=4, occupancy=0.5)
    d = m.to_dense()
    m2 = B.from_dense(d, bs=4)
    np.testing.assert_allclose(np.asarray(m2.to_dense()), np.asarray(d), rtol=1e-6)


def test_from_dense_shape_check():
    with pytest.raises(ValueError):
        B.from_dense(jnp.zeros((10, 10)), bs=4)


def test_mask_zeroes_blocks():
    key = jax.random.key(1)
    blocks = jax.random.normal(key, (4, 4, 3, 3))
    mask = jnp.zeros((4, 4), bool).at[0, 0].set(True)
    m = B.make_bsm(blocks, mask)
    # masked-out blocks must be exactly zero (consistency of the triple)
    dense = np.asarray(m.to_dense())
    assert np.all(dense[3:, :] == 0)
    assert np.any(dense[:3, :3] != 0)
    assert float(m.occupancy()) == pytest.approx(1 / 16)


def test_norms_consistent_with_blocks():
    key = jax.random.key(2)
    m = B.random_bsm(key, nb=5, bs=4, occupancy=0.4)
    ref = np.linalg.norm(
        np.asarray(m.blocks, np.float32), axis=(2, 3)
    )
    np.testing.assert_allclose(np.asarray(m.norms), ref, rtol=1e-5, atol=1e-6)


def test_filter_bsm_drops_small_blocks():
    key = jax.random.key(3)
    m = B.random_bsm(key, nb=6, bs=4, occupancy=1.0, pattern="dense")
    scaled = B.BlockSparseMatrix(
        blocks=m.blocks.at[0, 1].mul(1e-8),
        mask=m.mask,
        norms=B.block_norms(m.blocks.at[0, 1].mul(1e-8)),
    )
    f = B.filter_bsm(scaled, threshold=1e-4)
    assert not bool(f.mask[0, 1])
    assert bool(f.mask[0, 0])
    # filtered block data is zeroed, not just masked
    assert float(jnp.abs(f.blocks[0, 1]).max()) == 0.0


def test_identity_multiplicative():
    from repro.core.engine import multiply_reference

    key = jax.random.key(4)
    m = B.random_bsm(key, nb=4, bs=8, occupancy=0.5)
    eye = B.identity(4, 8)
    out = multiply_reference(m, eye)
    np.testing.assert_allclose(
        np.asarray(out.to_dense()), np.asarray(m.to_dense()), rtol=1e-5, atol=1e-5
    )


def test_add_scale():
    key = jax.random.key(5)
    a = B.random_bsm(key, nb=4, bs=4, occupancy=0.4)
    b = B.random_bsm(jax.random.key(6), nb=4, bs=4, occupancy=0.4)
    s = B.add(B.scale(a, 2.0), b)
    np.testing.assert_allclose(
        np.asarray(s.to_dense()),
        2.0 * np.asarray(a.to_dense()) + np.asarray(b.to_dense()),
        rtol=1e-5,
        atol=1e-5,
    )


def test_permutation_preserves_content():
    key = jax.random.key(7)
    m = B.random_bsm(key, nb=6, bs=4, occupancy=0.5)
    perm = B.random_load_balance_permutation(jax.random.key(8), 6)
    p = B.permute(m, perm, perm)
    # permuting block rows/cols == permuting dense rows/cols blockwise
    dense = np.asarray(m.to_dense()).reshape(6, 4, 6, 4)
    expect = dense[perm][:, :, perm].reshape(24, 24)
    np.testing.assert_allclose(np.asarray(p.to_dense()), expect, rtol=1e-6)


def test_grid_block_loads_balance():
    """The paper's randomized permutation evens out per-panel block loads."""
    rng = np.random.default_rng(0)
    nb = 64
    # adversarial pattern: all blocks in the top rows
    mask = np.zeros((nb, nb), bool)
    mask[:16, :] = True
    loads_before = B.grid_block_loads(mask, 4, 4)
    perm = rng.permutation(nb)
    loads_after = B.grid_block_loads(mask[perm][:, perm], 4, 4)
    assert loads_before.max() - loads_before.min() == 256  # fully unbalanced
    assert loads_after.std() < loads_before.std()


@settings(max_examples=20, deadline=None)
@given(
    nb=st.integers(2, 8),
    bs=st.sampled_from([1, 2, 4]),
    occ=st.floats(0.05, 1.0),
)
def test_property_occupancy_and_diag(nb, bs, occ):
    m = B.random_bsm(jax.random.key(42), nb=nb, bs=bs, occupancy=occ)
    # diagonal always occupied (operators have dominant diagonal)
    assert bool(jnp.all(jnp.diag(m.mask)))
    assert 0.0 < float(m.occupancy()) <= 1.0
    # norms zero exactly where mask is False
    off = np.asarray(m.norms)[~np.asarray(m.mask)]
    assert np.all(off == 0.0)
