"""Block-sparse matrix format: invariants and semantics (paper section 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bsm as B


def test_to_dense_roundtrip():
    key = jax.random.key(0)
    m = B.random_bsm(key, nb=6, bs=4, occupancy=0.5)
    d = m.to_dense()
    m2 = B.from_dense(d, bs=4)
    np.testing.assert_allclose(np.asarray(m2.to_dense()), np.asarray(d), rtol=1e-6)


def test_from_dense_shape_check():
    with pytest.raises(ValueError):
        B.from_dense(jnp.zeros((10, 10)), bs=4)


def test_mask_zeroes_blocks():
    key = jax.random.key(1)
    blocks = jax.random.normal(key, (4, 4, 3, 3))
    mask = jnp.zeros((4, 4), bool).at[0, 0].set(True)
    m = B.make_bsm(blocks, mask)
    # masked-out blocks must be exactly zero (consistency of the triple)
    dense = np.asarray(m.to_dense())
    assert np.all(dense[3:, :] == 0)
    assert np.any(dense[:3, :3] != 0)
    assert float(m.occupancy()) == pytest.approx(1 / 16)


def test_norms_consistent_with_blocks():
    key = jax.random.key(2)
    m = B.random_bsm(key, nb=5, bs=4, occupancy=0.4)
    ref = np.linalg.norm(
        np.asarray(m.blocks, np.float32), axis=(2, 3)
    )
    np.testing.assert_allclose(np.asarray(m.norms), ref, rtol=1e-5, atol=1e-6)


def test_filter_bsm_drops_small_blocks():
    key = jax.random.key(3)
    m = B.random_bsm(key, nb=6, bs=4, occupancy=1.0, pattern="dense")
    scaled = B.BlockSparseMatrix(
        blocks=m.blocks.at[0, 1].mul(1e-8),
        mask=m.mask,
        norms=B.block_norms(m.blocks.at[0, 1].mul(1e-8)),
    )
    f = B.filter_bsm(scaled, threshold=1e-4)
    assert not bool(f.mask[0, 1])
    assert bool(f.mask[0, 0])
    # filtered block data is zeroed, not just masked
    assert float(jnp.abs(f.blocks[0, 1]).max()) == 0.0


def test_identity_multiplicative():
    from repro.core.engine import multiply_reference

    key = jax.random.key(4)
    m = B.random_bsm(key, nb=4, bs=8, occupancy=0.5)
    eye = B.identity(4, 8)
    out = multiply_reference(m, eye)
    np.testing.assert_allclose(
        np.asarray(out.to_dense()), np.asarray(m.to_dense()), rtol=1e-5, atol=1e-5
    )


def test_add_scale():
    key = jax.random.key(5)
    a = B.random_bsm(key, nb=4, bs=4, occupancy=0.4)
    b = B.random_bsm(jax.random.key(6), nb=4, bs=4, occupancy=0.4)
    s = B.add(B.scale(a, 2.0), b)
    np.testing.assert_allclose(
        np.asarray(s.to_dense()),
        2.0 * np.asarray(a.to_dense()) + np.asarray(b.to_dense()),
        rtol=1e-5,
        atol=1e-5,
    )


def test_permutation_preserves_content():
    key = jax.random.key(7)
    m = B.random_bsm(key, nb=6, bs=4, occupancy=0.5)
    perm = B.random_load_balance_permutation(jax.random.key(8), 6)
    p = B.permute(m, perm, perm)
    # permuting block rows/cols == permuting dense rows/cols blockwise
    dense = np.asarray(m.to_dense()).reshape(6, 4, 6, 4)
    expect = dense[perm][:, :, perm].reshape(24, 24)
    np.testing.assert_allclose(np.asarray(p.to_dense()), expect, rtol=1e-6)


def test_grid_block_loads_balance():
    """The paper's randomized permutation evens out per-panel block loads."""
    rng = np.random.default_rng(0)
    nb = 64
    # adversarial pattern: all blocks in the top rows
    mask = np.zeros((nb, nb), bool)
    mask[:16, :] = True
    loads_before = B.grid_block_loads(mask, 4, 4)
    perm = rng.permutation(nb)
    loads_after = B.grid_block_loads(mask[perm][:, perm], 4, 4)
    assert loads_before.max() - loads_before.min() == 256  # fully unbalanced
    assert loads_after.std() < loads_before.std()


# ---------------------------------------------------------------------------
# rectangular atomic blocks in from_dense / identity (PR 2 made them
# first-class in the engines; the constructors must accept them too)
# ---------------------------------------------------------------------------


def test_from_dense_rectangular_blocks():
    dense = jnp.asarray(np.arange(8 * 6, dtype=np.float32).reshape(8, 6))
    m = B.from_dense(dense, (4, 2))
    assert (m.nb_r, m.nb_c, m.bs_r, m.bs_c) == (2, 3, 4, 2)
    np.testing.assert_allclose(np.asarray(m.to_dense()), np.asarray(dense))
    # int spec still means square
    m2 = B.from_dense(jnp.zeros((8, 8)), 4)
    assert (m2.bs_r, m2.bs_c) == (4, 4)
    with pytest.raises(ValueError):
        B.from_dense(dense, (4, 4))  # 6 % 4 != 0


def test_identity_rectangular_blocks():
    i = B.identity(3, (4, 2))
    assert i.blocks.shape == (3, 6, 4, 2)
    np.testing.assert_allclose(np.asarray(i.to_dense()), np.eye(12))
    # tuple spec with equal sides == the square fast path
    np.testing.assert_allclose(
        np.asarray(B.identity(3, (4, 4)).to_dense()),
        np.asarray(B.identity(3, 4).to_dense()),
    )
    with pytest.raises(ValueError):
        B.identity(3, (4, 5))  # 12 % 5 != 0


def test_identity_rectangular_multiplicative():
    from repro.core.engine import multiply_reference

    key = jax.random.key(9)
    blocks = jax.random.normal(key, (3, 3, 2, 4))  # rectangular A blocks
    a = B.make_bsm(blocks, jnp.ones((3, 3), bool))
    eye = B.identity(3, (4, 4))
    out = multiply_reference(a, eye)
    np.testing.assert_allclose(
        np.asarray(out.to_dense()), np.asarray(a.to_dense()),
        rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# derived norms: filter / scale / add never go through make_bsm recompute
# ---------------------------------------------------------------------------


def test_derived_norms_match_make_bsm():
    m = B.random_bsm(jax.random.key(10), nb=6, bs=4, occupancy=0.6)
    thr = float(np.median(np.asarray(m.norms)[np.asarray(m.mask)]))
    for got, want in (
        (B.filter_bsm(m, thr), B.make_bsm(m.blocks, m.mask & (m.norms > thr))),
        (B.scale(m, -2.5), B.make_bsm(m.blocks * -2.5, m.mask)),
    ):
        np.testing.assert_array_equal(np.asarray(got.mask), np.asarray(want.mask))
        np.testing.assert_allclose(np.asarray(got.norms), np.asarray(want.norms),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(got.blocks), np.asarray(want.blocks),
                                   rtol=1e-6, atol=1e-7)
    # axpy == scale + add
    y = B.random_bsm(jax.random.key(11), nb=6, bs=4, occupancy=0.3)
    got = B.axpy(3.0, m, y)
    want = B.add(B.scale(m, 3.0), y)
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               np.asarray(want.to_dense()), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got.norms), np.asarray(want.norms),
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# ShardedBSM: device-resident container + shard-local algebra
# ---------------------------------------------------------------------------


def _mesh11():
    return jax.make_mesh((1, 1), ("r", "c"))


def test_sharded_bsm_roundtrip_and_algebra():
    mesh = _mesh11()
    a = B.random_bsm(jax.random.key(12), nb=4, bs=4, occupancy=0.5)
    b = B.random_bsm(jax.random.key(13), nb=4, bs=4, occupancy=0.5)
    sa, sb = B.shard_bsm(a, mesh), B.shard_bsm(b, mesh)
    # round trip
    np.testing.assert_allclose(np.asarray(B.unshard_bsm(sa).to_dense()),
                               np.asarray(a.to_dense()))
    assert B.shard_bsm(sa, mesh) is sa  # idempotent
    # algebra parity with the replicated ops, including derived norms
    pairs = [
        (sa.add(sb), B.add(a, b)),
        (sa.scale(-0.5), B.scale(a, -0.5)),
        (sa.axpy(2.0, sb), B.axpy(2.0, a, b)),
    ]
    thr = float(np.median(np.asarray(a.norms)[np.asarray(a.mask)]))
    pairs.append((sa.filter(thr), B.filter_bsm(a, thr)))
    for got, want in pairs:
        assert isinstance(got, B.ShardedBSM)
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   np.asarray(want.to_dense()),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(got.unshard().norms),
                                   np.asarray(want.norms),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(sa.frobenius_norm()),
                               float(a.frobenius_norm()), rtol=1e-6)
    from repro.core.signiter import trace

    np.testing.assert_allclose(float(sa.trace()), float(trace(a)), rtol=1e-5)
    assert float(sa.occupancy()) == pytest.approx(float(a.occupancy()))


def test_sharded_bsm_identity_and_errors():
    mesh = _mesh11()
    i = B.sharded_identity(4, 4, mesh)
    assert isinstance(i, B.ShardedBSM)
    np.testing.assert_allclose(np.asarray(i.to_dense()), np.eye(16))
    m = B.random_bsm(jax.random.key(14), nb=5, bs=2, occupancy=0.5)
    with pytest.raises(ValueError):
        B.shard_bsm(m, jax.make_mesh((1,), ("r",)))  # no 'c' axis


def test_sharded_multiply_reference_parity():
    from repro.core.engine import multiply, multiply_reference

    mesh = _mesh11()
    a = B.random_bsm(jax.random.key(15), nb=4, bs=4, occupancy=0.5)
    b = B.random_bsm(jax.random.key(16), nb=4, bs=4, occupancy=0.5)
    ref = multiply_reference(a, b, threshold=1e-3)
    c = multiply(B.shard_bsm(a, mesh), B.shard_bsm(b, mesh),
                 engine="onesided", threshold=1e-3, filter_eps=1e-3)
    assert isinstance(c, B.ShardedBSM)
    want = B.filter_bsm(ref, 1e-3)
    np.testing.assert_allclose(np.asarray(c.to_dense()),
                               np.asarray(want.to_dense()),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(TypeError):
        multiply(B.shard_bsm(a, mesh), b)  # mixed operands


@settings(max_examples=20, deadline=None)
@given(
    nb=st.integers(2, 8),
    bs=st.sampled_from([1, 2, 4]),
    occ=st.floats(0.05, 1.0),
)
def test_property_occupancy_and_diag(nb, bs, occ):
    m = B.random_bsm(jax.random.key(42), nb=nb, bs=bs, occupancy=occ)
    # diagonal always occupied (operators have dominant diagonal)
    assert bool(jnp.all(jnp.diag(m.mask)))
    assert 0.0 < float(m.occupancy()) <= 1.0
    # norms zero exactly where mask is False
    off = np.asarray(m.norms)[~np.asarray(m.mask)]
    assert np.all(off == 0.0)
