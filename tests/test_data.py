"""Synthetic data pipeline: determinism, step-addressability, shard-locality."""
from __future__ import annotations

import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLMData


def _cfg(**kw):
    base = dict(vocab=512, seq_len=32, global_batch=8, seed=0)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_across_instances():
    a = SyntheticLMData(_cfg()).batch_numpy(5)
    b = SyntheticLMData(_cfg()).batch_numpy(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["targets"], b["targets"])


def test_step_addressable_restart():
    """Restarting from step N regenerates exactly the stream from N."""
    d = SyntheticLMData(_cfg())
    run1 = [d.batch_numpy(s)["tokens"] for s in range(4)]
    d2 = SyntheticLMData(_cfg())
    run2 = [d2.batch_numpy(s)["tokens"] for s in range(2, 4)]
    np.testing.assert_array_equal(run1[2], run2[0])
    np.testing.assert_array_equal(run1[3], run2[1])


def test_different_steps_differ():
    d = SyntheticLMData(_cfg())
    a = d.batch_numpy(0)["tokens"]
    b = d.batch_numpy(1)["tokens"]
    assert (a != b).any()


def test_seed_changes_stream():
    a = SyntheticLMData(_cfg(seed=0)).batch_numpy(0)["tokens"]
    b = SyntheticLMData(_cfg(seed=1)).batch_numpy(0)["tokens"]
    assert (a != b).any()


def test_targets_are_shifted_tokens():
    d = SyntheticLMData(_cfg())
    b = d.batch_numpy(0)
    rows = d._rows(0, 0, 8)
    np.testing.assert_array_equal(b["tokens"], rows[:, :-1])
    np.testing.assert_array_equal(b["targets"], rows[:, 1:])


def test_shard_local_rows_match_global():
    """Row-slice generation equals the same rows of the global batch —
    the multi-host invariant (each host generates only its slice)."""
    d = SyntheticLMData(_cfg())
    full = d._rows(3, 0, 8)
    lo = d._rows(3, 2, 5)
    np.testing.assert_array_equal(full[2:5], lo)


def test_markov_structure_learnable():
    """~half the transitions follow the fixed successor permutation — the
    signal convergence tests rely on."""
    d = SyntheticLMData(_cfg(seq_len=512, global_batch=4))
    b = d.batch_numpy(0)
    toks, tgts = b["tokens"], b["targets"]
    follows = (tgts == d._successor[toks]).mean()
    assert 0.35 < follows < 0.75, follows


def test_zipf_skew():
    d = SyntheticLMData(_cfg(vocab=128, seq_len=256, global_batch=16))
    toks = d.batch_numpy(0)["tokens"].ravel()
    counts = np.bincount(toks, minlength=128)
    assert counts[:8].sum() > counts[64:].sum()  # head dominates tail
