"""Trip-count-aware HLO cost model: validation against XLA cost_analysis."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analyze, model_flops, PEAK_FLOPS
from repro.roofline.hlo_cost import (
    analyze_hlo,
    parse_module,
    shape_elems_bytes,
    xla_cost_analysis,
)


def test_shape_bytes():
    assert shape_elems_bytes("f32[2,3]{1,0}") == (6, 24)
    assert shape_elems_bytes("bf16[128]") == (128, 256)
    assert shape_elems_bytes("pred[]") == (1, 1)
    # tuples sum; layout/tiling annotations ignored
    assert shape_elems_bytes("(s32[], f32[4,4]{1,0:T(8,128)})") == (17, 68)
    # /*index=N*/ comments inside big tuples must not break parsing
    e, b = shape_elems_bytes("(s32[], f32[8]{0}, /*index=5*/bf16[2,2])")
    assert (e, b) == (13, 44)


def test_matches_cost_analysis_loop_free():
    @jax.jit
    def f(x, w):
        return jnp.tanh(x @ w) @ w

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = f.lower(x, w).compile()
    r = analyze_hlo(c.as_text())
    xla = xla_cost_analysis(c)
    assert r.flops == pytest.approx(xla["flops"], rel=0.01)


def test_scan_flops_scale_with_trip_count():
    """The whole reason this module exists: XLA counts while bodies once."""

    def make(n):
        def g(x, w):
            def body(cr, _):
                return jnp.tanh(cr @ w), None

            y, _ = jax.lax.scan(body, x, None, length=n)
            return y

        return jax.jit(g)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    per = 2 * 128**3
    for n in (2, 16):
        c = make(n).lower(x, w).compile()
        r = analyze_hlo(c.as_text())
        assert r.flops == pytest.approx(n * per, rel=0.01)
        assert r.unknown_trip_loops == 0
        # XLA's aggregate number stays flat — document the discrepancy
        assert xla_cost_analysis(c)["flops"] == pytest.approx(per, rel=0.01)


def test_nested_scan_multiplies():
    def g(x, w):
        def outer(c0, _):
            def inner(c1, _):
                return c1 @ w, None

            y, _ = jax.lax.scan(inner, c0, None, length=3)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(g).lower(x, w).compile()
    r = analyze_hlo(c.as_text())
    assert r.flops == pytest.approx(15 * 2 * 64**3, rel=0.01)


def test_parse_module_entry_and_computations():
    @jax.jit
    def f(x):
        return jnp.sum(x * 2.0)

    c = f.lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    comps = parse_module(c.as_text())
    entries = [k for k, v in comps.items() if v.is_entry]
    assert len(entries) == 1


def test_collective_wire_formulas():
    from repro.roofline.hlo_cost import _collective_wire

    n = 8
    assert _collective_wire("all-gather", 800, n) == pytest.approx(700)
    assert _collective_wire("all-reduce", 800, n) == pytest.approx(1400)
    assert _collective_wire("reduce-scatter", 100, n) == pytest.approx(700)
    assert _collective_wire("all-to-all", 800, n) == pytest.approx(700)
    assert _collective_wire("collective-permute", 800, n) == 800


def test_model_flops_kinds():
    from repro.config import SHAPES
    from repro.configs import get_arch

    cfg = get_arch("olmo_1b")
    n = cfg.active_param_count()
    assert model_flops(cfg, SHAPES["train_4k"]) == pytest.approx(
        6.0 * n * 256 * 4096
    )
    assert model_flops(cfg, SHAPES["prefill_32k"]) == pytest.approx(
        2.0 * n * 32 * 32768
    )
    assert model_flops(cfg, SHAPES["decode_32k"]) == pytest.approx(2.0 * n * 128)


def test_moe_active_params_below_total():
    from repro.configs import get_arch

    cfg = get_arch("llama4_maverick_400b_a17b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
    dense = get_arch("qwen2_72b")
    assert dense.active_param_count() == dense.param_count()


def test_analyze_end_to_end_single_device():
    """analyze() on a tiny single-device jit — terms positive & coherent."""

    @jax.jit
    def f(x, w):
        return jnp.tanh(x @ w)

    x = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
    c = f.lower(x, w).compile()
    rep = analyze(c, n_chips=1, model_flops_total=2 * 512**3)
    assert rep.flops_per_device >= 2 * 512**3
    assert rep.compute_s == pytest.approx(rep.flops_per_device / PEAK_FLOPS)
    assert rep.dominant in ("compute", "memory", "collective")
    assert 0.0 < rep.useful_flops_ratio <= 1.2


def test_dus_fusion_memory_not_full_buffer():
    """A scan that dynamic-update-slices a big carried buffer must be
    charged the update region, not the whole buffer, per iteration."""

    def g(xs):
        buf = jnp.zeros((64, 128, 128), jnp.float32)  # 8 MB carried

        def body(b, i):
            return jax.lax.dynamic_update_slice(
                b, jnp.ones((1, 128, 128)), (i, 0, 0)
            ), None

        buf, _ = jax.lax.scan(body, buf, jnp.arange(64))
        return buf

    c = jax.jit(g).lower(jax.ShapeDtypeStruct((1,), jnp.float32)).compile()
    r = analyze_hlo(c.as_text())
    full = 64 * (64 * 128 * 128 * 4)  # whole buffer every iteration
    # must be well below the naive full-buffer accounting
    assert r.hbm_bytes < 0.5 * full, (r.hbm_bytes, full)


def test_dynamic_slice_memory_is_slice_sized():
    def g(x):
        def body(acc, i):
            sl = jax.lax.dynamic_slice(x, (i, 0), (1, 512))
            return acc + jnp.sum(sl), None

        out, _ = jax.lax.scan(body, jnp.zeros(()), jnp.arange(256))
        return out

    c = jax.jit(g).lower(jax.ShapeDtypeStruct((256, 512), jnp.float32)).compile()
    r = analyze_hlo(c.as_text())
    full = 256 * (256 * 512 * 4)  # whole operand per iteration
    assert r.hbm_bytes < 0.2 * full, (r.hbm_bytes, full)


def test_attn_tile_signature_accumulates():
    def g(q, k):
        def body(acc, i):
            s = q @ k.T  # (512, 1024) "attention tile"
            return acc + jnp.sum(s), None

        out, _ = jax.lax.scan(body, jnp.zeros(()), jnp.arange(7))
        return out

    q = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    k = jax.ShapeDtypeStruct((1024, 64), jnp.float32)
    c = jax.jit(g).lower(q, k).compile()
    r = analyze_hlo(c.as_text(), attn_tile_signature=(512, 1024))
    assert r.attn_tile_bytes > 0
    assert r.attn_tile_bytes <= r.hbm_bytes


def test_spgemm_stacks_flops_match_cost_analysis():
    """Filtered-product accounting: the compacted local stage must be
    priced by surviving products, not the dense cube — predicted vs
    cost_analysis within tolerance (satellite of the compaction PR)."""
    from repro.core import plan as plan_mod
    from repro.core.bsm import random_bsm
    from repro.core.local_mm import local_filtered_mm, pair_filter
    from repro.roofline import spgemm_dense_flops, spgemm_stacks_flops

    nb, bs = 12, 8
    a = random_bsm(jax.random.key(50), nb, bs, occupancy=0.15)
    b = random_bsm(jax.random.key(51), nb, bs, occupancy=0.15)
    thr = 1e-3

    # dense jnp backend: cost_analysis prices the full cube
    dense = jax.jit(
        lambda *xs: local_filtered_mm(*xs, threshold=thr, backend="jnp")
    )
    args = (a.blocks, a.mask, a.norms, b.blocks, b.mask, b.norms)
    measured_dense = xla_cost_analysis(dense.lower(*args).compile())["flops"]
    assert measured_dense >= spgemm_dense_flops(nb, nb, nb, bs, bs, bs)
    assert measured_dense == pytest.approx(
        spgemm_dense_flops(nb, nb, nb, bs, bs, bs), rel=0.25
    )

    # stacks backend: cost_analysis prices the padded product list
    ok = np.asarray(pair_filter(a.mask, a.norms, b.mask, b.norms, thr))
    stacks, n = plan_mod.get_product_stacks(ok)
    fn = plan_mod.get_local_compiled(
        nb, nb, nb, bs, bs, bs, jnp.float32,
        backend="stacks", capacity=stacks.capacity,
    )
    measured = xla_cost_analysis(
        fn.lower(a.blocks, b.blocks, stacks).compile()
    )["flops"]
    predicted = spgemm_stacks_flops(stacks.capacity, bs, bs, bs)
    assert measured == pytest.approx(predicted, rel=0.15)
    assert measured < 0.5 * measured_dense


def test_local_stage_cost_dtype_and_tile_aware():
    """Satellite: the dtype/tile-aware local cost model vs cost_analysis.

    ``LocalCost.flops`` is the *logical* MAC count — what XLA's
    cost_analysis reports regardless of storage dtype (the contraction
    accumulates in f32 either way) — while ``hbm_bytes`` tracks the
    storage width and ``effective`` the MXU dtype throughput and tile
    VMEM pressure."""
    from repro.core.local_mm import local_filtered_mm, local_stage_cost
    from repro.kernels.block_spgemm import VMEM_BUDGET_BYTES

    nb, bs = 6, 16

    def mk(dtype):
        k1, k2 = jax.random.split(jax.random.key(60))
        ab = jax.random.normal(k1, (nb, nb, bs, bs)).astype(dtype)
        bb = jax.random.normal(k2, (nb, nb, bs, bs)).astype(dtype)
        m = jnp.ones((nb, nb), bool)
        n = jnp.sqrt(jnp.sum(jnp.square(ab.astype(jnp.float32)), (2, 3)))
        return ab, m, n, bb, m, n

    fn = jax.jit(lambda *xs: local_filtered_mm(*xs, backend="jnp"))
    measured = {}
    for dtype in (jnp.float32, jnp.bfloat16):
        c = fn.lower(*mk(dtype)).compile()
        measured[dtype] = xla_cost_analysis(c)["flops"]
    lc32 = local_stage_cost(nb, nb, nb, bs, bs, bs, fill=1.0,
                            backend="jnp", dtype=jnp.float32)
    lc16 = local_stage_cost(nb, nb, nb, bs, bs, bs, fill=1.0,
                            backend="jnp", dtype=jnp.bfloat16)
    # logical flops: dtype-independent, matches cost_analysis both ways
    assert lc32.flops == lc16.flops
    assert measured[jnp.float32] == pytest.approx(lc32.flops, rel=0.25)
    assert measured[jnp.bfloat16] == pytest.approx(lc16.flops, rel=0.25)
    # storage traffic halves with the itemsize; effective cost follows the
    # doubled MXU throughput
    assert lc16.hbm_bytes == pytest.approx(lc32.hbm_bytes / 2)
    assert lc16.effective == pytest.approx(lc32.effective / 2)

    # tile awareness (pallas): sub-block tiles re-stream operands
    # (hbm grows with the tile-grid dims) at identical logical flops
    whole = local_stage_cost(1, 1, 1, 256, 256, 256, fill=1.0,
                             backend="pallas", capacity=1)
    split = local_stage_cost(1, 1, 1, 256, 256, 256, fill=1.0,
                             backend="pallas", capacity=1,
                             tile=(128, 128, 128))
    assert split.flops == whole.flops
    assert split.hbm_bytes > whole.hbm_bytes
    # a tile whose working set cannot fit VMEM is infeasible outright
    big = local_stage_cost(1, 1, 1, 1024, 1024, 1024, fill=1.0,
                           backend="pallas", capacity=1)
    assert not big.feasible and big.effective == float("inf")
    assert (
        2 * 3 * 1024 * 1024 * 4 + 1024 * 1024 * 4 > VMEM_BUDGET_BYTES
    )  # the shape above really is over budget, not a model quirk
