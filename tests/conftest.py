"""Shared test fixtures.

NOTE: XLA_FLAGS / device counts are deliberately NOT set here — smoke tests
run on the real single CPU device.  Multi-device tests go through
``tests/_dist.py`` subprocesses which set ``xla_force_host_platform_device_count``
before importing jax (see test_distributed.py).
"""
from __future__ import annotations

import os
import sys
import types

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# hypothesis fallback: when the real package is missing, install a tiny
# fixed-example substitute so the property tests still collect and run.
# Each strategy exposes a short list of representative examples (its bounds
# plus a midpoint); ``@given`` runs the test once per example tuple, cycling
# shorter example lists — deterministic, no shrinking, no randomness.
# ---------------------------------------------------------------------------

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    def _integers(min_value=0, max_value=100):
        mid = (min_value + max_value) // 2
        return _Strategy(dict.fromkeys([min_value, mid, max_value]))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        mid = 0.5 * (min_value + max_value)
        return _Strategy(dict.fromkeys([min_value, mid, max_value]))

    def _sampled_from(elements):
        return _Strategy(list(elements))

    def _booleans():
        return _Strategy([False, True])

    def _just(value):
        return _Strategy([value])

    def _given(*_args, **strategies):
        names = list(strategies)
        rounds = max(len(strategies[n].examples) for n in names)

        def deco(fn):
            def wrapper(*a, **kw):
                for i in range(rounds):
                    kw2 = dict(kw)
                    for n in names:
                        ex = strategies[n].examples
                        kw2[n] = ex[i % len(ex)]
                    fn(*a, **kw2)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    def _settings(*_a, **_kw):
        return lambda fn: fn

    def _assume(condition):
        if not condition:
            raise pytest.skip.Exception("hypothesis-fallback assume() false")
        return True

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = _assume
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.just = _just
    _st.composite = lambda fn: fn
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
