"""Shared test fixtures.

NOTE: XLA_FLAGS / device counts are deliberately NOT set here — smoke tests
run on the real single CPU device.  Multi-device tests go through
``tests/_dist.py`` subprocesses which set ``xla_force_host_platform_device_count``
before importing jax (see test_distributed.py).
"""
from __future__ import annotations

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
