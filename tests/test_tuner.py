"""Autotuning runtime: features, candidate model, pruning soundness, the
tuning DB, the corpus, and the all-caches clear_cache contract.

The candidate model and the Eq. (6) memory prune are *analytic* — they
are property-tested here on abstract meshes (no devices needed), across
rectangular grids and uneven depths.  End-to-end ``engine="auto"``
resolution runs on a real 1x1 mesh (single CPU device); the multi-device
behavior is covered by tests/_dist.py::check_tuner_auto.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro import tuner
from repro.core import bsm as B
from repro.core import plan as plan_mod
from repro.core.commvolume import device_memory_bytes
from repro.core.engine import multiply, multiply_reference
from repro.tuner import (
    Candidate,
    TuningDB,
    autotune,
    feature_bucket,
    featurize,
    rank_candidates,
)
from repro.tuner.corpus import corpus, make_mask
from repro.tuner.db import make_key
from repro.tuner.model import (
    enumerate_candidates,
    estimate_candidate,
    valid_square_depths,
)


class FakeMesh:
    """Mesh stand-in for analytic-only tuning: axis names + sizes, no
    devices.  Hash/eq by shape so ``plan_multiply``'s LRU treats equal
    shapes as one topology."""

    def __init__(self, **shape: int):
        self._shape = dict(shape)

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return dict(self._shape)

    def __hash__(self):
        return hash(tuple(self._shape.items()))

    def __eq__(self, other):
        return isinstance(other, FakeMesh) and other._shape == self._shape


def _pair(nb=8, bs=4, occupancy=0.2, seed=0, pattern="decay"):
    a = B.random_bsm(jax.random.key(seed), nb=nb, bs=bs,
                     occupancy=occupancy, pattern=pattern)
    b = B.random_bsm(jax.random.key(seed + 1), nb=nb, bs=bs,
                     occupancy=occupancy, pattern=pattern)
    return a, b


def _ok_cube(a, b):
    am, bm = np.asarray(a.mask, bool), np.asarray(b.mask, bool)
    return am[:, :, None] & bm[None, :, :]


# ---- features --------------------------------------------------------------


def test_featurize_counts_match_cube():
    a, b = _pair(nb=10, bs=4, occupancy=0.3)
    f = featurize(a, b, 0.0)
    ok = _ok_cube(a, b)
    # the boolean mask product is EXACT at threshold 0
    assert f.n_products == int(ok.sum())
    assert f.product_fill == pytest.approx(ok.mean())
    assert f.out_fill == pytest.approx(ok.any(axis=1).mean())
    assert f.occ_a == pytest.approx(np.asarray(a.mask).mean())


def test_featurize_bandwidth_banded():
    a = B.random_bsm(jax.random.key(0), nb=12, bs=4, occupancy=0.1,
                     pattern="banded", bandwidth=2)
    f = featurize(a, a, 0.0)
    assert f.bandwidth_a == pytest.approx(2 / 12)
    assert f.nb_r == f.nb_k == 12 and f.bs_r == 4


def test_feature_bucket_stable_and_discriminating():
    a, b = _pair(nb=8, occupancy=0.2, seed=0)
    f1 = featurize(a, b, 0.0)
    assert feature_bucket(f1) == feature_bucket(featurize(a, b, 0.0))
    big_a, big_b = _pair(nb=16, occupancy=0.2, seed=0)
    assert feature_bucket(f1) != feature_bucket(featurize(big_a, big_b, 0.0))


# ---- corpus ----------------------------------------------------------------


def test_corpus_masks():
    for kind in ("dft_chain", "exp_decay", "zipf"):
        m = make_mask(kind, 16, jax.random.key(3), occupancy=0.2, bandwidth=2)
        assert m.shape == (16, 16) and m.dtype == bool
        assert m[np.arange(16), np.arange(16)].all()  # dominant diagonal
        m2 = make_mask(kind, 16, jax.random.key(3), occupancy=0.2, bandwidth=2)
        np.testing.assert_array_equal(m, m2)  # deterministic per key


def test_corpus_zipf_is_heavy_tailed():
    m = make_mask("zipf", 32, jax.random.key(0), occupancy=0.15,
                  zipf_alpha=1.4)
    rows = m.sum(axis=1)
    assert rows.max() >= 4 * np.median(rows)  # hub rows dominate


def test_corpus_entries_build():
    for entry in corpus(smoke=True):
        a, b = entry.build()
        if entry.kind == "three_center":  # matricized: (nb^2, nb) grid
            assert (a.nb_r, a.nb_c) == (entry.nb**2, entry.nb)
            assert (a.bs_r, a.bs_c) == (entry.bs**2, entry.bs)
        else:
            assert a.nb_r == entry.nb and a.bs_r == entry.bs
        a2, b2 = entry.build()
        np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(a2.mask))
        if entry.symmetric:  # DFT families: symmetric H, B is H
            np.testing.assert_array_equal(
                np.asarray(a.mask), np.asarray(a.mask).T)


# ---- candidate enumeration -------------------------------------------------


def test_valid_square_depths():
    assert valid_square_depths(2) == [4]
    assert valid_square_depths(4) == [4, 16]
    assert valid_square_depths(6) == [4, 9, 36]
    assert valid_square_depths(3) == [9]


def test_enumerate_square_vs_rectangular():
    a, b = _pair(nb=8)
    f = featurize(a, b, 0.0)
    ok = _ok_cube(a, b)
    sq = enumerate_candidates(FakeMesh(r=2, c=2), f, ok=ok)
    engines = {(c.engine, c.l) for c in sq}
    assert ("cannon", None) in engines and ("twofive", 4) in engines
    rect = enumerate_candidates(FakeMesh(r=2, c=4), f, ok=ok)
    engines = {(c.engine, c.l) for c in rect}
    assert ("cannon", None) not in engines  # square grids only
    assert ("twofive", 2) in engines  # forced L = mx/mn
    # mx > mn^2: the paper's rule forbids a 2.5D factorization
    wide = enumerate_candidates(FakeMesh(r=2, c=8), f, ok=ok)
    assert all(c.engine != "twofive" for c in wide)
    stacked = enumerate_candidates(FakeMesh(l=2, r=2, c=2), f, ok=ok)
    assert {c.engine for c in stacked} == {"twofive"}


def test_enumerate_respects_constraints():
    a, b = _pair(nb=8)
    f = featurize(a, b, 0.0)
    only = enumerate_candidates(FakeMesh(r=2, c=2), f,
                                engines=("gather",), backends=("jnp",))
    assert {(c.engine, c.backend) for c in only} == {("gather", "jnp")}
    # without a concrete cube there is no sound capacity: compacted
    # backends must be skipped, never guessed
    nocube = enumerate_candidates(FakeMesh(r=2, c=2), f,
                                  backends=("jnp", "stacks"))
    assert {c.backend for c in nocube} == {"jnp"}


def test_enumerate_transport_dimension():
    """With a concrete cube the space doubles over transport modes (the
    capacities themselves come from the masks at execution); without one
    compressed transport is skipped like the compacted backends."""
    a, b = _pair(nb=8)
    f = featurize(a, b, 0.0)
    with_cube = enumerate_candidates(FakeMesh(r=2, c=2), f,
                                     ok=_ok_cube(a, b),
                                     engines=("gather",),
                                     backends=("jnp",))
    assert {c.transport for c in with_cube} == {"dense", "compressed"}
    nocube = enumerate_candidates(FakeMesh(r=2, c=2), f,
                                  engines=("gather",), backends=("jnp",))
    assert {c.transport for c in nocube} == {"dense"}
    pinned = enumerate_candidates(FakeMesh(r=2, c=2), f,
                                  ok=_ok_cube(a, b), engines=("gather",),
                                  backends=("jnp",),
                                  transports=("compressed",))
    assert {c.transport for c in pinned} == {"compressed"}
    # compressed candidates are labeled distinctly (the oracle tables in
    # bench_tuner key on labels)
    labels = {c.label for c in with_cube}
    assert labels == {"gather/jnp", "gather/jnp+ct"}


def test_compressed_transport_cheaper_at_low_fill():
    """The sparsity-aware volume model must rank compressed transport
    under dense for a low-occupancy pattern (Eq. (7) scaled by panel
    occupancy) and roughly tie at full occupancy."""
    a, b = _pair(nb=8, occupancy=0.08)
    f = featurize(a, b, 0.0)
    mesh = FakeMesh(r=2, c=2)
    dense = estimate_candidate(Candidate("gather"), mesh, f)
    comp = estimate_candidate(Candidate("gather", transport="compressed"),
                              mesh, f)
    assert comp.comm_s < dense.comm_s
    full_a, full_b = _pair(nb=8, occupancy=1.0)
    ff = featurize(full_a, full_b, 0.0)
    dense_f = estimate_candidate(Candidate("gather"), mesh, ff)
    comp_f = estimate_candidate(Candidate("gather", transport="compressed"),
                                mesh, ff)
    assert comp_f.comm_s >= 0.9 * dense_f.comm_s


def test_chain_safety_excludes_compressed_transport():
    from repro.tuner.model import chain_safe

    assert chain_safe(Candidate("gather"))
    assert not chain_safe(Candidate("gather", backend="stacks",
                                    stack_capacity=8))
    assert not chain_safe(Candidate("gather", transport="compressed"))
    # an envelope lifts the restriction: capacities derived from the
    # forecast union cube cover every sweep, so EVERY candidate is safe
    assert chain_safe(Candidate("gather", backend="stacks",
                                stack_capacity=8), envelope=True)
    assert chain_safe(Candidate("gather", transport="compressed"),
                      envelope=True)


def test_db_record_persists_transport(tmp_path):
    """The measured winner's transport mode rides the DB record, and a
    rehydrated record (even a pre-transport one) yields a valid
    candidate."""
    from repro.tuner import _db_candidate

    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a, b = _pair(nb=4, occupancy=0.4)
    plan_mod.clear_cache()
    db = TuningDB(str(tmp_path / "db.json"))
    dec = autotune(a, b, mesh, db=db, top_k=2)
    assert len(db.records) == 1
    rec = next(iter(db.records.values()))
    assert rec["transport"] in ("dense", "compressed")
    assert rec["transport"] == dec.transport
    # a record written before the transport field reads as dense
    f = featurize(a, b, 0.0)
    legacy = {"engine": "gather", "l": None, "backend": "jnp"}
    cand = _db_candidate(legacy, _ok_cube(a, b), mesh, f)
    assert cand is not None and cand.transport == "dense"
    # schema drift: an unknown mode is a miss, not a crash
    assert _db_candidate({**legacy, "transport": "zstd"},
                         _ok_cube(a, b), mesh, f) is None


def test_pre_transport_db_records_still_warm_hit(tmp_path):
    """A tuning DB persisted BEFORE the transport layer (4-element
    constraint keys, records without a transport field) must still
    resolve measurement-free: the unpinned constraint shape is
    unchanged, and the record reads as dense transport."""
    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a, b = _pair(nb=4, occupancy=0.4)
    f = featurize(a, b, 0.0)
    db = TuningDB(str(tmp_path / "db.json"))
    # the exact key shape PR 4 wrote: ("mult", "*", "*", 0), no transport
    old_key = make_key(feature_bucket(f),
                       tuner.mesh_signature(mesh)
                       if hasattr(tuner, "mesh_signature")
                       else tuple((n, int(mesh.shape[n]))
                                  for n in mesh.axis_names),
                       ("mult", "*", "*", 0), f.dtype)
    db.record(old_key, {"engine": "gather", "l": None, "backend": "jnp",
                        "measured_s": 1e-4})
    plan_mod.clear_cache()
    dec = autotune(a, b, mesh, db=db)
    assert dec.source == "db" and dec.engine == "gather"
    assert dec.transport == "dense"
    assert plan_mod.cache_stats()["tuner_trials"] == 0


# ---- Eq. (6) memory pruning: the property the tuner must never break -------

_MESHES = [
    {"r": 2, "c": 2},
    {"r": 2, "c": 4},
    {"r": 4, "c": 2},  # rectangular, forced virtual L = 2
    {"r": 6, "c": 2},  # rectangular with mx > mn^2: no 2.5D factorization
    {"r": 6, "c": 6},  # square with uneven L=9 (9 does not divide V=6)
    {"r": 2, "c": 8},  # no valid 2.5D factorization at all
    {"l": 2, "r": 2, "c": 2},
]


@settings(deadline=None, max_examples=40)
@given(
    mesh_shape=st.sampled_from(_MESHES),
    budget=st.sampled_from([3e5, 1e6, 5e6, 1e8]),
    occupancy=st.floats(min_value=0.05, max_value=0.6),
)
def test_prune_never_selects_over_budget(mesh_shape, budget, occupancy):
    """The tuner NEVER selects a candidate whose Eq. (6) footprint
    (incl. the device_stack_bound-sized stack arrays) exceeds the
    per-device budget — across rectangular meshes and uneven L; when
    nothing fits, it refuses rather than over-committing."""
    mesh = FakeMesh(**mesh_shape)
    a, b = _pair(nb=24, bs=4, occupancy=occupancy, seed=7)
    f = featurize(a, b, 0.0)
    ok = _ok_cube(a, b)
    try:
        report = rank_candidates(mesh, f, ok=ok, budget_bytes=budget)
    except ValueError:
        # refusal is the sound outcome when every candidate is too big:
        # verify at least the cheapest engine really exceeds the budget
        est = estimate_candidate(Candidate("gather"), mesh, f,
                                 budget_bytes=budget)
        assert est.mem_bytes > budget
        return
    assert report.ranked, "feasible report must be non-empty"
    for est in report.ranked:
        assert est.feasible
        assert est.mem_bytes <= budget, est
        # independent recomputation from the plan tables
        plan = plan_mod.plan_multiply(mesh, est.candidate.engine,
                                      est.candidate.l)
        mem = device_memory_bytes(
            plan, f.nb_r, f.bs_r, itemsize=4.0,
            stack_capacity=est.candidate.stack_capacity or 0,
        )
        assert mem == pytest.approx(est.mem_bytes)
        assert mem <= budget
    # compacted candidates carry the exact bucketed device bound
    for est in report.ranked:
        c = est.candidate
        if c.backend != "jnp":
            assert c.stack_capacity == plan_mod.get_device_capacity(
                ok, mesh, c.engine)


def test_analytic_decision_is_feasible():
    """autotune(measure=False) on an abstract mesh returns a decision
    whose footprint fits the budget."""
    plan_mod.clear_cache()
    mesh = FakeMesh(r=4, c=2)
    a, b = _pair(nb=16, bs=4, occupancy=0.2)
    dec = autotune(a, b, mesh, budget_bytes=1e8, measure=False)
    est = estimate_candidate(
        Candidate(dec.engine, dec.l, dec.backend, dec.stack_capacity),
        mesh, featurize(a, b, 0.0), budget_bytes=1e8)
    assert dec.source == "analytic" and est.feasible
    s = plan_mod.cache_stats()
    assert s["tuner_misses"] == 1 and s["tuner_trials"] == 0


# ---- tuning DB -------------------------------------------------------------


def test_db_roundtrip(tmp_path):
    path = str(tmp_path / "db.json")
    db = TuningDB(path)
    key = make_key(("fb1", 3), (("r", 2), ("c", 2)), ("mult", "*", "*", 0),
                   "float32")
    db.record(key, {"engine": "gather", "l": None, "backend": "jnp",
                    "measured_s": 1e-3})
    db2 = TuningDB.load(path)
    assert db2.lookup(key)["engine"] == "gather"
    assert TuningDB.load_or_create(path).lookup(key) is not None
    assert len(TuningDB.load_or_create(str(tmp_path / "missing.json"))) == 0


def test_db_hit_revalidated_for_this_topology():
    """A DB record must be re-run through the enumeration validity gates
    on every hit: a corrupt / hand-copied / schema-drifted record (an L
    the paper's rule forbids, an engine the grid shape excludes, a
    compacted backend on an empty pattern) must fall through to a fresh
    decision instead of crashing later in plan compilation."""
    from repro.tuner import _db_candidate

    mesh = FakeMesh(r=2, c=4)
    a, b = _pair(nb=8, bs=4, occupancy=0.3)
    feats = featurize(a, b, 0.0)
    ok = _ok_cube(a, b)
    # cannon is square-grid-only: invalid on 2x4 no matter what the
    # record says
    assert _db_candidate({"engine": "cannon", "l": None, "backend": "jnp"},
                         ok, mesh, feats) is None
    # L=3 violates the paper rule on this grid (forced L is 2)
    assert _db_candidate({"engine": "twofive", "l": 3, "backend": "jnp"},
                         ok, mesh, feats) is None
    # compacted backend over an empty pattern: no sound program to run
    assert _db_candidate({"engine": "gather", "l": None,
                          "backend": "stacks"},
                         np.zeros_like(ok), mesh, feats) is None
    good = _db_candidate({"engine": "gather", "l": None, "backend": "jnp"},
                         ok, mesh, feats)
    assert good is not None and good.engine == "gather"
    # end-to-end: a poisoned record in the right bucket falls through to
    # a fresh valid decision, not a crash in plan.validate_blocks
    plan_mod.clear_cache()
    db = TuningDB()
    db.record(make_key(feature_bucket(feats),
                       tuner.mesh_signature(mesh),
                       ("mult", "*", "*", 0), feats.dtype),
              {"engine": "cannon", "l": None, "backend": "jnp"})
    dec = autotune(a, b, mesh, db=db, measure=False)
    assert dec.engine != "cannon"
    s = plan_mod.cache_stats()
    assert s["tuner_misses"] == 1 and s["tuner_hits"] == 0, s


def test_decision_cache_keys_on_budget():
    """A decision made under one memory budget must never answer for
    another — the Eq. (6) guarantee would silently break otherwise."""
    plan_mod.clear_cache()
    mesh = FakeMesh(r=2, c=2)
    a, b = _pair(nb=16, bs=4, occupancy=0.2)
    autotune(a, b, mesh, budget_bytes=1e9, measure=False)
    autotune(a, b, mesh, budget_bytes=5e5, measure=False)
    s = plan_mod.cache_stats()
    assert s["tuner_misses"] == 2 and s["tuner_hits"] == 0, s
    # same budget twice IS a cache hit
    autotune(a, b, mesh, budget_bytes=5e5, measure=False)
    assert plan_mod.cache_stats()["tuner_hits"] == 1


def test_db_rejects_unknown_schema(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text('{"schema": "something-else", "records": {}}')
    with pytest.raises(ValueError):
        TuningDB.load(str(path))


# ---- end-to-end engine="auto" on a real (1x1) mesh -------------------------


def test_auto_multiply_matches_reference():
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a, b = _pair(nb=8, bs=8, occupancy=0.25)
    plan_mod.clear_cache()
    c = multiply(a, b, mesh, engine="auto", threshold=1e-6)
    ref = multiply_reference(a, b, threshold=1e-6)
    np.testing.assert_allclose(np.asarray(c.to_dense()),
                               np.asarray(ref.to_dense()),
                               rtol=1e-5, atol=1e-5)
    s1 = plan_mod.cache_stats()
    assert s1["tuner_misses"] == 1 and s1["tuner_trials"] >= 1
    # repeated pattern: decision-cache hit, zero new trials
    multiply(a, b, mesh, engine="auto", threshold=1e-6)
    s2 = plan_mod.cache_stats()
    assert s2["tuner_hits"] == s1["tuner_hits"] + 1
    assert s2["tuner_trials"] == s1["tuner_trials"]


def test_auto_warm_db_runs_zero_trials(tmp_path):
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a, b = _pair(nb=8, bs=8, occupancy=0.25, seed=3)
    path = str(tmp_path / "db.json")
    plan_mod.clear_cache()
    tuner.set_default_db(path)
    multiply(a, b, mesh, engine="auto", threshold=1e-6)
    assert plan_mod.cache_stats()["tuner_trials"] >= 1
    assert len(tuner.get_default_db()) == 1
    # a fresh process is simulated by clear_cache (drops decisions AND
    # the DB binding) + re-binding the persisted file
    plan_mod.clear_cache()
    tuner.set_default_db(path)
    multiply(a, b, mesh, engine="auto", threshold=1e-6)
    s = plan_mod.cache_stats()
    assert s["tuner_trials"] == 0 and s["tuner_misses"] == 0, s
    assert s["tuner_hits"] == 1, s


# ---- clear_cache drops EVERY cache level (regression) ----------------------


def test_clear_cache_drops_all_caches(tmp_path):
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a, b = _pair(nb=8, bs=8, occupancy=0.2, seed=5)
    plan_mod.clear_cache()
    tuner.set_default_db(str(tmp_path / "db.json"))
    # populate every level: program + pattern + chain + tuner caches
    multiply(a, b, mesh, engine="auto", threshold=1e-6)
    multiply(a, b, mesh, engine="gather", threshold=1e-6, backend="stacks")
    from repro.core.signiter import sign_iteration

    sign_iteration(a, mesh=mesh, engine="onesided", max_iter=2, tol=0.0)
    stats = plan_mod.cache_stats()
    assert stats["builds"] > 0 and stats["chain_misses"] == 1
    assert stats["pattern_misses"] > 0 and stats["tuner_misses"] == 1
    assert plan_mod.plan_multiply.cache_info().currsize > 0

    plan_mod.clear_cache()
    assert all(v == 0 for v in plan_mod.cache_stats().values()), (
        plan_mod.cache_stats())
    assert len(plan_mod._program_cache) == 0
    assert len(plan_mod._pattern_cache) == 0
    assert len(plan_mod._bound_cache) == 0
    assert plan_mod.plan_multiply.cache_info().currsize == 0
    assert len(tuner._decision_cache) == 0
    assert tuner.get_default_db() is None  # DB binding reset too
    # and the next resolution really is a cold miss
    multiply(a, b, mesh, engine="auto", threshold=1e-6)
    s = plan_mod.cache_stats()
    assert s["tuner_misses"] == 1 and s["misses"] >= 1


def test_clear_cache_drops_envelope_and_drift_levels(tmp_path):
    """The envelope layer's cache levels obey the same contract: the
    plan-layer forecast cache, the tuner's bucket/stream caches and the
    envelope/drift counters are all dropped by ONE clear_cache (mirror
    of test_clear_cache_drops_all_caches for the levels PR 8 added)."""
    from repro.core import envelope as E

    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a, b = _pair(nb=8, bs=8, occupancy=0.3, seed=5)
    plan_mod.clear_cache()
    tuner.set_default_db(str(tmp_path / "db.json"))
    # populate: forecast cache (miss + hit), drift counter (non-covering
    # envelope -> exact fallback), tuner bucket/stream caches
    m = np.asarray(a.mask, bool)
    n = np.asarray(a.norms, np.float32)
    env = plan_mod.get_envelope(m, n, sweeps=2, threshold=1e-6,
                                filter_eps=1e-6, bs=a.bs_r)
    assert plan_mod.get_envelope(m, n, sweeps=2, threshold=1e-6,
                                 filter_eps=1e-6, bs=a.bs_r) is env
    tiny = E.union_envelope([np.eye(8, dtype=bool)])
    multiply(a, b, mesh, engine="gather", threshold=1e-6,
             backend="stacks", envelope=tiny)
    autotune(a, b, mesh)
    stats = plan_mod.cache_stats()
    assert stats["envelope_misses"] == 1 and stats["envelope_hits"] == 1
    assert stats["drift_retunes"] == 1, stats
    assert len(plan_mod._envelope_cache) == 1
    assert len(tuner._bucket_cache) == 1
    assert len(tuner._stream_last_bucket) == 1

    plan_mod.clear_cache()
    assert all(v == 0 for v in plan_mod.cache_stats().values()), (
        plan_mod.cache_stats())
    assert len(plan_mod._envelope_cache) == 0
    assert len(tuner._bucket_cache) == 0
    assert len(tuner._stream_last_bucket) == 0
    # and the next forecast really is a cold miss
    plan_mod.get_envelope(m, n, sweeps=2, threshold=1e-6,
                          filter_eps=1e-6, bs=a.bs_r)
    s = plan_mod.cache_stats()
    assert s["envelope_misses"] == 1 and s["envelope_hits"] == 0, s


# ---- tile-shape search axis (MXU-tiled pallas kernel) ----------------------


def test_enumerate_tile_axis_on_pallas():
    """Large atomic blocks open the tile axis: every pallas candidate is
    replicated per feasible MXU tile shape (default None first), labels
    carry the shape, and non-pallas backends never grow the axis."""
    from repro.kernels.block_spgemm import tile_candidates
    from repro.kernels.ops import _default_interpret

    a, b = _pair(nb=4, bs=128, occupancy=0.4)
    f = featurize(a, b, 0.0)
    cands = enumerate_candidates(FakeMesh(r=2, c=2), f, ok=_ok_cube(a, b),
                                 engines=("gather",), backends=("pallas",),
                                 transports=("dense",))
    tiles = [c.tile for c in cands]
    expect = tile_candidates(128, 128, 128, np.dtype(f.dtype),
                             interpret=_default_interpret())
    assert tiles == expect and tiles[0] is None and len(tiles) > 1
    labels = {c.label for c in cands}
    assert "gather/pallas" in labels
    tm, tk, tn = next(t for t in tiles if t is not None)
    assert f"gather/pallas/t{tm}x{tk}x{tn}" in labels
    # jnp never grows a tile axis — tiling is a pallas staging concern
    jn = enumerate_candidates(FakeMesh(r=2, c=2), f, ok=_ok_cube(a, b),
                              engines=("gather",), backends=("jnp",),
                              transports=("dense",))
    assert all(c.tile is None for c in jn)


def test_estimate_tile_vmem_feasibility():
    """The analytic model folds the kernel's VMEM working set into
    feasibility: a whole-block candidate at bs=1024 f32 cannot stage and
    is marked infeasible, while a split tile of the same block is fine."""
    a, b = _pair(nb=4, bs=8, occupancy=0.4)
    f = featurize(a, b, 0.0)
    f = type(f)(**{**f.__dict__, "bs_r": 1024, "bs_k": 1024, "bs_c": 1024})
    mesh = FakeMesh(r=2, c=2)
    whole = estimate_candidate(
        Candidate("gather", backend="pallas", stack_capacity=4), mesh, f)
    assert not whole.feasible and "VMEM" in whole.reason
    split = estimate_candidate(
        Candidate("gather", backend="pallas", stack_capacity=4,
                  tile=(256, 256, 256)), mesh, f)
    assert split.feasible


def test_db_record_persists_tile(tmp_path):
    """The winner's tile rides the DB record; pre-tile records read as
    tile=None; a persisted tile invalid for this pattern's block shape
    drops to the default WITHOUT missing the whole record."""
    from repro.tuner import _db_candidate

    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a, b = _pair(nb=4, occupancy=0.4)
    plan_mod.clear_cache()
    db = TuningDB(str(tmp_path / "db.json"))
    dec = autotune(a, b, mesh, db=db, top_k=2)
    rec = next(iter(db.records.values()))
    assert "tile" in rec  # schema always writes the field
    assert (tuple(rec["tile"]) if rec["tile"] is not None else None) == dec.tile
    f = featurize(a, b, 0.0)
    ok = _ok_cube(a, b)
    base = {"engine": "gather", "l": None, "backend": "jnp"}
    # pre-tile record: reads as default staging
    cand = _db_candidate(base, ok, mesh, f)
    assert cand is not None and cand.tile is None
    # valid persisted tile survives rehydration (bs=4: only (4,4,4) or
    # finer divides; interpret mode relaxes lane alignment on CPU)
    cand = _db_candidate({**base, "tile": [4, 4, 4]}, ok, mesh, f)
    assert cand is not None and cand.tile in ((4, 4, 4), None)
    # a tile that does not divide this pattern's blocks drops to None,
    # keeping the engine/backend choice alive
    cand = _db_candidate({**base, "tile": [3, 5, 7]}, ok, mesh, f)
    assert cand is not None and cand.tile is None
    # garbage shapes are a default, not a crash
    cand = _db_candidate({**base, "tile": "64x64"}, ok, mesh, f)
    assert cand is not None and cand.tile is None


def test_pre_tile_db_records_still_warm_hit(tmp_path):
    """A DB persisted before the tile axis (records without a ``tile``
    field) still resolves measurement-free."""
    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a, b = _pair(nb=4, occupancy=0.4)
    f = featurize(a, b, 0.0)
    db = TuningDB(str(tmp_path / "db.json"))
    old_key = make_key(feature_bucket(f),
                       tuple((n, int(mesh.shape[n])) for n in mesh.axis_names),
                       ("mult", "*", "*", 0), f.dtype)
    db.record(old_key, {"engine": "gather", "l": None, "backend": "jnp",
                        "transport": "dense", "measured_s": 1e-4})
    plan_mod.clear_cache()
    dec = autotune(a, b, mesh, db=db)
    assert dec.source == "db" and dec.engine == "gather"
    assert dec.tile is None
    assert plan_mod.cache_stats()["tuner_trials"] == 0


# ---- block->device assignment axis (core.distribute) -----------------------


def test_corpus_imbalance_statistic():
    """Satellite of the distribution layer: the zipf hub family is the
    workload the layer exists for — its identity-layout per-device
    product-load imbalance is MATERIAL (>2x on a 4x4 grid), while the
    uniform family (the randomized-permutation limit) sits near 1x."""
    from repro.tuner.corpus import CorpusEntry

    z = CorpusEntry("zipf_hub", "zipf", 32, 8, occupancy=0.15,
                    zipf_alpha=1.4, seed=15)
    assert z.imbalance(4, 4) > 2.0
    u = CorpusEntry("uniform_flat", "uniform", 64, 8, occupancy=0.15,
                    seed=15)
    assert u.imbalance(4, 4) < 1.3
    # masks() is exactly what build() fills — the statistic describes the
    # operands the tuner will actually measure
    ma, mb = z.masks()
    a, b = z.build()
    np.testing.assert_array_equal(ma, np.asarray(a.mask))
    np.testing.assert_array_equal(mb, np.asarray(b.mask))


def test_corpus_three_center_tall_skinny():
    """Satellite of the tensor layer: the three_center family is the
    rectangular workload — its matricized mask is (nb^2, nb) tall-skinny,
    carries the on-site diagonal, honors the requested mean occupancy,
    and is EXACTLY the mask the tensor layer's matricization produces."""
    from repro.tuner.corpus import CorpusEntry

    e = CorpusEntry("tc", "three_center", 8, 4, occupancy=0.10, seed=17)
    ma, mb = e.masks()
    assert ma.shape == (64, 8) and mb.shape == (8, 8)  # nb_r = nb * nb_c
    i = np.arange(8)
    assert ma[i * 8 + i, i].all()  # on-site (i==j==k) blocks always kept
    assert 0.03 < ma.mean() < 0.30  # screened, but not empty
    ma2, _ = e.masks()
    np.testing.assert_array_equal(ma, ma2)  # deterministic per key
    # masks() is exactly what build() fills, post-matricization
    a, b = e.build()
    np.testing.assert_array_equal(ma, np.asarray(a.mask))
    np.testing.assert_array_equal(mb, np.asarray(b.mask))
    # ... and the tensor mask flattens to the same pattern the entry
    # advertises (build_tensor -> matricize == build)
    t, _ = e.build_tensor()
    np.testing.assert_array_equal(np.asarray(t.mask).reshape(64, 8), ma)
    # the imbalance statistic computes on the rectangular product grid
    assert e.imbalance(2, 2) >= 1.0
    with pytest.raises(ValueError, match="three_center"):
        CorpusEntry("x", "uniform", 8, 4).build_tensor()


def test_candidate_assign_labels():
    assert Candidate("gather").label == "gather/jnp"
    assert Candidate("gather", assign="nnz_greedy").label == "gather/jnp@nnz"
    assert Candidate("gather", assign="randomized").label == "gather/jnp@rand"


def test_enumerate_assignment_axis():
    """With hub-skewed counts the space grows an assignment axis; without
    counts (or with near-flat loads) it stays identity-only."""
    from repro.core.distribute import product_counts

    a, b = _pair(nb=8, bs=4, occupancy=0.2, seed=2)
    mask = np.asarray(a.mask).copy()
    mask[:2] = True  # hub rows
    counts = product_counts(mask, np.asarray(b.mask))
    f = featurize(a, b, 0.0)
    cands = enumerate_candidates(FakeMesh(r=2, c=2), f, ok=_ok_cube(a, b),
                                 engines=("gather",), backends=("jnp",),
                                 counts=counts)
    assigns = {c.assign for c in cands}
    assert "identity" in assigns
    assert "nnz_greedy" in assigns or "randomized" in assigns
    nocounts = enumerate_candidates(FakeMesh(r=2, c=2), f, ok=_ok_cube(a, b),
                                    engines=("gather",), backends=("jnp",))
    assert {c.assign for c in nocounts} == {"identity"}


def test_db_record_persists_assign(tmp_path):
    """The winner's assignment mode rides the DB record (mode only — the
    permutation is re-derived from the concrete mask product on every
    use) and survives a JSON round-trip."""
    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a, b = _pair(nb=4, occupancy=0.4)
    plan_mod.clear_cache()
    db = TuningDB(str(tmp_path / "db.json"))
    dec = autotune(a, b, mesh, db=db, top_k=2)
    rec = next(iter(db.records.values()))
    assert "assign" in rec and rec["assign"] == dec.assign
    db2 = TuningDB.load(str(tmp_path / "db.json"))
    rec2 = next(iter(db2.records.values()))
    assert rec2["assign"] == rec["assign"]


def test_db_assign_revalidated_per_topology():
    """Persisted assignment modes are revalidated on every hit like tile
    and transport: a mode underivable on THIS (pattern, mesh) — mesh
    shape whose lcm does not divide the block grid, unknown mode, missing
    counts — silently drops to identity, keeping the engine/backend
    choice alive instead of missing the record."""
    from repro.core.distribute import product_counts
    from repro.tuner import _db_candidate

    a, b = _pair(nb=8, bs=4, occupancy=0.3)
    feats = featurize(a, b, 0.0)
    ok = _ok_cube(a, b)
    counts = product_counts(np.asarray(a.mask), np.asarray(b.mask))
    mesh = FakeMesh(r=2, c=2)
    base = {"engine": "gather", "l": None, "backend": "jnp"}
    # a record written before the distribution layer reads as identity
    cand = _db_candidate(base, ok, mesh, feats, counts)
    assert cand is not None and cand.assign == "identity"
    # the persisted mode survives where the permutation is derivable
    cand = _db_candidate({**base, "assign": "nnz_greedy"}, ok, mesh, feats,
                         counts)
    assert cand is not None and cand.assign == "nnz_greedy"
    # a topology the record's plan cannot even validate on is a MISS
    # (nb = 8 does not divide a 2x3 grid), independent of assignment
    assert _db_candidate({**base, "assign": "nnz_greedy"}, ok,
                         FakeMesh(r=2, c=3), feats, counts) is None
    # a (pattern, mesh) where the symmetric permutation itself is
    # underivable (non-square block grid) drops the MODE, keeps the record
    counts_rect = np.ones((8, 6), np.int64)
    cand = _db_candidate({**base, "assign": "nnz_greedy"}, ok, mesh, feats,
                         counts_rect)
    assert cand is not None and cand.assign == "identity"
    # schema drift and missing counts drop to identity, not to a miss
    cand = _db_candidate({**base, "assign": "zigzag"}, ok, mesh, feats,
                         counts)
    assert cand is not None and cand.assign == "identity"
    cand = _db_candidate({**base, "assign": "nnz_greedy"}, ok, mesh, feats,
                         None)
    assert cand is not None and cand.assign == "identity"
    # compacted backend: the capacity must come from the PERMUTED cube
    from repro.core.distribute import assignment_for, permute_cube

    cand = _db_candidate({**base, "backend": "stacks",
                          "assign": "nnz_greedy"}, ok, mesh, feats, counts)
    assert cand is not None and cand.assign == "nnz_greedy"
    asg = assignment_for("nnz_greedy", counts, (2, 2))
    assert cand.stack_capacity == plan_mod.get_device_capacity(
        permute_cube(ok, asg.perm), mesh, "gather")


def test_model_scales_compute_by_imbalance():
    """The cost model prices load imbalance: on hub-skewed counts the
    identity candidate's local-compute estimate exceeds a balanced
    assignment's for the same engine, so the ranking can prefer the
    permuted layout without measuring."""
    from repro.core.distribute import product_counts
    from repro.tuner.model import assignment_imbalances

    a, b = _pair(nb=16, bs=8, occupancy=0.2, seed=4)
    mask = np.asarray(a.mask).copy()
    mask[:3] = True  # hub rows
    counts = product_counts(mask, np.asarray(b.mask))
    mesh = FakeMesh(r=2, c=2)
    f = featurize(a, b, 0.0)
    imbs = assignment_imbalances(counts, mesh)
    assert imbs["identity"] > imbs.get("nnz_greedy", imbs["identity"]) - 1e-9
    # the compacted backends are product-proportional, so the slowest
    # device gates them: compute scales by the candidate's own imbalance
    est_id = estimate_candidate(
        Candidate("gather", backend="stacks", stack_capacity=8), mesh, f,
        imbalance=imbs["identity"])
    est_gr = estimate_candidate(
        Candidate("gather", backend="stacks", stack_capacity=8,
                  assign="nnz_greedy"), mesh, f,
        imbalance=imbs["nnz_greedy"])
    assert est_gr.compute_s < est_id.compute_s
    # the dense jnp einsum contracts the full cube regardless of layout
    dj = estimate_candidate(Candidate("gather"), mesh, f,
                            imbalance=imbs["identity"])
    assert dj.compute_s == estimate_candidate(
        Candidate("gather"), mesh, f,
        imbalance=imbs["nnz_greedy"]).compute_s
