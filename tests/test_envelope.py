"""Pattern-envelope layer (core/envelope.py, DESIGN.md §7).

The load-bearing property: the forecast envelope SOUNDLY over-approximates
a drifting-pattern chain — every realized per-sweep mask is a bitwise
subset of the forecast sweep mask, every realized product cube a subset of
the envelope cube, across the corpus families x sweep counts x thresholds.
On top of that: envelope-compiled execution matches the per-pattern
retrace oracle bitwise, the plan-layer forecast cache counts
envelope_hits/misses, and a non-covering envelope triggers the drift
fallback (drift_retunes + exact execution) instead of wrong results.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.core import bsm as B
from repro.core import envelope as E
from repro.core import plan as plan_mod
from repro.core.engine import multiply
from repro.core.signiter import sign_iteration
from repro.kernels.stacks import pair_cube
from repro.tuner.corpus import KINDS, make_mask


def _chain_operand(kind: str, nb: int, bs: int, seed: int, occupancy=0.3):
    """Symmetric purification-shaped operand of one corpus family."""
    key = jax.random.key(seed)
    m = make_mask(kind, nb, key, occupancy=occupancy)
    m = m | m.T
    blocks = jax.random.normal(jax.random.key(seed + 1),
                               (nb, nb, bs, bs)) / np.sqrt(bs)
    blocks = 0.5 * (blocks + blocks.transpose(0, 1, 3, 2).swapaxes(0, 1))
    x = B.make_bsm(blocks, np.asarray(m))
    # unit spectral scale on the host: the operand every chain actually
    # multiplies (and the one the envelope must be forecast from)
    return B.scale(x, float(1.0 / max(float(x.frobenius_norm()), 1e-30)))


def _oracle_sweeps(x, sweeps: int, threshold: float, filter_eps: float):
    """Per-pattern retrace oracle: the realized per-sweep (mask, cube)
    sequence of the Newton-Schulz chain, one exact multiply at a time
    (the algebra order of signiter._make_sweep / the legacy loop)."""
    nb, bs = x.nb_r, x.bs_r
    ident = B.identity(nb, bs, x.dtype)
    masks, cubes = [], []
    for _ in range(sweeps):
        cubes.append(pair_cube(x.mask, x.mask, x.norms, x.norms, threshold))
        x2 = multiply(x, x, threshold=threshold, filter_eps=filter_eps)
        y = B.add(B.scale(x2, -1.0), B.scale(ident, 3.0))
        cubes.append(pair_cube(x.mask, y.mask, x.norms, y.norms, threshold))
        xn = multiply(x, y, threshold=threshold, filter_eps=filter_eps)
        x = B.scale(xn, 0.5)
        masks.append(np.asarray(x.mask, bool))
    return x, masks, cubes


# ---- soundness: envelope covers every realized sweep -----------------------


@settings(deadline=None, max_examples=24)
@given(
    kind=st.sampled_from(KINDS),
    sweeps=st.integers(min_value=1, max_value=4),
    threshold=st.sampled_from([0.0, 1e-8, 1e-3]),
    filter_eps=st.sampled_from([0.0, 1e-7, 1e-3]),
    seed=st.integers(min_value=0, max_value=3),
)
def test_envelope_covers_realized_chain(kind, sweeps, threshold,
                                        filter_eps, seed):
    x = _chain_operand(kind, nb=8, bs=4, seed=seed)
    env = E.forecast_chain(np.asarray(x.mask, bool),
                           np.asarray(x.norms, np.float32),
                           sweeps=sweeps, threshold=threshold,
                           filter_eps=filter_eps, bs=x.bs_r)
    _, masks, cubes = _oracle_sweeps(x, sweeps, threshold, filter_eps)
    assert len(env.sweep_masks) == sweeps
    for s, realized in enumerate(masks):
        fore = env.sweep_masks[s]
        assert not (realized & ~fore).any(), (kind, s)
    for cube in cubes:
        assert not (cube & ~np.asarray(env.cube)).any(), kind
    # the operand-mask unions cover every multiply's LEFT operand: the
    # entering pattern and every intermediate that re-enters as X (the
    # final sweep's result never multiplies again inside the window)
    assert env.covers(np.asarray(x.mask, bool))
    for realized in masks[:-1]:
        assert not (realized & ~np.asarray(env.mask_a)).any()


def test_forecast_is_monotone_in_sweeps():
    x = _chain_operand("exp_decay", nb=8, bs=4, seed=0)
    m = np.asarray(x.mask, bool)
    n = np.asarray(x.norms, np.float32)
    prev = None
    for s in (1, 2, 4):
        env = E.forecast_chain(m, n, sweeps=s, threshold=1e-8,
                               filter_eps=1e-7, bs=x.bs_r)
        if prev is not None:
            assert not (np.asarray(prev.cube)
                        & ~np.asarray(env.cube)).any()
        prev = env


def test_forecast_validates_inputs():
    m = np.eye(4, dtype=bool)
    n = np.ones((4, 4), np.float32)
    with pytest.raises(ValueError, match="sweeps"):
        E.forecast_chain(m, n, sweeps=0)
    with pytest.raises(ValueError, match="margin"):
        E.forecast_chain(m, n, sweeps=1, margin=-0.1)
    with pytest.raises(ValueError, match="square"):
        E.forecast_chain(np.ones((2, 3), bool), np.ones((2, 3)), sweeps=1)


# ---- envelope-compiled execution == per-pattern retrace oracle -------------


def test_envelope_chain_matches_retrace_oracle_bitwise():
    """Single-device fused chain against the envelope (ONE traced
    program, masks as data) == fused chain with per-cube capacity ==
    legacy per-pattern loop, bitwise on blocks and mask."""
    x = _chain_operand("exp_decay", nb=8, bs=4, seed=2)
    kw = dict(max_iter=4, tol=0.0, threshold=1e-8, filter_eps=1e-7,
              scale_input=False, backend="stacks")
    plan_mod.clear_cache()
    want, _ = sign_iteration(x, **kw)
    plan_mod.clear_cache()
    got, st = sign_iteration(x, envelope="auto", **kw)
    assert st.envelope and st.retraces == 1
    assert np.array_equal(np.asarray(got.blocks), np.asarray(want.blocks))
    assert np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
    s = plan_mod.cache_stats()
    assert s["chain_misses"] == 1 and s["envelope_misses"] == 1, s
    # result agrees with the eager oracle loop too (values, not bits:
    # the fused sweep reorders the inter-multiply algebra)
    oracle, masks, _ = _oracle_sweeps(x, 4, 1e-8, 1e-7)
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               np.asarray(oracle.to_dense()),
                               rtol=1e-5, atol=1e-6)
    assert np.array_equal(np.asarray(got.mask), masks[-1])


def test_envelope_multiply_single_device_builds_once():
    """multiply(envelope=...) on one device: every pattern the envelope
    covers executes through ONE traced program (the jitted reference
    body with the envelope's static capacity — masks enter as data)."""
    nb, bs = 8, 4
    rng = np.random.default_rng(0)
    masks = []
    for s in range(4):
        m = make_mask("uniform", nb, jax.random.key(s), occupancy=0.25)
        masks.append(m)
    env = E.union_envelope(masks, [np.asarray(masks[0])])
    bmat = B.random_bsm(jax.random.key(9), nb=nb, bs=bs, occupancy=0.3)
    bm = np.asarray(masks[0])
    bmat = B.make_bsm(bmat.blocks, np.asarray(bm))
    del rng
    plan_mod.clear_cache()
    for m in masks:
        blocks = jax.random.normal(jax.random.key(17), (nb, nb, bs, bs))
        a = B.make_bsm(blocks, np.asarray(m))
        got = multiply(a, bmat, backend="stacks", envelope=env,
                       threshold=1e-8, filter_eps=1e-7)
        want = multiply(a, bmat, backend="stacks",
                        threshold=1e-8, filter_eps=1e-7)
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   np.asarray(want.to_dense()),
                                   rtol=1e-5, atol=1e-6)
    s = plan_mod.cache_stats()
    assert s["drift_retunes"] == 0, s


# ---- forecast cache + drift fallback ---------------------------------------


def test_get_envelope_counts_hits_and_misses():
    x = _chain_operand("dft_chain", nb=8, bs=4, seed=1)
    m = np.asarray(x.mask, bool)
    n = np.asarray(x.norms, np.float32)
    plan_mod.clear_cache()
    e1 = plan_mod.get_envelope(m, n, sweeps=3, threshold=1e-8,
                               filter_eps=1e-7, bs=x.bs_r)
    e2 = plan_mod.get_envelope(m, n, sweeps=3, threshold=1e-8,
                               filter_eps=1e-7, bs=x.bs_r)
    assert e1 is e2
    s = plan_mod.cache_stats()
    assert s["envelope_misses"] == 1 and s["envelope_hits"] == 1, s
    # different sweep count -> a different forecast
    plan_mod.get_envelope(m, n, sweeps=4, threshold=1e-8,
                          filter_eps=1e-7, bs=x.bs_r)
    s = plan_mod.cache_stats()
    assert s["envelope_misses"] == 2, s


def test_non_covering_envelope_falls_back_exact():
    """A pattern OUTSIDE the envelope must not execute against it:
    multiply notes a drift re-tune and runs the exact path — correct
    results, counter bumped."""
    nb, bs = 8, 4
    a = B.random_bsm(jax.random.key(0), nb=nb, bs=bs, occupancy=0.4,
                     pattern="decay")
    bmat = B.random_bsm(jax.random.key(1), nb=nb, bs=bs, occupancy=0.4)
    tiny = E.union_envelope([np.eye(nb, dtype=bool)])
    assert not tiny.covers(np.asarray(a.mask, bool))
    plan_mod.clear_cache()
    got = multiply(a, bmat, backend="stacks", envelope=tiny,
                   threshold=1e-8, filter_eps=1e-7)
    want = multiply(a, bmat, backend="stacks",
                    threshold=1e-8, filter_eps=1e-7)
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               np.asarray(want.to_dense()),
                               rtol=1e-6, atol=1e-7)
    s = plan_mod.cache_stats()
    assert s["drift_retunes"] == 1, s


# ---- union envelopes -------------------------------------------------------


def test_union_envelope_covers_members():
    masks = [make_mask("uniform", 8, jax.random.key(s), occupancy=0.2)
             for s in range(5)]
    env = E.union_envelope(masks)
    for m in masks:
        assert env.covers(np.asarray(m, bool))
    # a pattern with one block outside the union is NOT covered
    union = np.asarray(env.mask_a, bool)
    if not union.all():
        outside = union.copy()
        i, j = np.argwhere(~union)[0]
        outside[i, j] = True
        assert not env.covers(outside)
    with pytest.raises(ValueError):
        E.union_envelope([])
    with pytest.raises(ValueError):
        E.union_envelope([np.ones((2, 3), bool)], [np.ones((2, 3), bool)])


def test_envelope_capacity_dominates_members():
    """The envelope's bucketed capacity >= any member pattern's exact
    surviving-product count — the static bound that makes one compiled
    program sound for the whole stream."""
    masks = [make_mask("zipf", 8, jax.random.key(s), occupancy=0.25)
             for s in range(4)]
    env = E.union_envelope(masks, [np.asarray(masks[0])])
    for m in masks:
        exact = int(pair_cube(m, masks[0]).sum())
        assert env.local_capacity() >= exact


# ---- DispatchCache: the serving-grade pattern-bucketed cache ---------------


def _routing_mask(nb, e, cols):
    """(nb, e) dispatch mask: every block row routes to ``cols``."""
    m = np.zeros((nb, e), bool)
    m[:, list(cols)] = True
    return m


def test_dispatch_cache_warm_then_all_hits():
    """A calibration-warmed bucket serves its whole mix as hits: no
    misses, no drift, and the envelope covers every stream mask."""
    rng = np.random.default_rng(0)
    eye = np.eye(8, dtype=bool)
    masks = [rng.random((8, 8)) < 0.4 for _ in range(6)]
    cache = E.DispatchCache(eye).warm(masks)
    assert cache.stats()["hits"] == 0  # calibration is not traffic

    plan_mod.clear_cache()
    for m in masks:
        env, dec = cache.resolve(m)
        assert env.covers(m, eye)
        assert dec["backend"] in ("jnp", "stacks", "pallas")
        assert dec["capacity"] >= 1
    st = plan_mod.cache_stats()
    assert st["dispatch_hits"] == 6, st
    assert st["dispatch_misses"] == 0, st
    assert st["drift_retunes"] == 0, st
    assert cache.stats()["hits"] == 6


def test_dispatch_cache_miss_then_widen_then_hit():
    """Cold bucket: first mask is a miss; an uncovered same-bucket mask
    widens the union (drift retune) and re-resolves the decision; the
    widened envelope then covers both mixes."""
    eye = np.eye(8, dtype=bool)
    m1 = _routing_mask(8, 8, (0, 1))
    m2 = _routing_mask(8, 8, (2, 3))  # same occupancy/row-load bucket
    cache = E.DispatchCache(eye)
    assert cache.bucket_of(m1) == cache.bucket_of(m2)

    plan_mod.clear_cache()
    cache.resolve(m1)
    st = plan_mod.cache_stats()
    assert (st["dispatch_misses"], st["drift_retunes"]) == (1, 0), st

    env, _ = cache.resolve(m2)
    st = plan_mod.cache_stats()
    assert st["drift_retunes"] == 1, st
    assert cache.stats()["widenings"] == 1
    assert env.covers(m1, eye) and env.covers(m2, eye)

    cache.resolve(m2)
    st = plan_mod.cache_stats()
    assert st["dispatch_hits"] == 1, st
    assert len(cache) == 1


def test_dispatch_cache_new_bucket_per_regime():
    """A mix whose occupancy moves a decile lands in a NEW bucket (its
    own envelope) instead of loosening the first bucket's union."""
    eye = np.eye(8, dtype=bool)
    sparse = _routing_mask(8, 8, (0,))  # occupancy 1/8
    dense = _routing_mask(8, 8, range(7))  # occupancy 7/8
    cache = E.DispatchCache(eye)
    assert cache.bucket_of(sparse) != cache.bucket_of(dense)
    plan_mod.clear_cache()
    cache.resolve(sparse)
    cache.resolve(dense)
    st = plan_mod.cache_stats()
    assert st["dispatch_misses"] == 2 and st["drift_retunes"] == 0, st
    assert len(cache) == 2


def test_dispatch_cache_db_roundtrip_capacity_monotone(tmp_path):
    """The tuner DB as a serving asset: a persisted dispatch decision
    warm-starts a relaunch (source == "db"), but only while its recorded
    capacity still covers the new launch's envelope."""
    import repro.tuner as tuner

    eye = np.eye(8, dtype=bool)
    mask = _routing_mask(8, 8, (1, 4))
    path = str(tmp_path / "db.json")
    plan_mod.clear_cache()
    tuner.set_default_db(path)
    try:
        cache = E.DispatchCache(eye)
        env, dec = cache.resolve(mask)
        assert dec["source"] == "analytic"
        key = cache._db_key(cache.bucket_of(mask))
        rec = tuner.get_default_db().lookup(key)
        assert rec is not None and rec["capacity"] == dec["capacity"]

        # relaunch: fresh cache, same DB -> measurement-free warm start
        relaunch = E.DispatchCache(eye)
        _, dec2 = relaunch.resolve(mask)
        assert dec2["source"] == "db"
        assert dec2["capacity"] == dec["capacity"]

        # a stale record whose capacity no longer covers the envelope is
        # re-derived and re-recorded, not trusted
        tuner.get_default_db().record(
            key, {"backend": dec["backend"], "capacity": 1})
        stale = E.DispatchCache(eye)
        _, dec3 = stale.resolve(mask)
        assert dec3["source"] == "analytic"
        assert dec3["capacity"] == dec["capacity"]
        assert tuner.get_default_db().lookup(key)["capacity"] == dec["capacity"]
    finally:
        plan_mod.clear_cache()  # drops the DB binding


def test_dispatch_cache_decision_fn_override():
    """An injected decision_fn pins the decision (no DB, no cost model)."""
    eye = np.eye(8, dtype=bool)
    cache = E.DispatchCache(
        eye, decision_fn=lambda env: {"backend": "jnp", "capacity": 64,
                                      "source": "pinned"})
    _, dec = cache.resolve(_routing_mask(8, 8, (0, 5)))
    assert dec == {"backend": "jnp", "capacity": 64, "source": "pinned"}
