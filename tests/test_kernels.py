"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per instructions: sweep shapes/dtypes for each kernel and assert_allclose
against the ref.py oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.block_spgemm import block_spgemm
from repro.kernels.flash_attention import flash_attention_single


# ---------------------------------------------------------------------------
# block_spgemm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bs", [8, 16, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_spgemm_shapes_dtypes(bs, dtype):
    ni, nk, nj = 3, 4, 2
    key = jax.random.key(0)
    a = jax.random.normal(key, (ni, nk, bs, bs), dtype)
    b = jax.random.normal(jax.random.key(1), (nk, nj, bs, bs), dtype)
    ok = jax.random.bernoulli(jax.random.key(2), 0.6, (ni, nk, nj))
    out = block_spgemm(a, b, ok, interpret=True)
    want = ref.block_spgemm_ref(a, b, ok)
    assert out.shape == (ni, nj, bs, bs)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2  # f32: 512-term k-sums
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_block_spgemm_filter_actually_skips():
    """A filtered-out (i,k,j) product must not contribute, even if data huge."""
    bs = 8
    a = jnp.ones((1, 2, bs, bs)) * 1e6
    b = jnp.ones((2, 1, bs, bs))
    ok = jnp.asarray([[[True], [False]]])  # only k=0 allowed
    out = block_spgemm(a, b, ok, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 1e6 * bs, rtol=1e-6)


def test_block_spgemm_all_filtered_is_zero():
    bs = 8
    a = jnp.ones((2, 2, bs, bs))
    b = jnp.ones((2, 2, bs, bs))
    ok = jnp.zeros((2, 2, 2), bool)
    out = block_spgemm(a, b, ok, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


@settings(max_examples=10, deadline=None)
@given(
    ni=st.integers(1, 4),
    nk=st.integers(1, 4),
    nj=st.integers(1, 4),
    bs=st.sampled_from([4, 8]),
    p=st.floats(0.0, 1.0),
)
def test_block_spgemm_property(ni, nk, nj, bs, p):
    a = jax.random.normal(jax.random.key(10), (ni, nk, bs, bs))
    b = jax.random.normal(jax.random.key(11), (nk, nj, bs, bs))
    ok = jax.random.bernoulli(jax.random.key(12), p, (ni, nk, nj))
    out = block_spgemm(a, b, ok, interpret=True)
    want = ref.block_spgemm_ref(a, b, ok)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_ops_wrapper_defaults_interpret_on_cpu():
    a = jnp.ones((1, 1, 8, 8))
    b = jnp.ones((1, 1, 8, 8))
    ok = jnp.ones((1, 1, 1), bool)
    out = ops.block_spgemm(a, b, ok)  # interpret auto-detected (CPU)
    np.testing.assert_allclose(np.asarray(out), 8.0)


def test_interpret_env_override(monkeypatch):
    from repro.kernels.ops import _default_interpret

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert _default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert _default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "auto")
    assert _default_interpret() is (jax.default_backend() != "tpu")


def test_block_spgemm_rectangular_blocks():
    """bs_r != bs_k != bs_c through the scalar-prefetch kernel."""
    ni, nk, nj, bs_r, bs_k, bs_c = 2, 3, 4, 8, 16, 4
    a = jax.random.normal(jax.random.key(20), (ni, nk, bs_r, bs_k))
    b = jax.random.normal(jax.random.key(21), (nk, nj, bs_k, bs_c))
    ok = jax.random.bernoulli(jax.random.key(22), 0.5, (ni, nk, nj))
    out = block_spgemm(a, b, ok, interpret=True)
    want = ref.block_spgemm_ref(a, b, ok)
    assert out.shape == (ni, nj, bs_r, bs_c)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_block_spgemm_compacted_capacity():
    """A tight static capacity (the whole point of the compaction) is
    numerically identical to the full-cube grid."""
    ni, nk, nj, bs = 4, 4, 4, 8
    a = jax.random.normal(jax.random.key(30), (ni, nk, bs, bs))
    b = jax.random.normal(jax.random.key(31), (nk, nj, bs, bs))
    ok = jax.random.bernoulli(jax.random.key(32), 0.1, (ni, nk, nj))
    n = int(ok.sum())
    from repro.kernels.stacks import bucket_capacity

    out = block_spgemm(
        a, b, ok, capacity=bucket_capacity(n), interpret=True
    )
    want = ref.block_spgemm_ref(a, b, ok)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_block_spgemm_stacks_grid_is_capacity():
    """The scalar-prefetch grid issues exactly `capacity` steps — the
    kernel's work scales with survivors, not the (ni, nj, nk) cube."""
    from repro.kernels.block_spgemm import block_spgemm_stacks
    from repro.kernels.stacks import compact_pair_mask

    ni, nk, nj, bs = 4, 4, 4, 8
    a = jax.random.normal(jax.random.key(40), (ni, nk, bs, bs))
    b = jax.random.normal(jax.random.key(41), (nk, nj, bs, bs))
    ok = jnp.zeros((ni, nk, nj), bool).at[1, 2, 3].set(True).at[1, 3, 3].set(True)
    stacks = compact_pair_mask(ok, capacity=8)
    out = block_spgemm_stacks(a, b, stacks, ni=ni, nj=nj, interpret=True)
    want = ref.block_spgemm_ref(a, b, ok)
    # only the visited tile is defined; compare it (the two-product k-run)
    np.testing.assert_allclose(
        np.asarray(out[1, 3]), np.asarray(want[1, 3]), rtol=1e-5, atol=1e-5
    )
    # and the pallas grid really is (capacity,), not the (ni*nj*nk) cube
    jpr = jax.make_jaxpr(
        lambda aa, bb, ss: block_spgemm_stacks(
            aa, bb, ss, ni=ni, nj=nj, interpret=True
        )
    )(a, b, stacks)
    grids = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if "pallas" in str(eqn.primitive):
                grids.append(eqn.params["grid_mapping"].grid)
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    walk(v.jaxpr)

    walk(jpr.jaxpr)
    # grid = (n_tm, n_tn, capacity, n_tk): whole-block default tile at
    # bs=8 puts all the tiling dims at 1 — work still scales with capacity
    assert grids == [(1, 1, 8, 1)], grids


# ---------------------------------------------------------------------------
# MXU tiling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tile", [(8, 16, 4), (4, 8, 4), (8, 8, 2)])
def test_block_spgemm_explicit_tile_matches_oracle(tile):
    """Blocks spanning several tiles (incl. rectangular tiles) accumulate
    across the k-tile grid dim exactly like the whole-block kernel."""
    ni, nk, nj, bs_r, bs_k, bs_c = 2, 3, 2, 8, 16, 4
    a = jax.random.normal(jax.random.key(50), (ni, nk, bs_r, bs_k))
    b = jax.random.normal(jax.random.key(51), (nk, nj, bs_k, bs_c))
    ok = jax.random.bernoulli(jax.random.key(52), 0.5, (ni, nk, nj))
    out = block_spgemm(a, b, ok, tile=tile, interpret=True)
    want = ref.block_spgemm_ref(a, b, ok)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_block_spgemm_tile_grid_shape():
    """An explicit sub-block tile multiplies the grid dims accordingly."""
    from repro.kernels.block_spgemm import block_spgemm_stacks
    from repro.kernels.stacks import compact_pair_mask

    ni, nk, nj, bs = 2, 2, 2, 16
    a = jax.random.normal(jax.random.key(60), (ni, nk, bs, bs))
    b = jax.random.normal(jax.random.key(61), (nk, nj, bs, bs))
    ok = jnp.ones((ni, nk, nj), bool)
    stacks = compact_pair_mask(ok, capacity=8)
    jpr = jax.make_jaxpr(
        lambda aa, bb, ss: block_spgemm_stacks(
            aa, bb, ss, ni=ni, nj=nj, tile=(8, 8, 8), interpret=True
        )
    )(a, b, stacks)
    grids = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if "pallas" in str(eqn.primitive):
                grids.append(eqn.params["grid_mapping"].grid)
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    walk(v.jaxpr)

    walk(jpr.jaxpr)
    assert grids == [(2, 2, 8, 2)], grids
    # and the tiled program still matches the oracle
    out = block_spgemm_stacks(a, b, stacks, ni=ni, nj=nj, tile=(8, 8, 8),
                              interpret=True)
    want = ref.block_spgemm_ref(a, b, ok)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_tile_validation_up_front():
    """Satellite: bad tiles fail fast in block_spgemm_stacks with a clear
    ValueError, not a Mosaic lowering error."""
    from repro.kernels.block_spgemm import (
        block_spgemm_stacks,
        validate_tile,
    )
    from repro.kernels.stacks import compact_pair_mask

    ni = nj = 2
    bs = 16
    a = jnp.ones((ni, 2, bs, bs))
    b = jnp.ones((2, nj, bs, bs))
    stacks = compact_pair_mask(jnp.ones((ni, 2, nj), bool), capacity=8)
    with pytest.raises(ValueError, match="does not divide block dim"):
        block_spgemm_stacks(a, b, stacks, ni=ni, nj=nj, tile=(5, 8, 8),
                            interpret=True)
    with pytest.raises(ValueError, match="must be positive"):
        validate_tile(bs, bs, bs, (0, 8, 8), interpret=True)
    with pytest.raises(ValueError, match="integer triple"):
        validate_tile(bs, bs, bs, "big", interpret=True)
    # compiled mode demands lane alignment of the minor dims
    with pytest.raises(ValueError, match="lane-aligned"):
        validate_tile(256, 256, 256, (8, 64, 64), interpret=False)
    # interpret mode only needs divisibility
    assert validate_tile(16, 16, 16, (8, 8, 8), interpret=True) == (8, 8, 8)


def test_default_tile_and_candidates():
    from repro.kernels.block_spgemm import (
        MAX_TILE,
        default_tile,
        tile_candidates,
        tile_working_set_bytes,
        validate_tile,
    )

    # small blocks stay whole-block
    assert default_tile(16, 16, 16) == (16, 16, 16)
    # oversized dims split to the largest aligned divisor <= MAX_TILE
    dt = default_tile(512, 512, 512)
    assert all(t <= MAX_TILE and 512 % t == 0 for t in dt)
    # the candidate list leads with None (= default) and every explicit
    # entry validates for the shape it was generated for
    cands = tile_candidates(512, 512, 512)
    assert cands[0] is None
    for t in cands[1:]:
        assert validate_tile(512, 512, 512, t) == t
    # bf16 working set is half the f32 one at the same tile (+ f32 acc)
    f32 = tile_working_set_bytes(128, 128, 128, (128, 128, 128), jnp.float32)
    bf16 = tile_working_set_bytes(128, 128, 128, (128, 128, 128), jnp.bfloat16)
    assert bf16 < f32


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sq,skv,d", [(128, 128, 64), (256, 128, 32), (128, 256, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(sq, skv, d, causal):
    if causal and sq > skv:
        pytest.skip("causal needs sq <= skv alignment here")
    q = jax.random.normal(jax.random.key(0), (sq, d), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (skv, d), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (skv, d), jnp.float32)
    out = flash_attention_single(q, k, v, causal=causal, bq=64, bkv=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    sq = 256
    q = jax.random.normal(jax.random.key(3), (sq, 64))
    k = jax.random.normal(jax.random.key(4), (sq, 64))
    v = jax.random.normal(jax.random.key(5), (sq, 64))
    out = flash_attention_single(
        q, k, v, causal=True, window=window, bq=64, bkv=64, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_attention_softcap():
    """gemma2-style tanh logit capping."""
    q = jax.random.normal(jax.random.key(6), (128, 64)) * 4
    k = jax.random.normal(jax.random.key(7), (128, 64)) * 4
    v = jax.random.normal(jax.random.key(8), (128, 64))
    out = flash_attention_single(
        q, k, v, causal=True, softcap=50.0, bq=64, bkv=64, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(jax.random.key(9), (128, 64), dtype)
    k = jax.random.normal(jax.random.key(10), (128, 64), dtype)
    v = jax.random.normal(jax.random.key(11), (128, 64), dtype)
    out = flash_attention_single(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_gqa_batched():
    """ops.flash_attention: GQA head replication + batch/head vmap."""
    b, h, hkv, s, d = 2, 8, 2, 128, 32
    q = jax.random.normal(jax.random.key(12), (b, h, s, d))
    k = jax.random.normal(jax.random.key(13), (b, hkv, s, d))
    v = jax.random.normal(jax.random.key(14), (b, hkv, s, d))
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    assert out.shape == (b, h, s, d)
    rep = h // hkv
    for bi in range(b):
        for hi in range(h):
            want = ref.attention_ref(q[bi, hi], k[bi, hi // rep], v[bi, hi // rep])
            np.testing.assert_allclose(
                np.asarray(out[bi, hi]), np.asarray(want), rtol=2e-4, atol=2e-4
            )
