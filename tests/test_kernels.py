"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per instructions: sweep shapes/dtypes for each kernel and assert_allclose
against the ref.py oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.block_spgemm import block_spgemm
from repro.kernels.flash_attention import flash_attention_single


# ---------------------------------------------------------------------------
# block_spgemm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bs", [8, 16, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_spgemm_shapes_dtypes(bs, dtype):
    ni, nk, nj = 3, 4, 2
    key = jax.random.key(0)
    a = jax.random.normal(key, (ni, nk, bs, bs), dtype)
    b = jax.random.normal(jax.random.key(1), (nk, nj, bs, bs), dtype)
    ok = jax.random.bernoulli(jax.random.key(2), 0.6, (ni, nk, nj))
    out = block_spgemm(a, b, ok, interpret=True)
    want = ref.block_spgemm_ref(a, b, ok)
    assert out.shape == (ni, nj, bs, bs)
    tol = 3e-4 if dtype == jnp.float32 else 3e-2  # f32: 512-term k-sums
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_block_spgemm_filter_actually_skips():
    """A filtered-out (i,k,j) product must not contribute, even if data huge."""
    bs = 8
    a = jnp.ones((1, 2, bs, bs)) * 1e6
    b = jnp.ones((2, 1, bs, bs))
    ok = jnp.asarray([[[True], [False]]])  # only k=0 allowed
    out = block_spgemm(a, b, ok, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 1e6 * bs, rtol=1e-6)


def test_block_spgemm_all_filtered_is_zero():
    bs = 8
    a = jnp.ones((2, 2, bs, bs))
    b = jnp.ones((2, 2, bs, bs))
    ok = jnp.zeros((2, 2, 2), bool)
    out = block_spgemm(a, b, ok, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


@settings(max_examples=10, deadline=None)
@given(
    ni=st.integers(1, 4),
    nk=st.integers(1, 4),
    nj=st.integers(1, 4),
    bs=st.sampled_from([4, 8]),
    p=st.floats(0.0, 1.0),
)
def test_block_spgemm_property(ni, nk, nj, bs, p):
    a = jax.random.normal(jax.random.key(10), (ni, nk, bs, bs))
    b = jax.random.normal(jax.random.key(11), (nk, nj, bs, bs))
    ok = jax.random.bernoulli(jax.random.key(12), p, (ni, nk, nj))
    out = block_spgemm(a, b, ok, interpret=True)
    want = ref.block_spgemm_ref(a, b, ok)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_ops_wrapper_defaults_interpret_on_cpu():
    a = jnp.ones((1, 1, 8, 8))
    b = jnp.ones((1, 1, 8, 8))
    ok = jnp.ones((1, 1, 1), bool)
    out = ops.block_spgemm(a, b, ok)  # interpret auto-detected (CPU)
    np.testing.assert_allclose(np.asarray(out), 8.0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sq,skv,d", [(128, 128, 64), (256, 128, 32), (128, 256, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(sq, skv, d, causal):
    if causal and sq > skv:
        pytest.skip("causal needs sq <= skv alignment here")
    q = jax.random.normal(jax.random.key(0), (sq, d), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (skv, d), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (skv, d), jnp.float32)
    out = flash_attention_single(q, k, v, causal=causal, bq=64, bkv=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    sq = 256
    q = jax.random.normal(jax.random.key(3), (sq, 64))
    k = jax.random.normal(jax.random.key(4), (sq, 64))
    v = jax.random.normal(jax.random.key(5), (sq, 64))
    out = flash_attention_single(
        q, k, v, causal=True, window=window, bq=64, bkv=64, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_attention_softcap():
    """gemma2-style tanh logit capping."""
    q = jax.random.normal(jax.random.key(6), (128, 64)) * 4
    k = jax.random.normal(jax.random.key(7), (128, 64)) * 4
    v = jax.random.normal(jax.random.key(8), (128, 64))
    out = flash_attention_single(
        q, k, v, causal=True, softcap=50.0, bq=64, bkv=64, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(jax.random.key(9), (128, 64), dtype)
    k = jax.random.normal(jax.random.key(10), (128, 64), dtype)
    v = jax.random.normal(jax.random.key(11), (128, 64), dtype)
    out = flash_attention_single(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_gqa_batched():
    """ops.flash_attention: GQA head replication + batch/head vmap."""
    b, h, hkv, s, d = 2, 8, 2, 128, 32
    q = jax.random.normal(jax.random.key(12), (b, h, s, d))
    k = jax.random.normal(jax.random.key(13), (b, hkv, s, d))
    v = jax.random.normal(jax.random.key(14), (b, hkv, s, d))
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    assert out.shape == (b, h, s, d)
    rep = h // hkv
    for bi in range(b):
        for hi in range(h):
            want = ref.attention_ref(q[bi, hi], k[bi, hi // rep], v[bi, hi // rep])
            np.testing.assert_allclose(
                np.asarray(out[bi, hi]), np.asarray(want), rtol=2e-4, atol=2e-4
            )
