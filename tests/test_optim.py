"""Optimizer + gradient compression (error feedback) tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import global_norm
from repro.optim.compress import compress_grads, init_compress_state
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, clip_norm=None)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(cfg, params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw_update(cfg, params, grads, state)[:2]

    for _ in range(300):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_adamw_weight_decay_shrinks():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=None)
    params = {"w": jnp.ones(4) * 10.0}
    state = adamw_init(cfg, params)
    zero_grads = {"w": jnp.zeros(4)}
    p1, _, _ = adamw_update(cfg, params, zero_grads, state)
    assert float(jnp.abs(p1["w"]).max()) < 10.0


def test_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw_init(cfg, params)
    huge = {"w": jnp.full((3,), 1e6)}
    _, _, metrics = adamw_update(cfg, params, huge, state)
    assert float(metrics["grad_norm"]) == pytest.approx(1e6 * np.sqrt(3), rel=1e-4)


def test_moment_dtype_bf16():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    state = adamw_init(cfg, {"w": jnp.zeros((4, 4))})
    assert state["mu"]["w"].dtype == jnp.bfloat16
    _, s2, _ = adamw_update(cfg, {"w": jnp.zeros((4, 4))}, {"w": jnp.ones((4, 4))}, state)
    assert s2["nu"]["w"].dtype == jnp.bfloat16


def test_global_norm():
    n = global_norm({"a": jnp.ones(4), "b": jnp.ones(12)})
    assert float(n) == pytest.approx(4.0)


def test_error_feedback_telescopes():
    """Sum of quantized grads + final residual == sum of true grads (exact
    memoryless error feedback)."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.zeros((64,))}
    residual = init_compress_state(params)
    true_sum = np.zeros(64, np.float64)
    quant_sum = np.zeros(64, np.float64)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64) * 1e-3, jnp.float32)}
        q, residual = compress_grads(g, residual)
        assert q["w"].dtype == jnp.bfloat16
        true_sum += np.asarray(g["w"], np.float64)
        quant_sum += np.asarray(q["w"], np.float64)
    final = quant_sum + np.asarray(residual["w"], np.float64)
    np.testing.assert_allclose(final, true_sum, atol=1e-6)


def test_compression_halves_payload():
    g = {"w": jnp.zeros((1024,), jnp.float32)}
    q, _ = compress_grads(g, init_compress_state(g))
    assert q["w"].dtype.itemsize * 2 == g["w"].dtype.itemsize


def test_warmup_cosine_schedule():
    s = lambda x: jnp.asarray(x, jnp.int32)
    assert float(linear_warmup_cosine(s(0), 10, 110)) == pytest.approx(0.0, abs=1e-6)
    assert float(linear_warmup_cosine(s(5), 10, 110)) == pytest.approx(0.5)
    assert float(linear_warmup_cosine(s(10), 10, 110)) == pytest.approx(1.0)
    end = float(linear_warmup_cosine(s(110), 10, 110, final_frac=0.1))
    assert end == pytest.approx(0.1, abs=1e-3)
    # cosine is monotonically decreasing after warmup
    vals = [float(linear_warmup_cosine(s(t), 10, 110)) for t in range(10, 111, 20)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))
