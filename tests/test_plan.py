"""Plan layer: schedule compilation, table validity, and the program cache.

The pull schedule is validated two ways without any devices:
  * structurally — every round is a valid partial permutation, every active
    process receives exactly the panels of ``group_products``;
  * numerically — a pure-numpy interpreter of the plan tables (mimicking
    ppermute semantics: listed pairs deliver, everyone else receives zeros)
    reproduces A @ B exactly for square, non-square, and deep topologies.

Multi-device execution of the same plans is covered by
tests/test_distributed.py::test_plan_rectangular_grids / test_plan_cache.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.core.plan import _partition_rounds, _pull_schedule, _resolve_l
from repro.core.topology import (
    Topology,
    coords3d,
    group_products,
    make_topology,
)


# ---- round partitioning ----------------------------------------------------


def test_partition_rounds_splits_multicasts():
    pairs = [(0, 1), (0, 2), (0, 3), (1, 4)]
    rounds = _partition_rounds(pairs)
    assert len(rounds) == 3  # source 0 serialized over 3 rounds
    for r in rounds:
        srcs = [s for s, _ in r]
        dsts = [d for _, d in r]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
    assert sorted(p for r in rounds for p in r) == sorted(pairs)


@pytest.mark.parametrize(
    "pr,pc,l",
    [(2, 2, 1), (4, 4, 1), (2, 4, 2), (4, 2, 2), (2, 2, 4), (4, 4, 4),
     (6, 6, 9), (3, 9, 3), (6, 2, 3)],
)
def test_pull_rounds_are_partial_permutations(pr, pc, l):
    topo = make_topology(pr, pc, l)
    a_ticks, b_ticks, c_rounds, ca, cb = _pull_schedule(topo)
    for ticks in (a_ticks, b_ticks):
        for rounds in ticks:
            for rd in rounds:
                srcs = [s for s, _ in rd.pairs]
                dsts = [d for _, d in rd.pairs]
                assert len(set(srcs)) == len(srcs), (pr, pc, l)
                assert len(set(dsts)) == len(dsts), (pr, pc, l)
    n = pr * pc
    for perm in c_rounds:
        assert sorted(s for s, _ in perm) == list(range(n))
        assert sorted(d for _, d in perm) == list(range(n))


@pytest.mark.parametrize("pr,pc,l", [(2, 4, 2), (4, 2, 2), (4, 4, 4)])
def test_pull_schedule_delivers_group_products(pr, pc, l):
    """Per tick, each active process receives exactly the virtual panels of
    ``group_products`` — the plan is faithful to Algorithm 2."""
    topo = make_topology(pr, pc, l)
    a_ticks, b_ticks, _, ca, cb = _pull_schedule(topo)
    s = topo.side3d
    for g in range(topo.ticks):
        got_a: dict[int, set] = {}
        got_b: dict[int, set] = {}
        for rd in a_ticks[g]:
            for src, dst in rd.pairs:
                m, jc = divmod(src, topo.p_c)
                got_a.setdefault(dst, set()).add((m, jc * ca + rd.q))
        for rd in b_ticks[g]:
            for src, dst in rd.pairs:
                ir, n = divmod(src, topo.p_c)
                got_b.setdefault(dst, set()).add((ir * cb + rd.q, n))
        for i in range(pr):
            for j in range(pc):
                _, _, lay = coords3d(topo, i, j)
                f = i * pc + j
                if g >= topo.layer_groups(lay):
                    assert f not in got_a and f not in got_b
                    continue
                prods = group_products(topo, i, j, g)
                assert got_a[f] == {(m, k) for m, k, _ in prods}
                assert got_b[f] == {(k, n) for _, k, n in prods}


# ---- numpy interpretation of the plan tables == A @ B ----------------------


def _execute_pull_plan(topo: Topology, a: np.ndarray, b: np.ndarray):
    """Interpret the pull schedule with numpy ppermute semantics."""
    a_ticks, b_ticks, c_rounds, ca, cb = _pull_schedule(topo)
    p_r, p_c, depth, s = topo.p_r, topo.p_c, topo.l, topo.side3d
    n = a.shape[0]
    hr, hc, hv = n // p_r, n // p_c, n // topo.v
    nproc = p_r * p_c

    def a_shard(f):
        i, j = divmod(f, p_c)
        return a[i * hr : (i + 1) * hr, j * hc : (j + 1) * hc]

    def b_shard(f):
        i, j = divmod(f, p_c)
        return b[i * hr : (i + 1) * hr, j * hc : (j + 1) * hc]

    parts = [np.zeros((depth, hr, hc)) for _ in range(nproc)]
    for g in range(topo.ticks):
        pan_a = [np.zeros((topo.l_r, hr, hv)) for _ in range(nproc)]
        pan_b = [np.zeros((topo.l_c, hv, hc)) for _ in range(nproc)]
        for rd in a_ticks[g]:
            for src, dst in rd.pairs:
                pan_a[dst][rd.slot] += a_shard(src)[
                    :, rd.q * hv : (rd.q + 1) * hv
                ]
        for rd in b_ticks[g]:
            for src, dst in rd.pairs:
                pan_b[dst][rd.slot] += b_shard(src)[
                    rd.q * hv : (rd.q + 1) * hv, :
                ]
        for f in range(nproc):
            for i3 in range(topo.l_r):
                for j3 in range(topo.l_c):
                    t = j3 * topo.l_r + i3
                    parts[f][t] += pan_a[f][i3] @ pan_b[f][j3]

    def layer_of(f):
        i, j = divmod(f, p_c)
        return (j // s) * topo.l_r + (i // s)

    totals = [parts[f][layer_of(f)].copy() for f in range(nproc)]
    for d, perm in enumerate(c_rounds, start=1):
        for src, dst in perm:
            totals[dst] += parts[src][(layer_of(src) + d) % depth]

    c = np.zeros((n, n))
    for f in range(nproc):
        i, j = divmod(f, p_c)
        c[i * hr : (i + 1) * hr, j * hc : (j + 1) * hc] = totals[f]
    return c


@pytest.mark.parametrize(
    "pr,pc,l",
    [(2, 2, 1), (2, 4, 2), (4, 2, 2), (2, 2, 4), (4, 4, 4), (4, 4, 16),
     (3, 9, 3), (6, 2, 3), (6, 6, 9)],
)
def test_pull_plan_numpy_execution_exact(pr, pc, l):
    # invalid L falls back to 1 (Algorithm 2's rule), e.g. (6, 2): 6 > 2^2
    topo = make_topology(pr, pc, l)
    import math

    n = math.lcm(topo.v, pr, pc) * 2
    rng = np.random.default_rng(pr * 100 + pc * 10 + l)
    a = rng.standard_normal((n, n))
    b = rng.standard_normal((n, n))
    c = _execute_pull_plan(topo, a, b)
    np.testing.assert_allclose(c, a @ b, rtol=1e-10, atol=1e-10)


# ---- depth resolution & validation -----------------------------------------


def test_resolve_l_rules():
    assert _resolve_l(2, 4, None) == 2  # forced mx/mn
    assert _resolve_l(4, 2, None) == 2
    assert _resolve_l(2, 8, None) == 1  # mx > mn^2 -> fallback
    assert _resolve_l(4, 4, None) == 1  # square default
    assert _resolve_l(4, 4, 4) == 4  # explicit override


def test_stacked_chunks_partition_virtual_range():
    """Uneven L: the per-layer chunks must still partition [0, V)."""
    for p, l in ((2, 4), (3, 2), (6, 4)):
        topo = Topology(p_r=p, p_c=p, l=l, l_r=1, l_c=l, side3d=p,
                        v=p, nbuffers_a=2, nbuffers_b=2)
        flat = []
        for li in range(l):
            lo, hi = topo.chunk(li)
            flat.extend(range(lo, hi))
        assert sorted(flat) == list(range(p))
        assert max(topo.layer_groups(li) for li in range(l)) == topo.ticks


def test_validate_blocks_errors():
    topo = make_topology(2, 4, 2)
    plan = plan_mod.MultiplyPlan(
        engine="twofive", kind="pull", mesh=None, axes=("r", "c"),
        p_r=2, p_c=4, topo=topo, ticks=topo.ticks,
    )
    plan.validate_blocks(8, 8)
    with pytest.raises(ValueError):
        plan.validate_blocks(6, 6)  # 6 % 4 != 0
    with pytest.raises(ValueError):
        plan.validate_blocks(10, 10)  # divides p_r but not V=4


def test_explicit_l_rejected_when_not_honored():
    """Engines with fixed depth (cannon/onesided/gather) and stacked meshes
    with a conflicting depth must reject an explicit ``l`` rather than
    silently ignoring it."""
    import jax

    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh2d = jax.make_mesh((1, 1), ("r", "c"))
    for engine in ("cannon", "onesided", "gather"):
        with pytest.raises(ValueError, match="no depth parameter"):
            plan_mod.plan_multiply(mesh2d, engine, 2)
    mesh3d = jax.make_mesh((1, 1, 1), ("l", "r", "c"))
    with pytest.raises(ValueError, match="conflicts with the mesh"):
        plan_mod.plan_multiply(mesh3d, "twofive", 4)


def test_scatter_layout_needs_stacked_mesh():
    topo = make_topology(2, 2, 1)
    plan = plan_mod.MultiplyPlan(
        engine="onesided", kind="pull", mesh=None, axes=("r", "c"),
        p_r=2, p_c=2, topo=topo, ticks=topo.ticks,
    )
    with pytest.raises(ValueError, match="stacked"):
        plan_mod.build_program(
            plan, threshold=0.0, backend="jnp", c_layout="scatter"
        )


# ---- program cache (single-device mesh: runs in the main test process) -----


def test_program_cache_hits_and_reuse():
    import jax

    from repro.core import bsm as B
    from repro.core.engine import multiply, multiply_reference

    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    a = B.random_bsm(jax.random.key(0), nb=4, bs=4, occupancy=0.6)
    b = B.random_bsm(jax.random.key(1), nb=4, bs=4, occupancy=0.6)
    ref = np.asarray(multiply_reference(a, b).to_dense())

    plan_mod.clear_cache()
    c1 = multiply(a, b, mesh, engine="twofive")
    s1 = plan_mod.cache_stats()
    c2 = multiply(a, b, mesh, engine="twofive")
    s2 = plan_mod.cache_stats()
    np.testing.assert_allclose(np.asarray(c1.to_dense()), ref, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(c2.to_dense()), ref, rtol=1e-5,
                               atol=1e-5)
    assert s1["misses"] == 1 and s1["builds"] == 1
    assert s2["builds"] == s1["builds"]  # second call: no re-build/lower
    assert s2["hits"] == s1["hits"] + 1
    # a different key (threshold) builds a distinct program
    multiply(a, b, mesh, engine="twofive", threshold=0.1)
    s3 = plan_mod.cache_stats()
    assert s3["builds"] == s2["builds"] + 1


# ---- transport in the program-cache key ------------------------------------


def test_get_compiled_requires_resolved_transport():
    """Mode strings must be resolved (plan.resolve_transport) BEFORE the
    program-cache key is formed — an auto decision baked into a key
    would alias distinct programs."""
    import jax

    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    with pytest.raises(TypeError, match="resolved PanelTransport"):
        plan_mod.get_compiled(mesh, "onesided", 4, 4, "float32",
                              transport="auto")


def test_build_shard_body_defaults_dense_transport():
    """Chain bodies (signiter) build with dense transport unless told
    otherwise — compressed capacities from an initial pattern are not
    chain-safe (the pattern evolves under the traced sweep)."""
    import jax

    from repro.core import transport as T

    if len(jax.devices()) != 1:
        pytest.skip("single-device check")
    mesh = jax.make_mesh((1, 1), ("r", "c"))
    plan = plan_mod.plan_multiply(mesh, "onesided")
    # None -> DENSE inside build_shard_body; an explicit PanelTransport
    # is honored (both bodies construct without error)
    plan_mod.build_shard_body(plan, threshold=0.0, backend="jnp")
    plan_mod.build_shard_body(
        plan, threshold=0.0, backend="jnp",
        transport=T.PanelTransport("compressed", 8, 8),
    )
