"""Communication-volume / memory model — paper Eq. (6), (7), Figs. 2 & 3."""
from __future__ import annotations

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import commvolume as CV
from repro.core.topology import make_topology


def test_ptp_equals_os1_tick_volume():
    """Table 2: PTP and OS1 communicate identical A/B volume (the paper's
    measured equality); PTP adds only the pre-shift."""
    topo = make_topology(8, 8, 1)
    ptp = CV.ptp_volume(topo, s_a=3.0, s_b=1.0)
    os1 = CV.osl_volume(topo, s_a=3.0, s_b=1.0, s_c=2.0)
    assert os1.c_volume == 0.0
    assert ptp.ab_volume == pytest.approx(os1.ab_volume + (3.0 + 1.0))


@pytest.mark.parametrize("l", [4, 9, 16])
def test_osl_sqrt_l_reduction(l):
    """Eq. (7): A/B volume scales 1/sqrt(L)."""
    p = 12 * int(math.isqrt(l))
    base = CV.osl_volume(make_topology(p, p, 1), 1.0, 1.0, 1.0)
    deep = CV.osl_volume(make_topology(p, p, l), 1.0, 1.0, 1.0)
    assert deep.ab_volume == pytest.approx(base.ab_volume / math.sqrt(l))
    assert deep.c_volume == pytest.approx(l - 1.0)


def test_fig3_ratio_matches_paper_shape():
    """Fig. 3: the OS1/OSL ratio is < sqrt(L) because of the (L-1) S_C term,
    and decreases as S_C/S_AB grows (the paper's H2O vs Dense ordering)."""
    topo4 = make_topology(36, 36, 4)
    # paper's measured S_C/S_{A,B}: H2O-DFT-LS 2.7, S-E 2.1, Dense 1.0
    r_h2o = CV.volume_ratio_os1_over_osl(topo4, 1.0, 1.0, 2.7 * 1.0)
    r_se = CV.volume_ratio_os1_over_osl(topo4, 1.0, 1.0, 2.1 * 1.0)
    r_dense = CV.volume_ratio_os1_over_osl(topo4, 1.0, 1.0, 1.0)
    assert 1.0 < r_h2o < 2.0  # < sqrt(4)
    assert r_h2o < r_se < r_dense < 2.0


def test_memory_factor_eq6():
    """Eq. (6) exact values."""
    sq = make_topology(8, 8, 4)
    f = CV.memory_factor(sq, s_a=1.0, s_b=1.0, s_c=2.0)
    assert f == pytest.approx(2.0 / (3 * 2.0) * 4 + (2 + 4) / 6.0)
    ns = make_topology(4, 8, 2)
    f = CV.memory_factor(ns, s_a=1.0, s_b=1.0, s_c=2.0)
    assert f == pytest.approx(2.0 / 6.0 * 2 + 1.0)
    assert CV.memory_factor(make_topology(4, 4, 1), 1, 1, 1) == 1.0


def test_scaling_law_sqrt_pl():
    """O(1/sqrt(PL)) scaling of communicated volume per process."""
    n = 1e8
    v1 = CV.scaling_per_process(256, 1, n)
    v2 = CV.scaling_per_process(1024, 1, n)
    v3 = CV.scaling_per_process(256, 4, n)
    assert v2 == pytest.approx(v1 / 2)
    assert v3 == pytest.approx(v1 / 2)


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([4, 6, 8, 12]),
    l=st.sampled_from([1, 4, 9]),
    sc_ratio=st.floats(0.5, 4.0),
)
def test_property_osl_total_monotone_in_l_for_small_sc(s, l, sc_ratio):
    """OSL total <= OS1 total whenever the S_C overhead term stays below the
    A/B saving — the paper's 'L pays off when communication dominates'."""
    if s % int(math.isqrt(l)) != 0:
        return
    topo1 = make_topology(s, s, 1)
    topol = make_topology(s, s, l)
    os1 = CV.osl_volume(topo1, 1.0, 1.0, sc_ratio)
    osl = CV.osl_volume(topol, 1.0, 1.0, sc_ratio)
    saving = os1.ab_volume - osl.ab_volume
    overhead = osl.c_volume
    if saving > overhead:
        assert osl.total < os1.total
    else:
        assert osl.total >= os1.total - 1e-9


def test_mesh25d_volume_model():
    """The JAX-engine mesh formulation keeps Eq. (7) asymptotics."""
    v1 = CV.mesh25d_volume(8, 1, 1.0, 1.0, 1.0)
    v4 = CV.mesh25d_volume(8, 4, 1.0, 1.0, 1.0)
    # AB volume: ticks go 8 -> 2, i.e. / L (panel count), while panel k-width
    # is unchanged in the mesh formulation -> net / L == /sqrt(L)^2
    assert v4.ab_volume < v1.ab_volume
    assert v4.c_volume == pytest.approx(3.0 / 4.0)
