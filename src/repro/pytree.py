"""Small pytree-dataclass helper used across the library."""
from __future__ import annotations

import dataclasses
from typing import TypeVar

import jax

_T = TypeVar("_T")


def pytree_dataclass(cls: type[_T] | None = None, *, meta_fields: tuple[str, ...] = ()):
    """Register a frozen dataclass as a JAX pytree.

    ``meta_fields`` are static (hashable) fields excluded from tracing.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        data_fields = tuple(
            f.name for f in dataclasses.fields(c) if f.name not in meta_fields
        )
        jax.tree_util.register_dataclass(
            c, data_fields=data_fields, meta_fields=tuple(meta_fields)
        )
        return c

    if cls is None:
        return wrap
    return wrap(cls)
