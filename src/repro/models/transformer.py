"""Composable multi-architecture LM stack (all 10 assigned architectures).

One parameter/pytree layout, three entry points:

  * ``forward``       — train/prefill hidden states (scan over layer groups)
  * ``loss_fn``       — forward + chunked cross-entropy (+ MoE aux loss)
  * ``init_cache`` / ``decode_step`` — single-token serving against a KV
    cache (attention), carried recurrent state (mamba/rwkv6), or both
    (jamba hybrid)

Layer heterogeneity (gemma2 local/global alternation, jamba 1:7
mamba:attention with every-other-layer MoE, rwkv6 attention-free) is
expressed as a *pattern period*: ``cfg.layer_kinds()`` gives the static
per-position spec within one period, parameters are stacked over the
``n_layers / period`` repetitions, and a single ``lax.scan`` runs the
repeats — O(1) HLO size for 80-layer models, which keeps the 512-device
dry-run compile tractable.

Sharding: model code is mesh-agnostic; activation constraints are injected
via ``repro.parallel.ctx.shard_act`` (no-op without an active rule set).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MoE
from repro.models import rwkv6 as R
from repro.parallel.ctx import shard_act

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(cfg: ArchConfig, kind: dict, key, dtype, cross: bool = False) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": L.init_norm(cfg, cfg.d_model)}
    if kind["mixer"] == "attention":
        p["attn"] = A.init_attention(cfg, ks[0], dtype)
    elif kind["mixer"] == "mamba":
        p["mamba"] = M.init_mamba(cfg, ks[0], dtype)
    elif kind["mixer"] == "rwkv6":
        p["rwkv"] = R.init_rwkv(cfg, ks[0], dtype)
        p["rwkv_ln2"] = L.init_norm(cfg, cfg.d_model)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        p["post_ln1"] = L.init_norm(cfg, cfg.d_model)
    if cross:
        p["xattn"] = A.init_attention(cfg, ks[1], dtype, cross=True)
        p["ln_x"] = L.init_norm(cfg, cfg.d_model)
    if kind["mixer"] != "rwkv6":  # rwkv6 channel-mix replaces the MLP
        p["ln2"] = L.init_norm(cfg, cfg.d_model)
        if kind["moe"]:
            p["moe"] = MoE.init_moe(cfg, ks[2], dtype)
        else:
            p["mlp"] = L.init_mlp(cfg, ks[2], cfg.d_model, cfg.d_ff, dtype)
        if cfg.post_norm:
            p["post_ln2"] = L.init_norm(cfg, cfg.d_model)
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    """Full parameter pytree; per-period blocks stacked over repetitions."""
    dtype = jnp.dtype(cfg.dtype)
    kinds = cfg.layer_kinds()
    period = len(kinds)
    reps = cfg.n_layers // period
    k_embed, k_blocks, k_enc = jax.random.split(key, 3)

    params: Params = {"embed": L.init_embed(cfg, k_embed, dtype)}
    blocks = []
    cross = cfg.encoder is not None
    for i, kind in enumerate(kinds):
        kk = jax.random.fold_in(k_blocks, i)
        init_one = functools.partial(_init_block, cfg, kind, dtype=dtype, cross=cross)
        blocks.append(jax.vmap(init_one)(jax.random.split(kk, reps)))
    params["blocks"] = tuple(blocks)
    params["final_norm"] = L.init_norm(cfg, cfg.d_model)

    if cfg.encoder is not None:
        enc_kind = {"mixer": "attention", "window": None, "moe": False}
        init_enc = functools.partial(
            _init_block, cfg, enc_kind, dtype=dtype, cross=False
        )
        params["encoder"] = {
            "blocks": (
                jax.vmap(init_enc)(jax.random.split(k_enc, cfg.encoder.n_layers)),
            ),
            "final_norm": L.init_norm(cfg, cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# caches / recurrent state
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    """Decode-time state, stacked (reps, ...) per pattern position."""
    dtype = jnp.dtype(cfg.dtype)
    kinds = cfg.layer_kinds()
    reps = cfg.n_layers // len(kinds)
    hkv, hd = cfg.n_kv_heads, cfg.hd

    caches = []
    for kind in kinds:
        if kind["mixer"] == "attention":
            c = {
                "k": jnp.zeros((reps, batch, hkv, max_len, hd), dtype),
                "v": jnp.zeros((reps, batch, hkv, max_len, hd), dtype),
            }
            if cfg.encoder is not None:
                c["xk"] = jnp.zeros(
                    (reps, batch, hkv, cfg.encoder.n_frames, hd), dtype
                )
                c["xv"] = jnp.zeros(
                    (reps, batch, hkv, cfg.encoder.n_frames, hd), dtype
                )
        elif kind["mixer"] == "mamba":
            c = jax.tree.map(
                lambda x: jnp.zeros((reps,) + x.shape, x.dtype),
                M.init_mamba_state(cfg, batch, dtype),
            )
        else:  # rwkv6
            c = jax.tree.map(
                lambda x: jnp.zeros((reps,) + x.shape, x.dtype),
                R.init_rwkv_state(cfg, batch, dtype),
            )
        caches.append(c)
    return {"blocks": tuple(caches)}


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _norm_res(cfg, p, name, post_name, x, sub):
    """Pre-norm residual, with gemma2-style sandwich post-norm.

    The norm output is constrained to full-seq ("btd_full"): under sequence
    parallelism this is the Megatron-SP g-operator — an activation all-gather
    here instead of weight-sized dW all-reduces at every TP matmul.
    """
    y = sub(shard_act(L.apply_norm(cfg, p[name], x), "btd_full"))
    # Megatron-SP g-bar: the projection output is constrained back to the
    # seq-sharded residual layout BEFORE the add, so the TP contraction
    # lowers to a reduce-scatter (half the wire bytes of all-reduce + slice)
    y = shard_act(y, "btd")
    if cfg.post_norm:
        y = L.apply_norm(cfg, p[post_name], y)
    return x + y, None


def _apply_attn_train(cfg, p, kind, x, positions, *, causal=True):
    q, k, v = A.qkv_proj(cfg, p, x, positions if cfg.rope else None)
    q = shard_act(q, "bhsd")
    k = shard_act(k, "bksd")
    v = shard_act(v, "bksd")
    o = A.chunked_attention(
        q, k, v,
        causal=causal,
        window=kind.get("window"),
        softcap=cfg.attn_softcap,
    )
    return A.out_proj(cfg, p, o)


def _apply_block_train(cfg, kind, p, x, positions, enc_out=None):
    """Train/prefill body for one layer. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind["mixer"] == "attention":
        def sub(xn):
            return _apply_attn_train(cfg, p["attn"], kind, xn, positions)

        x, _ = _norm_res(cfg, p, "ln1", "post_ln1", x, sub)
        if enc_out is not None:  # whisper cross-attention
            def xsub(xn):
                q, _, _ = A.qkv_proj(cfg, p["xattn"], xn, None)
                _, ek, ev = A.qkv_proj(cfg, p["xattn"], enc_out, None)
                o = A.chunked_attention(q, ek, ev, causal=False)
                return A.out_proj(cfg, p["xattn"], o)

            x = x + xsub(L.apply_norm(cfg, p["ln_x"], x))
    elif kind["mixer"] == "mamba":
        y, _ = M.apply_mamba(
            cfg, p["mamba"], shard_act(L.apply_norm(cfg, p["ln1"], x), "btd_full")
        )
        if cfg.post_norm:
            y = L.apply_norm(cfg, p["post_ln1"], y)
        x = x + y
    else:  # rwkv6: time mix + channel mix (its own pair of residuals)
        st = R.init_rwkv_state(cfg, x.shape[0], x.dtype)
        y, _ = R.apply_rwkv_time_mix(
            cfg, p["rwkv"], shard_act(L.apply_norm(cfg, p["ln1"], x), "btd_full"), st
        )
        x = x + y
        y, _ = R.apply_rwkv_channel_mix(
            cfg, p["rwkv"], shard_act(L.apply_norm(cfg, p["rwkv_ln2"], x), "btd_full"), st
        )
        return x + y, aux

    x = shard_act(x, "btd")
    if kind["moe"]:
        def msub(xn):
            y, a = MoE.apply_moe(cfg, p["moe"], xn)
            return y, a

        xn = shard_act(L.apply_norm(cfg, p["ln2"], x), "btd_full")
        y, aux = msub(xn)
        y = shard_act(y, "btd")
        if cfg.post_norm:
            y = L.apply_norm(cfg, p["post_ln2"], y)
        x = x + y
    else:
        x, _ = _norm_res(
            cfg, p, "ln2", "post_ln2", x, lambda xn: L.apply_mlp(cfg, p["mlp"], xn)
        )
    return shard_act(x, "btd"), aux


def _update_kv(cache_k, cache_v, k, v, position):
    """Write new K/V at `position` (decode) or [0, S) (prefill).

    A vector position (B,) writes each batch slot's single new row at its
    own fill level — continuous-batching refill desynchronizes the slots.
    """
    pos = jnp.asarray(position)
    if pos.ndim == 0:
        ck = lax.dynamic_update_slice(cache_k, k, (0, 0, position, 0))
        cv = lax.dynamic_update_slice(cache_v, v, (0, 0, position, 0))
        return ck, cv
    bidx = jnp.arange(cache_k.shape[0])
    ck = cache_k.at[bidx, :, pos, :].set(k[:, :, 0, :])
    cv = cache_v.at[bidx, :, pos, :].set(v[:, :, 0, :])
    return ck, cv


def _apply_block_decode(cfg, kind, p, x, cache, position, enc_out=None):
    """Single-token decode body. Returns (x, new_cache)."""
    if kind["mixer"] == "attention":
        xn = L.apply_norm(cfg, p["ln1"], x)
        pv = jnp.asarray(position)
        pos = pv[:, None] if pv.ndim else jnp.full((1,), position)
        q, k, v = A.qkv_proj(cfg, p["attn"], xn, pos if cfg.rope else None)
        ck, cv = _update_kv(cache["k"], cache["v"], k, v, position)
        o = A.decode_attention(
            q, ck, cv, position + 1,
            window=kind.get("window"),
            softcap=cfg.attn_softcap,
        )
        y = A.out_proj(cfg, p["attn"], o)
        if cfg.post_norm:
            y = L.apply_norm(cfg, p["post_ln1"], y)
        x = x + y
        new_cache = dict(cache, k=ck, v=cv)
        if "xk" in cache:  # whisper cross-attention against cached encoder KV
            xn = L.apply_norm(cfg, p["ln_x"], x)
            q, _, _ = A.qkv_proj(cfg, p["xattn"], xn, None)
            o = A.decode_attention(q, cache["xk"], cache["xv"], cache["xk"].shape[2])
            x = x + A.out_proj(cfg, p["xattn"], o)
    elif kind["mixer"] == "mamba":
        xn = L.apply_norm(cfg, p["ln1"], x)
        y, new_cache = M.decode_mamba(cfg, p["mamba"], xn, cache)
        if cfg.post_norm:
            y = L.apply_norm(cfg, p["post_ln1"], y)
        x = x + y
    else:  # rwkv6
        xn = L.apply_norm(cfg, p["ln1"], x)
        y, cache = R.decode_rwkv_time_mix(cfg, p["rwkv"], xn, cache)
        x = x + y
        xn = L.apply_norm(cfg, p["rwkv_ln2"], x)
        y, new_cache = R.decode_rwkv_channel_mix(cfg, p["rwkv"], xn, cache)
        return x + y, new_cache

    if kind["moe"]:
        xn = L.apply_norm(cfg, p["ln2"], x)
        y, _ = MoE.apply_moe(cfg, p["moe"], xn)
        if cfg.post_norm:
            y = L.apply_norm(cfg, p["post_ln2"], y)
        x = x + y
    else:
        x, _ = _norm_res(
            cfg, p, "ln2", "post_ln2", x, lambda xn: L.apply_mlp(cfg, p["mlp"], xn)
        )
    return x, new_cache


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------


def encode(cfg: ArchConfig, params: Params, frame_embeds: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, n_frames, d)."""
    enc = params["encoder"]
    x = frame_embeds + L.sinusoidal_positions(
        frame_embeds.shape[1], cfg.d_model
    ).astype(frame_embeds.dtype)
    kind = {"mixer": "attention", "window": None, "moe": False}

    def body(x, p):
        def sub(xn):
            return _apply_attn_train(cfg, p["attn"], kind, xn, None, causal=False)

        x, _ = _norm_res(cfg, p, "ln1", "post_ln1", x, sub)
        x, _ = _norm_res(
            cfg, p, "ln2", "post_ln2", x, lambda xn: L.apply_mlp(cfg, p["mlp"], xn)
        )
        return x, None

    x, _ = lax.scan(body, x, enc["blocks"][0])
    return L.apply_norm(cfg, enc["final_norm"], x)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def _embed_inputs(cfg, params, tokens, patch_embeds=None):
    x = L.embed_tokens(params["embed"], tokens)
    if cfg.frontend == "vision" and patch_embeds is not None:
        # early fusion stub: image patch embeddings occupy the prefix
        npatch = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, npatch:]], axis=1)
    if not cfg.rope:
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    return shard_act(x, "btd")


def forward(
    cfg: ArchConfig,
    params: Params,
    tokens: jax.Array,  # (B, S)
    *,
    patch_embeds: jax.Array | None = None,
    frame_embeds: jax.Array | None = None,
    remat: str = "none",  # none | full | dots
) -> tuple[jax.Array, jax.Array]:
    """Train/prefill forward. Returns (hidden (B, S, d), moe aux loss)."""
    x = _embed_inputs(cfg, params, tokens, patch_embeds)
    positions = jnp.arange(tokens.shape[1])
    enc_out = None
    if cfg.encoder is not None and frame_embeds is not None:
        enc_out = encode(cfg, params, frame_embeds)

    kinds = cfg.layer_kinds()

    def group(x, block_params):
        aux = jnp.zeros((), jnp.float32)
        for kind, p in zip(kinds, block_params):
            x, a = _apply_block_train(cfg, kind, p, x, positions, enc_out)
            aux = aux + a
        return x, aux

    if remat == "full":
        group = jax.checkpoint(group, policy=None)
    elif remat == "dots":
        group = jax.checkpoint(
            group, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    def body(carry, block_params):
        x, aux = carry
        x, a = group(x, block_params)
        return (x, aux + a), None

    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, aux


def loss_fn(
    cfg: ArchConfig,
    params: Params,
    batch: dict[str, jax.Array],
    *,
    aux_coef: float = 0.01,
    remat: str = "none",
    loss_chunk: int = 512,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Causal-LM loss (chunked CE over the vocab) + MoE load-balance aux."""
    x, aux = forward(
        cfg,
        params,
        batch["tokens"],
        patch_embeds=batch.get("patch_embeds"),
        frame_embeds=batch.get("frame_embeds"),
        remat=remat,
    )
    ce = L.chunked_cross_entropy(
        cfg, params["embed"], x, batch["targets"], chunk=loss_chunk
    )
    loss = ce + aux_coef * aux
    return loss, {"ce": ce, "moe_aux": aux}


def prefill(
    cfg: ArchConfig,
    params: Params,
    tokens: jax.Array,  # (B, S)
    cache: Params,
    *,
    patch_embeds=None,
    frame_embeds=None,
) -> tuple[jax.Array, Params]:
    """Run the prompt, fill the cache, return last-position logits.

    Attention K/V for the full prompt are written to the cache; recurrent
    states (mamba/rwkv) are advanced through the prompt.
    """
    x = _embed_inputs(cfg, params, tokens, patch_embeds)
    positions = jnp.arange(tokens.shape[1])
    enc_out = None
    if cfg.encoder is not None and frame_embeds is not None:
        enc_out = encode(cfg, params, frame_embeds)
    kinds = cfg.layer_kinds()

    def body(x, scanned):
        block_params, block_caches = scanned
        new_caches = []
        for kind, p, c in zip(kinds, block_params, block_caches):
            x, nc = _prefill_block(cfg, kind, p, x, c, positions, enc_out)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_matmul(cfg, params["embed"], x[:, -1:])
    return logits, {"blocks": new_blocks}


def _prefill_block(cfg, kind, p, x, cache, positions, enc_out=None):
    if kind["mixer"] == "attention":
        xn = L.apply_norm(cfg, p["ln1"], x)
        q, k, v = A.qkv_proj(cfg, p["attn"], xn, positions if cfg.rope else None)
        ck, cv = _update_kv(cache["k"], cache["v"], k, v, 0)
        o = A.chunked_attention(
            q, k, v, causal=True, window=kind.get("window"), softcap=cfg.attn_softcap
        )
        y = A.out_proj(cfg, p["attn"], o)
        if cfg.post_norm:
            y = L.apply_norm(cfg, p["post_ln1"], y)
        x = x + y
        new_cache = dict(cache, k=ck, v=cv)
        if enc_out is not None and "xk" in cache:
            xn = L.apply_norm(cfg, p["ln_x"], x)
            q, ek, ev = None, None, None
            q, _, _ = A.qkv_proj(cfg, p["xattn"], xn, None)
            _, ek, ev = A.qkv_proj(cfg, p["xattn"], enc_out, None)
            o = A.chunked_attention(q, ek, ev, causal=False)
            x = x + A.out_proj(cfg, p["xattn"], o)
            new_cache = dict(new_cache, xk=ek, xv=ev)
    elif kind["mixer"] == "mamba":
        xn = L.apply_norm(cfg, p["ln1"], x)
        y, new_cache = M.apply_mamba(cfg, p["mamba"], xn, cache)
        if cfg.post_norm:
            y = L.apply_norm(cfg, p["post_ln1"], y)
        x = x + y
    else:  # rwkv6
        xn = L.apply_norm(cfg, p["ln1"], x)
        y, cache = R.apply_rwkv_time_mix(cfg, p["rwkv"], xn, cache)
        x = x + y
        xn = L.apply_norm(cfg, p["rwkv_ln2"], x)
        y, new_cache = R.apply_rwkv_channel_mix(cfg, p["rwkv"], xn, cache)
        return x + y, new_cache

    if kind["moe"]:
        xn = L.apply_norm(cfg, p["ln2"], x)
        y, _ = MoE.apply_moe(cfg, p["moe"], xn)
        if cfg.post_norm:
            y = L.apply_norm(cfg, p["post_ln2"], y)
        x = x + y
    else:
        x, _ = _norm_res(
            cfg, p, "ln2", "post_ln2", x, lambda xn: L.apply_mlp(cfg, p["mlp"], xn)
        )
    return x, new_cache


def decode_step(
    cfg: ArchConfig,
    params: Params,
    tokens: jax.Array,  # (B, 1)
    cache: Params,
    position: jax.Array,  # int32 scalar or (B,): write offset == fill level
) -> tuple[jax.Array, Params]:
    """One serving step: (logits (B, 1, V), updated cache).

    ``position`` may be a (B,) vector of per-slot fill levels: continuous
    batching refills slots mid-stream, so slots decode at different
    positions within one step.
    """
    x = L.embed_tokens(params["embed"], tokens)
    if not cfg.rope:
        # absolute sinusoidal at the current position(s) (whisper)
        d = cfg.d_model
        pos = jnp.asarray(position, jnp.float32).reshape(-1)  # (1,) or (B,)
        div = jnp.exp(
            jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d)
        )
        ang = pos[:, None] * div  # (n, d/2)
        pe = jnp.zeros((pos.shape[0], d), jnp.float32)
        pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
        x = x + pe[:, None, :].astype(x.dtype)
    x = shard_act(x, "btd")
    kinds = cfg.layer_kinds()

    def body(x, scanned):
        block_params, block_caches = scanned
        new_caches = []
        for kind, p, c in zip(kinds, block_params, block_caches):
            x, nc = _apply_block_decode(cfg, kind, p, x, c, position)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.logits_matmul(cfg, params["embed"], x)
    return logits, {"blocks": new_blocks}
