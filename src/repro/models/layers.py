"""Shared model primitives: norms, rope, MLPs, embeddings, chunked loss."""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ArchConfig, d: int):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.zeros((d,), jnp.float32)}  # gemma-style (1 + w)
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "nonparametric_ln":
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * lax.rsqrt(var + 1e-6) * (1.0 + p["w"])
    else:
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * lax.rsqrt(var + 1e-5)
        if cfg.norm == "layernorm":
            y = y * p["w"] + p["b"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, hd), positions (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    # broadcast ang over leading dims of x; batched positions (B, S) keep
    # their batch dim aligned with x's leading axis and broadcast over the
    # head axes in between (per-slot decode positions, serving refill)
    if positions.ndim == 1:
        while ang.ndim < x.ndim:
            ang = ang[None]
    else:
        while ang.ndim < x.ndim:
            ang = ang[:, None]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(cfg: ArchConfig, key, d: int, ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d**-0.5
    scale_out = ff**-0.5
    p = {
        "w_in": (jax.random.normal(k1, (d, ff)) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (ff, d)) * scale_out).astype(dtype),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k3, (d, ff)) * scale_in).astype(dtype)
    return p


def apply_mlp(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    from repro.parallel.ctx import tp_reduce_dtype

    h = x @ p["w_in"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    dt = tp_reduce_dtype()
    if dt is not None:
        # down-proj contracts over the model-sharded d_ff: bf16 partials
        # halve the TP all-reduce payload
        return jnp.einsum("bsf,fd->bsd", h, p["w_out"], preferred_element_type=dt)
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# embedding / logits / loss
# ---------------------------------------------------------------------------


def init_embed(cfg: ArchConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["out"] = (
            jax.random.normal(k2, (cfg.vocab, cfg.d_model)) * cfg.d_model**-0.5
        ).astype(dtype)
    return p


def embed_tokens(p, tokens: jax.Array) -> jax.Array:
    return p["tok"][tokens]


def logits_matmul(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    w = p.get("out", p["tok"])
    logits = x @ w.T
    if cfg.final_softcap is not None:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


def chunked_cross_entropy(
    cfg: ArchConfig,
    p_embed,
    x: jax.Array,  # (B, S, d) final hidden states
    targets: jax.Array,  # (B, S)
    *,
    chunk: int = 512,
) -> jax.Array:
    """Cross-entropy without materializing full (B, S, V) f32 logits.

    Scans over sequence chunks; each chunk's logits live only inside the
    (remat'd) scan body — the memory-roofline lever for 100k-256k vocabs.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n = s // chunk
    xc = x.reshape(b, n, chunk, d).swapaxes(0, 1)  # (n, B, chunk, d)
    tc = targets.reshape(b, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, xt):
        from repro.parallel.ctx import shard_act

        xi, ti = xt
        xi = shard_act(xi, "ce_in")  # head_2p5d: d over the pod (depth) axis
        logits = logits_matmul(cfg, p_embed, xi).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc))
    return total / (b * s)
