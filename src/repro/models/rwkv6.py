"""RWKV-6 "Finch" mixer (arXiv:2404.05892) — attention-free, data-dependent
per-channel decay.

Time-mix (the attention replacement), per head of size hd:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (state S (hd, hd))
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

where r, k, v, g are projections of token-shift-interpolated inputs, the
decay w_t = exp(-exp(wd_t)) is *data dependent* (LoRA on the shifted input —
Finch's contribution over Eagle), and u is the per-channel "bonus" for the
current token.  Channel-mix is the squared-relu token-shift MLP.

Scan strategy mirrors mamba.py: outer lax.scan over sequence chunks carrying
(token-shift tail, per-head state), inner step-scan within the chunk (the
state update is a rank-1 non-diagonal recurrence, so the associative-scan
trick does not apply; the chunk keeps live memory bounded).  Decode carries
(last token, state) — O(1) per token, which is why rwkv6 runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig, RWKVConfig


def rwkv_dims(cfg: ArchConfig) -> tuple[int, int, int]:
    r = cfg.rwkv or RWKVConfig()
    assert cfg.d_model % r.head_dim == 0
    return cfg.d_model // r.head_dim, r.head_dim, r.decay_lora


def init_rwkv(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    h, hd, lora = rwkv_dims(cfg)
    ks = jax.random.split(key, 12)
    s = d**-0.5
    mk = lambda i, shape, sc=s: (jax.random.normal(ks[i], shape) * sc).astype(dtype)
    return {
        # token-shift interpolation factors (static part; x-dependent LoRA)
        "mu_rkvg": jnp.full((4, d), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "wr": mk(0, (d, d)),
        "wk": mk(1, (d, d)),
        "wv": mk(2, (d, d)),
        "wg": mk(3, (d, d)),
        "wo": mk(4, (d, d)),
        # data-dependent decay LoRA: wd_t = base + tanh(x W1) W2
        "decay_base": jnp.full((d,), -2.0, jnp.float32),
        "decay_w1": mk(5, (d, lora)),
        "decay_w2": (jax.random.normal(ks[6], (lora, d)) * lora**-0.5).astype(dtype),
        "bonus_u": jnp.zeros((h, hd), jnp.float32),
        "ln_x_w": jnp.ones((d,), jnp.float32),  # per-head group norm gain
        # channel mix
        "mu_c": jnp.full((2, d), 0.5, jnp.float32),
        "ck": mk(7, (d, cfg.d_ff)),
        "cv": (jax.random.normal(ks[8], (cfg.d_ff, d)) * cfg.d_ff**-0.5).astype(dtype),
        "cr": mk(9, (d, d)),
    }


def init_rwkv_state(cfg: ArchConfig, batch: int, dtype):
    h, hd, _ = rwkv_dims(cfg)
    return {
        "shift_t": jnp.zeros((batch, cfg.d_model), dtype),  # time-mix tail
        "shift_c": jnp.zeros((batch, cfg.d_model), dtype),  # channel-mix tail
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }


def _group_norm(x: jax.Array, h: int, hd: int, gain) -> jax.Array:
    """Per-head LayerNorm of the time-mix output (RWKV's ln_x)."""
    xs = x.reshape(x.shape[:-1] + (h, hd)).astype(jnp.float32)
    mu = jnp.mean(xs, -1, keepdims=True)
    var = jnp.var(xs, -1, keepdims=True)
    y = (xs - mu) * lax.rsqrt(var + 1e-5)
    return (y.reshape(x.shape) * gain).astype(x.dtype)


def _time_mix_projections(cfg: ArchConfig, p, x: jax.Array, x_prev: jax.Array):
    """Shifted interpolation + r/k/v/g/decay projections.

    x, x_prev: (..., S, d) current tokens and previous-token values.
    """
    h, hd, _ = rwkv_dims(cfg)
    mu = p["mu_rkvg"]  # (4, d)
    xr = x + (x_prev - x) * mu[0].astype(x.dtype)
    xk = x + (x_prev - x) * mu[1].astype(x.dtype)
    xv = x + (x_prev - x) * mu[2].astype(x.dtype)
    xg = x + (x_prev - x) * mu[3].astype(x.dtype)
    xw = x + (x_prev - x) * p["mu_w"].astype(x.dtype)

    shp = x.shape[:-1] + (h, hd)
    r = (xr @ p["wr"]).reshape(shp)
    k = (xk @ p["wk"]).reshape(shp)
    v = (xv @ p["wv"]).reshape(shp)
    g = jax.nn.silu(xg @ p["wg"])
    wd = p["decay_base"] + (
        jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wd.reshape(shp).astype(jnp.float32)))  # decay in (0,1)
    return r, k, v, g, w


def _wkv_step(s, rkvw):
    """One-token state update. s (B,h,hd,hd); r/k/v (B,h,hd); w (B,h,hd)."""
    r, k, v, w, u = rkvw
    kv = k[..., :, None] * v[..., None, :]  # (B,h,hd,hd) outer product
    out = jnp.einsum("bhi,bhij->bhj", r, s + u[None, :, :, None] * kv)
    s_new = w[..., :, None] * s + kv
    return s_new, out


def apply_rwkv_time_mix(cfg: ArchConfig, p, x: jax.Array, state):
    """Time mix over a sequence. x (B, S, d) -> (y, new state)."""
    r_cfg = cfg.rwkv or RWKVConfig()
    h, hd, _ = rwkv_dims(cfg)
    b, s, d = x.shape
    chunk = min(r_cfg.chunk, s)
    assert s % chunk == 0
    nchunks = s // chunk

    x_prev = jnp.concatenate([state["shift_t"][:, None].astype(x.dtype), x[:, :-1]], 1)
    r, k, v, g, w = _time_mix_projections(cfg, p, x, x_prev)
    kf = k.astype(jnp.float32) * hd**-0.5
    rf = r.astype(jnp.float32) * hd**-0.5
    vf = v.astype(jnp.float32)
    u = p["bonus_u"]

    def to_chunks(t):  # (B, S, ...) -> (nchunks, chunk, B, ...)
        return t.reshape((b, nchunks, chunk) + t.shape[2:]).swapaxes(0, 1).swapaxes(1, 2)

    rc, kc, vc, wc = map(to_chunks, (rf, kf, vf, w))

    def chunk_step(s0, inputs):
        rc_i, kc_i, vc_i, wc_i = inputs

        def tok(s_, t):
            return _wkv_step(s_, (rc_i[t], kc_i[t], vc_i[t], wc_i[t], u))

        s1, outs = lax.scan(tok, s0, jnp.arange(rc_i.shape[0]))
        return s1, outs

    s_final, ys = lax.scan(chunk_step, state["wkv"], (rc, kc, vc, wc))
    # ys (nchunks, chunk, B, h, hd) -> (B, S, d)
    y = ys.transpose(2, 0, 1, 3, 4).reshape(b, s, d)
    y = _group_norm(y.astype(x.dtype), h, hd, p["ln_x_w"]) * g
    out = y @ p["wo"]
    new_state = dict(state, shift_t=x[:, -1], wkv=s_final)
    return out, new_state


def apply_rwkv_channel_mix(cfg: ArchConfig, p, x: jax.Array, state):
    """Squared-relu channel mix with token shift. x (B, S, d)."""
    x_prev = jnp.concatenate([state["shift_c"][:, None].astype(x.dtype), x[:, :-1]], 1)
    mu = p["mu_c"]
    xk = x + (x_prev - x) * mu[0].astype(x.dtype)
    xr = x + (x_prev - x) * mu[1].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    y = jax.nn.sigmoid(xr @ p["cr"]) * (kk @ p["cv"])
    return y, dict(state, shift_c=x[:, -1])


def decode_rwkv_time_mix(cfg: ArchConfig, p, x: jax.Array, state):
    """Single-token time mix: x (B, 1, d)."""
    h, hd, _ = rwkv_dims(cfg)
    xt = x[:, 0]
    x_prev = state["shift_t"].astype(x.dtype)
    r, k, v, g, w = _time_mix_projections(cfg, p, xt, x_prev)
    s_new, out = _wkv_step(
        state["wkv"],
        (
            r.astype(jnp.float32) * hd**-0.5,
            k.astype(jnp.float32) * hd**-0.5,
            v.astype(jnp.float32),
            w,
            p["bonus_u"],
        ),
    )
    y = out.reshape(xt.shape[0], -1)
    y = _group_norm(y.astype(x.dtype), h, hd, p["ln_x_w"]) * g
    out = (y @ p["wo"])[:, None]
    return out, dict(state, shift_t=xt, wkv=s_new)


def decode_rwkv_channel_mix(cfg: ArchConfig, p, x: jax.Array, state):
    xt = x[:, 0]
    x_prev = state["shift_c"].astype(x.dtype)
    mu = p["mu_c"]
    xk = xt + (x_prev - xt) * mu[0].astype(x.dtype)
    xr = xt + (x_prev - xt) * mu[1].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    y = jax.nn.sigmoid(xr @ p["cr"]) * (kk @ p["cv"])
    return y[:, None], dict(state, shift_c=xt)
