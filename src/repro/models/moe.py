"""Mixture-of-Experts layer (llama4 / deepseek-moe / jamba).

Three dispatch implementations, selectable via ``MoEConfig.impl``:

* ``dense``   — every expert processes every token, gated combine.  Exact
  (no capacity drops); FLOPs scale with n_experts, so it is the smoke-test
  and oracle path, scanned over experts to bound memory.
* ``tp``      — capacity-based scatter dispatch local to each data shard;
  expert weights sharded over ``model`` on the d_expert dim (tensor
  parallel within every expert).  No token all-to-all at all — the design
  point that mirrors the paper's "retain the 2D data layout, never
  redistribute" argument (DESIGN.md §4).
* ``ep``      — expert parallelism: the dispatched buffer is resharded so
  experts live on ``model`` shards (GSPMD inserts the all-to-all); each
  device runs only its resident experts with *unsharded* per-expert
  weights.  The hillclimb comparison point.

The capacity dispatch is scatter/gather based (never materializes the
(tokens, experts, capacity) one-hot): tokens get (expert, slot) coordinates
from a capped cumulative count, are scattered into an (experts, capacity,
d_model) buffer, and gathered back with their router weights after the
batched expert matmuls.  Buffer size is top_k * capacity_factor * input —
the memory the technique inherently trades.

The (token-block x expert) structure is block-sparse: the paper's SpGEMM
view of MoE is benchmarked in benchmarks/moe_spgemm.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig, MoEConfig


def moe_dims(cfg: ArchConfig) -> tuple[int, int]:
    """(n_experts, d_expert) resolved against the arch."""
    moe = cfg.moe
    assert moe is not None
    return moe.n_experts, moe.d_expert or cfg.d_ff


def init_moe(cfg: ArchConfig, key, dtype):
    """Router + routed expert bank + optional shared experts."""
    moe = cfg.moe
    d = cfg.d_model
    e, de = moe_dims(cfg)
    ks = jax.random.split(key, 8)
    s_in, s_out = d**-0.5, de**-0.5
    glu = cfg.mlp in ("swiglu", "geglu")
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w_in": (jax.random.normal(ks[1], (e, d, de)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (e, de, d)) * s_out).astype(dtype),
    }
    if glu:
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, de)) * s_in).astype(dtype)
    if moe.n_shared:
        ds = de * moe.n_shared  # fused shared experts (deepseek: 2 shared)
        p["shared_in"] = (jax.random.normal(ks[4], (d, ds)) * s_in).astype(dtype)
        p["shared_out"] = (jax.random.normal(ks[5], (ds, d)) * de**-0.5).astype(dtype)
        if glu:
            p["shared_gate"] = (jax.random.normal(ks[6], (d, ds)) * s_in).astype(dtype)
    return p


def _expert_ffn(cfg: ArchConfig, p, xb: jax.Array) -> jax.Array:
    """Batched per-expert FFN: xb (..., E, C, d) -> (..., E, C, d)."""
    from repro.parallel.ctx import tp_reduce_dtype

    h = jnp.einsum("...ecd,edf->...ecf", xb, p["w_in"])
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", xb, p["w_gate"])) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(jnp.einsum("...ecd,edf->...ecf", xb, p["w_gate"])) * h
    else:
        h = jax.nn.gelu(h)
    dt = tp_reduce_dtype()
    kw = {"preferred_element_type": dt} if dt is not None else {}
    return jnp.einsum("...ecf,efd->...ecd", h, p["w_out"], **kw)


def _one_expert_ffn(cfg: ArchConfig, p_e, x: jax.Array) -> jax.Array:
    """Single expert on all tokens: x (..., d), p_e un-stacked weights."""
    h = x @ p_e["w_in"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p_e["w_gate"]) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p_e["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p_e["w_out"]


def _shared_ffn(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    h = x @ p["shared_in"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["shared_gate"]) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["shared_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["shared_out"]


def router_probs(moe: MoEConfig, logits32: jax.Array):
    """Top-k routing: returns (weights (..., k), expert ids (..., k), probs)."""
    probs = jax.nn.softmax(logits32, axis=-1)
    top_w, top_e = lax.top_k(probs, moe.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    return top_w, top_e, probs


def load_balance_loss(probs: jax.Array, top_e: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e (1.0 == balanced)."""
    pe = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    counts = jnp.zeros((n_experts,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    fe = counts / jnp.maximum(jnp.sum(counts), 1.0)
    return n_experts * jnp.sum(fe * pe)


# ---------------------------------------------------------------------------
# dispatch paths
# ---------------------------------------------------------------------------


def _apply_dense(cfg: ArchConfig, p, x: jax.Array, top_w, top_e):
    """Scan over experts; every expert sees every token (exact, no drops)."""
    e, _ = moe_dims(cfg)

    def body(acc, ep):
        eid, pe = ep
        y = _one_expert_ffn(cfg, pe, x)  # (..., d)
        w = jnp.sum(jnp.where(top_e == eid, top_w, 0.0), axis=-1)  # (...,)
        return acc + y * w[..., None].astype(y.dtype), None

    stacked = {k_: v for k_, v in p.items() if k_.startswith("w_")}
    acc0 = jnp.zeros_like(x)
    acc, _ = lax.scan(body, acc0, (jnp.arange(e), stacked))
    return acc


def _dispatch_indices(top_e: jax.Array, n_experts: int, capacity: int):
    """(T, K) expert ids -> (slot positions (T, K), keep mask (T, K)).

    Slot p of token t in expert e = number of earlier (t', k') choices of e,
    capped at capacity (Switch dispatch without the (T, E, C) one-hot).
    """
    t, k = top_e.shape
    flat = top_e.reshape(-1)  # (T*K,) in (t-major, k-minor) priority order
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    slot = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    keep = slot < capacity
    return slot.reshape(t, k), keep.reshape(t, k)


def _apply_capacity(cfg: ArchConfig, p, x: jax.Array, top_w, top_e, *, ep: bool):
    """Capacity scatter dispatch. x (B, S, d); B is the data-sharded dim."""
    moe = cfg.moe
    e, _ = moe_dims(cfg)
    b, s, d = x.shape
    k = moe.top_k
    capacity = max(int(s * k * moe.capacity_factor / e), 1)

    def row(xr, er, wr):  # (S, d), (S, K), (S, K): one batch row
        slot, keep = _dispatch_indices(er, e, capacity)
        wr = wr * keep.astype(wr.dtype)
        # scatter tokens into the (E, C, d) buffer (dropped -> clipped slot,
        # masked out of the combine by `keep`; slot C-1 collisions are
        # overwritten, which is safe because their gather weight is zero)
        buf = jnp.zeros((e, capacity, d), x.dtype)
        es = er.reshape(-1)
        ss = jnp.clip(slot.reshape(-1), 0, capacity - 1)
        xe = jnp.repeat(xr, k, axis=0)  # (S*K, d) token copies per choice
        msk = keep.reshape(-1, 1).astype(x.dtype)
        buf = buf.at[es, ss].add(xe * msk, mode="drop")
        return buf, slot, keep

    buf, slot, keep = jax.vmap(row)(x, top_e, top_w)  # (B, E, C, d)
    if ep:
        # reshard: experts -> model shards (GSPMD all-to-all), tokens stay.
        # named rule (NamedSharding) so it works under jit without a mesh
        # context; no-op when no rule set is active (single-device tests)
        from repro.parallel.ctx import shard_act

        buf = shard_act(buf, "moe_dispatch")
    yb = _expert_ffn(cfg, p, buf)  # (B, E, C, d)
    if ep:
        from repro.parallel.ctx import shard_act

        yb = shard_act(yb, "moe_combine")

    def combine(ybr, er, sr, kr, wr):  # (E, C, d), (S,K), (S,K), (S,K), (S,K)
        sr = jnp.clip(sr, 0, capacity - 1)
        y = ybr[er, sr]  # (S, K, d)
        w = (wr * kr.astype(wr.dtype)).astype(y.dtype)
        return jnp.sum(y * w[..., None], axis=1)

    return jax.vmap(combine)(yb, top_e, slot, keep, top_w)


def apply_moe(cfg: ArchConfig, p, x: jax.Array):
    """x (B, S, d) -> (y (B, S, d), aux load-balance loss)."""
    moe = cfg.moe
    e, _ = moe_dims(cfg)
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    top_w, top_e, probs = router_probs(moe, logits)
    aux = load_balance_loss(probs, top_e, e)
    top_w = top_w.astype(x.dtype)

    if moe.impl == "dense":
        y = _apply_dense(cfg, p, x, top_w, top_e)
    elif moe.impl in ("tp", "ep"):
        y = _apply_capacity(cfg, p, x, top_w, top_e, ep=(moe.impl == "ep"))
    else:
        raise ValueError(f"unknown moe impl {moe.impl!r}")

    if moe.n_shared:
        y = y + _shared_ffn(cfg, p, x)
    return y, aux
