"""Mixture-of-Experts layer (llama4 / deepseek-moe / jamba).

Three dispatch implementations, selectable via ``MoEConfig.impl``:

* ``dense``   — every expert processes every token, gated combine.  Exact
  (no capacity drops); FLOPs scale with n_experts, so it is the smoke-test
  and oracle path, scanned over experts to bound memory.
* ``tp``      — capacity-based scatter dispatch local to each data shard;
  expert weights sharded over ``model`` on the d_expert dim (tensor
  parallel within every expert).  No token all-to-all at all — the design
  point that mirrors the paper's "retain the 2D data layout, never
  redistribute" argument (DESIGN.md §4).
* ``ep``      — expert parallelism: the dispatched buffer is resharded so
  experts live on ``model`` shards (GSPMD inserts the all-to-all); each
  device runs only its resident experts with *unsharded* per-expert
  weights.  The hillclimb comparison point.

The capacity dispatch is scatter/gather based (never materializes the
(tokens, experts, capacity) one-hot): tokens get (expert, slot) coordinates
from a capped cumulative count, are scattered into an (experts, capacity,
d_model) buffer, and gathered back with their router weights after the
batched expert matmuls.  Buffer size is top_k * capacity_factor * input —
the memory the technique inherently trades.

The (token-block x expert) structure is block-sparse: the paper's SpGEMM
view of MoE is benchmarked in benchmarks/moe_spgemm.py, and the fourth
implementation executes it:

* ``spgemm``  — the serving path (DESIGN.md §11).  The per-batch routing
  decision becomes a (token-block x expert) dispatch BSM and the expert
  matmuls run through ``core.engine.multiply`` against block-diagonal
  expert weight banks, so the serving hot loop exercises the same
  compacted stacks / envelope / tuner machinery as the scientific
  workloads.  Under a :class:`DispatchSpec` (installed by the serving
  engine via :func:`dispatch_scope`) the multiplies reuse a warmed
  pattern envelope: one compiled program across a drifting request
  stream, zero per-batch pattern walks.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config import ArchConfig, MoEConfig


def moe_dims(cfg: ArchConfig) -> tuple[int, int]:
    """(n_experts, d_expert) resolved against the arch."""
    moe = cfg.moe
    assert moe is not None
    return moe.n_experts, moe.d_expert or cfg.d_ff


def init_moe(cfg: ArchConfig, key, dtype):
    """Router + routed expert bank + optional shared experts."""
    moe = cfg.moe
    d = cfg.d_model
    e, de = moe_dims(cfg)
    ks = jax.random.split(key, 8)
    s_in, s_out = d**-0.5, de**-0.5
    glu = cfg.mlp in ("swiglu", "geglu")
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w_in": (jax.random.normal(ks[1], (e, d, de)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (e, de, d)) * s_out).astype(dtype),
    }
    if glu:
        p["w_gate"] = (jax.random.normal(ks[3], (e, d, de)) * s_in).astype(dtype)
    if moe.n_shared:
        ds = de * moe.n_shared  # fused shared experts (deepseek: 2 shared)
        p["shared_in"] = (jax.random.normal(ks[4], (d, ds)) * s_in).astype(dtype)
        p["shared_out"] = (jax.random.normal(ks[5], (ds, d)) * de**-0.5).astype(dtype)
        if glu:
            p["shared_gate"] = (jax.random.normal(ks[6], (d, ds)) * s_in).astype(dtype)
    return p


def _expert_ffn(cfg: ArchConfig, p, xb: jax.Array) -> jax.Array:
    """Batched per-expert FFN: xb (..., E, C, d) -> (..., E, C, d)."""
    from repro.parallel.ctx import tp_reduce_dtype

    h = jnp.einsum("...ecd,edf->...ecf", xb, p["w_in"])
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", xb, p["w_gate"])) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(jnp.einsum("...ecd,edf->...ecf", xb, p["w_gate"])) * h
    else:
        h = jax.nn.gelu(h)
    dt = tp_reduce_dtype()
    kw = {"preferred_element_type": dt} if dt is not None else {}
    return jnp.einsum("...ecf,efd->...ecd", h, p["w_out"], **kw)


def _one_expert_ffn(cfg: ArchConfig, p_e, x: jax.Array) -> jax.Array:
    """Single expert on all tokens: x (..., d), p_e un-stacked weights."""
    h = x @ p_e["w_in"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p_e["w_gate"]) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p_e["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p_e["w_out"]


def _shared_ffn(cfg: ArchConfig, p, x: jax.Array) -> jax.Array:
    h = x @ p["shared_in"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["shared_gate"]) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["shared_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["shared_out"]


def router_probs(moe: MoEConfig, logits32: jax.Array):
    """Top-k routing: returns (weights (..., k), expert ids (..., k), probs)."""
    probs = jax.nn.softmax(logits32, axis=-1)
    top_w, top_e = lax.top_k(probs, moe.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    return top_w, top_e, probs


def load_balance_loss(probs: jax.Array, top_e: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e (1.0 == balanced)."""
    pe = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    counts = jnp.zeros((n_experts,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    fe = counts / jnp.maximum(jnp.sum(counts), 1.0)
    return n_experts * jnp.sum(fe * pe)


# ---------------------------------------------------------------------------
# serving dispatch scope (models <-> serving glue, DESIGN.md §11)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DispatchSpec:
    """A serving-resolved dispatch decision for the ``spgemm`` impl.

    Installed around tracing with :func:`dispatch_scope`; everything here
    is a trace-time static, so the serving engine keys its compiled
    programs by ``envelope.signature`` — envelope capacities join the jit
    key.  ``envelope`` only applies when its ``mask_a`` shape matches the
    (nb_tok, E) dispatch grid of the call (prefill and decode see
    different nb_tok); non-matching calls take the structural-bound cold
    path.  A covering envelope clips nothing, so the spgemm impl stays
    bit-close to the dense oracle; routed choices outside the envelope
    are dropped and counted (the serving analogue of capacity drops).
    """

    envelope: object | None = None  # core.envelope.Envelope
    backend: str | None = None  # None -> "stacks"
    stack_capacity: int | None = None  # None -> envelope/structural bound


_DISPATCH_SPEC: DispatchSpec | None = None


@contextlib.contextmanager
def dispatch_scope(spec: DispatchSpec | None):
    """Install ``spec`` as the ambient dispatch decision while tracing."""
    global _DISPATCH_SPEC
    prev = _DISPATCH_SPEC
    _DISPATCH_SPEC = spec
    try:
        yield spec
    finally:
        _DISPATCH_SPEC = prev


def current_dispatch_spec() -> DispatchSpec | None:
    return _DISPATCH_SPEC


def dispatch_block_mask(top_e: jax.Array, n_experts: int, token_block: int,
                        valid: jax.Array | None = None) -> jax.Array:
    """(T, K) routed expert ids -> (T // token_block, E) bool dispatch mask.

    Block (i, e) is occupied iff any (valid) token in block i routed one
    of its K choices to expert e — the block-sparse operand structure of
    the SpGEMM view of MoE (benchmarks/moe_spgemm.py builds its occupancy
    sweeps from this same function).  Traceable: works on traced ids
    inside the serving decode program as well as on concrete host arrays.
    """
    t, k = top_e.shape
    if t % token_block:
        raise ValueError(
            f"token count {t} not divisible by token_block {token_block}"
        )
    nb = t // token_block
    oh = jax.nn.one_hot(top_e.reshape(nb, token_block * k), n_experts,
                        dtype=jnp.float32)  # (nb, tb*K, E)
    if valid is not None:
        v = jnp.repeat(valid.astype(jnp.float32), k).reshape(
            nb, token_block * k)
        oh = oh * v[..., None]
    return jnp.max(oh, axis=1) > 0.5


# ---------------------------------------------------------------------------
# dispatch paths
# ---------------------------------------------------------------------------


def _apply_dense(cfg: ArchConfig, p, x: jax.Array, top_w, top_e):
    """Scan over experts; every expert sees every token (exact, no drops)."""
    e, _ = moe_dims(cfg)

    def body(acc, ep):
        eid, pe = ep
        y = _one_expert_ffn(cfg, pe, x)  # (..., d)
        w = jnp.sum(jnp.where(top_e == eid, top_w, 0.0), axis=-1)  # (...,)
        return acc + y * w[..., None].astype(y.dtype), None

    stacked = {k_: v for k_, v in p.items() if k_.startswith("w_")}
    acc0 = jnp.zeros_like(x)
    acc, _ = lax.scan(body, acc0, (jnp.arange(e), stacked))
    return acc


def _dispatch_indices(top_e: jax.Array, n_experts: int, capacity: int):
    """(T, K) expert ids -> (slot positions (T, K), keep mask (T, K)).

    Slot p of token t in expert e = number of earlier (t', k') choices of e,
    capped at capacity (Switch dispatch without the (T, E, C) one-hot).
    """
    t, k = top_e.shape
    flat = top_e.reshape(-1)  # (T*K,) in (t-major, k-minor) priority order
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    slot = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    keep = slot < capacity
    return slot.reshape(t, k), keep.reshape(t, k)


def _apply_capacity(cfg: ArchConfig, p, x: jax.Array, top_w, top_e, *, ep: bool):
    """Capacity scatter dispatch. x (B, S, d); B is the data-sharded dim."""
    moe = cfg.moe
    e, _ = moe_dims(cfg)
    b, s, d = x.shape
    k = moe.top_k
    capacity = max(int(s * k * moe.capacity_factor / e), 1)

    def row(xr, er, wr):  # (S, d), (S, K), (S, K): one batch row
        slot, keep = _dispatch_indices(er, e, capacity)
        wr = wr * keep.astype(wr.dtype)
        # scatter tokens into the (E, C, d) buffer (dropped -> clipped slot,
        # masked out of the combine by `keep`; slot C-1 collisions are
        # overwritten, which is safe because their gather weight is zero)
        buf = jnp.zeros((e, capacity, d), x.dtype)
        es = er.reshape(-1)
        ss = jnp.clip(slot.reshape(-1), 0, capacity - 1)
        xe = jnp.repeat(xr, k, axis=0)  # (S*K, d) token copies per choice
        msk = keep.reshape(-1, 1).astype(x.dtype)
        buf = buf.at[es, ss].add(xe * msk, mode="drop")
        return buf, slot, keep

    buf, slot, keep = jax.vmap(row)(x, top_e, top_w)  # (B, E, C, d)
    if ep:
        # reshard: experts -> model shards (GSPMD all-to-all), tokens stay.
        # named rule (NamedSharding) so it works under jit without a mesh
        # context; no-op when no rule set is active (single-device tests)
        from repro.parallel.ctx import shard_act

        buf = shard_act(buf, "moe_dispatch")
    yb = _expert_ffn(cfg, p, buf)  # (B, E, C, d)
    if ep:
        from repro.parallel.ctx import shard_act

        yb = shard_act(yb, "moe_combine")

    def combine(ybr, er, sr, kr, wr):  # (E, C, d), (S,K), (S,K), (S,K), (S,K)
        sr = jnp.clip(sr, 0, capacity - 1)
        y = ybr[er, sr]  # (S, K, d)
        w = (wr * kr.astype(wr.dtype)).astype(y.dtype)
        return jnp.sum(y * w[..., None], axis=1)

    dropped = jnp.sum(1 - keep.astype(jnp.int32))
    return jax.vmap(combine)(yb, top_e, slot, keep, top_w), dropped


def _diag_expert_bsm(w: jax.Array):
    """(E, din, dout) expert bank -> (E, E) block-diagonal BSM.

    Diagonal B means every occupied dispatch block contributes exactly one
    product, so the multiply's product count equals nnz(dispatch mask).
    """
    from repro.core import bsm as B

    e = w.shape[0]
    blocks = jnp.zeros((e, e) + w.shape[1:], w.dtype)
    blocks = blocks.at[jnp.arange(e), jnp.arange(e)].set(w)
    return B.make_bsm(blocks, jnp.eye(e, dtype=bool))


def _apply_spgemm(cfg: ArchConfig, p, x: jax.Array, top_w, top_e):
    """Expert dispatch as block-sparse SpGEMM through ``engine.multiply``.

    Tokens are grouped into blocks of ``moe.token_block``; the routing
    decision becomes an (nb_tok, E) dispatch BSM A whose occupied blocks
    replicate the token block across its routed expert columns, and the
    three expert matmuls (in / gate / out) run A against block-diagonal
    weight banks.  The combine gathers each token's K expert outputs back
    with the router weights, so the result matches the dense oracle
    exactly (no capacity drops) whenever the ambient envelope covers the
    pattern — the bit-closeness the serving bench gates on.
    """
    from repro.core import bsm as B
    from repro.core import engine as core_engine
    from repro.kernels.stacks import bucket_capacity

    moe = cfg.moe
    e, de = moe_dims(cfg)
    b, s, d = x.shape
    k = moe.top_k
    tpb = moe.token_block
    t = b * s
    xt = x.reshape(t, d)
    te = top_e.reshape(t, k)
    tw = top_w.reshape(t, k)
    pad = (-t) % tpb
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
        te = jnp.pad(te, ((0, pad), (0, 0)))
        tw = jnp.pad(tw, ((0, pad), (0, 0)))
    tt = t + pad
    nb = tt // tpb
    valid = jnp.arange(tt) < t
    mask = dispatch_block_mask(te, e, tpb, valid=valid)  # (nb, E)

    spec = current_dispatch_spec()
    env = spec.envelope if spec is not None else None
    if env is not None and tuple(np.asarray(env.mask_a).shape) != (nb, e):
        env = None  # prefill vs decode grid mismatch: structural fallback
    keep = jnp.ones((tt, k), bool)
    if env is not None:
        # clip the dispatch to the envelope so the warmed capacity is
        # sound under tracing (compact_pair_mask silently drops excess
        # products); clipped routed choices are the serving drop stat
        clip = jnp.asarray(np.asarray(env.mask_a, bool))
        mask = mask & clip
        blk = jnp.arange(tt) // tpb
        keep = clip[blk[:, None], te]
    dropped = jnp.sum((valid[:, None] & ~keep).astype(jnp.int32))

    backend = (spec.backend if spec is not None and spec.backend
               else "stacks")
    cap = spec.stack_capacity if spec is not None else None
    if cap is None and env is None:
        # structural bound: every block row occupies at most min(tb*K, E)
        # expert columns, diagonal B gives one product per occupied block
        cap = bucket_capacity(nb * min(tpb * k, e))

    a_blocks = jnp.broadcast_to(
        xt.reshape(nb, tpb, d)[:, None], (nb, e, tpb, d))
    A = B.make_bsm(a_blocks, mask)

    def mult(a_bsm, w_bank):
        return core_engine.multiply(
            a_bsm, _diag_expert_bsm(w_bank), backend=backend,
            stack_capacity=cap, envelope=env)

    h = mult(A, p["w_in"])  # (nb, E) blocks of (tb, de)
    if cfg.mlp == "swiglu":
        g = mult(A, p["w_gate"])
        hb = jax.nn.silu(g.blocks) * h.blocks
    elif cfg.mlp == "geglu":
        g = mult(A, p["w_gate"])
        hb = jax.nn.gelu(g.blocks) * h.blocks
    else:
        hb = jax.nn.gelu(h.blocks)
    # act(0) = 0 for gelu/silu, so masked blocks stay zero; make_bsm
    # re-zeroes and refreshes norms to keep the BSM consistent anyway
    out = mult(B.make_bsm(hb, h.mask), p["w_out"])  # (nb, E) x (tb, d)

    yt = out.blocks.transpose(0, 2, 1, 3).reshape(tt, e, d)
    y = yt[jnp.arange(tt)[:, None], te]  # (tt, K, d)
    w = (tw * keep.astype(tw.dtype)).astype(y.dtype)
    y = jnp.sum(y * w[..., None], axis=1)[:t]
    return y.reshape(b, s, d), dropped


def apply_moe(cfg: ArchConfig, p, x: jax.Array, *, collect_stats: bool = False):
    """x (B, S, d) -> (y (B, S, d), aux load-balance loss).

    With ``collect_stats=True`` returns ``(y, aux, stats)`` where stats
    carries ``dropped`` (routed (token, choice) pairs lost to capacity /
    envelope clipping; always 0 for the dense oracle) and ``routed``
    (total routed pairs) — the drop-rate the serving bench reports
    against ``capacity_factor``.
    """
    moe = cfg.moe
    e, _ = moe_dims(cfg)
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    top_w, top_e, probs = router_probs(moe, logits)
    aux = load_balance_loss(probs, top_e, e)
    top_w = top_w.astype(x.dtype)

    dropped = jnp.zeros((), jnp.int32)
    if moe.impl == "dense":
        y = _apply_dense(cfg, p, x, top_w, top_e)
    elif moe.impl in ("tp", "ep"):
        y, dropped = _apply_capacity(cfg, p, x, top_w, top_e,
                                     ep=(moe.impl == "ep"))
    elif moe.impl == "spgemm":
        y, dropped = _apply_spgemm(cfg, p, x, top_w, top_e)
    else:
        raise ValueError(f"unknown moe impl {moe.impl!r}")

    if moe.n_shared:
        y = y + _shared_ffn(cfg, p, x)
    if collect_stats:
        stats = {"dropped": dropped,
                 "routed": jnp.asarray(top_e.size, jnp.int32)}
        return y, aux, stats
    return y, aux
