"""Mamba (S6 selective state space) mixer — the jamba hybrid's workhorse.

Faithful S6 structure (Gu & Dao 2023, as configured by jamba-v0.1):
  in_proj (d -> 2*di), depthwise causal conv (d_conv), x_proj (di -> dt_rank
  + 2*d_state), dt_proj (dt_rank -> di), diagonal selective recurrence
  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t, y_t = C_t h_t + D x_t, gated by
  silu(z), out_proj (di -> d).

Scan strategy (TPU adaptation): the recurrence is chunked — an outer
``lax.scan`` over sequence chunks carries the (di, d_state) state, an inner
``associative_scan`` parallelizes within the chunk.  Memory is
O(chunk * di * d_state) instead of O(seq * di * d_state); the chunk size is
the remat/VMEM lever (hillclimb knob).  Decode is the O(1) single-token
recurrence on the carried state — the reason jamba runs the long_500k cell.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig, MambaConfig


def mamba_dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    m = cfg.mamba or MambaConfig()
    di = m.expand * cfg.d_model
    dt_rank = max(cfg.d_model // 16, 1)
    return di, m.d_state, m.d_conv, dt_rank


def init_mamba(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    di, n, dc, dtr = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    s = d**-0.5
    # S4D-real initialization of A (negative reals), dt bias for softplus
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    dt = jnp.exp(
        jax.random.uniform(ks[0], (di,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    return {
        "in_proj": (jax.random.normal(ks[1], (d, 2 * di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (dc, di)) * dc**-0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(ks[3], (di, dtr + 2 * n)) * di**-0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[4], (dtr, di)) * dtr**-0.5).astype(dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "a_log": jnp.log(a_init),  # (di, n) f32; A = -exp(a_log)
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di**-0.5).astype(dtype),
    }


def init_mamba_state(cfg: ArchConfig, batch: int, dtype):
    """(conv tail (B, d_conv-1, di), ssm state (B, di, n)) — f32 ssm state."""
    di, n, dc, _ = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, n), jnp.float32),
    }


def _ssm_coeffs(cfg: ArchConfig, p, xc: jax.Array):
    """Per-token SSM coefficients from the conv output xc (..., di).

    Returns (da (..., di, n) decay, db (..., di, n) input matrix, c (..., n)).
    """
    di, n, _, dtr = mamba_dims(cfg)
    proj = xc @ p["x_proj"]  # (..., dtr + 2n)
    dt_r, b, c = jnp.split(proj.astype(jnp.float32), [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])  # (di, n)
    da = jnp.exp(dt[..., None] * a)  # (..., di, n)
    db = dt[..., None] * b[..., None, :]  # (..., di, n)
    return da, db, c


def _chunk_scan(da, dbx, h0):
    """Within-chunk associative scan of h_t = da_t h_{t-1} + dbx_t.

    da/dbx: (T, B, di, n); h0: (B, di, n).  Returns (h (T,B,di,n), h_T)."""
    a, b = lax.associative_scan(
        lambda l, r: (l[0] * r[0], l[1] * r[0] + r[1]), (da, dbx), axis=0
    )
    h = a * h0[None] + b
    return h, h[-1]


def apply_mamba(cfg: ArchConfig, p, x: jax.Array, state=None):
    """x (B, S, d) -> (y (B, S, d), final state).  Chunked selective scan."""
    m = cfg.mamba or MambaConfig()
    di, n, dc, _ = mamba_dims(cfg)
    b, s, d = x.shape
    chunk = min(m.chunk, s)
    assert s % chunk == 0, (s, chunk)
    nchunks = s // chunk

    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each

    if state is None:
        state = init_mamba_state(cfg, b, x.dtype)

    # depthwise causal conv over the sequence, seeded by the carried tail
    xpad = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)
    conv = p["conv_b"].astype(jnp.float32) + sum(
        xpad[:, i : i + s].astype(jnp.float32)
        * p["conv_w"][i].astype(jnp.float32)
        for i in range(dc)
    )
    xc = jax.nn.silu(conv).astype(x.dtype)  # (B, S, di)
    new_conv = xpad[:, -(dc - 1) :] if dc > 1 else state["conv"]

    da, db, c = _ssm_coeffs(cfg, p, xc)  # (B,S,di,n), (B,S,di,n), (B,S,n)
    dbx = db * xc.astype(jnp.float32)[..., None]

    # outer scan over chunks (carries h), inner associative scan
    def to_chunks(t):  # (B, S, ...) -> (nchunks, chunk, B, ...)
        return t.reshape((b, nchunks, chunk) + t.shape[2:]).swapaxes(0, 1).swapaxes(1, 2)

    da_c, dbx_c, c_c, xc_c = map(to_chunks, (da, dbx, c, xc))

    def step(h, inputs):
        da_i, dbx_i, c_i, xc_i = inputs
        h_all, h_next = _chunk_scan(da_i, dbx_i, h)
        y = jnp.einsum("tbdn,tbn->tbd", h_all, c_i)  # (chunk, B, di)
        y = y + p["d_skip"] * xc_i.astype(jnp.float32)
        return h_next, y

    h_final, ys = lax.scan(step, state["ssm"], (da_c, dbx_c, c_c, xc_c))
    # ys (nchunks, chunk, B, di) -> (B, S, di)
    y = ys.transpose(2, 0, 1, 3).reshape(b, s, di).astype(x.dtype)

    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": h_final}


def decode_mamba(cfg: ArchConfig, p, x: jax.Array, state):
    """Single-token decode: x (B, 1, d) with carried state; O(1) per token."""
    di, n, dc, _ = mamba_dims(cfg)
    xz = x[:, 0] @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, di)

    window = jnp.concatenate([state["conv"].astype(xi.dtype), xi[:, None]], axis=1)
    conv = p["conv_b"].astype(jnp.float32) + jnp.einsum(
        "btd,td->bd", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
    )
    xc = jax.nn.silu(conv).astype(x.dtype)  # (B, di)

    da, db, c = _ssm_coeffs(cfg, p, xc)
    h = state["ssm"] * da + db * xc.astype(jnp.float32)[..., None]
    y = jnp.einsum("bdn,bn->bd", h, c) + p["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv": window[:, 1:], "ssm": h}
