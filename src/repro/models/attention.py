"""Attention: GQA projections + chunked (memory-efficient) attention.

Paths:
  * train/prefill — online-softmax double scan (q chunks x kv chunks): the
    pure-JAX analogue of the flash kernel; bounded memory at any seq_len,
    compile-friendly (two nested lax.scan = O(1) HLO).  On TPU hardware the
    Pallas kernel (kernels/flash_attention.py) replaces it (use_pallas).
  * decode — single-token query against a KV cache (serving.py drives it,
    including the sequence-sharded flash-decode variant).

Features per the assigned archs: GQA (kv groups), qkv bias (qwen),
sliding window + logit softcap (gemma2), rope on/off (whisper uses
sinusoidal absolute embeddings instead).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ArchConfig
from repro.models.layers import apply_rope

NEG_INF = -1e30


def init_attention(cfg: ArchConfig, key, dtype, cross: bool = False):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    s = d**-0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, hkv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, hkv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * (h * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def qkv_proj(cfg: ArchConfig, p, x: jax.Array, positions=None):
    """x (B, S, d) -> q (B, h, S, hd), k/v (B, hkv, S, hd)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd).swapaxes(1, 2)
    k = k.reshape(b, s, hkv, hd).swapaxes(1, 2)
    v = v.reshape(b, s, hkv, hd).swapaxes(1, 2)
    if cfg.rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(cfg: ArchConfig, p, attn: jax.Array) -> jax.Array:
    from repro.parallel.ctx import tp_reduce_dtype

    b, h, s, hd = attn.shape
    x = attn.swapaxes(1, 2).reshape(b, s, h * hd)
    dt = tp_reduce_dtype()
    if dt is not None:
        # bf16 partials -> the TP all-reduce over `model` moves half the bytes
        return jnp.einsum("bsk,kd->bsd", x, p["wo"], preferred_element_type=dt)
    return x @ p["wo"]


def chunked_attention(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Online-softmax chunked attention (f32 accumulators).

    Non-divisible sequence lengths (e.g. whisper's 1500 encoder frames) are
    zero-padded up to the chunk size; padded KV positions are masked out and
    padded query rows sliced off the result.
    """
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    if scale is None:
        scale = d**-0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    sq_orig, skv_orig = sq, skv
    pad_q = (-sq) % q_chunk
    pad_kv = (-skv) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        sq += pad_q
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        skv += pad_kv
    nq, nkv = sq // q_chunk, skv // kv_chunk

    # (nq, B, Hkv, G, cq, D) — GQA grouped, no kv repetition
    qs = (
        q.reshape(b, hkv, g, nq, q_chunk, d).transpose(3, 0, 1, 2, 4, 5)
    )
    ks = k.reshape(b, hkv, nkv, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, hkv, nkv, kv_chunk, d).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx  # (B,Hkv,G,cq,D), scalar chunk index
        m0 = jnp.full((b, hkv, g, q_chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)

        def kv_step(carry, kv_and_idx):
            m, l, acc = carry
            ki, vi, ik = kv_and_idx
            s_ = jnp.einsum(
                "bhgqd,bhkd->bhgqk",
                qi.astype(jnp.float32),
                ki.astype(jnp.float32),
            ) * scale
            if softcap is not None:
                s_ = jnp.tanh(s_ / softcap) * softcap
            qpos = iq * q_chunk + q_offset + jnp.arange(q_chunk)
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            msk = jnp.broadcast_to(
                kpos[None, :] < skv_orig, (q_chunk, kv_chunk)
            )  # exclude zero-padded KV
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > qpos[:, None] - window
            s_ = jnp.where(msk[None, None, None], s_, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_, -1, keepdims=True))
            p = jnp.exp(s_ - m_new)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, -1, keepdims=True)
            acc = acc * corr + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vi.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0), (ks, vs, jnp.arange(nkv))
        )
        safe = jnp.where(l == 0.0, 1.0, l)
        return None, (acc / safe).astype(q.dtype)

    _, outs = lax.scan(q_step, None, (qs, jnp.arange(nq)))
    # outs (nq, B, Hkv, G, cq, D) -> (B, H, Sq, D)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, d)
    return out[:, :, :sq_orig] if pad_q else out


def decode_attention(
    q: jax.Array,  # (B, H, 1, D)
    k_cache: jax.Array,  # (B, Hkv, Smax, D)
    v_cache: jax.Array,
    length: jax.Array,  # scalar or (B,): number of valid cache positions
    *,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Single-step decode attention over a (masked) KV cache."""
    b, h, _, d = q.shape
    hkv = k_cache.shape[1]
    g = h // hkv
    smax = k_cache.shape[2]
    if scale is None:
        scale = d**-0.5
    qg = q.reshape(b, hkv, g, d)
    # keep K/V in cache dtype with f32 MXU accumulation: pre-casting the
    # cache to f32 materializes a full-cache f32 copy in HBM (measured 2-3x
    # decode HBM blow-up, EXPERIMENTS §Perf iteration 8)
    s_ = jnp.einsum(
        "bhgd,bhkd->bhgk", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        s_ = jnp.tanh(s_ / softcap) * softcap
    kpos = jnp.arange(smax)
    length = jnp.asarray(length)
    if length.ndim == 0:
        msk = kpos < length
        if window is not None:
            msk &= kpos > length - 1 - window
        s_ = jnp.where(msk[None, None, None], s_, NEG_INF)
    else:
        # per-slot cache fill levels: continuous-batching refill leaves
        # each batch slot at its own decode position
        msk = kpos[None, :] < length[:, None]  # (B, Smax)
        if window is not None:
            msk &= kpos[None, :] > length[:, None] - 1 - window
        s_ = jnp.where(msk[:, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum(
        "bhgk,bhkd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, h, 1, d).astype(q.dtype)
