"""repro.checkpoint — sharded, atomic, keep-k checkpointing with cross-mesh
restore (elastic shrink/grow)."""
from repro.checkpoint.store import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
]
