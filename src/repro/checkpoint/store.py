"""Checkpoint store: atomic, step-tagged, keep-k, mesh-aware.

Layout:

    <dir>/step_000123/
        manifest.json     — step, mesh shape/axes, leaf index, status
        <leaf_id>.npy     — one file per pytree leaf (host numpy)

Guarantees:

* **Atomicity** — written to ``step_N.tmp`` and renamed; a manifest with
  ``"complete": true`` is written last, so a crash mid-save leaves either a
  previous valid step or an ignorable tmp dir.  ``latest_step`` only
  returns complete checkpoints.
* **Keep-k GC** — older complete steps beyond ``keep`` are removed after a
  successful save (never before).
* **Cross-mesh restore** — arrays are saved as full host arrays with the
  *logical* pytree layout; ``restore_checkpoint`` device_puts each leaf
  with the sharding of the *current* mesh, so a run checkpointed on
  (2,16,16) restores onto (16,16) or (4,16,16) unchanged — the elastic
  shrink/grow path (tested in tests/test_checkpoint.py).

On a real multi-host pod each host would write only its addressable shards
(tensorstore); the single-host container writes full arrays.  The manifest
format already carries the mesh metadata needed for that extension.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("__".join(parts), leaf))
    return out


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    *,
    mesh=None,
    keep: int = 3,
) -> str:
    """Atomically save `tree` as step `step`. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _leaf_paths(tree)
    index = {}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{name}.npy"
        np.save(os.path.join(tmp, fname), arr)
        index[name] = {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}

    manifest = {
        "step": step,
        "complete": True,
        "leaves": index,
        "mesh": {
            "shape": list(mesh.devices.shape) if mesh is not None else None,
            "axes": list(mesh.axis_names) if mesh is not None else None,
        },
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    _gc(directory, keep)
    return final


def _steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        manifest = os.path.join(directory, name, "manifest.json")
        try:
            with open(manifest) as f:
                if json.load(f).get("complete"):
                    steps.append(int(m.group(1)))
        except (OSError, json.JSONDecodeError):
            continue
    return sorted(steps)


def _gc(directory: str, keep: int) -> None:
    steps = _steps(directory)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    steps = _steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    tree_like: Any,
    *,
    shardings: Any | None = None,
) -> Any:
    """Restore into the structure of `tree_like`, placed per `shardings`
    (a matching pytree of NamedSharding / None for host arrays)."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    index = manifest["leaves"]

    names = [n for n, _ in _leaf_paths(tree_like)]
    leaves_like = [l for _, l in _leaf_paths(tree_like)]
    shard_leaves = (
        [s for _, s in _leaf_paths(shardings)] if shardings is not None else [None] * len(names)
    )
    treedef = jax.tree_util.tree_structure(tree_like)

    restored = []
    for name, like, shd in zip(names, leaves_like, shard_leaves):
        if name not in index:
            raise KeyError(f"checkpoint {path} missing leaf {name}")
        arr = np.load(os.path.join(path, index[name]["file"]))
        expected = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != expected:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != {expected}")
        if shd is not None:
            restored.append(jax.device_put(arr, shd))
        else:
            restored.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, restored)


class CheckpointManager:
    """Keep-k manager + auto-resume used by launch/train.py."""

    def __init__(self, directory: str, *, keep: int = 3, mesh=None):
        self.directory = directory
        self.keep = keep
        self.mesh = mesh

    def save(self, step: int, tree: Any) -> str:
        return save_checkpoint(
            self.directory, step, tree, mesh=self.mesh, keep=self.keep
        )

    def latest(self) -> int | None:
        return latest_step(self.directory)

    def restore_latest(self, tree_like: Any, shardings=None) -> tuple[int, Any] | None:
        step = self.latest()
        if step is None:
            return None
        return step, restore_checkpoint(
            self.directory, step, tree_like, shardings=shardings
        )
