"""repro.optim — AdamW, LR schedules, gradient clipping, and compressed
gradient synchronization with error feedback."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.compress import (
    CompressState,
    compress_grads,
    compressed_allreduce_shardmap,
    init_compress_state,
)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine

__all__ = [
    "AdamWConfig",
    "CompressState",
    "adamw_init",
    "adamw_update",
    "compress_grads",
    "compressed_allreduce_shardmap",
    "cosine_schedule",
    "global_norm",
    "init_compress_state",
    "linear_warmup_cosine",
]
