"""AdamW with configurable moment dtype and global-norm clipping.

Pure pytree-in / pytree-out functions (no optax dependency — the container
is offline).  Moment dtype is per-arch config: fp32 default, bf16 for the
400B MoE where fp32 moments would not fit HBM (DESIGN.md §8); master params
stay in the model dtype with fp32 update math.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    moment_dtype: str = "float32"


def adamw_init(cfg: AdamWConfig, params: Any) -> dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: dict[str, Any],
    lr_scale: jax.Array | float = 1.0,
) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
    """One update. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * cfg.b1 + g32 * (1 - cfg.b1)
        nu32 = nu.astype(jnp.float32) * cfg.b2 + jnp.square(g32) * (1 - cfg.b2)
        mhat = mu32 / b1c
        nhat = nu32 / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    # three passes (params trees may legitimately contain tuple nodes, so a
    # tuple-leaf unzip is unsafe); XLA CSE dedups the shared subexpressions
    p_new = jax.tree.map(lambda *a: upd(*a)[0], params, grads, state["mu"], state["nu"])
    mu_new = jax.tree.map(lambda *a: upd(*a)[1], params, grads, state["mu"], state["nu"])
    nu_new = jax.tree.map(lambda *a: upd(*a)[2], params, grads, state["mu"], state["nu"])
    new_state = {"mu": mu_new, "nu": nu_new, "step": step}
    return p_new, new_state, {"grad_norm": gn}
