"""Gradient compression with exact error feedback.

Distributed-optimization trick for the 1000+-node regime (DESIGN.md §9):
the DP gradient all-reduce is the largest recurring collective; casting the
payload to bf16 halves it.  Plain casting biases the update; *error
feedback* (Seide et al. 2014; Karimireddy et al. 2019) keeps an fp32
residual accumulator per parameter so the quantization error of step t is
re-injected at step t+1 — the sum of applied updates telescopes to the true
gradient sum (memoryless in expectation; tested in tests/test_optim.py).

Two entry points:
  * ``compress_grads``             — jit/GSPMD path: quantize + residual
    update as pure pytree math (the all-reduce itself is GSPMD-inserted and
    runs on the bf16 payload because the quantize happens *before* psum in
    the train step's shard_map'd grad sync).
  * ``compressed_allreduce_shardmap`` — explicit shard_map DP sync: bf16
    psum over the data axis with the residual kept locally.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map


CompressState = Any  # pytree of fp32 residuals, same structure as grads


def init_compress_state(params: Any) -> CompressState:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(
    grads: Any, residual: CompressState, dtype=jnp.bfloat16
) -> tuple[Any, CompressState]:
    """(compressed bf16 grads, new residual).  g_c = cast(g + r); r' = g + r - g_c."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q = corrected.astype(dtype)
        return q, corrected - q.astype(jnp.float32)

    q = jax.tree.map(lambda *a: one(*a)[0], grads, residual)
    r = jax.tree.map(lambda *a: one(*a)[1], grads, residual)
    return q, r


def compressed_allreduce_shardmap(mesh, *, axis: str = "data", dtype=jnp.bfloat16):
    """f(grads, residual) -> (synced fp32 grads, residual'): bf16 psum over
    ``axis`` with per-device error feedback (half the DP collective bytes)."""

    def body(grads, residual):
        q, r = compress_grads(grads, residual, dtype)
        synced = jax.tree.map(
            lambda g: lax.pmean(g.astype(dtype), axis).astype(jnp.float32), q
        )
        return synced, r

    spec = P(axis)  # leaves carry per-device replicas stacked on dim 0
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec),
    )
