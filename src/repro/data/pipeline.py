"""Deterministic synthetic LM data pipeline.

Properties a real pipeline needs and this one has:

* **Step-addressable determinism** — batch(step) is a pure function of
  (seed, step), so a restart from checkpoint step N regenerates exactly the
  stream from N (no data-loader state in the checkpoint).
* **Shard-local generation** — each host materializes only its slice of the
  global batch (``make_global_batch`` + ``jax.make_array_from_callback``);
  nothing is ever gathered to one host.
* **Non-uniform statistics** — Zipf-distributed tokens with short-range
  Markov structure, so the cross-entropy has a non-trivial optimum and
  convergence tests can assert actual learning (uniform random tokens
  cannot be learned).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2  # Zipf exponent for the unigram distribution


class SyntheticLMData:
    """batch(step) -> {tokens, targets} with deterministic content."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed unigram distribution + a deterministic "grammar" permutation
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._probs = p / p.sum()
        rng = np.random.default_rng(cfg.seed)
        self._successor = rng.permutation(cfg.vocab)

    def _rows(self, step: int, row_lo: int, row_hi: int) -> np.ndarray:
        """Rows [row_lo, row_hi) of batch `step` (the shard-local slice)."""
        cfg = self.cfg
        out = np.empty((row_hi - row_lo, cfg.seq_len + 1), np.int32)
        for i, row in enumerate(range(row_lo, row_hi)):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, row])
            )
            toks = rng.choice(cfg.vocab, size=cfg.seq_len + 1, p=self._probs)
            # Markov structure: with p=0.5 the next token is successor(prev)
            follow = rng.random(cfg.seq_len) < 0.5
            for t in range(1, cfg.seq_len + 1):
                if follow[t - 1]:
                    toks[t] = self._successor[toks[t - 1]]
            out[i] = toks
        return out

    def batch_numpy(self, step: int) -> dict[str, np.ndarray]:
        rows = self._rows(step, 0, self.cfg.global_batch)
        return {"tokens": rows[:, :-1], "targets": rows[:, 1:]}


def make_global_batch(
    data: SyntheticLMData, step: int, mesh, spec
) -> dict[str, jax.Array]:
    """Build the sharded global batch, generating only host-local rows."""
    from jax.sharding import NamedSharding

    cfg = data.cfg
    shape = (cfg.global_batch, cfg.seq_len)
    sharding = NamedSharding(mesh, spec)

    def cb(field):
        def make(index):
            rows = index[0]
            lo = rows.start or 0
            hi = rows.stop if rows.stop is not None else cfg.global_batch
            block = data._rows(step, lo, hi)
            sl = block[:, :-1] if field == "tokens" else block[:, 1:]
            cols = index[1]
            return sl[:, cols]

        return jax.make_array_from_callback(shape, sharding, make)

    return {"tokens": cb("tokens"), "targets": cb("targets")}
