"""Activation-sharding context.

Model code stays mesh-agnostic: it calls ``shard_act(x, name)`` at the
canonical cut points ("btd" residual stream, "bhsd"/"bksd" attention heads,
"logits").  Inside a ``with sharding_rules(rules):`` block each name maps to
a PartitionSpec and becomes a ``with_sharding_constraint``; outside, it is a
no-op — smoke tests and single-device runs never touch the mesh machinery.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


@dataclass(frozen=True)
class ShardingRules:
    """Name -> PartitionSpec table for activation constraints.

    ``reduce_dtype``: when set (e.g. jnp.bfloat16), TP-contracted matmuls
    (attention out-proj, MLP/MoE down-proj) produce partials in this dtype,
    so the GSPMD-inserted cross-shard all-reduce moves half the bytes — the
    bf16-collective optimization of the §Perf hillclimb.
    """

    table: dict[str, P] = field(default_factory=dict)
    reduce_dtype: object | None = None

    def spec(self, name: str) -> P | None:
        return self.table.get(name)


@contextlib.contextmanager
def sharding_rules(rules: ShardingRules | None):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def current_rules() -> ShardingRules | None:
    return getattr(_STATE, "rules", None)


def shard_act(x: jax.Array, name: str) -> jax.Array:
    """Constrain activation `x` per the active rule set (no-op without one)."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def tp_reduce_dtype():
    """preferred_element_type for TP-contracted matmuls (None = default)."""
    rules = current_rules()
    return None if rules is None else rules.reduce_dtype
