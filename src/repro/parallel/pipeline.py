"""GPipe-style pipeline schedule over a mesh axis (scan + ppermute).

Not used by the assigned meshes (every assigned model fits TP x DP on a
16x16 pod) but required for >2-pod scale-out, where the pod axis becomes
the stage axis.  The schedule is the classic fill/drain microbatch stream:

    T = n_micro + n_stages - 1 steps; at step t, stage s computes
    microbatch t - s (when in range); activations hop stage->stage+1 via
    one collective_permute per step.

Bubble fraction (n_stages-1)/T — the standard GPipe overhead; interleaved
1F1B is left as a documented extension point (the schedule function is the
only thing that would change).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map



def pipeline_shardmap(mesh, stage_fn, *, axis: str = "pod"):
    """Build f(stage_params, xs) running `stage_fn` as a pipeline.

    stage_params: pytree with leading dim n_stages (sharded over ``axis``).
    xs: (n_micro, ...) microbatch stream (replicated over ``axis``).
    Returns (n_micro, ...) outputs (replicated — psum-broadcast from the
    last stage).
    """
    n_stages = mesh.shape[axis]

    def body(stage_params, xs):
        # under shard_map: stage_params leaves (1, ...) — this stage's slice
        local = jax.tree.map(lambda a: a[0], stage_params)
        idx = lax.axis_index(axis)
        n_micro = xs.shape[0]
        t_total = n_micro + n_stages - 1
        fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        y0 = stage_fn(local, xs[0])  # shape probe (traced once, reused)
        out0 = jnp.zeros((n_micro,) + y0.shape, y0.dtype)

        def step(carry, t):
            recv, outs = carry
            x_in = jnp.where(
                idx == 0,
                xs[jnp.clip(t, 0, n_micro - 1)],
                recv.astype(xs.dtype) if recv.dtype != xs.dtype else recv,
            )
            y = stage_fn(local, x_in)
            # last stage banks microbatch t-(n_stages-1) when in range
            mb = t - (n_stages - 1)
            valid = (idx == n_stages - 1) & (mb >= 0) & (mb < n_micro)
            outs = lax.cond(
                valid,
                lambda o: lax.dynamic_update_slice(
                    o, y[None], (jnp.maximum(mb, 0),) + (0,) * y.ndim
                ),
                lambda o: o,
                outs,
            )
            recv = lax.ppermute(y, axis, fwd)
            return (recv, outs), None

        (_, outs), _ = lax.scan(
            step, (jnp.zeros_like(y0), out0), jnp.arange(t_total)
        )
        # broadcast the last stage's banked outputs to every stage
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        return lax.psum(outs, axis)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        # the fill/drain cond branches mix varying (stage-local) and
        # unvarying buffers; correctness is oracle-tested (tests/_dist.py)
        check_vma=False,
    )


def split_microbatches(x: jax.Array, n_micro: int) -> jax.Array:
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    return x.reshape((n_micro, b // n_micro) + x.shape[1:])
