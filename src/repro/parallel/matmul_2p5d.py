"""The paper's 2.5D schedule applied to the LM's largest matmuls.

Beyond-paper carry-over (DESIGN.md §4): the 2.5D SpGEMM insight — split the
contraction dimension over a depth axis L, compute partial products against
the *home* layout, and fuse the partial-result reduction into one collective
— applies verbatim to the LM-head / embedding matmul, whose (d_model x
vocab) weight is the biggest single GEMM in most of the assigned archs
(vocab 50k-256k).

On the multi-pod mesh the ``pod`` axis plays L:

    W (d, V)  sharded  P("pod", "model")     — d split over L, V over TP
    x (T, d)  sharded  P(None, "pod")        — activations split over d too
    partial = x_l @ W_l                      — no communication
    logits  = psum_scatter(partial, "pod")   — the (L-1)-panel reduction

Per-device communication: psum_scatter moves (L-1)/L of the logits shard
instead of all-gathering a d-replicated weight — the same
"(L-1) S_C vs V/sqrt(L) (S_A+S_B)" trade as paper Eq. (7).  ``plan_2p5d``
evaluates that trade analytically (it is the hillclimb napkin math).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map



def matmul_2p5d_shardmap(mesh, *, depth_axis: str = "pod", tp_axis: str = "model",
                         reduce: str = "scatter"):
    """Returns f(x, w) computing x @ w with the contraction dim split over
    ``depth_axis`` and the output dim over ``tp_axis``.

    x: (..., T, d) sharded (..., None, depth); w: (d, V) sharded (depth, tp).
    Output: (..., T, V) sharded over tp (and depth when reduce == "scatter",
    P(..., depth, tp) — token-sharded logits, the chunked-CE-friendly form).
    """

    def body(x, w):
        partial = jnp.einsum("...td,dv->...tv", x, w)  # local (T, V/tp)
        if reduce == "scatter":
            return lax.psum_scatter(
                partial, depth_axis, scatter_dimension=partial.ndim - 2, tiled=True
            )
        return lax.psum(partial, depth_axis)

    # (T, d) specs; callers with batch dims use the same trailing axes
    x_spec = P(None, depth_axis)
    w_spec = P(depth_axis, tp_axis)
    out_spec = P(depth_axis, tp_axis) if reduce == "scatter" else P(None, tp_axis)
    return shard_map(
        body, mesh=mesh, in_specs=(x_spec, w_spec), out_specs=out_spec
    )


@dataclass(frozen=True)
class Plan2p5d:
    l: int
    bytes_baseline: float  # all-gather the d-sharded weight per step
    bytes_2p5d: float  # psum_scatter of the partial logits
    wins: bool


def plan_2p5d(
    tokens: int, d_model: int, vocab: int, l: int, tp: int, bytes_per_el: int = 2
) -> Plan2p5d:
    """Napkin math for claiming the pod axis as 2.5D depth on the LM head.

    Baseline (pure DP over pod): weight fully resident, logits local — but
    the FSDP variant all-gathers W (d x V / tp) per step: d*V/tp bytes.
    2.5D: psum_scatter moves (l-1)/l of the partial logits: T*V/tp*(l-1)/l.
    """
    base = d_model * vocab / tp * bytes_per_el
    ours = tokens * vocab / tp * (l - 1) / l * bytes_per_el
    return Plan2p5d(l=l, bytes_baseline=base, bytes_2p5d=ours, wins=ours < base)
