"""Parameter / activation / cache sharding rules for the LM stack.

Strategy (DESIGN.md §8):

* ``model`` axis — tensor parallel: d_ff of every MLP and expert, attention
  heads (where the head count divides), vocab dim of embedding & LM head.
* ``data`` axis — batch data-parallel, *and* FSDP for the non-TP dim of
  every large parameter (ZeRO-3: gathered per layer inside the scan).
* ``pod`` axis (multi-pod mesh) — pure DP for the baseline; the 2.5D LM
  matmul (matmul_2p5d.py) and the FSDP extension claim it in hillclimbs.

Divisibility is checked per leaf: a dim is only sharded when the axis size
divides it (e.g. qwen1.5-4b's 20 heads stay unsharded on a 16-way model
axis while its 6912 d_ff shards cleanly; kv heads of GQA archs — 8 on 16 —
are replicated, the standard KV-replication of GQA TP).

All rules are pure functions of (path, shape, axis sizes) so the same table
drives jit in_shardings, with_sharding_constraint, and the dry-run's
ShapeDtypeStruct shardings.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ArchConfig, ShapeConfig
from repro.parallel.ctx import ShardingRules

# parameter-name -> (row rule, col rule) for 2D weight leaves;
# "fsdp" shards over data, "tp" over model, None replicates.
_MATMUL_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    # embeddings: vocab over model (vocab-parallel logits), d over data
    (r"embed/(tok|out)$", ("tp", "fsdp")),
    # attention
    (r"attn/wq$", ("fsdp", "tp")),
    (r"attn/wk$", ("fsdp", "tp")),
    (r"attn/wv$", ("fsdp", "tp")),
    (r"attn/wo$", ("tp", "fsdp")),
    (r"xattn/wq$", ("fsdp", "tp")),
    (r"xattn/wk$", ("fsdp", "tp")),
    (r"xattn/wv$", ("fsdp", "tp")),
    (r"xattn/wo$", ("tp", "fsdp")),
    (r"attn/b[qkv]$", ("tp",)),
    # dense MLP
    (r"mlp/w_in$", ("fsdp", "tp")),
    (r"mlp/w_gate$", ("fsdp", "tp")),
    (r"mlp/w_out$", ("tp", "fsdp")),
    # MoE — tp impl: experts over data (FSDP), d_expert over model
    (r"moe/router$", ("fsdp", None)),
    (r"moe/w_in$", ("fsdp", None, "tp")),
    (r"moe/w_gate$", ("fsdp", None, "tp")),
    (r"moe/w_out$", ("fsdp", "tp", None)),
    (r"moe/shared_in$", ("fsdp", "tp")),
    (r"moe/shared_gate$", ("fsdp", "tp")),
    (r"moe/shared_out$", ("tp", "fsdp")),
    # mamba
    (r"mamba/in_proj$", ("fsdp", "tp")),
    (r"mamba/conv_w$", (None, "tp")),
    (r"mamba/conv_b$", ("tp",)),
    (r"mamba/x_proj$", ("tp", None)),
    (r"mamba/dt_proj$", (None, "tp")),
    (r"mamba/dt_bias$", ("tp",)),
    (r"mamba/a_log$", ("tp", None)),
    (r"mamba/d_skip$", ("tp",)),
    (r"mamba/out_proj$", ("tp", "fsdp")),
    # rwkv6
    (r"rwkv/w[rkvg]$", ("fsdp", "tp")),
    (r"rwkv/wo$", ("tp", "fsdp")),
    (r"rwkv/decay_w1$", ("fsdp", None)),
    (r"rwkv/decay_w2$", (None, "tp")),
    (r"rwkv/ck$", ("fsdp", "tp")),
    (r"rwkv/cv$", ("tp", "fsdp")),
    (r"rwkv/cr$", ("fsdp", "tp")),
]

_EP_OVERRIDES: list[tuple[str, tuple[str | None, ...]]] = [
    # ep impl: experts over model, FSDP on d_model
    (r"moe/router$", ("fsdp", None)),
    (r"moe/w_in$", ("tp", "fsdp", None)),
    (r"moe/w_gate$", ("tp", "fsdp", None)),
    (r"moe/w_out$", ("tp", None, "fsdp")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_ok(dim: int, axis: str | None, axes: dict[str, int]) -> bool:
    return axis is not None and axis in axes and dim % axes[axis] == 0


def leaf_spec(
    path_s: str,
    shape: tuple[int, ...],
    axes: dict[str, int],
    *,
    fsdp_axis: str | tuple[str, ...] | None = "data",
    moe_impl: str = "tp",
    head_2p5d: bool = False,
) -> P:
    """PartitionSpec for one parameter leaf."""
    stacked = "blocks" in path_s  # scanned layers carry a leading reps dim
    core = shape[1:] if stacked else shape

    if head_2p5d and "pod" in axes and re.search(r"embed/out$", path_s):
        # the paper's 2.5D schedule on the LM head: vocab over TP, the
        # d_model *contraction* dim over the pod axis (depth L); GSPMD then
        # computes per-pod partial logits and reduces them over `pod` — the
        # (L-1)-panel C reduction of Algorithm 2 (see parallel/matmul_2p5d)
        v, d = core
        if v % axes.get("model", 1) == 0 and d % axes["pod"] == 0:
            parts = ["model", "pod"]
            return P(*([None] + parts)) if stacked else P(*parts)

    rules = _MATMUL_RULES
    if moe_impl == "ep":
        overridden = {pat for pat, _ in _EP_OVERRIDES}
        rules = _EP_OVERRIDES + [r for r in rules if r[0] not in overridden]

    entry: tuple[str | None, ...] | None = None
    for pat, spec in rules:
        if re.search(pat, path_s):
            entry = spec
            break
    if entry is None or len(entry) != len(core):
        return P(*([None] * len(shape)))  # norms, scalars, unmatched leaves

    def resolve(dim: int, role: str | None):
        if role == "tp":
            return "model" if _axis_ok(dim, "model", axes) else None
        if role == "fsdp":
            if fsdp_axis is None:
                return None
            fa = fsdp_axis if isinstance(fsdp_axis, tuple) else (fsdp_axis,)
            total = 1
            for a in fa:
                total *= axes.get(a, 1)
            if dim % total == 0:
                return fsdp_axis
            if dim % axes.get("data", 1) == 0:
                return "data"
            return None
        return None

    parts = [resolve(d, r) for d, r in zip(core, entry)]
    if stacked:
        parts = [None] + parts
    return P(*parts)


def param_specs(
    cfg: ArchConfig,
    params_shape: Any,
    mesh: Mesh,
    *,
    fsdp_axis="data",
    head_2p5d: bool = False,
) -> Any:
    """Spec tree matching the params pytree (built from its eval_shape)."""
    axes = dict(mesh.shape)
    moe_impl = cfg.moe.impl if cfg.moe else "tp"

    def rule(path, leaf):
        return leaf_spec(
            _path_str(path), leaf.shape, axes, fsdp_axis=fsdp_axis,
            moe_impl=moe_impl, head_2p5d=head_2p5d,
        )

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------
# batch / activations / cache
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh) -> tuple[str, ...] | str:
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else "data"


def batch_spec(mesh: Mesh, batch: int, *extra_dims: int) -> P:
    ba = batch_axes(mesh)
    size = 1
    for a in ba if isinstance(ba, tuple) else (ba,):
        size *= dict(mesh.shape)[a]
    lead = ba if batch % size == 0 else None
    return P(lead, *([None] * len(extra_dims)))


def activation_rules(
    cfg: ArchConfig, mesh: Mesh, *, batch: int, seq_parallel: bool = False,
    head_2p5d: bool = False, reduce_dtype=None,
) -> ShardingRules:
    axes = dict(mesh.shape)
    ba = batch_axes(mesh)
    size = 1
    for a in ba if isinstance(ba, tuple) else (ba,):
        size *= axes[a]
    b = ba if batch % size == 0 else None
    m = axes.get("model", 1)
    h_ok = cfg.n_heads % m == 0
    kv_ok = cfg.n_kv_heads % m == 0
    table = {
        # residual stream: seq-sharded over `model` under sequence
        # parallelism (Megatron-SP); norms/residual adds run on 1/TP tokens
        "btd": P(b, "model", None) if seq_parallel else P(b, None, None),
        # matmul inputs: always full-seq.  The explicit constraint after the
        # norm makes GSPMD insert an activation-sized all-gather there and
        # keeps weight-grad contractions OFF the model axis (a naive
        # seq-sharded matmul input turns every dW into a weight-sized
        # all-reduce over `model` — measured 80x1GB/step on qwen2-72b,
        # EXPERIMENTS §Perf iteration 3)
        "btd_full": P(b, None, None),
        "bhsd": P(b, "model" if h_ok else None, None, None),
        "bksd": P(b, "model" if kv_ok else None, None, None),
        "logits": P(b, None, "model"),
    }
    if cfg.moe is not None and cfg.moe.impl == "ep":
        # dispatched expert buffer (B, E, C, d): experts over `model`
        table["moe_dispatch"] = P(b, "model", None, None)
        table["moe_combine"] = P(b, None, None, None)
    if head_2p5d and "pod" in axes and cfg.d_model % axes["pod"] == 0:
        # CE-chunk input x (B, chunk, d): d split over the pod axis so the
        # LM-head contraction runs as per-pod partial products (2.5D depth)
        bb = "data" if b is not None else None
        table["ce_in"] = P(bb, None, "pod")
    # NamedSharding (not raw P) so with_sharding_constraint works without a
    # context mesh (jit-under-jit, dry-run lowering, etc.)
    table = {k: NamedSharding(mesh, v) for k, v in table.items()}
    return ShardingRules(table=table, reduce_dtype=reduce_dtype)


def cache_specs(cfg: ArchConfig, cache_shape: Any, mesh: Mesh, *, batch: int) -> Any:
    """Spec tree for the decode cache (KV + recurrent states).

    KV: batch over (pod, data) when divisible; kv-heads over model when
    divisible, else the *sequence* dim over model (flash-decoding layout —
    the long_500k route where batch=1 forbids batch sharding).
    """
    axes = dict(mesh.shape)
    ba = batch_axes(mesh)
    size = 1
    for a in ba if isinstance(ba, tuple) else (ba,):
        size *= axes[a]
    b = ba if batch % size == 0 else None
    m = axes.get("model", 1)

    def rule(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if re.search(r"/(k|v|xk|xv)$", ps):  # (reps, B, hkv, S, hd)
            _, _, hkv, s, _ = shape
            if hkv % m == 0:
                return P(None, b, "model", None, None)
            if s % m == 0:
                return P(None, b, None, "model", None)
            return P(None, b, None, None, None)
        if ps.endswith("ssm"):  # (reps, B, di, n)
            return P(None, b, "model" if shape[2] % m == 0 else None, None)
        if ps.endswith("conv"):  # (reps, B, dc-1, di)
            return P(None, b, None, "model" if shape[3] % m == 0 else None)
        if ps.endswith("wkv"):  # (reps, B, h, hd, hd)
            return P(None, b, "model" if shape[2] % m == 0 else None, None, None)
        if "shift" in ps:  # (reps, B, d)
            return P(None, b, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def input_specs_sharded(
    cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh
) -> dict[str, P]:
    """PartitionSpecs for the model-input ShapeDtypeStructs of the dry-run."""
    from repro.config import input_specs

    specs = input_specs(cfg, shape)
    out = {}
    for name, sds in specs.items():
        if sds.ndim == 0:
            out[name] = P()
        else:
            out[name] = batch_spec(mesh, sds.shape[0], *sds.shape[1:])
    return out


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
