"""repro.parallel — sharding rules, activation-constraint context, the 2.5D
LM matmul (the paper's technique applied to the LM's biggest matmuls), and a
scan-based pipeline schedule for >2-pod meshes."""
from repro.parallel.ctx import ShardingRules, shard_act, sharding_rules
from repro.parallel.sharding import (
    batch_spec,
    cache_specs,
    input_specs_sharded,
    param_specs,
)

__all__ = [
    "ShardingRules",
    "batch_spec",
    "cache_specs",
    "input_specs_sharded",
    "param_specs",
    "shard_act",
    "sharding_rules",
]
