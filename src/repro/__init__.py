"""repro — 2.5D communication-reducing block-sparse SpGEMM (DBCSR, PASC'17)
re-built as a TPU-native JAX framework, plus the multi-arch LM stack that
integrates the paper's distribution technique."""
import jax as _jax

__version__ = "1.0.0"

# Sharding-invariant PRNG: partitionable threefry is the default from
# jax 0.5; on 0.4.x the default (False) makes `jax.random` draws depend on
# the out_sharding, which breaks layout-equivalence guarantees this repo
# relies on (ZeRO-1 init == replicated init, cross-mesh checkpoint
# restore).  Version-compat shims for APIs live in ``repro.compat``.
if not _jax.config.jax_threefry_partitionable:
    _jax.config.update("jax_threefry_partitionable", True)
