"""repro — 2.5D communication-reducing block-sparse SpGEMM (DBCSR, PASC'17)
re-built as a TPU-native JAX framework, plus the multi-arch LM stack that
integrates the paper's distribution technique."""
__version__ = "1.0.0"
