"""llama4-maverick-400b-a17b [moe] — 128-expert top-1 MoE with early fusion,
MoE on alternating layers (interleaved dense/MoE as in the Llama-4 family).
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]"""
from repro.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(
        n_experts=128,
        top_k=1,
        n_shared=1,  # Llama-4 routes top-1 + always-on shared expert
        d_expert=8192,
        layer_period=2,  # MoE every other layer (interleaved)
        capacity_factor=1.25,
        impl="tp",
    ),
    opt_state_dtype="bfloat16",  # fp32 moments would not fit HBM at 400B
    source="hf:meta-llama/Llama-4 family (unverified)",
)
