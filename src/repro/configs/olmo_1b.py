"""olmo-1b [dense] — non-parametric LayerNorm.  [arXiv:2402.00838; hf]
long_500k SKIPPED (full attention)."""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    mlp="swiglu",
    norm="nonparametric_ln",
    tie_embeddings=True,
    source="arXiv:2402.00838",
)
