"""pixtral-12b [vlm] — Pixtral ViT frontend (stub) + Mistral-Nemo-style
decoder.  [hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    mlp="swiglu",
    norm="rmsnorm",
    frontend="vision",
    n_patches=256,
    rope=True,
    rope_theta=1_000_000_000.0,
    source="hf:mistralai/Pixtral-12B-2409 (unverified)",
)
