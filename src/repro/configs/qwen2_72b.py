"""qwen2-72b [dense] — GQA with QKV bias; the biggest dense TP case.
[arXiv:2407.10671; hf]  long_500k SKIPPED (full attention)."""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    mlp="swiglu",
    norm="rmsnorm",
    source="arXiv:2407.10671",
)
