"""qwen1.5-4b [dense] — QKV bias; 20 heads (deliberately indivisible by the
16-way model axis — exercises the head-replication TP fallback).
[hf:Qwen/Qwen1.5-4B; hf]  long_500k SKIPPED (full attention)."""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    mlp="swiglu",
    norm="rmsnorm",
    source="hf:Qwen/Qwen1.5-4B",
)
