"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave (one attention
layer per 8), MoE 16e top-2 on every other layer.  [arXiv:2403.19887; hf]"""
from repro.config import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    mlp="swiglu",
    norm="rmsnorm",
    mixer="mamba_hybrid",
    attn_layer_period=8,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    moe=MoEConfig(
        n_experts=16,
        top_k=2,
        n_shared=0,
        d_expert=14336,
        layer_period=2,
        capacity_factor=1.25,
        impl="tp",
    ),
    source="arXiv:2403.19887",
)
