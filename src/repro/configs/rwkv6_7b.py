"""rwkv6-7b "Finch" [ssm] — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]  Runs long_500k (O(1) recurrent state)."""
from repro.config import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # time-mix heads = d_model / head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    mixer="rwkv6",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk=256),
    mlp="swiglu",  # unused by rwkv blocks (channel-mix replaces the MLP)
    norm="layernorm",
    rope=True,  # no positional injection needed; kept for embed path parity
    source="arXiv:2404.05892",
)
