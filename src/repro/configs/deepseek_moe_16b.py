"""deepseek-moe-16b [moe] — fine-grained experts: 2 shared + 64 routed
top-6, d_expert=1408.  [arXiv:2401.06066; hf]"""
from repro.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        n_shared=2,
        d_expert=1408,
        layer_period=1,  # every layer is MoE (first layer dense in hf; kept uniform)
        capacity_factor=1.3,
        impl="tp",
    ),
    source="arXiv:2401.06066",
)
