"""repro.configs — assigned architecture registry + DBCSR benchmark matrices.

``get_arch(name)`` returns the full published config; ``--arch`` flags in
the launchers resolve here.  Each arch module carries its provenance note.
"""
from __future__ import annotations

import importlib

from repro.config import ArchConfig

ARCH_IDS = (
    "pixtral_12b",
    "llama4_maverick_400b_a17b",
    "deepseek_moe_16b",
    "whisper_large_v3",
    "jamba_v0_1_52b",
    "gemma2_27b",
    "qwen2_72b",
    "olmo_1b",
    "qwen1_5_4b",
    "rwkv6_7b",
)

_ALIASES = {
    "pixtral-12b": "pixtral_12b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "whisper-large-v3": "whisper_large_v3",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "gemma2-27b": "gemma2_27b",
    "qwen2-72b": "qwen2_72b",
    "olmo-1b": "olmo_1b",
    "qwen1.5-4b": "qwen1_5_4b",
    "rwkv6-7b": "rwkv6_7b",
}


def get_arch(name: str) -> ArchConfig:
    key = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; one of {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_archs() -> dict[str, ArchConfig]:
    return {aid: get_arch(aid) for aid in ARCH_IDS}
