"""whisper-large-v3 [audio] — encoder-decoder; the conv/mel frontend is a
stub (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.config import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,  # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    mlp="gelu",
    norm="layernorm",
    encoder=EncoderConfig(n_layers=32, n_frames=1500),
    frontend="audio",
    rope=False,  # absolute sinusoidal positions
    source="arXiv:2212.04356 (unverified)",
)
