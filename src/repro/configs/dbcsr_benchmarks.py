"""The paper's Table 1 benchmark matrices, scaled to block-grid form.

Paper values:                      H2O-DFT-LS   S-E          Dense
  block size (n x n)               23           6            32
  rows/columns                     158,976      1,119,744    60,000
  occupancy                        7-15 %       0.04-0.06 %  100 %
  multiplications                  193          1198         10

TPU adaptation (DESIGN.md §2): atomic blocks are packed into MXU-aligned
super-blocks; the *occupancy and pattern* are preserved at the block-grid
level, and full-size grids are exercised via the dry-run while scaled-down
grids (same occupancy) run numerically in the benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class MatrixBench:
    name: str
    block_size: int  # atomic block edge (paper Table 1)
    n_rows: int  # matrix dimension
    occupancy: float  # typical block occupancy
    pattern: str  # generator pattern (bsm.random_bsm)
    n_mults: int  # multiplications per application run
    flops: float  # paper-reported DBCSR FLOPs for the full run
    filter_eps: float = 1e-9


BENCHMARKS: dict[str, MatrixBench] = {
    "h2o_dft_ls": MatrixBench(
        name="H2O-DFT-LS",
        block_size=23,
        n_rows=158_976,
        occupancy=0.10,
        pattern="decay",
        n_mults=193,
        flops=4.038e15,
    ),
    "s_e": MatrixBench(
        name="S-E",
        block_size=6,
        n_rows=1_119_744,
        occupancy=5e-4,
        pattern="decay",
        n_mults=1198,
        flops=0.146e15,
    ),
    "dense": MatrixBench(
        name="Dense",
        block_size=32,
        n_rows=60_000,
        occupancy=1.0,
        pattern="dense",
        n_mults=10,
        flops=4.320e15,
    ),
}

# paper §4.1: measured average S_C / S_{A,B} panel-size ratios per benchmark
SC_OVER_SAB = {"h2o_dft_ls": 2.7, "s_e": 2.1, "dense": 1.0}

# strong-scaling node counts of Table 2
TABLE2_NODES = (200, 400, 729, 1296, 2704)
