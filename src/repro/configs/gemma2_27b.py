"""gemma2-27b [dense] — alternating local(4096)/global attention, logit
soft-capping, sandwich norms.  [arXiv:2408.00118; hf]

long_500k is SKIPPED: the global layers are full attention
(DESIGN.md §Arch-applicability)."""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    mlp="geglu",
    norm="rmsnorm",
    post_norm=True,  # sandwich (pre+post) norms
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    window_pattern=2,  # local every other layer
    source="arXiv:2408.00118",
)
