"""Training driver: fault-tolerant, checkpointed, straggler-aware.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 200 --mesh 1x1 --ckpt-dir /tmp/run1

Production features (DESIGN.md §9):
  * auto-resume from the latest complete checkpoint (atomic, keep-k);
  * step-addressable data (restart regenerates the exact stream);
  * straggler watchdog: per-step wall clock vs an EMA threshold; slow steps
    are logged and (configurably) trigger an early checkpoint so a
    replacement host can resume immediately;
  * preemption-safe: SIGTERM requests a checkpoint at the next step edge;
  * gradient compression (bf16 + error feedback) via --compress-grads;
  * elastic restart: checkpoints carry the mesh; restoring onto a different
    mesh re-shards per the current sharding rules (checkpoint/store.py).

On the CPU container this runs reduced configs on a 1x1 mesh; on real
hardware the same driver runs the full configs on the production mesh
(``--mesh 16x16`` / ``--mesh 2x16x16``).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import ShapeConfig
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticLMData, make_global_batch
from repro.launch.mesh import make_mesh
from repro.launch.steps import StepOptions, build_train_step
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.parallel.sharding import batch_spec


class StragglerWatchdog:
    """EMA-based per-step wall-clock anomaly detector."""

    def __init__(self, factor: float = 3.0, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.ema: float | None = None
        self.events: list[tuple[int, float]] = []
        self._n = 0

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self._n += 1
        if self.ema is None:
            self.ema = dt
            return False
        slow = self._n > self.warmup and dt > self.factor * self.ema
        if slow:
            self.events.append((step, dt))
        # slow steps don't poison the EMA
        self.ema = 0.9 * self.ema + 0.1 * min(dt, self.factor * self.ema)
        return slow


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 2:
        return make_mesh(dims, ("data", "model"))
    if len(dims) == 3:
        return make_mesh(dims, ("pod", "data", "model"))
    raise ValueError(f"mesh spec {spec!r}: want DxM or PxDxM")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = parse_mesh(args.mesh)
    shape = ShapeConfig("train", args.seq_len, args.global_batch, "train")
    options = StepOptions(remat=args.remat, compress_grads=args.compress_grads,
                          loss_chunk=min(512, args.seq_len))
    opt = AdamWConfig(lr=args.lr, moment_dtype=cfg.opt_state_dtype)

    step_fn, (p_sds, o_sds, b_sds) = build_train_step(
        cfg, mesh, shape, opt=opt, options=options
    )
    shardings = lambda t: jax.tree.map(lambda x: x.sharding, t)

    # ---- init or resume -------------------------------------------------
    mgr = CheckpointManager(args.ckpt_dir, mesh=mesh) if args.ckpt_dir else None
    start_step = 0
    if mgr is not None and mgr.latest() is not None:
        state_like = {"params": p_sds, "opt": o_sds}
        start_step, restored = mgr.restore_latest(
            state_like, shardings=shardings(state_like)
        )
        params, opt_state = restored["params"], restored["opt"]
        print(f"[train] resumed from step {start_step}", flush=True)
    else:
        params = jax.jit(
            lambda k: T.init_params(cfg, k), out_shardings=shardings(p_sds)
        )(jax.random.key(args.seed))
        opt_state = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype, device=s.sharding), o_sds
        )

    data = SyntheticLMData(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                   global_batch=args.global_batch, seed=args.seed)
    )
    spec = batch_spec(mesh, args.global_batch, args.seq_len)

    # ---- SIGTERM = checkpoint at the next step edge (preemption safety) --
    stop_requested = False

    def _on_term(signum, frame):
        nonlocal stop_requested
        stop_requested = True

    signal.signal(signal.SIGTERM, _on_term)

    watchdog = StragglerWatchdog()
    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = make_global_batch(data, step, mesh, spec)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])  # blocks; also the step boundary
        dt = time.time() - t0
        losses.append(loss)
        if not np.isfinite(loss):
            print(f"[train] step {step}: NON-FINITE LOSS {loss}", flush=True)
            return 1
        if watchdog.observe(step, dt):
            print(f"[train] step {step}: straggler ({dt:.2f}s vs EMA "
                  f"{watchdog.ema:.2f}s) — checkpointing early", flush=True)
            if mgr is not None:
                mgr.save(step + 1, {"params": params, "opt": opt_state})
        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms",
                  flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
        if stop_requested:
            print(f"[train] SIGTERM: checkpoint at step {step + 1} and exit",
                  flush=True)
            if mgr is not None:
                mgr.save(step + 1, {"params": params, "opt": opt_state})
            return 0

    if mgr is not None:
        mgr.save(args.steps, {"params": params, "opt": opt_state})
    dt = time.time() - t_start
    print(f"[train] done: {args.steps - start_step} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers={len(watchdog.events)}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
