"""Step builders shared by train.py, serve.py and dryrun.py.

``build_train_step`` / ``build_serve_step`` return (jitted fn, abstract
input trees with shardings attached) so the dry-run can ``.lower`` against
ShapeDtypeStructs while the real drivers call the same function with data.

Perf knobs (the §Perf hillclimb levers) are carried in ``StepOptions`` so
one flag flips a schedule for re-lowering:

  remat          — activation-checkpoint policy inside the layer scan
  fsdp_axis      — which mesh axes shard the non-TP weight dim
  seq_parallel   — shard the residual stream's sequence dim over `model`
  loss_chunk     — vocab-matmul chunking of the CE (memory lever)
  head_2p5d      — claim the pod axis as the paper's 2.5D depth L for the
                   LM-head matmul (multi-pod only)
  compress_grads — bf16 DP gradient sync with fp32 error feedback
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ArchConfig, ShapeConfig, input_specs
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import compress_grads as _compress
from repro.optim.compress import init_compress_state
from repro.parallel.ctx import sharding_rules
from repro.parallel.sharding import (
    activation_rules,
    batch_spec,
    cache_specs,
    param_specs,
)


@dataclass(frozen=True)
class StepOptions:
    remat: str = "dots"  # none | full | dots
    fsdp_axis: Any = "data"
    seq_parallel: bool = False
    loss_chunk: int = 1024
    head_2p5d: bool = False
    compress_grads: bool = False
    bf16_reduce: bool = False  # bf16 partials for TP-contracted matmuls
    microbatch: int = 1  # gradient-accumulation steps (activation memory / k)
    zero1: bool = False  # shard ONLY optimizer state over fsdp_axis (params
    # stay TP-sharded, data-replicated) — avoids per-microbatch weight
    # all-gathers; requires params/TP to fit HBM (not the 400B MoE)
    aux_coef: float = 0.01


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def abstract_state(cfg: ArchConfig, mesh, opt: AdamWConfig | None, options: StepOptions):
    """(params SDS+sharding, opt_state SDS+sharding, spec trees)."""
    p_shape = jax.eval_shape(functools.partial(T.init_params, cfg), jax.random.key(0))
    p_fsdp = None if options.zero1 else options.fsdp_axis
    p_spec = param_specs(cfg, p_shape, mesh, fsdp_axis=p_fsdp,
                         head_2p5d=options.head_2p5d)
    p_sh = _named(mesh, p_spec)
    p_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), p_shape, p_sh
    )
    if opt is None:
        return p_sds, None, p_spec, None
    # ZeRO-1: moments keep the full FSDP sharding even when params don't
    m_spec = param_specs(cfg, p_shape, mesh, fsdp_axis=options.fsdp_axis,
                         head_2p5d=options.head_2p5d)
    o_shape = jax.eval_shape(functools.partial(adamw_init, opt), p_shape)
    o_spec = {
        "mu": m_spec,
        "nu": m_spec,
        "step": P(),
    }
    if options.compress_grads:
        o_shape = dict(
            o_shape,
            efb=jax.eval_shape(init_compress_state, p_shape),
        )
        o_spec = dict(o_spec, efb=p_spec)
    o_sh = _named(mesh, o_spec)
    o_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), o_shape, o_sh
    )
    return p_sds, o_sds, p_spec, o_spec


def batch_sds(cfg: ArchConfig, shape: ShapeConfig, mesh) -> dict[str, jax.ShapeDtypeStruct]:
    """Model-input ShapeDtypeStructs with shardings attached."""
    out = {}
    for name, sds in input_specs(cfg, shape).items():
        if sds.ndim == 0:
            spec = P()
        else:
            spec = batch_spec(mesh, sds.shape[0], *sds.shape[1:])
        out[name] = jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        )
    return out


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def build_train_step(
    cfg: ArchConfig,
    mesh,
    shape: ShapeConfig,
    *,
    opt: AdamWConfig | None = None,
    options: StepOptions = StepOptions(),
):
    """Returns (jitted train_step, (params_sds, opt_sds, batch_sds))."""
    if opt is None:
        opt = AdamWConfig(moment_dtype=cfg.opt_state_dtype)
    rules = activation_rules(
        cfg, mesh, batch=shape.global_batch, seq_parallel=options.seq_parallel,
        head_2p5d=options.head_2p5d,
        reduce_dtype=jnp.bfloat16 if options.bf16_reduce else None,
    )

    def grad_fn(params, batch):
        with sharding_rules(rules):
            def lf(p):
                return T.loss_fn(
                    cfg,
                    p,
                    batch,
                    aux_coef=options.aux_coef,
                    remat=options.remat,
                    loss_chunk=options.loss_chunk,
                )

            return jax.value_and_grad(lf, has_aux=True)(params)

    # gradient/accumulator sharding: the ZeRO (moment) layout.  Without an
    # explicit constraint GSPMD settles the scan carry REPLICATED and
    # all-gathers every microbatch's weight grads to full f32 (measured
    # 640 x 970MB on qwen2-72b, EXPERIMENTS §Perf iteration 6); with it the
    # per-microbatch grads reduce-scatter into the sharded accumulator.
    _, _, _, o_spec_for_grads = abstract_state(cfg, mesh, opt, options)
    g_sharding = _named(mesh, o_spec_for_grads["mu"]) if o_spec_for_grads else None

    def train_step(params, opt_state, batch):
        k = options.microbatch
        if k > 1:
            # gradient accumulation: scan over k microbatches; activation
            # memory drops ~k-fold, FLOPs/collective volume unchanged, the
            # optimizer (and any DP grad sync) runs once on the accumulated
            # mean — the standard big-model memory/HBM-fit lever
            mb = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:])
                if getattr(x, "ndim", 0) >= 1 else x,
                batch,
            )
            g0 = jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), s
                ),
                params,
                g_sharding,
            )

            def mb_step(acc, b):
                acc_g, acc_loss, acc_ce, acc_aux = acc
                (loss, metrics), g = grad_fn(params, b)
                g = jax.tree.map(
                    lambda x, s: jax.lax.with_sharding_constraint(x, s),
                    g, g_sharding,
                )
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / k, acc_g, g
                )
                return (
                    acc_g,
                    acc_loss + loss / k,
                    acc_ce + metrics["ce"] / k,
                    acc_aux + metrics["moe_aux"] / k,
                ), None

            (grads, loss, ce, aux), _ = jax.lax.scan(
                mb_step,
                (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
                 jnp.zeros((), jnp.float32)),
                mb,
            )
            metrics = {"ce": ce, "moe_aux": aux}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        residual = None
        if options.compress_grads:
            # bf16 payload + fp32 error feedback; the residual rides in
            # opt_state["efb"] (created by abstract_state / init_opt_state)
            grads, residual = _compress(grads, opt_state["efb"])
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        core = {k: opt_state[k] for k in ("mu", "nu", "step")}
        params, core, om = adamw_update(opt, params, grads, core)
        opt_state = dict(core, efb=residual) if residual is not None else core
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics

    p_sds, o_sds, p_spec, o_spec = abstract_state(cfg, mesh, opt, options)
    b_sds = batch_sds(cfg, shape, mesh)
    shardings = lambda t: jax.tree.map(lambda x: x.sharding, t)
    jitted = jax.jit(
        train_step,
        in_shardings=(shardings(p_sds), shardings(o_sds), shardings(b_sds)),
        out_shardings=(
            shardings(p_sds),
            shardings(o_sds),
            None,
        ),
        donate_argnums=(0, 1),
    )
    return jitted, (p_sds, o_sds, b_sds)


# ---------------------------------------------------------------------------
# serve (prefill / decode)
# ---------------------------------------------------------------------------


def build_serve_step(
    cfg: ArchConfig,
    mesh,
    shape: ShapeConfig,
    *,
    options: StepOptions = StepOptions(),
):
    """Decode one token against a seq_len-deep cache (the decode_* cells).

    Returns (jitted decode fn, (params_sds, cache_sds, batch_sds))."""
    rules = activation_rules(cfg, mesh, batch=shape.global_batch)
    b = shape.global_batch

    def serve_step(params, cache, tokens, position):
        with sharding_rules(rules):
            return T.decode_step(cfg, params, tokens, cache, position)

    p_sds, _, p_spec, _ = abstract_state(cfg, mesh, None, options)
    # lambda of no args: batch/seq_len are static shape ints, not tracers
    c_shape = jax.eval_shape(lambda: T.init_cache(cfg, b, shape.seq_len))
    c_spec = cache_specs(cfg, c_shape, mesh, batch=b)
    c_sh = _named(mesh, c_spec)
    c_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), c_shape, c_sh
    )
    b_sds = batch_sds(cfg, shape, mesh)
    shardings = lambda t: jax.tree.map(lambda x: x.sharding, t)
    jitted = jax.jit(
        serve_step,
        in_shardings=(
            shardings(p_sds),
            shardings(c_sds),
            b_sds["tokens"].sharding,
            b_sds["position"].sharding,
        ),
        out_shardings=(None, shardings(c_sds)),
        donate_argnums=(1,),
    )
    return jitted, (p_sds, c_sds, b_sds)


def build_prefill_step(
    cfg: ArchConfig,
    mesh,
    shape: ShapeConfig,
    *,
    options: StepOptions = StepOptions(),
):
    """Prefill the cache from a full prompt (the prefill_* cells)."""
    rules = activation_rules(cfg, mesh, batch=shape.global_batch)
    b = shape.global_batch

    def prefill_step(params, cache, batch):
        with sharding_rules(rules):
            return T.prefill(
                cfg,
                params,
                batch["tokens"],
                cache,
                patch_embeds=batch.get("patch_embeds"),
                frame_embeds=batch.get("frame_embeds"),
            )

    p_sds, _, _, _ = abstract_state(cfg, mesh, None, options)
    c_shape = jax.eval_shape(lambda: T.init_cache(cfg, b, shape.seq_len))
    c_spec = cache_specs(cfg, c_shape, mesh, batch=b)
    c_sh = _named(mesh, c_spec)
    c_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), c_shape, c_sh
    )
    b_sds = batch_sds(cfg, shape, mesh)
    shardings = lambda t: jax.tree.map(lambda x: x.sharding, t)
    jitted = jax.jit(
        prefill_step,
        in_shardings=(shardings(p_sds), shardings(c_sds), shardings(b_sds)),
        out_shardings=(None, shardings(c_sds)),
        donate_argnums=(1,),
    )
    return jitted, (p_sds, c_sds, b_sds)
