"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must
succeed on the production meshes — single-pod (16, 16) = 256 chips and
multi-pod (2, 16, 16) = 512 chips — for every assigned architecture and
input shape.  Failures (sharding mismatch, OOM at compile, unsupported
collective) are bugs in the system, not in the dry-run.

Each cell writes one JSON artifact (memory analysis, cost analysis,
collective-byte breakdown, three-term roofline) to ``artifacts/dryrun/``;
re-runs skip complete cells unless ``--force``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi \
        --arch qwen2-72b --shape train_4k --force
    PYTHONPATH=src python -m repro.launch.dryrun --options remat=full
"""
# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first init):
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import roofline as RL  # noqa: E402
from repro.config import SHAPES, shape_applicable  # noqa: E402
from repro.configs import ARCH_IDS, get_arch  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    StepOptions,
    build_prefill_step,
    build_serve_step,
    build_train_step,
)

MESHES = ("single", "multi")


def cell_id(arch: str, shape: str, mesh: str, tag: str = "") -> str:
    suffix = f"__{tag}" if tag else ""
    return f"{arch}__{shape}__{mesh}{suffix}"


def parse_options(kvs: list[str]) -> StepOptions:
    kwargs = {}
    for kv in kvs:
        k, v = kv.split("=", 1)
        field = {f.name: f for f in dataclasses.fields(StepOptions)}[k]
        if v.lower() == "none":
            kwargs[k] = None
        elif field.type in ("bool", bool):
            kwargs[k] = v.lower() in ("1", "true", "yes")
        elif field.type in ("int", int):
            kwargs[k] = int(v)
        elif field.type in ("float", float):
            kwargs[k] = float(v)
        else:
            kwargs[k] = v
    return StepOptions(**kwargs)


def _flash_kernel_bytes(cfg, shape, mesh) -> float:
    """Per-device HBM traffic of the Pallas flash kernel replacing the
    chunked-attention oracle: Q/K/V/O streamed once per pass, ~3 passes
    (fwd + bwd recompute + bwd grads).  Used for the kernel-adjusted memory
    term (roofline.analyze docstring)."""
    if shape.kind == "decode":
        return 0.0
    axes = dict(mesh.shape)
    m = axes.get("model", 1)
    dsz = axes.get("data", 1) * axes.get("pod", 1)
    b_local = max(shape.global_batch // dsz, 1)
    h_local = cfg.n_heads // m if cfg.n_heads % m == 0 else cfg.n_heads
    kv_local = cfg.n_kv_heads // m if cfg.n_kv_heads % m == 0 else cfg.n_kv_heads
    kinds = cfg.layer_kinds()
    reps = cfg.n_layers // len(kinds)
    n_attn = sum(1 for k in kinds if k["mixer"] == "attention") * reps
    if cfg.encoder is not None:
        n_attn += cfg.encoder.n_layers + cfg.n_layers  # self + cross
    per_layer = (2 * h_local + 2 * kv_local) * b_local * shape.seq_len * cfg.hd * 2
    return 3.0 * n_attn * per_layer


def run_cell(
    arch_id: str,
    shape_id: str,
    mesh_kind: str,
    options: StepOptions,
    *,
    verbose: bool = True,
    moe_impl: str | None = None,
) -> dict:
    cfg = get_arch(arch_id)
    if moe_impl and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl=moe_impl))
    shape = SHAPES[shape_id]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size

    record: dict = {
        "arch": arch_id,
        "shape": shape_id,
        "mesh": mesh_kind,
        "mesh_shape": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "n_chips": n_chips,
        "options": dataclasses.asdict(options),
        "ok": False,
    }

    applicable, reason = shape_applicable(cfg, shape)
    if not applicable:
        record.update(skipped=True, skip_reason=reason, ok=True)
        return record

    t0 = time.time()
    if shape.kind == "train":
        step, (p_sds, o_sds, b_sds) = build_train_step(
            cfg, mesh, shape, options=options
        )
        args = (p_sds, o_sds, b_sds)
    elif shape.kind == "prefill":
        step, (p_sds, c_sds, b_sds) = build_prefill_step(
            cfg, mesh, shape, options=options
        )
        args = (p_sds, c_sds, b_sds)
    else:  # decode
        step, (p_sds, c_sds, b_sds) = build_serve_step(
            cfg, mesh, shape, options=options
        )
        args = (p_sds, c_sds, b_sds["tokens"], b_sds["position"])

    # `step` is already jitted with in/out shardings; lower against the SDSs
    lowered = step.lower(*args)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    report = RL.analyze(
        compiled,
        n_chips=n_chips,
        model_flops_total=RL.model_flops(cfg, shape),
        flash_kernel_bytes=_flash_kernel_bytes(cfg, shape, mesh),
    )
    record.update(
        ok=True,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        roofline=report.to_json(),
        params_total=cfg.param_count(),
        params_active=cfg.active_param_count(),
    )
    if verbose:
        mem_gb = report.memory["peak_bytes"] / 2**30
        print(
            f"  lower {t_lower:6.1f}s  compile {t_compile:6.1f}s  "
            f"mem/dev {mem_gb:6.2f} GiB  dominant={report.dominant}  "
            f"comp={report.compute_s*1e3:.2f}ms mem={report.memory_s*1e3:.2f}ms "
            f"coll={report.collective_s*1e3:.2f}ms",
            flush=True,
        )
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", nargs="*", default=None, help="arch ids (default all)")
    ap.add_argument("--shape", nargs="*", default=None, help="shape ids (default all)")
    ap.add_argument("--mesh", nargs="*", default=None, choices=MESHES)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix (perf variants)")
    ap.add_argument(
        "--options", nargs="*", default=[], help="StepOptions overrides k=v"
    )
    ap.add_argument("--moe-impl", default=None, choices=[None, "tp", "ep", "dense"],
                    help="override MoEConfig.impl for MoE archs")
    args = ap.parse_args()

    archs = args.arch or list(ARCH_IDS)
    shapes = args.shape or list(SHAPES)
    meshes = args.mesh or list(MESHES)
    options = parse_options(args.options)

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for mesh_kind in meshes:
        for arch_id in archs:
            arch_id = arch_id.replace("-", "_").replace(".", "_")
            for shape_id in shapes:
                cid = cell_id(arch_id, shape_id, mesh_kind, args.tag)
                path = os.path.join(args.out, cid + ".json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print(f"[skip] {cid} (done)", flush=True)
                            continue
                print(f"[cell] {cid}", flush=True)
                try:
                    record = run_cell(arch_id, shape_id, mesh_kind, options,
                                      moe_impl=args.moe_impl)
                except Exception as e:  # noqa: BLE001 — record and continue
                    record = {
                        "arch": arch_id,
                        "shape": shape_id,
                        "mesh": mesh_kind,
                        "ok": False,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures.append(cid)
                    print(f"  FAILED: {record['error'][:300]}", flush=True)
                with open(path, "w") as f:
                    json.dump(record, f, indent=1)
                jax.clear_caches()  # bound RAM across 64+ big compiles

    print(f"\ndone; {len(failures)} failures", flush=True)
    for cid in failures:
        print(f"  FAIL {cid}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
