"""Production meshes.

Functions (not module-level constants) so importing never touches jax
device state; the dry-run sets XLA_FLAGS for 512 fake devices before any
jax import, everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / scaled-down runs)."""
    return jax.make_mesh(shape, axes)


def make_spgemm_mesh(
    *,
    p: int | None = None,
    l: int = 1,
    p_r: int | None = None,
    p_c: int | None = None,
):
    """Mesh for the SpGEMM engines.

    ``p``          — square (r, c) grid side (``p_r = p_c = p``).
    ``p_r, p_c``   — non-square (r, c) grid (the paper's non-ideal
                     topologies); the 2.5D pull engine derives its virtual
                     depth L = max/min from the grid itself.
    ``l > 1``      — adds a depth axis: (l, r, c) mesh of l layer grids for
                     the stacked 2.5D formulation (square layers only).
    """
    if p is not None:
        p_r = p_c = p
    if p_r is None or p_c is None:
        raise ValueError("pass p= or both p_r= and p_c=")
    if l == 1:
        return jax.make_mesh((p_r, p_c), ("r", "c"))
    if p_r != p_c:
        raise ValueError(
            "stacked (l, r, c) meshes need square layer grids; non-square "
            "topologies run the 2.5D pull engine on the 2D (r, c) mesh"
        )
    return jax.make_mesh((l, p_r, p_c), ("l", "r", "c"))
