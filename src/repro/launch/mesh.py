"""Production meshes.

Functions (not module-level constants) so importing never touches jax
device state; the dry-run sets XLA_FLAGS for 512 fake devices before any
jax import, everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / scaled-down runs)."""
    return jax.make_mesh(shape, axes)


def make_spgemm_mesh(*, p: int, l: int = 1):
    """(l, r, c) mesh for the 2.5D SpGEMM engine: l layers of p x p."""
    if l == 1:
        return jax.make_mesh((p, p), ("r", "c"))
    return jax.make_mesh((l, p, p), ("l", "r", "c"))
