"""Serving driver: batched prefill + decode with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --batch 4 --max-new 16

Runs a batch of synthetic prompts through the ServingEngine (continuous
slot batching, greedy or temperature sampling) and reports tokens/s.  On
real hardware the same driver serves the full configs on the production
mesh; the decode-step sharding comes from the same rules as the dry-run's
``decode_*`` cells (serve options default to fsdp_axis=None — weights
replicated over `data`, sharded over `model` — because decode all-gathers
of FSDP-sharded weights per token dominate otherwise; see EXPERIMENTS §Perf).

``--queue N`` drains N requests through continuous batching (slot refill)
instead of one static round.  ``--tuning-db`` binds the tuner database so
serving-dispatch decisions persist across launches (the DB as a
serving-time asset, DESIGN.md §11); without it the engine falls back to
the static analytic decision.  ``--moe-impl spgemm`` routes MoE expert
dispatch through the block-sparse SpGEMM stack under a covering decode
envelope resolved per pattern bucket.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as T
from repro.serving.engine import GenerationConfig, ServingEngine


def _dispatch_spec(cfg, batch: int):
    """Covering decode-grid dispatch spec, resolved through the bucket
    cache (decision from the bound tuning DB when one is set)."""
    from repro.core.envelope import DispatchCache
    from repro.models.moe import DispatchSpec, moe_dims

    e, _ = moe_dims(cfg)
    tb = cfg.moe.token_block
    nb = (batch + tb - 1) // tb
    # static fallback envelope: covers every routing of the decode grid,
    # so no request is ever clipped; selective warmed envelopes come from
    # calibration traffic (benchmarks/bench_serving.py)
    full = np.ones((nb, e), bool)
    cache = DispatchCache(np.eye(e, dtype=bool), dtype=str(cfg.dtype))
    env, dec = cache.resolve(full)
    return DispatchSpec(envelope=env, backend=dec["backend"],
                        stack_capacity=dec["capacity"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queue", type=int, default=0,
                    help="drain N requests through continuous batching "
                         "(0 = one static generate round)")
    ap.add_argument("--moe-impl", default=None,
                    help="override cfg.moe.impl (e.g. spgemm) for MoE archs")
    ap.add_argument("--tuning-db", default=None,
                    help="tuning database path (created if missing); "
                         "omitted = static decisions only")
    args = ap.parse_args(argv)

    if args.tuning_db:
        from repro import tuner
        from repro.core import plan as plan_mod

        plan_mod.clear_cache()
        tuner.set_default_db(args.tuning_db)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.moe_impl:
        if cfg.moe is None:
            raise SystemExit(f"--moe-impl: arch {args.arch} has no MoE")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, impl=args.moe_impl))
    params = T.init_params(cfg, jax.random.key(args.seed))

    gen = GenerationConfig(max_new_tokens=args.max_new,
                           temperature=args.temperature, seed=args.seed)
    engine = ServingEngine(cfg, params, batch=args.batch,
                           max_len=args.max_len, gen=gen)
    if cfg.moe is not None and cfg.moe.impl == "spgemm":
        spec = _dispatch_spec(cfg, args.batch)
        engine.set_dispatch(spec)
        print(f"[serve] spgemm dispatch: capacity={spec.stack_capacity} "
              f"backend={spec.backend}")

    rng = np.random.default_rng(args.seed)
    n_req = args.queue if args.queue > 0 else args.batch
    prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
               for _ in range(n_req)]

    t0 = time.time()
    if args.queue > 0:
        outs = engine.serve(prompts)
        st = engine.last_serve_stats
        occ = (sum(s["occupancy"] for s in st["steps"]) / len(st["steps"])
               if st["steps"] else 0.0)
        print(f"[serve] queue drained: {st['n_requests']} requests, "
              f"{st['n_refills']} refills, mean occupancy {occ:.2f}")
    else:
        outs = engine.generate(prompts)
    dt = time.time() - t0
    n_tokens = sum(len(o) for o in outs)
    print(f"[serve] {n_req} requests, {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens / dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs[: min(4, len(outs))]):
        print(f"[serve] req{i}: {o[:12]}{'...' if len(o) > 12 else ''}")

    if args.tuning_db:
        from repro import tuner

        db = tuner.get_default_db()
        print(f"[serve] tuning db: {len(db)} record(s) at {db.path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
