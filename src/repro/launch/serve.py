"""Serving driver: batched prefill + decode with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --batch 4 --max-new 16

Runs a batch of synthetic prompts through the ServingEngine (continuous
slot batching, greedy or temperature sampling) and reports tokens/s.  On
real hardware the same driver serves the full configs on the production
mesh; the decode-step sharding comes from the same rules as the dry-run's
``decode_*`` cells (serve options default to fsdp_axis=None — weights
replicated over `data`, sharded over `model` — because decode all-gathers
of FSDP-sharded weights per token dominate otherwise; see EXPERIMENTS §Perf).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as T
from repro.serving.engine import GenerationConfig, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(cfg, jax.random.key(args.seed))

    gen = GenerationConfig(max_new_tokens=args.max_new,
                           temperature=args.temperature, seed=args.seed)
    engine = ServingEngine(cfg, params, batch=args.batch,
                           max_len=args.max_len, gen=gen)

    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
               for _ in range(args.batch)]

    t0 = time.time()
    outs = engine.generate(prompts)
    dt = time.time() - t0
    n_tokens = sum(len(o) for o in outs)
    print(f"[serve] {args.batch} requests, {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens / dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(outs[: min(4, len(outs))]):
        print(f"[serve] req{i}: {o[:12]}{'...' if len(o) > 12 else ''}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
