"""repro.launch — production mesh, step builders, dry-run, train/serve
drivers, elastic restart."""
