"""Purification driver: distributed density-matrix purification as a
long-running service loop.

    PYTHONPATH=src python -m repro.launch.purify --nb 16 --bs 8 \
        --p 2 --repeats 3 --sync-every 4 --tuning-db tuning_db.json

The production rendering of the paper's driving workload: build a sparse
model Hamiltonian, shard it ONCE onto the SpGEMM mesh, and run repeated
purifications (an SCF-like outer loop re-purifies a slowly-changing H)
entirely device-resident — the fused sign-iteration engine of
``core/signiter.py`` (DESIGN.md §5).  After the first purification every
later one is pure cache: the chain-step program, the multiply plan and
the jit executable are all reused (``plan.cache_stats()`` is printed per
repeat; ``builds`` must stay flat).

Engine selection is autotuned (DESIGN.md §6): with ``--tuning-db`` the
driver runs ``engine="auto"`` — the pattern-aware tuner picks (engine, L)
for H's sparsity pattern, measuring short trials on a cold database and
resolving *measurement-free* on a warm one; winners persist to the DB
file for the next launch.  Without a tuning DB the driver falls back to
the static ``--engine`` choice (default twofive) — a production loop
should not silently re-measure on every start.

On real hardware the same driver runs on a TPU slice mesh; here the
device count is faked for a laptop-scale proof (set
``--devices 0`` to use the real platform devices).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nb", type=int, default=16, help="block-grid side")
    ap.add_argument("--bs", type=int, default=8, help="atomic block size")
    ap.add_argument("--p", type=int, default=2, help="(r, c) grid side")
    ap.add_argument("--l", type=int, default=1, help="2.5D depth (l axis)")
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "cannon", "onesided", "gather",
                             "twofive"))
    ap.add_argument("--tuning-db", default=None,
                    help="tuning-database JSON path: enables engine "
                    "autotuning (warm-started when the file exists, "
                    "created/updated after measuring)")
    ap.add_argument("--occupancy", type=float, default=0.10)
    ap.add_argument("--threshold", type=float, default=1e-9)
    ap.add_argument("--filter-eps", type=float, default=1e-8)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--max-iter", type=int, default=100)
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--repeats", type=int, default=3,
                    help="purifications of the (perturbed) Hamiltonian")
    ap.add_argument("--devices", type=int, default=None,
                    help="fake host devices (default: enough for the mesh; "
                    "0 = use the real platform devices)")
    args = ap.parse_args(argv)

    need = args.p * args.p * max(args.l, 1)
    if args.devices != 0:
        fake = args.devices or need
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={fake} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import time

    import jax

    from repro import tuner
    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.signiter import density_matrix, trace
    from repro.launch.mesh import make_spgemm_mesh

    mesh = make_spgemm_mesh(p=args.p, l=args.l)
    engine = args.engine
    h = B.random_bsm(
        jax.random.key(0), nb=args.nb, bs=args.bs, occupancy=args.occupancy,
        pattern="decay", symmetric=True,
    )
    mu = 0.0
    plan_mod.clear_cache()
    if engine == "auto":
        if args.tuning_db:
            tuner.set_default_db(args.tuning_db)  # after clear_cache: it
            # resets the tuner binding along with every other cache level
        else:
            # no DB to consult or persist to: static fallback — a service
            # loop must not re-measure on every launch
            engine = "twofive"

    print(f"purify: H {h.shape[0]}x{h.shape[0]} "
          f"({float(h.occupancy()):.1%} blocks), mesh {dict(mesh.shape)}, "
          f"engine {engine}"
          + (f" (db {args.tuning_db})" if engine == "auto" else "")
          + f", sync_every {args.sync_every}")
    h_dev = B.shard_bsm(h, mesh)  # the one chain-boundary scatter
    for rep in range(args.repeats):
        t0 = time.perf_counter()
        p, stats = density_matrix(
            h_dev, mu, engine=engine,
            threshold=args.threshold, filter_eps=args.filter_eps,
            max_iter=args.max_iter, tol=args.tol,
            mode="fused", sync_every=args.sync_every,
        )
        dt = time.perf_counter() - t0
        cache = plan_mod.cache_stats()
        sweeps_s = stats.iterations / dt if dt > 0 else float("inf")
        print(f"  repeat {rep}: {stats.iterations} sweeps "
              f"({stats.host_syncs} syncs) in {dt:.2f}s "
              f"[{sweeps_s:.1f} sweeps/s], converged={stats.converged}, "
              f"trace(P)={float(trace(p)):.2f}, "
              f"cache builds={cache['builds']} "
              f"chain {cache['chain_hits']}h/{cache['chain_misses']}m "
              f"tuner {cache['tuner_hits']}h/{cache['tuner_misses']}m/"
              f"{cache['tuner_trials']}t")
        # SCF-like drift: perturb H on-device and re-purify (same pattern
        # -> every cache level hits; the chain program is reused as-is)
        h_dev = h_dev.scale(1.0 + 1e-3 * (rep + 1))
    final = plan_mod.cache_stats()
    # the chain program is compiled exactly once; program builds beyond it
    # can only come from the tuner's measured trials (cold DB), never from
    # the purification loop itself
    assert final["chain_misses"] == 1, final
    assert final["builds"] <= 1 + final["tuner_trials"], final
    assert final["tuner_misses"] <= 1, final  # one decision per pattern
    print(f"purify OK: one compiled chain step served "
          f"{final['chain_hits'] + 1} sweeps across {args.repeats} "
          f"purifications (builds={final['builds']}, "
          f"trials={final['tuner_trials']})")
    db = tuner.get_default_db()
    if db is not None and db.path:
        print(f"tuning db: {len(db)} record(s) at {db.path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
