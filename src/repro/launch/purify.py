"""Purification driver: distributed density-matrix purification as a
long-running service loop.

    PYTHONPATH=src python -m repro.launch.purify --nb 16 --bs 8 \
        --p 2 --l 2 --engine twofive --repeats 3 --sync-every 4

The production rendering of the paper's driving workload: build a sparse
model Hamiltonian, shard it ONCE onto the SpGEMM mesh, and run repeated
purifications (an SCF-like outer loop re-purifies a slowly-changing H)
entirely device-resident — the fused sign-iteration engine of
``core/signiter.py`` (DESIGN.md §4).  After the first purification every
later one is pure cache: the chain-step program, the multiply plan and
the jit executable are all reused (``plan.cache_stats()`` is printed per
repeat; ``builds`` must stay flat).

On real hardware the same driver runs on a TPU slice mesh; here the
device count is faked for a laptop-scale proof (set
``--devices 0`` to use the real platform devices).
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nb", type=int, default=16, help="block-grid side")
    ap.add_argument("--bs", type=int, default=8, help="atomic block size")
    ap.add_argument("--p", type=int, default=2, help="(r, c) grid side")
    ap.add_argument("--l", type=int, default=1, help="2.5D depth (l axis)")
    ap.add_argument("--engine", default="twofive",
                    choices=("cannon", "onesided", "gather", "twofive"))
    ap.add_argument("--occupancy", type=float, default=0.10)
    ap.add_argument("--threshold", type=float, default=1e-9)
    ap.add_argument("--filter-eps", type=float, default=1e-8)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--max-iter", type=int, default=100)
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--repeats", type=int, default=3,
                    help="purifications of the (perturbed) Hamiltonian")
    ap.add_argument("--devices", type=int, default=None,
                    help="fake host devices (default: enough for the mesh; "
                    "0 = use the real platform devices)")
    args = ap.parse_args(argv)

    need = args.p * args.p * max(args.l, 1)
    if args.devices != 0:
        fake = args.devices or need
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={fake} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import time

    import jax

    from repro.core import bsm as B
    from repro.core import plan as plan_mod
    from repro.core.signiter import density_matrix, trace
    from repro.launch.mesh import make_spgemm_mesh

    mesh = make_spgemm_mesh(p=args.p, l=args.l)
    engine = args.engine
    h = B.random_bsm(
        jax.random.key(0), nb=args.nb, bs=args.bs, occupancy=args.occupancy,
        pattern="decay", symmetric=True,
    )
    mu = 0.0
    plan_mod.clear_cache()

    print(f"purify: H {h.shape[0]}x{h.shape[0]} "
          f"({float(h.occupancy()):.1%} blocks), mesh {dict(mesh.shape)}, "
          f"engine {engine}, sync_every {args.sync_every}")
    h_dev = B.shard_bsm(h, mesh)  # the one chain-boundary scatter
    for rep in range(args.repeats):
        t0 = time.perf_counter()
        p, stats = density_matrix(
            h_dev, mu, engine=engine,
            threshold=args.threshold, filter_eps=args.filter_eps,
            max_iter=args.max_iter, tol=args.tol,
            mode="fused", sync_every=args.sync_every,
        )
        dt = time.perf_counter() - t0
        cache = plan_mod.cache_stats()
        sweeps_s = stats.iterations / dt if dt > 0 else float("inf")
        print(f"  repeat {rep}: {stats.iterations} sweeps "
              f"({stats.host_syncs} syncs) in {dt:.2f}s "
              f"[{sweeps_s:.1f} sweeps/s], converged={stats.converged}, "
              f"trace(P)={float(trace(p)):.2f}, "
              f"cache builds={cache['builds']} "
              f"chain {cache['chain_hits']}h/{cache['chain_misses']}m")
        # SCF-like drift: perturb H on-device and re-purify (same pattern
        # -> every cache level hits; the chain program is reused as-is)
        h_dev = h_dev.scale(1.0 + 1e-3 * (rep + 1))
    final = plan_mod.cache_stats()
    assert final["builds"] <= 1, final
    assert final["chain_misses"] == 1, final
    print(f"purify OK: one compiled chain step served "
          f"{final['chain_hits'] + 1} sweeps across {args.repeats} "
          f"purifications (builds={final['builds']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
