"""Jit'd public wrappers for the Pallas kernels.

``interpret`` resolution (``repro.config.pallas_interpret``): an explicit
argument wins, then the ``REPRO_PALLAS_INTERPRET`` env override, then
platform auto-detection — False (compiled Mosaic kernels) on real TPU,
True (validation mode — the kernel body executes in Python) elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import pallas_interpret
from repro.kernels import ref
from repro.kernels.block_spgemm import block_spgemm as _block_spgemm
from repro.kernels.flash_attention import flash_attention_single
from repro.kernels.stacks import ProductStacks  # noqa: F401  (re-export)


def _default_interpret() -> bool:
    cfg = pallas_interpret()
    if cfg is not None:
        return cfg
    return jax.default_backend() != "tpu"


def block_spgemm(
    a_blocks,
    b_blocks,
    pair_ok,
    *,
    capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
):
    """Filtered block-sparse matmul (see kernels/block_spgemm.py).

    ``capacity`` — static bound on surviving products (None = full cube);
    the scalar-prefetch grid iterates only that many steps.  ``tile`` —
    the MXU sub-tile shape (None resolves ``default_tile``).
    """
    if interpret is None:
        interpret = _default_interpret()
    return _block_spgemm(
        a_blocks, b_blocks, pair_ok, capacity=capacity, tile=tile,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bkv", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (b, h, sq, d)
    k: jax.Array,  # (b, hkv, skv, d)
    v: jax.Array,  # (b, hkv, skv, d)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Batched multi-head flash attention with GQA (hkv | h)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    assert h % hkv == 0, (h, hkv)
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    fn = functools.partial(
        flash_attention_single,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale,
        bq=bq,
        bkv=bkv,
        interpret=interpret,
    )
    return jax.vmap(jax.vmap(fn))(q, k, v)


__all__ = ["block_spgemm", "flash_attention", "ref"]
