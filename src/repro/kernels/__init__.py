"""Pallas TPU kernels for the perf-critical compute layers.

block_spgemm — DBCSR's filtered batched block GEMM (the paper's hot spot)
flash_attention — online-softmax attention for the LM stack

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
tests sweep shapes/dtypes in interpret mode (CPU) against the oracle.
"""
from repro.kernels import ops, ref
from repro.kernels.block_spgemm import block_spgemm
from repro.kernels.flash_attention import flash_attention_single

__all__ = ["ops", "ref", "block_spgemm", "flash_attention_single"]
