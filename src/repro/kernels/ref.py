"""Pure-jnp oracles for the Pallas kernels (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_spgemm_ref(
    a_blocks: jax.Array,  # (ni, nk, bs_r, bs_k)
    b_blocks: jax.Array,  # (nk, nj, bs_k, bs_c)
    pair_ok: jax.Array,  # (ni, nk, nj) bool — on-the-fly filter mask
    *,
    storage_dtype=None,
    out_dtype=None,
) -> jax.Array:
    """Filtered block-sparse matmul: C_ij = sum_k ok[i,k,j] * A_ik @ B_kj.

    The mixed-precision oracle: operands are (optionally) rounded to the
    reduced ``storage_dtype`` first — exactly the quantization a bf16/f8
    block store applies — then every product accumulates in f32 (matching
    the kernel's MXU accumulator), and the result is cast to ``out_dtype``
    (default: the storage dtype).  With both dtypes None this is the exact
    f32 reference the kernels are asserted against; with
    ``storage_dtype=bfloat16`` it is the tolerance baseline for the
    reduced-precision pipeline (documented in DESIGN.md §2: bf16 storage
    stays within ~3e-2 relative of the f32 oracle for unit-scaled blocks,
    f8 within ~2e-1).
    """
    if storage_dtype is not None:
        a_blocks = a_blocks.astype(storage_dtype)
        b_blocks = b_blocks.astype(storage_dtype)
    if out_dtype is None:
        out_dtype = a_blocks.dtype
    okf = pair_ok.astype(jnp.float32)
    c = jnp.einsum(
        "ikj,ikab,kjbc->ijac",
        okf,
        a_blocks.astype(jnp.float32),
        b_blocks.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
    )
    return c.astype(out_dtype)


def attention_ref(
    q: jax.Array,  # (sq, d)
    k: jax.Array,  # (skv, d)
    v: jax.Array,  # (skv, d)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Single-head attention oracle with causal/sliding-window masking and
    logit soft-capping (gemma2-style tanh cap).

    q_offset: absolute position of q[0] relative to k[0] (for decode where
    the query block sits at the end of the KV range).
    """
    sq, d = q.shape
    skv = k.shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = (
        jnp.einsum("qd,kd->qk", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (can happen with tiny windows) -> zeros, not NaN
    p = jnp.where(jnp.any(mask, -1, keepdims=True), p, 0.0)
    return jnp.einsum("qk,kd->qd", p, v.astype(jnp.float32)).astype(q.dtype)
