"""Pallas TPU kernel: filtered block-sparse matmul (DBCSR's batched
small-block GEMM stage, adapted to the MXU).

The paper offloads batches of small-block multiplications to LIBXSMM/GPU
with an on-the-fly norm filter.  TPU adaptation (DESIGN.md §2): the kernel
iterates the *compacted product list* (``kernels/stacks.py`` — DBCSR's
stacks), not the (ni, nj, nk) cube.  The list's int32 index arrays are
scalar-prefetched (``pltpu.PrefetchScalarGridSpec``), so the BlockSpec
index maps steer each grid step's HBM->VMEM DMA straight to the blocks of
the n-th surviving product: filtered triples cost neither grid steps nor
memory traffic.  Products are sorted by output tile with k-runs
contiguous; an f32 VMEM scratch accumulates each run (``first`` resets it,
``write`` casts it back to the output tile), and padding entries repeat
the final triple's coordinates so they re-visit resident blocks and issue
no MXU work (``valid`` = 0).

Atomic blocks may be rectangular (bs_r x bs_k times bs_k x bs_c); on real
hardware each dimension should be MXU-aligned (multiples of 128 — the
interpret-mode tests also sweep small sizes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.stacks import (
    ProductStacks,
    compact_pair_mask,
    resolve_capacity,
)


def _stacks_kernel(
    ia_ref, ik_ref, ij_ref, tile_ref, first_ref, write_ref, valid_ref,
    a_ref, b_ref, c_ref, acc_ref,
):
    n = pl.program_id(0)

    @pl.when(first_ref[n] == 1)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid_ref[n] == 1)
    def _mac():
        acc_ref[...] += jnp.dot(
            a_ref[0, 0].astype(jnp.float32),
            b_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(write_ref[n] == 1)
    def _write():
        c_ref[0, 0] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ni", "nj", "interpret"))
def block_spgemm_stacks(
    a_blocks: jax.Array,  # (ni, nk, bs_r, bs_k)
    b_blocks: jax.Array,  # (nk, nj, bs_k, bs_c)
    stacks: ProductStacks,
    *,
    ni: int,
    nj: int,
    interpret: bool = False,
) -> jax.Array:
    """C tiles of the compacted product list; one product per grid step.

    Only output tiles with at least one surviving product are written —
    callers zero the rest via the tile mask (``jnp.any(pair_ok, axis=1)``),
    exactly the ``c_mask`` they already compute.
    """
    from jax.experimental.pallas import tpu as pltpu

    _, _, bs_r, bs_k = a_blocks.shape
    nk, nj2, bs_k2, bs_c = b_blocks.shape
    assert bs_k == bs_k2, (a_blocks.shape, b_blocks.shape)
    assert nj2 == nj, (nj2, nj)
    out = jax.ShapeDtypeStruct((ni, nj, bs_r, bs_c), a_blocks.dtype)
    cap = stacks.capacity
    if cap == 0:
        return jnp.zeros(out.shape, out.dtype)

    # index maps receive (grid idx, *scalar prefetch refs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(cap,),
        in_specs=[
            pl.BlockSpec(
                (1, 1, bs_r, bs_k),
                lambda n, ia, ik, ij, *_: (ia[n], ik[n], 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, bs_k, bs_c),
                lambda n, ia, ik, ij, *_: (ik[n], ij[n], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bs_r, bs_c),
            lambda n, ia, ik, ij, *_: (ia[n], ij[n], 0, 0),
        ),
        scratch_shapes=[pltpu.VMEM((bs_r, bs_c), jnp.float32)],
    )
    return pl.pallas_call(
        _stacks_kernel,
        grid_spec=grid_spec,
        out_shape=out,
        interpret=interpret,
    )(*stacks, a_blocks, b_blocks)


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def block_spgemm(
    a_blocks: jax.Array,  # (ni, nk, bs_r, bs_k)
    b_blocks: jax.Array,  # (nk, nj, bs_k, bs_c)
    pair_ok: jax.Array,  # (ni, nk, nj) bool/int
    *,
    capacity: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """C_ij = sum_k ok[i,k,j] * A_ik @ B_kj via the compacted product list.

    ``capacity`` bounds the surviving products (static).  None means the
    full cube — always sound, no compaction win; callers with a concrete
    pattern pass the exact bucketed count (``plan.get_product_stacks``) so
    grid steps and DMA traffic shrink to the survivors.
    """
    ni, nk, bs_r, bs_k = a_blocks.shape
    nk2, nj, bs_k2, bs_c = b_blocks.shape
    assert nk == nk2 and bs_k == bs_k2, (a_blocks.shape, b_blocks.shape)
    assert pair_ok.shape == (ni, nk, nj)
    cap = resolve_capacity(capacity, ni * nk * nj)
    stacks = compact_pair_mask(pair_ok, capacity=cap)
    c = block_spgemm_stacks(
        a_blocks, b_blocks, stacks, ni=ni, nj=nj, interpret=interpret
    )
    # tiles with no surviving product are never visited by the grid
    c_mask = jnp.any(pair_ok.astype(bool), axis=1)
    return jnp.where(c_mask[:, :, None, None], c, jnp.zeros((), c.dtype))
