"""Pallas TPU kernel: filtered block-sparse matmul (DBCSR's batched
small-block GEMM stage, adapted to the MXU).

The paper offloads batches of small-block multiplications to LIBXSMM/GPU
with an on-the-fly norm filter.  TPU adaptation (DESIGN.md §2): the kernel
iterates the *compacted product list* (``kernels/stacks.py`` — DBCSR's
stacks), not the (ni, nj, nk) cube.  The list's int32 index arrays are
scalar-prefetched (``pltpu.PrefetchScalarGridSpec``), so the BlockSpec
index maps steer each grid step's HBM->VMEM DMA straight to the blocks of
the n-th surviving product: filtered triples cost neither grid steps nor
memory traffic.

**Tile grid.**  Each (bs_r, bs_k, bs_c) block product is decomposed into a
(tm, tk, tn) tile grid — grid = (bs_r/tm, bs_c/tn, capacity, bs_k/tk) with
the output-tile coordinates outermost and the contraction tiles innermost,
so one (tm, tn) f32 VMEM accumulator still fuses a whole k-run: ``first``
resets it at the run's first product and tk == 0, ``write`` casts it back
at the run's last product and the final tk.  Pallas double-buffers the
operand tile DMAs across grid steps (the revision pipeline), so a block
larger than one VMEM-resident tile streams tile-by-tile instead of
overflowing VMEM; blocks at or under the tile size keep the one-step-per-
product shape of the whole-block kernel (the degenerate 1x1x·x1 grid).
The cost of tiling is operand re-streaming — A tiles are fetched once per
output column tile and B tiles once per output row tile — which
``local_mm.local_stage_cost`` prices when the tuner searches tile shapes.

Mixed precision: operand tiles may be stored in bf16 (or f8 where the
platform supports it); the MXU accumulates in f32 regardless
(``preferred_element_type``), and the output tile is cast back to the
storage dtype only at write-back.

Atomic blocks may be rectangular (bs_r x bs_k times bs_k x bs_c).  On real
hardware every tile must be lane-aligned — ``validate_tile`` raises a
clear error up front instead of a Mosaic compile failure; interpret mode
(tests, CPU CI) sweeps small unaligned sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.stacks import (
    ProductStacks,
    compact_pair_mask,
    resolve_capacity,
)

LANE = 128  # minor-dim tiling of every TPU vreg / the MXU edge
# minimum sublane count (second-to-minor dim) per storage itemsize:
# f32 -> (8, 128), bf16 -> (16, 128), int8/f8 -> (32, 128)
_SUBLANES = {4: 8, 2: 16, 1: 32}

# Default ceiling on a single tile dimension: keeps the double-buffered
# working set a small fraction of VMEM (see tile_working_set_bytes) while
# staying MXU-shaped.  Blocks at or under this stay whole-block.
MAX_TILE = 256

# Per-core VMEM the operand/accumulator pipeline must fit in (TPU v4/v5
# class hardware).  Above half of it, Pallas can no longer double-buffer.
VMEM_BUDGET_BYTES = 16 * 2**20


def min_sublane(dtype) -> int:
    """Minimum sublane multiple of a VMEM tile for this storage dtype."""
    return _SUBLANES.get(jnp.dtype(dtype).itemsize, 8)


def _divisor_tile(n: int, cap: int, align: int) -> int:
    """Largest divisor of ``n`` that is <= cap, preferring multiples of
    ``align`` (so the chosen tile wastes no lanes/sublanes)."""
    if n <= cap:
        return n
    best, best_aligned = 1, 0
    for d in range(1, n + 1):
        if d > cap:
            break
        if n % d:
            continue
        best = d
        if d % align == 0:
            best_aligned = d
    return best_aligned or best


def default_tile(
    bs_r: int, bs_k: int, bs_c: int, dtype=jnp.float32
) -> tuple[int, int, int]:
    """The shipped tile choice for a block shape: whole-block up to
    ``MAX_TILE`` per dim, else the largest lane-preferring divisor.  The
    tuner may override this per (block shape, dtype, platform)."""
    sl = min_sublane(dtype)
    return (
        _divisor_tile(bs_r, MAX_TILE, sl),
        _divisor_tile(bs_k, MAX_TILE, LANE),
        _divisor_tile(bs_c, MAX_TILE, LANE),
    )


def validate_tile(
    bs_r: int,
    bs_k: int,
    bs_c: int,
    tile: tuple[int, int, int],
    dtype=jnp.float32,
    *,
    interpret: bool = False,
) -> tuple[int, int, int]:
    """Validate a (tm, tk, tn) tile against a block shape *up front*.

    Raises ``ValueError`` with an actionable message instead of letting an
    unaligned or non-dividing tile surface as a Mosaic compile failure.
    Interpret mode only requires divisibility (the interpreter has no lane
    layout); compiled mode additionally requires lane/sublane alignment:
    tk and tn are minor (lane) dims of the A/B/C tiles and must be
    multiples of 128; tm is a sublane dim and must be a multiple of the
    dtype's minimum sublane count (8 f32 / 16 bf16 / 32 f8).
    """
    try:
        tm, tk, tn = (int(t) for t in tile)
    except (TypeError, ValueError) as e:
        raise ValueError(
            f"tile must be a (tm, tk, tn) integer triple, got {tile!r}"
        ) from e
    if min(tm, tk, tn) <= 0:
        raise ValueError(f"tile dims must be positive, got {(tm, tk, tn)}")
    for name, bs, t in (("bs_r", bs_r, tm), ("bs_k", bs_k, tk),
                        ("bs_c", bs_c, tn)):
        if bs % t:
            raise ValueError(
                f"tile dim {t} does not divide block dim {name}={bs}: the "
                f"tile grid must cover the block exactly — pick a divisor "
                f"of {bs} or pad the atomic block"
            )
    if not interpret:
        sl = min_sublane(dtype)
        if tk % LANE or tn % LANE:
            raise ValueError(
                f"tile (tm={tm}, tk={tk}, tn={tn}) cannot be lane-aligned "
                f"on this platform: tk and tn are minor (lane) dims and "
                f"must be multiples of {LANE} for compiled Mosaic — use "
                f"interpret mode for small blocks, or pad the block"
            )
        if tm % sl:
            raise ValueError(
                f"tile dim tm={tm} is not sublane-aligned for "
                f"{jnp.dtype(dtype).name} (requires a multiple of {sl})"
            )
    return tm, tk, tn


def tile_working_set_bytes(
    bs_r: int,
    bs_k: int,
    bs_c: int,
    tile: tuple[int, int, int] | None,
    dtype=jnp.float32,
) -> float:
    """VMEM bytes the pipeline holds resident for one grid step: the
    double-buffered A/B operand tiles and C output tile at storage width,
    plus the single f32 accumulator."""
    tm, tk, tn = tile or (bs_r, bs_k, bs_c)
    itemsize = jnp.dtype(dtype).itemsize
    db = 2.0  # Pallas revision double-buffering
    return (
        db * (tm * tk + tk * tn + tm * tn) * itemsize  # A, B, C tiles
        + tm * tn * 4.0  # f32 accumulator scratch
    )


def tile_candidates(
    bs_r: int,
    bs_k: int,
    bs_c: int,
    dtype=jnp.float32,
    *,
    interpret: bool = False,
) -> list[tuple[int, int, int] | None]:
    """Distinct tile shapes worth measuring for one block shape.

    ``None`` (the default_tile resolution) always leads; explicit
    candidates cover the whole block, the MXU edge, and the default
    ceiling — deduplicated and filtered through ``validate_tile``.  In
    interpret mode half-block tiles join so CPU tests/benchmarks exercise
    a real tile grid at small sizes.
    """
    raw: list[tuple[int, int, int]] = [
        (bs_r, bs_k, bs_c),
        default_tile(bs_r, bs_k, bs_c, dtype),
    ]
    sl = min_sublane(dtype)
    for cap in (LANE, MAX_TILE):
        raw.append((
            _divisor_tile(bs_r, cap, sl),
            _divisor_tile(bs_k, cap, LANE),
            _divisor_tile(bs_c, cap, LANE),
        ))
    if interpret:
        if bs_r % 2 == 0 and bs_k % 2 == 0 and bs_c % 2 == 0:
            raw.append((bs_r // 2, bs_k // 2, bs_c // 2))
    out: list[tuple[int, int, int] | None] = [None]
    seen = {default_tile(bs_r, bs_k, bs_c, dtype)}  # what None resolves to
    for t in raw:
        if t in seen:
            continue
        try:
            validate_tile(bs_r, bs_k, bs_c, t, dtype, interpret=interpret)
        except ValueError:
            continue
        seen.add(t)
        out.append(t)
    return out


def _tiled_kernel(
    ia_ref, ik_ref, ij_ref, tile_ref, first_ref, write_ref, valid_ref,
    a_ref, b_ref, c_ref, acc_ref,
):
    n = pl.program_id(2)
    tk = pl.program_id(3)
    ntk = pl.num_programs(3)

    @pl.when((first_ref[n] == 1) & (tk == 0))
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid_ref[n] == 1)
    def _mac():
        acc_ref[...] += jnp.dot(
            a_ref[0, 0].astype(jnp.float32),
            b_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when((write_ref[n] == 1) & (tk == ntk - 1))
    def _write():
        c_ref[0, 0] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("ni", "nj", "tile", "interpret")
)
def block_spgemm_stacks(
    a_blocks: jax.Array,  # (ni, nk, bs_r, bs_k)
    b_blocks: jax.Array,  # (nk, nj, bs_k, bs_c)
    stacks: ProductStacks,
    *,
    ni: int,
    nj: int,
    tile: tuple[int, int, int] | None = None,
    interpret: bool = False,
) -> jax.Array:
    """C tiles of the compacted product list over the (tm, tk, tn) grid.

    Only output tiles with at least one surviving product are written —
    callers zero the rest via the tile mask (``jnp.any(pair_ok, axis=1)``),
    exactly the ``c_mask`` they already compute.  ``tile=None`` resolves
    ``default_tile`` (whole-block for blocks up to ``MAX_TILE`` per dim).
    """
    from jax.experimental.pallas import tpu as pltpu

    _, _, bs_r, bs_k = a_blocks.shape
    nk, nj2, bs_k2, bs_c = b_blocks.shape
    assert bs_k == bs_k2, (a_blocks.shape, b_blocks.shape)
    assert nj2 == nj, (nj2, nj)
    dtype = a_blocks.dtype
    out = jax.ShapeDtypeStruct((ni, nj, bs_r, bs_c), dtype)
    cap = stacks.capacity
    if cap == 0:
        return jnp.zeros(out.shape, out.dtype)
    if tile is None:
        tile = default_tile(bs_r, bs_k, bs_c, dtype)
    tm, tk, tn = validate_tile(
        bs_r, bs_k, bs_c, tile, dtype, interpret=interpret
    )
    n_tm, n_tk, n_tn = bs_r // tm, bs_k // tk, bs_c // tn

    # Output sub-tile coordinates outermost, contraction tiles innermost:
    # for one (ti, tj) the whole product list streams past the single
    # (tm, tn) accumulator, so k-run fusion is preserved per sub-tile.
    # Index maps receive (grid idx..., *scalar prefetch refs).
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(n_tm, n_tn, cap, n_tk),
        in_specs=[
            pl.BlockSpec(
                (1, 1, tm, tk),
                lambda ti, tj, n, tkk, ia, ik, ij, *_: (
                    ia[n], ik[n], ti, tkk
                ),
            ),
            pl.BlockSpec(
                (1, 1, tk, tn),
                lambda ti, tj, n, tkk, ia, ik, ij, *_: (
                    ik[n], ij[n], tkk, tj
                ),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, tm, tn),
            lambda ti, tj, n, tkk, ia, ik, ij, *_: (ia[n], ij[n], ti, tj),
        ),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
    )
    return pl.pallas_call(
        _tiled_kernel,
        grid_spec=grid_spec,
        out_shape=out,
        interpret=interpret,
    )(*stacks, a_blocks, b_blocks)


@functools.partial(
    jax.jit, static_argnames=("capacity", "tile", "interpret")
)
def block_spgemm(
    a_blocks: jax.Array,  # (ni, nk, bs_r, bs_k)
    b_blocks: jax.Array,  # (nk, nj, bs_k, bs_c)
    pair_ok: jax.Array,  # (ni, nk, nj) bool/int
    *,
    capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool = False,
) -> jax.Array:
    """C_ij = sum_k ok[i,k,j] * A_ik @ B_kj via the compacted product list.

    ``capacity`` bounds the surviving products (static).  None means the
    full cube — always sound, no compaction win; callers with a concrete
    pattern pass the exact bucketed count (``plan.get_product_stacks``) so
    grid steps and DMA traffic shrink to the survivors.  ``tile`` picks
    the MXU sub-tile shape (None = ``default_tile``).
    """
    ni, nk, bs_r, bs_k = a_blocks.shape
    nk2, nj, bs_k2, bs_c = b_blocks.shape
    assert nk == nk2 and bs_k == bs_k2, (a_blocks.shape, b_blocks.shape)
    assert pair_ok.shape == (ni, nk, nj)
    cap = resolve_capacity(capacity, ni * nk * nj)
    stacks = compact_pair_mask(pair_ok, capacity=cap)
    c = block_spgemm_stacks(
        a_blocks, b_blocks, stacks, ni=ni, nj=nj, tile=tile,
        interpret=interpret,
    )
    # tiles with no surviving product are never visited by the grid
    c_mask = jnp.any(pair_ok.astype(bool), axis=1)
    return jnp.where(c_mask[:, :, None, None], c, jnp.zeros((), c.dtype))
