"""Pallas TPU kernel: filtered block-sparse matmul (DBCSR's batched
small-block GEMM stage, adapted to the MXU).

The paper offloads batches of small-block multiplications to LIBXSMM/GPU
with an on-the-fly norm filter.  TPU adaptation (DESIGN.md §2): atomic
blocks are packed into MXU-aligned tiles (bs multiple of 128 on hardware;
the interpret-mode tests also sweep small sizes), and the filter becomes a
`@pl.when` predicate on the (i, k, j) product — a predicated-off tile issues
no MXU work on hardware, which is exactly DBCSR's "skip products whose norm
product falls below the threshold".

Grid: (ni, nj, nk) with k innermost; a VMEM f32 scratch accumulates the
k-sum (standard TPU matmul revisiting pattern) and is written back to the
output tile at the last k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spgemm_kernel(ok_ref, a_ref, b_ref, c_ref, acc_ref, *, nk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ok_ref[0, 0, 0] != 0)
    def _mac():
        acc_ref[...] += jnp.dot(
            a_ref[0, 0].astype(jnp.float32),
            b_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k_step == nk - 1)
    def _write():
        c_ref[0, 0] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_spgemm(
    a_blocks: jax.Array,  # (ni, nk, bs, bs)
    b_blocks: jax.Array,  # (nk, nj, bs, bs)
    pair_ok: jax.Array,  # (ni, nk, nj) bool/int
    *,
    interpret: bool = False,
) -> jax.Array:
    """C_ij = sum_k ok[i,k,j] * A_ik @ B_kj, one (i,j,k) block per grid step."""
    ni, nk, bs_r, bs_k = a_blocks.shape
    nk2, nj, bs_k2, bs_c = b_blocks.shape
    assert nk == nk2 and bs_k == bs_k2, (a_blocks.shape, b_blocks.shape)
    assert pair_ok.shape == (ni, nk, nj)
    ok = pair_ok.astype(jnp.int32)

    grid = (ni, nj, nk)
    out = jax.ShapeDtypeStruct((ni, nj, bs_r, bs_c), a_blocks.dtype)
    kernel = functools.partial(_spgemm_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # filter scalar for this (i, k, j) triple
            pl.BlockSpec((1, 1, 1), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, 1, bs_r, bs_k), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((1, 1, bs_k, bs_c), lambda i, j, k: (k, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bs_r, bs_c), lambda i, j, k: (i, j, 0, 0)),
        out_shape=out,
        scratch_shapes=[_vmem_scratch(bs_r, bs_c)],
        interpret=interpret,
    )(ok, a_blocks, b_blocks)


def _vmem_scratch(bs_r: int, bs_c: int):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((bs_r, bs_c), jnp.float32)
