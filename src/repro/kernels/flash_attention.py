"""Pallas TPU kernel: flash attention (online-softmax, VMEM-tiled).

Used by the LM stack on real TPU hardware for train/prefill attention; the
pure-jnp chunked path (models/attention.py) is the CPU/dry-run route.  The
kernel supports the features the assigned architectures need: causal
masking, sliding windows (gemma2 local layers), logit soft-capping (gemma2)
and an sm scale.

Single-head kernel over q (sq, d), k/v (skv, d); batch/head dims are vmapped
in ops.flash_attention (pallas_call composes with vmap by prepending grid
dims).  Grid (nq, nkv), kv innermost; m/l/acc scratch persists across the kv
sweep (TPU grid steps execute sequentially on a core).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    softcap: float | None,
    bq: int,
    bkv: int,
    nkv: int,
):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bkv

    # block-level skip: on hardware a predicated-off step issues no MXU work
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
    if window is not None:
        # newest key this block could need: q_end; oldest: q_start - window + 1
        run = jnp.logical_and(run, k_start + bkv - 1 > q_start - window)

    @pl.when(run)
    def _step():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v_ref.dtype).astype(jnp.float32),
            v_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bkv", "interpret"),
)
def flash_attention_single(
    q: jax.Array,  # (sq, d)
    k: jax.Array,  # (skv, d)
    v: jax.Array,  # (skv, d)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    sq, d = q.shape
    skv = k.shape[0]
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)
    if scale is None:
        scale = float(1.0 / (d**0.5))
    nq, nkv = sq // bq, skv // bkv

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        bq=bq,
        bkv=bkv,
        nkv=nkv,
    )
    return pl.pallas_call(
        kernel,
        grid=(nq, nkv),
        in_specs=[
            pl.BlockSpec((bq, d), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((bkv, d), lambda qi, ki: (ki, 0)),
            pl.BlockSpec((bkv, d), lambda qi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda qi, ki: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
