"""Product-list compaction: DBCSR's "stack generation" for the local stage.

DBCSR never multiplies the full (i, k, j) cube: the host walks the block
structure once, collects the surviving (i, k, j) triples into *stacks*, and
hands only those to the batched-GEMM backends (LIBXSMM / GPU), so local
FLOPs scale with occupancy, not grid volume.  This module is the TPU/XLA
rendering of that stage (DESIGN.md §2): the boolean ``pair_filter`` cube is
compacted into a *padded product list* — fixed-capacity int32 index arrays
(XLA needs static shapes) sorted by output tile with k-runs contiguous —
that drives both

* the ``stacks`` jnp backend (gather A/B by the list, one batched
  ``dot_general``, segment-sum into C), and
* the scalar-prefetch Pallas kernel (``kernels/block_spgemm.py``), whose
  grid iterates the list directly.

``compact_pair_mask`` is pure jnp, so it works on concrete host data (the
plan layer caches the result per sparsity-pattern signature,
``core/plan.py``) *and* on traced values inside shard_map engine bodies
(via ``jnp.flatnonzero(..., size=capacity)``).  Capacity is bucketed to
powers of two so one compiled program serves many patterns.
"""
from __future__ import annotations

import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ProductStacks(NamedTuple):
    """Padded product list over surviving (i, k, j) block triples.

    All fields are int32 arrays of shape (capacity,), sorted by output tile
    (i, j) with the k-run of each tile contiguous — padding entries repeat
    the last real triple's indices so kernels revisit (never re-fetch) the
    same blocks and issue no work.

    ia / ik / ij — block coordinates of each product (A_ik . B_kj -> C_ij)
    tile         — flattened output tile id, ``ia * nj + ij``
    first        — 1 at the first product of each tile's k-run (reset acc)
    write        — 1 at the last grid step touching a tile (write-back)
    valid        — 1 for real products, 0 for padding
    """

    ia: jax.Array
    ik: jax.Array
    ij: jax.Array
    tile: jax.Array
    first: jax.Array
    write: jax.Array
    valid: jax.Array

    @property
    def capacity(self) -> int:
        return self.ia.shape[0]


def bucket_capacity(n: int, *, minimum: int = 8) -> int:
    """Round a product count up to a power-of-two bucket.

    Bucketing bounds the number of distinct compiled programs: every
    pattern whose count lands in the same bucket reuses one executable
    (the padded tail is masked out).  ``n == 0`` keeps capacity 0 — the
    empty-product-list edge case short-circuits to a zero result.
    """
    if n <= 0:
        return 0
    cap = minimum
    while cap < n:
        cap *= 2
    return cap


def resolve_capacity(capacity: int | None, cube: int) -> int:
    """Effective static capacity: None means the full cube (always sound),
    an explicit bound is clamped to it.  The single policy point shared by
    the jnp-stacks and Pallas paths."""
    return cube if capacity is None else min(capacity, cube)


def product_count(pair_ok) -> int:
    """Number of surviving products of a *concrete* pair_filter cube."""
    return int(np.asarray(pair_ok).sum())


def pair_cube(
    mask_a, mask_b, norms_a=None, norms_b=None, threshold: float = 0.0
) -> np.ndarray:
    """Concrete (ni, nk, nj) pair-filter cube on the host (pure numpy).

    Presence product of the operand masks, AND — when ``threshold`` is
    active — the paper's norm-product screen ``|A_ik| |B_kj| > threshold``.
    The single host-side derivation shared by ``engine.multiply`` (stack
    capacities of one concrete multiply) and the envelope layer
    (``core/envelope.py`` unions these cubes over a whole chain).
    """
    am = np.asarray(mask_a, bool)
    bm = np.asarray(mask_b, bool)
    ok = am[:, :, None] & bm[None, :, :]
    if threshold > 0.0 and norms_a is not None:
        an = np.asarray(norms_a, np.float32)
        bn = np.asarray(norms_b, np.float32)
        ok &= an[:, :, None] * bn[None, :, :] > threshold
    return ok


def pattern_signature(pair_ok) -> bytes:
    """Digest of a concrete (ni, nk, nj) filter cube — the plan-cache key
    for compacted product lists (repeated sparsity patterns hit)."""
    ok = np.asarray(pair_ok).astype(bool)
    h = hashlib.sha1(repr(ok.shape).encode())
    h.update(np.packbits(ok).tobytes())
    return h.digest()


def compact_pair_mask(pair_ok: jax.Array, *, capacity: int) -> ProductStacks:
    """Compact a (ni, nk, nj) filter cube into a ``ProductStacks`` list.

    Works traced (inside jit/shard_map, ``capacity`` static) or concrete.
    If more than ``capacity`` products survive, the excess is silently
    dropped — callers must supply a sound capacity (exact count on the
    host path, an upper bound on the traced path; see
    ``plan.get_product_stacks`` / ``engine.multiply``).
    """
    ni, nk, nj = pair_ok.shape
    if capacity <= 0:
        z = jnp.zeros((0,), jnp.int32)
        return ProductStacks(z, z, z, z, z, z, z)
    # (i, j, k) row-major order: output tiles consecutive, k-runs contiguous
    okt = jnp.transpose(pair_ok.astype(bool), (0, 2, 1))
    flat = jnp.flatnonzero(okt.ravel(), size=capacity, fill_value=-1)
    flat = flat.astype(jnp.int32)
    valid = flat >= 0
    # padding repeats the last real triple (or triple 0 when none survive)
    last = jnp.max(jnp.where(valid, flat, 0))
    flat = jnp.where(valid, flat, last)
    ia = flat // (nj * nk)
    ij = (flat // nk) % nj
    ik = flat % nk
    tile = ia * nj + ij
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), tile[:-1]])
    nxt = jnp.concatenate([tile[1:], jnp.full((1,), -1, jnp.int32)])
    return ProductStacks(
        ia=ia,
        ik=ik,
        ij=ij,
        tile=tile,
        first=(tile != prev).astype(jnp.int32),
        write=(tile != nxt).astype(jnp.int32),
        valid=valid.astype(jnp.int32),
    )
