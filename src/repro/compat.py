"""jax version-compat shims.

The codebase targets the current jax API (``jax.shard_map``, ``lax.pcast``);
installed runtimes may be older (0.4.x ships ``shard_map`` only under
``jax.experimental`` with a ``check_rep`` kwarg, and has no ``pcast`` at
all).  Everything that builds a shard_map program imports from here instead
of from jax directly:

    from repro.compat import shard_map, pcast

``pcast(x, axes, to="varying")`` only adjusts the varying-manifest
annotation used by the new sharding-checker; on runtimes without it the
identity is semantically exact (there is no checker to inform).
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "pcast"]


if hasattr(jax, "shard_map"):  # jax >= 0.5: the public API
    shard_map = jax.shard_map
else:  # jax 0.4.x: experimental API, check_rep instead of check_vma

    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
        return _shard_map_04(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=check_vma,
            **kw,
        )


if hasattr(jax.lax, "pcast"):
    pcast = jax.lax.pcast
elif hasattr(jax.lax, "pvary"):  # transitional name

    def pcast(x, axes, *, to="varying"):
        return jax.lax.pvary(x, axes) if to == "varying" else x

else:  # no varying-manifest checker on this runtime -> identity

    def pcast(x, axes, *, to="varying"):
        return x
