"""Batched serving engine: prefill + decode against the model's cache.

Slot-based continuous batching: the engine owns ``batch`` slots; requests
occupy a slot through prefill and greedy/temperature decode, and finished
slots are refilled from the queue without draining the batch (the decode
step always runs the full batch — finished slots just carry padding, the
standard static-batch serving compromise on TPU where shapes must not
change).  Every jit boundary (prefill / decode_step / sample) compiles once
per shape.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig
from repro.models import transformer as T


@dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 == greedy
    eos_token: int | None = None
    seed: int = 0


@dataclass
class _Request:
    rid: int
    prompt: np.ndarray
    out: list[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        batch: int,
        max_len: int,
        gen: GenerationConfig = GenerationConfig(),
    ):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.gen = gen
        self._key = jax.random.key(gen.seed)

        self._prefill = jax.jit(
            functools.partial(T.prefill, cfg)
        )
        self._decode = jax.jit(functools.partial(T.decode_step, cfg))

    # -- sampling ----------------------------------------------------------
    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.gen.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits[:, -1].astype(jnp.float32) / self.gen.temperature
        )

    # -- one fully-batched generation round --------------------------------
    def generate(self, prompts: list[np.ndarray]) -> list[list[int]]:
        """Generate for up to `batch` same-length prompts (padded equal)."""
        assert len(prompts) <= self.batch
        plen = max(len(p) for p in prompts)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p) :] = p  # left-pad

        cache = T.init_cache(self.cfg, self.batch, self.max_len)
        logits, cache = self._prefill(self.params, jnp.asarray(toks), cache)
        next_tok = self._sample(logits)

        outs: list[list[int]] = [[] for _ in range(self.batch)]
        done = np.zeros(self.batch, bool)
        position = jnp.asarray(plen, jnp.int32)
        for _ in range(self.gen.max_new_tokens):
            for i, t in enumerate(np.asarray(next_tok)):
                if i < len(prompts) and not done[i]:
                    outs[i].append(int(t))
                    if self.gen.eos_token is not None and t == self.gen.eos_token:
                        done[i] = True
            if done[: len(prompts)].all():
                break
            logits, cache = self._decode(
                self.params, next_tok[:, None], cache, position
            )
            next_tok = self._sample(logits)
            position = position + 1
        return outs[: len(prompts)]
