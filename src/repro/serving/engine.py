"""Batched serving engine: prefill + decode against the model's cache.

Slot-based continuous batching: the engine owns ``batch`` slots; requests
occupy a slot through prefill and greedy/temperature decode, and finished
slots are refilled from the queue without draining the batch (the decode
step always runs the full batch — finished slots just carry padding, the
standard static-batch serving compromise on TPU where shapes must not
change).  Every jit boundary (prefill / decode_step / sample) compiles once
per shape.

Two entry points:

* ``generate``  — one fully-batched round: same-length prompts in, decoded
  continuations out (the original static round, kept for tests/examples);
* ``serve``     — drain a request queue through the slots: eos / length
  exhaustion frees a slot, the next queued request prefills into it, and
  decode proceeds with per-slot cache positions (``decode_step`` takes a
  (B,) position vector).  Per-step wall times and occupancy land in
  ``last_serve_stats`` for the traffic bench.

Serving dispatch (DESIGN.md §11): ``set_dispatch`` installs a
``models.moe.DispatchSpec`` — a warmed pattern envelope plus the decision
resolved for its bucket — and prefill/decode are re-jitted under
``dispatch_scope`` with the spec's statics baked in.  Programs are cached
per spec (envelope signature, backend, capacity), so envelope capacities
join the jit key and a drifting request stream inside one envelope reuses
one compiled program.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig
from repro.models import moe as MoE
from repro.models import transformer as T


@dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 == greedy
    eos_token: int | None = None
    seed: int = 0


@dataclass
class _Request:
    rid: int
    prompt: np.ndarray
    arrival: int = 0  # decode-step index at which the request exists
    out: list[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        batch: int,
        max_len: int,
        gen: GenerationConfig = GenerationConfig(),
    ):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.gen = gen
        self._key = jax.random.key(gen.seed)
        self._dispatch: MoE.DispatchSpec | None = None
        # compiled (prefill, decode) pairs keyed by the dispatch spec's
        # statics — the envelope signature IS part of the jit key
        self._programs: dict[tuple, tuple] = {}
        self.last_serve_stats: dict = {}

    # -- dispatch spec (serving path, DESIGN.md §11) -----------------------
    def set_dispatch(self, spec: MoE.DispatchSpec | None) -> None:
        """Install the ambient dispatch decision for the MoE spgemm impl.

        Programs traced under a previous spec stay cached; switching back
        to an already-seen envelope reuses its compiled pair.
        """
        self._dispatch = spec

    def _spec_key(self) -> tuple:
        s = self._dispatch
        if s is None:
            return (None,)
        sig = s.envelope.signature if s.envelope is not None else None
        return (sig, s.backend, s.stack_capacity)

    def _program(self) -> tuple:
        key = self._spec_key()
        prog = self._programs.get(key)
        if prog is None:
            cfg, spec = self.cfg, self._dispatch

            def pf(params, toks, cache, _spec=spec):
                with MoE.dispatch_scope(_spec):
                    return T.prefill(cfg, params, toks, cache)

            def df(params, toks, cache, position, _spec=spec):
                with MoE.dispatch_scope(_spec):
                    return T.decode_step(cfg, params, toks, cache, position)

            prog = (jax.jit(pf), jax.jit(df))
            self._programs[key] = prog
        return prog

    # -- sampling ----------------------------------------------------------
    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.gen.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits[:, -1].astype(jnp.float32) / self.gen.temperature
        )

    # -- one fully-batched generation round --------------------------------
    def generate(self, prompts: list[np.ndarray]) -> list[list[int]]:
        """Generate for up to `batch` same-length prompts (padded equal)."""
        assert len(prompts) <= self.batch
        prefill_fn, decode_fn = self._program()
        plen = max(len(p) for p in prompts)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p) :] = p  # left-pad

        cache = T.init_cache(self.cfg, self.batch, self.max_len)
        logits, cache = prefill_fn(self.params, jnp.asarray(toks), cache)
        next_tok = self._sample(logits)

        outs: list[list[int]] = [[] for _ in range(self.batch)]
        done = np.zeros(self.batch, bool)
        position = jnp.asarray(plen, jnp.int32)
        for _ in range(self.gen.max_new_tokens):
            for i, t in enumerate(np.asarray(next_tok)):
                if i < len(prompts) and not done[i]:
                    outs[i].append(int(t))
                    if self.gen.eos_token is not None and t == self.gen.eos_token:
                        done[i] = True
            if done[: len(prompts)].all():
                break
            logits, cache = decode_fn(
                self.params, next_tok[:, None], cache, position
            )
            next_tok = self._sample(logits)
            position = position + 1
        return outs[: len(prompts)]

    # -- continuous batching -----------------------------------------------
    def _refill(self, queue, active, cache, next_tok, pos_dev, plen,
                prefill_fn, step: int):
        """Prefill queued requests into free slots and splice their rows.

        One full-batch prefill program regardless of how many slots refill
        (shapes must not change); the fresh cache rows are scattered into
        the live cache along the batch axis (axis 1 of every leaf).
        """
        free = [i for i, r in enumerate(active) if r is None]
        slots: list[int] = []
        toks = np.zeros((self.batch, plen), np.int32)
        for slot in free:
            if not queue or queue[0].arrival > step:
                break
            req = queue.popleft()
            toks[slot, plen - len(req.prompt):] = req.prompt
            active[slot] = req
            slots.append(slot)
        if not slots:
            return cache, next_tok, pos_dev, 0
        fresh = T.init_cache(self.cfg, self.batch, self.max_len)
        logits, fresh = prefill_fn(self.params, jnp.asarray(toks), fresh)
        first = self._sample(logits)
        sel = jnp.zeros((self.batch,), bool).at[jnp.asarray(slots)].set(True)

        def mix(old, new):
            s = sel.reshape((1, self.batch) + (1,) * (old.ndim - 2))
            return jnp.where(s, new, old)

        cache = jax.tree.map(mix, cache, fresh)
        idx = jnp.asarray(slots)
        next_tok = next_tok.at[idx].set(first[idx])
        pos_dev = pos_dev.at[idx].set(plen)
        return cache, next_tok, pos_dev, len(slots)

    def serve(self, prompts: list[np.ndarray],
              arrivals: list[int] | None = None) -> list[list[int]]:
        """Drain a request queue through the ``batch`` slots.

        ``arrivals`` (optional, decode-step units, non-decreasing) holds
        request i back until that step — the traffic-shaping hook the
        serving bench drives Poisson/bursty processes through.  Returns
        the generated token lists in request order; per-step wall times,
        occupancy and refill counts land in ``last_serve_stats``.
        """
        if arrivals is None:
            arrivals = [0] * len(prompts)
        assert len(arrivals) == len(prompts)
        prefill_fn, decode_fn = self._program()
        plen = max(len(p) for p in prompts)
        assert plen + 1 < self.max_len
        max_new = self.gen.max_new_tokens
        limit = min(max_new, self.max_len - plen - 1)

        queue = deque(
            _Request(i, np.asarray(p, np.int32), arrival=int(a))
            for i, (p, a) in enumerate(zip(prompts, arrivals))
        )
        active: list[_Request | None] = [None] * self.batch
        results: dict[int, list[int]] = {}
        cache = T.init_cache(self.cfg, self.batch, self.max_len)
        next_tok = jnp.zeros((self.batch,), jnp.int32)
        pos_dev = jnp.zeros((self.batch,), jnp.int32)

        step = 0
        steps: list[dict] = []
        n_refills = 0
        while queue or any(r is not None for r in active):
            t0 = time.perf_counter()
            cache, next_tok, pos_dev, filled = self._refill(
                queue, active, cache, next_tok, pos_dev, plen,
                prefill_fn, step)
            n_refills += 1 if filled else 0
            occupied = [i for i, r in enumerate(active) if r is not None]
            if not occupied:
                # idle gap before the next arrival: jump the clock
                step = max(step + 1, queue[0].arrival if queue else step + 1)
                continue
            logits, cache = decode_fn(
                self.params, next_tok[:, None], cache, pos_dev)
            sampled = self._sample(logits)
            host_prev = np.asarray(next_tok)
            jax.block_until_ready(sampled)
            dt = time.perf_counter() - t0
            # the token decoded THIS step is the one that was in next_tok
            for i in occupied:
                req = active[i]
                tok = int(host_prev[i])
                req.out.append(tok)
                eos = (self.gen.eos_token is not None
                       and tok == self.gen.eos_token)
                if eos or len(req.out) >= limit:
                    results[req.rid] = req.out
                    active[i] = None
            next_tok = sampled
            pos_dev = jnp.minimum(pos_dev + 1, self.max_len - 1)
            steps.append({
                "step": step,
                "occupancy": len(occupied) / self.batch,
                "wall_s": dt,
                "refilled": filled,
            })
            step += 1
        self.last_serve_stats = {
            "steps": steps,
            "n_refills": n_refills,
            "n_requests": len(prompts),
        }
        return [results[i] for i in range(len(prompts))]
