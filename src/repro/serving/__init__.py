"""repro.serving — batched KV-cache serving engine."""
from repro.serving.engine import GenerationConfig, ServingEngine

__all__ = ["GenerationConfig", "ServingEngine"]
