"""Configuration system: architecture configs, input shapes, mesh specs.

Every assigned architecture is a frozen ``ArchConfig``; reduced smoke
variants come from ``ArchConfig.reduced()``.  Input shapes (the assigned
shape set) are ``ShapeConfig`` entries; ``input_specs`` builds the
ShapeDtypeStruct stand-ins used by the dry-run (no allocation).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int | None = None  # defaults to arch d_ff
    layer_period: int = 1  # MoE every `period` layers (llama4/jamba: 2)
    capacity_factor: float = 1.25
    # "tp" (experts TP-sharded) | "ep" (expert parallel) | "dense" (exact
    # oracle) | "spgemm" (dispatch as block-sparse SpGEMM through
    # engine.multiply — the serving path, DESIGN.md §11)
    impl: str = "tp"
    # block-row size of the (token-block x expert) dispatch BSM the
    # "spgemm" impl builds (tokens per block; T is padded up to a multiple)
    token_block: int = 4


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 16  # sequential-scan chunk (remat granularity)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 16


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder; the conv/mel frontend is a stub — input_specs
    provides precomputed frame embeddings (B, n_frames, d_model)."""

    n_layers: int
    n_frames: int = 1500


# ---------------------------------------------------------------------------
# architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads
    qkv_bias: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    sliding_window: int | None = None
    window_pattern: int = 2  # local layer every `pattern` layers (gemma2)
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    post_norm: bool = False  # gemma2 sandwich norms
    moe: MoEConfig | None = None
    mixer: str = "attention"  # attention | mamba_hybrid | rwkv6
    attn_layer_period: int = 8  # hybrid: attention every Nth layer
    mamba: MambaConfig | None = None
    rwkv: RWKVConfig | None = None
    encoder: EncoderConfig | None = None  # enc-dec (whisper)
    frontend: str | None = None  # audio | vision | None
    n_patches: int = 256  # vlm stub: image patches fused into the prefix
    rope: bool = True
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # bf16 for the 400B MoE (fits HBM)
    source: str = ""  # provenance note

    # ---- derived -------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_pattern_period(self) -> int:
        """Length of the repeating layer pattern (the scanned superblock)."""
        p = 1
        if self.moe is not None:
            p = _lcm(p, self.moe.layer_period)
        if self.sliding_window is not None:
            p = _lcm(p, self.window_pattern)
        if self.mixer == "mamba_hybrid":
            p = _lcm(p, self.attn_layer_period)
        return p

    def layer_kinds(self) -> list[dict]:
        """Per-position spec within one pattern period."""
        period = self.layer_pattern_period
        assert self.n_layers % period == 0, (self.name, self.n_layers, period)
        kinds = []
        for i in range(period):
            mixer = "attention"
            if self.mixer == "mamba_hybrid":
                mixer = "attention" if i % self.attn_layer_period == 0 else "mamba"
            elif self.mixer == "rwkv6":
                mixer = "rwkv6"
            window = None
            if self.sliding_window is not None and i % self.window_pattern == 0:
                window = self.sliding_window
            use_moe = self.moe is not None and (i % self.moe.layer_period
                                                == self.moe.layer_period - 1)
            kinds.append(dict(mixer=mixer, window=window, moe=use_moe))
        return kinds

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / linear attn)."""
        return self.mixer in ("mamba_hybrid", "rwkv6")

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and reporting)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd, h, hkv = self.hd, self.n_heads, self.n_kv_heads
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        kinds = self.layer_kinds()
        reps = self.n_layers // len(kinds)
        for k in kinds:
            p = 0
            if k["mixer"] == "attention":
                p += d * (h * hd) + 2 * d * (hkv * hd) + (h * hd) * d
                if self.qkv_bias:
                    p += h * hd + 2 * hkv * hd
            elif k["mixer"] == "mamba":
                m = self.mamba or MambaConfig()
                di = m.expand * d
                p += d * 2 * di + di * m.d_conv + di * (2 * m.d_state + 1)
                p += di * m.d_state + di + di * d  # dt/out projections
            elif k["mixer"] == "rwkv6":
                r = self.rwkv or RWKVConfig()
                p += 4 * d * d + d * r.decay_lora * 2 + 2 * d * ff  # time+channel mix
            if k["moe"]:
                moe = self.moe
                de = moe.d_expert or ff
                n_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
                p += moe.n_experts * n_mats * d * de
                p += moe.n_shared * n_mats * d * de
                p += d * moe.n_experts  # router
            elif k["mixer"] != "rwkv6":  # rwkv channel-mix counted above
                n_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
                p += n_mats * d * ff
            total += p * reps
        if self.encoder is not None:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = (4 * d * d + 2 * d * ff) * self.encoder.n_layers
            xattn = 4 * d * d * self.n_layers
            total += enc + xattn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        moe = self.moe
        de = moe.d_expert or self.d_ff
        n_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        kinds = self.layer_kinds()
        reps = self.n_layers // len(kinds)
        n_moe_layers = sum(1 for k in kinds if k["moe"]) * reps
        inactive = (moe.n_experts - moe.top_k) * n_mats * self.d_model * de
        return self.param_count() - n_moe_layers * inactive

    def reduced(self) -> "ArchConfig":
        """Small same-family variant for CPU smoke tests."""
        period = self.layer_pattern_period
        moe = self.moe
        if moe is not None:
            moe = replace(moe, n_experts=min(moe.n_experts, 8),
                          top_k=min(moe.top_k, 2), d_expert=128)
        enc = self.encoder
        if enc is not None:
            enc = replace(enc, n_layers=2, n_frames=16)
        hd = 32 if self.head_dim is not None else None
        return replace(
            self,
            n_layers=2 * period,  # two scanned repetitions of the pattern
            d_model=128,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=hd,
            d_ff=256,
            vocab=512,
            sliding_window=64 if self.sliding_window else None,
            moe=moe,
            mamba=replace(self.mamba, chunk=8) if self.mamba else None,
            rwkv=replace(self.rwkv, head_dim=32, chunk=8) if self.rwkv else None,
            encoder=enc,
            n_patches=8,
            dtype="float32",
        )


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# input shapes (the assigned shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs; reason if skipped."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("full-attention architecture: 500k-token decode needs "
                       "sub-quadratic attention (DESIGN.md §Arch-applicability)")
    return True, ""


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(arch.dtype)
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:  # decode: one new token against a seq_len-deep cache/state
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        specs["position"] = jax.ShapeDtypeStruct((), jnp.int32)
    if arch.frontend == "vision" and shape.kind != "decode":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, arch.n_patches, arch.d_model), dt
        )
    if arch.encoder is not None and shape.kind != "decode":
        specs["frame_embeds"] = jax.ShapeDtypeStruct(
            (b, arch.encoder.n_frames, arch.d_model), dt
        )
    return specs


# ---------------------------------------------------------------------------
# kernel execution mode
# ---------------------------------------------------------------------------


def transport_mode() -> str:
    """Configured panel-transport mode: "auto" | "dense" | "compressed".

    ``REPRO_TRANSPORT`` overrides (debugging / forcing a path): "dense"
    pins the bit-exact full-panel permutes, "compressed" forces
    occupancy-compressed packing (requires concrete operand patterns),
    unset/"auto" lets the plan layer choose per pattern from the bucketed
    capacity fill (``repro.core.transport.resolve_mode``).  Plumbed
    through ``plan.resolve_transport`` the same way
    ``REPRO_PALLAS_INTERPRET`` flows into the Pallas wrappers.
    """
    import os

    raw = os.environ.get("REPRO_TRANSPORT", "auto").strip().lower()
    if raw in ("", "auto"):
        return "auto"
    if raw in ("dense", "compressed"):
        return raw
    raise ValueError(
        f"REPRO_TRANSPORT={raw!r}: expected auto | dense | compressed"
    )


def storage_dtype() -> str:
    """Configured block-storage dtype for dtype-matrixed test/CI runs.

    ``REPRO_STORAGE_DTYPE`` selects the reduced-precision storage leg of
    the CI matrix: "float32" (default) keeps the exact path, "bfloat16"
    runs the mixed-precision path (bf16 blocks, f32 MXU accumulation —
    DESIGN.md §2).  Read by the dtype-matrixed end-to-end tests; library
    code never consults it (storage dtype is an explicit argument:
    ``bsm.astype`` / ``sign_iteration(storage_dtype=...)``).
    """
    import os

    raw = os.environ.get("REPRO_STORAGE_DTYPE", "float32").strip().lower()
    if raw in ("", "f32", "float32"):
        return "float32"
    if raw in ("bf16", "bfloat16"):
        return "bfloat16"
    raise ValueError(
        f"REPRO_STORAGE_DTYPE={raw!r}: expected float32 | bfloat16"
    )


def pallas_interpret() -> bool | None:
    """Configured Pallas interpret mode, or None for platform auto-detect.

    ``REPRO_PALLAS_INTERPRET`` overrides: "1"/"true" forces the
    interpreter (debugging on any platform), "0"/"false" forces compiled
    Mosaic kernels, unset/"auto" lets the wrappers pick — interpret off on
    real TPU, on elsewhere (``repro.kernels.ops._default_interpret``).
    """
    import os

    raw = os.environ.get("REPRO_PALLAS_INTERPRET", "auto").strip().lower()
    if raw in ("1", "true", "yes", "on"):
        return True
    if raw in ("0", "false", "no", "off"):
        return False
    return None
