"""Cannon's algorithm (the paper's PTP baseline) and the streaming
one-sided variant, as shard_map programs over a 2D device mesh.

PTP baseline (Algorithm 1):
  * pre-shift A row-wise by i, B column-wise by j  (``mpi_isend/irecv`` ->
    ``lax.ppermute`` over the flattened (r, c) axis, which expresses the
    per-row-different shift as one static permutation),
  * V = p ticks of  C += A_comp . B_comp  followed by a ring shift of A
    (left along c) and B (up along r); the last tick does not shift
    (paper: ``if itick < nticks``).

One-sided streaming variant (OS1 of the paper, ``onesided``):
  * no pre-shift; at tick t every device *pulls* the A/B panels it needs
    directly from their home location (``mpi_rget`` -> a statically known
    ppermute from the home buffer).  Receiver-indexed, sender never blocks —
    on TPU the schedule is static, which subsumes the paper's
    "synchronization only on the receiver" property.

Both engines communicate V*(S_A+S_B) per device (PTP additionally pre-shifts)
— exactly the PTP == OS1 volume equality of Table 2.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.bsm import BlockSparseMatrix, block_norms, make_bsm
from repro.core.local_mm import local_filtered_mm

_AXES = ("r", "c")


def _flat_perm(p: int, fn) -> list[tuple[int, int]]:
    """Permutation over the flattened (r, c) axis: fn(i, j) -> (di, dj)."""
    perm = []
    for i in range(p):
        for j in range(p):
            di, dj = fn(i, j)
            perm.append((i * p + j, di * p + dj))
    return perm


def _shift_pany(x, axis_name: str, mesh_axis_size: int, shift: int = 1):
    """Ring-shift along one mesh axis: device k receives from (k+shift)%p."""
    perm = [(src, (src - shift) % mesh_axis_size) for src in range(mesh_axis_size)]
    return lax.ppermute(x, axis_name, perm)


def _panel_mm(carry_c, a, b, threshold, backend):
    (cb, cm) = carry_c
    ab, am, an = a
    bb, bm, bn = b
    dcb, dcm = local_filtered_mm(
        ab, am, an, bb, bm, bn, threshold=threshold, backend=backend
    )
    return cb + dcb, cm | dcm


def cannon_shardmap(mesh, *, threshold: float = 0.0, backend: str = "jnp"):
    """Returns the shard_map'd multiply body for the PTP Cannon engine."""
    p = mesh.shape["r"]
    assert mesh.shape["c"] == p, "Cannon engine requires a square grid"
    blk = P("r", "c", None, None)
    m2 = P("r", "c")

    def body(ab, am, an, bb, bm, bn):
        # --- pre-shift (Algorithm 1): A_ij <- A_{i,(j+i)}, B_ij <- B_{(i+j),j}
        pre_a = _flat_perm(p, lambda i, j: (i, (j - i) % p))
        pre_b = _flat_perm(p, lambda i, j: ((i - j) % p, j))
        ab, am, an = (lax.ppermute(x, _AXES, pre_a) for x in (ab, am, an))
        bb, bm, bn = (lax.ppermute(x, _AXES, pre_b) for x in (bb, bm, bn))

        cb = jnp.zeros(
            (ab.shape[0], bb.shape[1], ab.shape[2], bb.shape[3]), ab.dtype
        )
        cm = jnp.zeros((ab.shape[0], bb.shape[1]), bool)
        cb = lax.pcast(cb, _AXES, to="varying")
        cm = lax.pcast(cm, _AXES, to="varying")

        def tick(carry, _):
            ab, am, an, bb, bm, bn, cb, cm = carry
            cb, cm = _panel_mm((cb, cm), (ab, am, an), (bb, bm, bn), threshold, backend)
            ab, am, an = (_shift_pany(x, "c", p, 1) for x in (ab, am, an))
            bb, bm, bn = (_shift_pany(x, "r", p, 1) for x in (bb, bm, bn))
            return (ab, am, an, bb, bm, bn, cb, cm), None

        if p > 1:
            (ab, am, an, bb, bm, bn, cb, cm), _ = lax.scan(
                tick, (ab, am, an, bb, bm, bn, cb, cm), None, length=p - 1
            )
        # final tick: compute only, no trailing shift (paper's itick==nticks)
        cb, cm = _panel_mm((cb, cm), (ab, am, an), (bb, bm, bn), threshold, backend)
        return cb, cm

    return jax.shard_map(
        body,
        mesh=mesh,
        # check_vma=False: the pallas backend's pallas_call builds plain
        # ShapeDtypeStructs (no vma annotation); engine outputs are
        # oracle-tested instead (tests/_dist.py::check_engines)
        check_vma=False,
        in_specs=(blk, m2, m2, blk, m2, m2),
        out_specs=(blk, m2),
    )


def onesided_shardmap(mesh, *, threshold: float = 0.0, backend: str = "jnp"):
    """OS1: pull-from-home streaming engine (no pre-shift).

    Tick t: device (i,j) pulls A_{i,k} and B_{k,j} with k=(i+j+t)%p straight
    from the home buffers.  Each pull is one static ppermute (bijection),
    unrolled over the V ticks so every permutation is static — this is the
    RMA access pattern of Algorithm 2 with L=1.
    """
    p = mesh.shape["r"]
    assert mesh.shape["c"] == p, "onesided engine requires a square grid"
    blk = P("r", "c", None, None)
    m2 = P("r", "c")

    def body(ab, am, an, bb, bm, bn):
        cb = jnp.zeros(
            (ab.shape[0], bb.shape[1], ab.shape[2], bb.shape[3]), ab.dtype
        )
        cm = jnp.zeros((ab.shape[0], bb.shape[1]), bool)
        for t in range(p):
            # A: home (i, k) -> (i, j); bijection in j for fixed t
            perm_a = _flat_perm(p, lambda i, k: (i, (k - i - t) % p))
            # B: home (k, j) -> (i, j)
            perm_b = _flat_perm(p, lambda k, j: ((k - j - t) % p, j))
            at, amt, ant = (lax.ppermute(x, _AXES, perm_a) for x in (ab, am, an))
            bt, bmt, bnt = (lax.ppermute(x, _AXES, perm_b) for x in (bb, bm, bn))
            cb, cm = _panel_mm((cb, cm), (at, amt, ant), (bt, bmt, bnt), threshold, backend)
        return cb, cm

    return jax.shard_map(
        body,
        mesh=mesh,
        # check_vma=False: the pallas backend's pallas_call builds plain
        # ShapeDtypeStructs (no vma annotation); engine outputs are
        # oracle-tested instead (tests/_dist.py::check_engines)
        check_vma=False,
        in_specs=(blk, m2, m2, blk, m2, m2),
        out_specs=(blk, m2),
    )


def multiply_2d(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    mesh,
    *,
    engine: str = "cannon",
    threshold: float = 0.0,
    backend: str = "jnp",
) -> BlockSparseMatrix:
    """Distributed C = A . B on a 2D (r, c) mesh."""
    fn = {"cannon": cannon_shardmap, "onesided": onesided_shardmap}[engine](
        mesh, threshold=threshold, backend=backend
    )
    cb, cm = fn(a.blocks, a.mask, a.norms, b.blocks, b.mask, b.norms)
    return BlockSparseMatrix(blocks=cb, mask=cm, norms=block_norms(cb))
