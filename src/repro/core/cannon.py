"""Cannon's algorithm (the paper's PTP baseline) and the streaming
one-sided variant, as thin executors of a MultiplyPlan.

PTP baseline (Algorithm 1, ``ring_executor``):
  * pre-shift A row-wise by i, B column-wise by j  (``mpi_isend/irecv`` ->
    ``lax.ppermute`` over the flattened (r, c) axis; the per-row-different
    shift is one static permutation from the plan),
  * V = p ticks of  C += A_comp . B_comp  followed by a ring shift of A
    (left along c) and B (up along r); the last tick does not shift
    (paper: ``if itick < nticks``).

One-sided streaming variant (OS1 of the paper, ``onesided``):
  * no pre-shift; at every tick each device *pulls* the A/B panels it needs
    directly from their home location (``mpi_rget`` -> a statically known
    ppermute from the home buffer).  Receiver-indexed, sender never blocks —
    on TPU the schedule is static, which subsumes the paper's
    "synchronization only on the receiver" property.  This is the L = 1
    case of the generalized pull executor in ``repro.core.twofive`` (the
    paper's OSL with L = 1 == OS1), so it also runs on non-square grids.

Communication goes through the shared transport layer
(``repro.core.transport``, DESIGN.md §3): panels move either dense
(blocks + mask; norms are never shipped — recomputed on arrival) or
occupancy-compressed (packed blocks + indices, wire bytes proportional to
occupancy), and the tick loop is double-buffered — the ring hop feeding
tick t+1 is issued *before* the GEMM of tick t, so XLA overlaps the
permute with the multiply the way the paper's non-blocking rgets do.

Both engines communicate V*(S_A+S_B) per device (PTP additionally
pre-shifts) under dense transport — exactly the PTP == OS1 volume
equality of Table 2; compressed transport scales both by panel occupancy.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import pcast, shard_map
from repro.core import transport as T
from repro.core.bsm import BlockSparseMatrix
from repro.core.local_mm import local_filtered_mm


def ring_body(
    plan,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    transport: T.PanelTransport = T.DENSE,
):
    """The per-shard PTP Cannon body (shards in, C shard out).

    Exposed separately from the executor so iteration chains
    (``core/signiter.py``) can inline the whole multiply into ONE
    enclosing shard_map — the engine body already operates on shards;
    the executor below only wraps it for the single-multiply call path.
    """
    mm_kw = dict(
        threshold=threshold, backend=backend,
        stack_capacity=stack_capacity, tile=tile, interpret=interpret,
    )
    axes = plan.axes
    ticks = plan.ticks
    tr = transport

    def body(ab, am, an, bb, bm, bn):
        del an, bn  # norms never ride the ring (recomputed at compute time)
        sa, sb = am.shape, bm.shape
        adt, bdt = ab.dtype, bb.dtype  # widen wire-cast panels back

        def compute(pa, pb, cb, cm):
            xb, xm = T.dense_view(tr, pa, *sa, dtype=adt)
            yb, ym = T.dense_view(tr, pb, *sb, dtype=bdt)
            dcb, dcm = local_filtered_mm(
                xb, xm, T.panel_norms(xb, threshold),
                yb, ym, T.panel_norms(yb, threshold), **mm_kw,
            )
            return cb + dcb, cm | dcm

        # --- pre-shift (Algorithm 1): A_ij <- A_{i,(j+i)}, B_ij <- B_{(i+j),j}
        pa = T.permute(T.ingest(tr, tr.cap_a, ab, am), axes, plan.pre_a)
        pb = T.permute(T.ingest(tr, tr.cap_b, bb, bm), axes, plan.pre_b)

        cb = jnp.zeros(
            (ab.shape[0], bb.shape[1], ab.shape[2], bb.shape[3]), ab.dtype
        )
        cm = jnp.zeros((ab.shape[0], bb.shape[1]), bool)
        cb = pcast(cb, axes, to="varying")
        cm = pcast(cm, axes, to="varying")

        if ticks == 1:
            return compute(pa, pb, cb, cm)

        # --- double-buffered ring: the hop for tick t+1 is in flight
        # before the GEMM of tick t runs (paper §4 comm/compute overlap)
        na = T.permute(pa, "c", plan.shift_a)
        nb_ = T.permute(pb, "r", plan.shift_b)

        def tick(carry, _):
            pa, pb, na, nb_, cb, cm = carry
            fa = T.permute(na, "c", plan.shift_a)
            fb = T.permute(nb_, "r", plan.shift_b)
            cb, cm = compute(pa, pb, cb, cm)
            return (na, nb_, fa, fb, cb, cm), None

        if ticks > 2:
            (pa, pb, na, nb_, cb, cm), _ = lax.scan(
                tick, (pa, pb, na, nb_, cb, cm), None, length=ticks - 2
            )
        # last two ticks: compute only, no trailing shift (itick==nticks)
        cb, cm = compute(pa, pb, cb, cm)
        return compute(na, nb_, cb, cm)

    return body


def ring_executor(plan, **kw):
    """The PTP Cannon engine: plan's pre-shift + V ring hops."""
    blk = P("r", "c", None, None)
    m2 = P("r", "c")
    return shard_map(
        ring_body(plan, **kw),
        mesh=plan.mesh,
        # check_vma=False: the pallas backend's pallas_call builds plain
        # ShapeDtypeStructs (no vma annotation); engine outputs are
        # oracle-tested instead (tests/_dist.py::check_engines)
        check_vma=False,
        in_specs=(blk, m2, m2, blk, m2, m2),
        out_specs=(blk, m2),
    )


def cannon_shardmap(mesh, *, threshold: float = 0.0, backend: str = "jnp"):
    """Back-compat: plan + executor for the PTP Cannon engine."""
    from repro.core import plan as plan_mod

    p = plan_mod.plan_multiply(mesh, "cannon")
    return plan_mod.build_program(
        p, threshold=threshold, backend=backend, c_layout="2d"
    )


def onesided_shardmap(mesh, *, threshold: float = 0.0, backend: str = "jnp"):
    """Back-compat: plan + executor for the OS1 pull engine."""
    from repro.core import plan as plan_mod

    p = plan_mod.plan_multiply(mesh, "onesided")
    return plan_mod.build_program(
        p, threshold=threshold, backend=backend, c_layout="2d"
    )


def multiply_2d(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    mesh,
    *,
    engine: str = "cannon",
    threshold: float = 0.0,
    backend: str = "jnp",
) -> BlockSparseMatrix:
    """Distributed C = A . B on a 2D (r, c) mesh (plan-cached program)."""
    from repro.core import plan as plan_mod

    return plan_mod.execute(
        a, b, mesh, engine, threshold=threshold, backend=backend
    )
