"""Process-topology logic of the paper (Algorithm 2).

This module reproduces, exactly and testably, the paper's:

* validity rules for the 2.5D depth factor ``L`` (section 3):
    - non-square grid (P_R != P_C): with mn=min, mx=max, require mx % mn == 0
      and mx <= mn^2; then L is *determined*: L = mx/mn, topology mn x mx/L x L.
    - square grid: L any square integer with sqrt(L) dividing P_R,
      topology (P_R/sqrt(L)) x (P_C/sqrt(L)) x L.
* buffer-count model (section 3): PTP needs 4 temporaries, OS1 needs 6,
  non-square OSL needs L+6, square OSL needs L+sqrt(L)+4.
* the one-sided fetch/compute schedule of Algorithm 2 and its 3D coordinates
  (i3D, j3D, l, side3D).

Note on fidelity: the published pseudocode's inline fetch-index expression
``k = (j + ((i*(V div P_R) + l + t)*P_C) div V) mod P_C`` is not
self-consistent for square topologies with L > 1 (the A- and B-panel
contraction indices evaluate at different loop iterations and misalign; the
float was evidently garbled in typesetting).  We therefore derive the
schedule from the paper's *stated invariants*, which pin it down uniquely up
to a skew:

  1. the loop advances in groups of L iterations ("ticks" of V/L total);
  2. within one group a process fetches L_R A panels and L_C B panels and
     performs all L = L_R*L_C pairwise products into its L target C panels
     (this amortization IS the sqrt(L) communication reduction);
  3. a valid product requires a single contraction index k per group;
  4. across the L processes sharing a C panel, the k ranges must partition
     [0, V): process layer l takes the contiguous chunk l*V/L + [0, V/L).

The Cannon-style skew (im + jn) spreads the pulls of a given panel across
source processes within a group (no hot spots), as in the paper.  The
pure-numpy ``simulate_algorithm2`` executes this schedule with real data and
is property-tested against ``A @ B`` for square and non-square grids.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


def lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def is_square_int(x: int) -> bool:
    r = math.isqrt(x)
    return r * r == x


@dataclass(frozen=True)
class Topology:
    """Resolved 2.5D topology for a (P_R, P_C) grid and depth L."""

    p_r: int
    p_c: int
    l: int
    l_r: int
    l_c: int
    side3d: int
    v: int  # number of virtual steps, lcm(P_R, P_C)
    nbuffers_a: int
    nbuffers_b: int

    @property
    def square(self) -> bool:
        return self.p_r == self.p_c

    @property
    def ticks(self) -> int:
        """Tick groups per multiplication: V for Cannon/OS1, ~V/L for OSL
        (exact for L | V; otherwise the max over layers of the uneven
        k-partition)."""
        return max(self.layer_groups(l) for l in range(self.l))

    def chunk(self, l: int) -> tuple[int, int]:
        """Layer l's slice of the virtual k-range [0, V): the L co-owners of
        a C panel partition the contraction index range."""
        return (l * self.v) // self.l, ((l + 1) * self.v) // self.l

    def layer_groups(self, l: int) -> int:
        lo, hi = self.chunk(l)
        return hi - lo

    @property
    def total_buffers(self) -> int:
        """Temporary-buffer count (section 3 of the paper)."""
        if self.l == 1:
            return 6  # one-sided L=1
        if not self.square:
            return self.l + 6
        return self.l + math.isqrt(self.l) + 4

    def fetch_counts(self, l: int = 0) -> tuple[int, int]:
        """(A fetches, B fetches) for a layer-l process over one multiply.

        L_R per group for A, L_C per group for B: V/sqrt(L) each on square
        topologies — the Eq. (7) reduction."""
        g = self.layer_groups(l)
        return g * self.l_r, g * self.l_c


def validate_l(p_r: int, p_c: int, l: int) -> bool:
    """Paper's validity rule for L on a (p_r, p_c) grid."""
    if l == 1:
        return True
    if p_r != p_c:
        mn, mx = min(p_r, p_c), max(p_r, p_c)
        return mx % mn == 0 and mx <= mn * mn and l == mx // mn
    return is_square_int(l) and p_r % math.isqrt(l) == 0


def make_topology(p_r: int, p_c: int, l: int) -> Topology:
    """Resolve the 3D topology; falls back to L=1 when invalid (as Alg. 2)."""
    if not validate_l(p_r, p_c, l):
        l = 1
    l_r, l_c = 1, 1
    nbuffers_a = 2
    if l > 1:
        if p_r > p_c:
            l_r = l
        elif p_r < p_c:
            l_c = l
        else:
            l_r = l_c = math.isqrt(l)
            nbuffers_a = max(2, l_r)
    side3d = max(p_r, p_c) // max(l_r, l_c)
    return Topology(
        p_r=p_r,
        p_c=p_c,
        l=l,
        l_r=l_r,
        l_c=l_c,
        side3d=side3d,
        v=lcm(p_r, p_c),
        nbuffers_a=nbuffers_a,
        nbuffers_b=2,
    )


def coords3d(topo: Topology, i: int, j: int) -> tuple[int, int, int]:
    """(i3D, j3D, l) of 2D process (i, j) — Algorithm 2."""
    i3d = i // topo.side3d
    j3d = j // topo.side3d
    l = j3d * topo.l_r + i3d
    return i3d, j3d, l


def group_k(topo: Topology, i: int, j: int, g: int) -> int:
    """Contraction (virtual) index consumed by process (i, j) in group g."""
    _, _, l = coords3d(topo, i, j)
    im, jn = i % topo.side3d, j % topo.side3d
    lo, _ = topo.chunk(l)
    return (im + jn + lo + g) % topo.v


def group_products(topo: Topology, i: int, j: int, g: int):
    """All (m, k, n) panel products performed by (i, j) in tick group g.

    A panels pulled from virtual grid position (m, k) — L_R of them;
    B panels from (k, n) — L_C of them; L pairwise products.
    """
    im, jn = i % topo.side3d, j % topo.side3d
    k = group_k(topo, i, j, g)
    out = []
    for i3 in range(topo.l_r):
        for j3 in range(topo.l_c):
            m = i3 * topo.side3d + im
            n = j3 * topo.side3d + jn
            out.append((m, k, n))
    return out


# ---------------------------------------------------------------------------
# Pure-numpy simulator of Algorithm 2 (fidelity oracle)
# ---------------------------------------------------------------------------


def simulate_algorithm2(
    a: np.ndarray, b: np.ndarray, p_r: int, p_c: int, l: int
) -> np.ndarray:
    """Execute the one-sided 2.5D schedule with real data (numpy).

    Panels stay in their *home* 2D positions (A on the (P_R x V) virtual
    grid, B on (V x P_C), both backed by the unchanged 2D layout — the
    paper's "no 3D redistribution"); every process pulls what it needs and
    partial C panels are accumulated at their owners at the end.
    """
    topo = make_topology(p_r, p_c, l)
    n = a.shape[0]
    if n % topo.v or n % p_r or n % p_c:
        raise ValueError("matrix size must divide grid dims and V")
    hr, hc, hv = n // p_r, n // p_c, n // topo.v

    def a_virtual(m, k):
        return a[m * hr : (m + 1) * hr, k * hv : (k + 1) * hv]

    def b_virtual(k, nn):
        return b[k * hv : (k + 1) * hv, nn * hc : (nn + 1) * hc]

    c = np.zeros((n, b.shape[1]))
    fetches_a = fetches_b = 0
    expect_a = expect_b = 0
    for i in range(p_r):
        for j in range(p_c):
            _, _, l = coords3d(topo, i, j)
            ea, eb = topo.fetch_counts(l)
            expect_a += ea
            expect_b += eb
            for g in range(topo.layer_groups(l)):
                prods = group_products(topo, i, j, g)
                fetches_a += topo.l_r
                fetches_b += topo.l_c
                for m, k, nn in prods:
                    c[m * hr : (m + 1) * hr, nn * hc : (nn + 1) * hc] += (
                        a_virtual(m, k) @ b_virtual(k, nn)
                    )
    assert fetches_a == expect_a
    assert fetches_b == expect_b
    return c
