"""All-gather ("pull from home") SpGEMM engine.

The TPU-native rendering of the paper's one-sided access pattern: every
device pulls the A panels of its block row (gather along ``c``) and the B
panels of its block column (gather along ``r``) directly from their home
positions — no pre-shift, no sender-side synchronization, 2D data layout
retained.  The per-device communicated volume equals Cannon's
(V * (S_A + S_B)), matching the PTP == OS1 equality in Table 2, but the
panels arrive as one fused ICI all-gather instead of V ring hops, so the
latency term is V times smaller (TPU all-gathers are the native multicast).

Memory: holds the full gathered row/column (p panels) instead of DBCSR's
double buffers — the TPU trade (VMEM/HBM is provisioned for this; the
kernel consumes the gathered panels tile by tile).

Works for any (r, c) grid, including the paper's non-square topologies.
Like the other engines it is a thin executor of a MultiplyPlan (the plan
carries no permutation tables here — the schedule is one fused collective —
but routing through the plan layer shares the program cache and the
predicted-volume model).
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import transport as T
from repro.core.bsm import BlockSparseMatrix
from repro.core.local_mm import local_filtered_mm


def gather_body(
    plan,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    transport: T.PanelTransport = T.DENSE,
):
    """The per-shard all-gather body (exposed for chain fusion — the
    panel all-gathers here are the engine's *internal* pulls, not a
    C gather; C comes home sharded).

    The gathers go through the transport layer: dense moves blocks +
    mask (norms recomputed after the gather), compressed all-gathers
    each home shard's packed buffer — still one fused collective pair
    per operand, with bytes proportional to occupancy.
    """
    tr = transport

    def body(ab, am, an, bb, bm, bn):
        del an, bn  # norms are not gathered (recomputed from the blocks)
        # pull the full block row of A / block column of B from home
        ab, am = T.all_gather_panels(tr, tr.cap_a, ab, am, "c", axis=1)
        bb, bm = T.all_gather_panels(tr, tr.cap_b, bb, bm, "r", axis=0)
        return local_filtered_mm(
            ab, am, T.panel_norms(ab, threshold),
            bb, bm, T.panel_norms(bb, threshold),
            threshold=threshold, backend=backend,
            stack_capacity=stack_capacity, tile=tile, interpret=interpret,
        )

    return body


def gather_executor(plan, **kw):
    blk = P("r", "c", None, None)
    m2 = P("r", "c")
    return shard_map(
        gather_body(plan, **kw),
        mesh=plan.mesh,
        # check_vma=False: the pallas backend's pallas_call builds plain
        # ShapeDtypeStructs (no vma annotation); engine outputs are
        # oracle-tested instead (tests/_dist.py::check_engines)
        check_vma=False,
        in_specs=(blk, m2, m2, blk, m2, m2),
        out_specs=(blk, m2),
    )


def gather_shardmap(mesh, *, threshold: float = 0.0, backend: str = "jnp"):
    """Back-compat: plan + executor for the all-gather engine."""
    from repro.core import plan as plan_mod

    p = plan_mod.plan_multiply(mesh, "gather")
    return plan_mod.build_program(
        p, threshold=threshold, backend=backend, c_layout="2d"
    )


def multiply_gather(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    mesh,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
) -> BlockSparseMatrix:
    from repro.core import plan as plan_mod

    return plan_mod.execute(
        a, b, mesh, "gather", threshold=threshold, backend=backend
    )
