"""Block→device distribution: sparsity-aware row/column assignment.

The 2.5D engines split the block grid into uniform (r, c) panels, so a
device's product load is whatever the sparsity pattern puts in its
panel.  Application patterns are not uniform: Zipf hub-row operators
(``tuner/corpus.py``) concentrate the surviving products on the few
devices owning the hub block-rows, and every capacity bound the stack
derives — compacted stack buckets, compressed-transport packing — is a
*maximum over devices*, so one hot panel inflates the padded work of
every device.  DBCSR's answer is a randomized row/column permutation
(Sivkov et al. 2019); Hong et al. 2024 (arXiv:2408.14558) go further and
partition by *nonzero count*.  This module implements both as a
plan-layer assignment stage (DESIGN.md §4):

``identity``    the unpermuted block-coordinate layout (the default);
``randomized``  DBCSR-style random permutation, seeded deterministically
                from the mask product so tuner and execution agree;
``nnz_greedy``  greedy bin-packing of block indices by their product
                load (row + column sums of the mask-product counts) into
                ``lcm(p_r, p_c)`` equal-cardinality bins — both the row
                panels and the column panels of the mesh are unions of
                whole bins, so one symmetric permutation balances both.

An :class:`Assignment` is one permutation ``perm`` applied to block rows
AND block columns: ``A' = P A Pᵀ``.  Symmetric assignments compose under
multiplication (``A'B' = P (AB) Pᵀ``) and fix the identity pattern, so a
whole Newton–Schulz chain runs in one permuted home layout — applied at
``shard_bsm``, undone at ``unshard``, with every engine, kernel and
transport in between unchanged (the permuted layout is just another
sparsity pattern).  Only cache keys grow the assignment signature
(``Assignment.key``); the tuner ranks assignment modes as one more
candidate axis and persists the winner in the tuning DB (``"assign"``
field; absent = identity).

Everything here is host-side numpy on the boolean masks — assignments
are data placement, not traced computation.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass

import numpy as np

from repro.core.commvolume import device_product_loads, load_imbalance  # noqa: F401

MODES = ("identity", "randomized", "nnz_greedy")


@dataclass(frozen=True)
class Assignment:
    """One symmetric block permutation: new block ``i`` is old ``perm[i]``.

    Applied to rows and columns alike (``blocks[perm][:, perm]``), so it
    is closed under multiplication and leaves the blocked identity
    invariant — the property fused iteration chains rely on to pin ONE
    assignment for a whole sweep sequence.
    """

    mode: str
    perm: tuple[int, ...]

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown assignment mode {self.mode!r}; "
                             f"one of {MODES}")

    @property
    def nb(self) -> int:
        return len(self.perm)

    @property
    def is_identity(self) -> bool:
        return all(p == i for i, p in enumerate(self.perm))

    @property
    def inv(self) -> tuple[int, ...]:
        """The undo permutation: ``x[perm][inv] == x``."""
        return tuple(int(i) for i in np.argsort(np.asarray(self.perm)))

    @property
    def key(self) -> tuple:
        """Compact cache-key element (mode + short digest of the perm):
        two different permutations must never share a compiled program
        that embeds the gather indices."""
        if self.is_identity:
            return ("identity",)
        digest = hashlib.sha1(
            np.asarray(self.perm, np.int64).tobytes()
        ).hexdigest()[:12]
        return (self.mode, self.nb, digest)

    def validate(self, nb_r: int, nb_c: int) -> None:
        """Check this assignment fits a (nb_r, nb_c) block grid: symmetric
        permutations need a square grid, and the perm must be a genuine
        permutation of its indices."""
        if nb_r != nb_c:
            raise ValueError(
                f"assignments permute rows and columns symmetrically; "
                f"block grid {nb_r}x{nb_c} is not square"
            )
        if len(self.perm) != nb_r:
            raise ValueError(
                f"assignment permutes {len(self.perm)} block indices, "
                f"matrix has {nb_r}"
            )
        if sorted(self.perm) != list(range(nb_r)):
            raise ValueError("assignment perm is not a permutation")


IDENTITY = None  # sentinel alias: resolve_assignment(None) == identity


def identity_assignment(nb: int) -> Assignment:
    return Assignment("identity", tuple(range(nb)))


def randomized_assignment(nb: int, seed: int) -> Assignment:
    """DBCSR's randomized load-balance permutation, explicit seed."""
    rng = np.random.default_rng(int(seed) & 0x7FFFFFFF)
    return Assignment("randomized", tuple(int(i) for i in rng.permutation(nb)))


def balance_bins(nb: int, p_r: int, p_c: int) -> int:
    """Bin count of the greedy packer: ``lcm(p_r, p_c)`` — the finest
    granularity at which both the row panels and the column panels of the
    mesh are unions of whole bins.  Both p_r and p_c divide nb (shard
    divisibility), so their lcm does too."""
    g = math.lcm(int(p_r), int(p_c))
    if nb % g:
        raise ValueError(
            f"block grid {nb} does not divide lcm(p_r={p_r}, p_c={p_c})={g}"
        )
    return g


def nnz_greedy_assignment(counts: np.ndarray, p_r: int, p_c: int) -> Assignment:
    """Greedy nnz-balanced bin-packing (Hong et al. 2024, rendered on the
    static block grid).

    Each block index is scored by its total product load — row plus
    column sums of the mask-product ``counts`` (products it contributes
    to as an A-row plus as a B-column) — then indices are placed, heaviest
    first, into the least-loaded of ``lcm(p_r, p_c)`` equal-cardinality
    bins.  The permutation concatenates the bins, so every (row, col)
    panel of the mesh holds bins of near-equal load.
    """
    counts = np.asarray(counts, np.int64)
    nb = counts.shape[0]
    if counts.shape[0] != counts.shape[1]:
        raise ValueError("nnz_greedy assignment needs a square block grid")
    g = balance_bins(nb, p_r, p_c)
    cap = nb // g
    w = counts.sum(axis=1) + counts.sum(axis=0)
    order = np.argsort(-w, kind="stable")
    bins: list[list[int]] = [[] for _ in range(g)]
    loads = np.zeros(g, np.int64)
    for i in order:
        open_bins = [j for j in range(g) if len(bins[j]) < cap]
        j = min(open_bins, key=lambda j: (loads[j], j))
        bins[j].append(int(i))
        loads[j] += int(w[i])
    perm = tuple(i for b in bins for i in b)
    return Assignment("nnz_greedy", perm)


def product_counts(mask_a, mask_b) -> np.ndarray:
    """Products contributing to each C block: the integer mask product
    ``A_mask @ B_mask`` (threshold-free on purpose — the tuner and the
    execution path must derive the SAME permutation from the same masks,
    independent of who walked the norm filter)."""
    am = np.asarray(mask_a, bool).astype(np.int64)
    bm = np.asarray(mask_b, bool).astype(np.int64)
    return am @ bm


def _grid(mesh_or_grid) -> tuple[int, int]:
    if isinstance(mesh_or_grid, tuple):
        p_r, p_c = mesh_or_grid
        return int(p_r), int(p_c)
    return int(mesh_or_grid.shape["r"]), int(mesh_or_grid.shape["c"])


def assignment_for(mode: str, counts: np.ndarray, mesh_or_grid) -> Assignment:
    """Deterministic assignment of one mode for (mask-product counts,
    mesh grid).  The randomized mode seeds from a digest of the counts,
    so every layer (tuner enumeration, DB rehydration, plan execution)
    derives the identical permutation for one pattern."""
    counts = np.asarray(counts, np.int64)
    nb = int(counts.shape[0])
    if mode == "identity":
        return identity_assignment(nb)
    if counts.shape[0] != counts.shape[1]:
        raise ValueError(
            f"non-identity assignments need a square block grid, got "
            f"{counts.shape}"
        )
    p_r, p_c = _grid(mesh_or_grid)
    if mode == "randomized":
        seed = int.from_bytes(
            hashlib.sha1(counts.tobytes()).digest()[:4], "little"
        )
        return randomized_assignment(nb, seed)
    if mode == "nnz_greedy":
        return nnz_greedy_assignment(counts, p_r, p_c)
    raise ValueError(f"unknown assignment mode {mode!r}; one of {MODES}")


def compute_assignment(mode: str, mask_a, mask_b, mesh_or_grid) -> Assignment:
    """Assignment of one mode from concrete operand masks (the execution
    path's entry point; see :func:`assignment_for` for determinism)."""
    return assignment_for(mode, product_counts(mask_a, mask_b), mesh_or_grid)


def apply_assignment(m, asg: Assignment):
    """Permute a BlockSparseMatrix into the assignment's home layout."""
    from repro.core import bsm as B

    asg.validate(m.nb_r, m.nb_c)
    if asg.is_identity:
        return m
    return B.permute(m, asg.perm, asg.perm)


def undo_assignment(m, asg: Assignment):
    """Inverse of :func:`apply_assignment` (bit-exact: pure reindexing)."""
    from repro.core import bsm as B

    asg.validate(m.nb_r, m.nb_c)
    if asg.is_identity:
        return m
    inv = asg.inv
    return B.permute(m, inv, inv)


def permute_cube(ok: np.ndarray, perm) -> np.ndarray:
    """The (i, k, j) filter cube in the permuted layout — what capacity
    bounds (``plan.get_device_capacity``) must be derived from when a
    non-identity assignment is in force."""
    p = np.asarray(perm)
    return np.asarray(ok)[np.ix_(p, p, p)]


def assignment_imbalance(counts: np.ndarray, mesh_or_grid,
                         asg: Assignment | None = None) -> float:
    """Max/mean per-device product load under an assignment (1.0 = perfectly
    balanced); the statistic the tuner's compute model scales by."""
    p_r, p_c = _grid(mesh_or_grid)
    perm = None if asg is None or asg.is_identity else asg.perm
    return load_imbalance(counts, p_r, p_c, perm=perm)
