"""Pattern-envelope forecasting: compile once for a whole drifting chain.

Purification physically changes the sparsity pattern every sweep — the
mask product fills blocks in, the threshold filter decays them — and the
paper's central empirical point is that this *effective fill-in upon
multiplication* decides performance.  Our fused chains (DESIGN.md §5)
trace one program while the pattern evolves underneath it, which is why
they pin the dense local backend and dense transport: a static compacted
capacity taken from the initial pattern would silently drop fill-in
products mid-iteration (``tuner.model.chain_safe``).

This module removes that restriction by forecasting.  ``forecast_chain``
propagates a *symbolic* (mask, norm-bound) pair through the Newton-Schulz
recurrence X <- 1/2 X (3I - X^2) in float64 — thresholded boolean
mask-product powers, the machinery of ``tuner/features.py`` — and returns
an :class:`Envelope`: an over-approximation of every per-sweep pattern
the realized chain can visit.  The plan layer then derives *sound static
capacities* from the envelope (stack product lists, transport packing
bounds), compiles ONE program against them, and the concrete per-sweep
mask enters as runtime *data* — the existing traced-capable mask-AND
inside ``compact_pair_mask`` / ``pack_panel`` does the per-sweep work.
A whole drifting-pattern chain then executes with ``builds == 1`` and
zero host-side stack regeneration, which is DBCSR's cheap per-multiply
stack regeneration (arXiv:1910.13555) amortized to *zero* per-multiply
host work, and the ahead-of-execution sparsity-structure prediction of
Hong et al. (arXiv:2408.14558) applied to a whole iteration.

Soundness
---------

The forecast is inductive.  Write ``m_s`` / ``n_s`` for the realized mask
and per-block Frobenius norms entering sweep ``s`` and ``M_s`` / ``N_s``
for the symbolic pair.  Invariant: ``m_s <= M_s`` (bitwise) and
``n_s <= (1 + eps_s) N_s`` elementwise, where ``eps_s`` is the
accumulated floating-point slack.  Each propagation step preserves it:

* a product survives the realized on-the-fly filter only if
  ``n_ik n_kj > threshold``; the symbolic filter keeps every product with
  ``N_ik N_kj > threshold / (1 + margin)``, so as long as
  ``(1 + eps_s)^2 <= 1 + margin`` the realized survivor set is a subset;
* the symbolic result bound ``N2_ij = sum_k N_ik N_kj`` over surviving
  products dominates the realized block norm by the triangle inequality;
* ``Y = 3I - X^2`` bounds as ``N2 + 3 sqrt(bs)`` on the diagonal
  (``||3 I_bs||_F = 3 sqrt(bs)``) and ``N2`` elsewhere;
* the post-multiplication filter compares against
  ``filter_eps / (1 + margin)`` *before* the exact 0.5 scale, mirroring
  the realized order in ``signiter._make_sweep``.

``margin`` absorbs the floating-point slack: realized f32 norms are
computed from realized f32 data, so they can exceed the exact-arithmetic
bound by accumulated rounding.  The default (5%) is generous for f32
chains of practical depth; reduced-precision storage (bf16) quantizes
every stored block per sweep and can need a larger margin on deep chains
— the parameter is exposed for exactly that reason.  Products *near* the
effective thresholds are kept either way, so a larger margin only makes
the envelope looser, never unsound.

``union_envelope`` is the stream-shaped constructor (no recurrence):
given a family of concrete operand masks — serving traffic, MoE expert
dispatch where no two batches share an exact mask — the envelope is the
mask union and its product cube, sound for any threshold (the norm
filter only removes products).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property

import numpy as np

# default floating-point slack absorbed by the effective thresholds (see
# the module docstring); 0 disables the relaxation (exact-arithmetic
# envelope, only sound for exact realized chains)
DEFAULT_MARGIN = 0.05


def _frozen(arr: np.ndarray) -> np.ndarray:
    arr = np.ascontiguousarray(arr)
    arr.flags.writeable = False
    return arr


@dataclass(frozen=True)
class Envelope:
    """Over-approximating pattern envelope of a multiply chain/stream.

    ``mask_a`` / ``mask_b``  — 2D bool unions of every left / right
        operand mask a chain multiply can ship (transport capacities).
    ``cube``                 — (nb_r, nb_k, nb_c) bool union of every
        per-multiply surviving-product cube (stack capacities).
    ``sweep_masks``          — per-sweep forecast result masks of a
        ``forecast_chain`` envelope (the per-sweep over-approximations
        the property tests check realized masks against); empty for
        stream envelopes.
    ``threshold`` / ``filter_eps`` / ``margin`` — the chain spec the
        forecast ran under (0 / 0 / 0 for stream envelopes).
    """

    mask_a: np.ndarray
    mask_b: np.ndarray
    cube: np.ndarray
    sweep_masks: tuple = ()
    threshold: float = 0.0
    filter_eps: float = 0.0
    margin: float = 0.0

    @cached_property
    def signature(self) -> bytes:
        """Digest identifying this envelope (decision-cache key part)."""
        import hashlib

        from repro.kernels.stacks import pattern_signature

        h = hashlib.sha1(b"envelope")
        h.update(pattern_signature(self.cube))
        h.update(pattern_signature(self.mask_a))
        h.update(pattern_signature(self.mask_b))
        h.update(np.float64([self.threshold, self.filter_eps,
                             self.margin]).tobytes())
        return h.digest()

    def covers(self, mask_a, mask_b=None) -> bool:
        """Whether a concrete operand pattern lies inside the envelope —
        the cheap (2D, no cube walk) drift check the engine runs before
        trusting envelope-derived capacities."""
        am = np.asarray(mask_a, bool)
        if am.shape != self.mask_a.shape or not (am <= self.mask_a).all():
            return False
        if mask_b is None:
            return True
        bm = np.asarray(mask_b, bool)
        return bm.shape == self.mask_b.shape and bool((bm <= self.mask_b).all())

    def local_capacity(self) -> int:
        """Bucketed single-device stack capacity covering every multiply
        of the chain (the union cube's product count)."""
        from repro.kernels.stacks import bucket_capacity

        return bucket_capacity(int(self.cube.sum()))

    def device_capacity(self, mesh, engine: str) -> int:
        """Bucketed per-device stack capacity over the envelope cube —
        sound for every sweep because capacity bounds are monotone in the
        cube (``plan.get_device_capacity``, LRU-cached on the envelope's
        pattern signature like any concrete cube)."""
        from repro.core import plan as plan_mod

        return plan_mod.get_device_capacity(self.cube, mesh, engine)

    def transport(self, mesh, engine: str, l: int | None = None,
                  mode: str = "auto"):
        """Panel transport resolved against the envelope's operand-mask
        unions: packing capacities that cover every panel any sweep can
        ship (``plan.get_transport`` — monotone in the masks)."""
        from repro.core import plan as plan_mod

        return plan_mod.get_transport(self.mask_a, self.mask_b, mesh,
                                      engine, l, mode)


def forecast_chain(
    mask,
    norms,
    *,
    sweeps: int,
    threshold: float = 0.0,
    filter_eps: float = 0.0,
    bs: int = 1,
    margin: float = DEFAULT_MARGIN,
) -> Envelope:
    """Symbolic fill-in forecast of ``sweeps`` Newton-Schulz sweeps.

    ``mask`` / ``norms`` — the concrete pattern entering the chain (post
    spectral scale, post storage cast: the operand the first sweep
    actually multiplies).  ``bs`` — the square block edge (the identity
    block's Frobenius norm is ``sqrt(bs)``).  Returns the
    :class:`Envelope` whose cube / mask unions cover every multiply of
    the chain and whose ``sweep_masks[s]`` covers the realized result
    mask of sweep ``s`` (see the module docstring for the invariant).
    """
    if sweeps < 1:
        raise ValueError(f"sweeps must be >= 1, got {sweeps}")
    if margin < 0.0:
        raise ValueError(f"margin must be >= 0, got {margin}")
    m = np.asarray(mask, bool)
    if m.ndim != 2 or m.shape[0] != m.shape[1]:
        raise ValueError(f"chain forecasting needs a square 2D mask, "
                         f"got shape {m.shape}")
    n = np.where(m, np.asarray(norms, np.float64), 0.0)
    nb = m.shape[0]
    # norm-bound ceiling: propagated bounds grow ~3x per sweep and would
    # overflow float64 on long chains.  Clipping DOWN stays sound because
    # any REALIZED norm is a finite float32 (<= ~3.4e38 << _NORM_CAP): a
    # clipped bound still dominates every value the filters compare, and
    # products of two capped bounds stay finite (1e200 < float64 max).
    _NORM_CAP = 1e100
    eye = np.eye(nb, dtype=bool)
    ident_norm = 3.0 * np.sqrt(float(bs))
    thr_eff = threshold / (1.0 + margin)
    eps_eff = filter_eps / (1.0 + margin)

    def product_cube(lm, ln, rm, rn):
        ok = lm[:, :, None] & rm[None, :, :]
        if threshold > 0.0:
            ok &= ln[:, :, None] * rn[None, :, :] > thr_eff
        return ok

    def contract(ok, ln, rn):
        cm = ok.any(axis=1)
        cn = np.where(ok, ln[:, :, None] * rn[None, :, :], 0.0).sum(axis=1)
        cn = np.minimum(cn, _NORM_CAP)
        if filter_eps > 0.0:
            keep = cm & (cn > eps_eff)
            cm, cn = keep, np.where(keep, cn, 0.0)
        return cm, cn

    cube = np.zeros((nb, nb, nb), bool)
    union_a = m.copy()
    union_b = m.copy()
    sweep_masks = []
    for _ in range(sweeps):
        # multiply 1: X . X (+ post-filter, the realized sweep's order)
        ok = product_cube(m, n, m, n)
        x2m, x2n = contract(ok, n, n)
        # Y = 3I - X^2: diagonal blocks gain the identity's norm bound
        ym = x2m | eye
        yn = x2n + ident_norm * eye
        # multiply 2: X . Y, post-filter BEFORE the exact 0.5 scale
        ok2 = product_cube(m, n, ym, yn)
        cm, cn = contract(ok2, n, yn)
        cube |= ok | ok2
        union_a |= m
        union_b |= m | ym
        m, n = cm, 0.5 * cn
        sweep_masks.append(_frozen(m))
    return Envelope(
        mask_a=_frozen(union_a),
        mask_b=_frozen(union_b),
        cube=_frozen(cube),
        sweep_masks=tuple(sweep_masks),
        threshold=float(threshold),
        filter_eps=float(filter_eps),
        margin=float(margin),
    )


def union_envelope(masks_a, masks_b=None) -> Envelope:
    """Stream envelope: the union of a family of concrete operand masks.

    ``masks_a`` — iterable of (nb_r, nb_k) left-operand masks (serving
    batches, MoE dispatch patterns); ``masks_b`` — right-operand masks
    (defaults to ``masks_a``, the A @ A stream).  The cube is the product
    cube of the unions — sound for any threshold, since the norm filter
    only ever removes products from the presence cube.
    """
    from repro.tuner.features import mask_union

    ua = mask_union(masks_a)
    ub = ua if masks_b is None else mask_union(masks_b)
    if ua.shape[1] != ub.shape[0]:
        raise ValueError(
            f"operand mask unions do not chain: {ua.shape} @ {ub.shape}"
        )
    cube = ua[:, :, None] & ub[None, :, :]
    return Envelope(mask_a=_frozen(ua), mask_b=_frozen(ub),
                    cube=_frozen(cube))


# ---------------------------------------------------------------------------
# DispatchCache: the serving-grade pattern-bucketed program cache
# ---------------------------------------------------------------------------


@dataclass
class DispatchBucket:
    """One warmed request-mix regime: a union envelope plus the decision
    resolved for it (local backend + stack capacity), and its counters."""

    envelope: Envelope
    decision: dict
    hits: int = 0
    widenings: int = 0


def _analytic_dispatch_decision(env: Envelope, bs_r: int, bs_k: int,
                                bs_c: int, dtype: str) -> dict:
    """Backend + capacity for a dispatch envelope, from the cost model.

    The same dense/compacted crossover the engine's ``choose_backend``
    runs on concrete patterns (``local_mm.backend_local_cost``), evaluated
    once on the envelope's union cube instead of per batch.
    """
    from repro.core.local_mm import backend_local_cost

    ni, nk, nj = env.cube.shape
    fill = float(env.cube.mean()) if env.cube.size else 0.0
    dense = backend_local_cost(ni, nk, nj, bs_r, bs_k, bs_c,
                               fill=1.0, backend="jnp", dtype=dtype)
    compact = backend_local_cost(ni, nk, nj, bs_r, bs_k, bs_c,
                                 fill=fill, backend="stacks", dtype=dtype)
    backend = "jnp" if dense <= compact else "stacks"
    return {"backend": backend, "capacity": env.local_capacity(),
            "source": "analytic"}


class DispatchCache:
    """Pattern-bucketed envelope/decision cache for serving streams.

    The serving regime the ROADMAP names: every batch routes tokens
    differently, so no two dispatch masks are equal — but request MIXES
    are stable for long stretches.  This cache groups masks into the
    coarse feature buckets of ``tuner.features.mask_bucket`` (log2 shape
    classes, occupancy deciles, row-load class) and keeps ONE union
    envelope per bucket, warmed over a calibration stream:

    * ``resolve(mask)`` on a warmed bucket whose envelope covers the mask
      is the warm serving path — zero per-batch pattern walks, the
      envelope's stable capacities route every batch of the mix through
      one traced program (``dispatch_hits`` in ``plan.cache_stats()``);
    * a mask that lands in a NEW bucket warms it (``dispatch_misses`` —
      once per request-mix regime, not per batch);
    * a mask that escapes its bucket's envelope WIDENS the union and
      re-resolves the decision (``drift_retunes``) — the bucketed
      capacities make most widenings land in the same capacity bucket,
      so the compiled program usually survives the widen.

    The per-bucket decision (local backend + stack capacity) is resolved
    ONCE per bucket, not per batch; with a tuning DB bound
    (``tuner.set_default_db`` — the ``--tuning-db`` serving flag) the
    decision is persisted under a ``dispatch|`` key, so a relaunched
    server warm-starts every previously-seen mix measurement-free: the
    tuner DB as a serving-time asset.
    """

    def __init__(self, mask_b, *, bs_r: int = 1, bs_k: int = 1,
                 bs_c: int = 1, dtype: str = "float32",
                 decision_fn=None):
        self.mask_b = np.asarray(mask_b, bool)
        self.bs_r, self.bs_k, self.bs_c = int(bs_r), int(bs_k), int(bs_c)
        self.dtype = str(dtype)
        self._decision_fn = decision_fn
        self._buckets: dict[tuple, DispatchBucket] = {}

    # ---- keys ----------------------------------------------------------
    def bucket_of(self, mask) -> tuple:
        from repro.tuner.features import mask_bucket

        return mask_bucket(mask, self.bs_r, self.bs_c)

    # ---- decision resolution (once per bucket) -------------------------
    def _db_key(self, key: tuple) -> str:
        return "dispatch|" + "|".join(str(p) for p in key)

    def _decide(self, key: tuple, env: Envelope) -> dict:
        from repro import tuner

        if self._decision_fn is not None:
            return dict(self._decision_fn(env))
        db = tuner.get_default_db()
        need = env.local_capacity()
        if db is not None:
            rec = db.lookup(self._db_key(key))
            # a persisted decision is only reusable if its capacity still
            # covers this launch's envelope (capacities are monotone in
            # the union — a looser warm-up needs a re-derive + re-record)
            if rec is not None and int(rec.get("capacity", 0)) >= need:
                return {"backend": rec["backend"],
                        "capacity": int(rec["capacity"]), "source": "db"}
        dec = _analytic_dispatch_decision(env, self.bs_r, self.bs_k,
                                          self.bs_c, self.dtype)
        if db is not None:
            db.record(self._db_key(key), dict(dec))
        return dec

    # ---- the serving-path API ------------------------------------------
    def warm(self, masks) -> "DispatchCache":
        """Fold a calibration stream into the buckets (no hit/miss
        accounting — calibration is not serving traffic)."""
        for m in masks:
            self._observe(np.asarray(m, bool), calibration=True)
        return self

    def resolve(self, mask) -> tuple[Envelope, dict]:
        """Serving-time lookup: (envelope, decision) for one batch's
        dispatch mask, with warm/miss/drift accounting."""
        return self._observe(np.asarray(mask, bool), calibration=False)

    def _observe(self, m: np.ndarray, *, calibration: bool):
        from repro.core import plan as plan_mod

        key = self.bucket_of(m)
        bkt = self._buckets.get(key)
        if bkt is None:
            env = union_envelope([m], [self.mask_b])
            bkt = DispatchBucket(envelope=env,
                                 decision=self._decide(key, env))
            self._buckets[key] = bkt
            if not calibration:
                plan_mod.note_dispatch_lookup(False)
            return bkt.envelope, bkt.decision
        if not bkt.envelope.covers(m):
            # in-bucket drift: widen the union, re-resolve the decision
            bkt.envelope = union_envelope(
                [bkt.envelope.mask_a, m], [self.mask_b])
            bkt.decision = self._decide(key, bkt.envelope)
            bkt.widenings += 1
            if not calibration:
                plan_mod.note_drift_retune()
            return bkt.envelope, bkt.decision
        if not calibration:
            bkt.hits += 1
            plan_mod.note_dispatch_lookup(True)
        return bkt.envelope, bkt.decision

    # ---- introspection -------------------------------------------------
    def __len__(self) -> int:
        return len(self._buckets)

    def stats(self) -> dict:
        return {
            "buckets": len(self._buckets),
            "hits": sum(b.hits for b in self._buckets.values()),
            "widenings": sum(b.widenings for b in self._buckets.values()),
            "capacities": sorted(
                {int(b.decision["capacity"]) for b in self._buckets.values()}
            ),
        }
