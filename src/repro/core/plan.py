"""Topology-driven multiply plans: one scheduler for all four engines.

A ``MultiplyPlan`` compiles a :class:`repro.core.topology.Topology` (the
paper's Algorithm 2 coordinates) into the *static* communication schedule a
shard_map engine executes: pre-shift permutations, per-tick ring shifts or
one-sided pulls, per-layer k-chunks, and the partial-C reduction.  The four
engines (``cannon``, ``onesided``, ``gather``, ``twofive``) are thin
executors of a plan — none of them derives coordinates inline any more.

Plan kinds
----------

``ring``     Cannon / PTP (Algorithm 1): pre-shift + V ring shifts.  Square
             2D meshes only (the paper's baseline).
``pull``     Algorithm 2 run directly on the 2D (r, c) process grid with the
             depth axis *virtual* — the paper's actual topology, including
             non-square grids (P_R != P_C, L = mx/mn forced) and L = 1
             (= OS1).  Every one-sided ``rget`` of the paper becomes a
             static partial permutation: per tick, per A/B panel slot, per
             home-shard subpanel, the (home -> requester) pairs derived from
             ``group_products``; multicasts are split greedily into rounds
             so each round is a valid (partial) permutation.
``stacked``  The TPU mesh formulation on an (l, r, c) mesh: A/B replicated
             over ``l``, layer l runs a Cannon schedule over its k-chunk
             ``Topology.chunk(l)``, partial C reduced over ``l``.  Uneven
             chunks (L does not divide the grid side) are supported via
             per-layer tick masking.
``gather``   Fused all-gather pull-from-home (TPU-native OS1), any grid.

Compiled-program cache
----------------------

``get_compiled`` returns a jitted shard_map program, LRU-cached on
``(mesh, engine, nb, bs, dtype, threshold, backend, c_layout, l,
stack_capacity, interpret, transport, assignment)`` so the hot paths
(sign iteration, serving, benchmark loops) never retrace or re-lower
after the first call.

Distribution layer
------------------

``resolve_assignment`` / ``get_assignment`` resolve the block→device
assignment (``core.distribute``, DESIGN.md): a symmetric row+column
permutation that rebalances per-device product load before the engines
partition the grid.  Replicated execution applies it inside the
compiled program (permute-in / unpermute-out around the engine body);
sharded execution relies on ``shard_bsm`` having applied it at the
chain boundary.  Every capacity bound (stacks, transport) is derived
from the PERMUTED pattern.

Panel transport
---------------

Engines no longer inline their communication: panel movement goes
through ``repro.core.transport`` (DESIGN.md §3), either ``dense``
(bit-exact full-panel permutes, norms dropped from the wire) or
``compressed`` (occupancy-packed buffers whose capacities are derived
soundly per device here, like PR 2's stack bounds).  ``get_transport``
resolves mode + capacities from the concrete operand masks (LRU-cached
on the pattern signatures; ``REPRO_TRANSPORT`` overrides the mode) and
the result joins the program-cache key; ``transport_*`` counters in
``cache_stats()`` expose the resolutions.
``get_local_compiled`` does the same for the single-device compacted
local stage (the ``stacks``/``pallas`` backends), keyed on block-grid
shape and *capacity bucket* — patterns with equal bucketed product counts
share one executable.  ``cache_stats()`` exposes hit/miss/build counters
for tests and benchmarks.

Autotuned dispatch
------------------

``execute`` / ``execute_sharded`` accept ``engine="auto"``: the decision
layer above this cache (``repro.tuner``, DESIGN.md §6) resolves
``(engine, L, backend, stack_capacity)`` from the concrete sparsity
pattern — analytic Eq. 6/7 pruning, then short measured trials whose
winners persist in a tuning database.  Tuner decisions are counted in
``cache_stats()`` (``tuner_hits`` / ``tuner_misses`` / ``tuner_trials``)
and dropped by ``clear_cache()`` like every other cache level.

Pattern cache
-------------

``get_product_stacks`` compacts a *concrete* pair-filter cube into its
padded product list (``kernels/stacks.py``) and LRU-caches the result on
the sparsity-pattern signature — DBCSR's stack generation, amortized: the
sign-iteration / serving loops re-multiply the same (or slowly evolving)
pattern, so repeated patterns cost neither a host walk nor a recompile
(the local program key depends only on the capacity bucket).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from functools import lru_cache

import jax.numpy as jnp

from repro.core.topology import (
    Topology,
    coords3d,
    group_k,
    make_topology,
)

Perm = tuple[tuple[int, int], ...]


@dataclass(frozen=True)
class PullRound:
    """One partial permutation of one home-shard subpanel.

    ``slot``  — which of the device's L_R A panels / L_C B panels this
                round feeds (the i3 / j3 coordinate of ``group_products``).
    ``q``     — subpanel index within the home shard (virtual index modulo
                the shard's subpanel count); selects a static slice.
    ``pairs`` — (home, requester) flattened-mesh index pairs; a valid
                partial permutation (unique sources, unique destinations).
    """

    slot: int
    q: int
    pairs: Perm


@dataclass(frozen=True)
class MultiplyPlan:
    """Static communication schedule for one (mesh, engine) pair."""

    engine: str
    kind: str  # "ring" | "pull" | "stacked" | "gather"
    mesh: object  # the jax Mesh the schedule was compiled for
    axes: tuple[str, ...]  # mesh axes of the flattened permutation domain
    p_r: int
    p_c: int
    topo: Topology
    ticks: int
    # --- ring (cannon) ---
    pre_a: Perm = ()
    pre_b: Perm = ()
    shift_a: Perm = ()  # one ring hop of A (along c)
    shift_b: Perm = ()  # one ring hop of B (along r)
    # --- pull (Algorithm 2 on the 2D grid) ---
    a_pulls: tuple[tuple[PullRound, ...], ...] = ()  # [tick][round]
    b_pulls: tuple[tuple[PullRound, ...], ...] = ()
    c_rounds: tuple[Perm, ...] = ()  # L-1 partial-C sends
    ca: int = 1  # A subpanels per home shard (= V / P_C)
    cb: int = 1  # B subpanels per home shard (= V / P_R)
    # --- stacked ((l, r, c) mesh) ---
    layer_groups: tuple[int, ...] = ()  # ticks of each layer
    chunk_starts: tuple[int, ...] = ()  # k-chunk offset of each layer

    @property
    def l(self) -> int:
        return self.topo.l

    def validate_blocks(
        self, nb_r: int, nb_c: int, nb_k: int | None = None
    ) -> None:
        """Check the product's block grids divide this plan's topology.

        ``(nb_r, nb_c)`` is the output grid; ``nb_k`` is the contracted
        block count (A is ``nb_r x nb_k``, B is ``nb_k x nb_c``).  With
        ``nb_k=None`` the historical square contract applies (``nb_k`` is
        implied equal to both, as every pre-tensor caller guaranteed).
        Rectangular callers MUST pass ``nb_k``: the k axis is the one the
        engines slice hardest — A's column panels shard over ``p_c``, B's
        row panels over ``p_r``, and the pull formulation additionally
        cuts k into V virtual subpanels — and none of that is implied by
        the output grid.
        """
        v = self.topo.v
        if nb_r % self.p_r or nb_c % self.p_c:
            raise ValueError(
                f"block grid {nb_r}x{nb_c} does not divide the "
                f"{self.p_r}x{self.p_c} process grid"
            )
        if nb_k is None:
            if self.kind == "pull" and (nb_r % v or nb_c % v):
                raise ValueError(
                    f"block grid {nb_r}x{nb_c} does not divide the virtual "
                    f"grid V={v} (required for one-sided panel pulls)"
                )
            return
        if nb_k % self.p_c or nb_k % self.p_r:
            raise ValueError(
                f"contracted block count nb_k={nb_k} does not divide the "
                f"{self.p_r}x{self.p_c} process grid (A column panels "
                f"shard over p_c={self.p_c}, B row panels over "
                f"p_r={self.p_r})"
            )
        if self.kind == "pull" and nb_k % v:
            raise ValueError(
                f"contracted block count nb_k={nb_k} does not divide the "
                f"virtual grid V={v} (required for one-sided k-subpanel "
                f"pulls)"
            )


# ---------------------------------------------------------------------------
# schedule compilation
# ---------------------------------------------------------------------------


def _ring_perm(p: int, shift: int = 1) -> Perm:
    """Receive from (k + shift) % p: the Cannon ring hop."""
    return tuple((src, (src - shift) % p) for src in range(p))


def _partition_rounds(pairs: list[tuple[int, int]]) -> list[Perm]:
    """Split (src, dst) pairs into valid partial permutations.

    A source that must multicast (same panel requested by several devices in
    one tick — the sqrt(L) amortization of the paper) is serialized over
    rounds; each round has unique sources and unique destinations.
    """
    rounds: list[list[tuple[int, int]]] = []
    used: list[tuple[set[int], set[int]]] = []
    for src, dst in pairs:
        for r, (srcs, dsts) in zip(rounds, used):
            if src not in srcs and dst not in dsts:
                r.append((src, dst))
                srcs.add(src)
                dsts.add(dst)
                break
        else:
            rounds.append([(src, dst)])
            used.append(({src}, {dst}))
    return [tuple(r) for r in rounds]


def _pull_schedule(topo: Topology):
    """Per-tick pull rounds + C-reduction rounds from Algorithm 2.

    Drives everything from the topology's stated invariants: per tick group
    ``g`` a process at (i, j) pulls the L_R A panels (m, k) and L_C B panels
    (k, n) of ``group_products`` from their *home* 2D positions, where the
    home of virtual A panel (m, k) is process (m, k // ca) subpanel k % ca
    (ca = V / P_C) and of B panel (k, n) is (k // cb, n) subpanel k % cb.
    """
    p_r, p_c, v, s = topo.p_r, topo.p_c, topo.v, topo.side3d
    ca, cb = v // p_c, v // p_r

    def flat(i: int, j: int) -> int:
        return i * p_c + j

    a_ticks: list[tuple[PullRound, ...]] = []
    b_ticks: list[tuple[PullRound, ...]] = []
    for g in range(topo.ticks):
        a_classes: dict[tuple[int, int], list[tuple[int, int]]] = {}
        b_classes: dict[tuple[int, int], list[tuple[int, int]]] = {}
        for i in range(p_r):
            for j in range(p_c):
                _, _, lay = coords3d(topo, i, j)
                if g >= topo.layer_groups(lay):
                    continue  # this layer's k-chunk is exhausted
                k = group_k(topo, i, j, g)
                im, jn = i % s, j % s
                for i3 in range(topo.l_r):
                    m = i3 * s + im
                    a_classes.setdefault((i3, k % ca), []).append(
                        (flat(m, k // ca), flat(i, j))
                    )
                for j3 in range(topo.l_c):
                    n = j3 * s + jn
                    b_classes.setdefault((j3, k % cb), []).append(
                        (flat(k // cb, n), flat(i, j))
                    )
        a_ticks.append(
            tuple(
                PullRound(slot=slot, q=q, pairs=perm)
                for (slot, q), pairs in sorted(a_classes.items())
                for perm in _partition_rounds(pairs)
            )
        )
        b_ticks.append(
            tuple(
                PullRound(slot=slot, q=q, pairs=perm)
                for (slot, q), pairs in sorted(b_classes.items())
                for perm in _partition_rounds(pairs)
            )
        )

    # L-1 partial-C sends: round d moves the partial for the panel d steps
    # along the flattened layer ring to its home (a full permutation).
    c_rounds: list[Perm] = []
    for d in range(1, topo.l):
        pairs = []
        for i in range(p_r):
            for j in range(p_c):
                _, _, lay = coords3d(topo, i, j)
                t = (lay + d) % topo.l
                ti3, tj3 = t % topo.l_r, t // topo.l_r
                pairs.append(
                    (flat(i, j), flat(ti3 * s + i % s, tj3 * s + j % s))
                )
        c_rounds.append(tuple(pairs))
    return tuple(a_ticks), tuple(b_ticks), tuple(c_rounds), ca, cb


def _resolve_l(p_r: int, p_c: int, l: int | None) -> int:
    """Default depth: forced mx/mn on non-square grids (the paper's rule),
    1 on square grids unless the caller asks for more."""
    if l is not None:
        return l
    if p_r != p_c:
        mn, mx = min(p_r, p_c), max(p_r, p_c)
        if mx % mn == 0 and mx <= mn * mn:
            return mx // mn
    return 1


@lru_cache(maxsize=256)
def plan_multiply(mesh, engine: str, l: int | None = None) -> MultiplyPlan:
    """Compile the static schedule for (mesh, engine).

    2D meshes must carry ("r", "c") axes; the 2.5D stacked formulation uses
    an ("l", "r", "c") mesh.  ``l`` overrides the depth for pull plans on
    square grids (non-square grids force L = mx/mn as in the paper).
    """
    axis_names = tuple(mesh.axis_names)
    if engine not in ("cannon", "onesided", "gather", "twofive"):
        raise ValueError(f"unknown engine {engine!r}")
    if l is not None and engine in ("cannon", "onesided", "gather"):
        raise ValueError(
            f"engine {engine!r} has no depth parameter (L is fixed at 1); "
            "use engine='twofive' for L > 1"
        )

    if "l" in axis_names:
        if engine != "twofive":
            raise ValueError(f"engine {engine!r} does not use an 'l' mesh axis")
        l_size = mesh.shape["l"]
        if l is not None and l != l_size:
            raise ValueError(
                f"l={l} conflicts with the mesh's 'l' axis of size {l_size}; "
                "the stacked engine takes its depth from the mesh"
            )
        p = mesh.shape["r"]
        if mesh.shape["c"] != p:
            raise ValueError(
                "stacked 2.5D requires square layer grids; use a 2D "
                "(r, c) mesh for non-square topologies (virtual depth)"
            )
        # the mesh formulation's chunk structure: V = p, depth = l_size.
        topo = Topology(
            p_r=p, p_c=p, l=l_size, l_r=1, l_c=l_size, side3d=p,
            v=p, nbuffers_a=2, nbuffers_b=2,
        )
        groups = tuple(topo.layer_groups(li) for li in range(l_size))
        starts = tuple(topo.chunk(li)[0] for li in range(l_size))
        ticks = max(groups)
        pre_a = tuple(
            (
                (li * p + i) * p + j,
                (li * p + i) * p + (j - i - starts[li]) % p,
            )
            for li in range(l_size)
            for i in range(p)
            for j in range(p)
        )
        pre_b = tuple(
            (
                (li * p + i) * p + j,
                (li * p + (i - j - starts[li]) % p) * p + j,
            )
            for li in range(l_size)
            for i in range(p)
            for j in range(p)
        )
        return MultiplyPlan(
            engine=engine, kind="stacked", mesh=mesh, axes=("l", "r", "c"),
            p_r=p, p_c=p, topo=topo, ticks=ticks,
            pre_a=pre_a, pre_b=pre_b,
            shift_a=_ring_perm(p), shift_b=_ring_perm(p),
            layer_groups=groups, chunk_starts=starts,
        )

    p_r, p_c = mesh.shape["r"], mesh.shape["c"]
    if engine == "gather":
        topo = make_topology(p_r, p_c, 1)
        return MultiplyPlan(
            engine=engine, kind="gather", mesh=mesh, axes=("r", "c"),
            p_r=p_r, p_c=p_c, topo=topo, ticks=1,
        )

    if engine == "cannon":
        if p_r != p_c:
            raise ValueError("Cannon engine requires a square grid")
        p = p_r
        topo = make_topology(p, p, 1)
        pre_a = tuple(
            (i * p + j, i * p + (j - i) % p) for i in range(p) for j in range(p)
        )
        pre_b = tuple(
            (i * p + j, ((i - j) % p) * p + j) for i in range(p) for j in range(p)
        )
        return MultiplyPlan(
            engine=engine, kind="ring", mesh=mesh, axes=("r", "c"),
            p_r=p, p_c=p, topo=topo, ticks=topo.v,
            pre_a=pre_a, pre_b=pre_b,
            shift_a=_ring_perm(p), shift_b=_ring_perm(p),
        )

    # onesided / twofive on the plain 2D grid: the pull formulation.
    depth = 1 if engine == "onesided" else _resolve_l(p_r, p_c, l)
    topo = make_topology(p_r, p_c, depth)
    if l is not None and engine == "twofive" and topo.l != l:
        raise ValueError(
            f"L={l} is invalid for a {p_r}x{p_c} grid (paper rule); "
            f"topology resolved L={topo.l}"
        )
    a_pulls, b_pulls, c_rounds, ca, cb = _pull_schedule(topo)
    return MultiplyPlan(
        engine=engine, kind="pull", mesh=mesh, axes=("r", "c"),
        p_r=p_r, p_c=p_c, topo=topo, ticks=topo.ticks,
        a_pulls=a_pulls, b_pulls=b_pulls, c_rounds=c_rounds, ca=ca, cb=cb,
    )


# ---------------------------------------------------------------------------
# compiled-program cache
# ---------------------------------------------------------------------------


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    builds: int = 0  # program constructions (lower/trace roots)
    evictions: int = 0
    pattern_hits: int = 0  # compacted product-list reuse (same signature)
    pattern_misses: int = 0
    chain_hits: int = 0  # fused chain-step program reuse (sign iteration)
    chain_misses: int = 0
    tuner_hits: int = 0  # engine="auto" decisions served without trials
    tuner_misses: int = 0  # decisions that needed analytic rank / trials
    tuner_trials: int = 0  # candidates actually timed by the tuner
    transport_hits: int = 0  # transport resolutions served from the cache
    transport_misses: int = 0  # resolutions that walked the masks
    transport_dense: int = 0  # fresh resolutions that chose dense panels
    transport_compressed: int = 0  # ... that chose compressed panels
    assign_hits: int = 0  # block-assignment resolutions served from cache
    assign_misses: int = 0  # resolutions that derived a permutation
    envelope_hits: int = 0  # chain-envelope forecasts served from cache
    envelope_misses: int = 0  # forecasts that ran the symbolic propagation
    dispatch_hits: int = 0  # serving-dispatch bucket lookups served warm
    dispatch_misses: int = 0  # ... that warmed a new bucket
    drift_retunes: int = 0  # pattern drift that forced a re-tune/re-derive

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "builds": self.builds,
            "evictions": self.evictions,
            "pattern_hits": self.pattern_hits,
            "pattern_misses": self.pattern_misses,
            "chain_hits": self.chain_hits,
            "chain_misses": self.chain_misses,
            "tuner_hits": self.tuner_hits,
            "tuner_misses": self.tuner_misses,
            "tuner_trials": self.tuner_trials,
            "transport_hits": self.transport_hits,
            "transport_misses": self.transport_misses,
            "transport_dense": self.transport_dense,
            "transport_compressed": self.transport_compressed,
            "assign_hits": self.assign_hits,
            "assign_misses": self.assign_misses,
            "envelope_hits": self.envelope_hits,
            "envelope_misses": self.envelope_misses,
            "dispatch_hits": self.dispatch_hits,
            "dispatch_misses": self.dispatch_misses,
            "drift_retunes": self.drift_retunes,
        }


_CACHE_MAXSIZE = 128
_program_cache: OrderedDict[tuple, object] = OrderedDict()
_pattern_cache: OrderedDict[bytes, tuple] = OrderedDict()
_bound_cache: OrderedDict[tuple, int] = OrderedDict()
_transport_cache: OrderedDict[tuple, object] = OrderedDict()
_assign_cache: OrderedDict[tuple, object] = OrderedDict()
_envelope_cache: OrderedDict[tuple, object] = OrderedDict()
_stats = CacheStats()


_extra_caches: list = []  # clear() callables of satellite layers (tuner)


def register_cache(clear_fn) -> None:
    """Register a satellite cache's clear callable: ``clear_cache()``
    must drop *every* cache level (program, pattern, chain, tuner) so
    test modules and drivers start from a genuinely clean slate."""
    if clear_fn not in _extra_caches:
        _extra_caches.append(clear_fn)


def cache_stats() -> dict:
    """Program/pattern/chain/tuner-cache counters (hits / misses / ...)."""
    return _stats.as_dict()


def clear_cache() -> None:
    """Drop ALL plan-layer caches and zero every counter: compiled
    programs (incl. chain steps), pattern product-lists, capacity bounds,
    transport resolutions, the compiled-schedule LRU (``plan_multiply``)
    and any registered satellite caches (the tuner's decision cache +
    default-DB binding)."""
    _program_cache.clear()
    _pattern_cache.clear()
    _bound_cache.clear()
    _transport_cache.clear()
    _assign_cache.clear()
    _envelope_cache.clear()
    plan_multiply.cache_clear()
    for fn in _extra_caches:
        fn()
    _stats.hits = _stats.misses = _stats.builds = _stats.evictions = 0
    _stats.pattern_hits = _stats.pattern_misses = 0
    _stats.chain_hits = _stats.chain_misses = 0
    _stats.tuner_hits = _stats.tuner_misses = _stats.tuner_trials = 0
    _stats.transport_hits = _stats.transport_misses = 0
    _stats.transport_dense = _stats.transport_compressed = 0
    _stats.assign_hits = _stats.assign_misses = 0
    _stats.envelope_hits = _stats.envelope_misses = 0
    _stats.dispatch_hits = _stats.dispatch_misses = 0
    _stats.drift_retunes = 0


# ---------------------------------------------------------------------------
# compacted product lists (DBCSR stack generation), pattern-signature cached
# ---------------------------------------------------------------------------


def get_product_stacks(pair_ok):
    """Compacted product list of a concrete (ni, nk, nj) filter cube.

    Returns ``(stacks, n_products)``: a ``kernels.stacks.ProductStacks``
    padded to the power-of-two capacity bucket of the surviving-product
    count, LRU-cached on the pattern signature.  A repeated sparsity
    pattern is a pure cache hit — no host walk, and (because the local
    program key depends only on shapes and the capacity bucket) no
    recompile either.
    """
    from repro.kernels.stacks import (
        bucket_capacity,
        compact_pair_mask,
        pattern_signature,
        product_count,
    )

    sig = pattern_signature(pair_ok)
    hit = _pattern_cache.get(sig)
    if hit is not None:
        _stats.pattern_hits += 1
        _pattern_cache.move_to_end(sig)
        return hit
    _stats.pattern_misses += 1
    n = product_count(pair_ok)
    stacks = compact_pair_mask(
        jnp.asarray(pair_ok), capacity=bucket_capacity(n)
    )
    entry = (stacks, n)
    _pattern_cache[sig] = entry
    if len(_pattern_cache) > _CACHE_MAXSIZE:
        _pattern_cache.popitem(last=False)
        _stats.evictions += 1
    return entry


def device_stack_bound(ok, mesh, engine: str) -> int:
    """Sound per-call product-count bound for the distributed engines.

    Every engine computes each surviving global triple exactly once, and a
    single ``local_filtered_mm`` call never sees more than one device's
    share: for the own-C-tile engines (cannon / onesided / gather) that
    share is the triples of the device's C panel; the twofive
    formulations compute partial panels for other owners, so the loose but
    sound total count is used.
    """
    if engine == "twofive":
        return int(ok.sum())
    p_r, p_c = mesh.shape["r"], mesh.shape["c"]
    nb_r, _, nb_c = ok.shape
    rr, cc = nb_r // p_r, nb_c // p_c
    best = 0
    for r in range(p_r):
        for c in range(p_c):
            cnt = int(ok[r * rr:(r + 1) * rr, :, c * cc:(c + 1) * cc].sum())
            best = max(best, cnt)
    return best


def get_device_capacity(ok, mesh, engine: str) -> int:
    """Bucketed distributed stack capacity, LRU-cached like the product
    lists: keyed on (pattern signature, partition class) so the hot-path
    multiply loop re-derives nothing for a repeated pattern."""
    from repro.kernels.stacks import bucket_capacity, pattern_signature

    key = (
        pattern_signature(ok), mesh.shape["r"], mesh.shape["c"],
        "twofive" if engine == "twofive" else "own-panel",
    )
    hit = _bound_cache.get(key)
    if hit is not None:
        _stats.pattern_hits += 1
        _bound_cache.move_to_end(key)
        return hit
    _stats.pattern_misses += 1
    cap = bucket_capacity(device_stack_bound(ok, mesh, engine))
    _bound_cache[key] = cap
    if len(_bound_cache) > _CACHE_MAXSIZE:
        _bound_cache.popitem(last=False)
        _stats.evictions += 1
    return cap


def get_transport(
    mask_a,
    mask_b,
    mesh,
    engine: str,
    l: int | None = None,
    mode: str = "auto",
):
    """Resolve the panel transport for one (pattern pair, mesh, engine).

    Derives the sound bucketed per-panel capacities from the concrete
    operand masks — the maximum occupied-block count over every A / B
    panel the plan's schedule ships (whole shards for ring / stacked /
    gather, virtual-grid subpanels for the pull formulation) — and
    applies the ``auto`` crossover (``transport.resolve_mode``).
    LRU-cached on the pattern signatures like the product lists, so a
    repeated pattern re-derives nothing; counted by the ``transport_*``
    fields of ``cache_stats()``.
    """
    import numpy as np

    from repro.core import transport as T
    from repro.kernels.stacks import pattern_signature

    am = np.asarray(mask_a, bool)
    bm = np.asarray(mask_b, bool)
    key = (
        "transport", pattern_signature(am), pattern_signature(bm),
        tuple((n, int(mesh.shape[n])) for n in mesh.axis_names),
        engine, l, mode,
    )
    hit = _transport_cache.get(key)
    if hit is not None:
        _stats.transport_hits += 1
        _transport_cache.move_to_end(key)
        return hit
    _stats.transport_misses += 1
    plan = plan_multiply(mesh, engine, l)
    cap_a, cap_b, blocks_a, blocks_b = T.capacities_for(am, bm, plan)
    resolved = T.resolve_mode(mode, cap_a, cap_b, blocks_a, blocks_b)
    if resolved == "compressed":
        tr = T.PanelTransport("compressed", cap_a, cap_b)
        _stats.transport_compressed += 1
    else:
        tr = T.DENSE
        _stats.transport_dense += 1
    _transport_cache[key] = tr
    if len(_transport_cache) > _CACHE_MAXSIZE:
        _transport_cache.popitem(last=False)
        _stats.evictions += 1
    return tr


def resolve_transport(spec, a, b, mesh, engine: str, l: int | None = None):
    """Normalize a transport spec to a concrete ``PanelTransport``.

    ``spec`` may be a ready ``PanelTransport`` (revalidated against this
    engine's panel partition — see below), a mode string (``"auto"`` /
    ``"dense"`` / ``"compressed"``), or ``None`` — the configured
    default (``config.transport_mode``, overridable via
    ``REPRO_TRANSPORT``).  Mode strings other than ``"dense"`` need
    concrete operand masks to derive capacities from; traced operands
    fall back to dense under ``auto`` (no pattern to pack against — the
    same degradation ``backend="auto"`` applies) and are an error under
    a forced ``"compressed"``.

    An explicit compressed ``PanelTransport`` is checked against the
    sound bounds of THIS (mesh, engine, pattern): capacities derived for
    one plan kind (e.g. pull subpanels) can under-cover another's panels
    (e.g. cannon's whole shards), and ``pack_panel`` truncates silently —
    under-capacity must be an error here, never a wrong C.  Traced
    operands skip the check (no pattern to validate against).
    """
    import jax

    from repro.core import transport as T

    traced = (
        isinstance(a.mask, jax.core.Tracer)
        or isinstance(b.mask, jax.core.Tracer)
    )
    if isinstance(spec, T.PanelTransport):
        if spec.compressed and not traced:
            # compare against the RAW per-panel bounds (not the bucketed
            # capacities get_transport hands out): any capacity covering
            # the true maximum occupied count is sound
            import numpy as np

            plan = plan_multiply(mesh, engine, l)
            (ar, ac), (br, bc) = T.plan_panel_parts(plan)
            need_a = T.panel_nnz_bound(np.asarray(a.mask, bool), ar, ac)
            need_b = T.panel_nnz_bound(np.asarray(b.mask, bool), br, bc)
            if spec.cap_a < need_a or spec.cap_b < need_b:
                raise ValueError(
                    f"transport capacities ({spec.cap_a}, {spec.cap_b}) "
                    f"under-cover the {engine!r} plan's panels "
                    f"(need >= ({need_a}, {need_b})): packing would "
                    "silently drop blocks"
                )
        return spec
    if spec is None:
        from repro.config import transport_mode

        mode = transport_mode()
    else:
        mode = spec
    if mode == "dense":
        return T.DENSE
    if mode not in ("auto", "compressed"):
        raise ValueError(
            f"unknown transport {mode!r}; a PanelTransport or one of "
            "auto | dense | compressed"
        )
    if traced:
        if mode == "compressed":
            raise ValueError(
                "transport='compressed' needs concrete operand patterns "
                "to derive sound panel capacities (operands are traced)"
            )
        return T.DENSE
    return get_transport(a.mask, b.mask, mesh, engine, l, mode)


def get_assignment(mask_a, mask_b, mesh, mode: str):
    """Resolve the block→device assignment of one (pattern pair, mesh,
    mode) — the distribution layer's analogue of :func:`get_transport`.

    Derives the deterministic permutation of ``core.distribute`` from the
    concrete operand masks (``assignment_for`` on the integer mask
    product), LRU-cached on the pattern signatures so a repeated pattern
    re-walks nothing; counted by the ``assign_*`` fields of
    ``cache_stats()``.
    """
    import numpy as np

    from repro.core import distribute as D
    from repro.kernels.stacks import pattern_signature

    am = np.asarray(mask_a, bool)
    bm = np.asarray(mask_b, bool)
    p_r, p_c = mesh.shape["r"], mesh.shape["c"]
    key = (
        "assign", pattern_signature(am), pattern_signature(bm),
        p_r, p_c, mode,
    )
    hit = _assign_cache.get(key)
    if hit is not None:
        _stats.assign_hits += 1
        _assign_cache.move_to_end(key)
        return hit
    _stats.assign_misses += 1
    asg = D.assignment_for(mode, D.product_counts(am, bm), (p_r, p_c))
    _assign_cache[key] = asg
    if len(_assign_cache) > _CACHE_MAXSIZE:
        _assign_cache.popitem(last=False)
        _stats.evictions += 1
    return asg


def resolve_assignment(spec, a, b, mesh):
    """Normalize an assignment spec to a ``distribute.Assignment`` or None
    (= identity layout).

    ``spec`` may be None / ``"identity"`` (no permutation), a mode string
    (``"randomized"`` / ``"nnz_greedy"`` — derived from the concrete
    operand masks via :func:`get_assignment`; traced operands are an
    error, exactly like a forced compressed transport), or a ready
    ``Assignment`` (validated against the operands' block grid; an
    explicitly-identity permutation collapses to None so cache keys stay
    in their pre-assignment shape).
    """
    if spec is None:
        return None
    from repro.core import distribute as D

    if isinstance(spec, str):
        if spec == "identity":
            return None
        if spec not in D.MODES:
            raise ValueError(
                f"unknown assignment {spec!r}; an Assignment or one of "
                f"{D.MODES}"
            )
        import jax

        if (isinstance(a.mask, jax.core.Tracer)
                or isinstance(b.mask, jax.core.Tracer)):
            raise ValueError(
                f"assignment={spec!r} needs concrete operand patterns to "
                "derive the permutation from (operands are traced); "
                "resolve the Assignment outside the trace"
            )
        asg = get_assignment(a.mask, b.mask, mesh, spec)
    elif isinstance(spec, D.Assignment):
        asg = spec
    else:
        raise TypeError(
            f"assignment must be None, a mode string {D.MODES}, or a "
            f"distribute.Assignment; got {type(spec).__name__}"
        )
    asg.validate(a.nb_r, a.nb_c)
    asg.validate(b.nb_r, b.nb_c)
    return None if asg.is_identity else asg


def get_envelope(
    mask,
    norms,
    *,
    sweeps: int,
    threshold: float = 0.0,
    filter_eps: float = 0.0,
    bs: int = 1,
    margin: float | None = None,
):
    """Forecast (or fetch) the pattern envelope of a purification chain.

    LRU-caches :func:`repro.core.envelope.forecast_chain` on the digest of
    the concrete entering pattern (mask bits + norm bytes) and the chain
    spec, so a serving loop that re-runs the same chain — or the warm
    sweeps of one iteration — pays the symbolic propagation exactly once.
    Counted by ``envelope_hits`` / ``envelope_misses`` in
    ``cache_stats()``.
    """
    import hashlib

    import numpy as np

    from repro.core import envelope as E

    if margin is None:
        margin = E.DEFAULT_MARGIN
    am = np.ascontiguousarray(np.asarray(mask, bool))
    an = np.ascontiguousarray(np.asarray(norms, np.float32))
    h = hashlib.sha1(np.packbits(am).tobytes())
    h.update(an.tobytes())
    key = (
        "envelope", h.digest(), am.shape, int(sweeps), float(threshold),
        float(filter_eps), int(bs), float(margin),
    )
    hit = _envelope_cache.get(key)
    if hit is not None:
        _stats.envelope_hits += 1
        _envelope_cache.move_to_end(key)
        return hit
    _stats.envelope_misses += 1
    env = E.forecast_chain(
        am, an, sweeps=sweeps, threshold=threshold, filter_eps=filter_eps,
        bs=bs, margin=margin,
    )
    _envelope_cache[key] = env
    if len(_envelope_cache) > _CACHE_MAXSIZE:
        _envelope_cache.popitem(last=False)
        _stats.evictions += 1
    return env


def note_drift_retune() -> None:
    """Count one drift-forced re-resolution (``drift_retunes``): a
    concrete pattern escaped its envelope, or a tuned decision stream's
    coarse feature bucket changed — either way the warm path was
    abandoned and capacities/modes were re-derived."""
    _stats.drift_retunes += 1


def note_dispatch_lookup(hit: bool) -> None:
    """Count one serving-dispatch bucket lookup (``dispatch_hits`` /
    ``dispatch_misses``): the pattern-bucketed serving cache
    (``core.envelope.DispatchCache``) resolved a per-batch dispatch mask
    against its warmed per-bucket envelopes — a hit means zero per-batch
    pattern walks (the warm serving path), a miss means a new bucket was
    warmed (once per request-mix regime, not per batch)."""
    if hit:
        _stats.dispatch_hits += 1
    else:
        _stats.dispatch_misses += 1


def get_local_compiled(
    ni: int,
    nk: int,
    nj: int,
    bs_r: int,
    bs_k: int,
    bs_c: int,
    dtype,
    *,
    backend: str,
    capacity: int,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
):
    """Jitted single-device compacted local-stage program, LRU-cached.

    The program maps ``(a_blocks, b_blocks, stacks) -> c_blocks`` where
    ``stacks`` is a padded product list of exactly ``capacity`` entries.
    The key carries no pattern data — only shapes, dtype, backend, the
    capacity bucket and (pallas) the MXU tile shape — so every pattern in
    a bucket shares one executable.
    """
    import jax

    if backend == "pallas" and interpret is None:
        # resolve before keying: the env/platform default must not get
        # baked into a None-keyed entry (REPRO_PALLAS_INTERPRET may change)
        from repro.kernels.ops import _default_interpret

        interpret = _default_interpret()
    key = (
        "local", ni, nk, nj, bs_r, bs_k, bs_c, jnp.dtype(dtype).name,
        backend, capacity, tile, interpret,
    )
    prog = _program_cache.get(key)
    if prog is not None:
        _stats.hits += 1
        _program_cache.move_to_end(key)
        return prog
    _stats.misses += 1
    _stats.builds += 1
    if backend == "stacks":
        from repro.core.local_mm import stacks_mm

        def fn(a_blocks, b_blocks, stacks):
            return stacks_mm(a_blocks, b_blocks, stacks, ni=ni, nj=nj)

    elif backend == "pallas":
        from repro.kernels.block_spgemm import block_spgemm_stacks

        interp = bool(interpret)

        def fn(a_blocks, b_blocks, stacks):
            return block_spgemm_stacks(
                a_blocks, b_blocks, stacks, ni=ni, nj=nj, tile=tile,
                interpret=interp,
            )

    else:
        raise ValueError(
            f"backend {backend!r} has no compacted local program"
        )
    prog = jax.jit(fn)
    _program_cache[key] = prog
    if len(_program_cache) > _CACHE_MAXSIZE:
        _program_cache.popitem(last=False)
        _stats.evictions += 1
    return prog


def build_program(plan: MultiplyPlan, *, threshold: float, backend: str,
                  c_layout: str, stack_capacity: int | None = None,
                  tile: tuple[int, int, int] | None = None,
                  interpret: bool | None = None, transport=None):
    """Construct (untraced) the shard_map executor for a plan."""
    if c_layout != "2d" and plan.kind != "stacked":
        raise ValueError(
            f"c_layout={c_layout!r} needs the stacked (l, r, c) mesh; "
            f"the {plan.kind!r} plan keeps C in the 2D (r, c) layout"
        )
    from repro.core import transport as T

    _stats.builds += 1
    kw = dict(
        threshold=threshold, backend=backend,
        stack_capacity=stack_capacity, tile=tile, interpret=interpret,
        transport=transport if transport is not None else T.DENSE,
    )
    if plan.kind == "ring":
        from repro.core.cannon import ring_executor

        return ring_executor(plan, **kw)
    if plan.kind == "pull":
        from repro.core.twofive import pull_executor

        return pull_executor(plan, **kw)
    if plan.kind == "stacked":
        from repro.core.twofive import stacked_executor

        return stacked_executor(plan, c_layout=c_layout, **kw)
    if plan.kind == "gather":
        from repro.core.gather import gather_executor

        return gather_executor(plan, **kw)
    raise ValueError(plan.kind)


def build_shard_body(plan: MultiplyPlan, *, threshold: float, backend: str,
                     stack_capacity: int | None = None,
                     tile: tuple[int, int, int] | None = None,
                     interpret: bool | None = None, transport=None):
    """The engine's raw per-shard body: ``(ab, am, an, bb, bm, bn) ->
    (cb, cm)`` on shards, no shard_map wrapper.

    Iteration chains (``core/signiter.py``) inline this into ONE enclosing
    shard_map spanning a whole sweep — multiple multiplies plus the
    inter-multiply algebra run per-shard with no re-partitioning between
    them, which is what makes the fused chain step a single cheap
    dispatch.  C always comes home in the 2D (r, c) layout (the stacked
    plan uses its c_layout="2d" psum), so chained calls compose.

    ``transport`` defaults to dense: chains are traced once while the
    sparsity pattern evolves underneath them, so a static compressed
    capacity from the initial pattern would be unsound — the same reason
    chains pin the dense local backend (``tuner.model.chain_safe``).
    """
    from repro.core import transport as T

    _stats.builds += 1
    kw = dict(
        threshold=threshold, backend=backend,
        stack_capacity=stack_capacity, tile=tile, interpret=interpret,
        transport=transport if transport is not None else T.DENSE,
    )
    if plan.kind == "ring":
        from repro.core.cannon import ring_body

        return ring_body(plan, **kw)
    if plan.kind == "pull":
        from repro.core.twofive import pull_body

        return pull_body(plan, **kw)
    if plan.kind == "stacked":
        from repro.core.twofive import stacked_body

        return stacked_body(plan, c_layout="2d", **kw)
    if plan.kind == "gather":
        from repro.core.gather import gather_body

        return gather_body(plan, **kw)
    raise ValueError(plan.kind)


def get_compiled(
    mesh,
    engine: str,
    nb_r: int,
    bs: int,
    dtype,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    c_layout: str = "2d",
    l: int | None = None,
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    transport=None,
    assignment=None,
    nb_k: int | None = None,
    nb_c: int | None = None,
    bs_k: int | None = None,
    bs_c: int | None = None,
):
    """Jitted multiply program for the key, LRU-cached.

    Repeated multiplies with the same key return the *same* jitted callable,
    so jax's compilation cache is hit and no retracing/relowering happens —
    the per-call dispatch cost collapses to argument handling.

    ``transport`` must already be concrete here (a ``PanelTransport`` or
    None = dense): mode and capacities are part of the key, so callers
    resolve patterns *before* keying (``execute`` / ``execute_sharded``
    via :func:`resolve_transport`) — an auto decision must never get
    baked into a None-keyed entry.

    ``assignment`` likewise must be concrete (a ``distribute.Assignment``
    or None = identity; :func:`resolve_assignment` normalizes specs).
    Non-identity assignments wrap the program with the symmetric
    permute-in / unpermute-out reindex — callers hand UNPERMUTED triples
    and get the result back in original block coordinates; the engine
    body in between only ever sees the permuted layout.  The assignment
    signature joins the key only when non-identity, so pre-assignment
    keys (and any state keyed on them) are unchanged.  Capacities in the
    key (``stack_capacity``, ``transport``) must have been derived from
    the PERMUTED pattern — a permutation changes which products land on
    which device, and an identity-layout bound can under-cover a hot
    permuted panel.

    ``nb_k`` / ``nb_c`` / ``bs_k`` / ``bs_c`` describe a rectangular
    product (A ``nb_r x nb_k`` of ``bs x bs_k`` blocks, B ``nb_k x nb_c``
    of ``bs_k x bs_c``).  Left at None they default to the square contract
    every pre-tensor caller used — the key is unchanged for those callers.
    When any is set, the full shape joins the key and the k dimension is
    validated against the plan (the engine bodies themselves are
    shape-polymorphic: one cache entry per full shape, jit retraces per
    input shape anyway).  Non-identity assignments are square-only — the
    symmetric block permutation has no meaning on a rectangular grid — so
    a rectangular shape plus an assignment is rejected here, loudly.
    """
    import jax

    from repro.core import transport as T

    if backend == "pallas" and interpret is None:
        # resolve before keying (as in get_local_compiled): the
        # env/platform default must not get baked into a None-keyed entry
        from repro.kernels.ops import _default_interpret

        interpret = _default_interpret()
    if transport is None:
        transport = T.DENSE
    elif not isinstance(transport, T.PanelTransport):
        raise TypeError(
            "get_compiled takes a resolved PanelTransport (or None = "
            f"dense), got {transport!r}; resolve mode strings with "
            "plan.resolve_transport first"
        )
    if assignment is not None and assignment.is_identity:
        assignment = None
    rect = (nb_k, nb_c, bs_k, bs_c) != (None, None, None, None)
    if rect and assignment is not None:
        raise ValueError(
            "block->device assignments permute rows and columns "
            "symmetrically; a rectangular product "
            f"({nb_r}x{nb_k or nb_r} @ {nb_k or nb_r}x{nb_c or nb_r}) "
            "has no symmetric layout — use assignment=None/'identity'"
        )
    key = (
        mesh, engine, nb_r, bs, jnp.dtype(dtype).name,
        float(threshold), backend, c_layout, l, stack_capacity, tile,
        interpret, transport.key,
    )
    if rect:
        key = key + (("rect", nb_k, nb_c, bs_k, bs_c),)
    if assignment is not None:
        key = key + (("assign",) + assignment.key,)
    prog = _program_cache.get(key)
    if prog is not None:
        _stats.hits += 1
        _program_cache.move_to_end(key)
        return prog
    _stats.misses += 1
    plan = plan_multiply(mesh, engine, l)
    if rect:
        plan.validate_blocks(
            nb_r, nb_r if nb_c is None else nb_c,
            nb_r if nb_k is None else nb_k,
        )
    else:
        plan.validate_blocks(nb_r, nb_r)
    fn = build_program(
        plan, threshold=threshold, backend=backend, c_layout=c_layout,
        stack_capacity=stack_capacity, tile=tile, interpret=interpret,
        transport=transport,
    )
    if assignment is not None:
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        inner = fn
        perm = jnp.asarray(assignment.perm)
        inv = jnp.asarray(assignment.inv)
        # The reindex gathers live OUTSIDE the engine's shard_map; pin
        # them replicated so the SPMD partitioner never tries to push the
        # engine's (r, c) home-layout shardings backwards through a
        # cross-shard gather (it cannot, and fails at HLO verification).
        # The replicated path hands replicated triples in anyway, and its
        # result is consumed replicated — the constraints cost nothing
        # beyond what the layout-oblivious caller already pays.
        rep = None if mesh is None else NamedSharding(mesh, P())

        def fn(ab, am, an, bb, bm, bn):
            def to(x):
                y = x[perm][:, perm]
                return y if rep is None else jax.lax.with_sharding_constraint(y, rep)

            cb, cm = inner(to(ab), to(am), to(an), to(bb), to(bm), to(bn))
            if rep is not None:
                cb = jax.lax.with_sharding_constraint(cb, rep)
                cm = jax.lax.with_sharding_constraint(cm, rep)
            return cb[inv][:, inv], cm[inv][:, inv]

    prog = jax.jit(fn)
    _program_cache[key] = prog
    if len(_program_cache) > _CACHE_MAXSIZE:
        _program_cache.popitem(last=False)
        _stats.evictions += 1
    return prog


def _rect_dims(a, b) -> dict:
    """Full-shape kwargs for :func:`get_compiled` from an operand pair.

    Square pairs (the entire pre-tensor surface) return ``{}`` so their
    program-cache keys are byte-identical to before; rectangular pairs —
    matricized tensor operands — return the four extra dims.  Incompatible
    inner shapes fail here, before any program is keyed.
    """
    if a.nb_c != b.nb_r or a.bs_c != b.bs_r:
        raise ValueError(
            f"operand shapes do not contract: A is {a.nb_r}x{a.nb_c} "
            f"blocks of {a.bs_r}x{a.bs_c}, B is {b.nb_r}x{b.nb_c} "
            f"blocks of {b.bs_r}x{b.bs_c}"
        )
    if (a.nb_c, b.nb_c, a.bs_c, b.bs_c) == (a.nb_r, a.nb_r, a.bs_r, a.bs_r):
        return {}
    return dict(nb_k=a.nb_c, nb_c=b.nb_c, bs_k=a.bs_c, bs_c=b.bs_c)


def _permuted_mask_views(a, b, asg):
    """Lightweight stand-ins carrying the PERMUTED operand masks, for
    deriving transport capacities in the layout the engine will run in.
    Traced masks pass through unpermuted — every consumer falls back to
    pattern-free behavior on tracers anyway."""
    import types

    import jax
    import numpy as np

    if (isinstance(a.mask, jax.core.Tracer)
            or isinstance(b.mask, jax.core.Tracer)):
        return a, b
    p = np.asarray(asg.perm)
    return (
        types.SimpleNamespace(mask=np.asarray(a.mask, bool)[p][:, p]),
        types.SimpleNamespace(mask=np.asarray(b.mask, bool)[p][:, p]),
    )


def execute(a, b, mesh, engine: str, **kw):
    """Run one cached multiply and rebuild the BlockSparseMatrix result.

    The shared execution path behind ``engine.multiply`` and the per-engine
    back-compat wrappers (``multiply_2d``/``multiply_gather``/
    ``multiply_25d``); keyword args are those of :func:`get_compiled`.

    ``assignment`` (None / mode string / ``distribute.Assignment``)
    selects the block→device distribution the multiply runs under; the
    permute/unpermute pair lives inside the compiled program, so the
    caller's matrices stay in original block coordinates throughout.
    Transport capacities are derived from the permuted masks — the
    pattern the engine actually ships.
    """
    from repro.core.bsm import BlockSparseMatrix, block_norms

    if engine == "auto":
        from repro.tuner import resolve_multiply

        engine, kw = resolve_multiply(a, b, mesh, kw)
    asg = resolve_assignment(kw.pop("assignment", None), a, b, mesh)
    ta, tb = (a, b) if asg is None else _permuted_mask_views(a, b, asg)
    kw["transport"] = resolve_transport(
        kw.get("transport"), ta, tb, mesh, engine, kw.get("l")
    )
    kw.update(_rect_dims(a, b))
    fn = get_compiled(mesh, engine, a.nb_r, a.bs_r, a.dtype,
                      assignment=asg, **kw)
    cb, cm = fn(a.blocks, a.mask, a.norms, b.blocks, b.mask, b.norms)
    return BlockSparseMatrix(blocks=cb, mask=cm, norms=block_norms(cb))


def execute_sharded(a, b, engine: str, **kw):
    """Sharded multiply: ShardedBSM in, ShardedBSM out, no gather.

    The shard_map engine bodies already operate on shards; this path hands
    them operands that are *born* in the specs they declare, so XLA inserts
    no resharding, and the result triple stays in the 2D home layout.
    Keyword args are those of :func:`get_compiled` (``c_layout`` is pinned
    to ``"2d"`` — a chain's C must come home to the same layout its next
    multiply consumes).

    Sharded operands already LIVE in their assignment's permuted home
    layout (``shard_bsm`` applied it before the scatter), so the engine
    runs as-is — their permuted masks are the pattern every capacity is
    derived from, and the result inherits the layout.  An ``assignment``
    kwarg here can only confirm the carried layout; redistributing a
    sharded matrix means unsharding first.
    """
    from repro.core.bsm import ShardedBSM, _assign_name, block_norms

    mesh = a.mesh
    if kw.pop("c_layout", "2d") != "2d":
        raise ValueError("sharded chains require c_layout='2d'")
    asg = a._join_assignment(b)
    spec = kw.pop("assignment", None)
    if spec is not None:
        want = getattr(spec, "mode", spec)
        if want != _assign_name(asg):
            raise ValueError(
                f"operands are sharded under assignment "
                f"{_assign_name(asg)}; cannot execute under {want!r} — "
                "unshard and redistribute instead"
            )
    if engine == "auto":
        # one host walk of the (concrete, device-resident) pattern; the
        # tuner's decision cache makes repeats free for a stable pattern.
        # The assignment is pinned to identity: the layout decision was
        # made at shard_bsm time and the pattern the tuner sees is
        # already the permuted one.
        from repro.tuner import resolve_multiply

        kw["assignment"] = "identity"
        engine, kw = resolve_multiply(a, b, mesh, kw)
        kw.pop("assignment", None)
    # transport resolution under the default "auto" costs one host pull
    # + digest of the 2D masks PER CALL (the signature hash, not the
    # cache lookup, is the cost — it must sync the device-resident
    # mask).  Latency-critical async loops that cannot afford the sync
    # pin the mode (transport="dense" / REPRO_TRANSPORT=dense skips the
    # walk entirely); fused chains (signiter) never reach here.
    kw["transport"] = resolve_transport(
        kw.get("transport"), a, b, mesh, engine, kw.get("l")
    )
    kw.update(_rect_dims(a, b))
    fn = get_compiled(mesh, engine, a.nb_r, a.bs_r, a.dtype,
                      c_layout="2d", **kw)
    cb, cm = fn(a.blocks, a.mask, a.norms, b.blocks, b.mask, b.norms)
    return ShardedBSM(blocks=cb, mask=cm, norms=block_norms(cb), mesh=mesh,
                      assignment=asg)


def get_chain_compiled(key: tuple, builder):
    """Fused chain-step program (a whole sign-iteration sweep — or any
    multi-multiply algebra chain), LRU-cached like the multiply programs
    but counted separately (``chain_hits`` / ``chain_misses``): the
    per-chain counters tell a benchmark how many sweeps of an iteration
    reused one compiled step.

    ``builder`` constructs the jitted program on a miss; program builds it
    performs (``build_program`` / ``get_local_compiled``) are counted by
    the ordinary ``builds`` counter, so "at most one program per distinct
    multiply shape across a 10-sweep iteration" is assertable from
    ``cache_stats()`` alone.

    Chains with ``engine="auto"`` resolve the engine through the tuner
    *before* keying (``signiter.sign_iteration``): the chain key always
    carries a concrete engine, and the tuner's decision join the same
    ``cache_stats()`` counters (``tuner_hits`` / ``tuner_misses`` /
    ``tuner_trials``).
    """
    key = ("chain",) + tuple(key)
    prog = _program_cache.get(key)
    if prog is not None:
        _stats.chain_hits += 1
        _program_cache.move_to_end(key)
        return prog
    _stats.chain_misses += 1
    prog = builder()
    _program_cache[key] = prog
    if len(_program_cache) > _CACHE_MAXSIZE:
        _program_cache.popitem(last=False)
        _stats.evictions += 1
    return prog
