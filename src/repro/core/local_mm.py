"""Local (per-device) filtered block multiplication.

This is DBCSR's "batched small-block GEMM with on-the-fly filtering" stage
(handled by LIBXSMM / GPU kernels in the paper).  Three implementations:

* ``jnp`` — a masked einsum oracle.  The (i,k,j) product is included only if
  both blocks are occupied AND ``norm(A_ik)*norm(B_kj) > threshold`` — the
  paper's on-the-fly filter.  Runs everywhere; FLOPs are *not* skipped (the
  einsum contracts the full cube) but the semantics are exact.  Right for
  high fill, where dense MXU work beats gather/scatter overhead.
* ``stacks`` — DBCSR's stack design (DESIGN.md §2): compact the filter cube
  into a padded product list (``kernels/stacks.py``), gather the surviving
  A/B blocks, run ONE batched ``dot_general`` over the list, segment-sum
  into C tiles.  FLOPs and memory traffic scale with the survivors:
  ``2 * capacity * bs_r * bs_k * bs_c`` instead of the
  ``ni * nk * nj``-cube.
* ``pallas`` — the scalar-prefetch TPU kernel
  (``repro.kernels.block_spgemm``): the grid iterates the same compacted
  list, one product per step, f32 VMEM accumulation per output-tile k-run.

``stack_capacity`` bounds the surviving products for the compacted
backends (static; None = full cube, always sound).  Callers with concrete
sparsity get exact bucketed capacities from the plan layer
(``plan.get_product_stacks`` / ``engine.multiply``); traced callers
(shard_map engine bodies) pass a host-derived upper bound.

Blocks may be rectangular: a_blocks (ni, nk, bs_r, bs_k) times b_blocks
(nk, nj, bs_k, bs_c) gives c_blocks (ni, nj, bs_r, bs_c).

All backends return (c_blocks, c_mask); norms of C are recomputed by the
caller (after the cross-device reduction, where applicable).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.block_spgemm import (
    VMEM_BUDGET_BYTES,
    tile_working_set_bytes,
)
from repro.kernels.stacks import (
    ProductStacks,
    compact_pair_mask,
    resolve_capacity,
)

BACKENDS = ("jnp", "stacks", "pallas")

# Effective-FLOP penalty of the compacted backends' gather/scatter stage
# relative to the dense einsum's streaming MXU access: the dense/compacted
# crossover sits where fill * GATHER_OVERHEAD == 1 (0.25 — DBCSR's batched
# GEMM wins at low occupancy, dense MXU work wins when the cube is mostly
# full; calibrated against benchmarks/bench_local_mm.py's sweep).
GATHER_OVERHEAD = 4.0

# MXU throughput multiplier per storage itemsize (f32 baseline; bf16
# doubles, 8-bit quadruples on hardware that packs the systolic array).
_MXU_DTYPE_SPEEDUP = {4: 1.0, 2: 2.0, 1: 4.0}

# FLOP-equivalents of one HBM byte (PEAK_FLOPS / HBM_BW for TPU v5e-class
# parts, 197e12 / 819e9 — kept inline to avoid a roofline import cycle).
_FLOPS_PER_BYTE = 240.0


@dataclass(frozen=True)
class LocalCost:
    """Cost breakdown of one local-stage call.

    ``flops`` are *logical* MACs-times-two — the number XLA's
    ``cost_analysis`` reports for the compiled program (asserted in
    ``tests/test_roofline.py``) — independent of storage dtype since the
    MXU accumulates in f32 either way.  ``hbm_bytes`` is operand/output
    traffic at the *storage width* (bf16 halves it), including the
    re-streaming a pallas tile grid adds.  ``effective`` is the
    FLOP-equivalent ranking cost (dtype throughput, gather overhead, VMEM
    pressure) the tuner and ``engine.choose_backend`` compare;
    ``feasible`` is False when the tile working set cannot fit VMEM at
    all (``effective`` is inf there).
    """

    flops: float
    hbm_bytes: float
    effective: float
    feasible: bool = True


def local_stage_cost(
    ni: int,
    nk: int,
    nj: int,
    bs_r: int,
    bs_k: int,
    bs_c: int,
    *,
    fill: float,
    backend: str,
    dtype=jnp.float32,
    tile: tuple[int, int, int] | None = None,
    capacity: int | None = None,
) -> LocalCost:
    """Dtype- and tile-aware analytic cost of one local-stage call.

    ``jnp`` always pays the dense cube (the einsum contracts everything,
    amortizing MXU padding over the full grid dims); the compacted
    backends pay the surviving products (``capacity`` when the caller has
    the exact bucketed count, else ``fill`` times the cube) times the
    gather/scatter overhead.  A pallas ``tile`` adds its re-streaming
    traffic (A tiles fetched once per output-column tile, B once per
    output-row tile) and the VMEM-pressure terms: past half the budget
    the operand pipeline loses double buffering (DMA serializes with the
    MXU — traffic joins the critical path), past the full budget the
    kernel cannot run at all.  Shared by ``engine.choose_backend`` and
    the tuner's candidate model (``repro.tuner.model``) so the
    single-device heuristic and the distributed autotuner agree —
    including for rectangular atomic blocks and reduced storage dtypes.
    """
    itemsize = float(jnp.dtype(dtype).itemsize)
    speed = _MXU_DTYPE_SPEEDUP.get(int(itemsize), 1.0)
    cube = float(ni) * nk * nj
    block = float(bs_r) * bs_k * bs_c
    dense_flops = 2.0 * cube * block
    if backend == "jnp":
        hbm = (ni * nk * bs_r * bs_k + nk * nj * bs_k * bs_c
               + ni * nj * bs_r * bs_c) * itemsize
        return LocalCost(dense_flops, hbm, dense_flops / speed)
    if backend not in ("stacks", "pallas"):
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    cap = float(capacity) if capacity is not None else fill * cube
    flops = 2.0 * cap * block
    compute = GATHER_OVERHEAD * fill * dense_flops / speed
    per_product = (bs_r * bs_k + bs_k * bs_c + bs_r * bs_c) * itemsize
    if backend == "stacks":
        return LocalCost(flops, cap * per_product, compute)
    tm, tk, tn = tile or (bs_r, bs_k, bs_c)
    n_tm, n_tn = -(-bs_r // tm), -(-bs_c // tn)
    hbm = cap * (n_tn * bs_r * bs_k + n_tm * bs_k * bs_c
                 + bs_r * bs_c) * itemsize
    extra = cap * ((n_tn - 1) * bs_r * bs_k
                   + (n_tm - 1) * bs_k * bs_c) * itemsize
    ws = tile_working_set_bytes(bs_r, bs_k, bs_c, (tm, tk, tn), dtype)
    if ws > VMEM_BUDGET_BYTES:
        return LocalCost(flops, hbm, float("inf"), feasible=False)
    if ws > VMEM_BUDGET_BYTES / 2:
        # double buffering lost: the full traffic joins the critical path
        return LocalCost(flops, hbm, compute + hbm * _FLOPS_PER_BYTE)
    return LocalCost(flops, hbm, compute + extra * _FLOPS_PER_BYTE)


def backend_local_cost(
    ni: int,
    nk: int,
    nj: int,
    bs_r: int,
    bs_k: int,
    bs_c: int,
    *,
    fill: float,
    backend: str,
    dtype=jnp.float32,
    tile: tuple[int, int, int] | None = None,
) -> float:
    """Effective-FLOP ranking cost (``local_stage_cost(...).effective``)."""
    return local_stage_cost(
        ni, nk, nj, bs_r, bs_k, bs_c, fill=fill, backend=backend,
        dtype=dtype, tile=tile,
    ).effective


def pair_filter(
    a_mask: jax.Array,
    a_norms: jax.Array,
    b_mask: jax.Array,
    b_norms: jax.Array,
    threshold: float,
) -> jax.Array:
    """On-the-fly filter mask over (i, k, j) block-product triples."""
    ok = a_mask[:, :, None] & b_mask[None, :, :]
    if threshold > 0.0:
        ok = ok & (a_norms[:, :, None] * b_norms[None, :, :] > threshold)
    return ok


def stacks_mm(
    a_blocks: jax.Array,
    b_blocks: jax.Array,
    stacks: ProductStacks,
    *,
    ni: int,
    nj: int,
    precision=jax.lax.Precision.HIGHEST,
) -> jax.Array:
    """Gather -> batched GEMM -> scatter over a compacted product list.

    The whole local stage is one (capacity, bs_r, bs_k) x (capacity, bs_k,
    bs_c) batched ``dot_general`` (f32 accumulation, as the MXU does) plus
    an unsorted segment-sum over output tiles; padding products are zeroed
    by the ``valid`` weights before the scatter.
    """
    bs_r, bs_c = a_blocks.shape[2], b_blocks.shape[3]
    dtype = a_blocks.dtype
    if stacks.capacity == 0:
        return jnp.zeros((ni, nj, bs_r, bs_c), dtype)
    ag = a_blocks[stacks.ia, stacks.ik].astype(jnp.float32)
    bg = b_blocks[stacks.ik, stacks.ij].astype(jnp.float32)
    prod = jax.lax.dot_general(
        ag, bg, (((2,), (1,)), ((0,), (0,))), precision=precision
    )
    prod = prod * stacks.valid.astype(jnp.float32)[:, None, None]
    seg = jnp.where(stacks.valid == 1, stacks.tile, ni * nj)
    c = jax.ops.segment_sum(prod, seg, num_segments=ni * nj + 1)
    return c[: ni * nj].reshape(ni, nj, bs_r, bs_c).astype(dtype)


def local_filtered_mm(
    a_blocks: jax.Array,
    a_mask: jax.Array,
    a_norms: jax.Array,
    b_blocks: jax.Array,
    b_mask: jax.Array,
    b_norms: jax.Array,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    precision=jax.lax.Precision.HIGHEST,
) -> tuple[jax.Array, jax.Array]:
    """C_ij += sum_k A_ik B_kj with on-the-fly norm filtering.

    Shapes: a_blocks (ni, nk, bs_r, bs_k), b_blocks (nk, nj, bs_k, bs_c)
    Returns: c_blocks (ni, nj, bs_r, bs_c), c_mask (ni, nj) bool.

    Every backend accumulates in f32 regardless of the storage dtype (the
    MXU semantics), so bf16/f8 operands lose precision only at block
    storage, never across the k-contraction.  ``tile`` selects the pallas
    kernel's MXU sub-tile shape (ignored elsewhere).  ``interpret``
    controls the pallas backend only: None auto-detects the platform
    (compiled Mosaic on TPU, interpreter elsewhere — see
    ``repro.config.pallas_interpret``).
    """
    ni, nk = a_blocks.shape[:2]
    nj = b_blocks.shape[1]
    ok = pair_filter(a_mask, a_norms, b_mask, b_norms, threshold)
    if backend == "pallas":
        from repro.kernels import ops as kops

        c_blocks = kops.block_spgemm(
            a_blocks, b_blocks, ok, capacity=stack_capacity, tile=tile,
            interpret=interpret,
        )
    elif backend == "stacks":
        cap = resolve_capacity(stack_capacity, ni * nk * nj)
        stacks = compact_pair_mask(ok, capacity=cap)
        c_blocks = stacks_mm(
            a_blocks, b_blocks, stacks, ni=ni, nj=nj, precision=precision
        )
    elif backend == "jnp":
        okf = ok.astype(jnp.float32)
        c_blocks = jnp.einsum(
            "ikj,ikab,kjbc->ijac",
            okf,
            a_blocks.astype(jnp.float32),
            b_blocks.astype(jnp.float32),
            precision=precision,
        ).astype(a_blocks.dtype)
    else:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    c_mask = jnp.any(ok, axis=1)
    return c_blocks, c_mask
