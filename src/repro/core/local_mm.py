"""Local (per-device) filtered block multiplication.

This is DBCSR's "batched small-block GEMM with on-the-fly filtering" stage
(handled by LIBXSMM / GPU kernels in the paper).  Two implementations:

* ``jnp`` — a masked einsum oracle.  The (i,k,j) product is included only if
  both blocks are occupied AND ``norm(A_ik)*norm(B_kj) > threshold`` — the
  paper's on-the-fly filter.  Runs everywhere; FLOPs are not actually skipped
  (XLA static shapes) but the *semantics* are exact.
* ``pallas`` — the TPU kernel in ``repro.kernels.block_spgemm``: MXU-aligned
  tiles, `@pl.when` predication genuinely skips filtered tiles on hardware.

Both return (c_blocks, c_mask); norms of C are recomputed by the caller
(after the cross-device reduction, where applicable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pair_filter(
    a_mask: jax.Array,
    a_norms: jax.Array,
    b_mask: jax.Array,
    b_norms: jax.Array,
    threshold: float,
) -> jax.Array:
    """On-the-fly filter mask over (i, k, j) block-product triples."""
    ok = a_mask[:, :, None] & b_mask[None, :, :]
    if threshold > 0.0:
        ok = ok & (a_norms[:, :, None] * b_norms[None, :, :] > threshold)
    return ok


def local_filtered_mm(
    a_blocks: jax.Array,
    a_mask: jax.Array,
    a_norms: jax.Array,
    b_blocks: jax.Array,
    b_mask: jax.Array,
    b_norms: jax.Array,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    precision=jax.lax.Precision.HIGHEST,
) -> tuple[jax.Array, jax.Array]:
    """C_ij += sum_k A_ik B_kj with on-the-fly norm filtering.

    Shapes: a_blocks (ni, nk, bs, bs), b_blocks (nk, nj, bs, bs)
    Returns: c_blocks (ni, nj, bs, bs), c_mask (ni, nj) bool.
    """
    ok = pair_filter(a_mask, a_norms, b_mask, b_norms, threshold)
    if backend == "pallas":
        from repro.kernels import ops as kops

        c_blocks = kops.block_spgemm(
            a_blocks, b_blocks, ok, interpret=True
        )
    elif backend == "jnp":
        okf = ok.astype(a_blocks.dtype)
        c_blocks = jnp.einsum(
            "ikj,ikab,kjbc->ijac",
            okf,
            a_blocks,
            b_blocks,
            precision=precision,
        )
    else:
        raise ValueError(f"unknown backend {backend!r}")
    c_mask = jnp.any(ok, axis=1)
    return c_blocks, c_mask
