"""Blocked sparse tensors and matricized einsum contraction (DESIGN.md §10).

DBCSR was generalized from matrices to blocked sparse *tensors* (Sivkov,
Seewald & Hutter 2019) for the low-scaling correlated methods (RPA/MP2)
whose working data are 3-index three-center integral tensors, and their
implementation strategy is the one reproduced here: a tensor contraction
is **matricized** — the tensor's indices are split into a (row group,
col group), the block grid is flattened onto an ordinary block-sparse
matrix, and the contraction runs as a plain distributed SpGEMM on the
existing engine stack.  Nothing below the matricization layer changes:
plan compilation, compacted stacks, compressed transport, tile
autotuning and ``engine="auto"`` all apply verbatim, because a
matricized tensor *is* a :class:`~repro.core.bsm.BlockSparseMatrix`
(typically tall-skinny — the workload that exercises the rectangular
block-grid plumbing of the plan layer hardest).

Containers:

* :class:`BlockSparseTensor` — the N-index analogue of the BSM triple:
  dense block grid + boolean occupation mask + per-block Frobenius
  norms::

      blocks : (nb_1, ..., nb_N, bs_1, ..., bs_N)
      mask   : (nb_1, ..., nb_N) bool
      norms  : (nb_1, ..., nb_N) float32

* :class:`MatricizedTensor` — a tensor living in matrix form (replicated
  ``BlockSparseMatrix`` or device-resident ``ShardedBSM``) together with
  the index map needed to undo the flattening.  Chained contractions
  whose splits line up stay device-resident end to end, like the
  purification chains of DESIGN.md §5.

Index map: ``matricize(t, row_axes, col_axes)`` flattens the block
coordinates *block-major* — matricized block (R, C) with
``R = ravel(i[row_axes])`` and ``C = ravel(i[col_axes])`` is exactly
tensor block ``i`` with its intra-block dims transposed to (row dims,
col dims) order and reshaped 2D.  One tensor block maps to one matrix
block, so mask and norms transfer by pure transpose + reshape (bit-exact
— a Frobenius norm does not care how the block is unrolled) and
``unmatricize`` inverts losslessly.

``contract("ijk,kl->ijl", t1, t2, mesh=...)`` picks the matricization
that aligns every contracted index on the shared k dimension (A rows =
A's free indices, A cols = the contracted group in A's spec order; B
transposed accordingly), multiplies through ``engine.multiply``, and
un-matricizes the product.  Indices repeated within one operand
(traces) and indices shared by inputs *and* output (batch/Hadamard
dims) are outside the matricized-SpGEMM model and rejected loudly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsm import (
    BlockSparseMatrix,
    ShardedBSM,
    shard_bsm,
)
from repro.pytree import pytree_dataclass

__all__ = [
    "BlockSparseTensor",
    "MatricizedTensor",
    "contract",
    "from_dense_tensor",
    "make_tensor",
    "matricize",
    "random_tensor",
    "shard_tensor",
    "tensor_block_norms",
    "unmatricize",
]


@pytree_dataclass
class BlockSparseTensor:
    """An N-index blocked sparse tensor: dense block grid + mask + norms."""

    blocks: jax.Array  # (nb_1..nb_N, bs_1..bs_N)
    mask: jax.Array  # (nb_1..nb_N) bool
    norms: jax.Array  # (nb_1..nb_N) float32

    # ---- shape helpers -------------------------------------------------
    @property
    def ndim(self) -> int:
        return self.mask.ndim

    @property
    def nbs(self) -> tuple[int, ...]:
        return tuple(self.blocks.shape[: self.ndim])

    @property
    def bss(self) -> tuple[int, ...]:
        return tuple(self.blocks.shape[self.ndim:])

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(nb * bs for nb, bs in zip(self.nbs, self.bss))

    @property
    def dtype(self):
        return self.blocks.dtype

    # ---- stats ---------------------------------------------------------
    def nnz_blocks(self) -> jax.Array:
        return jnp.sum(self.mask)

    def occupancy(self) -> jax.Array:
        return jnp.mean(self.mask.astype(jnp.float32))

    def frobenius_norm(self) -> jax.Array:
        return jnp.sqrt(jnp.sum(jnp.square(self.norms)))

    # ---- conversions ---------------------------------------------------
    def to_dense(self) -> jax.Array:
        n = self.ndim
        m = self.mask
        masked = self.blocks * m.reshape(m.shape + (1,) * n).astype(self.dtype)
        # interleave (grid_i, block_i) pairs, then merge each pair
        perm = tuple(x for i in range(n) for x in (i, n + i))
        return masked.transpose(perm).reshape(self.shape)


def tensor_block_norms(blocks: jax.Array, ndim: int) -> jax.Array:
    """Frobenius norm of every block of an ``ndim``-index blocked tensor,
    computed in f32 (the N-axis analogue of ``bsm.block_norms``)."""
    b32 = blocks.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(b32 * b32, axis=tuple(range(ndim, 2 * ndim))))


def make_tensor(blocks: jax.Array, mask: jax.Array) -> BlockSparseTensor:
    """Build a tensor from raw blocks + mask, zeroing masked-out data and
    recomputing norms (the ``make_bsm`` consistency contract)."""
    n = mask.ndim
    if blocks.ndim != 2 * n:
        raise ValueError(
            f"blocks must have 2x the mask's rank (grid dims + block "
            f"dims); got blocks rank {blocks.ndim} for mask rank {n}"
        )
    m = mask.astype(bool)
    blocks = blocks * m.reshape(m.shape + (1,) * n).astype(blocks.dtype)
    return BlockSparseTensor(
        blocks=blocks, mask=m, norms=tensor_block_norms(blocks, n)
    )


def _block_sizes(bss, ndim: int) -> tuple[int, ...]:
    if isinstance(bss, (tuple, list)):
        if len(bss) != ndim:
            raise ValueError(f"need {ndim} block sizes, got {bss!r}")
        return tuple(int(b) for b in bss)
    return (int(bss),) * ndim


def from_dense_tensor(dense: jax.Array, bss,
                      threshold: float = 0.0) -> BlockSparseTensor:
    """Block a dense N-index tensor; ``bss`` is an int (cubic blocks) or a
    per-index tuple — rectangular atomic blocks are first-class, exactly
    as in ``bsm.from_dense``."""
    n = dense.ndim
    bss = _block_sizes(bss, n)
    for d, b in zip(dense.shape, bss):
        if d % b:
            raise ValueError(
                f"dense shape {dense.shape} not divisible by blocks {bss}"
            )
    nbs = tuple(d // b for d, b in zip(dense.shape, bss))
    split = tuple(x for nb, b in zip(nbs, bss) for x in (nb, b))
    # (nb_1, bs_1, nb_2, bs_2, ...) -> (grids..., blocks...)
    perm = tuple(range(0, 2 * n, 2)) + tuple(range(1, 2 * n, 2))
    blocks = dense.reshape(split).transpose(perm)
    norms = tensor_block_norms(blocks, n)
    return make_tensor(blocks, norms > threshold)


def random_tensor(key, nbs, bss, *, occupancy: float = 0.1,
                  pattern: str = "decay", dtype=jnp.float32,
                  decay: float = 0.5) -> BlockSparseTensor:
    """Random blocked tensor with a physically shaped occupation mask.

    ``pattern="decay"`` keeps block (i_1, ..., i_N) occupied with
    probability decaying exponentially in the spread of its (normalized)
    index coordinates — the shape of a screened three-center integral
    tensor ``(ij|k)``, where overlap dies off with distance between the
    centers; ``pattern="uniform"`` is the flat Bernoulli control.  The
    full-diagonal blocks (all normalized coordinates equal) are always
    kept, mirroring ``random_bsm``'s dominant diagonal.
    """
    n = len(tuple(nbs))
    nbs = tuple(int(x) for x in nbs)
    bss = _block_sizes(bss, n)
    k_mask, k_data = jax.random.split(key)
    grids = jnp.meshgrid(
        *[jnp.arange(nb, dtype=jnp.float32) / max(nb - 1, 1) for nb in nbs],
        indexing="ij",
    )
    coords = jnp.stack(grids)  # (n, nb_1, ..., nb_N)
    spread = jnp.max(coords, axis=0) - jnp.min(coords, axis=0)
    if pattern == "decay":
        keep = jnp.exp(-spread / max(decay, 1e-6))
        u = jax.random.uniform(k_mask, spread.shape)
        # calibrate the acceptance scale so the mean occupancy lands near
        # the request while the decay profile sets the *shape*
        scale = occupancy / jnp.clip(jnp.mean(keep), 1e-6, None)
        mask = u < keep * scale
    elif pattern == "uniform":
        mask = jax.random.uniform(k_mask, spread.shape) < occupancy
    else:
        raise ValueError(f"unknown pattern {pattern!r}")
    mask = mask | (spread == 0.0)  # dominant diagonal
    blocks = jax.random.normal(k_data, tuple(nbs) + tuple(bss), dtype=dtype)
    return make_tensor(blocks, mask)


# ---------------------------------------------------------------------------
# matricization: the lossless index map onto the SpGEMM stack
# ---------------------------------------------------------------------------


def _check_split(ndim: int, row_axes, col_axes) -> tuple[tuple, tuple]:
    row_axes = tuple(int(a) for a in row_axes)
    col_axes = tuple(int(a) for a in col_axes)
    if not row_axes or not col_axes:
        raise ValueError(
            "matricization needs at least one index on each side; got "
            f"rows {row_axes}, cols {col_axes}"
        )
    if sorted(row_axes + col_axes) != list(range(ndim)):
        raise ValueError(
            f"rows {row_axes} + cols {col_axes} must partition the "
            f"{ndim} tensor indices exactly once each"
        )
    return row_axes, col_axes


def matricize(t: BlockSparseTensor, row_axes, col_axes) -> BlockSparseMatrix:
    """Flatten a blocked tensor onto a block-sparse matrix.

    ``row_axes`` / ``col_axes`` (ordered, disjoint, covering all indices)
    select which tensor indices compose the matrix rows and columns.  The
    flattening is block-major: matrix block (ravel(i_rows), ravel(i_cols))
    is tensor block i, with block data transposed to (row dims, col dims)
    and reshaped — so the mask and the norms move by the same transpose +
    reshape, bit-exact, and :func:`unmatricize` inverts losslessly.
    """
    n = t.ndim
    row_axes, col_axes = _check_split(n, row_axes, col_axes)
    grid_perm = row_axes + col_axes
    block_perm = tuple(a + n for a in grid_perm)
    nb_r = int(np.prod([t.nbs[a] for a in row_axes]))
    nb_c = int(np.prod([t.nbs[a] for a in col_axes]))
    bs_r = int(np.prod([t.bss[a] for a in row_axes]))
    bs_c = int(np.prod([t.bss[a] for a in col_axes]))
    blocks = t.blocks.transpose(grid_perm + block_perm).reshape(
        nb_r, nb_c, bs_r, bs_c
    )
    mask = t.mask.transpose(grid_perm).reshape(nb_r, nb_c)
    norms = t.norms.transpose(grid_perm).reshape(nb_r, nb_c)
    return BlockSparseMatrix(blocks=blocks, mask=mask, norms=norms)


def unmatricize(m: BlockSparseMatrix, row_axes, col_axes,
                nbs, bss) -> BlockSparseTensor:
    """Invert :func:`matricize`: fold a block-sparse matrix back into the
    (``nbs``, ``bss``) blocked tensor it was flattened from.  ``row_axes``
    / ``col_axes`` / ``nbs`` / ``bss`` describe the TENSOR (the same
    arguments/properties the matricize call saw)."""
    nbs = tuple(int(x) for x in nbs)
    n = len(nbs)
    bss = _block_sizes(bss, n)
    row_axes, col_axes = _check_split(n, row_axes, col_axes)
    grid_perm = row_axes + col_axes
    expect = (
        int(np.prod([nbs[a] for a in row_axes])),
        int(np.prod([nbs[a] for a in col_axes])),
        int(np.prod([bss[a] for a in row_axes])),
        int(np.prod([bss[a] for a in col_axes])),
    )
    if tuple(m.blocks.shape) != expect:
        raise ValueError(
            f"matrix blocks {tuple(m.blocks.shape)} do not fold into "
            f"tensor nbs={nbs} bss={bss} under rows {row_axes} / cols "
            f"{col_axes} (expected {expect})"
        )
    inv = np.argsort(grid_perm)
    split_grid = tuple(nbs[a] for a in grid_perm)
    split_block = tuple(bss[a] for a in grid_perm)
    undo = tuple(inv) + tuple(int(i) + n for i in inv)
    blocks = m.blocks.reshape(split_grid + split_block).transpose(undo)
    mask = m.mask.reshape(split_grid).transpose(tuple(inv))
    norms = m.norms.reshape(split_grid).transpose(tuple(inv))
    return BlockSparseTensor(blocks=blocks, mask=mask, norms=norms)


class MatricizedTensor:
    """A blocked tensor living in matricized form, with its index map.

    ``bsm`` is the flattened matrix — a replicated ``BlockSparseMatrix``
    or a device-resident ``ShardedBSM``.  ``row_axes`` / ``col_axes`` /
    ``nbs`` / ``bss`` record the tensor structure so :meth:`to_tensor`
    can undo the flattening.  :func:`contract` accepts these as operands
    and returns one when the product stays sharded — chained
    contractions whose splits line up never leave the devices.
    """

    def __init__(self, bsm, row_axes, col_axes, nbs, bss):
        nbs = tuple(int(x) for x in nbs)
        n = len(nbs)
        bss = _block_sizes(bss, n)
        row_axes, col_axes = _check_split(n, row_axes, col_axes)
        self.bsm = bsm
        self.row_axes = row_axes
        self.col_axes = col_axes
        self.nbs = nbs
        self.bss = bss

    @property
    def ndim(self) -> int:
        return len(self.nbs)

    @property
    def sharded(self) -> bool:
        return isinstance(self.bsm, ShardedBSM)

    @property
    def dtype(self):
        return self.bsm.dtype

    def to_tensor(self) -> BlockSparseTensor:
        """Leave matrix form: gather (if sharded) and un-matricize — the
        chain-boundary operation, like ``ShardedBSM.unshard``."""
        m = self.bsm.unshard() if self.sharded else self.bsm
        return unmatricize(m, self.row_axes, self.col_axes,
                           self.nbs, self.bss)

    def __repr__(self) -> str:
        kind = "sharded" if self.sharded else "replicated"
        return (
            f"MatricizedTensor(nbs={self.nbs}, bss={self.bss}, "
            f"rows={self.row_axes}, cols={self.col_axes}, {kind})"
        )


def shard_tensor(t: BlockSparseTensor, mesh, row_axes,
                 col_axes) -> MatricizedTensor:
    """Matricize ``t`` under (``row_axes`` | ``col_axes``) and scatter the
    matrix to its 2D home layout — the tensor chain's entry point, the
    analogue of ``bsm.shard_bsm`` (and like it, the ONLY scatter of a
    chain; everything after runs on the shards)."""
    m = matricize(t, row_axes, col_axes)
    return MatricizedTensor(
        shard_bsm(m, mesh), row_axes, col_axes, t.nbs, t.bss
    )


# ---------------------------------------------------------------------------
# einsum-style contraction driver
# ---------------------------------------------------------------------------


def _parse_spec(spec: str, n_ops: int) -> tuple[list[str], str]:
    spec = spec.replace(" ", "")
    if "->" not in spec:
        raise ValueError(
            f"contract spec {spec!r} needs an explicit '->' output"
        )
    ins, out = spec.split("->")
    in_specs = ins.split(",")
    if len(in_specs) != n_ops:
        raise ValueError(
            f"spec {spec!r} names {len(in_specs)} operands, got {n_ops}"
        )
    for s in in_specs + [out]:
        if not all(c.isalpha() for c in s):
            raise ValueError(f"bad index letters in {spec!r}")
    for s in in_specs:
        if len(set(s)) != len(s):
            raise ValueError(
                f"repeated index within one operand in {spec!r}: traces "
                "are outside the matricized-SpGEMM model"
            )
    if len(set(out)) != len(out):
        raise ValueError(f"repeated output index in {spec!r}")
    return in_specs, out


def _operand_dims(op, spec: str) -> tuple[tuple[int, ...], tuple[int, ...]]:
    if not isinstance(op, (BlockSparseTensor, MatricizedTensor)):
        raise TypeError(
            f"operand for {spec!r} must be a BlockSparseTensor or "
            f"MatricizedTensor, got {type(op).__name__}"
        )
    nbs = op.nbs
    bss = op.bss
    if len(spec) != len(nbs):
        raise ValueError(
            f"operand has {len(nbs)} indices but spec names {spec!r}"
        )
    return nbs, bss


def _pair_contract(a, a_spec: str, b, b_spec: str, out: str,
                   mesh, engine: str, kw: dict):
    """One matricized SpGEMM: contract every index shared by ``a_spec``
    and ``b_spec`` that does not survive into ``out``."""
    from repro.core.engine import multiply

    shared = [c for c in a_spec if c in b_spec]
    batch = [c for c in shared if c in out]
    if batch:
        raise NotImplementedError(
            f"index {batch[0]!r} appears in both operands AND the output "
            "— batch/Hadamard dims are outside the matricized-SpGEMM "
            "model (contract them pairwise or use dense einsum)"
        )
    if not shared:
        raise ValueError(
            f"operands {a_spec!r} and {b_spec!r} share no contracted "
            "index — outer products are not SpGEMMs"
        )
    free_a = [c for c in a_spec if c not in shared]
    free_b = [c for c in b_spec if c not in shared]
    if not free_a or not free_b:
        raise ValueError(
            f"contraction {a_spec},{b_spec} leaves no free index on one "
            "side; full inner products are not supported"
        )
    stray = (set(out) - set(free_a) - set(free_b))
    if stray:
        raise ValueError(
            f"output index {stray.pop()!r} appears in no operand"
        )

    # the contracted group is aligned in A-spec order on both sides
    k_order = [c for c in a_spec if c in shared]
    a_nbs, a_bss = _operand_dims(a, a_spec)
    b_nbs, b_bss = _operand_dims(b, b_spec)
    for c in k_order:
        ia, ib = a_spec.index(c), b_spec.index(c)
        if a_nbs[ia] != b_nbs[ib] or a_bss[ia] != b_bss[ib]:
            raise ValueError(
                f"contracted index {c!r} disagrees between operands: "
                f"{a_nbs[ia]} blocks of {a_bss[ia]} vs "
                f"{b_nbs[ib]} blocks of {b_bss[ib]}"
            )

    a_rows = tuple(a_spec.index(c) for c in free_a)
    a_cols = tuple(a_spec.index(c) for c in k_order)
    b_rows = tuple(b_spec.index(c) for c in k_order)
    b_cols = tuple(b_spec.index(c) for c in free_b)
    ma = _as_matrix(a, a_rows, a_cols, "A")
    mb = _as_matrix(b, b_rows, b_cols, "B")
    mc = multiply(ma, mb, mesh, engine=engine, **kw)

    out_nbs = tuple(a_nbs[a_spec.index(c)] for c in free_a) + tuple(
        b_nbs[b_spec.index(c)] for c in free_b
    )
    out_bss = tuple(a_bss[a_spec.index(c)] for c in free_a) + tuple(
        b_bss[b_spec.index(c)] for c in free_b
    )
    nat = "".join(free_a) + "".join(free_b)  # C's natural index order
    row_axes = tuple(range(len(free_a)))
    col_axes = tuple(range(len(free_a), len(nat)))
    if isinstance(mc, ShardedBSM):
        if out != nat:
            raise ValueError(
                f"sharded contraction produces index order {nat!r}; "
                f"reordering to {out!r} needs a gather — request "
                f"'->{nat}' and transpose at the chain boundary"
            )
        return MatricizedTensor(mc, row_axes, col_axes, out_nbs, out_bss), nat
    t = unmatricize(mc, row_axes, col_axes, out_nbs, out_bss)
    if out != nat:
        t = _transpose_tensor(t, tuple(nat.index(c) for c in out))
        nat = out
    return t, nat


def _as_matrix(op, rows: tuple, cols: tuple, side: str):
    """Matricize an operand for one SpGEMM — or pass its existing
    matricized form through when the split already lines up (the
    device-resident chaining fast path)."""
    if isinstance(op, BlockSparseTensor):
        return matricize(op, rows, cols)
    if isinstance(op, MatricizedTensor):
        if (op.row_axes, op.col_axes) == (rows, cols):
            return op.bsm
        if op.sharded:
            raise ValueError(
                f"operand {side} is sharded under split "
                f"({op.row_axes} | {op.col_axes}) but this contraction "
                f"needs ({rows} | {cols}): re-matricizing a sharded "
                "tensor is a global redistribution — call .to_tensor() "
                "at the chain boundary and re-shard under the new split"
            )
        return matricize(op.to_tensor(), rows, cols)
    raise TypeError(
        f"operand {side} must be a BlockSparseTensor or MatricizedTensor, "
        f"got {type(op).__name__}"
    )


def _transpose_tensor(t: BlockSparseTensor, perm: tuple) -> BlockSparseTensor:
    n = t.ndim
    gp = tuple(perm)
    bp = tuple(a + n for a in gp)
    return BlockSparseTensor(
        blocks=t.blocks.transpose(gp + bp),
        mask=t.mask.transpose(gp),
        norms=t.norms.transpose(gp),
    )


def contract(spec: str, *operands, mesh=None, engine: str = "auto", **kw):
    """Einsum-style blocked sparse tensor contraction over the SpGEMM stack.

    ``contract("ijk,kl->ijl", t1, t2, mesh=mesh, engine="auto")`` splits
    each operand's indices into (free | contracted), matricizes both onto
    block-sparse matrices whose shared k dimension carries ALL contracted
    indices (in first-operand order), multiplies via ``engine.multiply``
    — so thresholded filtering, compacted stacks, compressed transport,
    tile autotuning and the tuner all apply unchanged — and folds the
    product back into a tensor.  Keyword args (``threshold``,
    ``filter_eps``, ``backend``, ``l``, ``transport``, ...) pass through
    to ``multiply``.

    Operands may be :class:`BlockSparseTensor` (replicated) or
    :class:`MatricizedTensor` (see :func:`shard_tensor`).  When the
    operands of a pairwise product are sharded, the product stays
    sharded and is returned as a ``MatricizedTensor`` under its natural
    (free-A | free-B) split — feed it straight into the next
    ``contract`` with a matching split and the chain never gathers.

    More than two operands contract pairwise left-to-right; each
    intermediate keeps exactly the indices later operands or the output
    still need.
    """
    in_specs, out = _parse_spec(spec, len(operands))
    if len(operands) < 2:
        raise ValueError("contract needs at least two operands")
    acc, acc_spec = operands[0], in_specs[0]
    for i in range(1, len(operands)):
        later = set("".join(in_specs[i + 1:]))
        if i == len(operands) - 1:
            step_out = out
        else:
            keep = [c for c in acc_spec + in_specs[i]
                    if c in later or c in out]
            # natural pairwise order; duplicates impossible (batch dims
            # are rejected inside _pair_contract)
            step_out = "".join(dict.fromkeys(keep))
        acc, acc_spec = _pair_contract(
            acc, acc_spec, operands[i], in_specs[i], step_out,
            mesh, engine, dict(kw),
        )
    return acc


def contract_reference(spec: str, *operands) -> jax.Array:
    """Dense einsum oracle: densify every operand and let ``np.einsum``
    do the contraction — the ground truth the distributed ``contract``
    is validated against in tests and benchmarks."""
    dense = []
    for op in operands:
        t = op.to_tensor() if isinstance(op, MatricizedTensor) else op
        dense.append(np.asarray(t.to_dense()))
    return np.einsum(spec, *dense)
