"""Block-sparse matrix format (DBCSR analogue for TPU/XLA).

DBCSR stores matrices in blocked CSR distributed over a 2D process grid.
XLA needs static shapes, so the TPU-native equivalent used here is a dense
*block grid* plus a boolean occupation mask and per-block Frobenius norms:

    blocks : (nb_r, nb_c, bs_r, bs_c)   block data (zero where unoccupied)
    mask   : (nb_r, nb_c) bool          block occupation
    norms  : (nb_r, nb_c) float32       per-block Frobenius norms

The mask/norms drive DBCSR's *on-the-fly filtering* (skip block products with
``norm(A_ik) * norm(B_kj) <= eps``) and *post-filtering* (drop result blocks
below threshold).  On real TPU hardware the Pallas kernel predicates the MXU
tiles on the mask so filtered products are genuinely skipped; the pure-jnp
path multiplies by the mask (numerically identical).

DBCSR uses randomized row/column permutations for load balance; with the
dense block-grid storage the layout is already statically balanced, but the
permutation utilities are kept (and tested) because the *algorithmic* load
balance of the sparsity pattern still matters for the occupancy statistics
we report in the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.pytree import pytree_dataclass


@pytree_dataclass
class BlockSparseMatrix:
    """A block-sparse matrix: dense block grid + mask + block norms."""

    blocks: jax.Array  # (nb_r, nb_c, bs_r, bs_c)
    mask: jax.Array  # (nb_r, nb_c) bool
    norms: jax.Array  # (nb_r, nb_c) float32

    # ---- shape helpers -------------------------------------------------
    @property
    def nb_r(self) -> int:
        return self.blocks.shape[0]

    @property
    def nb_c(self) -> int:
        return self.blocks.shape[1]

    @property
    def bs_r(self) -> int:
        return self.blocks.shape[2]

    @property
    def bs_c(self) -> int:
        return self.blocks.shape[3]

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nb_r * self.bs_r, self.nb_c * self.bs_c)

    @property
    def dtype(self):
        return self.blocks.dtype

    # ---- stats ---------------------------------------------------------
    def nnz_blocks(self) -> jax.Array:
        return jnp.sum(self.mask)

    def occupancy(self) -> jax.Array:
        """Fraction of occupied blocks (the paper's 'occupancy')."""
        return jnp.mean(self.mask.astype(jnp.float32))

    def frobenius_norm(self) -> jax.Array:
        return jnp.sqrt(jnp.sum(jnp.square(self.norms)))

    # ---- conversions ---------------------------------------------------
    def to_dense(self) -> jax.Array:
        nb_r, nb_c, bs_r, bs_c = self.blocks.shape
        masked = self.blocks * self.mask[:, :, None, None].astype(self.blocks.dtype)
        return masked.transpose(0, 2, 1, 3).reshape(nb_r * bs_r, nb_c * bs_c)


def block_norms(blocks: jax.Array) -> jax.Array:
    """Frobenius norm of every block, computed in f32."""
    b32 = blocks.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(b32 * b32, axis=(-2, -1)))


def make_bsm(blocks: jax.Array, mask: jax.Array) -> BlockSparseMatrix:
    """Build a BSM from raw blocks + mask, zeroing masked-out data and
    recomputing norms (keeps the three fields mutually consistent)."""
    m = mask.astype(bool)
    blocks = blocks * m[:, :, None, None].astype(blocks.dtype)
    return BlockSparseMatrix(blocks=blocks, mask=m, norms=block_norms(blocks))


def from_dense(
    dense: jax.Array, bs: int, threshold: float = 0.0
) -> BlockSparseMatrix:
    n_r, n_c = dense.shape
    if n_r % bs or n_c % bs:
        raise ValueError(f"dense shape {dense.shape} not divisible by bs={bs}")
    nb_r, nb_c = n_r // bs, n_c // bs
    blocks = dense.reshape(nb_r, bs, nb_c, bs).transpose(0, 2, 1, 3)
    norms = block_norms(blocks)
    mask = norms > threshold
    return make_bsm(blocks, mask)


def filter_bsm(m: BlockSparseMatrix, threshold: float) -> BlockSparseMatrix:
    """Post-multiplication filtering: drop blocks with norm <= threshold."""
    keep = m.mask & (m.norms > threshold)
    return make_bsm(m.blocks, keep)


def identity(nb: int, bs: int, dtype=jnp.float32) -> BlockSparseMatrix:
    eye_blk = jnp.eye(bs, dtype=dtype)
    blocks = jnp.zeros((nb, nb, bs, bs), dtype)
    idx = jnp.arange(nb)
    blocks = blocks.at[idx, idx].set(eye_blk)
    mask = jnp.eye(nb, dtype=bool)
    return make_bsm(blocks, mask)


def add(a: BlockSparseMatrix, b: BlockSparseMatrix) -> BlockSparseMatrix:
    return make_bsm(a.blocks + b.blocks, a.mask | b.mask)


def scale(a: BlockSparseMatrix, s) -> BlockSparseMatrix:
    return make_bsm(a.blocks * jnp.asarray(s, a.dtype), a.mask)


# ---------------------------------------------------------------------------
# Pattern generation (benchmark matrices; Table 1 of the paper)
# ---------------------------------------------------------------------------


def _pattern_mask(key, nb_r, nb_c, occupancy, pattern, bandwidth):
    """numpy mask generation (host side — patterns are data, not traced)."""
    rng = np.random.default_rng(np.asarray(jax.random.key_data(key)).ravel()[:2])
    if pattern == "dense":
        return np.ones((nb_r, nb_c), bool)
    if pattern == "random":
        m = rng.random((nb_r, nb_c)) < occupancy
    elif pattern == "banded":
        # |i - j| <= bw occupied; models near-sightedness of the operators
        i = np.arange(nb_r)[:, None]
        j = np.arange(nb_c)[None, :]
        m = np.abs(i - j) <= bandwidth
    elif pattern == "decay":
        # exponential decay of occupation probability with block distance —
        # the shape of linear-scaling DFT operators (H, S, P)
        i = np.arange(nb_r)[:, None]
        j = np.arange(nb_c)[None, :]
        d = np.abs(i - j)
        # calibrate scale so mean probability ~= occupancy
        scale_ = max(occupancy * nb_c / 2.0, 1e-3)
        p = np.exp(-d / scale_)
        m = rng.random((nb_r, nb_c)) < p
    else:
        raise ValueError(f"unknown pattern {pattern!r}")
    # diagonal always occupied (operators have dominant diagonal)
    n = min(nb_r, nb_c)
    m[np.arange(n), np.arange(n)] = True
    return m


def random_bsm(
    key: jax.Array,
    nb: int,
    bs: int,
    occupancy: float = 0.1,
    pattern: str = "random",
    bandwidth: int = 2,
    dtype=jnp.float32,
    symmetric: bool = False,
) -> BlockSparseMatrix:
    """Random block-sparse matrix with the given block occupancy pattern."""
    k_mask, k_data = jax.random.split(key)
    mask_np = _pattern_mask(k_mask, nb, nb, occupancy, pattern, bandwidth)
    if symmetric:
        mask_np = mask_np | mask_np.T
    mask = jnp.asarray(mask_np)
    blocks = jax.random.normal(k_data, (nb, nb, bs, bs), dtype) / np.sqrt(bs)
    if symmetric:
        blocks = 0.5 * (blocks + blocks.transpose(1, 0, 3, 2))
    return make_bsm(blocks, mask)


def random_load_balance_permutation(key: jax.Array, nb: int) -> np.ndarray:
    """DBCSR's randomized row/col permutation for static load balance."""
    rng = np.random.default_rng(np.asarray(jax.random.key_data(key)).ravel()[:2])
    return rng.permutation(nb)


def permute(m: BlockSparseMatrix, perm_r, perm_c) -> BlockSparseMatrix:
    perm_r = jnp.asarray(perm_r)
    perm_c = jnp.asarray(perm_c)
    return BlockSparseMatrix(
        blocks=m.blocks[perm_r][:, perm_c],
        mask=m.mask[perm_r][:, perm_c],
        norms=m.norms[perm_r][:, perm_c],
    )


def grid_block_loads(mask: np.ndarray | jax.Array, pr: int, pc: int) -> np.ndarray:
    """Occupied-block count of each (pr x pc) panel — load-balance metric."""
    mask = np.asarray(mask)
    nb_r, nb_c = mask.shape
    return (
        mask.reshape(pr, nb_r // pr, pc, nb_c // pc)
        .sum(axis=(1, 3))
        .astype(np.int64)
    )
