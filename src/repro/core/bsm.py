"""Block-sparse matrix format (DBCSR analogue for TPU/XLA).

DBCSR stores matrices in blocked CSR distributed over a 2D process grid.
XLA needs static shapes, so the TPU-native equivalent used here is a dense
*block grid* plus a boolean occupation mask and per-block Frobenius norms:

    blocks : (nb_r, nb_c, bs_r, bs_c)   block data (zero where unoccupied)
    mask   : (nb_r, nb_c) bool          block occupation
    norms  : (nb_r, nb_c) float32       per-block Frobenius norms

The mask/norms drive DBCSR's *on-the-fly filtering* (skip block products with
``norm(A_ik) * norm(B_kj) <= eps``) and *post-filtering* (drop result blocks
below threshold).  On real TPU hardware the Pallas kernel predicates the MXU
tiles on the mask so filtered products are genuinely skipped; the pure-jnp
path multiplies by the mask (numerically identical).

DBCSR uses randomized row/column permutations for load balance; with the
dense block-grid storage the layout is already statically balanced, but the
permutation utilities are kept (and tested) because the *algorithmic* load
balance of the sparsity pattern still matters for the occupancy statistics
we report in the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.pytree import pytree_dataclass


@pytree_dataclass
class BlockSparseMatrix:
    """A block-sparse matrix: dense block grid + mask + block norms."""

    blocks: jax.Array  # (nb_r, nb_c, bs_r, bs_c)
    mask: jax.Array  # (nb_r, nb_c) bool
    norms: jax.Array  # (nb_r, nb_c) float32

    # ---- shape helpers -------------------------------------------------
    @property
    def nb_r(self) -> int:
        return self.blocks.shape[0]

    @property
    def nb_c(self) -> int:
        return self.blocks.shape[1]

    @property
    def bs_r(self) -> int:
        return self.blocks.shape[2]

    @property
    def bs_c(self) -> int:
        return self.blocks.shape[3]

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nb_r * self.bs_r, self.nb_c * self.bs_c)

    @property
    def dtype(self):
        return self.blocks.dtype

    # ---- stats ---------------------------------------------------------
    def nnz_blocks(self) -> jax.Array:
        return jnp.sum(self.mask)

    def occupancy(self) -> jax.Array:
        """Fraction of occupied blocks (the paper's 'occupancy')."""
        return jnp.mean(self.mask.astype(jnp.float32))

    def frobenius_norm(self) -> jax.Array:
        return jnp.sqrt(jnp.sum(jnp.square(self.norms)))

    # ---- conversions ---------------------------------------------------
    def astype(self, dtype) -> "BlockSparseMatrix":
        """Cast block storage to ``dtype``, recalibrating norms.

        Norms are recomputed from the *quantized* blocks (always in f32 —
        ``block_norms``), so the on-the-fly threshold filter sees the
        values that will actually be multiplied, not the pre-rounding
        ones — the recalibration rule of DESIGN.md §2's mixed-precision
        pipeline.  Identity (same object) when the dtype already matches.
        """
        if jnp.dtype(dtype) == self.dtype:
            return self
        blocks = self.blocks.astype(dtype)
        return BlockSparseMatrix(
            blocks=blocks, mask=self.mask, norms=block_norms(blocks)
        )

    def to_dense(self) -> jax.Array:
        nb_r, nb_c, bs_r, bs_c = self.blocks.shape
        masked = self.blocks * self.mask[:, :, None, None].astype(self.blocks.dtype)
        return masked.transpose(0, 2, 1, 3).reshape(nb_r * bs_r, nb_c * bs_c)


def block_norms(blocks: jax.Array) -> jax.Array:
    """Frobenius norm of every block, computed in f32."""
    b32 = blocks.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(b32 * b32, axis=(-2, -1)))


def make_bsm(blocks: jax.Array, mask: jax.Array) -> BlockSparseMatrix:
    """Build a BSM from raw blocks + mask, zeroing masked-out data and
    recomputing norms (keeps the three fields mutually consistent)."""
    m = mask.astype(bool)
    blocks = blocks * m[:, :, None, None].astype(blocks.dtype)
    return BlockSparseMatrix(blocks=blocks, mask=m, norms=block_norms(blocks))


def _block_shape(bs) -> tuple[int, int]:
    """Normalize a block-size spec: int -> square, (bs_r, bs_c) -> as-is."""
    if isinstance(bs, (tuple, list)):
        bs_r, bs_c = bs
        return int(bs_r), int(bs_c)
    return int(bs), int(bs)


def from_dense(dense: jax.Array, bs, threshold: float = 0.0) -> BlockSparseMatrix:
    """Block a dense matrix; ``bs`` may be an int or a (bs_r, bs_c) tuple
    (rectangular atomic blocks are first-class, see DESIGN.md §2)."""
    bs_r, bs_c = _block_shape(bs)
    n_r, n_c = dense.shape
    if n_r % bs_r or n_c % bs_c:
        raise ValueError(
            f"dense shape {dense.shape} not divisible by bs=({bs_r}, {bs_c})"
        )
    nb_r, nb_c = n_r // bs_r, n_c // bs_c
    blocks = dense.reshape(nb_r, bs_r, nb_c, bs_c).transpose(0, 2, 1, 3)
    norms = block_norms(blocks)
    mask = norms > threshold
    return make_bsm(blocks, mask)


def filter_bsm(m: BlockSparseMatrix, threshold: float) -> BlockSparseMatrix:
    """Post-multiplication filtering: drop blocks with norm <= threshold.

    Norms are *derived* (existing norms under the new mask), not recomputed
    — ``make_bsm`` stays the consistency fallback for callers with raw
    blocks/mask pairs of unknown provenance.
    """
    keep = m.mask & (m.norms > threshold)
    return BlockSparseMatrix(
        blocks=m.blocks * keep[:, :, None, None].astype(m.dtype),
        mask=keep,
        norms=jnp.where(keep, m.norms, 0.0),
    )


def identity(nb: int, bs, dtype=jnp.float32) -> BlockSparseMatrix:
    """Blocked identity.  ``bs`` may be an int or a (bs_r, bs_c) tuple; a
    rectangular blocking must still tile a square matrix (nb * bs_r
    divisible by bs_c), and the global diagonal then crosses block
    boundaries, so the rectangular path blocks a dense eye."""
    bs_r, bs_c = _block_shape(bs)
    if bs_r == bs_c:
        eye_blk = jnp.eye(bs_r, dtype=dtype)
        blocks = jnp.zeros((nb, nb, bs_r, bs_r), dtype)
        idx = jnp.arange(nb)
        blocks = blocks.at[idx, idx].set(eye_blk)
        mask = jnp.eye(nb, dtype=bool)
        return make_bsm(blocks, mask)
    n = nb * bs_r
    if n % bs_c:
        raise ValueError(
            f"identity of size {n} (nb={nb} x bs_r={bs_r}) is not "
            f"divisible by bs_c={bs_c}"
        )
    return from_dense(jnp.eye(n, dtype=dtype), (bs_r, bs_c))


def add(a: BlockSparseMatrix, b: BlockSparseMatrix) -> BlockSparseMatrix:
    """A + B.  Inputs are consistent triples (masked-out blocks are zero),
    so the sum needs no re-masking; only the data-dependent norms are
    recomputed."""
    blocks = a.blocks + b.blocks
    return BlockSparseMatrix(
        blocks=blocks, mask=a.mask | b.mask, norms=block_norms(blocks)
    )


def scale(a: BlockSparseMatrix, s) -> BlockSparseMatrix:
    """s * A with derived norms: |s| . norms (no block-norm recompute)."""
    s = jnp.asarray(s, a.dtype)
    return BlockSparseMatrix(
        blocks=a.blocks * s,
        mask=a.mask,
        norms=a.norms * jnp.abs(s).astype(jnp.float32),
    )


def axpy(s, x: BlockSparseMatrix, y: BlockSparseMatrix) -> BlockSparseMatrix:
    """s * X + Y (one fused update; norms recomputed on the sum)."""
    blocks = x.blocks * jnp.asarray(s, x.dtype) + y.blocks
    return BlockSparseMatrix(
        blocks=blocks, mask=x.mask | y.mask, norms=block_norms(blocks)
    )


# ---------------------------------------------------------------------------
# ShardedBSM: device-resident block-sparse matrices (DESIGN.md §2, §5)
# ---------------------------------------------------------------------------


def _bsm_shardings(mesh):
    """(blocks, mask/norms) NamedShardings of the 2D home layout: block rows
    over the mesh's ``r`` axis, block columns over ``c``; replicated over a
    depth axis ``l`` when the mesh has one (the stacked 2.5D engine pulls
    its own per-layer copies)."""
    return (
        NamedSharding(mesh, P("r", "c", None, None)),
        NamedSharding(mesh, P("r", "c")),
    )


@pytree_dataclass(meta_fields=("mesh", "assignment"))
class ShardedBSM:
    """A block-sparse matrix resident on a device mesh for the lifetime of
    an iteration chain.

    Same triple as :class:`BlockSparseMatrix` — blocks / mask / norms — but
    carried in the 2D home layout with explicit ``NamedSharding`` (block
    rows over mesh axis ``r``, block columns over ``c``), plus device-side
    algebra that updates norms incrementally instead of round-tripping
    through ``make_bsm``.  The paper's "never redistribute" design point:
    a purification chain shards its operands once (``shard_bsm``), every
    multiply and every inter-multiply update runs on the shards, and the
    result is gathered once at the chain boundary (``unshard``).

    ``assignment`` records the block→device distribution the triple lives
    under (``core.distribute.Assignment``, None = identity layout): the
    shards hold the PERMUTED matrix, ``unshard`` undoes the permutation,
    and every algebra result inherits the layout.  Mixing layouts in one
    operation is a hard error — permutations are data placement, and two
    placements cannot be added blockwise.
    """

    blocks: jax.Array  # (nb_r, nb_c, bs_r, bs_c), sharded P(r, c, -, -)
    mask: jax.Array  # (nb_r, nb_c) bool, sharded P(r, c)
    norms: jax.Array  # (nb_r, nb_c) float32, sharded P(r, c)
    mesh: object  # static: the home mesh (pytree meta field)
    assignment: object = None  # static: distribute.Assignment or None

    def _join_assignment(self, other: "ShardedBSM"):
        if self.assignment != other.assignment:
            raise ValueError(
                "operands live under different block assignments "
                f"({_assign_name(self.assignment)} vs "
                f"{_assign_name(other.assignment)}); reshard one of them"
            )
        return self.assignment

    # ---- shape helpers -------------------------------------------------
    @property
    def nb_r(self) -> int:
        return self.blocks.shape[0]

    @property
    def nb_c(self) -> int:
        return self.blocks.shape[1]

    @property
    def bs_r(self) -> int:
        return self.blocks.shape[2]

    @property
    def bs_c(self) -> int:
        return self.blocks.shape[3]

    @property
    def shape(self) -> tuple[int, int]:
        return (self.nb_r * self.bs_r, self.nb_c * self.bs_c)

    @property
    def dtype(self):
        return self.blocks.dtype

    # ---- device-side algebra (norms updated incrementally) -------------
    def add(self, other: "ShardedBSM") -> "ShardedBSM":
        blocks = self.blocks + other.blocks
        return ShardedBSM(
            blocks=blocks,
            mask=self.mask | other.mask,
            norms=block_norms(blocks),
            mesh=self.mesh,
            assignment=self._join_assignment(other),
        )

    def scale(self, s) -> "ShardedBSM":
        s = jnp.asarray(s, self.dtype)
        return ShardedBSM(
            blocks=self.blocks * s,
            mask=self.mask,
            norms=self.norms * jnp.abs(s).astype(jnp.float32),
            mesh=self.mesh,
            assignment=self.assignment,
        )

    def axpy(self, s, y: "ShardedBSM") -> "ShardedBSM":
        """s * self + y."""
        blocks = self.blocks * jnp.asarray(s, self.dtype) + y.blocks
        return ShardedBSM(
            blocks=blocks,
            mask=self.mask | y.mask,
            norms=block_norms(blocks),
            mesh=self.mesh,
            assignment=self._join_assignment(y),
        )

    def filter(self, threshold: float) -> "ShardedBSM":
        """Post-filter on the shards: drop blocks with norm <= threshold
        (derived norms — no recompute, no gather)."""
        keep = self.mask & (self.norms > threshold)
        return ShardedBSM(
            blocks=self.blocks * keep[:, :, None, None].astype(self.dtype),
            mask=keep,
            norms=jnp.where(keep, self.norms, 0.0),
            mesh=self.mesh,
            assignment=self.assignment,
        )

    def frobenius_norm(self) -> jax.Array:
        """Device-resident scalar (an all-reduce, never a gather)."""
        return jnp.sqrt(jnp.sum(jnp.square(self.norms)))

    def trace(self) -> jax.Array:
        idx = jnp.arange(min(self.nb_r, self.nb_c))
        diag = self.blocks[idx, idx]
        dmask = self.mask[idx, idx]
        tr = jnp.trace(diag, axis1=-2, axis2=-1)
        return jnp.sum(tr * dmask)

    def occupancy(self) -> jax.Array:
        return jnp.mean(self.mask.astype(jnp.float32))

    def nnz_blocks(self) -> jax.Array:
        return jnp.sum(self.mask)

    def astype(self, dtype) -> "ShardedBSM":
        """Cast block storage on the shards, recalibrating norms from the
        quantized blocks (see :meth:`BlockSparseMatrix.astype`) — no
        gather, the cast and the norm reduction both run shard-local."""
        if jnp.dtype(dtype) == self.dtype:
            return self
        blocks = self.blocks.astype(dtype)
        return ShardedBSM(
            blocks=blocks,
            mask=self.mask,
            norms=block_norms(blocks),
            mesh=self.mesh,
            assignment=self.assignment,
        )

    # ---- chain-boundary conversions ------------------------------------
    def unshard(self) -> BlockSparseMatrix:
        """Gather the triple to every device — the explicit chain-boundary
        conversion (the ONLY place a purification chain pays a gather).
        Undoes the block assignment, so callers always get the matrix back
        in its original (unpermuted) block coordinates."""
        rep = NamedSharding(self.mesh, P())
        out = BlockSparseMatrix(
            blocks=jax.device_put(self.blocks, rep),
            mask=jax.device_put(self.mask, rep),
            norms=jax.device_put(self.norms, rep),
        )
        if self.assignment is not None:
            from repro.core import distribute as D

            out = D.undo_assignment(out, self.assignment)
        return out

    def to_dense(self) -> jax.Array:
        return self.unshard().to_dense()


def _assign_name(assignment) -> str:
    return "identity" if assignment is None else assignment.mode


def _resolve_shard_assignment(m: BlockSparseMatrix, mesh, assignment):
    """Normalize a ``shard_bsm`` assignment spec: None / "identity" stay
    the identity layout; a mode string derives the deterministic
    assignment from the matrix's own mask product (``X @ X`` — the
    purification-chain pattern); a ``distribute.Assignment`` is validated
    as-is.  Identity assignments collapse to None so cache keys and
    pytree meta stay exactly as before this layer existed."""
    if assignment is None:
        return None
    from repro.core import distribute as D

    if isinstance(assignment, str):
        if assignment == "identity":
            return None
        assignment = D.compute_assignment(
            assignment, np.asarray(m.mask), np.asarray(m.mask), mesh
        )
    asg = assignment
    if not isinstance(asg, D.Assignment):
        raise TypeError(
            f"assignment must be None, a mode string {D.MODES}, or a "
            f"distribute.Assignment; got {type(asg).__name__}"
        )
    asg.validate(m.nb_r, m.nb_c)
    return None if asg.is_identity else asg


def shard_bsm(
    m: BlockSparseMatrix | ShardedBSM, mesh, assignment=None
) -> ShardedBSM:
    """Scatter a BlockSparseMatrix to its 2D home layout on ``mesh``.

    The inverse of :meth:`ShardedBSM.unshard`; the two are the explicit
    chain boundaries of DESIGN.md §5.  Idempotent on an already-sharded
    matrix of the same mesh.

    ``assignment`` selects the block→device distribution (DESIGN.md §4's
    distribution layer): None keeps the identity layout, a mode string
    ("randomized" / "nnz_greedy") derives the deterministic permutation
    from the matrix's own mask, and an explicit ``distribute.Assignment``
    is applied as-is.  The permutation happens HERE, on the replicated
    matrix, before the scatter — engines and kernels only ever see the
    permuted home layout.
    """
    if isinstance(m, ShardedBSM):
        if m.mesh is not mesh and m.mesh != mesh:
            raise ValueError("matrix is already sharded on a different mesh")
        if assignment is not None:
            want = _resolve_shard_assignment(m, mesh, assignment)
            if want != m.assignment:
                raise ValueError(
                    f"matrix is already sharded under assignment "
                    f"{_assign_name(m.assignment)}; unshard before "
                    f"redistributing to {_assign_name(want)}"
                )
        return m
    if "r" not in mesh.axis_names or "c" not in mesh.axis_names:
        raise ValueError(
            f"SpGEMM meshes carry ('r', 'c') axes; got {mesh.axis_names}"
        )
    p_r, p_c = mesh.shape["r"], mesh.shape["c"]
    if m.nb_r % p_r or m.nb_c % p_c:
        raise ValueError(
            f"block grid {m.nb_r}x{m.nb_c} does not divide the "
            f"{p_r}x{p_c} process grid"
        )
    asg = _resolve_shard_assignment(m, mesh, assignment)
    if asg is not None:
        from repro.core import distribute as D

        m = D.apply_assignment(m, asg)
    blk, m2 = _bsm_shardings(mesh)
    return ShardedBSM(
        blocks=jax.device_put(m.blocks, blk),
        mask=jax.device_put(m.mask, m2),
        norms=jax.device_put(m.norms, m2),
        mesh=mesh,
        assignment=asg,
    )


def unshard_bsm(m: BlockSparseMatrix | ShardedBSM) -> BlockSparseMatrix:
    """Chain-boundary gather; identity on an unsharded matrix."""
    return m.unshard() if isinstance(m, ShardedBSM) else m


def cast_bsm(m, dtype):
    """Storage-dtype cast with norm recalibration for either matrix kind
    (``BlockSparseMatrix`` or ``ShardedBSM``); identity when already at
    ``dtype``.  The one entry point reduced-precision pipelines
    (``signiter.sign_iteration(storage_dtype=...)``) go through."""
    return m.astype(dtype)


def sharded_identity(
    nb: int, bs, mesh, dtype=jnp.float32, assignment=None
) -> ShardedBSM:
    """Blocked identity born sharded (no replicated intermediate kept).
    Symmetric assignments fix the identity pattern (P I Pᵀ = I), so any
    ``assignment`` yields the same data — it is carried so the result can
    join algebra with operands living in that layout."""
    return shard_bsm(identity(nb, bs, dtype), mesh, assignment=assignment)


# ---------------------------------------------------------------------------
# Pattern generation (benchmark matrices; Table 1 of the paper)
# ---------------------------------------------------------------------------


def _pattern_mask(key, nb_r, nb_c, occupancy, pattern, bandwidth):
    """numpy mask generation (host side — patterns are data, not traced)."""
    rng = np.random.default_rng(np.asarray(jax.random.key_data(key)).ravel()[:2])
    if pattern == "dense":
        return np.ones((nb_r, nb_c), bool)
    if pattern == "random":
        m = rng.random((nb_r, nb_c)) < occupancy
    elif pattern == "banded":
        # |i - j| <= bw occupied; models near-sightedness of the operators
        i = np.arange(nb_r)[:, None]
        j = np.arange(nb_c)[None, :]
        m = np.abs(i - j) <= bandwidth
    elif pattern == "decay":
        # exponential decay of occupation probability with block distance —
        # the shape of linear-scaling DFT operators (H, S, P)
        i = np.arange(nb_r)[:, None]
        j = np.arange(nb_c)[None, :]
        d = np.abs(i - j)
        # calibrate scale so mean probability ~= occupancy
        scale_ = max(occupancy * nb_c / 2.0, 1e-3)
        p = np.exp(-d / scale_)
        m = rng.random((nb_r, nb_c)) < p
    else:
        raise ValueError(f"unknown pattern {pattern!r}")
    # diagonal always occupied (operators have dominant diagonal)
    n = min(nb_r, nb_c)
    m[np.arange(n), np.arange(n)] = True
    return m


def random_bsm(
    key: jax.Array,
    nb: int,
    bs: int,
    occupancy: float = 0.1,
    pattern: str = "random",
    bandwidth: int = 2,
    dtype=jnp.float32,
    symmetric: bool = False,
) -> BlockSparseMatrix:
    """Random block-sparse matrix with the given block occupancy pattern."""
    k_mask, k_data = jax.random.split(key)
    mask_np = _pattern_mask(k_mask, nb, nb, occupancy, pattern, bandwidth)
    if symmetric:
        mask_np = mask_np | mask_np.T
    mask = jnp.asarray(mask_np)
    blocks = jax.random.normal(k_data, (nb, nb, bs, bs), dtype) / np.sqrt(bs)
    if symmetric:
        blocks = 0.5 * (blocks + blocks.transpose(1, 0, 3, 2))
    return make_bsm(blocks, mask)


def random_load_balance_permutation(key: jax.Array, nb: int) -> np.ndarray:
    """DBCSR's randomized row/col permutation for static load balance."""
    rng = np.random.default_rng(np.asarray(jax.random.key_data(key)).ravel()[:2])
    return rng.permutation(nb)


def permute(m: BlockSparseMatrix, perm_r, perm_c) -> BlockSparseMatrix:
    perm_r = jnp.asarray(perm_r)
    perm_c = jnp.asarray(perm_c)
    return BlockSparseMatrix(
        blocks=m.blocks[perm_r][:, perm_c],
        mask=m.mask[perm_r][:, perm_c],
        norms=m.norms[perm_r][:, perm_c],
    )


def grid_block_loads(mask: np.ndarray | jax.Array, pr: int, pc: int) -> np.ndarray:
    """Occupied-block count of each (pr x pc) panel — load-balance metric."""
    mask = np.asarray(mask)
    nb_r, nb_c = mask.shape
    return (
        mask.reshape(pr, nb_r // pr, pc, nb_c // pc)
        .sum(axis=(1, 3))
        .astype(np.int64)
    )
