"""repro.core — the paper's contribution: block-sparse matrix format and
communication-reducing distributed multiplication engines."""
from repro.core.bsm import (
    BlockSparseMatrix,
    ShardedBSM,
    add,
    axpy,
    block_norms,
    filter_bsm,
    from_dense,
    identity,
    make_bsm,
    permute,
    random_bsm,
    scale,
    shard_bsm,
    sharded_identity,
    unshard_bsm,
)
from repro.core.commvolume import (
    memory_factor,
    mesh25d_volume,
    osl_volume,
    ptp_volume,
    volume_ratio_os1_over_osl,
)
from repro.core.engine import ENGINES, lower_multiply, multiply, multiply_reference
from repro.core.signiter import density_matrix, sign_iteration, trace
from repro.core.topology import (
    Topology,
    make_topology,
    simulate_algorithm2,
    validate_l,
)

__all__ = [
    "BlockSparseMatrix",
    "ENGINES",
    "ShardedBSM",
    "Topology",
    "add",
    "axpy",
    "block_norms",
    "density_matrix",
    "filter_bsm",
    "from_dense",
    "identity",
    "lower_multiply",
    "make_bsm",
    "make_topology",
    "memory_factor",
    "mesh25d_volume",
    "multiply",
    "multiply_reference",
    "osl_volume",
    "permute",
    "ptp_volume",
    "random_bsm",
    "scale",
    "shard_bsm",
    "sharded_identity",
    "sign_iteration",
    "simulate_algorithm2",
    "trace",
    "unshard_bsm",
    "validate_l",
    "volume_ratio_os1_over_osl",
]
