"""Panel transport layer: how A/B panels move between devices.

Every engine used to inline its communication — ``lax.ppermute`` of the
full (blocks, mask, norms) triple in the ring/pull bodies, fused
``all_gather`` in the gather engine — so bytes-on-wire were independent
of occupancy and strictly serialized with the local GEMM.  This module
extracts that stage into one shared abstraction with two jointly-designed
capabilities (DESIGN.md §3):

**Occupancy-compressed panels** (``mode="compressed"``).  Before a panel
is shifted or pulled, only its *occupied* blocks are packed into a
bounded-capacity buffer plus a one-based index array::

    packed : (capacity, bs_r, bs_c)   occupied blocks, padding zeroed
    idx1   : (capacity,) int32        flat position + 1; 0 = padding

and unpacked (scatter into a zero panel, mask rebuilt from the indices)
on arrival, so wire bytes scale with block occupancy instead of dense
panel size — the sparsity-aware communication of Hong et al.
(arXiv:2408.14558) rendered on the static-shape collectives TPUs have.
The one-based encoding makes the format *partial-permutation safe*:
devices a ``ppermute`` does not address receive zeros, and an all-zero
``idx1`` decodes as an empty panel, never as block (0, 0).

Capacity is derived soundly per device from the concrete sparsity
pattern by the plan layer (``plan.get_transport`` — the transport
analogue of PR 2's distributed stack bounds): the bucketed maximum
occupied-block count over every panel the schedule ships.  A capacity
that covers every panel makes compressed transport *bit-exact* vs dense:
the same blocks arrive, the mask is reconstructed exactly, and norms are
recomputed from the identical block data (see below).

**Norm-free wire format** (both modes).  Per-block norms are only
consumed by the on-the-fly threshold filter, and they are a pure
function of the blocks (``bsm.block_norms``, f32), so shipping them with
every hop was redundant traffic.  Neither mode moves norms any more:
``panel_norms`` recomputes them from the received blocks at compute time
(bit-identical — same op, same data), or skips the work entirely when
``threshold == 0``.

**Double-buffered pipelining.**  The engines' tick loops are
restructured (in ``cannon.py``/``twofive.py``, using these helpers) so
the permute feeding tick t+1 is *issued before* the GEMM of tick t: the
GEMM never depends on a collective issued in its own step, which lets
XLA overlap communication with compute the way the paper's non-blocking
``mpi_rget`` does (§4).  The cost is one extra in-flight panel set — the
paper's double buffering, already counted by the Eq. (6) buffer model.

``mode="dense"`` keeps the original bit-exact full-panel permutes (minus
the norms) and is chosen automatically when fill is high; the mode and
capacities join the compiled-program cache key in ``plan.get_compiled``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

MODES = ("dense", "compressed")

# wire element formats: "native" ships blocks at their storage dtype
# (bf16-stored matrices therefore already halve wire bytes — losslessly);
# a reduced wire on wider storage ("bfloat16", optionally "float8_e4m3fn"
# where the platform has it) is a LOSSY opt-in: blocks are rounded at the
# sender and widened back at the receiver, so it never rides the auto
# path — callers choose it explicitly (and the tuner never enumerates it,
# keeping its correctness guards exact).
WIRES = ("native", "bfloat16", "float8_e4m3fn")

# bucketed-capacity fill above which auto transport keeps dense panels:
# the packed hop ships capacity * (block + 4B index) — once the bucketed
# capacity approaches the panel's block count the index overhead and the
# pack/unpack scatter stop paying for the byte saving (and iteration
# loops whose fill-in climbs through the crossover would churn program
# keys; see plan.get_transport).
AUTO_COMPRESS_MAX_FILL = 0.25

# smallest compressed buffer: collectives over zero-length arrays are
# not worth lowering, and tiny buckets churn program keys (kernels/
# stacks.bucket_capacity uses the same floor for product lists)
MIN_CAPACITY = 8


@dataclass(frozen=True)
class PanelTransport:
    """Resolved transport of one multiply: mode + per-panel capacities.

    ``cap_a`` / ``cap_b`` are the packed-buffer capacities (occupied
    blocks) of one shipped A / B panel — 0 in dense mode.  They are part
    of the compiled-program cache key: a pattern whose bucketed bounds
    change compiles a new program, exactly like the stack-capacity
    buckets of the compacted local backends.

    ``wire`` selects the wire element format (see ``WIRES``): "native"
    (the default) ships at storage width; a narrower wire casts blocks
    down before the hop and back up on arrival (lossy on wider storage,
    a no-op on matching storage).
    """

    mode: str = "dense"
    cap_a: int = 0
    cap_b: int = 0
    wire: str = "native"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown transport mode {self.mode!r}; "
                             f"one of {MODES}")
        if self.mode == "compressed" and min(self.cap_a, self.cap_b) <= 0:
            raise ValueError(
                "compressed transport needs positive panel capacities "
                f"(got cap_a={self.cap_a}, cap_b={self.cap_b})"
            )
        if self.wire not in WIRES:
            raise ValueError(f"unknown wire format {self.wire!r}; "
                             f"one of {WIRES}")

    @property
    def compressed(self) -> bool:
        return self.mode == "compressed"

    @property
    def wire_dtype(self):
        """jnp dtype blocks are cast to on the wire; None = storage."""
        return None if self.wire == "native" else jnp.dtype(self.wire)

    def wire_itemsize(self, storage_itemsize: float) -> float:
        """Bytes per block element on the wire (what the volume model
        charges): the storage width under a native wire, the reduced
        width otherwise."""
        wd = self.wire_dtype
        return storage_itemsize if wd is None else float(wd.itemsize)

    @property
    def key(self) -> tuple:
        """Program-cache key contribution.  The wire element is appended
        ONLY when non-native, so pre-wire cache keys (and every test /
        record that pins them) keep their 3-element shape."""
        base = (self.mode, self.cap_a, self.cap_b)
        return base if self.wire == "native" else base + (self.wire,)


DENSE = PanelTransport()


# ---------------------------------------------------------------------------
# packing format
# ---------------------------------------------------------------------------


def pack_panel(blocks: jax.Array, mask: jax.Array, capacity: int):
    """Pack a (nr, nc, bs_r, bs_c) panel into its wire form.

    Returns ``(packed, idx1)`` — occupied blocks gathered into a
    ``(capacity, bs_r, bs_c)`` buffer (padding zeroed) and the one-based
    flat positions (0 = padding).  ``capacity`` must bound the occupied
    count or the excess is silently dropped; the plan layer's
    ``get_transport`` derives sound bounds, and the property tests
    (tests/test_transport.py) pin the roundtrip exactness.
    """
    nr, nc = mask.shape
    flat = jnp.flatnonzero(
        mask.ravel(), size=capacity, fill_value=-1
    ).astype(jnp.int32)
    valid = flat >= 0
    safe = jnp.where(valid, flat, 0)
    packed = blocks.reshape((nr * nc,) + blocks.shape[2:])[safe]
    packed = jnp.where(
        valid[:, None, None], packed, jnp.zeros((), blocks.dtype)
    )
    return packed, (flat + 1) * valid.astype(jnp.int32)


def unpack_panel(packed: jax.Array, idx1: jax.Array, nr: int, nc: int):
    """Inverse of :func:`pack_panel`: scatter the wire form back into a
    dense ``(nr, nc, bs_r, bs_c)`` panel + its boolean mask.

    Safe on partial-permute output: an unaddressed receiver holds zeros,
    which decode as an empty panel (``idx1 == 0`` is padding).
    """
    valid = idx1 > 0
    safe = jnp.where(valid, idx1 - 1, 0)
    guarded = packed * valid[:, None, None].astype(packed.dtype)
    flatb = jnp.zeros((nr * nc,) + packed.shape[1:], packed.dtype)
    flatb = flatb.at[safe].add(guarded)
    mask = jnp.zeros((nr * nc,), bool).at[safe].max(valid)
    return flatb.reshape((nr, nc) + packed.shape[1:]), mask.reshape(nr, nc)


def panel_norms(blocks: jax.Array, threshold: float) -> jax.Array:
    """Per-block norms of a received panel, for the on-the-fly filter.

    Norms are no longer transported: with ``threshold > 0`` they are
    recomputed from the (exactly transported) blocks — bit-identical to
    home norms that came from ``block_norms`` (same op, same data) —
    and with ``threshold == 0`` the filter never reads them, so a zero
    placeholder skips the reduction entirely.

    Caveat: PR 3's derived-norm algebra (``scale`` stores
    ``norms * |s|``) can differ from ``block_norms(blocks * s)`` in the
    final f32 ULPs, so a block product whose norm product lies *exactly*
    on the threshold boundary could filter differently than the
    stored-norm oracle — the measure-zero ambiguity every
    threshold-filter implementation has across backends (DBCSR's GPU vs
    LIBXSMM paths included); away from the boundary the decisions agree
    exactly.
    """
    if threshold > 0.0:
        from repro.core.bsm import block_norms

        return block_norms(blocks)
    return jnp.zeros(blocks.shape[:2], jnp.float32)


# ---------------------------------------------------------------------------
# panel streams (what the engine bodies carry through their tick loops)
# ---------------------------------------------------------------------------


def _to_wire(tr: PanelTransport, blocks: jax.Array) -> jax.Array:
    """Cast blocks to the wire element format (no-op for native)."""
    wd = tr.wire_dtype
    return blocks if wd is None or blocks.dtype == wd else blocks.astype(wd)


def ingest(tr: PanelTransport, capacity: int, blocks, mask):
    """Panel state entering an engine body: packed pair or (blocks, mask),
    blocks cast down to the wire dtype when one is selected."""
    if tr.compressed:
        packed, idx1 = pack_panel(blocks, mask, capacity)
        return (_to_wire(tr, packed), idx1)
    return (_to_wire(tr, blocks), mask)


def permute(state, axes, pairs):
    """One transport hop: permute both wire arrays (mode-independent —
    dense state is (blocks, mask), compressed is (packed, idx1))."""
    return tuple(lax.ppermute(x, axes, list(pairs)) for x in state)


def dense_view(tr: PanelTransport, state, nr: int, nc: int, dtype=None):
    """(blocks, mask) view of a panel state for the local GEMM.

    ``dtype`` — the compute/storage dtype to widen wire-cast blocks back
    to (engine bodies pass their operand dtype); None leaves blocks at
    whatever width they arrived."""
    if tr.compressed:
        blocks, mask = unpack_panel(state[0], state[1], nr, nc)
    else:
        blocks, mask = state
    if dtype is not None and blocks.dtype != jnp.dtype(dtype):
        blocks = blocks.astype(dtype)
    return blocks, mask


def all_gather_panels(
    tr: PanelTransport, capacity: int, blocks, mask, axis_name: str,
    axis: int,
):
    """The gather engine's fused pull-from-home, transport-aware.

    Dense: tiled all-gather of blocks + mask (the original schedule,
    minus the norms).  Compressed: all-gather of each home shard's packed
    buffer + indices, then one scatter rebuilding the concatenated
    row/column panel — still a single fused collective pair, but the
    gathered bytes scale with occupancy.
    """
    dtype = blocks.dtype  # widen wire-cast blocks back after the gather
    if not tr.compressed:
        gb = lax.all_gather(
            _to_wire(tr, blocks), axis_name, axis=axis, tiled=True
        )
        gm = lax.all_gather(mask, axis_name, axis=axis, tiled=True)
        return gb.astype(dtype), gm
    nr, nc = mask.shape
    packed, idx1 = pack_panel(blocks, mask, capacity)
    packed = _to_wire(tr, packed)
    ps = lax.all_gather(packed, axis_name, axis=0, tiled=False)
    ix = lax.all_gather(idx1, axis_name, axis=0, tiled=False)
    p = ps.shape[0]
    valid = ix > 0
    loc = jnp.where(valid, ix - 1, 0)
    r, c = loc // nc, loc % nc
    src = jnp.arange(p, dtype=jnp.int32)[:, None]
    if axis == 1:  # A row panel: source s owns columns [s*nc, (s+1)*nc)
        gf = r * (p * nc) + src * nc + c
        out_r, out_c = nr, p * nc
    elif axis == 0:  # B column panel: source s owns rows [s*nr, (s+1)*nr)
        gf = (src * nr + r) * nc + c
        out_r, out_c = p * nr, nc
    else:
        raise ValueError(f"gather axis must be 0 or 1, got {axis}")
    guarded = ps * valid[..., None, None].astype(ps.dtype)
    flatb = jnp.zeros((out_r * out_c,) + ps.shape[2:], ps.dtype)
    flatb = flatb.at[gf.ravel()].add(
        guarded.reshape((-1,) + ps.shape[2:])
    )
    gm = jnp.zeros((out_r * out_c,), bool).at[gf.ravel()].max(valid.ravel())
    out = flatb.reshape((out_r, out_c) + ps.shape[2:]).astype(dtype)
    return out, gm.reshape(out_r, out_c)


# ---------------------------------------------------------------------------
# capacity bounds (host-side, numpy — the transport analogue of
# plan.device_stack_bound)
# ---------------------------------------------------------------------------


def panel_nnz_bound(mask, row_parts: int, col_parts: int) -> int:
    """Max occupied-block count over a (row_parts x col_parts) partition
    of ``mask`` — the sound capacity for a schedule that ships those
    partitions as panels.  Pure numpy; hypothesis-tested for soundness
    against every partition cell (tests/test_transport.py)."""
    m = np.asarray(mask, bool)
    nb_r, nb_c = m.shape
    if nb_r % row_parts or nb_c % col_parts:
        raise ValueError(
            f"mask {m.shape} does not divide a {row_parts}x{col_parts} "
            "panel partition"
        )
    hr, hc = nb_r // row_parts, nb_c // col_parts
    counts = m.reshape(row_parts, hr, col_parts, hc).sum(axis=(1, 3))
    return int(counts.max()) if counts.size else 0


def plan_panel_parts(plan) -> tuple[tuple[int, int], tuple[int, int]]:
    """(row_parts, col_parts) of the A and B panels a plan ships.

    Ring / stacked / gather schedules move whole 2D home shards; the pull
    formulation moves virtual-grid subpanels — ``ca`` column slices of an
    A shard, ``cb`` row slices of a B shard (DESIGN.md §3).
    """
    if plan.kind == "pull":
        return ((plan.p_r, plan.p_c * plan.ca),
                (plan.p_r * plan.cb, plan.p_c))
    return ((plan.p_r, plan.p_c), (plan.p_r, plan.p_c))


def bucket(n: int) -> int:
    """Power-of-two capacity bucket with the transport floor."""
    from repro.kernels.stacks import bucket_capacity

    return max(MIN_CAPACITY, bucket_capacity(n))


def capacities_for(mask_a, mask_b, plan) -> tuple[int, int, int, int]:
    """Bucketed per-panel packing capacities + panel block counts of one
    (operand-mask pair, plan): ``(cap_a, cap_b, blocks_a, blocks_b)``.

    The single derivation point behind ``plan.get_transport`` — monotone
    in the masks, so capacities derived from a pattern *envelope* (the
    union of every mask a chain can ship, ``core/envelope.py``) soundly
    cover every concrete per-sweep panel."""
    am = np.asarray(mask_a, bool)
    bm = np.asarray(mask_b, bool)
    (ar, ac), (br, bc) = plan_panel_parts(plan)
    cap_a = bucket(panel_nnz_bound(am, ar, ac))
    cap_b = bucket(panel_nnz_bound(bm, br, bc))
    blocks_a = (am.shape[0] // ar) * (am.shape[1] // ac)
    blocks_b = (bm.shape[0] // br) * (bm.shape[1] // bc)
    return cap_a, cap_b, blocks_a, blocks_b


def resolve_mode(
    mode: str, cap_a: int, cap_b: int, blocks_a: int, blocks_b: int
) -> str:
    """``auto`` policy: compress only while the bucketed capacities stay
    well under the panel block counts (crossover ``AUTO_COMPRESS_MAX_FILL``
    — past it the index overhead and scatter cost eat the byte saving,
    and evolving patterns would flap across the boundary)."""
    if mode != "auto":
        return mode
    fill_a = cap_a / max(blocks_a, 1)
    fill_b = cap_b / max(blocks_b, 1)
    if max(fill_a, fill_b) <= AUTO_COMPRESS_MAX_FILL:
        return "compressed"
    return "dense"
