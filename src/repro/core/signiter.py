"""Matrix-sign iteration — the paper's driving application (linear-scaling
DFT density-matrix purification, Eqs. (1)-(3)).

    sign(A) = A (A^2)^{-1/2};   X_{n+1} = 1/2 X_n (3 I - X_n^2)

Each iteration is two block-sparse multiplications with on-the-fly and
post-multiplication filtering — exactly the workload DBCSR is built for
(SpGEMM > 80% of CP2K linear-scaling runtime).

Two execution modes (DESIGN.md §5):

``fused`` (default) — the device-resident iteration engine.  The operands
    are sharded ONCE at the chain boundary (``bsm.shard_bsm``) and the whole
    Newton-Schulz sweep — X², post-filter, 3I − X², X·Y, post-filter, the
    0.5 scale, residual and occupancy — compiles into ONE cached program per
    (mesh, shape, engine, backend, thresholds), fetched through
    ``plan.get_chain_compiled``.  Matrices, norms and the convergence
    residual stay on the mesh between sweeps; the host syncs the residual
    only every ``sync_every`` sweeps.  This is the paper's "never
    redistribute" design applied across a *chain* of multiplies: DBCSR
    keeps matrices home-resident for the whole purification (Lazzaro &
    Hutter 2017; arXiv:1910.13555).

``legacy`` — the original host-driven loop: each sweep re-enters
    ``multiply()`` from replicated arrays (re-shard A/B, gather C), runs the
    inter-multiply algebra as separate dispatches, and syncs the residual
    every sweep.  Kept as the parity oracle and the benchmark baseline
    (``benchmarks/bench_signiter.py`` measures the dispatch-overhead gap).

``density_matrix`` then evaluates P = 1/2 (I - sign(mu I - H)) — the
simplified (S = I, orthonormal basis) form of paper Eq. (1); the eigenvalue
counting identity trace(P) = #{eigenvalues < mu} is used as the convergence
observable in tests and examples.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core import bsm as B
from repro.core import plan as plan_mod
from repro.core.bsm import block_norms
from repro.core.engine import multiply
from repro.core.local_mm import local_filtered_mm


@dataclass
class SignIterStats:
    iterations: int
    converged: bool
    residual: float
    occupancy_trace: list[float]
    multiplications: int
    residual_trace: list[float] = field(default_factory=list)
    mode: str = "legacy"
    sync_every: int = 1
    host_syncs: int = 0  # device->host residual syncs (fused: ~it/sync_every)
    retraces: int = 0  # program (re)builds this chain triggered: fused =
    #   chain_misses delta (1 = whole chain ran one program), legacy =
    #   per-multiply program misses delta
    envelope: bool = False  # chain ran against a forecast pattern envelope


def _scale_any(x, s):
    """s * x for either matrix container (derived norms, no recompute)."""
    return x.scale(s) if isinstance(x, B.ShardedBSM) else B.scale(x, s)


def _resolve_engine(x, mesh, engine: str, threshold: float,
                    l: int | None, envelope=None) -> tuple[str, int | None]:
    """``engine="auto"`` for an iteration: ONE tuner resolution on the
    initial pattern (X ~ X0 . X0, the purification's own multiply shape),
    then every sweep of the chain runs the chosen (engine, L).

    Chains are tuned with ``chain=True``: without an envelope only
    chain-safe candidates (dense local backend, dense transport) are
    considered, because the fused sweep is traced once while the
    sparsity pattern evolves underneath it — see
    ``tuner.model.chain_safe``.  With ``envelope`` the capacities come
    from the forecast union cube, which covers every sweep's pattern, so
    the tuner ranks the full candidate space.
    """
    if engine != "auto":
        return engine, l
    if mesh is None:
        return "twofive", l  # single-device: the engine is vestigial
    from repro import tuner

    dec = tuner.autotune(x, x, mesh, threshold=threshold, l=l, chain=True,
                         envelope=envelope)
    return dec.engine, dec.l


def _scale_to_unit_spectrum(x):
    """Scale X0 so its spectrum lies in [-1, 1] (Frobenius bound)."""
    nrm = x.frobenius_norm()
    return _scale_any(x, 1.0 / jnp.maximum(nrm, 1e-30))


# ---------------------------------------------------------------------------
# the fused device-resident sweep
# ---------------------------------------------------------------------------


def _make_sweep(mm, dtype, filter_eps: float, *, total_blocks: int,
                psum_axes=None):
    """One whole Newton-Schulz sweep as a single traceable function.

    ``mm(ab, am, an, bb, bm, bn) -> (cb, cm)`` is the multiply body — the
    engine's raw per-shard body (``plan.build_shard_body``) when the sweep
    runs inside one enclosing shard_map, or ``local_filtered_mm`` on a
    single device.  Everything between the two multiplies is shard-local
    algebra with incrementally-updated norms; the residual and occupancy
    leave as device scalars via ``psum_axes`` all-reduces — never a gather
    of the matrix.
    """
    eps = float(filter_eps)

    def post_filter(cb, cm, cn):
        if eps <= 0.0:
            return cb, cm, cn
        keep = cm & (cn > eps)
        return (
            cb * keep[:, :, None, None].astype(cb.dtype),
            keep,
            jnp.where(keep, cn, 0.0),
        )

    def sweep(xb, xm, xn, ib, im):
        # X^2 (multiply 1) + post-filter, mirroring multiply(filter_eps=...)
        x2b, x2m = mm(xb, xm, xn, xb, xm, xn)
        x2n = block_norms(x2b)
        x2b, x2m, x2n = post_filter(x2b, x2m, x2n)
        # Y = 3I - X^2: elementwise on the shards, norms from the new blocks
        yb = ib * jnp.asarray(3.0, dtype) - x2b
        ym = im | x2m
        yn = block_norms(yb)
        # X . Y (multiply 2) + post-filter + the 1/2 scale (derived norms)
        cb, cm = mm(xb, xm, xn, yb, ym, yn)
        cn = block_norms(cb)
        cb, cm, cn = post_filter(cb, cm, cn)
        cb = cb * jnp.asarray(0.5, dtype)
        cn = cn * jnp.float32(0.5)
        # convergence: || X_{n+1} - X_n ||_F / || X_{n+1} ||_F — partial
        # sums per shard, all three scalars in ONE stacked all-reduce
        diff = (cb - xb).astype(jnp.float32)
        partials = jnp.stack([
            jnp.sum(jnp.square(diff)),
            jnp.sum(jnp.square(cn)),
            jnp.sum(cm.astype(jnp.float32)),
        ])
        if psum_axes is not None:
            partials = jax.lax.psum(partials, psum_axes)
        num_sq, den_sq, occ_cnt = partials
        residual = jnp.sqrt(num_sq) / jnp.maximum(jnp.sqrt(den_sq), 1e-30)
        occupancy = occ_cnt / total_blocks
        return cb, cm, cn, residual, occupancy

    return sweep


def _sweep_key(mesh, engine, nb_r, nb_c, bs_r, bs_c, dtype, threshold,
               filter_eps, backend, l, stack_capacity, tile, interpret,
               transport=None):
    key = (
        "signiter", mesh, engine, nb_r, nb_c, bs_r, bs_c,
        jnp.dtype(dtype).name, float(threshold), float(filter_eps),
        backend, l, stack_capacity, tile, interpret,
    )
    # appended ONLY for non-dense transport so pre-envelope chain keys
    # (and everything that pins them) keep their original shape
    if transport is not None and transport.mode != "dense":
        key = key + (transport.key,)
    return key


def get_sweep_program(
    x,
    mesh,
    *,
    engine: str,
    threshold: float,
    filter_eps: float,
    backend: str,
    l: int | None = None,
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    envelope=None,
    transport=None,
):
    """The compiled fused sweep for (mesh, shape, engine, backend, ...),
    cached in the plan layer's program cache (``plan.get_chain_compiled``,
    counted by ``chain_hits``/``chain_misses``).

    ``mesh=None`` builds the single-device sweep around
    ``local_filtered_mm``.  Otherwise the WHOLE sweep is one shard_map
    around the engine's raw per-shard body (``plan.build_shard_body``):
    both multiplies, the inter-multiply algebra and the residual partials
    run per-shard with no re-partitioning between them, so one sweep is
    one dispatch of one SPMD program — and one program build per distinct
    multiply shape, shared by both multiplies.

    ``envelope`` (a ``core.envelope.Envelope``) lifts the chain-safety
    pins: ``backend="auto"`` resolves against the envelope's union cube
    through the analytic cost model, a ``None`` ``stack_capacity`` takes
    the envelope's (bucketed) capacity, and non-dense ``transport``
    resolves its per-panel capacities from the envelope's operand-mask
    unions — all sound for every pattern the envelope covers, so the
    chain still compiles exactly once.  Without an envelope the historic
    pins stand: "auto" degrades to "jnp" and non-dense transport raises.
    """
    if engine == "auto":
        raise ValueError(
            "resolve engine='auto' before building a chain program "
            "(sign_iteration does this via the tuner); the chain key "
            "must carry a concrete engine"
        )
    from repro.core import transport as T
    if envelope is not None:
        if backend == "auto":
            # the envelope's union cube is the chain-wide fill bound:
            # feed it through the same analytic crossover the tuner uses
            from repro.tuner.model import choose_local_backend

            backend = choose_local_backend(
                x.nb_r, x.nb_c, x.nb_c, x.bs_r, x.bs_c, x.bs_c,
                fill=float(envelope.cube.mean()),
            )
        if stack_capacity is None and backend in ("stacks", "pallas"):
            stack_capacity = (
                envelope.local_capacity() if mesh is None
                else envelope.device_capacity(mesh, engine)
            )
        if mesh is not None and not isinstance(transport, T.PanelTransport):
            mode = transport
            if mode is None or mode == "dense":
                transport = None  # dense inside build_shard_body
            elif mode in ("auto", "compressed"):
                transport = envelope.transport(mesh, engine, l, mode)
            else:
                raise ValueError(
                    f"unknown transport {mode!r}; a PanelTransport or "
                    "one of auto | dense | compressed"
                )
    else:
        if backend == "auto":
            # auto walks the concrete pattern on the host; inside the
            # fused (traced) sweep there is no concrete pattern — dense
            # einsum it is
            backend = "jnp"
        # without an envelope the panel transport is pinned dense for the
        # same reason: the sweep is traced once while the sparsity
        # pattern evolves underneath it, so a compressed capacity derived
        # from the initial pattern would silently drop fill-in blocks
        # mid-iteration (chain safety — tuner.model.chain_safe).  Dense
        # transport still gets the norm-free wire format and the
        # double-buffered pipelining from the shared layer.
        if transport is not None and not (
            isinstance(transport, T.PanelTransport)
            and transport.mode == "dense"
        ) and transport != "dense":
            raise ValueError(
                "non-dense chain transport needs an envelope: a static "
                "packing capacity derived from the initial pattern would "
                "silently drop fill-in panels mid-iteration "
                "(core/envelope.py)"
            )
        transport = None
    if backend == "pallas" and interpret is None:
        from repro.kernels.ops import _default_interpret

        interpret = _default_interpret()
    key = _sweep_key(mesh, engine, x.nb_r, x.nb_c, x.bs_r, x.bs_c, x.dtype,
                     threshold, filter_eps, backend, l, stack_capacity,
                     tile, interpret, transport)
    mm_kw = dict(threshold=threshold, backend=backend,
                 stack_capacity=stack_capacity, tile=tile,
                 interpret=interpret, transport=transport)
    total_blocks = x.nb_r * x.nb_c

    def builder():
        if mesh is None:
            local_kw = {k: v for k, v in mm_kw.items() if k != "transport"}

            def mm(*args):
                return local_filtered_mm(*args, **local_kw)

            return jax.jit(_make_sweep(mm, x.dtype, filter_eps,
                                       total_blocks=total_blocks))
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        plan = plan_mod.plan_multiply(mesh, engine, l)
        plan.validate_blocks(x.nb_r, x.nb_c)
        # transport=None -> dense inside build_shard_body (chain-safe);
        # an envelope-resolved PanelTransport rides through untouched
        mm = plan_mod.build_shard_body(plan, **mm_kw)
        sweep = _make_sweep(mm, x.dtype, filter_eps,
                            total_blocks=total_blocks, psum_axes=("r", "c"))
        blk = P("r", "c", None, None)
        m2 = P("r", "c")
        fn = shard_map(
            sweep,
            mesh=mesh,
            # check_vma=False for the same reason as the engine executors
            # (oracle-tested outputs; pallas bodies carry no vma)
            check_vma=False,
            in_specs=(blk, m2, m2, blk, m2),
            out_specs=(blk, m2, m2, P(), P()),
        )
        return jax.jit(fn)

    return plan_mod.get_chain_compiled(key, builder)


class _ChainShape:
    """Abstract operand of a chain program: just the key fields of
    ``get_sweep_program``, no block data."""

    def __init__(self, nb: int, bs, dtype):
        self.nb_r = self.nb_c = nb
        self.bs_r, self.bs_c = B._block_shape(bs)
        self.dtype = jnp.dtype(dtype)


def lower_sweep(
    mesh,
    nb: int,
    bs: int,
    *,
    engine: str = "twofive",
    threshold: float = 0.0,
    filter_eps: float = 0.0,
    backend: str = "jnp",
    dtype=jnp.float32,
    l: int | None = None,
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
):
    """Lower (without executing) one fused sweep for HLO inspection — the
    proof that a sweep performs no global gather: X enters and leaves in
    the 2D home layout, so the only collectives are the engine's panel
    moves and the scalar residual/occupancy all-reduces."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    shape = _ChainShape(nb, bs, dtype)
    fn = get_sweep_program(shape, mesh, engine=engine, threshold=threshold,
                           filter_eps=filter_eps, backend=backend, l=l,
                           stack_capacity=stack_capacity, tile=tile,
                           interpret=interpret)
    bs_r, bs_c = shape.bs_r, shape.bs_c
    if mesh is None:
        blk = jax.ShapeDtypeStruct((nb, nb, bs_r, bs_c), dtype)
        m2b = jax.ShapeDtypeStruct((nb, nb), jnp.bool_)
        m2f = jax.ShapeDtypeStruct((nb, nb), jnp.float32)
    else:
        s_blk = NamedSharding(mesh, P("r", "c", None, None))
        s_m2 = NamedSharding(mesh, P("r", "c"))
        blk = jax.ShapeDtypeStruct((nb, nb, bs_r, bs_c), dtype, sharding=s_blk)
        m2b = jax.ShapeDtypeStruct((nb, nb), jnp.bool_, sharding=s_m2)
        m2f = jax.ShapeDtypeStruct((nb, nb), jnp.float32, sharding=s_m2)
    return fn.lower(blk, m2b, m2f, blk, m2b)


# ---------------------------------------------------------------------------
# iteration drivers
# ---------------------------------------------------------------------------


def sign_iteration_legacy(
    x0: B.BlockSparseMatrix,
    *,
    mesh=None,
    engine: str = "twofive",
    threshold: float = 0.0,
    filter_eps: float = 0.0,
    max_iter: int = 50,
    tol: float = 1e-6,
    scale_input: bool = True,
    backend: str = "jnp",
    l: int | None = None,
    storage_dtype=None,
    tile: tuple[int, int, int] | None = None,
    assignment=None,
) -> tuple[B.BlockSparseMatrix, SignIterStats]:
    """The host-driven per-op loop (parity oracle / benchmark baseline):
    two ``multiply()`` re-entries per sweep from replicated arrays, eager
    inter-multiply algebra, a host residual sync every sweep.  With a
    compacted ``backend`` every multiply walks X's concrete pattern — the
    pattern cache (``plan.cache_stats()['pattern_hits']``) re-hits as the
    iteration's sparsity structure stabilizes.  ``engine="auto"`` is
    resolved ONCE on the initial pattern (not per multiply): the tuner
    decision holds for the whole iteration.  ``assignment`` is threaded to
    every multiply (results come back in original block coordinates, so
    the inter-multiply algebra is layout-oblivious)."""
    engine, l = _resolve_engine(x0, mesh, engine, threshold, l)
    nb, bs = x0.nb_r, x0.bs_r
    ident = B.identity(nb, bs, x0.dtype)
    x = _scale_to_unit_spectrum(x0) if scale_input else x0
    if storage_dtype is not None:
        # reduced-precision block storage: cast AFTER the spectral scale
        # (the scale is a global scalar — quantize the scaled operand) and
        # recalibrate norms from the quantized blocks (bsm.astype) so the
        # on-the-fly filter sees the norms of what actually multiplies
        x = B.cast_bsm(x, storage_dtype)
        ident = B.cast_bsm(ident, storage_dtype)
    occ, res_trace = [], []
    n_mults = 0
    converged = False
    residual = float("inf")
    misses0 = plan_mod.cache_stats()["misses"]
    it = 0
    for it in range(1, max_iter + 1):
        x2 = multiply(
            x, x, mesh, engine=engine, threshold=threshold,
            filter_eps=filter_eps, backend=backend, l=l, tile=tile,
            assignment=assignment,
        )
        n_mults += 1
        # 3I - X^2
        y = B.add(B.scale(x2, -1.0), B.scale(ident, 3.0))
        xn = multiply(
            x, y, mesh, engine=engine, threshold=threshold,
            filter_eps=filter_eps, backend=backend, l=l, tile=tile,
            assignment=assignment,
        )
        xn = B.scale(xn, 0.5)
        n_mults += 1
        # convergence: || X_{n+1} - X_n ||_F / || X_n ||_F
        diff = B.add(xn, B.scale(x, -1.0))
        residual = float(diff.frobenius_norm() / jnp.maximum(xn.frobenius_norm(), 1e-30))
        res_trace.append(residual)
        occ.append(float(xn.occupancy()))
        x = xn
        if residual < tol:
            converged = True
            break
    stats = SignIterStats(
        iterations=it,
        converged=converged,
        residual=residual,
        occupancy_trace=occ,
        multiplications=n_mults,
        residual_trace=res_trace,
        mode="legacy",
        sync_every=1,
        host_syncs=it,
        retraces=plan_mod.cache_stats()["misses"] - misses0,
    )
    return x, stats


def sign_iteration(
    x0: B.BlockSparseMatrix | B.ShardedBSM,
    *,
    mesh=None,
    engine: str = "twofive",
    threshold: float = 0.0,
    filter_eps: float = 0.0,
    max_iter: int = 50,
    tol: float = 1e-6,
    scale_input: bool = True,
    mode: str = "fused",
    sync_every: int = 1,
    backend: str = "jnp",
    l: int | None = None,
    stack_capacity: int | None = None,
    storage_dtype=None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    assignment=None,
    envelope=None,
    transport=None,
) -> tuple[B.BlockSparseMatrix | B.ShardedBSM, SignIterStats]:
    """Newton-Schulz iteration X <- 1/2 X (3I - X^2) to sign(x0).

    mode       — "fused" (device-resident sweep, default) or "legacy"
                 (per-op host loop; parity oracle).
    sync_every — fused only: host-sync the device-resident residual every
                 k sweeps instead of every multiply.  With k > 1 the loop
                 may run up to k-1 sweeps past convergence (the sign fixed
                 point is stable, so extra sweeps only polish); residual
                 and occupancy traces stay complete either way.
    backend    — local stage for the fused sweep ("auto" degrades to
                 "jnp": the sweep is traced, there is no concrete pattern
                 to compact; "stacks"/"pallas" take ``stack_capacity`` as
                 their static product bound, full cube when omitted).
    storage_dtype — reduced-precision block storage for the whole chain
                 (e.g. ``jnp.bfloat16``): X and I are quantized ONCE at
                 the chain boundary (after the spectral scale) with norms
                 recalibrated from the quantized blocks (``bsm.astype``),
                 every multiply accumulates in f32 on the MXU, and panels
                 ride the wire at storage width — half the f32 bytes for
                 bf16.  Residual/occupancy stay f32.  Expect the bf16
                 fixed point within ~3e-2 of the f32 oracle elementwise
                 (``kernels.ref`` documents the tolerance model).
    tile       — MXU tile override (tm, tk, tn) for the pallas backend
                 (None = ``kernels.block_spgemm.default_tile``).
    envelope   — fused only: compile the chain against a forecast
                 pattern envelope (DESIGN.md §7).  ``"auto"`` (or
                 ``True``) forecasts it here from the finalized operand
                 via ``plan.get_envelope`` (``sweeps=max_iter``); a
                 ready ``core.envelope.Envelope`` is used as-is.  The
                 envelope lifts the chain-safety pins: ``backend="auto"``
                 resolves through the cost model against the union cube,
                 compacted backends take the envelope's capacity bound,
                 and non-dense ``transport`` becomes available — while
                 the whole drifting-pattern chain still compiles ONCE
                 (``stats.retraces == 1`` cold, 0 warm).
    transport  — fused only: panel-transport mode for the sweep's
                 multiplies ("auto" | "dense" | "compressed" or a ready
                 ``PanelTransport``).  Non-dense modes require
                 ``envelope`` (chain safety — see ``get_sweep_program``).
    assignment — block→device distribution for the WHOLE chain: resolved
                 ONCE at the shard boundary (None / a mode string / a
                 ``distribute.Assignment`` — see ``bsm.shard_bsm``).  The
                 Newton-Schulz fixed point is layout-equivariant
                 (sign(P X Pᵀ) = P sign(X) Pᵀ and P I Pᵀ = I), so every
                 sweep runs in the one permuted home layout with no
                 re-distribution; ``unshard`` at the exit boundary (or the
                 carried ``ShardedBSM.assignment``) restores original
                 block coordinates.

    A ShardedBSM ``x0`` stays sharded end-to-end (under its own carried
    assignment — passing a conflicting ``assignment`` raises) and the
    result is a ShardedBSM; a BlockSparseMatrix with ``mesh`` given is
    sharded once at entry and gathered once at exit (the chain
    boundaries).
    """
    if mode == "legacy":
        if isinstance(x0, B.ShardedBSM):
            raise TypeError("legacy mode operates on replicated matrices; "
                            "unshard first (bsm.unshard_bsm)")
        if envelope is not None or transport is not None:
            raise ValueError(
                "envelope/transport are fused-chain controls; the legacy "
                "loop re-enters multiply() per pattern (pass them to "
                "multiply directly if needed)"
            )
        return sign_iteration_legacy(
            x0, mesh=mesh, engine=engine, threshold=threshold,
            filter_eps=filter_eps, max_iter=max_iter, tol=tol,
            scale_input=scale_input, backend=backend, l=l,
            storage_dtype=storage_dtype, tile=tile, assignment=assignment,
        )
    if mode != "fused":
        raise ValueError(f"unknown mode {mode!r}; 'fused' or 'legacy'")
    if sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {sync_every}")

    sharded_in = isinstance(x0, B.ShardedBSM)
    if sharded_in:
        if mesh is not None and mesh is not x0.mesh and mesh != x0.mesh:
            raise ValueError("mesh argument conflicts with operand mesh")
        mesh = x0.mesh
        if assignment is not None and (
            getattr(assignment, "mode", assignment)
            != B._assign_name(x0.assignment)
        ):
            raise ValueError(
                f"operand is sharded under assignment "
                f"{B._assign_name(x0.assignment)}; unshard before "
                f"iterating under a different layout"
            )
    nb, bs = x0.nb_r, x0.bs_r
    ident = B.identity(nb, bs, x0.dtype)
    if mesh is not None:
        # one layout decision for the whole chain, made HERE at the shard
        # boundary; the identity inherits it (P I Pᵀ = I, data unchanged)
        x = x0 if sharded_in else B.shard_bsm(x0, mesh,
                                              assignment=assignment)
        ident = B.shard_bsm(ident, mesh, assignment=x.assignment)
    else:
        if assignment not in (None, "identity"):
            raise ValueError("assignment needs a mesh: a block→device "
                             "distribution has no meaning on one device")
        x = x0
    x = _scale_to_unit_spectrum(x) if scale_input else x
    if storage_dtype is not None:
        # quantize once at the chain boundary, shard-local for ShardedBSM;
        # norms recalibrated from the quantized blocks (bsm.astype)
        x = B.cast_bsm(x, storage_dtype)
        ident = B.cast_bsm(ident, storage_dtype)
    env = envelope
    if env is True or env == "auto":
        # forecast from the FINALIZED operand (post-scale, post-cast, in
        # chain layout): the envelope's norm bounds must dominate the
        # norms the filters actually see.  One host sync of (mask, norms)
        # at the chain boundary; plan.get_envelope memoizes the forecast.
        import numpy as np

        env = plan_mod.get_envelope(
            np.asarray(x.mask, bool), np.asarray(x.norms, np.float32),
            sweeps=max_iter, threshold=threshold, filter_eps=filter_eps,
            bs=x.bs_r,
        )
    # engine resolution sees the finalized operand and the envelope: with
    # one, autotune(chain=True) ranks the full candidate space
    engine, l = _resolve_engine(x, mesh, engine, threshold, l, envelope=env)

    chain_misses0 = plan_mod.cache_stats()["chain_misses"]
    sweep = None
    xb, xm, xn = x.blocks, x.mask, x.norms
    ib, im = ident.blocks, ident.mask
    occ_trace: list[float] = []
    res_trace: list[float] = []
    pending: list[tuple] = []
    converged = False
    syncs = 0
    it = 0
    for it in range(1, max_iter + 1):
        # fetched per sweep: the chain counters in plan.cache_stats() then
        # record how many sweeps of this iteration reused one program
        sweep = get_sweep_program(
            x, mesh, engine=engine, threshold=threshold,
            filter_eps=filter_eps, backend=backend, l=l,
            stack_capacity=stack_capacity, tile=tile, interpret=interpret,
            envelope=env, transport=transport,
        )
        xb, xm, xn, res_d, occ_d = sweep(xb, xm, xn, ib, im)
        pending.append((res_d, occ_d))
        if it % sync_every == 0 or it == max_iter:
            syncs += 1
            for res_d, occ_d in pending:
                r = float(res_d)
                res_trace.append(r)
                occ_trace.append(float(occ_d))
                if r < tol:
                    converged = True
            pending = []
            if converged:
                break

    if mesh is not None:
        out = B.ShardedBSM(blocks=xb, mask=xm, norms=xn, mesh=mesh,
                           assignment=x.assignment)
        result = out if sharded_in else out.unshard()
    else:
        result = B.BlockSparseMatrix(blocks=xb, mask=xm, norms=xn)
    stats = SignIterStats(
        iterations=it,
        converged=converged,
        residual=res_trace[-1] if res_trace else float("inf"),
        occupancy_trace=occ_trace,
        multiplications=2 * it,
        residual_trace=res_trace,
        mode="fused",
        sync_every=sync_every,
        host_syncs=syncs,
        retraces=plan_mod.cache_stats()["chain_misses"] - chain_misses0,
        envelope=env is not None,
    )
    return result, stats


def density_matrix(
    h: B.BlockSparseMatrix | B.ShardedBSM,
    mu: float,
    *,
    mesh=None,
    engine: str = "twofive",
    threshold: float = 0.0,
    filter_eps: float = 0.0,
    max_iter: int = 60,
    tol: float = 1e-6,
    mode: str = "fused",
    sync_every: int = 1,
    backend: str = "jnp",
    storage_dtype=None,
    tile: tuple[int, int, int] | None = None,
    assignment=None,
    envelope=None,
    transport=None,
) -> tuple[B.BlockSparseMatrix | B.ShardedBSM, SignIterStats]:
    """P = 1/2 (I - sign(H - mu I))  (paper Eq. (1) with S = I).

    The shift, sign iteration and projector assembly all run where ``h``
    lives: a ShardedBSM Hamiltonian yields a ShardedBSM density matrix
    with no intermediate gather (derived-norm algebra at both ends).
    ``assignment`` pins one block→device distribution for the whole
    purification (see ``sign_iteration``).
    """
    nb, bs = h.nb_r, h.bs_r
    ident = B.identity(nb, bs, h.dtype)
    if isinstance(h, B.ShardedBSM):
        # the identity joins h's layout (P I Pᵀ = I) so the shift algebra
        # stays shard-local under whatever assignment h was sharded with
        ident = B.shard_bsm(ident, h.mesh, assignment=h.assignment)
        shifted = ident.scale(-mu).add(h)
    else:
        shifted = B.add(h, B.scale(ident, -mu))
    sgn, stats = sign_iteration(
        shifted,
        mesh=mesh,
        engine=engine,
        threshold=threshold,
        filter_eps=filter_eps,
        max_iter=max_iter,
        tol=tol,
        mode=mode,
        sync_every=sync_every,
        backend=backend,
        storage_dtype=storage_dtype,
        tile=tile,
        assignment=assignment,
        envelope=envelope,
        transport=transport,
    )
    if sgn.dtype != ident.dtype:  # projector algebra in storage dtype
        ident = B.cast_bsm(ident, sgn.dtype)
    if isinstance(sgn, B.ShardedBSM):
        p = sgn.scale(-1.0).add(ident).scale(0.5)
    else:
        p = B.scale(B.add(ident, B.scale(sgn, -1.0)), 0.5)
    return p, stats


def trace(m: B.BlockSparseMatrix | B.ShardedBSM) -> jnp.ndarray:
    if isinstance(m, B.ShardedBSM):
        return m.trace()
    diag_blocks = m.blocks[jnp.arange(m.nb_r), jnp.arange(m.nb_c)]
    diag_mask = m.mask[jnp.arange(m.nb_r), jnp.arange(m.nb_c)]
    tr = jnp.trace(diag_blocks, axis1=-2, axis2=-1)
    return jnp.sum(tr * diag_mask)
