"""Matrix-sign iteration — the paper's driving application (linear-scaling
DFT density-matrix purification, Eqs. (1)-(3)).

    sign(A) = A (A^2)^{-1/2};   X_{n+1} = 1/2 X_n (3 I - X_n^2)

Each iteration is two block-sparse multiplications with on-the-fly and
post-multiplication filtering — exactly the workload DBCSR is built for
(SpGEMM > 80% of CP2K linear-scaling runtime).

``density_matrix`` then evaluates P = 1/2 (I - sign(mu I - H)) — the
simplified (S = I, orthonormal basis) form of paper Eq. (1); the eigenvalue
counting identity trace(P) = #{eigenvalues < mu} is used as the convergence
observable in tests and examples.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.core import bsm as B
from repro.core.engine import multiply


@dataclass
class SignIterStats:
    iterations: int
    converged: bool
    residual: float
    occupancy_trace: list[float]
    multiplications: int


def _scale_to_unit_spectrum(x: B.BlockSparseMatrix) -> B.BlockSparseMatrix:
    """Scale X0 so its spectrum lies in [-1, 1] (Frobenius bound)."""
    nrm = x.frobenius_norm()
    return B.scale(x, 1.0 / jnp.maximum(nrm, 1e-30))


def sign_iteration(
    x0: B.BlockSparseMatrix,
    *,
    mesh=None,
    engine: str = "twofive",
    threshold: float = 0.0,
    filter_eps: float = 0.0,
    max_iter: int = 50,
    tol: float = 1e-6,
    scale_input: bool = True,
) -> tuple[B.BlockSparseMatrix, SignIterStats]:
    """Newton-Schulz iteration X <- 1/2 X (3I - X^2) to sign(x0)."""
    nb, bs = x0.nb_r, x0.bs_r
    ident = B.identity(nb, bs, x0.dtype)
    x = _scale_to_unit_spectrum(x0) if scale_input else x0
    occ = []
    n_mults = 0
    converged = False
    residual = float("inf")
    it = 0
    for it in range(1, max_iter + 1):
        x2 = multiply(
            x, x, mesh, engine=engine, threshold=threshold, filter_eps=filter_eps
        )
        n_mults += 1
        # 3I - X^2
        y = B.add(B.scale(x2, -1.0), B.scale(ident, 3.0))
        xn = multiply(
            x, y, mesh, engine=engine, threshold=threshold, filter_eps=filter_eps
        )
        xn = B.scale(xn, 0.5)
        n_mults += 1
        # convergence: || X_{n+1} - X_n ||_F / || X_n ||_F
        diff = B.add(xn, B.scale(x, -1.0))
        residual = float(diff.frobenius_norm() / jnp.maximum(xn.frobenius_norm(), 1e-30))
        occ.append(float(xn.occupancy()))
        x = xn
        if residual < tol:
            converged = True
            break
    stats = SignIterStats(
        iterations=it,
        converged=converged,
        residual=residual,
        occupancy_trace=occ,
        multiplications=n_mults,
    )
    return x, stats


def density_matrix(
    h: B.BlockSparseMatrix,
    mu: float,
    *,
    mesh=None,
    engine: str = "twofive",
    threshold: float = 0.0,
    filter_eps: float = 0.0,
    max_iter: int = 60,
    tol: float = 1e-6,
) -> tuple[B.BlockSparseMatrix, SignIterStats]:
    """P = 1/2 (I - sign(H - mu I))  (paper Eq. (1) with S = I)."""
    nb, bs = h.nb_r, h.bs_r
    ident = B.identity(nb, bs, h.dtype)
    shifted = B.add(h, B.scale(ident, -mu))
    sgn, stats = sign_iteration(
        shifted,
        mesh=mesh,
        engine=engine,
        threshold=threshold,
        filter_eps=filter_eps,
        max_iter=max_iter,
        tol=tol,
    )
    p = B.scale(B.add(ident, B.scale(sgn, -1.0)), 0.5)
    return p, stats


def trace(m: B.BlockSparseMatrix) -> jnp.ndarray:
    diag_blocks = m.blocks[jnp.arange(m.nb_r), jnp.arange(m.nb_c)]
    diag_mask = m.mask[jnp.arange(m.nb_r), jnp.arange(m.nb_c)]
    tr = jnp.trace(diag_blocks, axis1=-2, axis2=-1)
    return jnp.sum(tr * diag_mask)
