"""2.5D communication-reducing SpGEMM engine (the paper's OSL, Algorithm 2).

Two executors, both thin interpreters of a
:class:`repro.core.plan.MultiplyPlan` (see DESIGN.md §3-§4):

``pull_executor``  — Algorithm 2 run directly on the 2D (r, c) process grid
    with the depth axis *virtual*, exactly as in the paper: the 2D block
    layout of A, B, C is retained ("no 3D redistribution"), every process
    pulls the panels of ``group_products`` from their home positions (each
    one-sided rget is a static partial permutation from the plan), performs
    its L pairwise products per tick group, and the L-1 partial-C panels
    are sent to their owners at the end.  This covers the paper's non-square
    topologies (P_R != P_C with forced L = mx/mn), L = 1 (= OS1, the
    ``onesided`` engine), and square grids with a square L.

``stacked_executor`` — the TPU mesh formulation on an (l, r, c) device
    mesh: A and B replicated over the depth axis ``l`` (the analogue of
    exposing panels in MPI windows every layer can rget from); layer ``l``
    runs a Cannon schedule over its k-chunk ``Topology.chunk(l)`` (pre-shift
    offset = the chunk start), and the partial C panels are combined with
    one psum / psum_scatter over ``l`` — the paper's L-1 partial-panel
    sends fused into the ICI-native collective.  Uneven chunks (L does not
    divide the grid side) are handled by masking ticks past a layer's chunk.

Panel movement goes through the shared transport layer
(``repro.core.transport``, DESIGN.md §3): dense (blocks + mask, norms
recomputed on arrival) or occupancy-compressed (packed blocks + one-based
indices — partial-permutation safe, so the pull formulation's rget rounds
compress too).  Both executors pipeline: the pull executor issues tick
group g+1's permutes before group g's pairwise products, the stacked
executor double-buffers its ring exactly like ``cannon.ring_body``.

Per-device communicated volume under dense transport: the pull executor
moves Eq. (7) verbatim — (V/sqrt(L))(S_A+S_B) panel pulls plus (L-1) S_C
partial sends per process; the stacked executor moves (s/L)(S_A+S_B)
panels + (L-1)/L S_C == O(1/sqrt(P L)) with P = L s^2 — the same
asymptotics in mesh coordinates (see commvolume.mesh25d_volume and
commvolume.plan_volume, which also models the compressed wire format).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import pcast, shard_map
from repro.core import transport as T
from repro.core.bsm import BlockSparseMatrix
from repro.core.local_mm import local_filtered_mm


def pull_body(
    plan,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    transport: T.PanelTransport = T.DENSE,
):
    """The per-shard Algorithm-2 pull body (shards in, C shard out);
    exposed so iteration chains can inline it into one enclosing
    shard_map (``core/signiter.py``)."""
    mm_kw = dict(
        threshold=threshold, backend=backend,
        stack_capacity=stack_capacity, tile=tile, interpret=interpret,
    )
    topo = plan.topo
    l_r, l_c, depth, s = topo.l_r, topo.l_c, topo.l, topo.side3d
    axes = plan.axes
    tr = transport

    def body(ab, am, an, bb, bm, bn):
        del an, bn  # norms are not pulled (recomputed per received panel)
        nr, nc = ab.shape[0], bb.shape[1]
        wa = ab.shape[1] // plan.ca  # A subpanel width (block cols)
        wb = bb.shape[0] // plan.cb  # B subpanel height (block rows)
        dtype = ab.dtype

        def pull_group(g):
            """Issue every one-sided pull of tick group ``g`` and return
            the accumulated dense (blocks, mask) panel per slot."""
            a_pan = [
                (
                    jnp.zeros((nr, wa) + ab.shape[2:], dtype),
                    jnp.zeros((nr, wa), bool),
                )
                for _ in range(l_r)
            ]
            b_pan = [
                (
                    jnp.zeros((wb, nc) + bb.shape[2:], dtype),
                    jnp.zeros((wb, nc), bool),
                )
                for _ in range(l_c)
            ]
            for rd in plan.a_pulls[g]:
                sl = slice(rd.q * wa, (rd.q + 1) * wa)
                st = T.ingest(tr, tr.cap_a, ab[:, sl], am[:, sl])
                rb, rm = T.dense_view(
                    tr, T.permute(st, axes, rd.pairs), nr, wa, dtype=dtype
                )
                pb, pm = a_pan[rd.slot]
                a_pan[rd.slot] = (pb + rb, pm | rm)
            for rd in plan.b_pulls[g]:
                sl = slice(rd.q * wb, (rd.q + 1) * wb)
                st = T.ingest(tr, tr.cap_b, bb[sl], bm[sl])
                rb, rm = T.dense_view(
                    tr, T.permute(st, axes, rd.pairs), wb, nc, dtype=dtype
                )
                pb, pm = b_pan[rd.slot]
                b_pan[rd.slot] = (pb + rb, pm | rm)
            return a_pan, b_pan

        # partial C accumulators, one per target panel slot t = j3*L_R + i3
        c_blk = [
            jnp.zeros((nr, nc, ab.shape[2], bb.shape[3]), dtype)
            for _ in range(depth)
        ]
        c_msk = [jnp.zeros((nr, nc), bool) for _ in range(depth)]

        # pipelined groups: group g+1's pulls are issued before group g's
        # pairwise products consume the current panels (rget overlap, §4)
        cur = pull_group(0)
        for g in range(plan.ticks):
            nxt = pull_group(g + 1) if g + 1 < plan.ticks else None
            a_pan, b_pan = cur
            a_n = [T.panel_norms(pb, threshold) for pb, _ in a_pan]
            b_n = [T.panel_norms(pb, threshold) for pb, _ in b_pan]
            # ---- the L pairwise panel products of this group -------------
            for i3 in range(l_r):
                for j3 in range(l_c):
                    t = j3 * l_r + i3
                    pa, pam = a_pan[i3]
                    pb, pbm = b_pan[j3]
                    dcb, dcm = local_filtered_mm(
                        pa, pam, a_n[i3], pb, pbm, b_n[j3], **mm_kw
                    )
                    c_blk[t] = c_blk[t] + dcb
                    c_msk[t] = c_msk[t] | dcm
            cur = nxt

        if depth == 1:
            return c_blk[0], c_msk[0]

        # ---- the L-1 partial-C sends to the panel owners -----------------
        i = lax.axis_index("r")
        j = lax.axis_index("c")
        lay = (j // s) * l_r + (i // s)  # own layer == own panel slot
        stack_b = jnp.stack(c_blk)
        stack_m = jnp.stack(c_msk)
        total_b = jnp.take(stack_b, lay, axis=0)
        total_m = jnp.take(stack_m, lay, axis=0)
        for d, perm in enumerate(plan.c_rounds, start=1):
            t_send = (lay + d) % depth
            rb = lax.ppermute(
                jnp.take(stack_b, t_send, axis=0), axes, list(perm)
            )
            rm = lax.ppermute(
                jnp.take(stack_m, t_send, axis=0), axes, list(perm)
            )
            total_b = total_b + rb
            total_m = total_m | rm
        return total_b, total_m

    return body


def pull_executor(plan, **kw):
    """Algorithm 2 as static pulls on the 2D (r, c) mesh (any valid grid)."""
    blk = P("r", "c", None, None)
    m2 = P("r", "c")
    return shard_map(
        pull_body(plan, **kw),
        mesh=plan.mesh,
        # check_vma=False: the pallas backend's pallas_call builds plain
        # ShapeDtypeStructs (no vma annotation); engine outputs are
        # oracle-tested instead (tests/_dist.py::check_engines)
        check_vma=False,
        in_specs=(blk, m2, m2, blk, m2, m2),
        out_specs=(blk, m2),
    )


def stacked_body(
    plan,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    c_layout: str = "2d",
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    transport: T.PanelTransport = T.DENSE,
):
    """The per-shard (l, r, c)-mesh 2.5D body (exposed for chain fusion,
    like ``pull_body``); with c_layout="2d" the returned C shard is
    replicated over ``l``, so chained multiplies compose."""
    ticks = plan.ticks
    groups = tuple(plan.layer_groups)
    uneven = len(set(groups)) > 1
    axes = plan.axes
    tr = transport

    def body(ab, am, an, bb, bm, bn):
        del an, bn  # norms never ride the ring (recomputed at compute time)
        sa, sb = am.shape, bm.shape
        adt, bdt = ab.dtype, bb.dtype  # widen wire-cast panels back
        mm_kw = dict(
            threshold=threshold, backend=backend,
            stack_capacity=stack_capacity, tile=tile, interpret=interpret,
        )
        my_groups = jnp.take(
            jnp.asarray(groups, jnp.int32), lax.axis_index("l")
        )

        def compute(pa, pb, cb, cm, t):
            xb, xm = T.dense_view(tr, pa, *sa, dtype=adt)
            yb, ym = T.dense_view(tr, pb, *sb, dtype=bdt)
            dcb, dcm = local_filtered_mm(
                xb, xm, T.panel_norms(xb, threshold),
                yb, ym, T.panel_norms(yb, threshold), **mm_kw,
            )
            if uneven:
                # mask ticks past this layer's k-chunk (uneven-L support)
                active = t < my_groups
                dcb = dcb * active.astype(dcb.dtype)
                dcm = dcm & active
            return cb + dcb, cm | dcm

        # pre-shift with per-layer chunk offset: A_ij <- A_{i, j+i+start_l},
        # B_ij <- B_{i+j+start_l, j}; one static flattened permutation.
        pa = T.permute(T.ingest(tr, tr.cap_a, ab, am), axes, plan.pre_a)
        pb = T.permute(T.ingest(tr, tr.cap_b, bb, bm), axes, plan.pre_b)

        cb = jnp.zeros(
            (ab.shape[0], bb.shape[1], ab.shape[2], bb.shape[3]), ab.dtype
        )
        cm = jnp.zeros((ab.shape[0], bb.shape[1]), bool)
        cb = pcast(cb, axes, to="varying")
        cm = pcast(cm, axes, to="varying")

        if ticks == 1:
            cb, cm = compute(pa, pb, cb, cm, jnp.asarray(0, jnp.int32))
        else:
            # double-buffered ring: the hop for tick t+1 is in flight
            # before the GEMM of tick t (see cannon.ring_body)
            na = T.permute(pa, "c", plan.shift_a)
            nb_ = T.permute(pb, "r", plan.shift_b)

            def tick(carry, t):
                pa, pb, na, nb_, cb, cm = carry
                fa = T.permute(na, "c", plan.shift_a)
                fb = T.permute(nb_, "r", plan.shift_b)
                cb, cm = compute(pa, pb, cb, cm, t)
                return (na, nb_, fa, fb, cb, cm), None

            if ticks > 2:
                (pa, pb, na, nb_, cb, cm), _ = lax.scan(
                    tick, (pa, pb, na, nb_, cb, cm),
                    jnp.arange(ticks - 2, dtype=jnp.int32),
                )
            # last two ticks: compute only, no trailing shift
            cb, cm = compute(pa, pb, cb, cm,
                             jnp.asarray(ticks - 2, jnp.int32))
            cb, cm = compute(na, nb_, cb, cm,
                             jnp.asarray(ticks - 1, jnp.int32))

        # --- partial-C reduction over the depth axis (the L-1 sends)
        cmi = cm.astype(jnp.int32)
        if c_layout == "2d":
            return lax.psum(cb, "l"), lax.psum(cmi, "l") > 0
        cb = lax.psum_scatter(cb, "l", scatter_dimension=0, tiled=True)
        cmi = lax.psum_scatter(cmi, "l", scatter_dimension=0, tiled=True)
        return cb, cmi > 0

    return body


def stacked_executor(plan, *, c_layout: str = "2d", **kw):
    """The (l, r, c)-mesh 2.5D executor.

    c_layout:
      "2d"      — C replicated over l (psum), sharded (r, c): the paper's
                  layout (C lives on the 2D grid).
      "scatter" — C reduce-scattered over l along block rows: keeps the
                  result distributed over all P devices (cheaper reduction,
                  (L-1)/L instead of 2(L-1)/L traffic).
    """
    blk_in = P("r", "c", None, None)  # replicated over the unmentioned 'l'
    m2_in = P("r", "c")
    if c_layout == "2d":
        blk_out, m2_out = P("r", "c", None, None), P("r", "c")
    elif c_layout == "scatter":
        # psum_scatter splits each (r)-row panel over l: r-major, l-minor
        blk_out, m2_out = P(("r", "l"), "c", None, None), P(("r", "l"), "c")
    else:
        raise ValueError(f"unknown c_layout {c_layout!r}")
    return shard_map(
        stacked_body(plan, c_layout=c_layout, **kw),
        mesh=plan.mesh,
        # check_vma=False: the pallas backend's pallas_call builds plain
        # ShapeDtypeStructs (no vma annotation); engine outputs are
        # oracle-tested instead (tests/_dist.py::check_engines)
        check_vma=False,
        in_specs=(blk_in, m2_in, m2_in, blk_in, m2_in, m2_in),
        out_specs=(blk_out, m2_out),
    )


def twofive_shardmap(
    mesh,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    c_layout: str = "2d",
):
    """Back-compat: compile the plan for ``mesh`` and build its executor."""
    from repro.core import plan as plan_mod

    p = plan_mod.plan_multiply(mesh, "twofive")
    return plan_mod.build_program(
        p, threshold=threshold, backend=backend, c_layout=c_layout
    )


def multiply_25d(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    mesh,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    c_layout: str = "2d",
) -> BlockSparseMatrix:
    """Distributed C = A . B with the 2.5D engine (plan-cached program)."""
    from repro.core import plan as plan_mod

    return plan_mod.execute(
        a, b, mesh, "twofive",
        threshold=threshold, backend=backend, c_layout=c_layout,
    )
