"""2.5D communication-reducing SpGEMM engine (the paper's OSL, Algorithm 2)
as a shard_map program over an (l, r, c) device mesh.

TPU-native formulation of the paper's scheme (see DESIGN.md §2):

  * the 2D block data layout of A, B, C is *retained* (sharded over (r, c));
    A and B are replicated over the depth axis ``l`` — the analogue of
    exposing the panels in MPI windows that every layer can rget from;
  * layer ``l`` runs a Cannon schedule over only its 1/L slice of the
    k-range (pre-shift offset ``l * s/L``, then s/L ticks) — the paper's
    "each process computes the partial multiplications for L different C
    panels" re-expressed per layer;
  * the partial C panels are combined with one reduce-scatter (psum_scatter)
    or psum over ``l`` — the paper's L-1 partial-panel sends + accumulation,
    fused into the ICI-native collective; it overlaps with the final tick
    under XLA's latency-hiding scheduler (the paper overlaps the same way).

Per-device communicated volume: (s/L)(S_A+S_B) panels + (L-1)/L S_C
==  2 N^2/(s L) + N^2 (L-1)/(s^2 L)  ==  O(1/sqrt(P L)) with P = L s^2
— Eq. (7) of the paper in mesh coordinates (see commvolume.mesh25d_volume).

Validity: L must divide the layer-grid side s (slightly wider than the
paper's square-integer rule; topology.py keeps the paper's rule for the
fidelity tests and comm model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.bsm import BlockSparseMatrix, block_norms
from repro.core.local_mm import local_filtered_mm

_AXES = ("l", "r", "c")


def _flat_perm3(l_size: int, p: int, fn) -> list[tuple[int, int]]:
    """Static permutation over the flattened (l, r, c) axis.

    fn(l, i, j) -> (dl, di, dj); index = (l * p + i) * p + j.
    """
    perm = []
    for l in range(l_size):
        for i in range(p):
            for j in range(p):
                dl, di, dj = fn(l, i, j)
                perm.append(((l * p + i) * p + j, (dl * p + di) * p + dj))
    return perm


def twofive_shardmap(
    mesh,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    c_layout: str = "2d",
):
    """Returns the shard_map'd multiply body for the 2.5D engine.

    c_layout:
      "2d"      — C replicated over l (psum), sharded (r, c): the paper's
                  layout (C lives on the 2D grid).
      "scatter" — C reduce-scattered over l along block rows: keeps the
                  result distributed over all P devices (cheaper reduction,
                  (L-1)/L instead of 2(L-1)/L traffic).
    """
    l_size = mesh.shape["l"]
    p = mesh.shape["r"]
    assert mesh.shape["c"] == p, "2.5D engine requires square layer grids"
    assert p % l_size == 0, f"L={l_size} must divide the layer-grid side {p}"
    ticks = p // l_size

    blk_in = P("r", "c", None, None)  # replicated over the unmentioned 'l'
    m2_in = P("r", "c")
    if c_layout == "2d":
        blk_out, m2_out = P("r", "c", None, None), P("r", "c")
    else:
        # psum_scatter splits each (r)-row panel over l: r-major, l-minor
        blk_out, m2_out = P(("r", "l"), "c", None, None), P(("r", "l"), "c")

    def body(ab, am, an, bb, bm, bn):
        # --- pre-shift with layer offset: A_ij <- A_{i, (j + i + l*ticks)},
        #     B_ij <- B_{(i + j + l*ticks), j}; one static flattened perm.
        pre_a = _flat_perm3(
            l_size, p, lambda l, i, j: (l, i, (j - i - l * ticks) % p)
        )
        pre_b = _flat_perm3(
            l_size, p, lambda l, i, j: (l, (i - j - l * ticks) % p, j)
        )
        ab, am, an = (lax.ppermute(x, _AXES, pre_a) for x in (ab, am, an))
        bb, bm, bn = (lax.ppermute(x, _AXES, pre_b) for x in (bb, bm, bn))

        cb = jnp.zeros(
            (ab.shape[0], bb.shape[1], ab.shape[2], bb.shape[3]), ab.dtype
        )
        cm = jnp.zeros((ab.shape[0], bb.shape[1]), bool)
        cb = lax.pcast(cb, _AXES, to="varying")
        cm = lax.pcast(cm, _AXES, to="varying")

        def shift1(x, axis):
            perm = [(s, (s - 1) % p) for s in range(p)]
            return lax.ppermute(x, axis, perm)

        def tick(carry, _):
            ab, am, an, bb, bm, bn, cb, cm = carry
            dcb, dcm = local_filtered_mm(
                ab, am, an, bb, bm, bn, threshold=threshold, backend=backend
            )
            cb, cm = cb + dcb, cm | dcm
            ab, am, an = (shift1(x, "c") for x in (ab, am, an))
            bb, bm, bn = (shift1(x, "r") for x in (bb, bm, bn))
            return (ab, am, an, bb, bm, bn, cb, cm), None

        if ticks > 1:
            (ab, am, an, bb, bm, bn, cb, cm), _ = lax.scan(
                tick, (ab, am, an, bb, bm, bn, cb, cm), None, length=ticks - 1
            )
        dcb, dcm = local_filtered_mm(
            ab, am, an, bb, bm, bn, threshold=threshold, backend=backend
        )
        cb, cm = cb + dcb, cm | dcm

        # --- partial-C reduction over the depth axis (the L-1 sends)
        cmi = cm.astype(jnp.int32)
        if c_layout == "2d":
            return lax.psum(cb, "l"), lax.psum(cmi, "l") > 0
        cb = lax.psum_scatter(cb, "l", scatter_dimension=0, tiled=True)
        cmi = lax.psum_scatter(cmi, "l", scatter_dimension=0, tiled=True)
        return cb, cmi > 0

    return jax.shard_map(
        body,
        mesh=mesh,
        # check_vma=False: the pallas backend's pallas_call builds plain
        # ShapeDtypeStructs (no vma annotation); engine outputs are
        # oracle-tested instead (tests/_dist.py::check_engines)
        check_vma=False,
        in_specs=(blk_in, m2_in, m2_in, blk_in, m2_in, m2_in),
        out_specs=(blk_out, m2_out),
    )


def multiply_25d(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    mesh,
    *,
    threshold: float = 0.0,
    backend: str = "jnp",
    c_layout: str = "2d",
) -> BlockSparseMatrix:
    """Distributed C = A . B on an (l, r, c) mesh with the 2.5D engine."""
    fn = twofive_shardmap(
        mesh, threshold=threshold, backend=backend, c_layout=c_layout
    )
    cb, cm = fn(a.blocks, a.mask, a.norms, b.blocks, b.mask, b.norms)
    return BlockSparseMatrix(blocks=cb, mask=cm, norms=block_norms(cb))
