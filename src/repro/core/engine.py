"""Engine dispatcher: the public ``multiply`` entry point.

Engines (paper terminology in parentheses):

  cannon    — 2D Cannon, ring point-to-point shifts (PTP, Algorithm 1)
  onesided  — 2D pull-from-home streaming, no pre-shift (OS1, Alg. 2, L=1);
              any (r, c) grid
  gather    — 2D pull-from-home via fused all-gather (TPU-native OS1)
  twofive   — 2.5D with depth axis L (OSL, Algorithm 2): on an (l, r, c)
              mesh the stacked formulation (uneven L supported); on a 2D
              (r, c) mesh the pull formulation with a *virtual* depth axis,
              including non-square grids (L = mx/mn forced, paper §3)

Every engine executes a compiled :class:`repro.core.plan.MultiplyPlan`; the
jitted programs are LRU-cached (``repro.core.plan.get_compiled``) so the
hot paths — sign iteration, serving, benchmark loops — never retrace or
re-lower after the first multiply.

A single-device reference (`multiply_reference`) implements the identical
filtered semantics without any mesh — the oracle for every engine test.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.core.bsm import BlockSparseMatrix, block_norms, filter_bsm
from repro.core.local_mm import local_filtered_mm

ENGINES = ("cannon", "onesided", "gather", "twofive")


@partial(jax.jit, static_argnames=("threshold", "backend"))
def multiply_reference(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    threshold: float = 0.0,
    backend: str = "jnp",
) -> BlockSparseMatrix:
    """Single-device filtered block multiply (oracle)."""
    cb, cm = local_filtered_mm(
        a.blocks,
        a.mask,
        a.norms,
        b.blocks,
        b.mask,
        b.norms,
        threshold=threshold,
        backend=backend,
    )
    return BlockSparseMatrix(blocks=cb, mask=cm, norms=block_norms(cb))


def multiply(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    mesh=None,
    *,
    engine: str = "twofive",
    threshold: float = 0.0,
    filter_eps: float | None = None,
    backend: str = "jnp",
    c_layout: str = "2d",
    l: int | None = None,
) -> BlockSparseMatrix:
    """Distributed filtered C = A . B.

    threshold  — on-the-fly filter: skip block products with
                 norm(A_ik) * norm(B_kj) <= threshold.
    filter_eps — post-multiplication filter: drop result blocks with
                 norm <= filter_eps (defaults to ``threshold``).
    l          — depth override for the 2D-mesh ``twofive`` pull engine
                 (square grids; non-square grids force L = mx/mn).
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    if mesh is None:
        c = multiply_reference(a, b, threshold=threshold, backend=backend)
    else:
        c = plan_mod.execute(
            a, b, mesh, engine,
            threshold=threshold, backend=backend, c_layout=c_layout, l=l,
        )
    eps = threshold if filter_eps is None else filter_eps
    if eps > 0.0:
        c = filter_bsm(c, eps)
    return c


def lower_multiply(
    mesh,
    nb: int,
    bs: int,
    *,
    engine: str = "twofive",
    threshold: float = 0.0,
    backend: str = "jnp",
    dtype=jnp.float32,
    c_layout: str = "2d",
    l: int | None = None,
):
    """Lower (without executing) one multiplication for HLO inspection —
    the source of the measured collective bytes in the benchmarks.  Shares
    the plan-layer program cache with ``multiply``."""
    fn = plan_mod.get_compiled(
        mesh,
        engine,
        nb,
        bs,
        dtype,
        threshold=threshold,
        backend=backend,
        c_layout=c_layout,
        l=l,
    )
    blk = jax.ShapeDtypeStruct((nb, nb, bs, bs), dtype)
    m2b = jax.ShapeDtypeStruct((nb, nb), jnp.bool_)
    m2f = jax.ShapeDtypeStruct((nb, nb), jnp.float32)
    return fn.lower(blk, m2b, m2f, blk, m2b, m2f)
