"""Engine dispatcher: the public ``multiply`` entry point.

Engines (paper terminology in parentheses):

  cannon    — 2D Cannon, ring point-to-point shifts (PTP, Algorithm 1)
  onesided  — 2D pull-from-home streaming, no pre-shift (OS1, Alg. 2, L=1);
              any (r, c) grid
  gather    — 2D pull-from-home via fused all-gather (TPU-native OS1)
  twofive   — 2.5D with depth axis L (OSL, Algorithm 2): on an (l, r, c)
              mesh the stacked formulation (uneven L supported); on a 2D
              (r, c) mesh the pull formulation with a *virtual* depth axis,
              including non-square grids (L = mx/mn forced, paper §3)

Every engine executes a compiled :class:`repro.core.plan.MultiplyPlan`; the
jitted programs are LRU-cached (``repro.core.plan.get_compiled``) so the
hot paths — sign iteration, serving, benchmark loops — never retrace or
re-lower after the first multiply.

Local backends (``core/local_mm.py``): ``jnp`` dense masked einsum,
``stacks`` compacted gather-GEMM-scatter, ``pallas`` the scalar-prefetch
TPU kernel — plus ``"auto"``, the occupancy-driven heuristic: when the
sparsity pattern is concrete, the exact surviving-product fill is measured
on the host and the compacted backends are picked below
``AUTO_DENSE_FILL`` (DBCSR behaves the same way: stacks always, but its
batched GEMM only wins when occupancy is low; dense MXU einsum wins when
the cube is mostly full).  Auto also derives a *sound* static capacity for
the compacted backends — exact count single-device, per-device bound
distributed — so compaction never drops products.

A single-device reference (`multiply_reference`) implements the identical
filtered semantics without any mesh — the oracle for every engine test.
The compacted single-device path runs through the plan layer's
pattern-signature cache (``plan.get_product_stacks``): a repeated pattern
re-uses both its product list and its compiled program.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.core.bsm import (
    BlockSparseMatrix,
    ShardedBSM,
    block_norms,
    filter_bsm,
)
from repro.core.local_mm import (
    GATHER_OVERHEAD,
    backend_local_cost,
    local_filtered_mm,
)

ENGINES = ("cannon", "onesided", "gather", "twofive")

# surviving-product fill at which the dense einsum and the compacted
# backends break even under the shared analytic model
# (``local_mm.backend_local_cost``); kept as a named constant for tests
AUTO_DENSE_FILL = 1.0 / GATHER_OVERHEAD


def _is_concrete(*arrays) -> bool:
    return not any(isinstance(x, jax.core.Tracer) for x in arrays)


def _host_pair_filter(a: BlockSparseMatrix, b: BlockSparseMatrix,
                      threshold: float) -> np.ndarray:
    """Concrete (i, k, j) filter cube on the host (numpy)."""
    from repro.kernels.stacks import pair_cube

    return pair_cube(a.mask, b.mask, a.norms, b.norms, threshold)


def choose_backend(a: BlockSparseMatrix, b: BlockSparseMatrix,
                   threshold: float = 0.0, *, ok=None) -> str:
    """Cost-model-driven local-backend selection (the ``"auto"`` policy).

    Delegates to the shared analytic model
    (``local_mm.backend_local_cost``, also used by the tuner's candidate
    ranking — DESIGN.md §6): dense einsum when the full-cube MXU work
    undercuts the compacted path's gathered products, compacted list
    otherwise; the compacted flavor is the Pallas kernel on real TPU and
    the jnp gather-GEMM-scatter elsewhere.  Traced inputs (inside someone
    else's jit) fall back to ``jnp`` — no concrete pattern to compact.

    ``ok`` — optional precomputed concrete filter cube, so one host walk
    serves both this heuristic and the capacity bound in ``multiply``.
    """
    if ok is None:
        if not _is_concrete(a.mask, a.norms, b.mask, b.norms):
            return "jnp"
        ok = _host_pair_filter(a, b, threshold)
    fill = float(ok.mean()) if ok.size else 0.0
    ni, nk = a.nb_r, a.nb_c
    nj = b.nb_c
    dims = (ni, nk, nj, a.bs_r, a.bs_c, b.bs_c)
    dense = backend_local_cost(*dims, fill=1.0, backend="jnp",
                               dtype=a.dtype)
    compact = backend_local_cost(*dims, fill=fill, backend="stacks",
                                 dtype=a.dtype)
    if dense <= compact:
        return "jnp"
    return "pallas" if jax.default_backend() == "tpu" else "stacks"


# distributed per-device capacity bounds live in the plan layer
# (plan.device_stack_bound / plan.get_device_capacity — LRU-cached on the
# pattern signature alongside the product lists, cleared by clear_cache)
device_stack_bound = plan_mod.device_stack_bound


@partial(jax.jit, static_argnames=("threshold", "backend", "stack_capacity",
                                   "tile", "interpret"))
def _multiply_reference_jit(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    threshold: float = 0.0,
    backend: str = "jnp",
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
) -> BlockSparseMatrix:
    cb, cm = local_filtered_mm(
        a.blocks,
        a.mask,
        a.norms,
        b.blocks,
        b.mask,
        b.norms,
        threshold=threshold,
        backend=backend,
        stack_capacity=stack_capacity,
        tile=tile,
        interpret=interpret,
    )
    return BlockSparseMatrix(blocks=cb, mask=cm, norms=block_norms(cb))


def _reference_compacted(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    threshold: float,
    backend: str,
    tile: tuple[int, int, int] | None,
    interpret: bool | None,
    ok: np.ndarray | None = None,
) -> BlockSparseMatrix:
    """Single-device stacks/pallas path over the plan layer's caches.

    Host compaction with the *exact* bucketed capacity, product list
    cached per pattern signature, program cached per capacity bucket —
    DBCSR's stack generation amortized across repeated multiplies.
    """
    if ok is None:
        ok = _host_pair_filter(a, b, threshold)
    ni, nk, nj = ok.shape
    stacks, _n = plan_mod.get_product_stacks(ok)
    cm = jnp.asarray(ok.any(axis=1))
    if stacks.capacity == 0:
        cb = jnp.zeros((ni, nj, a.bs_r, b.bs_c), a.dtype)
        return BlockSparseMatrix(blocks=cb, mask=cm, norms=block_norms(cb))
    fn = plan_mod.get_local_compiled(
        ni, nk, nj, a.bs_r, a.bs_c, b.bs_c, a.dtype,
        backend=backend, capacity=stacks.capacity, tile=tile,
        interpret=interpret,
    )
    cb = fn(a.blocks, b.blocks, stacks)
    # the pallas grid only visits tiles with surviving products
    cb = jnp.where(cm[:, :, None, None], cb, jnp.zeros((), cb.dtype))
    return BlockSparseMatrix(blocks=cb, mask=cm, norms=block_norms(cb))


def multiply_reference(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    threshold: float = 0.0,
    backend: str = "jnp",
    *,
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    ok: np.ndarray | None = None,
) -> BlockSparseMatrix:
    """Single-device filtered block multiply (oracle).

    ``ok`` — optional precomputed concrete filter cube; one host walk then
    serves backend choice, compaction and the C mask.
    """
    concrete = _is_concrete(a.blocks, a.mask, a.norms, b.mask, b.norms)
    if backend == "auto":
        if ok is None and concrete:
            ok = _host_pair_filter(a, b, threshold)
        backend = choose_backend(a, b, threshold, ok=ok)
    if backend in ("stacks", "pallas") and concrete and stack_capacity is None:
        return _reference_compacted(a, b, threshold, backend, tile,
                                    interpret, ok)
    return _multiply_reference_jit(
        a, b, threshold, backend,
        stack_capacity=stack_capacity, tile=tile, interpret=interpret,
    )


def multiply(
    a: BlockSparseMatrix | ShardedBSM,
    b: BlockSparseMatrix | ShardedBSM,
    mesh=None,
    *,
    engine: str = "twofive",
    threshold: float = 0.0,
    filter_eps: float | None = None,
    backend: str | None = None,
    c_layout: str = "2d",
    l: int | None = None,
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    transport=None,
    assignment=None,
    envelope=None,
) -> BlockSparseMatrix | ShardedBSM:
    """Distributed filtered C = A . B.

    engine     — one of ``ENGINES``, or ``"auto"``: the pattern-aware
                 tuner (``repro.tuner``) picks engine, depth L, local
                 backend, stack capacity and panel transport from the
                 concrete sparsity pattern — analytic Eq. 6/7 pruning,
                 then short measured trials, with winners persisted in
                 the tuning DB so later runs resolve without timing
                 anything.
    threshold  — on-the-fly filter: skip block products with
                 norm(A_ik) * norm(B_kj) <= threshold.
    filter_eps — post-multiplication filter: drop result blocks with
                 norm <= filter_eps (defaults to ``threshold``).
    l          — depth override for the 2D-mesh ``twofive`` pull engine
                 (square grids; non-square grids force L = mx/mn).
    backend    — local stage: "jnp" | "stacks" | "pallas" | "auto"
                 (occupancy heuristic, see ``choose_backend``).  The
                 default (None) is "jnp" for static engines; under
                 ``engine="auto"`` it leaves the backend to the tuner —
                 pass an explicit backend to pin it.
    stack_capacity — static surviving-product bound for the compacted
                 backends; derived automatically from the concrete
                 pattern when omitted (exact single-device, sound
                 per-device bound distributed).
    interpret  — Pallas execution mode (None = platform auto-detect).
    transport  — panel transport: a ``transport.PanelTransport``, or
                 "auto" | "dense" | "compressed" (None = the configured
                 default, ``REPRO_TRANSPORT``/auto).  "auto" packs only
                 occupied blocks into bounded buffers when the pattern's
                 fill is low (wire bytes scale with occupancy — DESIGN.md
                 §3) and keeps the bit-exact dense panels otherwise; the
                 plan layer derives sound per-panel capacities from the
                 concrete masks (``plan.get_transport``).
    assignment — block→device distribution: None (identity, or under
                 ``engine="auto"`` the tuner's choice), a mode string
                 ("identity" | "randomized" | "nnz_greedy" — derived
                 deterministically from the concrete masks), or a ready
                 ``distribute.Assignment``.  Replicated operands are
                 permuted inside the compiled program (results come back
                 in original block coordinates); sharded operands already
                 carry their layout from ``shard_bsm`` and an explicit
                 value here can only confirm it.  Requires a mesh —
                 single-device multiplies have no devices to balance.
    envelope   — optional ``core.envelope.Envelope``: derive every
                 pattern-dependent static (stack capacity, transport
                 capacities, the auto-backend fill) from the envelope
                 instead of walking THIS call's concrete pattern.  A
                 stream of drifting patterns inside one envelope then
                 shares one compiled program (stable capacity buckets,
                 no per-call host cube walk) — the concrete mask does
                 the per-call work as data.  Concrete operands are
                 checked against the envelope (cheap 2D subset test); a
                 pattern that escaped it falls back to the exact
                 per-pattern derivation and counts ``drift_retunes`` in
                 ``cache_stats()``.  Traced operands trust the envelope
                 (there is no concrete pattern to check — the caller
                 guarantees coverage, as fused chains do by
                 construction).

    ShardedBSM operands take the device-resident path: the multiply runs
    on the shards (``plan.execute_sharded``) and returns a ShardedBSM —
    no gather, no re-shard; post-filtering happens shard-local with
    derived norms.  Both operands must be sharded on the same mesh.
    """
    if engine != "auto" and engine not in ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}; one of {ENGINES} or 'auto'"
        )
    env = envelope
    if (
        env is not None
        and _is_concrete(a.mask, b.mask)
        and not env.covers(np.asarray(a.mask, bool),
                           np.asarray(b.mask, bool))
    ):
        # the pattern drifted out of its envelope: abandon the warm path
        # and re-derive everything exactly for this call
        plan_mod.note_drift_retune()
        env = None
    # None = the caller left the backend open: static engines get the
    # historical "jnp" default, the tuner gets the full backend space
    pinned = backend if backend not in (None, "auto") else None
    if backend is None:
        backend = "jnp"
    if isinstance(a, ShardedBSM) or isinstance(b, ShardedBSM):
        if not (isinstance(a, ShardedBSM) and isinstance(b, ShardedBSM)):
            raise TypeError(
                "mixed ShardedBSM / BlockSparseMatrix operands; shard both "
                "(bsm.shard_bsm) or neither"
            )
        if a.mesh is not b.mesh and a.mesh != b.mesh:
            raise ValueError("operands sharded on different meshes")
        if mesh is not None and mesh is not a.mesh and mesh != a.mesh:
            raise ValueError("mesh argument conflicts with operand mesh")
        if c_layout != "2d":
            raise ValueError("sharded chains require c_layout='2d'")
        if engine == "auto":
            # full tuner resolution: one host walk of the device-resident
            # pattern, amortized by the decision cache across repeats.
            # assign is pinned to identity — the layout decision was made
            # at shard_bsm time and the tuner sees the permuted pattern.
            from repro import tuner

            dec = tuner.autotune(
                a, b, a.mesh, threshold=threshold, backend=pinned,
                l=l, interpret=interpret,
                transport=_transport_pin(transport),
                assign="identity", envelope=env,
            )
            engine, l, backend = dec.engine, dec.l, dec.backend
            if stack_capacity is None:
                stack_capacity = dec.stack_capacity
            if tile is None:
                tile = dec.tile
            if transport is None or transport == "auto":
                # adopt the tuner's measured mode (as resolve_multiply
                # does) — "auto" left in place would re-resolve through
                # the static crossover and could contradict the trials
                transport = dec.transport
        elif backend == "auto":
            if env is not None:
                # envelope fill decides without touching device masks
                backend = choose_backend(a, b, threshold,
                                         ok=np.asarray(env.cube))
            else:
                # the auto heuristic walks the concrete pattern on the
                # host — a round-trip the device-resident path avoids
                backend = "jnp"
        if backend in ("stacks", "pallas") and stack_capacity is None:
            if env is not None:
                # envelope capacity: stable across the whole drifting
                # stream (one program), no per-call mask sync
                stack_capacity = plan_mod.get_device_capacity(
                    env.cube, a.mesh, engine)
            elif _is_concrete(a.mask, a.norms, b.mask, b.norms):
                # sound per-device bound from the concrete (and, under a
                # non-identity assignment, already-permuted) shard masks
                # — without it the compacted program pads every device to
                # the full cube and the balanced layout's smaller hot
                # device buys nothing.  Costs the same per-call host mask
                # sync the auto transport resolution below already pays;
                # pass an explicit stack_capacity to skip it.
                stack_capacity = plan_mod.get_device_capacity(
                    _host_pair_filter(a, b, threshold), a.mesh, engine)
        if env is not None:
            transport = _envelope_transport(
                env.mask_a, env.mask_b, transport, a.mesh, engine, l)
        c = plan_mod.execute_sharded(
            a, b, engine,
            threshold=threshold, backend=backend, l=l,
            stack_capacity=stack_capacity, tile=tile, interpret=interpret,
            transport=transport, assignment=assignment,
        )
        eps = threshold if filter_eps is None else filter_eps
        return c.filter(eps) if eps > 0.0 else c
    if mesh is None and assignment not in (None, "identity"):
        raise ValueError(
            "assignment needs a mesh: a block→device distribution has no "
            "meaning on a single device"
        )
    if engine == "auto":
        if mesh is None:
            engine = "twofive"  # single-device: the engine is vestigial
        else:
            # delegate the whole (engine, L, backend, capacity, transport,
            # assignment) decision to the tuner (repro.tuner, DESIGN.md §6)
            from repro import tuner

            dec = tuner.autotune(
                a, b, mesh, threshold=threshold, backend=pinned,
                l=l, interpret=interpret,
                transport=_transport_pin(transport),
                assign=_assign_pin(assignment), envelope=env,
            )
            engine, l, backend = dec.engine, dec.l, dec.backend
            if stack_capacity is None:
                stack_capacity = dec.stack_capacity
            if tile is None:
                tile = dec.tile
            if transport is None or transport == "auto":
                # adopt the tuner's measured mode (see the sharded path)
                transport = dec.transport
            if assignment is None:
                # adopt the tuner's winning layout (identity when the
                # pattern is already balanced)
                assignment = dec.assign
    # the layout every capacity bound below must be derived from
    asg = None
    if mesh is not None:
        asg = plan_mod.resolve_assignment(assignment, a, b, mesh)
    # one host walk of the concrete filter cube serves both the auto
    # heuristic and the distributed capacity bound; an envelope replaces
    # the walk entirely (its union cube is the bound for the stream)
    ok_np = None
    if (
        env is None
        and (backend == "auto" or (backend in ("stacks", "pallas")
                                   and mesh is not None
                                   and stack_capacity is None))
        and _is_concrete(a.mask, a.norms, b.mask, b.norms)
    ):
        ok_np = _host_pair_filter(a, b, threshold)
    if backend == "auto":
        backend = choose_backend(
            a, b, threshold,
            ok=np.asarray(env.cube) if env is not None else ok_np,
        )
    if mesh is None:
        if (
            env is not None
            and backend in ("stacks", "pallas")
            and stack_capacity is None
        ):
            # static envelope capacity routes the whole stream through
            # one traced compacted program (mask-as-data, no host walks)
            stack_capacity = env.local_capacity()
        c = multiply_reference(
            a, b, threshold=threshold, backend=backend,
            stack_capacity=stack_capacity, tile=tile, interpret=interpret,
            ok=ok_np,
        )
    else:
        if backend in ("stacks", "pallas") and stack_capacity is None:
            # capacity must cover the PERMUTED pattern's hottest device —
            # the layout the engine actually partitions
            ok_cap = None
            if env is not None:
                ok_cap = np.asarray(env.cube)
            elif ok_np is not None:
                ok_cap = ok_np
            if ok_cap is not None:
                if asg is not None:
                    from repro.core.distribute import permute_cube

                    ok_cap = permute_cube(ok_cap, asg.perm)
                stack_capacity = plan_mod.get_device_capacity(
                    ok_cap, mesh, engine)
        if env is not None:
            em_a, em_b = env.mask_a, env.mask_b
            if asg is not None:
                p = np.asarray(asg.perm)
                em_a, em_b = em_a[p][:, p], em_b[p][:, p]
            transport = _envelope_transport(
                em_a, em_b, transport, mesh, engine, l)
        c = plan_mod.execute(
            a, b, mesh, engine,
            threshold=threshold, backend=backend, c_layout=c_layout, l=l,
            stack_capacity=stack_capacity, tile=tile, interpret=interpret,
            transport=transport, assignment=asg,
        )
    eps = threshold if filter_eps is None else filter_eps
    if eps > 0.0:
        c = filter_bsm(c, eps)
    return c


def _envelope_transport(mask_a, mask_b, transport, mesh, engine: str,
                        l: int | None):
    """Resolve a transport spec against ENVELOPE operand-mask unions.

    Capacities derived from the unions cover every panel any pattern in
    the stream can ship and stay constant across it — one compiled
    program instead of per-call derivation from the concrete masks (and
    no per-call host mask sync on the sharded path).  A ready
    ``PanelTransport`` passes through untouched."""
    from repro.core import transport as T

    if isinstance(transport, T.PanelTransport):
        return transport
    if transport is None:
        from repro.config import transport_mode

        mode = transport_mode()
    else:
        mode = transport
    if mode == "dense":
        return T.DENSE
    if mode not in ("auto", "compressed"):
        raise ValueError(
            f"unknown transport {mode!r}; a PanelTransport or one of "
            "auto | dense | compressed"
        )
    return plan_mod.get_transport(mask_a, mask_b, mesh, engine, l, mode)


def _transport_pin(transport) -> str | None:
    """The tuner constraint a caller-supplied transport implies: explicit
    modes pin the decision, ``None``/"auto" leave it to the tuner."""
    from repro.core.transport import PanelTransport

    if isinstance(transport, PanelTransport):
        return transport.mode
    if transport in ("dense", "compressed"):
        return transport
    return None


def _assign_pin(assignment) -> str | None:
    """The tuner constraint a caller-supplied assignment implies: an
    explicit mode (or a ready ``Assignment``) pins the decision, ``None``
    leaves the layout to the tuner."""
    if assignment is None:
        return None
    return getattr(assignment, "mode", assignment)


def lower_multiply(
    mesh,
    nb: int,
    bs: int,
    *,
    engine: str = "twofive",
    threshold: float = 0.0,
    backend: str = "jnp",
    dtype=jnp.float32,
    c_layout: str = "2d",
    l: int | None = None,
    stack_capacity: int | None = None,
    tile: tuple[int, int, int] | None = None,
    interpret: bool | None = None,
    transport=None,
    nb_k: int | None = None,
    nb_c: int | None = None,
    bs_k: int | None = None,
    bs_c: int | None = None,
):
    """Lower (without executing) one multiplication for HLO inspection —
    the source of the measured collective bytes in the benchmarks.  Shares
    the plan-layer program cache with ``multiply``.

    ``transport`` must be a resolved ``PanelTransport`` (or None = dense):
    lowering is abstract, so there is no pattern to resolve "auto" from —
    derive capacities from a concrete mask via ``plan.get_transport``.

    ``nb_k``/``nb_c``/``bs_k``/``bs_c`` (default: square) lower a
    rectangular matricized product A (nb x nb_k of bs x bs_k blocks) @
    B (nb_k x nb_c of bs_k x bs_c blocks).
    """
    nb_k = nb if nb_k is None else nb_k
    nb_c = nb if nb_c is None else nb_c
    bs_k = bs if bs_k is None else bs_k
    bs_c = bs if bs_c is None else bs_c
    square = (nb_k, nb_c, bs_k, bs_c) == (nb, nb, bs, bs)
    fn = plan_mod.get_compiled(
        mesh,
        engine,
        nb,
        bs,
        dtype,
        threshold=threshold,
        backend=backend,
        c_layout=c_layout,
        l=l,
        stack_capacity=stack_capacity,
        tile=tile,
        interpret=interpret,
        transport=transport,
        **({} if square else dict(nb_k=nb_k, nb_c=nb_c,
                                  bs_k=bs_k, bs_c=bs_c)),
    )
    a_blk = jax.ShapeDtypeStruct((nb, nb_k, bs, bs_k), dtype)
    b_blk = jax.ShapeDtypeStruct((nb_k, nb_c, bs_k, bs_c), dtype)
    am_b = jax.ShapeDtypeStruct((nb, nb_k), jnp.bool_)
    am_f = jax.ShapeDtypeStruct((nb, nb_k), jnp.float32)
    bm_b = jax.ShapeDtypeStruct((nb_k, nb_c), jnp.bool_)
    bm_f = jax.ShapeDtypeStruct((nb_k, nb_c), jnp.float32)
    return fn.lower(a_blk, am_b, am_f, b_blk, bm_b, bm_f)
