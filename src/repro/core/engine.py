"""Engine dispatcher: the public ``multiply`` entry point.

Engines (paper terminology in parentheses):

  cannon    — 2D Cannon, ring point-to-point shifts (PTP, Algorithm 1)
  onesided  — 2D pull-from-home streaming, no pre-shift (OS1, Alg. 2, L=1)
  gather    — 2D pull-from-home via fused all-gather (TPU-native OS1)
  twofive   — 2.5D with depth axis L (OSL, Algorithm 2)

A single-device reference (`multiply_reference`) implements the identical
filtered semantics without any mesh — the oracle for every engine test.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bsm import BlockSparseMatrix, block_norms, filter_bsm
from repro.core.cannon import multiply_2d
from repro.core.gather import multiply_gather
from repro.core.local_mm import local_filtered_mm
from repro.core.twofive import multiply_25d

ENGINES = ("cannon", "onesided", "gather", "twofive")


@partial(jax.jit, static_argnames=("threshold", "backend"))
def multiply_reference(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    threshold: float = 0.0,
    backend: str = "jnp",
) -> BlockSparseMatrix:
    """Single-device filtered block multiply (oracle)."""
    cb, cm = local_filtered_mm(
        a.blocks,
        a.mask,
        a.norms,
        b.blocks,
        b.mask,
        b.norms,
        threshold=threshold,
        backend=backend,
    )
    return BlockSparseMatrix(blocks=cb, mask=cm, norms=block_norms(cb))


def multiply(
    a: BlockSparseMatrix,
    b: BlockSparseMatrix,
    mesh=None,
    *,
    engine: str = "twofive",
    threshold: float = 0.0,
    filter_eps: float | None = None,
    backend: str = "jnp",
    c_layout: str = "2d",
) -> BlockSparseMatrix:
    """Distributed filtered C = A . B.

    threshold  — on-the-fly filter: skip block products with
                 norm(A_ik) * norm(B_kj) <= threshold.
    filter_eps — post-multiplication filter: drop result blocks with
                 norm <= filter_eps (defaults to ``threshold``).
    """
    if mesh is None:
        c = multiply_reference(a, b, threshold=threshold, backend=backend)
    elif engine in ("cannon", "onesided"):
        c = multiply_2d(
            a, b, mesh, engine=engine, threshold=threshold, backend=backend
        )
    elif engine == "gather":
        c = multiply_gather(a, b, mesh, threshold=threshold, backend=backend)
    elif engine == "twofive":
        c = multiply_25d(
            a, b, mesh, threshold=threshold, backend=backend, c_layout=c_layout
        )
    else:
        raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")
    eps = threshold if filter_eps is None else filter_eps
    if eps > 0.0:
        c = filter_bsm(c, eps)
    return c


def lower_multiply(
    mesh,
    nb: int,
    bs: int,
    *,
    engine: str = "twofive",
    threshold: float = 0.0,
    backend: str = "jnp",
    dtype=jnp.float32,
    c_layout: str = "2d",
):
    """Lower (without executing) one multiplication for HLO inspection —
    the source of the measured collective bytes in the benchmarks."""
    from repro.core import cannon as _cannon
    from repro.core import gather as _gather
    from repro.core import twofive as _twofive

    if engine in ("cannon", "onesided"):
        fn = {
            "cannon": _cannon.cannon_shardmap,
            "onesided": _cannon.onesided_shardmap,
        }[engine](mesh, threshold=threshold, backend=backend)
    elif engine == "gather":
        fn = _gather.gather_shardmap(mesh, threshold=threshold, backend=backend)
    elif engine == "twofive":
        fn = _twofive.twofive_shardmap(
            mesh, threshold=threshold, backend=backend, c_layout=c_layout
        )
    else:
        raise ValueError(engine)

    blk = jax.ShapeDtypeStruct((nb, nb, bs, bs), dtype)
    m2b = jax.ShapeDtypeStruct((nb, nb), jnp.bool_)
    m2f = jax.ShapeDtypeStruct((nb, nb), jnp.float32)
    return jax.jit(fn).lower(blk, m2b, m2f, blk, m2b, m2f)
