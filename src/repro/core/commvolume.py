"""Communication-volume and memory model of the paper (Eq. (6), (7)).

All quantities are *per process*, per multiplication, in units of the panel
sizes ``s_a``, ``s_b``, ``s_c`` (bytes or elements — caller's choice).

Paper Eq. (7): total requested data per process

    (V / sqrt(L)) * (S_A + S_B)   +   (L - 1) * S_C

giving O(1/sqrt(P*L)) scaling for the communicated volume, while the memory
footprint grows by O(L) (Eq. (6)).

These analytic values are cross-checked in the benchmarks against the
*measured* collective bytes of the lowered shard_map programs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.topology import Topology, make_topology


@dataclass(frozen=True)
class VolumeReport:
    engine: str
    p_r: int
    p_c: int
    l: int
    ticks: int
    ab_volume: float  # A+B panel traffic per process
    c_volume: float  # partial-C reduction traffic per process
    total: float


def ptp_volume(topo: Topology, s_a: float, s_b: float) -> VolumeReport:
    """Cannon + point-to-point (Algorithm 1): V shifts of A and B panels,
    plus the pre-shift (2 extra panel transfers)."""
    v = topo.v
    ab = v * (s_a + s_b) + (s_a + s_b)  # ticks + pre-shift
    return VolumeReport("ptp", topo.p_r, topo.p_c, 1, v, ab, 0.0, ab)


def osl_volume(topo: Topology, s_a: float, s_b: float, s_c: float) -> VolumeReport:
    """One-sided 2.5D (Algorithm 2), paper Eq. (7). L=1 gives OS1 (no
    pre-shift, same tick volume as PTP)."""
    v, l = topo.v, topo.l
    ab = (v / math.sqrt(l)) * (s_a + s_b)
    c = (l - 1) * s_c
    return VolumeReport(
        f"os{l}", topo.p_r, topo.p_c, l, v // l, ab, c, ab + c
    )


def memory_factor(topo: Topology, s_a: float, s_b: float, s_c: float) -> float:
    """Eq. (6): temporary-buffer memory growth of OSL relative to OS1."""
    l = topo.l
    if l == 1:
        return 1.0
    base = s_c / (3.0 * (s_a + s_b)) * l
    if topo.square:
        return base + (math.isqrt(l) + 4.0) / 6.0
    return base + 1.0


def volume_ratio_os1_over_osl(
    topo: Topology, s_a: float, s_b: float, s_c: float
) -> float:
    """Figure 3 of the paper: OS1 volume / OSL volume (>1 == OSL wins)."""
    os1 = osl_volume(make_topology(topo.p_r, topo.p_c, 1), s_a, s_b, s_c)
    osl = osl_volume(topo, s_a, s_b, s_c)
    return os1.total / osl.total


def scaling_per_process(p: int, l: int, n_elems: float) -> float:
    """O(1/sqrt(P*L)) communicated-volume scaling law (for plots): the
    communicated A+B volume per process for an n x n matrix on P processes
    re-factored with depth L (square topology)."""
    return 2.0 * n_elems / math.sqrt(p * l)


def mesh25d_volume(
    s: int, l: int, s_a: float, s_b: float, s_c: float
) -> VolumeReport:
    """Volume model for the *mesh formulation* used by the JAX engine
    (`repro.core.twofive`): an (L, s, s) device mesh where every layer runs
    s/L Cannon ticks over its k-slice and partial C is reduce-scattered over
    the L axis.  Panel sizes here are the (N/s)^2-block panels.

    Equivalent asymptotics to Eq. (7): AB volume = (s/L)(S_A+S_B) panels =
    2 N^2 / (s L) elements = O(1/sqrt(P L)) with P = L s^2.
    """
    ticks = s // l
    ab = (ticks - 1 + 1) * (s_a + s_b) + (s_a + s_b)  # ticks + pre-shift
    c = (l - 1) / l * s_c  # reduce-scatter bytes over the depth axis
    return VolumeReport(f"mesh25d-l{l}", s, s, l, ticks, ab, c, ab + c)
