"""Communication-volume and memory model of the paper (Eq. (6), (7)).

All quantities are *per process*, per multiplication, in units of the panel
sizes ``s_a``, ``s_b``, ``s_c`` (bytes or elements — caller's choice).

Paper Eq. (7): total requested data per process

    (V / sqrt(L)) * (S_A + S_B)   +   (L - 1) * S_C

giving O(1/sqrt(P*L)) scaling for the communicated volume, while the memory
footprint grows by O(L) (Eq. (6)).

These analytic values are cross-checked in the benchmarks against the
*measured* collective bytes of the lowered shard_map programs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.topology import Topology, make_topology


@dataclass(frozen=True)
class VolumeReport:
    engine: str
    p_r: int
    p_c: int
    l: int
    ticks: int
    ab_volume: float  # A+B panel traffic per process
    c_volume: float  # partial-C reduction traffic per process
    total: float


def ptp_volume(topo: Topology, s_a: float, s_b: float) -> VolumeReport:
    """Cannon + point-to-point (Algorithm 1): V shifts of A and B panels,
    plus the pre-shift (2 extra panel transfers)."""
    v = topo.v
    ab = v * (s_a + s_b) + (s_a + s_b)  # ticks + pre-shift
    return VolumeReport("ptp", topo.p_r, topo.p_c, 1, v, ab, 0.0, ab)


def osl_volume(topo: Topology, s_a: float, s_b: float, s_c: float) -> VolumeReport:
    """One-sided 2.5D (Algorithm 2), paper Eq. (7). L=1 gives OS1 (no
    pre-shift, same tick volume as PTP)."""
    v, l = topo.v, topo.l
    ab = (v / math.sqrt(l)) * (s_a + s_b)
    c = (l - 1) * s_c
    return VolumeReport(
        f"os{l}", topo.p_r, topo.p_c, l, v // l, ab, c, ab + c
    )


def memory_factor(topo: Topology, s_a: float, s_b: float, s_c: float) -> float:
    """Eq. (6): temporary-buffer memory growth of OSL relative to OS1."""
    l = topo.l
    if l == 1:
        return 1.0
    base = s_c / (3.0 * (s_a + s_b)) * l
    if topo.square:
        return base + (math.isqrt(l) + 4.0) / 6.0
    return base + 1.0


def volume_ratio_os1_over_osl(
    topo: Topology, s_a: float, s_b: float, s_c: float
) -> float:
    """Figure 3 of the paper: OS1 volume / OSL volume (>1 == OSL wins)."""
    os1 = osl_volume(make_topology(topo.p_r, topo.p_c, 1), s_a, s_b, s_c)
    osl = osl_volume(topo, s_a, s_b, s_c)
    return os1.total / osl.total


def scaling_per_process(p: int, l: int, n_elems: float) -> float:
    """O(1/sqrt(P*L)) communicated-volume scaling law (for plots): the
    communicated A+B volume per process for an n x n matrix on P processes
    re-factored with depth L (square topology)."""
    return 2.0 * n_elems / math.sqrt(p * l)


def _panel_bytes(rows: int, cols: int, bs: int, itemsize: float,
                 bs2: int | None = None) -> float:
    """Wire bytes of one (rows x cols)-block panel as the engines move it
    under dense transport: blocks (itemsize) + occupation mask (1 byte).
    Norms never ride the wire any more — they are recomputed from the
    received blocks (``transport.panel_norms``).  ``bs2`` (default ``bs``)
    is the second atomic-block dim of a rectangular-block panel."""
    return rows * cols * (bs * (bs if bs2 is None else bs2) * itemsize + 1.0)


def _packed_bytes(entries: float, bs: int, itemsize: float,
                  bs2: int | None = None) -> float:
    """Wire bytes of one compressed panel: ``entries`` packed blocks plus
    the one-based int32 index array (``transport.pack_panel``)."""
    return entries * (bs * (bs if bs2 is None else bs2) * itemsize + 4.0)


def _transport_spec(
    transport,
) -> tuple[str, float | None, float | None, float | None]:
    """Normalize a transport argument for the volume model: mode plus
    exact per-panel capacities when available (a resolved
    ``PanelTransport``), or None capacities for the occupancy-scaled
    analytic flavor (mode given as the string "compressed").  The fourth
    element is the wire itemsize a non-native wire format pins (None =
    charge the caller's storage ``itemsize``) — index and mask overheads
    always stay at their own fixed widths."""
    if transport is None or transport == "dense":
        return "dense", None, None, None
    if transport == "compressed":
        return "compressed", None, None, None
    if getattr(transport, "mode", None) in ("dense", "compressed"):
        wire = getattr(transport, "wire", "native")
        w = None if wire == "native" else float(np.dtype(wire).itemsize)
        if transport.mode == "dense":
            return "dense", None, None, w
        return ("compressed", float(transport.cap_a),
                float(transport.cap_b), w)
    raise ValueError(f"unknown transport spec {transport!r}")


def plan_volume(
    plan,
    nb: int,
    bs: int,
    *,
    itemsize: float = 4.0,
    c_layout: str = "2d",
    transport=None,
    occ_a: float = 1.0,
    occ_b: float = 1.0,
    nb_k: int | None = None,
    nb_c: int | None = None,
    bs_k: int | None = None,
    bs_c: int | None = None,
) -> VolumeReport:
    """Predicted per-device collective wire bytes of one multiplication
    executed from ``plan`` — the paper's volume model evaluated on the
    *actual compiled schedule*, valid for non-square grids too.

    Sparsity-aware: under compressed transport each A/B hop ships packed
    blocks + indices instead of the dense panel, so the Eq. (7) A/B term
    scales with panel occupancy.  ``transport`` may be a resolved
    ``transport.PanelTransport`` (exact bucketed capacities — what
    ``benchmarks/measure_comm.py`` asserts against the compiled HLO) or
    the string ``"compressed"`` with ``occ_a``/``occ_b`` (the tuner's
    analytic flavor: entries ~= occupancy x panel blocks, no bucketing).

    Mirrors the accounting conventions of ``roofline.hlo_cost.analyze_hlo``
    so ``benchmarks/measure_comm.py`` can compare measured vs. modeled:
    collective-permute costs its full payload; all-gather (n-1)/n of the
    gathered output; all-reduce 2(n-1)/n; reduce-scatter (n-1) x output.

    ``nb_k``/``nb_c``/``bs_k``/``bs_c`` (default: square) price a
    rectangular matricized product: A panels are (nb x nb_k) grids of
    bs x bs_k blocks, B (nb_k x nb_c) of bs_k x bs_c, C (nb x nb_c) of
    bs x bs_c.  Square callers' numbers are unchanged.
    """
    topo = plan.topo
    p_r, p_c, depth = plan.p_r, plan.p_c, topo.l
    nb_k = nb if nb_k is None else nb_k
    nb_c = nb if nb_c is None else nb_c
    bs_k = bs if bs_k is None else bs_k
    bs_c = bs if bs_c is None else bs_c
    ar, ac = nb // p_r, nb_k // p_c  # A home shard (block rows, cols)
    br, bc = nb_k // p_r, nb_c // p_c  # B home shard
    cr, cc = nb // p_r, nb_c // p_c  # C home shard
    mode, cap_a, cap_b, wire_item = _transport_spec(transport)
    # A/B panel payloads travel at the WIRE width (bf16 wire on f32
    # storage halves them; bf16 storage halves them natively via the
    # caller's itemsize); partial-C traffic is accumulator state and
    # always moves at storage width.
    ab_item = itemsize if wire_item is None else wire_item

    def hop_a(rows: int, cols: int) -> float:
        if mode == "compressed":
            n = cap_a if cap_a is not None else occ_a * rows * cols
            return _packed_bytes(n, bs, ab_item, bs_k)
        return _panel_bytes(rows, cols, bs, ab_item, bs_k)

    def hop_b(rows: int, cols: int) -> float:
        if mode == "compressed":
            n = cap_b if cap_b is not None else occ_b * rows * cols
            return _packed_bytes(n, bs_k, ab_item, bs_c)
        return _panel_bytes(rows, cols, bs_k, ab_item, bs_c)

    if plan.kind == "pull":
        wa = ac // plan.ca  # A subpanel block-cols (= nb_k / V)
        wb = br // plan.cb  # B subpanel block-rows
        ab = 0.0
        for g in range(plan.ticks):
            ab += len(plan.a_pulls[g]) * hop_a(ar, wa)
            ab += len(plan.b_pulls[g]) * hop_b(wb, bc)
        # L-1 partial-C sends: blocks + mask (always dense — the partial
        # panels are accumulator state, not home panels with known bounds)
        c = len(plan.c_rounds) * (cr * cc * bs * bs_c * itemsize + cr * cc)
        name = f"pull-os{depth}"
    elif plan.kind == "ring":
        # pre-shift + (ticks - 1) double-buffered hops of A and B
        ab = plan.ticks * (hop_a(ar, ac) + hop_b(br, bc))
        c = 0.0
        name = "ring-ptp"
    elif plan.kind == "gather":
        if mode == "compressed":
            # untiled all-gather of each shard's packed buffer + indices:
            # (p-1)/p of the gathered (p, capacity, ...) output
            na = cap_a if cap_a is not None else occ_a * ar * ac
            nb_e = cap_b if cap_b is not None else occ_b * br * bc
            ga = (p_c - 1) * _packed_bytes(na, bs, ab_item, bs_k)
            gb = (p_r - 1) * _packed_bytes(nb_e, bs_k, ab_item, bs_c)
        else:
            ga = _panel_bytes(ar, nb_k, bs, ab_item, bs_k) * (p_c - 1) / p_c
            gb = _panel_bytes(nb_k, bc, bs_k, ab_item, bs_c) * (p_r - 1) / p_r
        ab, c = ga + gb, 0.0
        name = "gather"
    elif plan.kind == "stacked":
        ab = plan.ticks * (hop_a(ar, ac) + hop_b(br, bc))
        cb = cr * cc * bs * bs_c * itemsize + cr * cc * 4.0  # blocks + i32 mask
        if c_layout == "2d":
            c = 2.0 * cb * (depth - 1) / depth  # all-reduce over l
        else:
            c = (depth - 1) * cb / depth  # reduce-scatter: (n-1) x output
        name = f"stacked-l{depth}"
    else:
        raise ValueError(plan.kind)
    if mode == "compressed":
        name += "+ct"
    return VolumeReport(
        name, p_r, p_c, depth, plan.ticks, ab, c, ab + c
    )


def device_memory_bytes(
    plan,
    nb: int,
    bs: int,
    *,
    itemsize: float = 4.0,
    c_layout: str = "2d",
    stack_capacity: int = 0,
    nb_k: int | None = None,
    nb_c: int | None = None,
    bs_k: int | None = None,
    bs_c: int | None = None,
) -> float:
    """Eq. (6) rendered in bytes: per-device memory footprint of one
    multiplication executed from ``plan``.

    Three terms, mirroring the paper's accounting:

    * the home shards of A, B and C (the O(1) baseline);
    * temporary panel buffers, counted with the paper's §3 buffer model
      (``Topology.total_buffers``: 4 for PTP, 6 for OS1, L+6 / L+sqrt(L)+4
      for OSL — the O(L) growth of Eq. (6)) at the panel granularity the
      plan actually moves, PLUS the extra in-flight panel generation the
      double-buffered pipelining keeps (three generations per operand on
      the ring engines, one prefetched tick group for the pull
      formulation — DESIGN.md §3), plus the L-1 partial-C accumulators
      of the pull formulation; the gather plan instead stages the full
      gathered row/column panels;
    * the compacted-backend stack arrays when ``stack_capacity`` > 0:
      gathered A/B operands, the product buffer (f32) and the seven
      int32 index arrays of ``kernels.stacks.ProductStacks``.

    The tuner prunes every candidate whose footprint exceeds the
    per-device budget — the one decision the measured trials must never
    be allowed to make (an OOM trial is not a data point).

    Panel temporaries are counted at their dense size regardless of
    transport: compressed buffers are strictly smaller (packed blocks +
    indices, unpacked transiently for the GEMM), so the dense accounting
    stays a sound upper bound for the prune.

    ``nb_k``/``nb_c``/``bs_k``/``bs_c`` (default: square) account a
    rectangular matricized product; square callers' numbers are unchanged.
    """
    topo = plan.topo
    nb_k = nb if nb_k is None else nb_k
    nb_c = nb if nb_c is None else nb_c
    bs_k = bs if bs_k is None else bs_k
    bs_c = bs if bs_c is None else bs_c
    ar, ac = nb // plan.p_r, nb_k // plan.p_c
    br, bc = nb_k // plan.p_r, nb_c // plan.p_c
    cr, cc = nb // plan.p_r, nb_c // plan.p_c
    shard_a = _panel_bytes(ar, ac, bs, itemsize, bs_k)
    shard_b = _panel_bytes(br, bc, bs_k, itemsize, bs_c)
    shard_c = _panel_bytes(cr, cc, bs, itemsize, bs_c)
    total = shard_a + shard_b + shard_c  # A, B, C home shards
    if plan.kind == "ring":
        # pipelined ring: three panel generations per operand in flight
        # (current / next / prefetched hop — cannon.ring_body)
        total += 3.0 * (shard_a + shard_b)
    elif plan.kind == "gather":
        # gathered A row panel / B col panel
        total += _panel_bytes(ar, nb_k, bs, itemsize, bs_k)
        total += _panel_bytes(nb_k, bc, bs_k, itemsize, bs_c)
    elif plan.kind == "pull":
        sub = max(
            _panel_bytes(ar, ac // plan.ca, bs, itemsize, bs_k),  # A subpanel
            _panel_bytes(br // plan.cb, bc, bs_k, itemsize, bs_c),  # B subpanel
        )
        total += topo.total_buffers * sub
        # the prefetched next tick group's panel set (pull pipelining)
        total += (topo.l_r + topo.l_c) * sub
        total += (topo.l - 1) * shard_c  # partial C panels of the L targets
    elif plan.kind == "stacked":
        # pipelined ring panels: three generations per operand
        total += 3.0 * (shard_a + shard_b)
        # reduction buffer over the depth axis
        total += shard_c if c_layout == "2d" else shard_c / topo.l
    else:
        raise ValueError(plan.kind)
    if stack_capacity > 0:
        # gathered a, b + f32 product per entry
        gemm = (bs * bs_k + bs_k * bs_c + bs * bs_c) * 4.0
        total += stack_capacity * (gemm + 7 * 4.0)
    return total


def device_product_loads(
    counts: np.ndarray, p_r: int, p_c: int, perm=None
) -> np.ndarray:
    """Per-device product load over a (p_r, p_c) grid: the mask-product
    ``counts`` (A_mask @ B_mask as integers — surviving block products per
    C block) summed over each device's (row panel, col panel).  ``perm``
    optionally views the grid under a symmetric block assignment
    (``core.distribute``) without materializing the permuted matrices.
    """
    counts = np.asarray(counts, np.int64)
    if perm is not None:
        p = np.asarray(perm)
        counts = counts[p][:, p]
    nb_r, nb_c = counts.shape
    if nb_r % p_r or nb_c % p_c:
        raise ValueError(
            f"block grid {nb_r}x{nb_c} does not divide mesh {p_r}x{p_c}"
        )
    return counts.reshape(
        p_r, nb_r // p_r, p_c, nb_c // p_c
    ).sum(axis=(1, 3))


def load_imbalance(
    counts: np.ndarray, p_r: int, p_c: int, perm=None
) -> float:
    """Max/mean per-device product load (1.0 = perfectly balanced).  The
    slowest device gates every tick barrier, so compacted local compute —
    priced at mean load by ``local_mm.local_stage_cost`` — stretches by
    exactly this factor; the tuner's model multiplies it in
    (``tuner/model.py``) and the scheduler's job is to drive it back
    toward 1 by choosing an assignment."""
    loads = device_product_loads(counts, p_r, p_c, perm=perm)
    mean = float(loads.mean())
    if mean <= 0.0:
        return 1.0
    return float(loads.max()) / mean


def mesh25d_volume(
    s: int, l: int, s_a: float, s_b: float, s_c: float
) -> VolumeReport:
    """Volume model for the *mesh formulation* used by the JAX engine
    (`repro.core.twofive`): an (L, s, s) device mesh where every layer runs
    s/L Cannon ticks over its k-slice and partial C is reduce-scattered over
    the L axis.  Panel sizes here are the (N/s)^2-block panels.

    Equivalent asymptotics to Eq. (7): AB volume = (s/L)(S_A+S_B) panels =
    2 N^2 / (s L) elements = O(1/sqrt(P L)) with P = L s^2.
    """
    ticks = s // l
    ab = (ticks - 1 + 1) * (s_a + s_b) + (s_a + s_b)  # ticks + pre-shift
    c = (l - 1) / l * s_c  # reduce-scatter bytes over the depth axis
    return VolumeReport(f"mesh25d-l{l}", s, s, l, ticks, ab, c, ab + c)
