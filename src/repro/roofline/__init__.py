"""Three-term roofline analysis from compiled XLA artifacts.

CPU-only container: TPU v5e is the *target*, not the runtime, so wall-clock
MFU cannot be measured.  Instead every dry-run cell derives, from the
compiled SPMD module (which is the per-device program):

    compute term     = HLO_FLOPs_per_device / peak_FLOP/s
    memory term      = HLO_bytes_per_device / HBM_bw
    collective term  = collective_wire_bytes_per_device / ICI_bw

(The prompt's "HLO_FLOPs / (chips x peak)" with module-total FLOPs equals
our "per-device / peak" — XLA's cost_analysis on the partitioned module
already reports per-device numbers.)

``collective_bytes`` is not in cost_analysis: ``parse_collectives`` scans
the optimized HLO text, sums operand/result sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, and converts
to wire bytes with the standard ring-algorithm factors:

    all-gather        (n-1)/n * gathered_bytes
    reduce-scatter    (n-1)   * scattered_bytes    (== (n-1)/n * input)
    all-reduce        2 (n-1)/n * payload_bytes    (ring RS + AG)
    all-to-all        (n-1)/n * payload_bytes
    collective-permute  payload_bytes

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (one link's worth per chip is the conservative per-chip injection rate
used for the collective term).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# --- TPU v5e target constants ------------------------------------------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per-chip injection, conservative)

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0,
    "s2": 1, "u2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `%name = SHAPE op(` where SHAPE is `bf16[1,2,3]{...}` or a (tuple, of, them)
_INSTR_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")


def shape_bytes(shape_text: str) -> int:
    """Total bytes of one HLO shape string (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = m.group(1)
        return len(ids.split(",")) if ids else 1
    return default


@dataclass
class CollectiveStats:
    """Per-device collective traffic of one compiled module."""

    by_kind_bytes: dict[str, float] = field(default_factory=dict)
    by_kind_count: dict[str, int] = field(default_factory=dict)
    wire_bytes: float = 0.0  # ring-model bytes on the wire, per device
    payload_bytes: float = 0.0  # raw summed result sizes

    def add(self, kind: str, payload: int, wire: float) -> None:
        self.by_kind_bytes[kind] = self.by_kind_bytes.get(kind, 0.0) + wire
        self.by_kind_count[kind] = self.by_kind_count.get(kind, 0) + 1
        self.wire_bytes += wire
        self.payload_bytes += payload


def parse_collectives(hlo_text: str, *, default_group: int = 1) -> CollectiveStats:
    """Sum collective traffic from optimized HLO text (one device's module)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m is None:
            continue
        shape_text, op = m.group(1), m.group(2)
        kind = op.removesuffix("-start")
        payload = shape_bytes(shape_text)
        n = max(_group_size(line, default_group), 1)
        if kind == "all-gather":
            # result shape is the gathered (full) buffer
            wire = payload * (n - 1) / n
        elif kind == "reduce-scatter":
            # result shape is the scattered (1/n) buffer; input = n * result
            wire = payload * (n - 1)
        elif kind == "all-reduce":
            wire = 2.0 * payload * (n - 1) / n
        elif kind == "all-to-all":
            wire = payload * (n - 1) / n
        else:  # collective-permute
            wire = float(payload)
        stats.add(kind, payload, wire)
    return stats


# ---------------------------------------------------------------------------
# roofline report
# ---------------------------------------------------------------------------


@dataclass
class RooflineReport:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_flops_ratio: float  # MODEL_FLOPS / (per-device HLO flops * chips)
    collectives: CollectiveStats
    memory: dict[str, float]
    top_collectives: list = field(default_factory=list)
    top_memory: list = field(default_factory=list)
    top_flops: list = field(default_factory=list)
    # memory term with attention-prob tile traffic replaced by the Pallas
    # flash kernel's true HBM streaming (the TPU perf path)
    memory_s_kernel: float = 0.0
    attn_tile_bytes: float = 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step spent on the compute roof if terms overlap
        perfectly: compute_s / max(all terms) — 1.0 means compute-bound at
        the roof."""
        b = self.bound_s
        return self.compute_s / b if b > 0 else 0.0

    def to_json(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_by_kind_bytes": self.collectives.by_kind_bytes,
            "collective_by_kind_count": self.collectives.by_kind_count,
            "memory": self.memory,
            "top_collectives": [[b, d] for b, d in self.top_collectives[:8]],
            "top_memory": [[b, d] for b, d in self.top_memory[:8]],
            "top_flops": [[b, d] for b, d in self.top_flops[:8]],
            "memory_s_kernel": self.memory_s_kernel,
            "attn_tile_bytes": self.attn_tile_bytes,
        }


def analyze(
    compiled,
    *,
    n_chips: int,
    model_flops_total: float,
    peak_flops: float = PEAK_FLOPS,
    hbm_bw: float = HBM_BW,
    ici_bw: float = ICI_BW,
    attn_tile_signature: tuple[int, int] | None = (512, 1024),
    flash_kernel_bytes: float = 0.0,
) -> RooflineReport:
    """Roofline terms from one compiled (SPMD-partitioned) executable.

    Uses the trip-count-aware HLO cost model (``hlo_cost.analyze_hlo``):
    XLA's aggregate cost_analysis() counts every while body once, which
    under-counts scanned-layer programs by the layer count (verified in
    tests/test_roofline.py), so it is only kept as a cross-check floor.

    Kernel adjustment: the dry-run lowers the pure-jnp chunked attention
    (the CPU oracle), which streams (q_chunk x kv_chunk) f32 probability
    tiles through HBM.  The TPU perf path is the Pallas flash kernel
    (kernels/flash_attention.py) where those tiles live in VMEM.  The
    report therefore carries BOTH memory terms: raw HLO, and
    kernel-adjusted = raw - measured tile traffic + ``flash_kernel_bytes``
    (the kernel's true Q/K/V/O streaming, computed analytically by the
    caller).  EXPERIMENTS.md §Roofline reports both.
    """
    from repro.roofline.hlo_cost import analyze_hlo

    hlo = compiled.as_text()
    cost = analyze_hlo(
        hlo, default_group=n_chips, attn_tile_signature=attn_tile_signature
    )
    flops = cost.flops
    hbm_bytes = cost.hbm_bytes

    stats = CollectiveStats(
        by_kind_bytes=dict(cost.by_kind_bytes),
        by_kind_count={k: int(v) for k, v in cost.by_kind_count.items()},
        wire_bytes=cost.collective_wire_bytes,
        payload_bytes=cost.collective_payload_bytes,
    )

    mem = compiled.memory_analysis()
    memory = {
        "argument_bytes": float(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": float(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": float(getattr(mem, "alias_size_in_bytes", 0)),
        "peak_bytes": float(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        ),
    }

    compute_s = flops / peak_flops
    memory_s = hbm_bytes / hbm_bw
    collective_s = stats.wire_bytes / ici_bw
    adj_bytes = max(hbm_bytes - cost.attn_tile_bytes + flash_kernel_bytes, 0.0)
    memory_s_kernel = adj_bytes / hbm_bw
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    total_hlo_flops = flops * n_chips
    useful = model_flops_total / total_hlo_flops if total_hlo_flops else 0.0
    return RooflineReport(
        flops_per_device=flops,
        hbm_bytes_per_device=hbm_bytes,
        collective_bytes_per_device=stats.wire_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_total=model_flops_total,
        useful_flops_ratio=useful,
        collectives=stats,
        memory=memory,
        top_collectives=cost.top_collectives,
        top_memory=cost.top_memory,
        top_flops=cost.top_flops,
        memory_s_kernel=memory_s_kernel,
        attn_tile_bytes=cost.attn_tile_bytes,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6 N D (train), 2 N D (prefill), 2 N_active B (decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 token / sequence


# local SpGEMM stage flop models (surviving-product accounting) — the
# predicted side of the measured-vs-modeled assertions in test_roofline
from repro.roofline.hlo_cost import (  # noqa: E402
    spgemm_dense_flops,
    spgemm_stacks_flops,
)
