"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 40 layers reports the FLOPs/bytes/collectives of a single
layer (verified: scan length 1 vs 10 give identical 'flops').  Training
steps bury >95 % of their work inside while loops (layer scan, chunked-CE
scan, SSM sequence scans, remat'd backward scans), so the aggregate numbers
are useless for a roofline.

This module re-derives per-device cost from the *optimized HLO text*:

1. split the module into computations and per-computation symbol tables
   (every instruction line defines ``%name = shape op(...)``);
2. build the call graph (fusion ``calls=``, ``to_apply=``, while
   ``body=/condition=``, conditional branches);
3. extract while trip counts from the condition computation's loop-bound
   constant (lax.scan lowers to a 0..N counter compared LT N);
4. propagate an execution-count multiplier from ENTRY through the graph
   (while bodies multiply by their trip count);
5. cost instructions x multiplier:
     * FLOPs: dot/dot-general (2 * prod(out) * prod(contracting)) and
       convolutions (2 * prod(out) * prod(kernel_spatial) * in_features);
     * collective wire bytes: ring-model factors per collective kind;
     * HBM bytes: operands + outputs of every *top-level* instruction
       (fusion-internal intermediates never touch HBM, so fused
       computations are costed as one instruction — XLA's own convention).

The model is validated against cost_analysis() on loop-free modules
(tests/test_roofline.py) and against analytic transformer FLOP counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1,
    "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# `  %name = shape op(operands), attrs` (ROOT optional, % optional).
# The shape is matched lazily: tuple shapes embed `/*index=N*/` comments (and
# thus `=` characters), so the shape group is "everything up to the first
# ` op(` occurrence" — opcode then open-paren.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$"
)
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_TRIP_COUNT_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")

COLLECTIVE_OPS = {
    "all-gather", "all-gather-start",
    "all-reduce", "all-reduce-start",
    "reduce-scatter",
    "all-to-all",
    "collective-permute", "collective-permute-start",
}

# ops that are pure bookkeeping — no HBM traffic attributed
_NO_MEM_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "while", "conditional", "call", "iota",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
    "async-done", "async-update", "partition-id", "replica-id",
    "opt-barrier", "domain",
}


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    jax >= 0.5 returns one flat dict; 0.4.x returns a list with one dict per
    partition (usually length 1).  Always returns a dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        if not ca:
            return {}
        out: dict = {}
        for part in ca:
            for k, v in part.items():
                out[k] = out.get(k, 0.0) + v if isinstance(v, (int, float)) else v
        return out
    return ca


def shape_elems_bytes(shape_text: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


def shape_dims(shape_text: str) -> list[int]:
    """Dims of the FIRST array shape in the text."""
    m = _SHAPE_RE.search(shape_text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Instruction:
    name: str
    shape: str
    op: str
    rest: str  # operands + attributes (the tail of the line)

    def operand_names(self) -> list[str]:
        """Names inside the top-level parens (until the matching close).

        Operands may be typed (``f32[2,3]{1,0} %name``) — the shape carries
        commas and braces, so splitting happens only at paren depth 1 outside
        any ``[]``/``{}`` nesting.
        """
        depth = 1
        bracket = 0
        parts: list[str] = []
        token = ""
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            elif ch in "[{":
                bracket += 1
            elif ch in "]}":
                bracket -= 1
            elif ch == "," and depth == 1 and bracket == 0:
                parts.append(token)
                token = ""
                continue
            token += ch
        if token:
            parts.append(token)
        out = []
        for part in parts:
            part = part.strip()
            m = re.match(r"%?([\w.\-]+)$", part)
            if m:
                out.append(m.group(1))
            else:
                # typed operand like `f32[2,3] %name`
                m = re.search(r"%([\w.\-]+)\s*$", part)
                if m:
                    out.append(m.group(1))
        return out


@dataclass
class Computation:
    name: str
    is_entry: bool
    instructions: list[Instruction] = field(default_factory=list)
    symbols: dict[str, str] = field(default_factory=dict)  # name -> shape


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hm = _COMP_HEADER_RE.match(line.strip())
        if hm and "=" not in line.split("(")[0]:
            current = Computation(name=hm.group(2), is_entry=bool(hm.group(1)))
            comps[current.name] = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            instr = Instruction(
                name=im.group(1), shape=im.group(2), op=im.group(3), rest=im.group(4)
            )
            current.instructions.append(instr)
            current.symbols[instr.name] = instr.shape
    return comps


def _while_trip_count(while_ins: Instruction, cond: Computation | None) -> int | None:
    """Trip count of one while op.

    Primary: XLA's own loop analysis, serialized on the instruction as
    ``backend_config={"known_trip_count":{"n":"8"}, ...}``.
    Fallback: the largest scalar constant in the condition computation
    (lax.scan lowers to a 0..N counter compared LT N)."""
    m = _TRIP_COUNT_RE.search(while_ins.rest)
    if m:
        return int(m.group(1))
    if cond is None:
        return None
    best = None
    for ins in cond.instructions:
        if ins.op == "constant":
            cm = re.match(r"\s*\(?\s*(-?\d+)\s*\)?", ins.rest)
            sm = _SHAPE_RE.search(ins.shape)
            if cm and sm is not None and not sm.group(2):  # scalar int
                v = int(cm.group(1))
                if v > 0 and (best is None or v > best):
                    best = v
    return best


@dataclass
class CostReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_payload_bytes: float = 0.0
    by_kind_bytes: dict[str, float] = field(default_factory=dict)
    by_kind_count: dict[str, float] = field(default_factory=dict)
    while_trips: dict[str, int] = field(default_factory=dict)
    unknown_trip_loops: int = 0
    # HBM bytes of attention-probability tiles (shapes ending in the
    # chunked-attention (q_chunk, kv_chunk) signature).  On the TPU target
    # these live in VMEM inside the Pallas flash kernel; the roofline's
    # kernel-adjusted memory term subtracts them (see roofline.analyze).
    attn_tile_bytes: float = 0.0
    # top contributors for debugging / the §Perf hillclimb: (bytes, descr)
    top_collectives: list[tuple[float, str]] = field(default_factory=list)
    top_memory: list[tuple[float, str]] = field(default_factory=list)
    top_flops: list[tuple[float, str]] = field(default_factory=list)

    def finalize(self, k: int = 12) -> "CostReport":
        self.top_collectives = sorted(self.top_collectives, reverse=True)[:k]
        self.top_memory = sorted(self.top_memory, reverse=True)[:k]
        self.top_flops = sorted(self.top_flops, reverse=True)[:k]
        return self


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    out_elems = 1
    for d in shape_dims(ins.shape):
        out_elems *= d
    ops = ins.operand_names()
    if not ops:
        return 0.0
    lhs_shape = comp.symbols.get(ops[0])
    if lhs_shape is None:
        return 2.0 * out_elems  # unknown contraction — floor
    lhs_dims = shape_dims(lhs_shape)
    m = _CONTRACT_RE.search(ins.rest)
    contract = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(ins: Instruction, comp: Computation) -> float:
    out_elems = 1
    for d in shape_dims(ins.shape):
        out_elems *= d
    ops = ins.operand_names()
    if len(ops) < 2:
        return 2.0 * out_elems
    rhs_shape = comp.symbols.get(ops[1])
    if rhs_shape is None:
        return 2.0 * out_elems
    rhs_elems, _ = shape_elems_bytes(rhs_shape)
    rhs_dims = shape_dims(rhs_shape)
    out_features = rhs_dims[-1] if rhs_dims else 1
    per_out = rhs_elems / max(out_features, 1)
    return 2.0 * out_elems * per_out


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        ids = m.group(1)
        return len(ids.split(",")) if ids else 1
    return default


def _collective_wire(kind: str, payload: int, n: int) -> float:
    if kind == "all-gather":
        return payload * (n - 1) / n
    if kind == "reduce-scatter":
        return float(payload) * (n - 1)
    if kind == "all-reduce":
        return 2.0 * payload * (n - 1) / n
    if kind == "all-to-all":
        return payload * (n - 1) / n
    return float(payload)  # collective-permute


def analyze_hlo(
    hlo_text: str,
    *,
    default_group: int = 1,
    attn_tile_signature: tuple[int, int] | None = None,
) -> CostReport:
    comps = parse_module(hlo_text)
    report = CostReport()

    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return report

    # ---- execution-count multipliers over the call graph -----------------
    mult: dict[str, float] = {entry.name: 1.0}
    # fused computations are costed as one instruction for memory, but their
    # dots still count for flops; track which computations are fusion bodies
    fusion_bodies: set[str] = set()

    stack = [entry.name]
    visited: set[str] = set()
    while stack:
        cname = stack.pop()
        if cname in visited or cname not in comps:
            continue
        visited.add(cname)
        comp = comps[cname]
        m = mult.get(cname, 1.0)
        for ins in comp.instructions:
            if ins.op == "while":
                wm = _WHILE_RE.search(ins.rest)
                if not wm:
                    continue
                cond_name, body_name = wm.group(1), wm.group(2)
                trips = _while_trip_count(ins, comps.get(cond_name))
                if trips is None:
                    trips = 1
                    report.unknown_trip_loops += 1
                report.while_trips[ins.name] = trips
                for sub in (body_name, cond_name):
                    mult[sub] = max(mult.get(sub, 0.0), m * trips)
                    stack.append(sub)
            else:
                for regex in (_CALLS_RE, _TO_APPLY_RE):
                    cm = regex.search(ins.rest)
                    if cm:
                        sub = cm.group(1)
                        mult[sub] = max(mult.get(sub, 0.0), m)
                        stack.append(sub)
                        if ins.op == "fusion":
                            fusion_bodies.add(sub)
                bm = _BRANCHES_RE.search(ins.rest)
                if bm:
                    for sub in _OPERAND_RE.findall(bm.group(1)):
                        mult[sub] = max(mult.get(sub, 0.0), m)
                        stack.append(sub)

    # ---- slice-aware fusion parameter traffic -----------------------------
    # A kLoop fusion whose body dynamic-slices one of its parameters reads
    # only the slice, not the whole (often loop-carried, often huge) buffer.
    # For each fused computation, map parameter index -> bytes actually read
    # when a slicing op consumes that parameter directly.
    sliced_params: dict[str, dict[int, float]] = {}
    # fusions that in-place dynamic-update-slice a parameter: the fusion's
    # real traffic is the update region (r/w), not the whole carried buffer
    dus_fusions: dict[str, float] = {}  # fused comp -> update bytes
    dus_param_idx: dict[str, set[int]] = {}  # params aliased by the DUS
    for cname in fusion_bodies:
        comp = comps.get(cname)
        if comp is None:
            continue
        param_index: dict[str, int] = {}
        for ins in comp.instructions:
            if ins.op == "parameter":
                pm = re.match(r"\s*(\d+)", ins.rest)
                if pm:
                    param_index[ins.name] = int(pm.group(1))
        slices: dict[int, float] = {}
        # follow simple pass-through ops (bitcast/copy/convert of a param)
        alias_of: dict[str, str] = {}
        for ins in comp.instructions:
            if ins.op in ("bitcast", "copy", "convert", "reshape", "transpose"):
                ops = ins.operand_names()
                if ops:
                    root = alias_of.get(ops[0], ops[0])
                    alias_of[ins.name] = root
        for ins in comp.instructions:
            ops = [alias_of.get(o, o) for o in ins.operand_names()]
            if ins.op in ("dynamic-slice", "slice", "gather"):
                if ops and ops[0] in param_index:
                    _, sb = shape_elems_bytes(ins.shape)
                    idx = param_index[ops[0]]
                    slices[idx] = slices.get(idx, 0.0) + sb
            elif ins.op == "dynamic-update-slice":
                upd = 0.0
                if len(ops) >= 2:
                    osh = comp.symbols.get(ins.operand_names()[1])
                    if osh is not None:
                        _, upd = shape_elems_bytes(osh)
                dus_fusions[cname] = dus_fusions.get(cname, 0.0) + upd
                if ops and ops[0] in param_index:
                    dus_param_idx.setdefault(cname, set()).add(param_index[ops[0]])
        if slices:
            sliced_params[cname] = slices

    # ---- cost every computation x its multiplier -------------------------
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue  # unreachable (dead) computation
        in_fusion = cname in fusion_bodies
        for ins in comp.instructions:
            op = ins.op
            if op in ("dot", "dot-general"):
                fl = m * _dot_flops(ins, comp)
                report.flops += fl
                report.top_flops.append((fl, f"{ins.name} x{m:g} {ins.shape[:48]}"))
            elif op == "convolution":
                report.flops += m * _conv_flops(ins, comp)

            if op in COLLECTIVE_OPS:
                kind = op.removesuffix("-start")
                _, payload = shape_elems_bytes(ins.shape)
                if op in ("all-gather-start", "collective-permute-start"):
                    # start ops carry (operand, result) tuples; result only
                    payload = payload // 2
                n = max(_group_size(ins.rest, default_group), 1)
                wire = m * _collective_wire(kind, payload, n)
                report.collective_wire_bytes += wire
                report.collective_payload_bytes += m * payload
                report.by_kind_bytes[kind] = report.by_kind_bytes.get(kind, 0.0) + wire
                report.by_kind_count[kind] = report.by_kind_count.get(kind, 0.0) + m
                report.top_collectives.append(
                    (wire, f"{kind} {ins.name} x{m:g} n={n} {ins.shape[:64]}")
                )

            # ---- memory bytes (top-level instructions only) --------------
            if in_fusion or op in _NO_MEM_OPS or op in COLLECTIVE_OPS:
                continue
            _, out_bytes = shape_elems_bytes(ins.shape)
            ops_names = ins.operand_names()
            if op in ("dynamic-slice", "slice", "gather"):
                bytes_ = 2.0 * out_bytes  # read slice + write result
            elif op in ("dynamic-update-slice", "scatter"):
                upd = 0.0
                if len(ops_names) >= 2:
                    osh = comp.symbols.get(ops_names[1])
                    if osh is not None:
                        _, upd = shape_elems_bytes(osh)
                bytes_ = 2.0 * (upd or out_bytes)  # in-place: r/w update region
            else:
                callee = None
                if op == "fusion":
                    cm = _CALLS_RE.search(ins.rest)
                    callee = cm.group(1) if cm else None
                slices = sliced_params.get(callee, {}) if callee else {}
                dus_bytes = dus_fusions.get(callee) if callee else None
                dus_params = dus_param_idx.get(callee, set()) if callee else set()
                operand_bytes = 0.0
                for i, oname in enumerate(ops_names):
                    if i in dus_params:
                        continue  # aliased in-place by the DUS — counted below
                    if i in slices:
                        operand_bytes += slices[i]
                        continue
                    oshape = comp.symbols.get(oname)
                    if oshape is not None:
                        _, ob = shape_elems_bytes(oshape)
                        operand_bytes += ob
                if dus_bytes is not None:
                    # in-place update: read+write the update region only
                    bytes_ = 2.0 * dus_bytes + operand_bytes
                else:
                    bytes_ = out_bytes + operand_bytes
            report.hbm_bytes += m * bytes_
            report.top_memory.append(
                (m * bytes_, f"{op} {ins.name} x{m:g} {ins.shape[:48]}")
            )
            if attn_tile_signature is not None:
                dims = shape_dims(ins.shape)
                if len(dims) >= 2 and tuple(dims[-2:]) == attn_tile_signature:
                    report.attn_tile_bytes += m * bytes_

    return report.finalize()


# ---------------------------------------------------------------------------
# local SpGEMM stage models (predicted side of the HLO assertions)
# ---------------------------------------------------------------------------


def spgemm_dense_flops(
    ni: int, nk: int, nj: int, bs_r: int, bs_k: int, bs_c: int
) -> float:
    """Local-stage FLOPs of the dense masked-einsum (``jnp``) backend.

    The einsum contracts the full (ni, nk, nj) cube regardless of the
    filter — this is what the local stage cost before compaction, and what
    ``cost_analysis`` reports for it (the mask-weighting adds a few
    percent on top; assert with rel tolerance).
    """
    return 2.0 * ni * nk * nj * bs_r * bs_k * bs_c


def spgemm_stacks_flops(
    capacity: int, bs_r: int, bs_k: int, bs_c: int
) -> float:
    """Local-stage FLOPs of the compacted (``stacks``/``pallas``) backends.

    One batched GEMM over the padded product list: FLOPs scale with the
    *surviving products* (padded to the capacity bucket), not the cube —
    the quantity ``cost_analysis`` reports for the compiled stacks
    program, and the term the roofline's compute model prices for
    filtered multiplies.
    """
    return 2.0 * capacity * bs_r * bs_k * bs_c
