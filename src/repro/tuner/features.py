"""Sparsity-pattern featurization for the autotuning runtime (DESIGN.md §6).

The paper's central empirical point is that the winning algorithm variant
depends on the *application* sparsity pattern — banded near-sighted
operators, exponential-decay fill, heterogeneous row loads — not just on
the process count.  This module reduces a concrete BSM operand pair to the
small feature vector the tuner keys its decisions on:

* occupancies of A and B and the **product fill** (surviving (i, k, j)
  triples / cube) computed from the *boolean mask product*
  ``A_mask @ B_mask`` — exact for threshold 0, an upper bound otherwise
  (the norm filter only removes products);
* the estimated output fill (blocks of C with at least one contribution),
  which decides whether post-filtering will keep the pattern sparse;
* block-row bandwidth of both operands (the near-sightedness of the
  operator — banded patterns keep fill-in local, random patterns do not);
* panel byte sizes, which set the communication-volume scale of Eq. (7);
* the product-load **imbalance** (max/mean per-panel product load of the
  mask product over a canonical mesh-independent grid): how unevenly the
  pattern loads a uniform block→device partition — the feature that makes
  the tuner consider non-identity block assignments (``core.distribute``).

``feature_bucket`` coarsens the vector (log2 shape classes, occupancy
deciles) into the persisted tuning-database key: patterns that land in the
same bucket share one measured decision, exactly like the capacity buckets
of the compiled-program cache (``kernels/stacks.py``).
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass

import numpy as np


@dataclass(frozen=True)
class PairFeatures:
    """Tuning features of one (A, B) multiply operand pair."""

    nb_r: int
    nb_k: int
    nb_c: int
    bs_r: int
    bs_k: int
    bs_c: int
    dtype: str
    occ_a: float  # block occupancy of A
    occ_b: float  # block occupancy of B
    n_products: int  # surviving (i, k, j) triples (mask product)
    product_fill: float  # n_products / (nb_r * nb_k * nb_c)
    out_fill: float  # fraction of C blocks with >= 1 contribution
    bandwidth_a: float  # block-row bandwidth of A, normalized by nb
    bandwidth_b: float
    panel_kb: float  # one A home-shard-row panel triple, kilobytes
    imbalance: float = 1.0  # max/mean product load, canonical grid

    @property
    def cube(self) -> int:
        return self.nb_r * self.nb_k * self.nb_c

    def as_dict(self) -> dict:
        return asdict(self)


def _bandwidth(mask: np.ndarray) -> int:
    """Largest |i - j| over occupied blocks (0 for empty/diagonal-only)."""
    idx = np.argwhere(mask)
    if idx.size == 0:
        return 0
    return int(np.abs(idx[:, 0] - idx[:, 1]).max())


def _itemsize(dtype) -> int:
    return int(np.dtype(str(np.dtype(dtype))).itemsize)


CANONICAL_GRID = 4  # imbalance reference grid (mesh-independent feature)


def _canonical_divisor(n: int, target: int = CANONICAL_GRID) -> int:
    for g in range(min(target, max(n, 1)), 0, -1):
        if n % g == 0:
            return g
    return 1


def _canonical_imbalance(counts: np.ndarray) -> float:
    """Max/mean product load over a canonical square-ish grid.

    Mesh-independent on purpose: the feature (and its DB bucket) must not
    change with the mesh the pattern happens to run on — ``mesh_signature``
    is a separate part of the DB key, and the exact per-mesh imbalance is
    recomputed by the model when ranking candidates."""
    from repro.core.commvolume import load_imbalance

    g_r = _canonical_divisor(counts.shape[0])
    g_c = _canonical_divisor(counts.shape[1])
    if g_r < 2 and g_c < 2:
        return 1.0
    return load_imbalance(counts, g_r, g_c)


def mask_product(mask_a, mask_b) -> np.ndarray:
    """Integer boolean-mask product: products per C block.

    One (nb_r, nb_k) x (nb_k, nb_c) int matmul instead of materializing
    the (nb_r, nb_k, nb_c) filter cube — exact for threshold 0, an upper
    bound otherwise (the norm filter only removes products).  The
    mask-power machinery the envelope layer (``core/envelope.py``)
    iterates to forecast chain fill-in.
    """
    am = np.asarray(mask_a, bool)
    bm = np.asarray(mask_b, bool)
    return am.astype(np.int64) @ bm.astype(np.int64)


def mask_union(masks) -> np.ndarray:
    """Bitwise union of a family of equal-shape boolean masks (the stream
    side of the envelope layer: one bound covering every member)."""
    it = iter(masks)
    try:
        first = next(it)
    except StopIteration:
        raise ValueError("mask_union needs at least one mask") from None
    out = np.asarray(first, bool).copy()
    for m in it:
        mm = np.asarray(m, bool)
        if mm.shape != out.shape:
            raise ValueError(
                f"mask shapes differ: {mm.shape} vs {out.shape}"
            )
        out |= mm
    return out


def featurize(a, b, threshold: float = 0.0) -> PairFeatures:
    """Feature vector of a concrete BSM pair (host-side, no device work).

    The product count comes from the integer mask product
    (:func:`mask_product`), so featurizing stays cheap at block grids far
    larger than the compaction path walks.
    """
    am = np.asarray(a.mask, bool)
    bm = np.asarray(b.mask, bool)
    counts = mask_product(am, bm)  # products per C block
    n_products = int(counts.sum())
    nb_r, nb_k = am.shape
    nb_c = bm.shape[1]
    cube = nb_r * nb_k * nb_c
    bs_r, bs_k, bs_c = a.bs_r, a.bs_c, b.bs_c
    itemsize = _itemsize(a.dtype)
    # one block-row panel triple of A (blocks + mask + norms), the unit the
    # engines move per pull — the s_a of Eq. (7) in bytes
    panel_kb = nb_k * (bs_r * bs_k * itemsize + 1 + 4) / 1024.0
    return PairFeatures(
        nb_r=nb_r,
        nb_k=nb_k,
        nb_c=nb_c,
        bs_r=bs_r,
        bs_k=bs_k,
        bs_c=bs_c,
        dtype=str(np.dtype(a.dtype)),
        occ_a=float(am.mean()) if am.size else 0.0,
        occ_b=float(bm.mean()) if bm.size else 0.0,
        n_products=n_products,
        product_fill=n_products / cube if cube else 0.0,
        out_fill=float((counts > 0).mean()) if counts.size else 0.0,
        bandwidth_a=_bandwidth(am) / max(nb_r, 1),
        bandwidth_b=_bandwidth(bm) / max(nb_k, 1),
        panel_kb=panel_kb,
        imbalance=_canonical_imbalance(counts),
    )


def _log2_class(x: int) -> int:
    return int(round(math.log2(max(int(x), 1))))


def _decile(x: float, step: float = 0.1) -> int:
    return min(int(x / step), int(round(1.0 / step)))


def mask_bucket(mask, bs_r: int = 1, bs_c: int = 1) -> tuple:
    """Coarse bucket of a SINGLE operand mask — the serving-dispatch key.

    The pattern-bucketed serving cache (``core.envelope.DispatchCache``)
    keys its per-bucket union envelopes on this: the same log2 shape
    classes and occupancy deciles as :func:`feature_bucket`, plus a
    row-load class (max/mean occupied blocks per block row — how peaked
    the expert demand is).  Request mixes whose dispatch masks drift
    *within* a bucket share one warmed envelope (and its compiled
    program); a mix that moves the occupancy or row-load class lands in a
    new bucket and warms it once.
    """
    m = np.asarray(mask, bool)
    if m.ndim != 2:
        raise ValueError(f"mask_bucket needs a 2D mask, got shape {m.shape}")
    nb_r, nb_c = m.shape
    occ = float(m.mean()) if m.size else 0.0
    row = m.sum(axis=1).astype(np.float64)
    mean = row.mean() if row.size else 0.0
    peak = float(row.max() / mean) if mean > 0 else 1.0
    return (
        "db1",  # dispatch-bucket schema version
        _log2_class(nb_r), _log2_class(nb_c),
        _log2_class(bs_r), _log2_class(bs_c),
        _decile(occ),
        # half-integer row-load classes, capped at 4x (hot-expert mixes
        # must not share an envelope with balanced ones: their union
        # would be needlessly loose for both)
        min(int(round(peak * 2)), 8),
    )


def feature_bucket(f: PairFeatures) -> tuple:
    """Coarse, stable bucket of a feature vector — the tuning-DB key part.

    Shapes collapse to log2 classes, occupancies and fills to deciles:
    application reruns with drifting-but-similar patterns (SCF loops,
    serving traffic) re-hit one measured decision instead of re-tuning.
    """
    return (
        "fb2",  # bucket-schema version (bump when fields change)
        _log2_class(f.nb_r), _log2_class(f.nb_k), _log2_class(f.nb_c),
        _log2_class(f.bs_r), _log2_class(f.bs_k), _log2_class(f.bs_c),
        f.dtype,
        _decile(f.occ_a), _decile(f.occ_b),
        _decile(f.product_fill, 0.05),
        _decile(f.out_fill),
        _decile(f.bandwidth_a), _decile(f.bandwidth_b),
        # half-integer imbalance classes, capped at 4x: balanced (~1.0)
        # and hub-dominated (>2x) patterns must never share one record
        min(int(round(f.imbalance * 2)), 8),
    )
