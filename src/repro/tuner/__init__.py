"""Pattern-aware autotuning runtime (DESIGN.md §6).

The decision layer above the plan cache: given a concrete operand pair
and a mesh, pick ``(engine, L, backend, stack_capacity, transport)`` —
the choices the paper shows are workload-dependent (2D vs 2.5D, depth L,
local backend, and now dense vs occupancy-compressed panel transport) —
instead of making every caller hardcode them.

Decision flow (each stage short-circuits the ones after it):

    features ──> decision cache ──> tuning DB ──> analytic prune ──> measure
    (features.py)   (exact pattern)   (db.py,        (model.py,       (measure.py,
                                      bucketed)      Eq. 6/7)         top-k trials)

* ``featurize`` reduces the pair to occupancies / product fill / bandwidth;
* the in-memory decision cache re-hits the *exact* pattern signature
  (hot loops re-multiplying one pattern resolve for free);
* the persisted :class:`~repro.tuner.db.TuningDB` re-hits the *feature
  bucket* (later runs — purify drivers, serving — are measurement-free);
* the analytic model enumerates feasible candidates, prices them with the
  paper's comm-volume model (Eq. 7) + roofline local FLOPs, and prunes
  any whose Eq. (6) memory footprint exceeds the per-device budget;
* short timed trials of the surviving top-k (through the compiled-program
  cache, so the winner is already hot) have the final word.

Counters join ``plan.cache_stats()``: ``tuner_hits`` (decisions served
without trials), ``tuner_misses`` (decisions that needed trials),
``tuner_trials`` (candidates actually timed).  ``plan.clear_cache()``
drops the decision cache and resets the default DB binding.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.core import plan as plan_mod
from repro.tuner.corpus import CorpusEntry, corpus, make_mask  # noqa: F401
from repro.tuner.db import TuningDB, make_key
from repro.tuner.features import PairFeatures, feature_bucket, featurize  # noqa: F401
from repro.tuner.measure import best_trial, measure_candidates
from repro.tuner.model import (
    _ASSIGN_TAGS,
    Candidate,
    ModelReport,  # noqa: F401
    assignment_space,
    chain_safe,
    choose_local_backend,  # noqa: F401
    device_memory_budget,
    enumerate_candidates,  # noqa: F401
    estimate_candidate,
    mesh_signature,
    rank_candidates,
)

__all__ = [
    "Decision", "autotune", "resolve_multiply", "set_default_db",
    "get_default_db", "TuningDB", "Candidate", "PairFeatures",
    "featurize", "feature_bucket", "rank_candidates", "corpus",
]


@dataclass(frozen=True)
class Decision:
    """A resolved (engine, L, backend, capacity, transport, assignment)
    choice and where it came from: "cache" | "db" | "measured" |
    "analytic"."""

    engine: str
    l: int | None
    backend: str
    stack_capacity: int | None
    source: str
    measured_s: float | None = None
    transport: str = "dense"  # panel transport mode for this pattern
    tile: tuple[int, int, int] | None = None  # pallas MXU tile override
    assign: str = "identity"  # block→device assignment mode

    @property
    def label(self) -> str:
        tag = self.engine if self.l is None else f"{self.engine}-l{self.l}"
        tag = f"{tag}/{self.backend}"
        if self.tile is not None:
            tm, tk, tn = self.tile
            tag = f"{tag}/t{tm}x{tk}x{tn}"
        if self.transport == "compressed":
            tag += "+ct"
        tag += _ASSIGN_TAGS.get(self.assign, "")
        return f"{tag}[{self.source}]"


_CACHE_MAXSIZE = 128
_decision_cache: OrderedDict[tuple, Decision] = OrderedDict()
# pattern-delta detection (DESIGN.md §7): decisions re-usable while the
# coarse feature bucket holds, keyed on the full DB key (bucket + mesh +
# constraints); revalidated per pattern like a DB hit
_bucket_cache: OrderedDict[tuple, Decision] = OrderedDict()
# last bucket seen per decision *stream* (everything but the pattern):
# a known stream changing bucket is pattern drift -> drift_retunes
_stream_last_bucket: OrderedDict[tuple, tuple] = OrderedDict()
_default_db: TuningDB | None = None


def set_default_db(db: TuningDB | str | None) -> TuningDB | None:
    """Bind the process-wide tuning DB (a :class:`TuningDB` or a path,
    warm-started when the file exists).  ``None`` unbinds."""
    global _default_db
    _default_db = TuningDB.load_or_create(db) if isinstance(db, str) else db
    return _default_db


def get_default_db() -> TuningDB | None:
    return _default_db


def _reset() -> None:
    """Drop all tuner state (registered with ``plan.clear_cache``)."""
    global _default_db
    _decision_cache.clear()
    _bucket_cache.clear()
    _stream_last_bucket.clear()
    _default_db = None


plan_mod.register_cache(_reset)


def _constraints(engines, backends, l, chain: bool,
                 transport: str | None, assign: str | None = None,
                 envelope: bool = False) -> tuple:
    """Constraint part of the decision/DB key.  The transport and assign
    elements are appended ONLY when the caller pinned a mode (and the
    ``env`` marker only under an envelope): the unpinned (and
    chain-default) shapes keep their earlier short forms, so a tuning DB
    persisted before the transport / distribution / envelope layers
    still warm-hits — its records simply read as ``transport="dense"`` /
    ``assign="identity"`` (``_db_candidate``).  Envelope-resolved
    decisions must never answer for exact-pattern resolutions (their
    capacities come from different cubes), hence the marker."""
    base = (
        "chain" if chain else "mult",
        ",".join(engines) if engines else "*",
        ",".join(backends) if backends else "*",
        0 if l is None else int(l),
    )
    return (base + ((transport,) if transport else ())
            + (("assign:" + assign,) if assign else ())
            + (("env",) if envelope else ()))


def _operand_key(a, b, mesh, constraints: tuple, threshold: float,
                 budget: float, measure: bool, tdb,
                 extra: bytes | None = None) -> tuple:
    """Decision-cache key from the operand *masks and norms* — NOT the
    O(nb^3) filter cube, so a decision-cache hit costs two 2D digests
    (the cube is only materialized on the miss path).  Budget, mode and
    DB binding are part of the key: a decision made under one budget (or
    analytically) must never answer for another.  ``extra`` joins the
    digest (the envelope signature: a decision resolved against one
    envelope must never answer for another)."""
    import hashlib

    from repro.kernels.stacks import pattern_signature

    h = hashlib.sha1(pattern_signature(np.asarray(a.mask, bool)))
    h.update(pattern_signature(np.asarray(b.mask, bool)))
    if threshold > 0.0:  # the filter cube depends on norms too
        h.update(np.asarray(a.norms, np.float32).tobytes())
        h.update(np.asarray(b.norms, np.float32).tobytes())
    if extra is not None:
        h.update(extra)
    return (h.digest(), mesh_signature(mesh), constraints,
            str(np.dtype(a.dtype)), float(threshold), float(budget),
            bool(measure), id(tdb) if tdb is not None else None)


def _capacity_for(cand: Candidate, ok, mesh) -> int | None:
    """Always re-derive compacted capacities from the *concrete* pattern:
    a DB/bucket hit must never smuggle in a stale (unsound) bound."""
    if cand.backend == "jnp":
        return None
    return plan_mod.get_device_capacity(ok, mesh, cand.engine)


def _db_candidate(rec: dict, ok, mesh, feats, counts=None) -> Candidate | None:
    """Rehydrate a DB record into a candidate VALID for this exact
    (mesh, pattern) — feature buckets are coarse, so a record measured at
    a different block grid can share the bucket while being
    topology-invalid here.  Re-runs the same validity gates
    ``enumerate_candidates`` applies; None = treat as a miss.

    ``transport`` is persisted as a *mode* only (records predating it
    read as dense): the sound per-panel capacities are always re-derived
    from the concrete pattern at execution (``plan.get_transport``), so
    a bucket hit can never smuggle in a stale packing bound.  ``tile``
    (records predating it read as None = backend default) is re-validated
    against this pattern's block shape on the current platform — a tile
    measured for one arch may not be lane-alignable on another; an
    invalid tile silently drops to the default instead of missing the
    whole record (the engine/backend choice is still worth reusing).
    ``assign`` (records predating it read as identity) is re-validated
    the same way via ``_db_assign``: a mode whose permutation cannot be
    derived on THIS (pattern, mesh) drops to identity, and the compacted
    capacity is re-derived from the PERMUTED cube — a bucket hit must
    never hand the program an identity-layout bound for a permuted run."""
    cand = Candidate(rec["engine"], rec["l"], rec["backend"],
                     transport=rec.get("transport", "dense"),
                     tile=_db_tile(rec.get("tile"), feats),
                     assign=_db_assign(rec.get("assign"), mesh, counts))
    if cand.transport not in ("dense", "compressed"):
        return None  # schema drift: unknown mode is a miss, not a crash
    try:
        plan = plan_mod.plan_multiply(mesh, cand.engine, cand.l)
        plan.validate_blocks(feats.nb_r, feats.nb_c, feats.nb_k)
    except ValueError:
        return None
    if cand.backend == "jnp":
        return cand
    ok_m = ok
    if cand.assign != "identity":
        from repro.core.distribute import permute_cube

        asg = assignment_space(counts, mesh,
                               assigns=(cand.assign,)).get(cand.assign)
        ok_m = permute_cube(ok, asg.perm)
    cap = _capacity_for(cand, ok_m, mesh)
    if not cap:
        return None  # empty pattern: the compacted program has no work
    return Candidate(cand.engine, cand.l, cand.backend, cap, cand.transport,
                     cand.tile, cand.assign)


def _db_assign(raw, mesh, counts) -> str:
    """Persisted assignment mode -> a mode derivable on this exact
    (pattern, mesh), else "identity".  Records predating the distribution
    layer carry no "assign" and read as identity; an unknown mode, a
    missing mask product, or a (grid, mesh) the symmetric permutation
    cannot divide (non-square counts, nb % lcm(p_r, p_c) != 0) silently
    drops to identity instead of missing the whole record — the
    engine/backend choice is still worth reusing."""
    if raw in (None, "identity"):
        return "identity"
    try:
        space = assignment_space(counts, mesh, assigns=(str(raw),))
    except (ValueError, TypeError, KeyError):
        return "identity"
    return str(raw) if space.get(str(raw)) is not None else "identity"


def _db_tile(raw, feats) -> tuple[int, int, int] | None:
    """Persisted tile -> a tile valid for this (block shape, dtype,
    platform), else None (= ``default_tile``; never trust a persisted
    shape blindly — JSON round-trips tuples as lists, and the record may
    come from a different arch or block-shape bucket)."""
    if raw is None:
        return None
    from repro.kernels.block_spgemm import validate_tile
    from repro.kernels.ops import _default_interpret

    try:
        tile = (int(raw[0]), int(raw[1]), int(raw[2]))
        return validate_tile(
            feats.bs_r, feats.bs_k, feats.bs_c, tile,
            np.dtype(feats.dtype), interpret=_default_interpret(),
        )
    except (ValueError, TypeError, IndexError, KeyError):
        return None


def autotune(
    a,
    b,
    mesh,
    *,
    threshold: float = 0.0,
    engines: tuple[str, ...] | None = None,
    backend: str | None = None,
    l: int | None = None,
    chain: bool = False,
    top_k: int = 3,
    reps: int = 2,
    budget_bytes: float | None = None,
    db: TuningDB | None = None,
    measure: bool = True,
    interpret: bool | None = None,
    transport: str | None = None,
    assign: str | None = None,
    envelope=None,
) -> Decision:
    """Resolve ``(engine, L, backend, stack_capacity, transport,
    assignment)`` for one operand pair on one mesh.

    ``backend`` / ``l`` / ``engines`` / ``transport`` / ``assign`` pin
    parts of the decision (the tuner only chooses what the caller left
    open).  ``assign="identity"`` pins the block→device assignment to
    the home layout — the sharded execute path uses this (operands are
    already distributed; the layout decision was made at ``shard_bsm``).
    ``chain=True`` restricts to chain-safe candidates (dense local
    backend + dense transport: a fused iteration's pattern evolves under
    a traced sweep, so static compacted capacities from the initial
    pattern would be unsound; assignment stays identity there for the
    same reason enumerate skips it on dense-jnp — the layout cannot
    change dense uniform work).  ``measure=False`` stops after the
    analytic ranking (no device work — usable on abstract meshes).

    ``envelope`` — optional ``core.envelope.Envelope``: capacities (and
    the candidate ranking's fill) are derived from the envelope's union
    cube instead of THIS pattern's filter cube, so the decision is sound
    for — and stable across — every pattern the envelope covers.  With
    ``chain=True`` this lifts the dense-backend/dense-transport pinning:
    every candidate is chain-safe against an envelope
    (``model.chain_safe``), which is what lets a fused drifting-pattern
    chain run compacted backends and compressed transport.
    """
    if mesh is None:
        raise ValueError("autotune requires a mesh (the decision space is "
                         "the distributed engine/depth/backend choice)")
    from repro.core.engine import _host_pair_filter

    enveloped = envelope is not None
    backends = (backend,) if backend else (
        ("jnp",) if chain and not enveloped else None)
    transports = (transport,) if transport else (
        ("dense",) if chain and not enveloped else None)
    assigns = (assign,) if assign else (("identity",) if chain else None)
    constraints = _constraints(engines, backends, l, chain, transport,
                               assign, envelope=enveloped)
    budget = device_memory_budget() if budget_bytes is None else budget_bytes
    tdb = db if db is not None else _default_db
    key = _operand_key(a, b, mesh, constraints, threshold, budget,
                       measure, tdb,
                       extra=envelope.signature if enveloped else None)

    hit = _decision_cache.get(key)
    if hit is not None:
        plan_mod._stats.tuner_hits += 1
        _decision_cache.move_to_end(key)
        return hit

    feats = featurize(a, b, threshold)
    # every capacity below comes from this cube: the concrete pattern's
    # filter cube, or the envelope's union cube (sound for the stream)
    ok = np.asarray(envelope.cube) if enveloped else _host_pair_filter(
        a, b, threshold)
    from repro.core.distribute import product_counts

    if enveloped:
        counts = product_counts(envelope.mask_a, envelope.mask_b)
    else:
        counts = product_counts(np.asarray(a.mask, bool),
                                np.asarray(b.mask, bool))
    db_key = make_key(feature_bucket(feats), mesh_signature(mesh),
                      constraints, feats.dtype)

    # pattern-delta detection: the bucket history of this decision
    # *stream* (same mesh/constraints/dtype/..., drifting patterns).  A
    # known stream whose coarse bucket just changed is drift — whatever
    # warm level catches it below, modes/capacities get re-derived.
    stream = key[1:]
    last = _stream_last_bucket.get(stream)
    if last is not None and last != db_key:
        plan_mod.note_drift_retune()
    _stream_last_bucket[stream] = db_key
    if len(_stream_last_bucket) > _CACHE_MAXSIZE:
        _stream_last_bucket.popitem(last=False)

    # the bucket cache additionally keys on the budget: a mode choice
    # made under one Eq. (6) budget must never answer for another (the
    # decision-cache invariant, kept at bucket granularity too)
    bucket_key = (db_key, float(budget))

    def finish(dec: Decision) -> Decision:
        _decision_cache[key] = dec
        if len(_decision_cache) > _CACHE_MAXSIZE:
            _decision_cache.popitem(last=False)
        _bucket_cache[bucket_key] = dec
        _bucket_cache.move_to_end(bucket_key)
        if len(_bucket_cache) > _CACHE_MAXSIZE:
            _bucket_cache.popitem(last=False)
        return dec

    if tdb is not None:
        rec = tdb.lookup(db_key)
        if rec is not None:
            cand = _db_candidate(rec, ok, mesh, feats, counts)
            if (
                cand is not None
                and estimate_candidate(cand, mesh, feats,
                                       budget_bytes=budget).feasible
                and (not chain or chain_safe(cand, envelope=enveloped))
            ):
                plan_mod._stats.tuner_hits += 1
                return finish(Decision(
                    engine=cand.engine, l=cand.l, backend=cand.backend,
                    stack_capacity=cand.stack_capacity, source="db",
                    measured_s=rec.get("measured_s"),
                    transport=cand.transport, tile=cand.tile,
                    assign=cand.assign,
                ))
            # invalid here / stale (budget, constraints): fall through

    bucket_hit = _bucket_cache.get(bucket_key)
    if bucket_hit is not None:
        # warm drift path: a new exact pattern landed in a bucket this
        # stream already resolved — revalidate the remembered modes like
        # a DB record (capacities ALWAYS re-derived from ``ok``)
        cand = _db_candidate({
            "engine": bucket_hit.engine, "l": bucket_hit.l,
            "backend": bucket_hit.backend,
            "transport": bucket_hit.transport,
            "tile": (list(bucket_hit.tile)
                     if bucket_hit.tile is not None else None),
            "assign": bucket_hit.assign,
        }, ok, mesh, feats, counts)
        if (
            cand is not None
            and estimate_candidate(cand, mesh, feats,
                                   budget_bytes=budget).feasible
            and (not chain or chain_safe(cand, envelope=enveloped))
        ):
            plan_mod._stats.tuner_hits += 1
            return finish(Decision(
                engine=cand.engine, l=cand.l, backend=cand.backend,
                stack_capacity=cand.stack_capacity, source="bucket",
                measured_s=bucket_hit.measured_s,
                transport=cand.transport, tile=cand.tile,
                assign=cand.assign,
            ))

    report = rank_candidates(
        mesh, feats, ok=ok, counts=counts, engines=engines,
        backends=backends, l=l, transports=transports, assigns=assigns,
        budget_bytes=budget, top_k=top_k if measure else 1,
    )
    if chain:
        ranked = tuple(e for e in report.ranked
                       if chain_safe(e.candidate, envelope=enveloped))
        if not ranked:
            raise ValueError("no chain-safe candidate survives the prune")
        report = ModelReport(ranked=ranked, pruned=report.pruned)

    if not measure:
        best = report.ranked[0].candidate
        plan_mod._stats.tuner_misses += 1
        return finish(Decision(
            engine=best.engine, l=best.l, backend=best.backend,
            stack_capacity=best.stack_capacity, source="analytic",
            transport=best.transport, tile=best.tile, assign=best.assign,
        ))

    plan_mod._stats.tuner_misses += 1
    trials = measure_candidates(
        a, b, mesh, [e.candidate for e in report.ranked],
        threshold=threshold, interpret=interpret, reps=reps,
    )
    plan_mod._stats.tuner_trials += len(trials)
    win = best_trial(trials)
    cand = win.candidate
    if tdb is not None:
        tdb.record(db_key, {
            "engine": cand.engine, "l": cand.l, "backend": cand.backend,
            "transport": cand.transport,
            "tile": list(cand.tile) if cand.tile is not None else None,
            "assign": cand.assign,
            "measured_s": win.seconds,
            "trials": [
                {"label": t.candidate.label, "seconds": t.seconds,
                 "error": t.error}
                for t in trials
            ],
        })
    return finish(Decision(
        engine=cand.engine, l=cand.l, backend=cand.backend,
        stack_capacity=cand.stack_capacity, source="measured",
        measured_s=win.seconds, transport=cand.transport, tile=cand.tile,
        assign=cand.assign,
    ))


def resolve_multiply(a, b, mesh, kw: dict) -> tuple[str, dict]:
    """``engine="auto"`` resolution for ``plan.execute`` /
    ``plan.execute_sharded``: returns the concrete engine plus the
    keyword set with the tuner's L / backend / capacity / transport /
    assignment filled in (the caller's explicit choices are honored as
    constraints)."""
    kw = dict(kw)
    backend = kw.get("backend")
    from repro.core.engine import _assign_pin, _transport_pin

    tr = kw.get("transport")
    tr_pin = _transport_pin(tr)
    asg_spec = kw.get("assignment")
    dec = autotune(
        a, b, mesh,
        threshold=kw.get("threshold", 0.0),
        backend=None if backend in (None, "auto") else backend,
        l=kw.get("l"),
        interpret=kw.get("interpret"),
        transport=tr_pin,
        assign=_assign_pin(asg_spec),
    )
    kw["backend"] = dec.backend
    kw["l"] = dec.l
    if kw.get("stack_capacity") is None:
        kw["stack_capacity"] = dec.stack_capacity
    if kw.get("tile") is None:
        kw["tile"] = dec.tile
    if tr is None or tr == "auto":
        # the tuner's measured mode; capacities are derived from the
        # concrete pattern in plan.resolve_transport
        kw["transport"] = dec.transport
    if asg_spec is None:
        # the tuner's chosen layout; the permutation itself is re-derived
        # deterministically by plan.resolve_assignment
        kw["assignment"] = dec.assign
    return dec.engine, kw
