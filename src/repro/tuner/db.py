"""Persisted tuning database (DESIGN.md §6).

A flat JSON file mapping ``(feature bucket, mesh shape, constraint set,
dtype)`` keys to the measured winning candidate — DBCSR's autotuned
parameter sets, per workload class instead of per kernel shape
(arXiv:1910.13555).  With a warm DB the tuner performs **zero** timed
trials: ``launch/purify.py`` / ``examples/linear_scaling_dft.py`` resolve
``engine="auto"`` by lookup alone, and ``plan.cache_stats()`` proves it
(``tuner_trials`` stays flat).

The file format is versioned and append-friendly: records carry their
measured seconds and the losing trials, so a later re-tune can compare.
Records persist the winning panel-transport *mode* (``"transport":
"dense" | "compressed"``; absent in pre-transport records, read as
dense) — mode only, never capacities: the sound per-panel packing bounds
are re-derived from the concrete pattern on every use
(``plan.get_transport``), so a stale record can never smuggle in an
unsound bound.

The block→device assignment follows the same rule: records persist the
winning *mode* only (``"assign": "identity" | "randomized" |
"nnz_greedy"``; absent in pre-distribution records, read as identity),
never a permutation — the permutation is a pure function of the concrete
mask product (``distribute.assignment_for``) and is re-derived on every
use.  On lookup the mode is revalidated for the exact (pattern, mesh) at
hand (``tuner._db_assign``) and silently drops to identity when the
symmetric permutation cannot be derived there (non-square block grid,
``nb % lcm(p_r, p_c) != 0``, unknown mode) — a bucket hit reuses the
engine/backend choice rather than missing the whole record.

Envelope-resolved decisions (``autotune(..., envelope=...)`` — fused
drifting-pattern chains and traffic streams, DESIGN.md §7) live under
their own constraint shape (an ``"env"`` marker element), so they never
answer for exact-pattern resolutions: their capacities were derived from
an envelope's union cube, and the mode-only persistence rule is what
makes the records shareable across every pattern an envelope covers —
capacities are re-derived from whichever cube (exact or envelope) the
next resolution runs under.
"""
from __future__ import annotations

import json
import os
from typing import Any

SCHEMA = "repro-tuning-db-v1"


def make_key(bucket: tuple, mesh_sig: tuple, constraints: tuple,
             dtype: str) -> str:
    """Deterministic string key (JSON object keys must be strings)."""
    return json.dumps(
        [list(bucket), [list(p) for p in mesh_sig], list(constraints), dtype],
        separators=(",", ":"),
    )


class TuningDB:
    """In-memory record store with optional JSON persistence."""

    def __init__(self, path: str | None = None):
        self.path = path
        self.records: dict[str, dict[str, Any]] = {}

    # ---- persistence ---------------------------------------------------
    @classmethod
    def load(cls, path: str) -> "TuningDB":
        db = cls(path)
        with open(path) as f:
            data = json.load(f)
        if data.get("schema") != SCHEMA:
            raise ValueError(
                f"{path}: unknown tuning-db schema {data.get('schema')!r}"
            )
        db.records = data.get("records", {})
        return db

    @classmethod
    def load_or_create(cls, path: str) -> "TuningDB":
        """Warm-start from ``path`` when it exists, else an empty DB that
        will persist there on the first ``save()``."""
        if path and os.path.exists(path):
            return cls.load(path)
        return cls(path)

    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("TuningDB has no path; pass save(path=...)")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"schema": SCHEMA, "records": self.records}, f,
                      indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic: readers never see a torn file
        self.path = path
        return path

    # ---- records -------------------------------------------------------
    def lookup(self, key: str) -> dict | None:
        return self.records.get(key)

    def record(self, key: str, decision: dict) -> None:
        self.records[key] = decision
        if self.path:
            self.save()

    def __len__(self) -> int:
        return len(self.records)
