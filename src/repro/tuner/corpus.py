"""Application-pattern corpus: CP2K-shaped inputs for the tuner.

The paper insists performance tests use *application-like* matrices
because the sparsity pattern (and the fill-in it produces) decides which
algorithm wins — uniform random masks systematically mislead.  This
module generates the three pattern families the tuner is exercised and
benchmarked on:

``dft_chain``   banded block structure of a quasi-1D "linear-scaling DFT
                chain" (H2O chains / nanotubes in CP2K): near-sighted
                operators occupy |i - j| <= bandwidth, fill-in stays
                local, output fill barely grows.
``exp_decay``   exponential decay of occupation probability with block
                distance — the shape of 3D linear-scaling DFT operators
                (H, S, P in H2O-DFT-LS); moderate, distance-correlated
                fill-in.
``zipf``        Zipf-distributed block-*row* loads in natural order: a
                few hub rows near the top are nearly dense, most rows
                nearly empty.  This is the static block-grid rendering of
                DBCSR's heterogeneous block-size distributions (Table 1's
                amorphous/interface systems): with the TPU format's fixed
                atomic block size, what survives of "Zipf block sizes" is
                exactly the per-row load imbalance — clustered, as a
                by-molecule atom ordering clusters it — which is what
                stresses the per-device capacity bounds, the 2.5D load
                balance, and the block→device assignment layer
                (``core.distribute``).

``uniform``     Uniform random occupation — the load-balanced limit a
                banded/decay operator reaches after DBCSR's randomized
                row/column permutation (§"randomized permutations for
                load balance").  The distance-correlated families above
                concentrate occupied blocks in the diagonal panels, so
                per-panel maxima (stack capacities, transport packing
                bounds) stay high even at low global occupancy; the
                uniform family is where occupancy-proportional wins
                (compressed transport, compacted stacks) show cleanly.

``three_center`` Tall-skinny matricized tensor operands: the decayed
                3-index occupation mask of a screened three-center
                integral tensor ``(ij|k)`` (the RPA/MP2 workload DBCSR's
                tensor extension targets — Sivkov et al. 2019),
                flattened block-major to an ``(nb^2, nb)`` block grid
                against a square decay-patterned ``(k, l)`` operand.
                ``nb_r >> nb_c``: the family that exercises the
                rectangular-grid plumbing of the plan layer and the
                k-dimension divisibility rules hardest.

Each entry builds a reproducible operand pair (symmetric H for the DFT
families — the corpus mirrors ``H @ H`` of the purification workload;
the three_center family mirrors the ``contract("ijk,kl->ijl")`` product
of ``core.tensor``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core import bsm as B

# the 2-index mask families make_mask() builds; the three_center tensor
# family lives at the CorpusEntry level (masks()/build()/build_tensor())
# because its A mask is a matricized 3-index pattern, not a make_mask kind
KINDS = ("dft_chain", "exp_decay", "zipf", "uniform")
ENTRY_KINDS = KINDS + ("three_center",)


@dataclass(frozen=True)
class CorpusEntry:
    name: str
    kind: str
    nb: int
    bs: int
    occupancy: float = 0.1
    bandwidth: int = 2
    zipf_alpha: float = 1.4
    seed: int = 0
    threshold: float = 1e-6
    params: dict = field(default_factory=dict)

    @property
    def symmetric(self) -> bool:
        return self.kind in ("dft_chain", "exp_decay")

    def masks(self) -> tuple[np.ndarray, np.ndarray]:
        """The concrete (A, B) occupation masks of this entry — exactly
        the (symmetrized) patterns ``build`` fills with values, without
        materializing any block data.  Three-center entries return the
        MATRICIZED (nb^2, nb) tensor mask and the square (nb, nb) mask
        of the ``kl`` operand."""
        key = jax.random.key(self.seed)
        k_mask, _, _ = jax.random.split(key, 3)
        if self.kind == "three_center":
            ma = three_center_mask(self.nb, k_mask,
                                   occupancy=self.occupancy)
            mb = make_mask("exp_decay", self.nb,
                           jax.random.fold_in(k_mask, 1),
                           occupancy=max(self.occupancy, 0.15))
            return ma, mb
        ma = make_mask(self.kind, self.nb, k_mask,
                       occupancy=self.occupancy, bandwidth=self.bandwidth,
                       zipf_alpha=self.zipf_alpha)
        if self.symmetric:
            ma = ma | ma.T
            return ma, ma  # H @ H: the purification multiply
        # independent second operand: SpGEMM traffic, not purification
        mb = make_mask(self.kind, self.nb, jax.random.fold_in(k_mask, 1),
                       occupancy=self.occupancy,
                       zipf_alpha=self.zipf_alpha)
        return ma, mb

    def imbalance(self, p_r: int = 2, p_c: int = 2) -> float:
        """Max/mean per-device product load of this entry's multiply on a
        (p_r, p_c) grid under the identity block→device assignment — the
        statistic the distribution layer (``core.distribute``) exists to
        flatten.  ``zipf``'s hub rows push it well above 2x while
        ``uniform`` sits near 1x (asserted in tests/test_tuner.py)."""
        from repro.core.commvolume import load_imbalance
        from repro.core.distribute import product_counts

        ma, mb = self.masks()
        return load_imbalance(product_counts(ma, mb), p_r, p_c)

    def build(self) -> tuple[B.BlockSparseMatrix, B.BlockSparseMatrix]:
        """Reproducible (A, B) operand pair for this entry.

        Three-center entries return the MATRICIZED tensor operand — an
        (nb^2, nb) tall-skinny ``BlockSparseMatrix`` whose mask is
        byte-identical to ``masks()[0]`` — so the tuner and benchmarks
        consume every family through the same matrix interface."""
        if self.kind == "three_center":
            from repro.core import tensor as T

            t, b = self.build_tensor()
            return T.matricize(t, (0, 1), (2,)), b
        key = jax.random.key(self.seed)
        _, k_a, k_b = jax.random.split(key, 3)
        ma, mb = self.masks()
        a = _fill(ma, k_a, self.bs, symmetric=self.symmetric)
        if self.symmetric:
            return a, a
        return a, _fill(mb, k_b, self.bs, symmetric=False)

    def build_tensor(self):
        """The un-flattened (T, B) operand pair of a three-center entry:
        the 3-index ``BlockSparseTensor`` (ij|k) and the square (k, l)
        matrix it contracts with via ``contract("ijk,kl->ijl")``."""
        if self.kind != "three_center":
            raise ValueError(
                f"build_tensor() is only defined for three_center "
                f"entries, not kind={self.kind!r}")
        from repro.core import tensor as T

        key = jax.random.key(self.seed)
        k_mask, k_a, k_b = jax.random.split(key, 3)
        nb, bs = self.nb, self.bs
        m3 = _three_center_mask3(nb, k_mask, occupancy=self.occupancy)
        blocks = jax.random.normal(k_a, (nb, nb, nb, bs, bs, bs)) / bs**1.5
        t = T.make_tensor(blocks, m3)
        mb = make_mask("exp_decay", nb, jax.random.fold_in(k_mask, 1),
                       occupancy=max(self.occupancy, 0.15))
        b = _fill(mb, k_b, bs, symmetric=False)
        return t, b


def _rng(key) -> np.random.Generator:
    return np.random.default_rng(
        np.asarray(jax.random.key_data(key)).ravel()[:2]
    )


def _with_diag(m: np.ndarray) -> np.ndarray:
    n = min(m.shape)
    m[np.arange(n), np.arange(n)] = True
    return m


def make_mask(kind: str, nb: int, key, *, occupancy: float = 0.1,
              bandwidth: int = 2, zipf_alpha: float = 1.4) -> np.ndarray:
    """Concrete (nb, nb) occupation mask of one corpus family."""
    rng = _rng(key)
    i = np.arange(nb)[:, None]
    j = np.arange(nb)[None, :]
    if kind == "dft_chain":
        m = np.abs(i - j) <= bandwidth
    elif kind == "exp_decay":
        scale = max(occupancy * nb / 2.0, 1e-3)
        m = rng.random((nb, nb)) < np.exp(-np.abs(i - j) / scale)
    elif kind == "uniform":
        # the randomized-permutation load-balanced limit: occupation
        # probability independent of block distance
        m = rng.random((nb, nb)) < occupancy
    elif kind == "zipf":
        # row r carries weight (r+1)^-alpha in NATURAL order — hub rows
        # cluster at the top the way a by-molecule atom ordering clusters
        # heavy blocks in DBCSR's inputs; normalize so the mean fill
        # matches `occupancy`.  The clustering is the point: a uniform
        # block→device partition lands every hub on one device row-panel,
        # which is exactly the imbalance the distribution layer
        # (core.distribute) exists to flatten.
        w = (np.arange(nb, dtype=np.float64) + 1.0) ** -zipf_alpha
        p_row = np.clip(w * (occupancy * nb / w.sum()), 0.0, 1.0)
        m = rng.random((nb, nb)) < p_row[:, None]
    else:
        raise ValueError(f"unknown corpus kind {kind!r}; one of {KINDS}")
    return _with_diag(np.asarray(m, bool))


def _fill(mask: np.ndarray, key, bs: int, *, symmetric: bool):
    mask = np.asarray(mask, bool)
    if symmetric:
        mask = mask | mask.T
    nb_r, nb_c = mask.shape
    blocks = jax.random.normal(key, (nb_r, nb_c, bs, bs)) / np.sqrt(bs)
    if symmetric:
        blocks = 0.5 * (blocks + blocks.transpose(1, 0, 3, 2))
    return B.make_bsm(blocks, np.asarray(mask))


def _three_center_mask3(nb: int, key, *, occupancy: float = 0.1,
                        decay: float = 0.25) -> np.ndarray:
    """Decayed (nb, nb, nb) occupation mask of a screened three-center
    integral tensor (ij|k): occupation probability falls exponentially
    with the normalized index spread max(i,j,k) - min(i,j,k) — the
    block-grid rendering of Schwarz/overlap screening, where only
    near-lying atom triples survive.  The i==j==k "diagonal" fiber is
    kept unconditionally (the on-site integrals), mirroring the
    dominant diagonal of the 2-index families."""
    rng = _rng(key)
    i = np.arange(nb, dtype=np.float64)
    spread = (np.maximum(np.maximum(i[:, None, None], i[None, :, None]),
                         i[None, None, :])
              - np.minimum(np.minimum(i[:, None, None], i[None, :, None]),
                           i[None, None, :])) / max(nb - 1, 1)
    shape = np.exp(-spread / decay)
    # calibrate the amplitude so the MEAN fill matches `occupancy`
    p = np.clip(shape * (occupancy / shape.mean()), 0.0, 1.0)
    m = rng.random((nb, nb, nb)) < p
    m |= spread == 0.0
    return np.asarray(m, bool)


def three_center_mask(nb: int, key, *, occupancy: float = 0.1,
                      decay: float = 0.25) -> np.ndarray:
    """The MATRICIZED (nb^2, nb) view of ``_three_center_mask3`` — the
    block-major flatten of indices (i, j) onto rows and k onto columns,
    exactly what ``tensor.matricize(t, (0, 1), (2,))`` produces for the
    mask.  Tall-skinny: nb_r = nb^2 >> nb_c = nb."""
    m3 = _three_center_mask3(nb, key, occupancy=occupancy, decay=decay)
    return m3.reshape(nb * nb, nb)


def corpus(*, nb: int = 16, bs: int = 16, smoke: bool = False) -> list[CorpusEntry]:
    """The standard tuner corpus (``smoke`` shrinks sizes for CI).

    The ``bigblock`` entry carries large atomic blocks (several MXU tiles
    per block — CP2K's molecular-orbital block sizes, Table 1's upper
    range) so the tuner's tile-shape axis and the tiled pallas kernel are
    exercised on a pattern where whole-block VMEM staging stops being an
    option; ``benchmarks/bench_tuner.py``'s oracle-gap assertion covers
    it like every other entry.
    """
    if smoke:
        nb, bs = min(nb, 8), min(bs, 8)
    big_nb, big_bs = (4, 64) if smoke else (max(nb // 2, 8), 128)
    return [
        CorpusEntry("dft_chain_narrow", "dft_chain", nb, bs,
                    bandwidth=max(1, nb // 8), seed=11),
        CorpusEntry("dft_chain_wide", "dft_chain", nb, bs,
                    bandwidth=max(2, nb // 4), seed=12),
        CorpusEntry("exp_decay_sparse", "exp_decay", nb, bs,
                    occupancy=0.08, seed=13),
        CorpusEntry("exp_decay_filled", "exp_decay", nb, bs,
                    occupancy=0.35, seed=14),
        CorpusEntry("zipf_hub", "zipf", nb, bs,
                    occupancy=0.15, zipf_alpha=1.4, seed=15),
        CorpusEntry("dft_chain_bigblock", "dft_chain", big_nb, big_bs,
                    bandwidth=max(1, big_nb // 4), seed=16),
        # tall-skinny matricized tensor product: (nb^2, nb) @ (nb, nb)
        CorpusEntry("three_center_tall", "three_center",
                    4 if smoke else 8, bs, occupancy=0.10, seed=17),
    ]
