"""Analytic candidate model: enumerate, cost, and prune (DESIGN.md §6).

The tuner's first stage is purely analytic — no device work.  For a mesh
and a feature vector it enumerates every feasible ``(engine, L, backend,
stack_capacity)`` combination, prices each one with

* the paper's communication-volume model evaluated on the *actual
  compiled schedule* (``commvolume.plan_volume``, Eq. (7) incl.
  non-square grids), converted to seconds at the roofline ICI rate, and
* the local-stage roofline FLOP models (``roofline.hlo_cost``), dense
  cube for the ``jnp`` backend, surviving-products for the compacted
  backends (with the gather/scatter overhead factor that sets the
  dense/compacted crossover — ``local_mm.backend_local_cost``),

and prunes every candidate whose per-device memory footprint — the
Eq. (6) buffer model (``commvolume.device_memory_bytes``) plus the
compacted stack arrays sized by ``plan.get_device_capacity`` — exceeds
the per-device budget.  The surviving candidates, ranked by modeled time,
are what ``tuner.measure`` actually times: the analytic stage exists to
keep the measured stage short, exactly as in DBCSR's autotuning
(arXiv:1910.13555) and Hong et al.'s sparsity-aware algorithm selection
(arXiv:2408.14558).

Absolute times use TPU-v5e roofline constants, so on other hardware they
are wrong in scale but consistent in *ranking* — which is all the prune
needs; measurement has the final word.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass, field

import numpy as np

from repro.core import commvolume
from repro.core import plan as plan_mod
from repro.core.local_mm import backend_local_cost, local_stage_cost
from repro.core.topology import validate_l
from repro.roofline import ICI_BW, PEAK_FLOPS
from repro.tuner.features import PairFeatures

# per-device memory budget for candidate pruning: TPU v5e HBM with a 10%
# reserve, overridable for tests / other targets
_DEFAULT_BUDGET = 0.9 * 16e9


def device_memory_budget() -> float:
    """Per-device byte budget (``REPRO_DEVICE_MEMORY_BYTES`` overrides)."""
    raw = os.environ.get("REPRO_DEVICE_MEMORY_BYTES", "").strip()
    return float(raw) if raw else _DEFAULT_BUDGET


# modeled per-tick dispatch/latency overhead: serializes the many-tick
# schedules (Cannon's V hops) against the one-shot gather engine even when
# their byte volumes tie.  Seconds; coarse on purpose — measurement refines.
TICK_OVERHEAD_S = 20e-6


_ASSIGN_TAGS = {"randomized": "@rand", "nnz_greedy": "@nnz"}


@dataclass(frozen=True)
class Candidate:
    """One point of the tuner's decision space."""

    engine: str
    l: int | None = None  # depth for twofive pull plans (None = plan default)
    backend: str = "jnp"
    stack_capacity: int | None = None  # compacted backends: device bound
    transport: str = "dense"  # panel transport mode ("dense"|"compressed")
    tile: tuple[int, int, int] | None = None  # pallas MXU tile (None=default)
    assign: str = "identity"  # block→device assignment mode (distribute.MODES)

    @property
    def label(self) -> str:
        tag = self.engine if self.l is None else f"{self.engine}-l{self.l}"
        tag = f"{tag}/{self.backend}"
        if self.tile is not None:
            tm, tk, tn = self.tile
            tag = f"{tag}/t{tm}x{tk}x{tn}"
        if self.transport == "compressed":
            tag += "+ct"
        return tag + _ASSIGN_TAGS.get(self.assign, "")


@dataclass(frozen=True)
class Estimate:
    """Analytic cost of one candidate on one (mesh, features) pair."""

    candidate: Candidate
    comm_s: float
    compute_s: float
    mem_bytes: float
    feasible: bool
    reason: str = ""  # why infeasible (empty when feasible)

    @property
    def total_s(self) -> float:
        return self.comm_s + self.compute_s


@dataclass(frozen=True)
class ModelReport:
    """Ranked feasible candidates + everything that was pruned."""

    ranked: tuple[Estimate, ...]  # feasible, best modeled time first
    pruned: tuple[Estimate, ...] = field(default=())


def mesh_signature(mesh) -> tuple:
    """Hashable, JSON-able identity of a mesh for decision/DB keys."""
    return tuple((name, int(mesh.shape[name])) for name in mesh.axis_names)


def valid_square_depths(p: int) -> list[int]:
    """Depths L > 1 valid on a square p x p grid (paper §3 rule)."""
    return [k * k for k in range(2, p + 1) if p % k == 0]


def assignment_space(
    counts, mesh, *, assigns: tuple[str, ...] | None = None
) -> dict[str, object]:
    """The assignment modes worth ranking for one (counts, mesh) pair,
    resolved to their deterministic ``distribute.Assignment`` objects
    (identity maps to None).

    Without concrete ``counts`` there is nothing to derive a permutation
    from, so only identity survives — the same degradation as compressed
    transport without masks.  Non-square block grids cannot take a
    symmetric permutation and also collapse to identity.
    """
    from repro.core import distribute as D

    if assigns is None:
        assigns = D.MODES
    out: dict[str, object] = {}
    for mode in assigns:
        if mode == "identity":
            out["identity"] = None
            continue
        if counts is None:
            continue
        c = np.asarray(counts)
        if c.shape[0] != c.shape[1] or c.shape[0] % math.lcm(
            int(mesh.shape["r"]), int(mesh.shape["c"])
        ):
            continue
        out[mode] = D.assignment_for(mode, c, (mesh.shape["r"],
                                               mesh.shape["c"]))
    if not out:
        out["identity"] = None
    return out


def enumerate_candidates(
    mesh,
    feats: PairFeatures,
    *,
    ok=None,
    counts=None,
    engines: tuple[str, ...] | None = None,
    backends: tuple[str, ...] | None = None,
    l: int | None = None,
    transports: tuple[str, ...] | None = None,
    assigns: tuple[str, ...] | None = None,
) -> list[Candidate]:
    """All (engine, L, backend, capacity, transport, assignment) points
    feasible for ``mesh``.

    ``ok`` — optional concrete filter cube; with it the compacted
    backends get their exact bucketed per-device capacity
    (``plan.get_device_capacity``), without it they are skipped (no sound
    static bound to hand the compiled program) and so is compressed
    transport (capacities are derived from the concrete masks at
    execution).  ``engines`` / ``l`` / ``backends`` / ``transports`` /
    ``assigns`` restrict the space (caller-pinned choices).

    The ``pallas`` backend additionally fans out over the MXU tile shapes
    worth measuring for this block shape and storage dtype
    (``kernels.block_spgemm.tile_candidates``; ``tile=None`` = the
    shipped ``default_tile``).  The searched axis is the *tile*; the
    storage dtype is a feature (part of the DB key), not a choice — the
    tuner never trades precision for speed on its own.

    ``counts`` — the integer mask product; with it non-identity block
    assignments (``core.distribute``) join the space for the candidates
    they can actually change: the compacted backends (whose capacity is a
    max over devices — derived here from the PERMUTED cube) and
    compressed transport (max-over-panels capacities).  For a dense-jnp
    candidate every device does identical dense work whatever the
    layout, so fanning assignments out there would only burn trial time.
    """
    axes = tuple(mesh.axis_names)
    if transports is None:
        transports = ("dense", "compressed") if ok is not None else ("dense",)
    elif ok is None:
        transports = tuple(t for t in transports if t == "dense")
    if backends is None:
        import jax

        backends = ("jnp", "pallas") if jax.default_backend() == "tpu" \
            else ("jnp", "stacks")
    assign_map = assignment_space(counts, mesh, assigns=assigns)

    pairs: list[tuple[str, int | None]] = []
    if "l" in axes:
        # stacked (l, r, c) mesh: the depth is physical, twofive only
        pairs = [("twofive", None)]
    else:
        p_r, p_c = int(mesh.shape["r"]), int(mesh.shape["c"])
        if p_r == p_c:
            pairs = [("cannon", None), ("onesided", None), ("gather", None)]
            pairs += [("twofive", d) for d in valid_square_depths(p_r)]
        else:
            pairs = [("onesided", None), ("gather", None)]
            mn, mx = min(p_r, p_c), max(p_r, p_c)
            if validate_l(p_r, p_c, mx // mn) and mx // mn > 1:
                pairs.append(("twofive", mx // mn))
    if engines is not None:
        pairs = [(e, d) for e, d in pairs if e in engines]
    if l is not None:
        pairs = [(e, d) for e, d in pairs
                 if (d == l if e == "twofive" else False) or e != "twofive"]

    out: list[Candidate] = []
    for engine, depth in pairs:
        try:
            plan = plan_mod.plan_multiply(mesh, engine, depth)
            plan.validate_blocks(feats.nb_r, feats.nb_c, feats.nb_k)
        except ValueError:
            continue  # block grid does not divide this topology
        for backend in backends:
            for tp in transports:
                for mode, asg in assign_map.items():
                    if (mode != "identity" and backend == "jnp"
                            and tp != "compressed"):
                        # dense panels + dense cube: every device does
                        # identical work in any layout
                        continue
                    if backend == "jnp":
                        out.append(Candidate(
                            engine, depth, "jnp", None, tp, None, mode
                        ))
                    elif ok is not None:
                        ok_m = ok
                        if asg is not None:
                            from repro.core.distribute import permute_cube

                            ok_m = permute_cube(ok, asg.perm)
                        cap = plan_mod.get_device_capacity(ok_m, mesh,
                                                           engine)
                        if cap > 0:
                            for tile in _backend_tiles(backend, feats):
                                out.append(Candidate(
                                    engine, depth, backend, cap, tp,
                                    tile, mode
                                ))
    return out


def _backend_tiles(
    backend: str, feats: PairFeatures
) -> list[tuple[int, int, int] | None]:
    """Tile axis of the search space: only the pallas kernel is tiled
    (``[None]`` — the backend default — for everything else)."""
    if backend != "pallas":
        return [None]
    from repro.kernels.block_spgemm import tile_candidates
    from repro.kernels.ops import _default_interpret

    return tile_candidates(
        feats.bs_r, feats.bs_k, feats.bs_c, np.dtype(feats.dtype),
        interpret=_default_interpret(),
    )


def _n_devices(mesh) -> int:
    n = 1
    for name in mesh.axis_names:
        n *= int(mesh.shape[name])
    return n


def estimate_candidate(
    cand: Candidate,
    mesh,
    feats: PairFeatures,
    *,
    budget_bytes: float | None = None,
    imbalance: float | None = None,
) -> Estimate:
    """Model one candidate: comm seconds + local-compute seconds + the
    Eq. (6) memory-feasibility verdict.

    ``imbalance`` — max/mean per-device product load under THIS
    candidate's block assignment (``commvolume.load_imbalance`` on the
    exact mesh grid; ``rank_candidates`` computes it per assignment mode
    from the mask-product counts).  Defaults to the feature vector's
    canonical-grid statistic.  It scales the local-compute term for the
    compacted backends — their work is product-proportional, and the
    slowest device gates every tick barrier — while the dense ``jnp``
    einsum contracts the full uniform cube on every device and is immune.
    """
    budget = device_memory_budget() if budget_bytes is None else budget_bytes
    plan = plan_mod.plan_multiply(mesh, cand.engine, cand.l)
    itemsize = float(np.dtype(feats.dtype).itemsize)
    # sparsity-aware volume: compressed transport scales the Eq. (7) A/B
    # term by panel occupancy (analytic flavor — execution derives the
    # exact bucketed capacities from the concrete masks)
    vol = commvolume.plan_volume(
        plan, feats.nb_r, feats.bs_r, itemsize=itemsize,
        transport=cand.transport, occ_a=feats.occ_a, occ_b=feats.occ_b,
        nb_k=feats.nb_k, nb_c=feats.nb_c,
        bs_k=feats.bs_k, bs_c=feats.bs_c,
    )
    comm_s = vol.total / ICI_BW + plan.ticks * TICK_OVERHEAD_S

    ndev = _n_devices(mesh)
    if cand.backend == "jnp":
        fill = 1.0  # dense einsum contracts the full cube
    else:
        fill = feats.product_fill
    # dtype- and tile-aware local cost: MXU throughput scales with the
    # storage width and a tile must fit the double-buffered VMEM budget —
    # a tile that does not is infeasible, same verdict as Eq. (6)
    lc = local_stage_cost(
        feats.nb_r, feats.nb_k, feats.nb_c,
        feats.bs_r, feats.bs_k, feats.bs_c,
        fill=fill, backend=cand.backend,
        dtype=feats.dtype, tile=cand.tile,
        capacity=cand.stack_capacity,
    )
    compute_s = lc.effective / ndev / PEAK_FLOPS
    if cand.backend != "jnp":
        # mean-load cost -> slowest-device cost (see the docstring)
        imb = imbalance if imbalance is not None else feats.imbalance
        compute_s *= max(float(imb), 1.0)

    mem = commvolume.device_memory_bytes(
        plan, feats.nb_r, feats.bs_r, itemsize=itemsize,
        stack_capacity=cand.stack_capacity or 0,
        nb_k=feats.nb_k, nb_c=feats.nb_c,
        bs_k=feats.bs_k, bs_c=feats.bs_c,
    )
    feasible = mem <= budget and lc.feasible
    if feasible:
        reason = ""
    elif not lc.feasible:
        reason = (
            f"tile {cand.tile or 'default'} working set exceeds the VMEM "
            f"budget for blocks {feats.bs_r}x{feats.bs_k}x{feats.bs_c} "
            f"({feats.dtype})"
        )
    else:
        reason = (
            f"memory {mem / 1e9:.2f} GB exceeds budget {budget / 1e9:.2f} GB "
            f"(Eq. 6, L={plan.topo.l})"
        )
    return Estimate(
        candidate=cand, comm_s=comm_s, compute_s=compute_s,
        mem_bytes=mem, feasible=feasible, reason=reason,
    )


def assignment_imbalances(counts, mesh, modes=None) -> dict[str, float]:
    """Exact per-mesh max/mean product-load factor of every assignment
    mode (identity included) — the numbers ``rank_candidates`` scales
    compacted compute by, and what the benchmarks report as the
    per-device load spread."""
    from repro.core.commvolume import load_imbalance

    p_r, p_c = int(mesh.shape["r"]), int(mesh.shape["c"])
    out: dict[str, float] = {}
    for mode, asg in assignment_space(counts, mesh, assigns=modes).items():
        perm = None if asg is None else asg.perm
        out[mode] = load_imbalance(counts, p_r, p_c, perm=perm) \
            if counts is not None else 1.0
    return out


def rank_candidates(
    mesh,
    feats: PairFeatures,
    *,
    ok=None,
    counts=None,
    engines: tuple[str, ...] | None = None,
    backends: tuple[str, ...] | None = None,
    l: int | None = None,
    transports: tuple[str, ...] | None = None,
    assigns: tuple[str, ...] | None = None,
    budget_bytes: float | None = None,
    top_k: int | None = None,
) -> ModelReport:
    """Enumerate -> estimate -> prune -> rank.  Raises ``ValueError`` when
    no candidate fits the per-device memory budget (the caller must then
    shrink the problem or raise the budget — silently over-committing
    device memory is the one thing the tuner must never do).

    With ``counts`` (the integer mask product) the estimates price each
    candidate at its OWN assignment's exact per-mesh load imbalance; the
    coarse canonical-grid feature only backstops the counts-free path.
    """
    cands = enumerate_candidates(
        mesh, feats, ok=ok, counts=counts, engines=engines,
        backends=backends, l=l, transports=transports, assigns=assigns,
    )
    if not cands:
        raise ValueError(
            f"no engine candidate fits mesh {mesh_signature(mesh)} and "
            f"block grid {feats.nb_r}x{feats.nb_c}"
        )
    imbs = assignment_imbalances(counts, mesh, modes=assigns) \
        if counts is not None else {}
    ests = [
        estimate_candidate(c, mesh, feats, budget_bytes=budget_bytes,
                           imbalance=imbs.get(c.assign))
        for c in cands
    ]
    feasible = sorted((e for e in ests if e.feasible), key=lambda e: e.total_s)
    pruned = tuple(e for e in ests if not e.feasible)
    if not feasible:
        raise ValueError(
            "every candidate exceeds the per-device memory budget: "
            + "; ".join(f"{e.candidate.label}: {e.reason}" for e in pruned)
        )
    if top_k is not None:
        feasible = feasible[:top_k]
    return ModelReport(ranked=tuple(feasible), pruned=pruned)


def choose_local_backend(
    ni: int, nk: int, nj: int,
    bs_r: int, bs_k: int, bs_c: int,
    fill: float,
) -> str:
    """Dense-vs-compacted local backend from the analytic cost model —
    the generalization of the old fixed occupancy threshold: the
    crossover now follows ``local_mm.backend_local_cost`` (and therefore
    moves with rectangular block shapes), instead of a hard-coded fill.
    Returns "jnp" or the compacted family's platform flavor."""
    import jax

    dense = backend_local_cost(ni, nk, nj, bs_r, bs_k, bs_c,
                               fill=1.0, backend="jnp")
    compact = backend_local_cost(ni, nk, nj, bs_r, bs_k, bs_c,
                                 fill=fill, backend="stacks")
    if dense <= compact:
        return "jnp"
    return "pallas" if jax.default_backend() == "tpu" else "stacks"


def chain_safe(cand: Candidate, *, envelope: bool = False) -> bool:
    """Whether a candidate is sound for a *fused iteration chain*: the
    sweep is traced once and the sparsity pattern evolves underneath it
    (fill-in), so a static stack capacity derived from the initial
    pattern could silently drop products mid-iteration — and a static
    compressed-transport capacity could silently drop *panels*.  Without
    further information only the dense local backend with dense
    transport is chain-safe.  Under ``envelope=True`` the capacities are
    derived from a forecast pattern envelope that over-approximates
    every per-sweep pattern (``core/envelope.py``), so *every* candidate
    is chain-safe — the restriction the envelope layer exists to lift."""
    if envelope:
        return True
    return cand.backend == "jnp" and cand.transport == "dense"


def _sqrt_l_note(l: int) -> str:  # pragma: no cover - debug helper
    return f"sqrt(L)={math.isqrt(l)}"
