"""Measured trials: the tuner's ground truth (DESIGN.md §6).

The analytic model ranks; short timed trials decide.  Every trial runs
through the ordinary ``engine.multiply`` path, so the compiled programs it
builds land in (and are later served from) the plan layer's program cache —
tuning is not wasted work: the winning candidate's executable is already
hot when the application multiplies for real.

Timing discipline: one untimed warm-up call per candidate (compile +
cache fill), then ``reps`` *interleaved* timed rounds — each round times
every candidate once, blocking on the FULL output triple (blocks, mask,
norms: a lazily materialized buffer must not escape the clock) — keeping
the minimum per candidate.  Interleaving matters: machine-load drift during the pass
hits all candidates alike instead of biasing whichever happened to run
last, and the minimum filters one-off scheduler noise (the standard for
microbenchmarks of cached programs; cf. benchmarks/bench_plan_cache.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax

from repro.tuner.model import Candidate


@dataclass(frozen=True)
class Trial:
    candidate: Candidate
    seconds: float  # min over interleaved timed rounds of one multiply
    error: str = ""  # non-empty when the trial failed (candidate skipped)

    @property
    def ok(self) -> bool:
        return not self.error


def measure_candidates(
    a,
    b,
    mesh,
    candidates,
    *,
    threshold: float = 0.0,
    interpret: bool | None = None,
    reps: int = 2,
) -> list[Trial]:
    """Time one multiply per candidate through the cached engine path.

    Operands may be replicated ``BlockSparseMatrix`` (mesh passed through)
    or ``ShardedBSM`` (already on the mesh — the trial measures exactly
    the device-resident path the application will run).  A candidate that
    fails to build/execute is returned with its error instead of aborting
    the whole tuning pass.
    """
    from repro.core.bsm import ShardedBSM
    from repro.core.engine import multiply

    sharded = isinstance(a, ShardedBSM)

    def make_run(c):
        def run():
            # sharded operands already live in their assignment's layout
            # (and carry it); only the replicated path runs the trial
            # under the candidate's block→device assignment
            return multiply(
                a, b, None if sharded else mesh,
                engine=c.engine, threshold=threshold, backend=c.backend,
                l=c.l, stack_capacity=c.stack_capacity, tile=c.tile,
                interpret=interpret, transport=c.transport,
                assignment=None if sharded else c.assign,
            )

        return run

    def wait(out):
        # block on the FULL output triple, not just the blocks: mask and
        # norms may materialize lazily (derived-norm algebra, async
        # dispatch), and a trial that stops the clock before they land
        # under-reports the candidate
        jax.block_until_ready((out.blocks, out.mask, out.norms))

    runners: dict[int, object] = {}
    best: dict[int, float] = {}
    errors: dict[int, str] = {}
    for i, cand in enumerate(candidates):
        run = make_run(cand)
        try:
            wait(run())  # warm-up: compile/caches
            runners[i] = run
            best[i] = float("inf")
        except Exception as e:  # noqa: BLE001 - surface per-candidate
            errors[i] = repr(e)
    for _ in range(reps):  # interleaved rounds (see module docstring)
        for i, run in list(runners.items()):
            try:
                t0 = time.perf_counter()
                wait(run())
                best[i] = min(best[i], time.perf_counter() - t0)
            except Exception as e:  # noqa: BLE001 - contain per candidate
                errors[i] = repr(e)
                del runners[i]  # a failed candidate is out of the race
                del best[i]
    return [
        Trial(candidate=cand, seconds=best.get(i, float("inf")),
              error=errors.get(i, ""))
        for i, cand in enumerate(candidates)
    ]


def best_trial(trials) -> Trial:
    ok = [t for t in trials if t.ok]
    if not ok:
        raise ValueError(
            "every measured candidate failed: "
            + "; ".join(f"{t.candidate.label}: {t.error}" for t in trials)
        )
    return min(ok, key=lambda t: t.seconds)
