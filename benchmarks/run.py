"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip-measured]

Prints ``name,value,notes`` CSV.  Each module's ``check()`` asserts the
paper-claim validation (Table 2 within 2x on all 39 cells, Fig. 2/3/4
scaling laws, Fig. 1 bounds); ``run()`` emits the numbers.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

from benchmarks import (
    fig1_speedups,
    fig2_message_sizes,
    fig3_comm_ratios,
    fig4_weak_scaling,
    moe_spgemm,
    roofline_report,
    table1_matrices,
    table2_strong_scaling,
)

MODULES = [
    ("table1", table1_matrices, False),
    ("table2", table2_strong_scaling, True),
    ("fig1", fig1_speedups, True),
    ("fig2", fig2_message_sizes, True),
    ("fig3", fig3_comm_ratios, True),
    ("fig4", fig4_weak_scaling, True),
    ("moe_spgemm", moe_spgemm, True),
    ("roofline", roofline_report, False),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-measured", action="store_true",
                    help="skip the 64-fake-device HLO measurement subprocess")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    failures = []
    for name, mod, has_check in MODULES:
        if args.only and name not in args.only:
            continue
        try:
            if has_check:
                mod.check()
            for row_name, val, note in mod.run():
                print(f"{row_name},{val},{note}")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name}/CHECK_FAILED,-1,{e!r}", flush=True)

    if not args.skip_measured and (not args.only or "measured" in args.only):
        # HLO-measured engine collective bytes need fake devices -> subprocess
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        proc = subprocess.run(
            [sys.executable, os.path.join(root, "benchmarks", "measure_comm.py")],
            capture_output=True, text=True, timeout=900,
        )
        if proc.returncode != 0:
            failures.append(("measured", proc.stderr[-500:]))
            print(f"measured/CHECK_FAILED,-1,{proc.stderr[-200:]!r}")
        else:
            sys.stdout.write(proc.stdout)

    if failures:
        print(f"\n{len(failures)} benchmark module(s) FAILED", file=sys.stderr)
        for n, e in failures:
            print(f"  {n}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
