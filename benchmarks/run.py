"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip-measured]
    PYTHONPATH=src python -m benchmarks.run --summary-only

Prints ``name,value,notes`` CSV.  Each module's ``check()`` asserts the
paper-claim validation (Table 2 within 2x on all 39 cells, Fig. 2/3/4
scaling laws, Fig. 1 bounds); ``run()`` emits the numbers.

The run ends with an aggregate of every ``BENCH_*.json`` series the CI
benchmarks emit (local_mm, signiter, tuner, plan_cache, ...): one flat
``file:path,value`` table, so the perf trajectory of any metric is
greppable across PRs from one place.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

from benchmarks import (
    bench_serving,
    fig1_speedups,
    fig2_message_sizes,
    fig3_comm_ratios,
    fig4_weak_scaling,
    moe_spgemm,
    roofline_report,
    table1_matrices,
    table2_strong_scaling,
)

MODULES = [
    ("table1", table1_matrices, False),
    ("table2", table2_strong_scaling, True),
    ("fig1", fig1_speedups, True),
    ("fig2", fig2_message_sizes, True),
    ("fig3", fig3_comm_ratios, True),
    ("fig4", fig4_weak_scaling, True),
    ("moe_spgemm", moe_spgemm, True),
    ("serving", bench_serving, True),
    ("roofline", roofline_report, False),
]


def _flatten(prefix: str, obj, out: list[tuple[str, object]]) -> None:
    """Flatten a BENCH json into (dotted.path, scalar) rows."""
    if isinstance(obj, dict):
        for k in sorted(obj):
            _flatten(f"{prefix}.{k}" if prefix else str(k), obj[k], out)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _flatten(f"{prefix}[{i}]", v, out)
    elif isinstance(obj, (int, float, str, bool)) or obj is None:
        out.append((prefix, obj))


def summarize_bench_json(paths: list[str] | None = None) -> int:
    """One flat, greppable summary table of every BENCH_*.json series."""
    if paths is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        # realpath-dedup: running from the repo root must not list each
        # file twice (absolute via root + relative via cwd)
        paths = sorted(
            {os.path.realpath(p)
             for p in glob.glob(os.path.join(root, "BENCH_*.json"))
             + glob.glob("BENCH_*.json")}
        )
    if not paths:
        return 0
    print("\n# BENCH summary (file:path,value)")
    n = 0
    for path in paths:
        tag = os.path.basename(path).removesuffix(".json")
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError) as e:
            print(f"{tag}:LOAD_FAILED,{e!r}")
            continue
        rows: list[tuple[str, object]] = []
        _flatten("", data, rows)
        for key, val in rows:
            if isinstance(val, float):
                val = f"{val:.6g}"
            print(f"{tag}:{key},{val}")
            n += 1
    return n


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-measured", action="store_true",
                    help="skip the 64-fake-device HLO measurement subprocess")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--summary-only", action="store_true",
                    help="only aggregate existing BENCH_*.json files")
    args = ap.parse_args()

    if args.summary_only:
        summarize_bench_json()
        return

    failures = []
    for name, mod, has_check in MODULES:
        if args.only and name not in args.only:
            continue
        try:
            if has_check:
                mod.check()
            for row_name, val, note in mod.run():
                print(f"{row_name},{val},{note}")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name}/CHECK_FAILED,-1,{e!r}", flush=True)

    if not args.skip_measured and (not args.only or "measured" in args.only):
        # HLO-measured engine collective bytes need fake devices -> subprocess
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        proc = subprocess.run(
            [sys.executable, os.path.join(root, "benchmarks", "measure_comm.py")],
            capture_output=True, text=True, timeout=900,
        )
        if proc.returncode != 0:
            failures.append(("measured", proc.stderr[-500:]))
            print(f"measured/CHECK_FAILED,-1,{proc.stderr[-200:]!r}")
        else:
            sys.stdout.write(proc.stdout)

    summarize_bench_json()

    if failures:
        print(f"\n{len(failures)} benchmark module(s) FAILED", file=sys.stderr)
        for n, e in failures:
            print(f"  {n}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
