"""Tensor contraction benchmark: the matricized einsum layer on the
tall-skinny three-center workload (DESIGN.md §10).

The contraction ``contract("ijk,kl->ijl", T, B)`` of a screened
three-center integral tensor against a decay-patterned operator is the
RPA/MP2-shaped workload DBCSR's tensor extension targets (Sivkov et al.
2019).  Matricized, it is an (nb^2, nb) x (nb, nb) SpGEMM — the
rectangular block grid that exercises the plan layer's non-square
plumbing — and every layer underneath (filtering, compacted stacks,
transport, the tuner) applies verbatim.  Gated:

  * **sparsity pays** — at 10% block occupancy the filtered contraction
    executes <= 50% of the dense einsum's floating-point work
    (mask-level accounting: surviving block products x block MACs vs the
    full ijkl product space);
  * **the tuner earns its keep** — ``engine="auto"`` (free choice of
    engine, depth, backend, transport from the measured trials) is
    >= 1.2x faster than the WORST static (engine, L) choice at the
    default jnp backend — the combination a hardcoding caller could
    have shipped on this rectangular shape;
  * **correctness** — the distributed contraction matches the dense
    ``np.einsum`` oracle before any number is reported.

Results go to BENCH_tensor.json (CI perf-trajectory series, aggregated
by ``benchmarks/run.py`` like every BENCH_*.json).

    python benchmarks/bench_tensor.py [--smoke] [--out BENCH_tensor.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import plan as plan_mod  # noqa: E402
from repro.core import tensor as T  # noqa: E402
from repro.core.engine import multiply  # noqa: E402
from repro.launch.mesh import make_spgemm_mesh  # noqa: E402
from repro.tuner.corpus import CorpusEntry  # noqa: E402
from repro.tuner.model import valid_square_depths  # noqa: E402

THRESHOLD = 1e-6


def walltime(run, reps: int) -> float:
    out = run()
    jax.block_until_ready((out.blocks, out.mask, out.norms))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = run()
        jax.block_until_ready((out.blocks, out.mask, out.norms))
        best = min(best, time.perf_counter() - t0)
    return best


def flop_accounting(a, b) -> dict:
    """Mask-level work comparison: surviving block products of the
    filtered SpGEMM vs the dense einsum's full product space."""
    ma, mb = np.asarray(a.mask, bool), np.asarray(b.mask, bool)
    nb_r, nb_k = ma.shape
    _, nb_c = mb.shape
    bs_r, bs_k = int(a.blocks.shape[2]), int(a.blocks.shape[3])
    bs_c = int(b.blocks.shape[3])
    products = int((ma[:, :, None] & mb[None, :, :]).sum())
    sparse = 2.0 * products * bs_r * bs_k * bs_c
    dense = 2.0 * (nb_r * bs_r) * (nb_k * bs_k) * (nb_c * bs_c)
    return {
        "surviving_products": products,
        "product_space": nb_r * nb_k * nb_c,
        "sparse_flops": sparse,
        "dense_flops": dense,
        "flop_ratio": sparse / dense,
    }


def static_space(mesh) -> list[tuple[str, int | None]]:
    """Every static (engine, L) a hardcoding caller could pin on this
    mesh — the same space ``tuner.model.enumerate_candidates`` fans
    over (jnp backend)."""
    p_r, p_c = int(mesh.shape["r"]), int(mesh.shape["c"])
    pairs: list[tuple[str, int | None]] = []
    if p_r == p_c:
        pairs = [("cannon", None), ("onesided", None), ("gather", None)]
        pairs += [("twofive", d) for d in valid_square_depths(p_r)]
    else:
        pairs = [("onesided", None), ("gather", None)]
    return pairs


def run_bench(smoke: bool) -> dict:
    nb, bs = (8, 8) if smoke else (8, 16)
    reps = 5 if smoke else 10
    entry = CorpusEntry("three_center_tall", "three_center", nb, bs,
                        occupancy=0.10, seed=17)
    t, bm = entry.build_tensor()
    b2 = T.make_tensor(bm.blocks, bm.mask)
    a, b = entry.build()  # the matricized pair (masks == tensor masks)
    mesh = make_spgemm_mesh(p=2)
    plan_mod.clear_cache()

    # correctness first: never report numbers off a wrong contraction
    ref = T.contract_reference("ijk,kl->ijl", t, b2)
    got = T.contract("ijk,kl->ijl", t, b2, mesh=mesh, engine="auto",
                     threshold=THRESHOLD)
    np.testing.assert_allclose(np.asarray(got.to_dense()), ref,
                               rtol=1e-4, atol=1e-4)

    flops = flop_accounting(a, b)

    # statics at the default jnp backend, measured min-of-reps, two
    # passes min-merged (pass one also warms every compiled program)
    statics: dict[str, float] = {}
    for _ in range(2):
        for eng, l in static_space(mesh):
            try:
                plan_mod.plan_multiply(mesh, eng, l).validate_blocks(
                    a.nb_r, b.nb_c, a.nb_c)
            except ValueError:
                continue  # grid does not divide this topology
            label = eng if l is None else f"{eng}(L={l})"
            s = walltime(
                lambda e=eng, d=l: multiply(a, b, mesh, engine=e, l=d,
                                            threshold=THRESHOLD), reps)
            statics[label] = min(s, statics.get(label, float("inf")))

    # the tuner's pick with full freedom (engine, L, backend, transport)
    auto_s = float("inf")
    for _ in range(2):
        auto_s = min(auto_s, walltime(
            lambda: multiply(a, b, mesh, engine="auto",
                             threshold=THRESHOLD), reps))
    stats = plan_mod.cache_stats()

    worst_label = max(statics, key=statics.get)
    best_label = min(statics, key=statics.get)
    return {
        "bench": "tensor_contraction",
        "smoke": smoke,
        "mesh": "2x2",
        "threshold": THRESHOLD,
        "entry": entry.name,
        "tensor_nbs": list(t.nbs),
        "tensor_bss": list(t.bss),
        "matricized": {"nb_r": a.nb_r, "nb_c": b.nb_c, "nb_k": a.nb_c,
                       "bs_r": a.bs_r, "bs_c": b.bs_c, "bs_k": a.bs_c},
        "occupancy_a": float(np.asarray(a.mask, bool).mean()),
        "occupancy_b": float(np.asarray(b.mask, bool).mean()),
        "flops": flops,
        "static_ms": {k: v * 1e3 for k, v in statics.items()},
        "worst_static": worst_label,
        "best_static": best_label,
        "auto_ms": auto_s * 1e3,
        "auto_vs_worst_static": statics[worst_label] / auto_s,
        "auto_vs_best_static": statics[best_label] / auto_s,
        "tuner_hits": stats["tuner_hits"],
    }


def check(result: dict) -> None:
    # sparsity pays: <= 50% of the dense einsum work at 10% occupancy
    assert result["flops"]["flop_ratio"] <= 0.50, result["flops"]
    # the tuner beats the worst static (engine, L) a caller could pin
    assert result["auto_vs_worst_static"] >= 1.2, {
        "auto_ms": result["auto_ms"],
        "static_ms": result["static_ms"],
    }
    # ... and never loses materially to the best one
    assert result["auto_vs_best_static"] >= 0.80, {
        "auto_ms": result["auto_ms"],
        "static_ms": result["static_ms"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    result = run_bench(args.smoke)
    check(result)
    m = result["matricized"]
    print(f"tensor/{result['entry']}: ({m['nb_r']}x{m['nb_k']}) x "
          f"({m['nb_k']}x{m['nb_c']}) blocks, "
          f"flop ratio {result['flops']['flop_ratio']:.3f}")
    for lab, ms in sorted(result["static_ms"].items(), key=lambda kv: kv[1]):
        print(f"  static {lab:>14} {ms:8.3f} ms")
    print(f"  auto {result['auto_ms']:8.3f} ms "
          f"(x{result['auto_vs_worst_static']:.2f} vs worst static, "
          f"x{result['auto_vs_best_static']:.2f} vs best)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
