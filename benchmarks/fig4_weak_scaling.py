"""Fig. 4 reproduction: weak scaling of the S-E benchmark.

Paper setup: 76 water molecules per process -> constant FLOPs/data per
process; matrix dimension grows with P, occupancy decays ~1/P (1.1 % at 144
nodes -> 0.04 % at 3844).  Square grids, L=4 for the OSL runs.

Reported: per-multiplication A/B+C communicated volume per process for PTP,
OS1, OS4 over the node counts, and the OS4/OS1 ratio — the paper's
observation that OS4 'becomes beneficial for a large enough number of
processes' shows up as the ratio crossing below 1 as P grows.
"""
from __future__ import annotations

import math

from repro.core.commvolume import osl_volume, ptp_volume
from repro.core.topology import make_topology

NODES = (144, 400, 1024, 1936, 3844)  # squares, as in the paper's figure
MOLS_PER_PROC = 76
ROWS_PER_MOL = 6  # S-E: 6x6 blocks, one block row per molecule-orbital set
OCC_144 = 0.011  # paper: 1.1 % at 144 nodes, ~1/P decay


def cell(nodes: int, l: int) -> dict[str, float]:
    p = int(math.isqrt(nodes))
    assert p * p == nodes
    n = MOLS_PER_PROC * ROWS_PER_MOL * nodes  # rows grow linearly with P
    occ = OCC_144 * 144 / nodes
    topo = make_topology(p, p, l)
    v = topo.v
    s_a = (n / p) * (n / v) * occ * 8
    s_b = s_a
    s_c = 2.1 * s_a  # paper-measured S-E fill-in ratio
    rep = osl_volume(topo, s_a, s_b, s_c)
    ptp = ptp_volume(topo if l == 1 else make_topology(p, p, 1), s_a, s_b)
    return {"osl_gb": rep.total / 1e9, "ptp_gb": ptp.total / 1e9}


def run() -> list[tuple[str, float, str]]:
    rows = []
    for nodes in NODES:
        c1 = cell(nodes, 1)
        c4 = cell(nodes, 4)
        rows.append((f"fig4/n{nodes}/ptp_gb", round(c1["ptp_gb"], 2), "per mult"))
        rows.append((f"fig4/n{nodes}/os1_gb", round(c1["osl_gb"], 2), ""))
        rows.append((f"fig4/n{nodes}/os4_gb", round(c4["osl_gb"], 2), ""))
        rows.append(
            (
                f"fig4/n{nodes}/os4_over_os1",
                round(c4["osl_gb"] / c1["osl_gb"], 3),
                "<1 == 2.5D wins",
            )
        )
    return rows


def check() -> None:
    # weak scaling: per-process volume grows ~sqrt(P) for L=1 (N grows with
    # P, panel width shrinks ~1/sqrt(P)) — communication eventually dominates,
    # which is the paper's motivation for L>1 at scale.
    v144 = cell(144, 1)["osl_gb"]
    v3844 = cell(3844, 1)["osl_gb"]
    expect = math.sqrt(3844 / 144)
    assert 0.6 * expect < v3844 / v144 < 1.6 * expect
    # OS4 advantage grows with P (the paper's crossover)
    r = [cell(n, 4)["osl_gb"] / cell(n, 1)["osl_gb"] for n in NODES]
    assert all(b <= a + 1e-9 for a, b in zip(r, r[1:])), r
    assert r[-1] < 0.75, r


if __name__ == "__main__":
    check()
    for name, val, note in run():
        print(f"{name},{val},{note}")
