"""Pattern-envelope benchmark: recompile-free drifting-pattern chains.

The envelope PR's headline numbers, with asserted gates:

  * builds == 1: a 10-sweep Newton-Schulz chain whose fill-in pattern
    drifts EVERY sweep executes through ONE compiled sweep program — the
    chain is compiled once against the forecast envelope's capacities and
    the concrete per-sweep masks enter as data (gate: plan counters
    ``builds == 1``, ``chain_misses == 1``, ``chain_hits == sweeps-1``,
    ``envelope_misses == 1``), bitwise equal to the chain-safe fused
    chain that re-walks nothing either but was only safe for static
    patterns.

  * warm drift-path dispatch >= 5x lower than per-pattern retrace: the
    steady-state envelope sweep vs the legacy per-op loop with a
    compacted backend (the retrace path: every sweep re-enters
    ``multiply()`` on the drifted pattern — host pattern walk, stack
    re-compaction, eager algebra, residual sync).  Timed back-to-back,
    paired, median-of-ratios.

  * envelope padded-work overhead <= documented per-family bound: the
    forecast capacity (padded product slots the one-shot program
    executes) over the peak realized product count of the chain, per
    corpus family.  Buckets round capacities to powers of two, so the
    bound is the product of forecast slack and bucket rounding.

Results go to BENCH_envelope.json (CI ``--smoke`` leg, aggregated by the
perf-trajectory step next to BENCH_signiter.json).

    python benchmarks/bench_envelope.py [--smoke] [--out BENCH_envelope.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import bsm as B  # noqa: E402
from repro.core import envelope as E  # noqa: E402
from repro.core import plan as plan_mod  # noqa: E402
from repro.core.engine import multiply  # noqa: E402
from repro.core.signiter import (  # noqa: E402
    get_sweep_program,
    sign_iteration,
    sign_iteration_legacy,
)
from repro.kernels.stacks import pair_cube  # noqa: E402
from repro.launch.mesh import make_spgemm_mesh  # noqa: E402
from repro.tuner.corpus import KINDS, make_mask  # noqa: E402

THRESHOLD = 1e-8
FILTER_EPS = 1e-7

# Gate 3 documented bounds: forecast capacity / peak realized products per
# corpus family at the calibration point below (nb=12, occupancy=0.15,
# threshold=1e-3, 3 sweeps, seeds 0-2).  Measured overheads sit at
# 2.3-4.5x (forecast slack x power-of-two bucket rounding); the bounds
# leave one bucket step of headroom.
OVERHEAD_SWEEPS = 3
OVERHEAD_NB = 12
OVERHEAD_OCC = 0.15
OVERHEAD_THRESHOLD = 1e-3
OVERHEAD_BOUNDS = {
    "dft_chain": 6.5,
    "exp_decay": 6.5,
    "zipf": 5.5,
    "uniform": 5.5,
}


def _chain_operand(kind: str, nb: int, bs: int, seed: int, occupancy: float):
    """Symmetric purification-shaped operand of one corpus family, scaled
    to unit spectral norm on the host (``scale_input=False`` chains)."""
    m = make_mask(kind, nb, jax.random.key(seed), occupancy=occupancy)
    m = np.asarray(m) | np.asarray(m).T
    blocks = jax.random.normal(jax.random.key(seed + 1),
                               (nb, nb, bs, bs)) / np.sqrt(bs)
    blocks = 0.5 * (blocks + blocks.transpose(0, 1, 3, 2).swapaxes(0, 1))
    x = B.make_bsm(blocks, m)
    return B.scale(x, float(1.0 / max(float(x.frobenius_norm()), 1e-30)))


def _realized_peak(x, sweeps: int, threshold: float, filter_eps: float) -> int:
    """Peak realized product count over the chain (per-pattern oracle)."""
    ident = B.identity(x.nb_r, x.bs_r, x.dtype)
    peak = 0
    for _ in range(sweeps):
        peak = max(peak, int(pair_cube(x.mask, x.mask, x.norms, x.norms,
                                       threshold).sum()))
        x2 = multiply(x, x, threshold=threshold, filter_eps=filter_eps)
        y = B.add(B.scale(x2, -1.0), B.scale(ident, 3.0))
        peak = max(peak, int(pair_cube(x.mask, y.mask, x.norms, y.norms,
                                       threshold).sum()))
        xn = multiply(x, y, threshold=threshold, filter_eps=filter_eps)
        x = B.scale(xn, 0.5)
    return peak


def _make_envelope_steady(x, mesh, env, sweeps: int, engine: str):
    """Steady-state envelope sweep runner: `sweeps` dispatches of the ONE
    envelope-compiled chain-step program, operands device-resident, the
    drifted mask flowing through as data (chain boundaries are one-time
    costs, reported separately)."""
    sx = B.shard_bsm(x, mesh)
    ident = B.shard_bsm(B.identity(x.nb_r, x.bs_r, x.dtype), mesh)
    sweep = get_sweep_program(sx, mesh, engine=engine, threshold=THRESHOLD,
                              filter_eps=FILTER_EPS, backend="stacks",
                              envelope=env)

    def run():
        st = (sx.blocks, sx.mask, sx.norms)
        for _ in range(sweeps):
            out = sweep(st[0], st[1], st[2], ident.blocks, ident.mask)
            st = out[:3]
        jax.block_until_ready(out)

    return run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--nb", type=int, default=None)
    ap.add_argument("--bs", type=int, default=None)
    ap.add_argument("--sweeps", type=int, default=10)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--engine", default="onesided")
    ap.add_argument("--out", default="BENCH_envelope.json")
    args = ap.parse_args()

    nb = args.nb or 8
    bs = args.bs or (4 if args.smoke else 8)
    reps = args.reps or (5 if args.smoke else 10)
    sweeps = args.sweeps
    mesh = make_spgemm_mesh(p=2)

    x = B.random_bsm(jax.random.key(0), nb=nb, bs=bs, occupancy=0.3,
                     pattern="decay", symmetric=True)
    x = B.scale(x, float(1.0 / max(float(x.frobenius_norm()), 1e-30)))
    kw = dict(mesh=mesh, engine=args.engine, threshold=THRESHOLD,
              filter_eps=FILTER_EPS, max_iter=sweeps, tol=0.0,
              scale_input=False, backend="stacks")

    # ---- gate 1: builds == 1 across the drifting chain, bitwise parity ---
    plan_mod.clear_cache()
    want, _ = sign_iteration(x, mode="fused", sync_every=sweeps, **kw)
    plan_mod.clear_cache()
    got, st_env = sign_iteration(x, mode="fused", sync_every=sweeps,
                                 envelope="auto", **kw)
    stats = plan_mod.cache_stats()
    assert st_env.envelope and st_env.retraces == 1, st_env
    assert stats["builds"] == 1, stats
    assert stats["chain_misses"] == 1, stats
    assert stats["chain_hits"] == sweeps - 1, stats
    assert stats["envelope_misses"] == 1, stats
    assert stats["drift_retunes"] == 0, stats
    assert np.array_equal(np.asarray(got.blocks), np.asarray(want.blocks))
    assert np.array_equal(np.asarray(got.mask), np.asarray(want.mask))
    # warm re-run re-hits the forecast + chain caches: zero retraces
    _, st_warm = sign_iteration(x, mode="fused", sync_every=sweeps,
                                envelope="auto", **kw)
    assert st_warm.retraces == 0, st_warm
    assert plan_mod.cache_stats()["envelope_hits"] >= 1

    # ---- gate 2: warm drift-path dispatch vs per-pattern retrace ---------
    # the retrace baseline is the legacy per-op loop with the same
    # compacted backend: every sweep walks the drifted pattern on the
    # host, re-compacts stacks and pays the eager-algebra dispatch pile;
    # the envelope sweep pays one dispatch of the one compiled program.
    # Both sides warm (all caches hit); paired back-to-back reps so shared
    # machine noise cancels out of the headline median-of-ratios.
    env = plan_mod.get_envelope(np.asarray(x.mask, bool),
                                np.asarray(x.norms, np.float32),
                                sweeps=sweeps, threshold=THRESHOLD,
                                filter_eps=FILTER_EPS, bs=x.bs_r)
    retrace_run = lambda: sign_iteration_legacy(x, **kw)  # noqa: E731
    env_run = _make_envelope_steady(x, mesh, env, sweeps, args.engine)
    retrace_run(), env_run()  # warm-up: compile + fill every cache level
    retrace_best, env_best = float("inf"), float("inf")
    pair_ratios = []
    for _ in range(reps):
        t0 = time.perf_counter()
        retrace_run()
        tr = (time.perf_counter() - t0) / sweeps
        t0 = time.perf_counter()
        env_run()
        te = (time.perf_counter() - t0) / sweeps
        retrace_best, env_best = min(retrace_best, tr), min(env_best, te)
        pair_ratios.append(tr / te)
    ratio = sorted(pair_ratios)[len(pair_ratios) // 2]
    chain_s = None
    for _ in range(reps):
        t0 = time.perf_counter()
        sign_iteration(x, mode="fused", sync_every=sweeps, envelope="auto",
                       **kw)
        dt = (time.perf_counter() - t0) / sweeps
        chain_s = dt if chain_s is None else min(chain_s, dt)

    # ---- gate 3: envelope padded-work overhead per corpus family ---------
    overheads = {}
    for kind in KINDS:
        worst = 0.0
        for seed in range(3):
            xf = _chain_operand(kind, OVERHEAD_NB, 4, seed, OVERHEAD_OCC)
            fenv = E.forecast_chain(
                np.asarray(xf.mask, bool), np.asarray(xf.norms, np.float32),
                sweeps=OVERHEAD_SWEEPS, threshold=OVERHEAD_THRESHOLD,
                filter_eps=OVERHEAD_THRESHOLD, bs=xf.bs_r)
            peak = _realized_peak(xf, OVERHEAD_SWEEPS, OVERHEAD_THRESHOLD,
                                  OVERHEAD_THRESHOLD)
            worst = max(worst, fenv.local_capacity() / max(peak, 1))
        overheads[kind] = worst
        assert worst <= OVERHEAD_BOUNDS[kind], (
            f"{kind}: envelope overhead {worst:.2f}x exceeds documented "
            f"bound {OVERHEAD_BOUNDS[kind]}x")

    report = {
        "bench": "envelope_chain",
        "backend": jax.default_backend(),
        "engine": args.engine,
        "nb": nb,
        "bs": bs,
        "sweeps": sweeps,
        "threshold": THRESHOLD,
        "filter_eps": FILTER_EPS,
        "builds": stats["builds"],
        "chain_misses": stats["chain_misses"],
        "envelope_misses": stats["envelope_misses"],
        "retrace_per_sweep_ms": retrace_best * 1e3,
        "envelope_per_sweep_ms": env_best * 1e3,
        "envelope_chain_per_sweep_ms": chain_s * 1e3,
        "drift_dispatch_ratio": ratio,
        "paired_ratios": pair_ratios,
        "overhead_by_family": overheads,
        "overhead_bounds": OVERHEAD_BOUNDS,
        "cache": plan_mod.cache_stats(),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"bench/envelope/builds,{stats['builds']},one program for "
          f"{sweeps} drifting sweeps")
    print(f"bench/envelope/retrace_per_sweep_ms,{retrace_best * 1e3:.3f},"
          f"per-pattern retrace (legacy stacks loop)")
    print(f"bench/envelope/envelope_per_sweep_ms,{env_best * 1e3:.3f},"
          f"steady-state envelope dispatch")
    print(f"bench/envelope/chain_per_sweep_ms,{chain_s * 1e3:.3f},"
          f"incl. chain boundaries + forecast-cache hit")
    print(f"bench/envelope/drift_dispatch_ratio,{ratio:.1f},"
          f"retrace/envelope (median of {reps} paired reps)")
    for kind, oh in overheads.items():
        print(f"bench/envelope/overhead_{kind},{oh:.2f},"
              f"capacity/peak realized (bound {OVERHEAD_BOUNDS[kind]}x)")
    print(f"wrote {args.out}")
    assert ratio >= 5.0, (
        f"envelope chain must cut drift-path dispatch >= 5x over "
        f"per-pattern retrace, got {ratio:.1f}x"
    )


if __name__ == "__main__":
    main()
