"""Plan-cache dispatch microbenchmark: repeated multiplies (the sign-
iteration hot path) must not retrace or re-lower after the first call.

Standalone (fake-device flag set before jax import), like measure_comm:

    python benchmarks/bench_plan_cache.py

Prints the first-call (compile) latency vs. the steady-state per-call
latency of ``multiply`` on 8x8 blocks, plus the plan-layer cache counters:
after warm-up the program cache takes only hits and the build counter stays
flat — no re-lowering on the hot path.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=16 " + os.environ.get("XLA_FLAGS", "")
)

import sys  # noqa: E402
import time  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.core import bsm as B  # noqa: E402
from repro.core import plan as plan_mod  # noqa: E402
from repro.core.engine import multiply  # noqa: E402
from repro.core.signiter import sign_iteration  # noqa: E402
from repro.launch.mesh import make_spgemm_mesh  # noqa: E402

NB, BS = 8, 8
REPS = 20


def main() -> None:
    mesh = make_spgemm_mesh(p=2, l=2)
    a = B.random_bsm(jax.random.key(0), nb=NB, bs=BS, occupancy=0.5,
                     pattern="decay", symmetric=True)
    b = B.random_bsm(jax.random.key(1), nb=NB, bs=BS, occupancy=0.5,
                     pattern="decay")

    plan_mod.clear_cache()
    t0 = time.perf_counter()
    multiply(a, b, mesh, engine="twofive").blocks.block_until_ready()
    first = time.perf_counter() - t0

    times = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        multiply(a, b, mesh, engine="twofive").blocks.block_until_ready()
        times.append(time.perf_counter() - t0)
    steady = sorted(times)[len(times) // 2]
    stats = plan_mod.cache_stats()

    print(f"bench/plan_cache/first_call_s,{first:.4f},")
    print(f"bench/plan_cache/steady_call_s,{steady:.4f},median of {REPS}")
    print(f"bench/plan_cache/speedup,{first / steady:.1f},first/steady")
    print(f"bench/plan_cache/stats,{stats},")
    assert stats["builds"] == 1 and stats["hits"] == REPS, stats
    assert steady < first, (first, steady)

    # the driving application, legacy per-op loop: Newton-Schulz sign
    # iteration (2 multiplies per sweep) reuses one cached program
    plan_mod.clear_cache()
    t0 = time.perf_counter()
    _, st = sign_iteration(a, mesh=mesh, engine="twofive", max_iter=6,
                           threshold=0.0, filter_eps=0.0, mode="legacy")
    total = time.perf_counter() - t0
    stats = plan_mod.cache_stats()
    print(f"bench/plan_cache/signiter_mults,{st.multiplications},"
          f"{total:.3f}s total, cache {stats}")
    assert stats["builds"] == 1, stats
    assert stats["hits"] == st.multiplications - 1, stats

    # fused chain mode: the whole sweep is one cached program — see
    # benchmarks/bench_signiter.py for the dispatch-overhead comparison
    plan_mod.clear_cache()
    t0 = time.perf_counter()
    _, st = sign_iteration(a, mesh=mesh, engine="twofive", max_iter=6,
                           threshold=0.0, filter_eps=0.0, mode="fused")
    total = time.perf_counter() - t0
    stats = plan_mod.cache_stats()
    print(f"bench/plan_cache/signiter_fused_sweeps,{st.iterations},"
          f"{total:.3f}s total, cache {stats}")
    assert stats["builds"] == 1, stats
    assert stats["chain_misses"] == 1, stats
    assert stats["chain_hits"] == st.iterations - 1, stats


if __name__ == "__main__":
    main()
