"""Table 2 reproduction: communicated data per process, strong scaling.

For every (benchmark, node count, L) cell of the paper's Table 2 we evaluate
Eq. (7) with the paper's matrix parameters:

    bytes/process = n_mults * [ (V/sqrt(L)) (S_A+S_B)  +  (L-1) S_C ]
    S_A = (N/P_R)(N/V) occ * 8B,  S_B = (N/V)(N/P_C) occ * 8B,
    S_C = (S_C/S_AB ratio) * mean(S_A, S_B)  (paper-measured ratios)

and compare against the paper's *measured* GB (COMM_GB).  This is the
validation that our implementation of the paper's communication model is
faithful — the same model drives the TPU engine (twofive.py) whose HLO
collective bytes are measured in tests/_dist.py::check_comm_volume.
"""
from __future__ import annotations

import math

from benchmarks.paper_data import COMM_GB, GRIDS, TABLE2_L
from repro.configs.dbcsr_benchmarks import BENCHMARKS, SC_OVER_SAB
from repro.core.commvolume import osl_volume
from repro.core.topology import make_topology


def model_comm_gb(bench_key: str, nodes: int, l: int) -> float:
    b = BENCHMARKS[bench_key]
    p_r, p_c = GRIDS[nodes]
    topo = make_topology(p_r, p_c, l)
    assert topo.l == l, (bench_key, nodes, l, "L invalid for this grid")
    n = b.n_rows
    v = topo.v
    s_a = (n / p_r) * (n / v) * b.occupancy * 8
    s_b = (n / v) * (n / p_c) * b.occupancy * 8
    s_c = SC_OVER_SAB[bench_key] * 0.5 * (s_a + s_b)
    rep = osl_volume(topo, s_a, s_b, s_c)
    return b.n_mults * rep.total / 1e9


def run() -> list[tuple[str, float, str]]:
    rows = []
    worst = 0.0
    for bench in BENCHMARKS:
        for nodes, cells in COMM_GB[bench].items():
            for l, paper_gb in cells.items():
                ours = model_comm_gb(bench, nodes, l)
                ratio = ours / paper_gb
                worst = max(worst, abs(math.log(ratio)))
                rows.append(
                    (
                        f"table2/{bench}/n{nodes}/L{l}",
                        round(ours, 1),
                        f"paper={paper_gb}GB ratio={ratio:.2f}",
                    )
                )
    rows.append(
        (
            "table2/worst_log_ratio",
            round(worst, 3),
            "max |log(model/paper)| over all 39 cells",
        )
    )
    return rows


def check() -> None:
    """Assert the Eq. (7) model tracks every Table 2 cell within 2x (the
    paper's own caveats: filtering changes effective occupancy per
    iteration, our occ is the single 'typical' value of Table 1)."""
    for bench in BENCHMARKS:
        for nodes, cells in COMM_GB[bench].items():
            for l, paper_gb in cells.items():
                ours = model_comm_gb(bench, nodes, l)
                assert 0.5 < ours / paper_gb < 2.0, (bench, nodes, l, ours, paper_gb)
    # and the sqrt(P) strong-scaling law between node counts (L=1 column)
    for bench in BENCHMARKS:
        g200 = model_comm_gb(bench, 400, 1)
        g2704 = model_comm_gb(bench, 2704, 1)
        expect = math.sqrt(2704 / 400)
        assert 0.7 * expect < g200 / g2704 < 1.4 * expect


if __name__ == "__main__":
    check()
    for name, val, note in run():
        print(f"{name},{val},{note}")
