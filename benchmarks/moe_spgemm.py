"""Beyond-paper carry-over: MoE expert compute as block-sparse SpGEMM.

The (token-block x expert) dispatch structure of an MoE layer IS a
block-sparse matrix: block row = a contiguous block of tokens, block col =
an expert, occupied iff any token in the block routes to that expert.  The
paper's on-the-fly filtering (skip products below a norm threshold) maps to
skipping (token-block, expert) pairs with no routed tokens — exactly what
the Pallas ``block_spgemm`` kernel's ``@pl.when`` predication does on the
MXU.

This benchmark measures the occupancy of that dispatch matrix for the
assigned MoE archs (top-k over E experts, realistic router entropy) and the
fraction of block products the filter removes — the FLOP savings the
SpGEMM view buys on TPU hardware.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_arch


def dispatch_occupancy(
    n_tokens: int, n_experts: int, top_k: int, token_block: int, key
) -> float:
    """Occupancy of the (token-block x expert) block mask under uniform-ish
    routing (worst case for filtering: balanced load)."""
    top_e = jax.random.randint(key, (n_tokens, top_k), 0, n_experts)
    nb = n_tokens // token_block
    blocks = top_e[: nb * token_block].reshape(nb, token_block * top_k)
    onehot = jax.nn.one_hot(blocks, n_experts).max(axis=1)  # (nb, E)
    return float(onehot.mean())


def run() -> list[tuple[str, float, str]]:
    rows = []
    cases = {
        "llama4_maverick_400b_a17b": None,  # 128e top-1
        "deepseek_moe_16b": None,  # 64e top-6
        "jamba_v0_1_52b": None,  # 16e top-2
    }
    for aid in cases:
        cfg = get_arch(aid)
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        for tb in (64, 256):
            occ = dispatch_occupancy(4096, e, k, tb, jax.random.key(0))
            rows.append(
                (
                    f"moe_spgemm/{aid}/tb{tb}/occupancy",
                    round(occ, 3),
                    f"E={e} top{k}; filter skips {1 - occ:.0%} of block products",
                )
            )
    return rows


def check() -> None:
    # top-1 of 128 experts with small token blocks is very sparse; the
    # filter removes most products — the SpGEMM view pays off most there
    occ_sparse = dispatch_occupancy(4096, 128, 1, 64, jax.random.key(0))
    occ_dense = dispatch_occupancy(4096, 16, 2, 256, jax.random.key(0))
    assert occ_sparse < 0.5
    assert occ_dense > occ_sparse


if __name__ == "__main__":
    check()
    for name, val, note in run():
        print(f"{name},{val},{note}")
