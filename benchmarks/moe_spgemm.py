"""Beyond-paper carry-over: MoE expert compute as block-sparse SpGEMM.

The (token-block x expert) dispatch structure of an MoE layer IS a
block-sparse matrix: block row = a contiguous block of tokens, block col =
an expert, occupied iff any token in the block routes to that expert.  The
paper's on-the-fly filtering (skip products below a norm threshold) maps to
skipping (token-block, expert) pairs with no routed tokens — exactly what
the Pallas ``block_spgemm`` kernel's ``@pl.when`` predication does on the
MXU.

Two parts:

* ``run()``/``check()`` (the ``benchmarks.run`` aggregation legs) measure
  the occupancy of that dispatch matrix for the assigned MoE archs (top-k
  over E experts) — the FLOP savings the SpGEMM view buys.

* ``main()`` (the CI ``--smoke`` leg, BENCH_moe_spgemm.json) runs the
  dispatch stream through the pattern-envelope layer (core/envelope.py):
  every serving batch routes tokens differently, so the per-batch dispatch
  mask DRIFTS — the per-pattern path re-walks the pattern and re-compacts
  on every batch, while ``multiply(..., envelope=union_envelope(stream))``
  executes every batch through ONE traced program with the concrete mask
  entering as data.  The smoke gates assert exactly that: one trace for
  the whole stream, zero per-batch pattern walks, every batch bit-correct
  against the per-pattern oracle.

NOTE: imported in-process by ``benchmarks/run.py`` — this module must not
set XLA_FLAGS or otherwise touch global process state at import time.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import get_arch  # noqa: E402


def dispatch_occupancy(
    n_tokens: int, n_experts: int, top_k: int, token_block: int, key
) -> float:
    """Occupancy of the (token-block x expert) block mask under uniform-ish
    routing (worst case for filtering: balanced load).

    Delegates to ``models.moe.dispatch_block_mask`` — the same function
    the serving ``spgemm`` impl builds its operand from, so this artifact
    and BENCH_serving.json cannot drift apart.
    """
    from repro.models.moe import dispatch_block_mask

    top_e = jax.random.randint(key, (n_tokens, top_k), 0, n_experts)
    nb = n_tokens // token_block
    mask = dispatch_block_mask(top_e[: nb * token_block], n_experts,
                               token_block)
    return float(mask.mean())


def dispatch_mask(nb_tok: int, n_experts: int, top_k: int,
                  tokens_per_block: int, key):
    """Concrete (nb_tok, E) block dispatch mask of one routed batch
    (``models.moe.dispatch_block_mask`` on sampled routing)."""
    import numpy as np

    from repro.models.moe import dispatch_block_mask

    top_e = jax.random.randint(key, (nb_tok * tokens_per_block, top_k),
                               0, n_experts)
    return np.asarray(dispatch_block_mask(top_e, n_experts,
                                          tokens_per_block))


def run() -> list[tuple[str, float, str]]:
    rows = []
    cases = {
        "llama4_maverick_400b_a17b": None,  # 128e top-1
        "deepseek_moe_16b": None,  # 64e top-6
        "jamba_v0_1_52b": None,  # 16e top-2
    }
    for aid in cases:
        cfg = get_arch(aid)
        e, k = cfg.moe.n_experts, cfg.moe.top_k
        for tb in (64, 256):
            occ = dispatch_occupancy(4096, e, k, tb, jax.random.key(0))
            rows.append(
                (
                    f"moe_spgemm/{aid}/tb{tb}/occupancy",
                    round(occ, 3),
                    f"E={e} top{k}; filter skips {1 - occ:.0%} of block products",
                )
            )
    return rows


def check() -> None:
    # top-1 of 128 experts with small token blocks is very sparse; the
    # filter removes most products — the SpGEMM view pays off most there
    occ_sparse = dispatch_occupancy(4096, 128, 1, 64, jax.random.key(0))
    occ_dense = dispatch_occupancy(4096, 16, 2, 256, jax.random.key(0))
    assert occ_sparse < 0.5
    assert occ_dense > occ_sparse
    # cross-artifact coupling: the occupancy legs and the serving impl
    # must be built from the same mask construction
    m = dispatch_mask(16, 8, 2, 4, jax.random.key(3))
    occ = dispatch_occupancy(64, 8, 2, 4, jax.random.key(3))
    assert abs(occ - float(m.mean())) < 1e-6


def main() -> None:
    """The envelope-stream smoke benchmark (CI leg)."""
    import argparse
    import json
    import time

    import numpy as np

    from repro.core import bsm as B
    from repro.core import envelope as E
    from repro.core import plan as plan_mod
    from repro.core.engine import _multiply_reference_jit, multiply

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--batches", type=int, default=None)
    ap.add_argument("--out", default="BENCH_moe_spgemm.json")
    args = ap.parse_args()

    nb_tok, n_experts, top_k, tpb = 8, 8, 2, 4
    bs = 8 if args.smoke else 16
    batches = args.batches or (6 if args.smoke else 12)
    reps = 3 if args.smoke else 10

    # block-diagonal expert weights: an (E, E) grid occupied on the diag
    eye = np.eye(n_experts, dtype=bool)
    wb = jax.random.normal(jax.random.key(1),
                           (n_experts, n_experts, bs, bs)) / np.sqrt(bs)
    w = B.make_bsm(wb, eye)

    # the drifting batch stream: per-batch routed dispatch masks
    masks = [dispatch_mask(nb_tok, n_experts, top_k, tpb, jax.random.key(s))
             for s in range(batches)]
    stream = []
    for s, m in enumerate(masks):
        blocks = jax.random.normal(jax.random.key(100 + s),
                                   (nb_tok, n_experts, bs, bs)) / np.sqrt(bs)
        stream.append(B.make_bsm(blocks, m))
    env = E.union_envelope(masks, [eye])
    assert all(env.covers(m, eye) for m in masks)

    # ---- correctness + one-trace gate across the whole drifting stream --
    plan_mod.clear_cache()
    _multiply_reference_jit.clear_cache()
    for a in stream:
        got = multiply(a, w, backend="stacks", envelope=env)
        want = multiply(a, w, backend="stacks")
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   np.asarray(want.to_dense()),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got.mask),
                                      np.asarray(want.mask))
    env_traces = int(_multiply_reference_jit._cache_size())
    stats = plan_mod.cache_stats()
    assert env_traces == 1, (
        f"the envelope stream must execute through ONE traced program, "
        f"traced {env_traces}")
    assert stats["drift_retunes"] == 0, stats
    # the baseline walked one pattern per batch; the envelope path none
    assert stats["pattern_misses"] >= batches, stats

    # ---- warm dispatch: envelope stream vs per-pattern retrace ----------
    def env_pass():
        for a in stream:
            out = multiply(a, w, backend="stacks", envelope=env)
        jax.block_until_ready(out.blocks)

    def retrace_pass():
        for a in stream:
            out = multiply(a, w, backend="stacks")
        jax.block_until_ready(out.blocks)

    env_pass(), retrace_pass()  # warm every cache level
    ratios, env_best, retrace_best = [], float("inf"), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        retrace_pass()
        tr = (time.perf_counter() - t0) / batches
        t0 = time.perf_counter()
        env_pass()
        te = (time.perf_counter() - t0) / batches
        env_best, retrace_best = min(env_best, te), min(retrace_best, tr)
        ratios.append(tr / te)
    ratio = sorted(ratios)[len(ratios) // 2]

    occ_rows = run()
    report = {
        "bench": "moe_spgemm_envelope_stream",
        "backend": jax.default_backend(),
        "nb_tok": nb_tok,
        "n_experts": n_experts,
        "top_k": top_k,
        "bs": bs,
        "batches": batches,
        "stream_occupancy": float(np.mean([m.mean() for m in masks])),
        "envelope_fill": float(np.asarray(env.mask_a).mean()),
        "envelope_traces": env_traces,
        "envelope_per_batch_ms": env_best * 1e3,
        "retrace_per_batch_ms": retrace_best * 1e3,
        "warm_dispatch_ratio": ratio,
        "paired_ratios": ratios,
        "cache": plan_mod.cache_stats(),
        "occupancy": {name: val for name, val, _ in occ_rows},
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"bench/moe_spgemm/envelope_traces,{env_traces},one program for "
          f"{batches} drifting batches")
    print(f"bench/moe_spgemm/envelope_per_batch_ms,{env_best * 1e3:.3f},")
    print(f"bench/moe_spgemm/retrace_per_batch_ms,{retrace_best * 1e3:.3f},")
    print(f"bench/moe_spgemm/warm_dispatch_ratio,{ratio:.2f},"
          f"retrace/envelope (median of {reps} paired reps)")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    check()
    for name, val, note in run():
        print(f"{name},{val},{note}")
    main()
