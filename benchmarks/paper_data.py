"""Published reference numbers from the paper (PASC '17) used as oracles.

Table 2: DBCSR total communicated data per process (GB) for the strong
scaling runs, and the grid/L layout per node count.  Fig. 3 ratio inputs
(measured S_C / S_{A,B}) are in configs/dbcsr_benchmarks.SC_OVER_SAB.
"""
from __future__ import annotations

# node count -> (P_R, P_C) process grid. 200 nodes is the paper's
# non-square example (virtual topology V = lcm = 20); the rest are square.
GRIDS = {
    200: (10, 20),
    400: (20, 20),
    729: (27, 27),
    1296: (36, 36),
    2704: (52, 52),
}

# node count -> L values reported in Table 2 (besides L=1).  Non-square 200
# forces L=2 (= mx/mn); square grids allow square L with sqrt(L) | P_R.
TABLE2_L = {
    200: (2,),
    400: (4,),
    729: (9,),
    1296: (4, 9),
    2704: (4,),
}

# Table 2, "DBCSR total communicated data per process (GB)":
# benchmark -> {nodes: {L: GB}}; L=1 covers both PTP and OS1 (equal volume).
COMM_GB = {
    "h2o_dft_ls": {
        200: {1: 640, 2: 491},
        400: {1: 318, 4: 228},
        729: {1: 236, 9: 145},
        1296: {1: 177, 4: 108, 9: 96},
        2704: {1: 122, 4: 70},
    },
    "s_e": {
        200: {1: 856, 2: 630},
        400: {1: 445, 4: 286},
        729: {1: 329, 9: 200},
        1296: {1: 247, 4: 140, 9: 125},
        2704: {1: 171, 4: 93},
    },
    "dense": {
        200: {1: 51, 2: 38},
        400: {1: 26, 4: 15},
        729: {1: 20, 9: 10},
        1296: {1: 15, 4: 8, 9: 6},
        2704: {1: 10, 4: 5},
    },
}

# Table 2, DBCSR execution time (seconds), PTP vs best OSL per node count
EXEC_S = {
    "h2o_dft_ls": {
        200: {"ptp": 325, "os1": 298, "best": 260},
        400: {"ptp": 212, "os1": 184, "best": 148},
        729: {"ptp": 155, "os1": 137, "best": 117},
        1296: {"ptp": 136, "os1": 120, "best": 85},
        2704: {"ptp": 99, "os1": 85, "best": 55},
    },
    "s_e": {
        200: {"ptp": 558, "os1": 500, "best": 459},
        400: {"ptp": 390, "os1": 310, "best": 310},
        729: {"ptp": 310, "os1": 246, "best": 246},
        1296: {"ptp": 282, "os1": 205, "best": 199},
        2704: {"ptp": 249, "os1": 178, "best": 172},
    },
    "dense": {
        200: {"ptp": 42.8, "os1": 43.0, "best": 42.8},
        400: {"ptp": 22.1, "os1": 21.9, "best": 21.9},
        729: {"ptp": 13.3, "os1": 13.3, "best": 13.3},
        1296: {"ptp": 11.2, "os1": 10.9, "best": 10.5},
        2704: {"ptp": 10.8, "os1": 10.0, "best": 9.7},
    },
}

# paper headline: best OSL speedup 1.80x (H2O-DFT-LS at 2704 nodes)
BEST_SPEEDUP = 1.80

# §4: fraction of DBCSR time in mpi_waitall for A/B at 2704 nodes
WAITALL_FRAC_2704 = {
    "h2o_dft_ls": {"ptp": 0.57, "os1": 0.50},
    "s_e": {"ptp": 0.32, "os1": 0.05},
    "dense": {"ptp": 0.41, "os1": 0.37},
}
