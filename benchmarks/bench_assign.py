"""Block→device assignment benchmark: per-device load spread + measured
multiply speedup of the nnz-balanced layouts on the application corpus.

Per corpus entry (the zipf hub family the distribution layer exists for,
plus the uniform and banded families it must NOT regress) and per
assignment mode {identity, randomized, nnz_greedy} the sweep reports:

  * **per-device product-load spread** — min/max/mean of
    ``distribute.device_product_loads`` on the 4x4 mesh grid and the
    max/mean imbalance factor.  Gated: on ``zipf_hub`` the identity
    layout is > 2x imbalanced and ``nnz_greedy`` lands <= 1.3x;
  * **compacted stack capacity** — ``plan.get_device_capacity`` of the
    (permuted) filter cube: the power-of-two bucket of the worst
    device's product count, i.e. the amount of padded gather-GEMM work
    every device executes.  Balancing shrinks the bucket — this is the
    mechanism that converts layout balance into wall time even on the
    fake-device CPU mesh (real meshes add the tick-barrier wait);
  * **measured multiply wall time** — min-of-reps of the SHARDED
    in-layout multiply at the tuner's chosen engine with the compacted
    stacks backend, per mode.  Sharded deliberately: a layout is decided
    once at the chain boundary (DBCSR pays its randomized permutation
    once at matrix creation), so the steady-state cost of a chain is the
    in-layout multiply — the one-time permute/scatter is not billed to
    every product.  Gated: on ``zipf_hub`` the nnz-balanced layout is
    >= 1.2x faster than identity, and on uniform/banded the tuner-chosen
    mode is within 5% of identity (no regression where there is nothing
    to balance);
  * **projected speedup** — the tuner model's own total-seconds ratio
    (identity vs mode, each priced at its exact per-mesh imbalance),
    the number ``rank_candidates`` uses to prefer a layout analytically.

Results go to BENCH_assign.json (CI perf-trajectory series; ``--smoke``
shrinks block sizes and reps but keeps the 32-block grid — the
imbalance statistic needs enough rows per device panel to be meaningful).

    python benchmarks/bench_assign.py [--smoke] [--out BENCH_assign.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=16 " + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import distribute as D  # noqa: E402
from repro.core import plan as plan_mod  # noqa: E402
from repro.core.engine import multiply  # noqa: E402
from repro.launch.mesh import make_spgemm_mesh  # noqa: E402
from repro.tuner import Candidate, autotune, featurize  # noqa: E402
from repro.tuner.corpus import CorpusEntry  # noqa: E402
from repro.tuner.model import (  # noqa: E402
    assignment_imbalances,
    estimate_candidate,
)

THRESHOLD = 1e-6
MODES = ("identity", "randomized", "nnz_greedy")


def entries(smoke: bool) -> list[CorpusEntry]:
    # nb=32 on the 4x4 mesh -> 8-row device panels: enough rows that the
    # hub concentration (and its cure) is visible in the device loads
    # bs must be large enough that the per-device padded gather-GEMM work
    # (stack_capacity x bs^3 MACs) dominates dispatch on the host mesh —
    # that work is what balancing shrinks
    nb, bs = (32, 16) if smoke else (32, 32)
    return [
        CorpusEntry("zipf_hub", "zipf", nb, bs,
                    occupancy=0.15, zipf_alpha=1.4, seed=15),
        CorpusEntry("uniform_flat", "uniform", nb, bs,
                    occupancy=0.15, seed=17),
        CorpusEntry("dft_chain_banded", "dft_chain", nb, bs,
                    bandwidth=max(1, nb // 8), seed=11),
    ]


def walltime(run, reps: int) -> float:
    out = run()
    jax.block_until_ready((out.blocks, out.mask, out.norms))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = run()
        jax.block_until_ready((out.blocks, out.mask, out.norms))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_entry(entry: CorpusEntry, mesh, reps: int) -> dict:
    a, b = entry.build()
    ma, mb = np.asarray(a.mask, bool), np.asarray(b.mask, bool)
    counts = D.product_counts(ma, mb)
    ok = ma[:, :, None] & mb[None, :, :]
    p_r, p_c = int(mesh.shape["r"]), int(mesh.shape["c"])
    feats = featurize(a, b, THRESHOLD)
    imbs = assignment_imbalances(counts, mesh)

    # the tuner's choice for this pattern (engine + layout), measured
    plan_mod.clear_cache()
    dec = autotune(a, b, mesh, threshold=THRESHOLD, top_k=3, reps=reps)
    engine = dec.engine

    def time_mode(asg) -> float:
        # steady-state chain cost: operands already live in the layout
        # (the one-time permute/scatter is the chain boundary's bill)
        from repro.core import bsm as B

        ha = B.shard_bsm(a, mesh, assignment=asg)
        hb = B.shard_bsm(b, mesh, assignment=asg)
        return walltime(
            lambda: multiply(ha, hb, None, engine=engine,
                             threshold=THRESHOLD, backend="stacks",
                             transport="dense"), reps)

    modes = {}
    for mode in MODES:
        asg = D.compute_assignment(mode, ma, mb, mesh)
        loads = D.device_product_loads(counts, p_r, p_c, perm=asg.perm)
        ok_m = ok if asg.is_identity else D.permute_cube(ok, asg.perm)
        cap = plan_mod.get_device_capacity(ok_m, mesh, engine)
        # the model's own projection: seconds priced at each layout's
        # exact imbalance, compacted backend (the slowest device gates
        # every tick).  The compute term carries the whole effect; the
        # total folds in the (layout-independent) comm term.
        est = estimate_candidate(
            Candidate(engine, dec.l, "stacks", cap, assign=mode), mesh,
            feats, imbalance=imbs.get(mode, 1.0))
        modes[mode] = {
            "imbalance": imbs.get(mode, 1.0),
            "load_min": int(loads.min()),
            "load_max": int(loads.max()),
            "load_mean": float(loads.mean()),
            "stack_capacity": cap,
            "host_ms": time_mode(None if mode == "identity" else asg) * 1e3,
            "model_total_us": est.total_s * 1e6,
            "model_compute_us": est.compute_s * 1e6,
        }
    ident = modes["identity"]
    for mode, row in modes.items():
        row["host_speedup_vs_identity"] = ident["host_ms"] / row["host_ms"]
        row["projected_speedup_vs_identity"] = (
            ident["model_total_us"] / row["model_total_us"])
        row["projected_compute_speedup"] = (
            ident["model_compute_us"] / row["model_compute_us"])
    return {
        "entry": entry.name,
        "kind": entry.kind,
        "nb": entry.nb,
        "bs": entry.bs,
        "engine": engine,
        "tuner_backend": dec.backend,
        "tuner_assign": dec.assign,
        "modes": modes,
    }


def run_bench(smoke: bool) -> dict:
    mesh = make_spgemm_mesh(p=4)
    reps = 2 if smoke else 4
    rows = [bench_entry(e, mesh, reps) for e in entries(smoke)]
    return {"smoke": smoke, "mesh": "4x4", "threshold": THRESHOLD,
            "rows": rows}


def check(result: dict) -> None:
    by_name = {r["entry"]: r for r in result["rows"]}
    z = by_name["zipf_hub"]["modes"]
    # the hub family is materially imbalanced and the greedy packer
    # flattens it within the gate
    assert z["identity"]["imbalance"] > 2.0, z["identity"]
    assert z["nnz_greedy"]["imbalance"] <= 1.3, z["nnz_greedy"]
    # balancing shrinks the padded-work bucket every device executes...
    assert z["nnz_greedy"]["stack_capacity"] < \
        z["identity"]["stack_capacity"], z
    # ...which converts to measured wall time at the tuner's engine
    assert z["nnz_greedy"]["host_speedup_vs_identity"] >= 1.2, z
    # the model agrees, and its slowest-device compute term carries the
    # effect (the comm term is layout-independent)
    assert z["nnz_greedy"]["projected_speedup_vs_identity"] > 1.0, z
    assert z["nnz_greedy"]["projected_compute_speedup"] >= 1.5, z
    # balanced families: the tuner-chosen layout must not regress
    for name in ("uniform_flat", "dft_chain_banded"):
        row = by_name[name]
        chosen = row["modes"].get(row["tuner_assign"],
                                  row["modes"]["identity"])
        assert chosen["host_speedup_vs_identity"] >= 0.95, (name, chosen)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    result = run_bench(args.smoke)
    check(result)
    for r in result["rows"]:
        parts = ", ".join(
            f"{m}: imb {v['imbalance']:.2f} cap {v['stack_capacity']} "
            f"x{v['host_speedup_vs_identity']:.2f}"
            for m, v in r["modes"].items())
        print(f"assign/{r['entry']}/{r['engine']} "
              f"(tuner: {r['tuner_assign']}) {parts}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
