"""Transport-layer benchmark: compressed vs dense panels across an
occupancy sweep (bytes on the wire + wall time), on the application-
pattern corpus (``repro.tuner.corpus``).

Per (corpus entry, engine) the sweep measures:

  * **wire bytes** — per-device collective bytes of the compiled HLO,
    dense vs compressed transport (the same measurement
    ``benchmarks/measure_comm.py`` asserts): compressed must reach
    <= 35% of dense on at least one low-occupancy entry — the
    load-balanced uniform family; distance-correlated families
    (banded/decay) concentrate occupied blocks in diagonal panels, so
    their per-panel capacity is the densest panel's count and their
    ratio is reported, not gated;
  * **host wall time** — min-of-reps multiply wall time on the fake-
    device CPU mesh, both modes.  Reported for the trajectory, NOT
    asserted: XLA's host "collectives" are intra-process memcpys, so
    byte savings do not convert to wall time here the way they do on a
    real interconnect (the pack/unpack scatter work is all the host
    sees);
  * **projected interconnect-bound wall time** — the measured HLO bytes
    fed through the same roofline cost model the tuner ranks with
    (bytes / ICI_BW + per-tick dispatch + local FLOPs at the compacted
    backend's occupancy): the transport PR's headline — >= 1.3x over
    the dense path on at least one low-occupancy corpus entry — is
    asserted on this projection, with the measured byte ratio as its
    load-bearing input.

Also re-checks bit-exactness (compressed == dense results) on every
entry it times — never report numbers off a wrong result.

Results go to BENCH_transport.json (CI perf-trajectory series;
``--smoke`` shrinks the sweep).

    python benchmarks/bench_transport.py [--smoke] [--out BENCH_transport.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=16 " + os.environ.get("XLA_FLAGS", "")
)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import plan as plan_mod  # noqa: E402
from repro.core.commvolume import plan_volume  # noqa: E402
from repro.core.engine import lower_multiply, multiply  # noqa: E402
from repro.core.local_mm import backend_local_cost  # noqa: E402
from repro.launch.mesh import make_spgemm_mesh  # noqa: E402
from repro.roofline import ICI_BW, PEAK_FLOPS  # noqa: E402
from repro.roofline.hlo_cost import analyze_hlo  # noqa: E402
from repro.tuner.corpus import CorpusEntry  # noqa: E402
from repro.tuner.features import featurize  # noqa: E402

THRESHOLD = 1e-6
LOW_OCC = 0.12  # entries at or below this block occupancy are "low"


def entries(smoke: bool) -> list[CorpusEntry]:
    # shards must hold enough blocks that the packing-bucket floor does
    # not dominate (nb=32 on the 4x4 mesh -> 64-block shards).  The
    # uniform (load-balanced) family is where per-panel capacities track
    # global occupancy; the distance-correlated families show the
    # diagonal-concentration effect (capacity = the densest panel).
    nb, bs = (32, 8) if smoke else (32, 16)
    out = [
        CorpusEntry("uniform_sparse", "uniform", nb, bs,
                    occupancy=0.05, seed=17),
        CorpusEntry("exp_decay_sparse", "exp_decay", nb, bs,
                    occupancy=0.05, seed=13),
        CorpusEntry("exp_decay_mid", "exp_decay", nb, bs,
                    occupancy=0.2, seed=14),
    ]
    if not smoke:
        out.append(CorpusEntry("uniform_10", "uniform", nb, bs,
                               occupancy=0.1, seed=18))
        out.append(CorpusEntry("dft_chain_narrow", "dft_chain", nb, bs,
                               bandwidth=max(1, nb // 16), seed=11))
        out.append(CorpusEntry("exp_decay_filled", "exp_decay", nb, bs,
                               occupancy=0.45, seed=15))
        out.append(CorpusEntry("zipf_hub", "zipf", nb, bs,
                               occupancy=0.1, zipf_alpha=1.4, seed=16))
    return out


def wire_bytes(mesh, nb: int, bs: int, engine: str, transport) -> float:
    lowered = lower_multiply(mesh, nb, bs, engine=engine,
                             threshold=THRESHOLD, transport=transport)
    rep = analyze_hlo(lowered.compile().as_text(), default_group=mesh.size)
    return rep.collective_wire_bytes


def walltime(run, reps: int) -> float:
    out = run()
    jax.block_until_ready((out.blocks, out.mask, out.norms))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = run()
        jax.block_until_ready((out.blocks, out.mask, out.norms))
        best = min(best, time.perf_counter() - t0)
    return best


def projected_s(bytes_on_wire: float, plan, feats, ndev: int) -> float:
    """Interconnect-bound roofline projection: measured wire bytes at
    ICI rate + compacted-backend local FLOPs (identical in both modes —
    only the bytes differ).  The tuner's per-tick dispatch term is
    deliberately excluded: it is identical in both modes AND the
    double-buffered schedule exists precisely to hide it behind the
    local GEMM, so the non-overlappable cost is bytes + FLOPs."""
    local = backend_local_cost(
        feats.nb_r, feats.nb_k, feats.nb_c,
        feats.bs_r, feats.bs_k, feats.bs_c,
        fill=feats.product_fill, backend="stacks",
    )
    return bytes_on_wire / ICI_BW + local / ndev / PEAK_FLOPS


def bench_entry(entry: CorpusEntry, mesh, engine: str, reps: int) -> dict:
    a, b = entry.build()
    feats = featurize(a, b, THRESHOLD)
    mask_a = np.asarray(a.mask, bool)
    mask_b = np.asarray(b.mask, bool)
    tr = plan_mod.get_transport(mask_a, mask_b, mesh, engine,
                                mode="compressed")
    plan = plan_mod.plan_multiply(mesh, engine)

    by_dense = wire_bytes(mesh, entry.nb, entry.bs, engine, None)
    by_comp = wire_bytes(mesh, entry.nb, entry.bs, engine, tr)
    model_comp = plan_volume(plan, entry.nb, entry.bs,
                             transport=tr).total

    def run(transport):
        return multiply(a, b, mesh, engine=engine, threshold=THRESHOLD,
                        backend="stacks", transport=transport)

    # correctness first: compressed must equal dense bitwise
    cd, cc = run("dense"), run(tr)
    np.testing.assert_array_equal(np.asarray(cc.blocks),
                                  np.asarray(cd.blocks))
    np.testing.assert_array_equal(np.asarray(cc.mask), np.asarray(cd.mask))

    wt_dense = walltime(lambda: run("dense"), reps)
    wt_comp = walltime(lambda: run(tr), reps)
    ndev = mesh.size
    proj_dense = projected_s(by_dense, plan, feats, ndev)
    proj_comp = projected_s(by_comp, plan, feats, ndev)
    return {
        "entry": entry.name,
        "engine": engine,
        "nb": entry.nb,
        "bs": entry.bs,
        "occupancy": feats.occ_a,
        "cap_a": tr.cap_a,
        "cap_b": tr.cap_b,
        "bytes_dense": by_dense,
        "bytes_compressed": by_comp,
        "bytes_ratio": by_comp / by_dense,
        "model_bytes_compressed": model_comp,
        "host_ms_dense": wt_dense * 1e3,
        "host_ms_compressed": wt_comp * 1e3,
        "host_speedup": wt_dense / wt_comp,
        "projected_us_dense": proj_dense * 1e6,
        "projected_us_compressed": proj_comp * 1e6,
        "projected_speedup": proj_dense / proj_comp,
    }


def run_bench(smoke: bool) -> dict:
    mesh = make_spgemm_mesh(p=4)
    reps = 2 if smoke else 4
    engines = ("onesided",) if smoke else ("onesided", "gather")
    rows = []
    for entry in entries(smoke):
        for engine in engines:
            rows.append(bench_entry(entry, mesh, engine, reps))
    return {"smoke": smoke, "mesh": "4x4", "threshold": THRESHOLD,
            "rows": rows}


def check(result: dict) -> None:
    rows = result["rows"]
    low = [r for r in rows if r["occupancy"] <= LOW_OCC]
    assert low, "sweep has no low-occupancy entry"
    for r in rows:
        # the sparsity-aware model predicts the compressed HLO bytes
        assert 0.8 < r["bytes_compressed"] / r["model_bytes_compressed"] \
            < 1.25, (r["entry"], r["engine"])
    # bytes-on-wire collapse to <= 35% of dense on a load-balanced
    # low-occupancy entry (diagonal-concentrated families keep panel
    # capacities at the densest panel — reported, not gated)
    assert any(r["bytes_ratio"] <= 0.35 for r in low), [
        (r["entry"], r["engine"], r["bytes_ratio"]) for r in low
    ]
    # >= 1.3x projected interconnect-bound improvement on at least one
    # low-occupancy corpus entry (measured bytes driving the projection)
    best = max(r["projected_speedup"] for r in low)
    assert best >= 1.3, [
        (r["entry"], r["engine"], r["projected_speedup"]) for r in low
    ]
    # the byte saving must shrink as fill rises (sanity of the sweep)
    by_occ = sorted(rows, key=lambda r: r["occupancy"])
    assert by_occ[0]["bytes_ratio"] < by_occ[-1]["bytes_ratio"], (
        by_occ[0], by_occ[-1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    result = run_bench(args.smoke)
    check(result)
    for r in result["rows"]:
        print(f"transport/{r['entry']}/{r['engine']}/bytes_ratio,"
              f"{r['bytes_ratio']:.3f},occ {r['occupancy']:.2f}; "
              f"projected x{r['projected_speedup']:.2f}; "
              f"host x{r['host_speedup']:.2f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
